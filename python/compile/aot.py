"""AOT compile path: lower every (model × step) graph to HLO *text* and
write the artifact manifest the Rust coordinator loads.

HLO text — NOT `lowered.compiler_ir("hlo")`/.serialize() — is the
interchange format: jax ≥ 0.5 serializes HloModuleProto with 64-bit
instruction ids which xla_extension 0.5.1 (the version the `xla` crate
binds) rejects; the text parser reassigns ids and round-trips cleanly.
See /opt/xla-example/README.md.

All step functions return a single array (never a tuple), so we lower with
``return_tuple=False`` and the Rust side gets a plain array output buffer it
can feed straight back into the next `execute_b` call (device-resident
training state).

Usage:  cd python && python -m compile.aot --out-dir ../artifacts [--models ace-sim,...]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import configs, model, steps
from .configs import BF16, ModelCfg, quant_cfg_for

MANIFEST_VERSION = 4


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    return comp.as_hlo_text()


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def _arg(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


class ArtifactBuilder:
    def __init__(self, out_dir: str, verbose: bool = True):
        self.out_dir = out_dir
        self.verbose = verbose
        # Partial rebuilds (--models X) must not clobber other models'
        # manifest entries: merge with the existing manifest if compatible.
        existing = {}
        path = os.path.join(out_dir, "manifest.json")
        if os.path.exists(path):
            try:
                with open(path) as f:
                    old = json.load(f)
                if old.get("version") == MANIFEST_VERSION:
                    existing = old.get("models", {})
            except (OSError, json.JSONDecodeError):
                pass
        self.manifest = {
            "version": MANIFEST_VERSION,
            "vocab": configs.VOCAB,
            "special": {"pad": configs.PAD, "bos": configs.BOS, "eos": configs.EOS, "sep": configs.SEP},
            "n_scalars": steps.N_SCALARS,
            "scalar_names": ["step", "loss", "kl", "ce", "grad_norm", "lr", "aux0", "aux1"],
            "models": existing,
        }

    def model_entry(self, cfg: ModelCfg):
        # Rebuild the entry the first time a model is touched in this run
        # (a merged-in entry from a previous manifest may describe a stale
        # config); only untouched models keep their old entries.
        if not hasattr(self, "_touched"):
            self._touched = set()
        if cfg.name not in self._touched:
            self._touched.add(cfg.name)
            self.manifest["models"].pop(cfg.name, None)
        entry = self.manifest["models"].get(cfg.name)
        if entry is None:
            qc = quant_cfg_for(cfg.name)
            entry = {
                "d_model": cfg.d_model,
                "n_heads": cfg.n_heads,
                "d_ff": cfg.d_ff,
                "blocks": list(cfg.blocks),
                "n_experts": cfg.n_experts,
                "vocab": cfg.vocab,
                "seq_len": cfg.seq_len,
                "batch": cfg.batch,
                "vision": cfg.vision,
                "vision_grid": cfg.vision_grid,
                "vision_patch": cfg.vision_patch,
                "param_count": model.param_count(cfg),
                "state_len": steps.state_len(cfg),
                "quant": {
                    "weights": qc.weights,
                    "acts": qc.acts,
                    "impl": qc.impl,
                    "skip_attention": qc.skip_attention,
                    "skip_first": qc.skip_first,
                    "skip_last": qc.skip_last,
                },
                "params": [
                    {"name": n, "shape": list(s), "offset": o, "size": z}
                    for n, s, o, z in model.param_layout(cfg)
                ],
                "artifacts": {},
            }
            self.manifest["models"][cfg.name] = entry
        return entry

    def lower(self, cfg: ModelCfg, key: str, fn, example_args, arg_descr):
        entry = self.model_entry(cfg)
        rel = f"{cfg.name}/{key}.hlo.txt"
        path = os.path.join(self.out_dir, rel)
        os.makedirs(os.path.dirname(path), exist_ok=True)
        t0 = time.time()
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        out_aval = lowered.out_info
        entry["artifacts"][key] = {
            "file": rel,
            "args": arg_descr,
            "out_shape": list(np.shape(out_aval)) if hasattr(out_aval, "shape") else None,
        }
        if self.verbose:
            print(f"  [{cfg.name}] {key}: {len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s")

    def save_manifest(self):
        path = os.path.join(self.out_dir, "manifest.json")
        with open(path, "w") as f:
            json.dump(self.manifest, f, indent=1)
        print(f"wrote {path}")


def _io_shapes(cfg: ModelCfg):
    B, S = cfg.batch, cfg.seq_len
    n = steps.state_len(cfg)
    p = model.param_count(cfg)
    state = _sds((n,), jnp.float32)
    params = _sds((p,), jnp.float32)
    tokens = _sds((B, S), jnp.int32)
    mask = _sds((B, S), jnp.float32)
    lr = _sds((), jnp.float32)
    adv = _sds((B,), jnp.float32)
    idx = _sds((B,), jnp.int32)
    pix = (
        _sds((B, cfg.vision_grid**2, cfg.vision_patch), jnp.float32) if cfg.vision else None
    )
    return state, params, tokens, mask, lr, adv, idx, pix


def _pix_args(cfg, pix):
    if cfg.vision:
        return [pix], [_arg("pixels", pix.shape, "f32")]
    return [], []


def build_model_artifacts(b: ArtifactBuilder, name: str, full: bool = True):
    base = configs.ZOO[name]  # BF16 config
    qcfg = base.with_quant(quant_cfg_for(name))
    impl = "pallas" if name in configs.PALLAS_MODELS else "jnp"
    state, params, tokens, mask, lr, adv, idx, pix = _io_shapes(base)
    pargs, pdesc = _pix_args(base, pix)

    st_d = [_arg("state", state.shape, "f32")]
    pa_d = [_arg("params", params.shape, "f32")]
    tp_d = [_arg("teacher_params", params.shape, "f32")]
    tk_d = [_arg("tokens", tokens.shape, "i32")]
    mk_d = [_arg("mask", mask.shape, "f32")]
    lr_d = [_arg("lr", (), "f32")]
    adv_d = [_arg("advantage", adv.shape, "f32")]
    ix_d = [_arg("frontier_idx", idx.shape, "i32")]

    # --- forward passes -------------------------------------------------
    fwd_b = steps.make_fwd(base)
    fwd_q = steps.make_fwd(qcfg)
    b.lower(base, "fwd_bf16", lambda p, t, *px: fwd_b(p, t, *px), [params, tokens, *pargs], pa_d + tk_d + pdesc)
    b.lower(base, "fwd_nvfp4", lambda p, t, *px: fwd_q(p, t, *px), [params, tokens, *pargs], pa_d + tk_d + pdesc)

    # Frontier-gather twins: fused forward + per-row dynamic slice of the
    # logits at a frontier-index input -> (B, V). The Rust decode loop
    # (`Sampler::generate`) downloads B·V floats per emitted token through
    # these instead of the full B·S·V tensor, falling back transparently
    # when an older manifest lacks the keys.
    fwd_last_b = steps.make_fwd_last(base)
    fwd_last_q = steps.make_fwd_last(qcfg)
    b.lower(
        base, "fwd_last_bf16", lambda p, t, i, *px: fwd_last_b(p, t, i, *px),
        [params, tokens, idx, *pargs], pa_d + tk_d + ix_d + pdesc,
    )
    b.lower(
        base, "fwd_last_nvfp4", lambda p, t, i, *px: fwd_last_q(p, t, i, *px),
        [params, tokens, idx, *pargs], pa_d + tk_d + ix_d + pdesc,
    )

    # Device-side scalar-block slice: the CPU PJRT plugin has no
    # CopyRawToHost, so the Rust loop reads per-step metrics through this
    # 8-float artifact instead of downloading the whole state.
    n_scal = steps.N_SCALARS
    b.lower(
        base, "scalars", lambda s: s[-n_scal:], [state], st_d,
    )
    # fwd over the params inside a *state* vector — used for device-resident
    # rollout generation during the RL stage (no host round-trip of params).
    pcount = model.param_count(base)
    b.lower(
        base, "fwd_bf16_state",
        lambda s, t, *px: fwd_b(s[:pcount], t, *px),
        [state, tokens, *pargs], st_d + tk_d + pdesc,
    )
    b.lower(
        base, "fwd_last_bf16_state",
        lambda s, t, i, *px: fwd_last_b(s[:pcount], t, i, *px),
        [state, tokens, idx, *pargs], st_d + tk_d + ix_d + pdesc,
    )

    # --- teacher-precision training (stage 1 SFT) ------------------------
    sft = steps.make_sft_step(base)
    b.lower(
        base, "sft_bf16", lambda s, t, m, l, *px: sft(s, t, m, l, *px),
        [state, tokens, mask, lr, *pargs], st_d + tk_d + mk_d + lr_d + pdesc,
    )

    # --- QAT / QAD / eval -------------------------------------------------
    qat = steps.make_sft_step(qcfg)
    b.lower(
        base, "qat_nvfp4", lambda s, t, m, l, *px: qat(s, t, m, l, *px),
        [state, tokens, mask, lr, *pargs], st_d + tk_d + mk_d + lr_d + pdesc,
    )
    qad = steps.make_qad_step(qcfg, base, impl)
    b.lower(
        base, "qad_nvfp4", lambda s, tp, t, m, l, *px: qad(s, tp, t, m, l, *px),
        [state, params, tokens, mask, lr, *pargs], st_d + tp_d + tk_d + mk_d + lr_d + pdesc,
    )
    ev_q = steps.make_eval_metrics(qcfg, base, impl)
    b.lower(
        base, "eval_nvfp4", lambda p, tp, t, m, *px: ev_q(p, tp, t, m, *px),
        [params, params, tokens, mask, *pargs], pa_d + tp_d + tk_d + mk_d + pdesc,
    )
    ev_b = steps.make_eval_metrics(base, base, impl)
    b.lower(
        base, "eval_bf16", lambda p, tp, t, m, *px: ev_b(p, tp, t, m, *px),
        [params, params, tokens, mask, *pargs], pa_d + tp_d + tk_d + mk_d + pdesc,
    )

    if not full:
        return

    # --- RL stage (RL-heavy models) ---------------------------------------
    if name in ("ace-sim", "nano3-sim"):
        rl = steps.make_rl_step(base)
        b.lower(
            base, "rl_bf16", lambda s, t, m, a, l, *px: rl(s, t, m, a, l, *px),
            [state, tokens, mask, adv, lr, *pargs], st_d + tk_d + mk_d + adv_d + lr_d + pdesc,
        )

    # --- MSE distillation baseline (Table 8: ace + nano) ------------------
    if name in ("ace-sim", "nano-sim"):
        mse = steps.make_mse_step(qcfg, base)
        b.lower(
            base, "mse_nvfp4", lambda s, tp, t, m, l, *px: mse(s, tp, t, m, l, *px),
            [state, params, tokens, mask, lr, *pargs], st_d + tp_d + tk_d + mk_d + lr_d + pdesc,
        )

    # --- native-quantized-training proxy + format baselines (ace only) ----
    if name == "ace-sim":
        nqt = steps.make_sft_step(qcfg, quantize_grads=True)
        b.lower(
            base, "nqt_nvfp4", lambda s, t, m, l, *px: nqt(s, t, m, l, *px),
            [state, tokens, mask, lr, *pargs], st_d + tk_d + mk_d + lr_d + pdesc,
        )
        for fmt in ("mxfp4", "int4"):
            fcfg = base.with_quant(quant_cfg_for(name, fmt))
            fwd_f = steps.make_fwd(fcfg)
            b.lower(
                base, f"fwd_{fmt}", lambda p, t, *px: fwd_f(p, t, *px),
                [params, tokens, *pargs], pa_d + tk_d + pdesc,
            )
            fwd_last_f = steps.make_fwd_last(fcfg)
            b.lower(
                base, f"fwd_last_{fmt}", lambda p, t, i, *px: fwd_last_f(p, t, i, *px),
                [params, tokens, idx, *pargs], pa_d + tk_d + ix_d + pdesc,
            )

    # --- cross-size teacher (Table 9: nano student, super teacher) --------
    if name == "nano-sim":
        sup = configs.ZOO["super-sim"]
        sup_params = _sds((model.param_count(sup),), jnp.float32)
        qad_x = steps.make_qad_step(qcfg, sup, impl)
        b.lower(
            base, "qad_nvfp4_xsuper",
            lambda s, tp, t, m, l, *px: qad_x(s, tp, t, m, l, *px),
            [state, sup_params, tokens, mask, lr, *pargs],
            st_d + [_arg("teacher_params", sup_params.shape, "f32")] + tk_d + mk_d + lr_d + pdesc,
        )


def write_golden(out_dir: str):
    """Golden vectors for the Rust quant substrate: the JAX oracle's NVFP4
    quantization of fixed tensors, compared bit-exactly by
    rust/tests/golden_cross_validation.rs."""
    import numpy as np

    from .kernels import ref

    rng = np.random.default_rng(0x601de)
    golden = {}
    # E4M3 round-trip across the full range incl. ties/subnormals.
    xs = np.concatenate(
        [
            rng.normal(size=256) * 100,
            rng.uniform(-500, 500, size=128),
            [0.0, 448.0, -448.0, 1e9, -1e9, 2.0**-9, 2.0**-10, 0.75 * 2**-6],
        ]
    ).astype(np.float32)
    golden["e4m3_in"] = [float(v) for v in xs]
    golden["e4m3_out"] = [float(v) for v in np.asarray(ref.e4m3_round(jnp.asarray(xs)))]
    # E2M1 grid behaviour.
    ys = np.concatenate(
        [rng.normal(size=128) * 3, [0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0, -2.5, 8.0]]
    ).astype(np.float32)
    golden["e2m1_in"] = [float(v) for v in ys]
    golden["e2m1_out"] = [float(v) for v in np.asarray(ref.e2m1_round(jnp.asarray(ys)))]
    # Full NVFP4 fake-quant of a (8, 64) tensor with outliers.
    t = (rng.normal(size=(8, 64)) * 2.0).astype(np.float32)
    t[1, 3] = 77.0
    t[5, 16:32] = 0.0
    deq, codes, scales = ref.nvfp4_quantize_ref(jnp.asarray(t))
    golden["nvfp4_in"] = [float(v) for v in t.reshape(-1)]
    golden["nvfp4_deq"] = [float(v) for v in np.asarray(deq).reshape(-1)]
    golden["nvfp4_codes"] = [float(v) for v in np.asarray(codes).reshape(-1)]
    golden["nvfp4_scales"] = [float(v) for v in np.asarray(scales).reshape(-1)]
    golden["nvfp4_tensor_scale"] = float(ref.nvfp4_tensor_scale(jnp.asarray(t)))
    golden["nvfp4_rows"] = 8
    golden["nvfp4_cols"] = 64
    # MXFP4 + INT4 baselines on the same tensor.
    golden["mxfp4_deq"] = [
        float(v) for v in np.asarray(ref.mxfp4_fake_quant_ref(jnp.asarray(t))).reshape(-1)
    ]
    golden["int4_deq"] = [
        float(v) for v in np.asarray(ref.int4_fake_quant_ref(jnp.asarray(t))).reshape(-1)
    ]
    path = os.path.join(out_dir, "golden.json")
    with open(path, "w") as f:
        json.dump(golden, f)
    print(f"wrote {path}")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default=None, help="comma-separated subset of the zoo")
    args = ap.parse_args()

    names = args.models.split(",") if args.models else list(configs.ZOO)
    os.makedirs(args.out_dir, exist_ok=True)
    b = ArtifactBuilder(args.out_dir)
    t0 = time.time()
    for name in names:
        full = not name.startswith("size-")
        print(f"lowering {name} (full={full}) ...")
        build_model_artifacts(b, name, full=full)
    b.save_manifest()
    write_golden(args.out_dir)
    print(f"total {time.time() - t0:.1f}s")


if __name__ == "__main__":
    main()
