"""L2: the sim model zoo — decoder LMs with NVFP4 fake-quantized GEMMs.

Architecture kinds (configs.ModelCfg.blocks):
  * "attn" — pre-LN causal multi-head attention + MLP (transformer block)
  * "ssm"  — gated diagonal linear recurrence (Mamba-2 proxy) via
             lax.associative_scan
  * "moe"  — top-2-of-E expert MLP with a softmax router (dense compute,
             mask-combine — shapes stay static for AOT)

plus an optional grid-image patch embedder for the VLM sim.

Every GEMM routes through `qgemm`, which applies the configured fake-quant
(L1 kernel via kernels.fake_quant, straight-through gradient) to the weight
and/or activation operands — including the paper's *selective quantization*
(skip attention blocks / first & last blocks, §3.4).

Parameters live in a flat f32 vector with a deterministic layout
(`param_layout`) shared with the Rust coordinator through the artifact
manifest; `steps.py` packs params+Adam state+metrics into the single state
vector the Rust hot loop chains on-device.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .configs import ModelCfg, QuantCfg
from .kernels import QuantSpec, fake_quant


# --------------------------------------------------------------- param layout


def param_defs(cfg: ModelCfg):
    """Deterministic (name, shape) list — the contract with the Rust side."""
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab
    total_seq = cfg.seq_len + (cfg.vision_grid**2 if cfg.vision else 0)
    defs = [("embed", (v, d)), ("pos_emb", (total_seq, d))]
    if cfg.vision:
        defs.append(("vis_proj", (cfg.vision_patch, d)))
        defs.append(("vis_bias", (d,)))
    for i, kind in enumerate(cfg.blocks):
        p = f"b{i}."
        if kind == "attn":
            defs += [
                (p + "ln1", (d,)),
                (p + "wq", (d, d)),
                (p + "wk", (d, d)),
                (p + "wv", (d, d)),
                (p + "wo", (d, d)),
                (p + "ln2", (d,)),
                (p + "w1", (d, ff)),
                (p + "w2", (ff, d)),
            ]
        elif kind == "ssm":
            defs += [
                (p + "ln", (d,)),
                (p + "win", (d, 3 * d)),  # value, gate, decay-logit
                (p + "a_bias", (d,)),
                (p + "wout", (d, d)),
            ]
        elif kind == "moe":
            defs += [
                (p + "ln", (d,)),
                (p + "router", (d, cfg.n_experts)),
                (p + "w1", (cfg.n_experts, d, ff)),
                (p + "w2", (cfg.n_experts, ff, d)),
            ]
        else:
            raise ValueError(f"unknown block kind {kind!r}")
    defs += [("ln_f", (d,)), ("head", (d, v))]
    return defs


def param_count(cfg: ModelCfg) -> int:
    return sum(int(np.prod(s)) for _, s in param_defs(cfg))


def param_layout(cfg: ModelCfg):
    """[(name, shape, offset, size)] into the flat parameter vector."""
    out, off = [], 0
    for name, shape in param_defs(cfg):
        size = int(np.prod(shape))
        out.append((name, shape, off, size))
        off += size
    return out


def unflatten(cfg: ModelCfg, vec: jnp.ndarray) -> dict:
    return {
        name: lax.slice_in_dim(vec, off, off + size).reshape(shape)
        for name, shape, off, size in param_layout(cfg)
    }


def init_params(cfg: ModelCfg, seed: int = 0) -> jnp.ndarray:
    """Flat f32 init vector: scaled-normal fan-in init, ones for norm scales."""
    rng = np.random.default_rng(seed)
    parts = []
    for name, shape in param_defs(cfg):
        n = int(np.prod(shape))
        leaf = name.split(".")[-1]
        if leaf.startswith("ln"):
            parts.append(np.ones(n, np.float32))
        elif leaf in ("a_bias", "vis_bias"):
            parts.append(np.zeros(n, np.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            std = 1.0 / math.sqrt(fan_in)
            parts.append((rng.normal(size=n) * std).astype(np.float32))
    return jnp.concatenate([jnp.asarray(p) for p in parts])


# ------------------------------------------------------------------ building


def _specs(qc: QuantCfg):
    return QuantSpec(qc.weights, qc.impl), QuantSpec(qc.acts, qc.impl)


def qgemm(x, w, qc: QuantCfg, quantized: bool):
    """The quantized GEMM: fake-quantize activation rows and weight columns
    along the contraction axis (blocks of 16 on K), then matmul — the
    composition form of the fused L1 kernel (pytest-verified identical)."""
    if not quantized or (qc.weights == "none" and qc.acts == "none"):
        return x @ w
    wspec, aspec = _specs(qc)
    if qc.weights != "none":
        # w is (K, N) — quantize along K: transpose so blocks lie on K.
        w = fake_quant(w.T, wspec).T
    if qc.acts != "none":
        x = fake_quant(x, aspec)
    return x @ w


def rmsnorm(x, scale, eps=1e-6):
    return x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps) * scale


def _attn_block(x, p, prefix, cfg: ModelCfg, quantized: bool):
    d, h = cfg.d_model, cfg.n_heads
    hd = d // h
    qc = cfg.quant
    y = rmsnorm(x, p[prefix + "ln1"])
    B, S, _ = y.shape
    y2 = y.reshape(B * S, d)
    q = qgemm(y2, p[prefix + "wq"], qc, quantized).reshape(B, S, h, hd)
    k = qgemm(y2, p[prefix + "wk"], qc, quantized).reshape(B, S, h, hd)
    v = qgemm(y2, p[prefix + "wv"], qc, quantized).reshape(B, S, h, hd)
    att = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    mask = jnp.tril(jnp.ones((S, S), bool))
    att = jnp.where(mask[None, None], att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(B * S, d)
    x = x + qgemm(o, p[prefix + "wo"], qc, quantized).reshape(B, S, d)
    # MLP half
    y = rmsnorm(x, p[prefix + "ln2"]).reshape(B * S, d)
    hdn = jax.nn.gelu(qgemm(y, p[prefix + "w1"], qc, quantized))
    x = x + qgemm(hdn, p[prefix + "w2"], qc, quantized).reshape(B, S, d)
    return x


def _ssm_block(x, p, prefix, cfg: ModelCfg, quantized: bool):
    """Gated diagonal linear recurrence: h_t = a_t ⊙ h_{t-1} + (1-a_t) ⊙ v_t.

    A Mamba-2/SSD proxy: per-token input-dependent decay (selective state),
    elementwise state, silu gate on the output path. The scan is associative:
    (a1,b1)∘(a2,b2) = (a1·a2, a2·b1 + b2), evaluated with
    lax.associative_scan over time (log-depth — the HLO stays shallow).
    """
    d = cfg.d_model
    qc = cfg.quant
    B, S, _ = x.shape
    y = rmsnorm(x, p[prefix + "ln"]).reshape(B * S, d)
    z = qgemm(y, p[prefix + "win"], qc, quantized).reshape(B, S, 3 * d)
    v, g, al = z[..., :d], z[..., d : 2 * d], z[..., 2 * d :]
    a = jax.nn.sigmoid(al + p[prefix + "a_bias"])
    b = (1.0 - a) * v

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, b), axis=1)
    o = (h * jax.nn.silu(g)).reshape(B * S, d)
    return x + qgemm(o, p[prefix + "wout"], qc, quantized).reshape(B, S, d)


def _moe_block(x, p, prefix, cfg: ModelCfg, quantized: bool):
    """Top-2-of-E expert MLP, dense compute + renormalized mask combine."""
    E, k = cfg.n_experts, cfg.moe_top_k
    d = cfg.d_model
    qc = cfg.quant
    B, S, _ = x.shape
    y = rmsnorm(x, p[prefix + "ln"]).reshape(B * S, d)
    # Router stays high-precision (routers are never quantized in practice).
    logits = y @ p[prefix + "router"]
    probs = jax.nn.softmax(logits, axis=-1)
    # Top-2 threshold without lax.top_k or sort-gather: the `topk` HLO op
    # and batched-gather attributes postdate the XLA 0.5.1 text parser the
    # runtime binds. Two max passes (mask out one argmax occurrence) give
    # the 2nd-largest value; `probs >= thresh` then keeps the top-2.
    assert k == 2, "sim MoE supports top-2 routing"
    m1_idx = jnp.argmax(probs, axis=-1)
    onehot = jax.nn.one_hot(m1_idx, probs.shape[-1], dtype=probs.dtype)
    masked = jnp.where(onehot > 0, -jnp.inf, probs)
    thresh = jnp.max(masked, axis=-1, keepdims=True)
    gate = jnp.where(probs >= thresh, probs, 0.0)
    gate = gate / (jnp.sum(gate, axis=-1, keepdims=True) + 1e-9)
    out = jnp.zeros_like(y)
    for e in range(E):
        hdn = jax.nn.gelu(qgemm(y, p[prefix + "w1"][e], qc, quantized))
        oe = qgemm(hdn, p[prefix + "w2"][e], qc, quantized)
        out = out + gate[:, e : e + 1] * oe
    return x + out.reshape(B, S, d)


def _block_quantized(cfg: ModelCfg, i: int, kind: str) -> bool:
    """Selective quantization (paper §3.4)."""
    qc = cfg.quant
    if qc.weights == "none" and qc.acts == "none":
        return False
    if kind == "attn" and qc.skip_attention:
        return False
    if i < qc.skip_first:
        return False
    if i >= len(cfg.blocks) - qc.skip_last:
        return False
    return True


def forward(cfg: ModelCfg, params_vec: jnp.ndarray, tokens: jnp.ndarray, pixels=None):
    """Logits over the *text* positions: (B, S, vocab).

    tokens: i32 (B, S). pixels (VLM only): f32 (B, G*G, patch) — embedded and
    prepended; causal attention runs over the joint sequence, and the image
    positions are dropped from the returned logits.
    """
    p = unflatten(cfg, params_vec)
    qc = cfg.quant
    B, S = tokens.shape
    x = p["embed"][tokens]  # embedding lookup is not a GEMM — never quantized
    n_img = 0
    if cfg.vision:
        assert pixels is not None, "VLM forward requires pixels"
        n_img = cfg.vision_grid**2
        quant_vis = not (qc.weights == "none" and qc.acts == "none")
        img = qgemm(
            pixels.reshape(B * n_img, cfg.vision_patch), p["vis_proj"], qc, quant_vis
        ).reshape(B, n_img, cfg.d_model) + p["vis_bias"]
        x = jnp.concatenate([img, x], axis=1)
    x = x + p["pos_emb"][None, : x.shape[1]]
    for i, kind in enumerate(cfg.blocks):
        quantized = _block_quantized(cfg, i, kind)
        if kind == "attn":
            x = _attn_block(x, p, f"b{i}.", cfg, quantized)
        elif kind == "ssm":
            x = _ssm_block(x, p, f"b{i}.", cfg, quantized)
        else:
            x = _moe_block(x, p, f"b{i}.", cfg, quantized)
    x = rmsnorm(x, p["ln_f"])
    if n_img:
        x = x[:, n_img:]
    Bx, Sx, d = x.shape
    # The LM head is a GEMM — quantized unless the last block is skipped
    # (the paper's "last two layers at BF16" covers the head).
    head_q = _block_quantized(cfg, len(cfg.blocks) - 1, "head")
    logits = qgemm(x.reshape(Bx * Sx, d), p["head"], cfg.quant, head_q)
    return logits.reshape(Bx, Sx, cfg.vocab)
