"""Model/step configurations for the sim model zoo (DESIGN.md §2).

Each entry mirrors one of the paper's evaluation models, scaled to run on
the CPU PJRT backend. Sizes are chosen so (a) the tasks in `rust/src/data`
are learnable in a few thousand SFT steps, (b) NVFP4 PTQ produces a clearly
measurable accuracy drop (small models — the paper's regime of interest),
and (c) the AOT train-step artifacts execute in milliseconds.

The vocabulary is shared with the Rust tokenizer (rust/src/data/tokenizer.rs)
— keep VOCAB in sync; the manifest records it and Rust asserts equality.
"""

from __future__ import annotations

from dataclasses import dataclass, field, replace

# Token space: must match rust/src/data/tokenizer.rs exactly.
# 0..9 digits, then operators/letters/specials. 64 ids, multiple of 16.
VOCAB = 64
PAD, BOS, EOS, SEP = 0, 1, 2, 3

SEQ_LEN = 40  # training/eval sequence length (tokens)
BATCH = 16  # per-step batch baked into the train-step artifacts


@dataclass(frozen=True)
class QuantCfg:
    """Which tensors are fake-quantized, with what format.

    weights/acts: "none" | "nvfp4" | "mxfp4" | "int4"
    impl: "pallas" | "jnp"  (numerically identical; pallas = L1 kernel path)
    skip_attention: keep attention-block GEMMs in high precision
        (paper §3.4: Nemotron Nano keeps attention layers at BF16).
    skip_first / skip_last: number of leading/trailing blocks kept in
        high precision (paper §3.4: first and last two layers at BF16).
    """

    weights: str = "nvfp4"
    acts: str = "nvfp4"
    impl: str = "jnp"
    skip_attention: bool = False
    skip_first: int = 0
    skip_last: int = 0


BF16 = QuantCfg(weights="none", acts="none")


@dataclass(frozen=True)
class ModelCfg:
    """A decoder LM. `blocks` is a tuple of "attn" | "ssm" | "moe" kinds."""

    name: str
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 256
    blocks: tuple = ("attn", "attn", "attn", "attn")
    vocab: int = VOCAB
    seq_len: int = SEQ_LEN
    batch: int = BATCH
    n_experts: int = 4  # for "moe" blocks (top-2 routing)
    moe_top_k: int = 2
    vision: bool = False  # prepend a grid-image patch embedder (VLM sim)
    vision_grid: int = 4  # grid of vision_grid × vision_grid patch tokens
    vision_patch: int = 16  # raw floats per patch
    quant: QuantCfg = field(default_factory=lambda: BF16)

    def with_quant(self, q: QuantCfg) -> "ModelCfg":
        return replace(self, quant=q)


def _t(name, d, heads, ff, n_blocks, **kw):
    return ModelCfg(
        name=name, d_model=d, n_heads=heads, d_ff=ff, blocks=("attn",) * n_blocks, **kw
    )


# --- The sim zoo (paper model → sim counterpart) -----------------------------

# Llama Nemotron Super V1 49B → plain transformer, the "large" sim.
# (Sizes tuned for the single-core CPU-PJRT testbed — see DESIGN.md §5.)
SUPER_SIM = _t("super-sim", d=144, heads=4, ff=288, n_blocks=4)

# AceReason Nemotron 1.1 7B (Qwen2.5 base, RL-heavy) → plain transformer.
ACE_SIM = _t("ace-sim", d=96, heads=4, ff=192, n_blocks=3)

# Nemotron Nano 9B V2: hybrid Mamba-Transformer (4 attn + 52 mamba) →
# hybrid with mostly ssm blocks and 2 attention blocks.
NANO_SIM = ModelCfg(
    name="nano-sim",
    d_model=96,
    n_heads=4,
    d_ff=192,
    blocks=("ssm", "attn", "ssm", "ssm", "attn", "ssm"),
)

# Nemotron 3 Nano 30B-A3B: MoE hybrid Mamba-Transformer →
# ssm + moe blocks with a single attention block.
NANO3_SIM = ModelCfg(
    name="nano3-sim",
    d_model=96,
    n_heads=4,
    d_ff=144,
    blocks=("ssm", "moe", "attn", "moe"),
    n_experts=4,
)

# Nemotron Nano 12B v2 VL → VLM sim with the grid-image front-end.
VL_SIM = ModelCfg(
    name="vl-sim",
    d_model=96,
    n_heads=4,
    d_ff=192,
    blocks=("attn", "attn", "attn"),
    vision=True,
)

# Width sweep for Table 12 (PTQ robustness vs model size).
SIZE_SWEEP = (
    _t("size-xs", d=32, heads=2, ff=64, n_blocks=2, batch=16),
    _t("size-s", d=64, heads=4, ff=128, n_blocks=2, batch=16),
    _t("size-m", d=96, heads=4, ff=192, n_blocks=3, batch=16),
    _t("size-l", d=160, heads=4, ff=320, n_blocks=4, batch=16),
)

ZOO = {m.name: m for m in (SUPER_SIM, ACE_SIM, NANO_SIM, NANO3_SIM, VL_SIM, *SIZE_SWEEP)}

# Per-model quantization configs (paper §3.4 "Quantization Configuration").
QUANT_OVERRIDES = {
    # Nano keeps attention + first/last blocks high-precision.
    "nano-sim": QuantCfg(skip_attention=True, skip_first=1, skip_last=1),
    # Nano-3 keeps its attention (and neighbours) high-precision; here the
    # single attn block + adjacent ssm.
    "nano3-sim": QuantCfg(skip_attention=True),
}

# The flagship config exercises the Pallas kernel path end-to-end; the sweep
# configs use the verified-identical jnp path to keep artifact build time sane.
PALLAS_MODELS = {"ace-sim"}


def quant_cfg_for(name: str, fmt: str = "nvfp4") -> QuantCfg:
    base = QUANT_OVERRIDES.get(name, QuantCfg())
    impl = "pallas" if name in PALLAS_MODELS else "jnp"
    return replace(base, weights=fmt, acts=fmt, impl=impl)
