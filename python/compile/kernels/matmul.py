"""L1 Pallas kernel: fused NVFP4 GEMM — the inference hot path.

Stand-in for the Blackwell NVFP4 tensor-core GEMM: each grid step pulls an
(M-tile × K-tile) slab of activations and a (K-tile × N-tile) slab of weights
into VMEM, fake-quantizes both along the contraction axis (block-16 E2M1
values, E4M3 block scales, FP32 tensor scales), and accumulates the product
into the resident output tile fed to the MXU via ``jnp.dot``.

TPU adaptation of the GPU datapath (DESIGN.md §Hardware-Adaptation):
  * 16-element quantization blocks stay contiguous along the lane axis;
  * tiles default to 128×128 — the MXU systolic-array shape;
  * scales are applied as rank-broadcast multiplies before the dot, not
    inside the MAC loop (TPUs have no FP4 MAC; accuracy is identical);
  * the K axis is the innermost grid dimension, so the (i, j) output block
    stays resident in VMEM across the whole contraction (accumulate into
    o_ref — no HBM round-trip per K step).

Correctness: pytest asserts this kernel == ref.nvfp4_matmul_ref and the
composed `fake_quant(x) @ fake_quant(w)` used in the L2 model graphs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

TILE_M = 128
TILE_N = 128
TILE_K = 128  # 8 quantization blocks per K-tile


def _quant_tile_lastaxis(x, ts):
    """Fake-quantize a 2-D tile along its last axis (blocks of 16)."""
    rows, cols = x.shape
    xb = x.reshape(rows, cols // 16, 16)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    sb = jnp.clip(amax / ref.E2M1_MAX / ts, -ref.E4M3_MAX, ref.E4M3_MAX)
    sb = sb.astype(jnp.float8_e4m3fn).astype(jnp.float32)
    denom = sb * ts
    y = jnp.where(denom > 0, xb / denom, 0.0)
    # Arithmetic E2M1 rounding — no array constants inside Pallas bodies.
    codes = ref.e2m1_round_arith(y)
    return (codes * denom).reshape(rows, cols)


def _mm_kernel(x_ref, wt_ref, tsx_ref, tsw_ref, o_ref):
    """Grid = (M/TM, N/TN, K/TK); K innermost — o_ref accumulates across K."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _():
        o_ref[...] = jnp.zeros_like(o_ref)

    xq = _quant_tile_lastaxis(x_ref[...], tsx_ref[0, 0])
    # Weights arrive pre-transposed (N, K) so quantization blocks lie along
    # the contraction axis for both operands, as in the tensor-core GEMM.
    wq = _quant_tile_lastaxis(wt_ref[...], tsw_ref[0, 0])
    o_ref[...] += jnp.dot(xq, wq.T, preferred_element_type=jnp.float32)


def nvfp4_matmul(
    x: jnp.ndarray,
    w: jnp.ndarray,
    tm: int = TILE_M,
    tn: int = TILE_N,
    tk: int = TILE_K,
) -> jnp.ndarray:
    """Fused NVFP4 GEMM: x (M,K) @ w (K,N), both quantized along K.

    Tile sizes clamp to the problem size; dims must divide evenly by the
    clamped tiles (model dims here are multiples of 16/64/128 by config).
    """
    m, kdim = x.shape
    kdim2, n = w.shape
    assert kdim == kdim2, (x.shape, w.shape)
    tm = min(tm, m)
    tn = min(tn, n)
    tk = min(tk, kdim)
    assert m % tm == 0 and n % tn == 0 and kdim % tk == 0, (m, n, kdim, tm, tn, tk)
    assert tk % 16 == 0
    tsx = ref.nvfp4_tensor_scale(x).reshape(1, 1)
    tsw = ref.nvfp4_tensor_scale(w).reshape(1, 1)
    wt = w.T  # (N, K): contraction along the last axis for quantization
    grid = (m // tm, n // tn, kdim // tk)
    return pl.pallas_call(
        _mm_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, k: (i, k)),
            pl.BlockSpec((tn, tk), lambda i, j, k: (j, k)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, k: (i, j)),
        interpret=True,
    )(x.astype(jnp.float32), wt.astype(jnp.float32), tsx, tsw)


def vmem_bytes(tm: int = TILE_M, tn: int = TILE_N, tk: int = TILE_K) -> int:
    """Estimated VMEM residency per grid step (f32 tiles + quant temps).

    Used by the §Perf analysis in DESIGN.md: x-tile + w-tile + out-tile plus
    one blocked copy of each operand for the quantization temporaries.
    """
    f32 = 4
    return f32 * (2 * tm * tk + 2 * tn * tk + tm * tn)
