"""Pure-jnp reference oracles for the L1 Pallas kernels.

These are the ground truth for correctness: every Pallas kernel in this
package is pytest-compared against the functions here (see
python/tests/), and the Rust `quant` substrate cross-validates its
bit-exact NVFP4 codec against `nvfp4_quantize_ref` through golden files.

NVFP4 (paper §2.1):
  * values on the E2M1 grid  {0, ±0.5, ±1, ±1.5, ±2, ±3, ±4, ±6}
  * block size 16 along the last axis
  * per-block scale stored as FP8 E4M3 (non-power-of-two scaling)
  * second-level per-tensor FP32 scale for dynamic range

MXFP4 baseline: block 32, power-of-two (E8M0) scales, no tensor scale.
INT4 baseline: symmetric per-channel scale, grid {-7..7}.
"""

from __future__ import annotations

import jax.numpy as jnp

# --- E2M1 -------------------------------------------------------------------

# Positive representable magnitudes of FP4 E2M1.
E2M1_GRID = jnp.asarray([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0], jnp.float32)
# Midpoints between consecutive grid values; ties resolve to the value with
# an even mantissa bit, which for this grid is the even *index*.
E2M1_BOUNDS = jnp.asarray([0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0], jnp.float32)
E2M1_MAX = 6.0

E4M3_MAX = 448.0


def e2m1_round(x: jnp.ndarray) -> jnp.ndarray:
    """Round to the nearest E2M1 value, round-half-to-even, clamp to ±6."""
    a = jnp.clip(jnp.abs(x), 0.0, E2M1_MAX).astype(jnp.float32)
    b = E2M1_BOUNDS.reshape((1,) * a.ndim + (-1,))
    ax = a[..., None]
    idx_down = jnp.sum(ax > b, axis=-1)  # ties round toward grid[idx]
    idx_up = jnp.sum(ax >= b, axis=-1)  # ties round toward grid[idx+1]
    is_tie = idx_up != idx_down
    # On a tie pick the even grid index (even mantissa).
    idx = jnp.where(is_tie & (idx_down % 2 == 1), idx_up, idx_down)
    mag = E2M1_GRID[idx]
    return jnp.sign(x).astype(jnp.float32) * mag


def e2m1_round_arith(x: jnp.ndarray) -> jnp.ndarray:
    """E2M1 round-half-even written with scalar thresholds only.

    Identical to `e2m1_round` (pytest-verified) but uses no array constants,
    so it can be traced inside a Pallas kernel body (Pallas forbids captured
    array consts). Boundary cases resolve to the even-mantissa neighbour:
    0.25→0, 0.75→1, 1.25→1, 1.75→2, 2.5→2, 3.5→4, 5→4.
    """
    a = jnp.abs(x).astype(jnp.float32)
    mag = jnp.where(
        a <= 0.25,
        0.0,
        jnp.where(
            a < 0.75,
            0.5,
            jnp.where(
                a <= 1.25,
                1.0,
                jnp.where(
                    a < 1.75,
                    1.5,
                    jnp.where(a <= 2.5, 2.0, jnp.where(a < 3.5, 3.0, jnp.where(a <= 5.0, 4.0, 6.0))),
                ),
            ),
        ),
    )
    return jnp.sign(x).astype(jnp.float32) * mag


def e4m3_round(x: jnp.ndarray) -> jnp.ndarray:
    """Round to FP8 E4M3 (finite, fn variant) and decode back to f32."""
    x = jnp.clip(x, -E4M3_MAX, E4M3_MAX)
    return x.astype(jnp.float8_e4m3fn).astype(jnp.float32)


# --- NVFP4 ------------------------------------------------------------------


def nvfp4_tensor_scale(x: jnp.ndarray) -> jnp.ndarray:
    """Second-level FP32 scale: map the tensor amax onto E2M1_MAX*E4M3_MAX."""
    amax = jnp.max(jnp.abs(x)).astype(jnp.float32)
    s = amax / (E2M1_MAX * E4M3_MAX)
    return jnp.where(amax > 0, s, 1.0)


def nvfp4_quantize_ref(x: jnp.ndarray, tensor_scale: jnp.ndarray | None = None):
    """Fake-quantize `x` to NVFP4 along the last axis (block=16).

    Returns (dequantized f32 tensor, e2m1 codes, decoded block scales).
    The dequantized tensor is exactly what NVFP4 hardware would compute:
    code * e4m3(block_scale) * tensor_scale.
    """
    orig_shape = x.shape
    assert orig_shape[-1] % 16 == 0, f"last dim {orig_shape[-1]} not /16"
    xb = x.reshape(orig_shape[:-1] + (orig_shape[-1] // 16, 16)).astype(jnp.float32)
    if tensor_scale is None:
        tensor_scale = nvfp4_tensor_scale(x)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    raw = amax / E2M1_MAX / tensor_scale
    sb = e4m3_round(raw)
    denom = sb * tensor_scale
    codes = e2m1_round(jnp.where(denom > 0, xb / denom, 0.0))
    deq = (codes * denom).reshape(orig_shape)
    return deq, codes.reshape(orig_shape), sb[..., 0]


def nvfp4_fake_quant_ref(x: jnp.ndarray) -> jnp.ndarray:
    return nvfp4_quantize_ref(x)[0]


# --- MXFP4 baseline ----------------------------------------------------------


def mxfp4_fake_quant_ref(x: jnp.ndarray, block: int = 32) -> jnp.ndarray:
    """MXFP4: E2M1 values, block=32, power-of-two (E8M0) shared scale."""
    orig_shape = x.shape
    assert orig_shape[-1] % block == 0
    xb = x.reshape(orig_shape[:-1] + (orig_shape[-1] // block, block)).astype(jnp.float32)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    # Shared exponent: floor(log2(amax)) - floor(log2(6)) == floor(log2(amax)) - 2.
    e = jnp.floor(jnp.log2(jnp.where(amax > 0, amax, 1.0))) - 2.0
    s = jnp.exp2(e)
    codes = e2m1_round(jnp.where(amax > 0, xb / s, 0.0))
    return (codes * s).reshape(orig_shape)


# --- INT4 baseline -----------------------------------------------------------


def int4_fake_quant_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Symmetric INT4 with per-channel (last-axis) scale, grid -7..7."""
    amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    s = jnp.where(amax > 0, amax / 7.0, 1.0)
    q = jnp.clip(jnp.round(x / s), -7, 7)
    return q * s


# --- KL / distillation losses -------------------------------------------------


def log_softmax_ref(z: jnp.ndarray) -> jnp.ndarray:
    m = jnp.max(z, axis=-1, keepdims=True)
    y = z - m
    return y - jnp.log(jnp.sum(jnp.exp(y), axis=-1, keepdims=True))


def kl_per_token_ref(t_logits: jnp.ndarray, s_logits: jnp.ndarray) -> jnp.ndarray:
    """Forward KL(teacher || student) per token, summed over the vocab axis."""
    lt = log_softmax_ref(t_logits.astype(jnp.float32))
    ls = log_softmax_ref(s_logits.astype(jnp.float32))
    pt = jnp.exp(lt)
    return jnp.sum(pt * (lt - ls), axis=-1)


def kl_grad_wrt_student_ref(t_logits: jnp.ndarray, s_logits: jnp.ndarray) -> jnp.ndarray:
    """d KL(t||s) / d s_logits = softmax(s) - softmax(t) (per token)."""
    pt = jnp.exp(log_softmax_ref(t_logits.astype(jnp.float32)))
    ps = jnp.exp(log_softmax_ref(s_logits.astype(jnp.float32)))
    return ps - pt


# --- NVFP4 GEMM ---------------------------------------------------------------


def nvfp4_matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Quantize both operands along the contraction axis, then matmul.

    x: (M, K) quantized along K (its last axis); w: (K, N) quantized along K
    (its first axis — transposed so blocks lie along the contraction, as the
    NVFP4 tensor-core GEMM does).
    """
    xq = nvfp4_fake_quant_ref(x)
    wq = nvfp4_fake_quant_ref(w.T).T
    return jnp.dot(xq, wq, preferred_element_type=jnp.float32)
