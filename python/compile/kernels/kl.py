"""L1 Pallas kernel: fused per-token KL(teacher || student) over the vocab.

The QAD loss (paper Eq. 1). One kernel instance loads a tile of teacher and
student logit rows into VMEM, computes both log-softmaxes, and reduces the
KL sum over the vocab axis — one HBM pass over each logits tensor instead of
the five separate reductions the unfused formulation costs.

A custom VJP supplies the analytic gradient ``softmax(s) - softmax(t)``
(scaled by the incoming per-token cotangent), so the backward pass never
differentiates through the kernel. The teacher side is non-differentiable by
construction (teacher params are frozen in QAD).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

ROW_TILE = 64


def _kl_kernel(t_ref, s_ref, o_ref):
    t = t_ref[...].astype(jnp.float32)
    s = s_ref[...].astype(jnp.float32)
    tm = jnp.max(t, axis=-1, keepdims=True)
    sm = jnp.max(s, axis=-1, keepdims=True)
    tz = t - tm
    sz = s - sm
    lt = tz - jnp.log(jnp.sum(jnp.exp(tz), axis=-1, keepdims=True))
    ls = sz - jnp.log(jnp.sum(jnp.exp(sz), axis=-1, keepdims=True))
    o_ref[...] = jnp.sum(jnp.exp(lt) * (lt - ls), axis=-1, keepdims=True)


def _kl_pallas_2d(t2: jnp.ndarray, s2: jnp.ndarray) -> jnp.ndarray:
    rows, vocab = t2.shape
    tile = min(ROW_TILE, rows)
    grid = (rows // tile,)
    out = pl.pallas_call(
        _kl_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, 1), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, vocab), lambda i: (i, 0)),
            pl.BlockSpec((tile, vocab), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((tile, 1), lambda i: (i, 0)),
        interpret=True,
    )(t2, s2)
    return out[:, 0]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def kl_per_token(t_logits: jnp.ndarray, s_logits: jnp.ndarray, impl: str = "pallas"):
    """KL(teacher || student) per token; leading axes preserved."""
    return _kl_fwd_impl(t_logits, s_logits, impl)


def _kl_fwd_impl(t_logits, s_logits, impl):
    if impl == "jnp":
        return ref.kl_per_token_ref(t_logits, s_logits)
    shape = t_logits.shape
    rows = 1
    for d in shape[:-1]:
        rows *= d
    t2 = t_logits.reshape(rows, shape[-1])
    s2 = s_logits.reshape(rows, shape[-1])
    tile = min(ROW_TILE, rows)
    pad = (-rows) % tile
    if pad:
        z = jnp.zeros((pad, shape[-1]), t2.dtype)
        t2 = jnp.concatenate([t2, z], axis=0)
        s2 = jnp.concatenate([s2, z], axis=0)
    out = _kl_pallas_2d(t2, s2)
    if pad:
        out = out[:rows]
    return out.reshape(shape[:-1])


def _kl_fwd(t_logits, s_logits, impl):
    return _kl_fwd_impl(t_logits, s_logits, impl), (t_logits, s_logits)


def _kl_bwd(impl, res, g):
    t_logits, s_logits = res
    grad_s = ref.kl_grad_wrt_student_ref(t_logits, s_logits) * g[..., None]
    # Teacher logits are frozen in QAD; zero cotangent keeps jax happy if a
    # caller ever differentiates through the teacher path.
    return (jnp.zeros_like(t_logits), grad_s)


kl_per_token.defvjp(_kl_fwd, _kl_bwd)
