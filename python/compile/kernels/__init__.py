"""L1 Pallas kernels + pure-jnp reference oracles.

Kernels (interpret=True — lowered to plain HLO so the CPU PJRT client runs
them; real TPU lowering would emit Mosaic custom-calls):

  * nvfp4.fake_quant      — NVFP4/MXFP4/INT4 fake-quant with an STE VJP
  * kl.kl_per_token       — fused KL(teacher || student) with analytic VJP
  * matmul.nvfp4_matmul   — fused quantize-quantize-GEMM (inference hot path)

ref.py holds the jnp oracles every kernel is tested against.
"""

from . import kl, matmul, nvfp4, ref  # noqa: F401
from .nvfp4 import QuantSpec, fake_quant  # noqa: F401
