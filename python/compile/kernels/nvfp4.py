"""L1 Pallas kernel: NVFP4 fake-quantization (block-16, E4M3 scales, FP32
tensor scale) with a straight-through-estimator custom VJP.

This is the quantization hot-spot of the paper: every GEMM operand in the
student model passes through `fake_quant` on the forward pass. The kernel is
written for TPU VMEM tiling (rows × full 16-element blocks live in one tile;
the per-block scale is computed in-register from the tile) and lowered with
``interpret=True`` so the emitted HLO runs on the CPU PJRT plugin — see
DESIGN.md §Hardware-Adaptation.

The straight-through estimator (``x + stop_grad(q(x) - x)`` expressed as a
custom VJP) is what makes QAD/QAT training possible: gradients flow through
the quantizer as identity while the forward pass sees the NVFP4 grid.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Largest row-tile processed by one kernel instance. Sized so a
# (ROW_TILE, cols) f32 tile plus its scale tensor stays ≲2 MiB of VMEM for
# the model widths used here (cols ≤ 4096).
ROW_TILE = 128


def _quant_kernel(x_ref, ts_ref, o_ref):
    """One grid step: fake-quantize a (rows, cols) tile, blocks of 16 on cols.

    ts_ref is the (1,1) per-tensor FP32 scale (second-level scaling),
    computed once outside the kernel — it is a global reduction and cannot
    live inside a tiled grid.
    """
    x = x_ref[...]
    rows, cols = x.shape
    ts = ts_ref[0, 0]
    xb = x.reshape(rows, cols // 16, 16)
    amax = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    # First-level scale, stored in E4M3 as on Blackwell.
    raw = amax / ref.E2M1_MAX / ts
    sb = jnp.clip(raw, -ref.E4M3_MAX, ref.E4M3_MAX)
    sb = sb.astype(jnp.float8_e4m3fn).astype(jnp.float32)
    denom = sb * ts
    y = jnp.where(denom > 0, xb / denom, 0.0)
    # E2M1 round-half-even in arithmetic form — Pallas kernels cannot
    # capture array constants, so no lookup table here.
    codes = ref.e2m1_round_arith(y)
    o_ref[...] = (codes * denom).reshape(rows, cols)


@functools.partial(jax.jit, static_argnames=())
def _fake_quant_pallas_2d(x2: jnp.ndarray, ts: jnp.ndarray) -> jnp.ndarray:
    rows, cols = x2.shape
    tile = min(ROW_TILE, rows)
    # Grid only over full tiles; pallas requires rows % tile == 0 — callers
    # pad via `fake_quant` below.
    grid = (rows // tile,)
    return pl.pallas_call(
        _quant_kernel,
        out_shape=jax.ShapeDtypeStruct((rows, cols), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, cols), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, cols), lambda i: (i, 0)),
        interpret=True,
    )(x2, ts)


def nvfp4_fake_quant_pallas(x: jnp.ndarray) -> jnp.ndarray:
    """Pallas-kernel NVFP4 fake-quant of an arbitrary-rank tensor.

    The last axis must be a multiple of 16. Rows (the product of leading
    axes) are padded up to the tile size; padding is sliced away afterwards
    and never contributes to block scales (blocks are row-local).
    """
    shape = x.shape
    assert shape[-1] % 16 == 0, f"last dim {shape[-1]} not a multiple of 16"
    x2 = x.reshape(-1, shape[-1]).astype(jnp.float32)
    rows = x2.shape[0]
    ts = ref.nvfp4_tensor_scale(x).reshape(1, 1)
    tile = min(ROW_TILE, rows)
    pad = (-rows) % tile
    if pad:
        x2 = jnp.concatenate([x2, jnp.zeros((pad, shape[-1]), jnp.float32)], axis=0)
    out = _fake_quant_pallas_2d(x2, ts)
    if pad:
        out = out[:rows]
    return out.reshape(shape)


# --- STE wrapper -------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def fake_quant(x: jnp.ndarray, spec: "QuantSpec") -> jnp.ndarray:
    """Fake-quantize per `spec` with a straight-through gradient."""
    return _fq_fwd_impl(x, spec)


def _fq_fwd_impl(x, spec):
    fmt = spec.fmt
    if fmt == "none":
        return x
    if fmt == "nvfp4":
        if spec.impl == "pallas":
            return nvfp4_fake_quant_pallas(x)
        return ref.nvfp4_fake_quant_ref(x)
    if fmt == "mxfp4":
        return ref.mxfp4_fake_quant_ref(x)
    if fmt == "int4":
        return ref.int4_fake_quant_ref(x)
    raise ValueError(f"unknown quant fmt {fmt!r}")


def _fq_fwd(x, spec):
    return _fq_fwd_impl(x, spec), None


def _fq_bwd(spec, _res, g):
    # Straight-through estimator: quantizer gradient is identity.
    return (g,)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


class QuantSpec:
    """Quantization format selector for one tensor class (static pytree leaf).

    fmt: "none" | "nvfp4" | "mxfp4" | "int4"
    impl: "pallas" (L1 kernel) | "jnp" (reference path — numerically
          identical, verified by pytest; used for the large sweep configs
          where interpret-mode grid loops dominate build time).
    """

    def __init__(self, fmt: str = "nvfp4", impl: str = "jnp"):
        self.fmt = fmt
        self.impl = impl

    def __hash__(self):
        return hash((self.fmt, self.impl))

    def __eq__(self, other):
        return isinstance(other, QuantSpec) and (self.fmt, self.impl) == (
            other.fmt,
            other.impl,
        )

    def __repr__(self):
        return f"QuantSpec({self.fmt!r}, impl={self.impl!r})"


NONE = QuantSpec("none")
