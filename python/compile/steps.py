"""L2: training / evaluation step graphs lowered to AOT artifacts.

Every training step is a **state-vector function**

    step : (state f32[N], ...batch..., lr f32[]) -> state' f32[N]
    state = [ params (P) | adam_m (P) | adam_v (P) | scalar block (8) ]

with a single (non-tuple) array output, so the Rust hot loop can chain the
output buffer of step *t* straight into step *t+1* via `execute_b` — the
training state never leaves the device. Per-step metrics (loss, KL, CE,
grad-norm, step counter) are written into the trailing scalar block; the
Rust side reads just those 8 floats back per step with an offset
`copy_raw_to_host_sync` instead of downloading megabytes of parameters.

Step variants (paper §3):
  sft   — cross-entropy on labels, teacher-precision model (stage-1 training)
  rl    — REINFORCE: -advantage · log p(sequence) (stage-2 RL post-training)
  qat   — cross-entropy on labels, *quantized* forward (the paper's QAT)
  qad   — KL(teacher ‖ quantized student) via the L1 fused kernel (Eq. 1)
  mse   — MSE on logits distillation baseline (Table 8)
  nqt   — "native quantized training" proxy: QAT + NVFP4-quantized gradient
          GEMM outputs (Figure 2 ablation; see DESIGN.md substitutions)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from .configs import PAD, ModelCfg
from .kernels import QuantSpec
from .kernels.kl import kl_per_token
from .kernels.nvfp4 import fake_quant
from .model import forward, param_count

N_SCALARS = 8
# scalar block slots
S_STEP, S_LOSS, S_KL, S_CE, S_GNORM, S_LR, S_AUX0, S_AUX1 = range(N_SCALARS)

ADAM_B1, ADAM_B2, ADAM_EPS = 0.9, 0.999, 1e-8


def state_len(cfg: ModelCfg) -> int:
    return 3 * param_count(cfg) + N_SCALARS


def init_state(cfg: ModelCfg, params_vec) -> jnp.ndarray:
    p = param_count(cfg)
    z = jnp.zeros(2 * p + N_SCALARS, jnp.float32)
    return jnp.concatenate([params_vec, z])


def split_state(cfg: ModelCfg, state):
    p = param_count(cfg)
    return state[:p], state[p : 2 * p], state[2 * p : 3 * p], state[3 * p :]


# ----------------------------------------------------------------- losses


def _shift(tokens, mask):
    """(inputs, labels, label_mask): next-token prediction over S-1 positions."""
    return tokens[:, :-1], tokens[:, 1:], mask[:, 1:]


def ce_loss(cfg: ModelCfg, params, tokens, mask, pixels=None):
    inp, lab, m = _shift(tokens, mask)
    logits = forward(cfg, params, inp, pixels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    denom = jnp.sum(m) + 1e-6
    return -jnp.sum(ll * m) / denom


def kl_distill_loss(cfg: ModelCfg, tcfg: ModelCfg, params, t_params, tokens, mask, pixels=None, impl="jnp"):
    """QAD loss (Eq. 1): mean per-token KL(teacher ‖ student) over the mask."""
    inp, _, m = _shift(tokens, mask)
    s_logits = forward(cfg, params, inp, pixels)
    t_logits = lax.stop_gradient(forward(tcfg, t_params, inp, pixels))
    kl = kl_per_token(t_logits, s_logits, impl)
    denom = jnp.sum(m) + 1e-6
    return jnp.sum(kl * m) / denom


def mse_distill_loss(cfg: ModelCfg, tcfg: ModelCfg, params, t_params, tokens, mask, pixels=None):
    inp, _, m = _shift(tokens, mask)
    s_logits = forward(cfg, params, inp, pixels)
    t_logits = lax.stop_gradient(forward(tcfg, t_params, inp, pixels))
    se = jnp.mean((s_logits - t_logits) ** 2, axis=-1)
    denom = jnp.sum(m) + 1e-6
    return jnp.sum(se * m) / denom


def reinforce_loss(cfg: ModelCfg, params, tokens, mask, adv, pixels=None):
    """-E[adv · log p(response)]; adv is per-sequence (B,), already centred."""
    inp, lab, m = _shift(tokens, mask)
    logits = forward(cfg, params, inp, pixels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
    seq_ll = jnp.sum(ll * m, axis=-1) / (jnp.sum(m, axis=-1) + 1e-6)
    return -jnp.mean(adv * seq_ll)


# ----------------------------------------------------------------- optimizer


def adam_update(cfg: ModelCfg, state, grads, lr, extra_metrics):
    params, m, v, sc = split_state(cfg, state)
    step = sc[S_STEP] + 1.0
    m = ADAM_B1 * m + (1 - ADAM_B1) * grads
    v = ADAM_B2 * v + (1 - ADAM_B2) * grads * grads
    mhat = m / (1 - ADAM_B1**step)
    vhat = v / (1 - ADAM_B2**step)
    params = params - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    gnorm = jnp.sqrt(jnp.sum(grads * grads))
    sc = sc.at[S_STEP].set(step)
    sc = sc.at[S_GNORM].set(gnorm)
    sc = sc.at[S_LR].set(lr)
    for slot, val in extra_metrics.items():
        sc = sc.at[slot].set(val)
    return jnp.concatenate([params, m, v, sc])


def _quantize_grads(grads, p_count_vec_shape):
    """Figure-2 'native quantized training' proxy: pass the gradient vector
    through NVFP4 fake-quant (pad to a block multiple, quantize, unpad) —
    standing in for low-precision Wgrad/Dgrad GEMM outputs."""
    n = grads.shape[0]
    padn = (-n) % 16
    g = jnp.concatenate([grads, jnp.zeros(padn, jnp.float32)]) if padn else grads
    gq = fake_quant(g.reshape(1, -1), QuantSpec("nvfp4", "jnp")).reshape(-1)
    return gq[:n] if padn else gq


# ----------------------------------------------------------------- step fns


def make_sft_step(cfg: ModelCfg, quantize_grads: bool = False):
    """CE training step; with cfg.quant set this *is* the QAT step."""

    def step(state, tokens, mask, lr, pixels=None):
        params = split_state(cfg, state)[0]

        def loss_fn(p):
            return ce_loss(cfg, p, tokens, mask, pixels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        if quantize_grads:
            grads = _quantize_grads(grads, None)
        return adam_update(cfg, state, grads, lr, {S_LOSS: loss, S_CE: loss})

    return step


def make_rl_step(cfg: ModelCfg):
    def step(state, tokens, mask, adv, lr, pixels=None):
        params = split_state(cfg, state)[0]

        def loss_fn(p):
            return reinforce_loss(cfg, p, tokens, mask, adv, pixels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return adam_update(cfg, state, grads, lr, {S_LOSS: loss})

    return step


def make_qad_step(cfg: ModelCfg, tcfg: ModelCfg, impl="jnp"):
    def step(state, t_params, tokens, mask, lr, pixels=None):
        params = split_state(cfg, state)[0]

        def loss_fn(p):
            return kl_distill_loss(cfg, tcfg, p, t_params, tokens, mask, pixels, impl)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return adam_update(cfg, state, grads, lr, {S_LOSS: loss, S_KL: loss})

    return step


def make_mse_step(cfg: ModelCfg, tcfg: ModelCfg):
    def step(state, t_params, tokens, mask, lr, pixels=None):
        params = split_state(cfg, state)[0]

        def loss_fn(p):
            return mse_distill_loss(cfg, tcfg, p, t_params, tokens, mask, pixels)

        loss, grads = jax.value_and_grad(loss_fn)(params)
        return adam_update(cfg, state, grads, lr, {S_LOSS: loss})

    return step


def make_fwd(cfg: ModelCfg):
    def fwd(params, tokens, pixels=None):
        return forward(cfg, params, tokens, pixels)

    return fwd


def make_fwd_last(cfg: ModelCfg):
    """Fused forward + per-row frontier gather: (params, tokens, idx) ->
    (B, V) logits rows, where idx[b] selects the position whose logits the
    decode loop needs (its frontier minus one). The sampler downloads B·V
    floats per emitted token instead of the full B·S·V tensor."""

    def fwd_last(params, tokens, idx, pixels=None):
        logits = forward(cfg, params, tokens, pixels)  # (B, S, V)
        return jnp.take_along_axis(logits, idx[:, None, None], axis=1)[:, 0, :]

    return fwd_last


def make_eval_metrics(cfg: ModelCfg, tcfg: ModelCfg, impl="jnp"):
    """-> f32[8]: [kl_mean, ce_mean, masked_tokens, kl_sum, ce_sum, 0, 0, 0].

    Table 1's two columns (KL vs teacher, CE vs labels) in one pass; sums are
    returned so the Rust side can aggregate exactly across batches.
    """

    def ev(params, t_params, tokens, mask, pixels=None):
        inp, lab, m = _shift(tokens, mask)
        s_logits = forward(cfg, params, inp, pixels)
        t_logits = forward(tcfg, t_params, inp, pixels)
        kl = kl_per_token(t_logits, s_logits, impl)
        logp = jax.nn.log_softmax(s_logits, axis=-1)
        ll = jnp.take_along_axis(logp, lab[..., None], axis=-1)[..., 0]
        n = jnp.sum(m)
        kl_sum = jnp.sum(kl * m)
        ce_sum = -jnp.sum(ll * m)
        denom = n + 1e-6
        return jnp.stack(
            [kl_sum / denom, ce_sum / denom, n, kl_sum, ce_sum, 0.0, 0.0, 0.0]
        )

    return ev


# ------------------------------------------------------------- batch shapes


def batch_shapes(cfg: ModelCfg):
    """Example (tokens, mask[, pixels]) ShapeDtypeStructs for lowering."""
    B, S = cfg.batch, cfg.seq_len
    tokens = jax.ShapeDtypeStruct((B, S), jnp.int32)
    mask = jax.ShapeDtypeStruct((B, S), jnp.float32)
    out = [tokens, mask]
    if cfg.vision:
        out.append(
            jax.ShapeDtypeStruct((B, cfg.vision_grid**2, cfg.vision_patch), jnp.float32)
        )
    return out


def validate_numerics(cfg: ModelCfg, seed: int = 0):
    """Quick self-check used by pytest: one step of each kind runs and the
    metrics land in the scalar block."""
    from .model import init_params

    rng = np.random.default_rng(seed)
    params = init_params(cfg, seed)
    state = init_state(cfg, params)
    B, S = cfg.batch, cfg.seq_len
    tokens = jnp.asarray(rng.integers(4, cfg.vocab, size=(B, S)), jnp.int32)
    mask = jnp.ones((B, S), jnp.float32).at[:, : S // 2].set(0.0)
    pixels = (
        jnp.asarray(rng.normal(size=(B, cfg.vision_grid**2, cfg.vision_patch)), jnp.float32)
        if cfg.vision
        else None
    )
    lr = jnp.float32(1e-3)
    s1 = make_sft_step(cfg)(state, tokens, mask, lr, pixels)
    return s1
