"""L1 correctness: Pallas NVFP4 kernels vs the pure-jnp oracle (ref.py).

This is the core correctness signal of the compile path: if these pass, the
HLO artifacts built by aot.py contain numerically-correct NVFP4 semantics.
Hypothesis sweeps shapes/dtypes/value distributions.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import kl, matmul, nvfp4, ref

RNG = np.random.default_rng(1234)


def randn(shape, scale=1.0, dtype=np.float32):
    return jnp.asarray((RNG.normal(size=shape) * scale).astype(dtype))


# ---------------------------------------------------------------- E2M1 / E4M3


class TestE2M1:
    def test_grid_values_fixed(self):
        exact = jnp.asarray([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0, -3.0, -6.0])
        assert jnp.all(ref.e2m1_round(exact) == exact)

    @pytest.mark.parametrize(
        "x,want",
        [
            (0.25, 0.0),  # tie -> even (0)
            (0.75, 1.0),  # tie -> even (1.0)
            (1.25, 1.0),
            (1.75, 2.0),
            (2.5, 2.0),
            (3.5, 4.0),
            (5.0, 4.0),
            (-2.5, -2.0),
            (-5.0, -4.0),
        ],
    )
    def test_round_half_even_ties(self, x, want):
        assert float(ref.e2m1_round(jnp.float32(x))) == want

    def test_clamp_to_six(self):
        assert float(ref.e2m1_round(jnp.float32(100.0))) == 6.0
        assert float(ref.e2m1_round(jnp.float32(-7.0))) == -6.0

    def test_arith_equals_table(self):
        xs = jnp.asarray(
            np.concatenate(
                [
                    RNG.normal(size=4096) * 3,
                    RNG.uniform(-7, 7, size=4096),
                    [0.25, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0, -0.25, -0.75, 0.0, 6.0, -6.0, 8.0],
                ]
            ).astype(np.float32)
        )
        assert jnp.all(ref.e2m1_round(xs) == ref.e2m1_round_arith(xs))

    def test_monotone(self):
        xs = jnp.linspace(-8, 8, 2001)
        ys = ref.e2m1_round(xs)
        assert jnp.all(jnp.diff(ys) >= 0)


class TestE4M3:
    def test_exact_values(self):
        # E4M3 represents powers of two and 448 exactly.
        for v in [0.0, 1.0, 2.0, 0.5, 448.0, -448.0, 1.5, 0.0625]:
            assert float(ref.e4m3_round(jnp.float32(v))) == v

    def test_saturates(self):
        assert float(ref.e4m3_round(jnp.float32(1e9))) == 448.0
        assert float(ref.e4m3_round(jnp.float32(-1e9))) == -448.0

    def test_relative_error_bound(self):
        # Normal-range E4M3 has 3 mantissa bits -> rel err <= 2^-4.
        x = jnp.asarray(RNG.uniform(1.0, 400.0, size=4096).astype(np.float32))
        y = ref.e4m3_round(x)
        assert float(jnp.max(jnp.abs(y - x) / x)) <= 2.0**-4 + 1e-6


# ------------------------------------------------------------------- NVFP4


class TestNVFP4Ref:
    def test_idempotent(self):
        x = randn((32, 64))
        q1 = ref.nvfp4_fake_quant_ref(x)
        q2 = ref.nvfp4_fake_quant_ref(q1)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=0, atol=1e-6)

    def test_zero_tensor(self):
        x = jnp.zeros((8, 32))
        assert jnp.all(ref.nvfp4_fake_quant_ref(x) == 0.0)

    def test_codes_on_grid(self):
        x = randn((16, 64), scale=5.0)
        _, codes, _ = ref.nvfp4_quantize_ref(x)
        grid = np.asarray([0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0])
        a = np.abs(np.asarray(codes)).ravel()
        assert np.all(np.isin(a, grid))

    def test_relative_error_reasonable(self):
        # NVFP4 on N(0,1): relative Frobenius error must sit in the known band.
        x = randn((256, 256))
        q = ref.nvfp4_fake_quant_ref(x)
        rel = float(jnp.linalg.norm(q - x) / jnp.linalg.norm(x))
        assert 0.03 < rel < 0.20, rel

    def test_scale_invariance(self):
        # Two-level scaling makes fake-quant scale-equivariant.
        x = randn((16, 32))
        q1 = ref.nvfp4_fake_quant_ref(x)
        q2 = ref.nvfp4_fake_quant_ref(x * 2**10) / 2**10
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-5, atol=1e-7)

    def test_outlier_containment(self):
        # A giant outlier must not destroy other *blocks* (block-16 isolation).
        x = np.array(randn((1, 64)))
        x[0, 0] = 1000.0
        q = np.asarray(ref.nvfp4_fake_quant_ref(jnp.asarray(x)))
        # Blocks 2..4 (indices 16..64) keep a sane relative error.
        rel = np.linalg.norm(q[0, 16:] - x[0, 16:]) / np.linalg.norm(x[0, 16:])
        assert rel < 0.25, rel

    def test_better_than_mxfp4_on_outliers(self):
        # The paper's motivation: NVFP4's small blocks + E4M3 scales beat
        # MXFP4's 32-blocks + power-of-two scales on outlier-heavy data.
        x = np.array(randn((64, 128)))
        idx = RNG.integers(0, x.size, size=32)
        x.ravel()[idx] *= 50.0
        x = jnp.asarray(x)
        err_nv = float(jnp.linalg.norm(ref.nvfp4_fake_quant_ref(x) - x))
        err_mx = float(jnp.linalg.norm(ref.mxfp4_fake_quant_ref(x) - x))
        assert err_nv < err_mx, (err_nv, err_mx)


class TestNVFP4Pallas:
    @pytest.mark.parametrize("shape", [(1, 16), (4, 32), (48, 64), (128, 128), (200, 48), (3, 5, 32)])
    def test_matches_ref(self, shape):
        x = randn(shape, scale=2.0)
        got = nvfp4.nvfp4_fake_quant_pallas(x)
        want = ref.nvfp4_fake_quant_ref(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_ref_with_outliers(self):
        x = np.array(randn((64, 64)))
        x[3, 17] = 500.0
        x[10, :16] = 0.0
        got = nvfp4.nvfp4_fake_quant_pallas(jnp.asarray(x))
        want = ref.nvfp4_fake_quant_ref(jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @settings(max_examples=25, deadline=None)
    @given(
        rows=st.integers(1, 96),
        cols_blocks=st.integers(1, 8),
        scale=st.sampled_from([1e-3, 1.0, 37.5, 1e4]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, rows, cols_blocks, scale, seed):
        r = np.random.default_rng(seed)
        x = jnp.asarray((r.normal(size=(rows, cols_blocks * 16)) * scale).astype(np.float32))
        got = nvfp4.nvfp4_fake_quant_pallas(x)
        want = ref.nvfp4_fake_quant_ref(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_inside_jit(self):
        x = randn((32, 32))
        got = jax.jit(nvfp4.nvfp4_fake_quant_pallas)(x)
        want = ref.nvfp4_fake_quant_ref(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestFakeQuantSTE:
    def test_none_is_identity(self):
        x = randn((8, 16))
        np.testing.assert_array_equal(
            np.asarray(nvfp4.fake_quant(x, nvfp4.QuantSpec("none"))), np.asarray(x)
        )

    @pytest.mark.parametrize("fmt", ["nvfp4", "mxfp4", "int4"])
    def test_gradient_is_identity(self, fmt):
        spec = nvfp4.QuantSpec(fmt, impl="jnp")
        x = randn((8, 32))
        ct = randn((8, 32))
        _, vjp = jax.vjp(lambda z: nvfp4.fake_quant(z, spec), x)
        (g,) = vjp(ct)
        np.testing.assert_array_equal(np.asarray(g), np.asarray(ct))

    def test_pallas_and_jnp_impls_identical(self):
        x = randn((40, 64), scale=3.0)
        a = nvfp4.fake_quant(x, nvfp4.QuantSpec("nvfp4", impl="pallas"))
        b = nvfp4.fake_quant(x, nvfp4.QuantSpec("nvfp4", impl="jnp"))
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ------------------------------------------------------------------ KL kernel


class TestKLKernel:
    def test_matches_ref(self):
        t = randn((37, 96), scale=3.0)
        s = randn((37, 96), scale=3.0)
        got = kl.kl_per_token(t, s, "pallas")
        want = ref.kl_per_token_ref(t, s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-6)

    def test_identical_logits_zero_kl(self):
        t = randn((16, 64))
        got = kl.kl_per_token(t, t, "pallas")
        np.testing.assert_allclose(np.asarray(got), 0.0, atol=1e-6)

    def test_nonnegative(self):
        t = randn((64, 48), scale=5.0)
        s = randn((64, 48), scale=5.0)
        assert float(jnp.min(kl.kl_per_token(t, s, "pallas"))) >= -1e-6

    def test_shift_invariance(self):
        # KL over softmax is invariant to per-token logit shifts.
        t = randn((8, 32))
        s = randn((8, 32))
        a = kl.kl_per_token(t, s, "pallas")
        b = kl.kl_per_token(t + 100.0, s - 50.0, "pallas")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-5)

    def test_3d_shapes(self):
        t = randn((2, 9, 32))
        s = randn((2, 9, 32))
        got = kl.kl_per_token(t, s, "pallas")
        assert got.shape == (2, 9)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref.kl_per_token_ref(t, s)), rtol=1e-5, atol=1e-6
        )

    def test_custom_vjp_matches_autodiff_of_ref(self):
        t = randn((6, 24))
        s = randn((6, 24))
        g_kernel = jax.grad(lambda z: jnp.sum(kl.kl_per_token(t, z, "pallas")))(s)
        g_ref = jax.grad(lambda z: jnp.sum(ref.kl_per_token_ref(t, z)))(s)
        np.testing.assert_allclose(np.asarray(g_kernel), np.asarray(g_ref), rtol=1e-4, atol=1e-6)

    @settings(max_examples=20, deadline=None)
    @given(
        rows=st.integers(1, 80),
        vocab=st.sampled_from([16, 48, 64, 160]),
        scale=st.sampled_from([0.1, 1.0, 10.0]),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, rows, vocab, scale, seed):
        r = np.random.default_rng(seed)
        t = jnp.asarray((r.normal(size=(rows, vocab)) * scale).astype(np.float32))
        s = jnp.asarray((r.normal(size=(rows, vocab)) * scale).astype(np.float32))
        got = kl.kl_per_token(t, s, "pallas")
        want = ref.kl_per_token_ref(t, s)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-5)


# ------------------------------------------------------------- fused matmul


class TestNVFP4Matmul:
    @pytest.mark.parametrize(
        "m,k,n,tiles",
        [
            (16, 32, 16, (16, 16, 32)),
            (32, 64, 48, (16, 16, 32)),
            (64, 128, 64, (32, 32, 64)),
            (128, 128, 128, (128, 128, 128)),
        ],
    )
    def test_matches_ref(self, m, k, n, tiles):
        x = randn((m, k))
        w = randn((k, n))
        tm, tn, tk = tiles
        got = matmul.nvfp4_matmul(x, w, tm=tm, tn=tn, tk=tk)
        want = ref.nvfp4_matmul_ref(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_tiling_invariance(self):
        # Output must not depend on the tile decomposition.
        x = randn((64, 128))
        w = randn((128, 64))
        a = matmul.nvfp4_matmul(x, w, tm=64, tn=64, tk=128)
        b = matmul.nvfp4_matmul(x, w, tm=16, tn=16, tk=16)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-4, atol=1e-4)

    def test_matches_composed_fake_quant_gemm(self):
        # The L2 model graphs use fake_quant(x) @ fake_quant(w.T).T — the
        # fused kernel must agree with that composition.
        x = randn((32, 64))
        w = randn((64, 32))
        composed = jnp.dot(
            ref.nvfp4_fake_quant_ref(x), ref.nvfp4_fake_quant_ref(w.T).T
        )
        got = matmul.nvfp4_matmul(x, w, tm=32, tn=32, tk=64)
        np.testing.assert_allclose(np.asarray(got), np.asarray(composed), rtol=1e-4, atol=1e-4)

    @settings(max_examples=12, deadline=None)
    @given(
        mi=st.integers(1, 4),
        ki=st.integers(1, 4),
        ni=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_sweep(self, mi, ki, ni, seed):
        r = np.random.default_rng(seed)
        m, k, n = 16 * mi, 32 * ki, 16 * ni
        x = jnp.asarray(r.normal(size=(m, k)).astype(np.float32))
        w = jnp.asarray(r.normal(size=(k, n)).astype(np.float32))
        got = matmul.nvfp4_matmul(x, w, tm=16, tn=16, tk=32)
        want = ref.nvfp4_matmul_ref(x, w)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)

    def test_vmem_estimate_positive(self):
        assert matmul.vmem_bytes() == 4 * (2 * 128 * 128 + 2 * 128 * 128 + 128 * 128)
