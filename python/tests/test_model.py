"""L2 correctness: model zoo shapes, quantization placement, and training
step semantics (the graphs that become the AOT artifacts)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import configs, model, steps
from compile.configs import BF16, QuantCfg, quant_cfg_for

RNG = np.random.default_rng(7)


def make_batch(cfg, seed=0):
    r = np.random.default_rng(seed)
    tokens = jnp.asarray(r.integers(4, cfg.vocab, size=(cfg.batch, cfg.seq_len)), jnp.int32)
    mask = jnp.ones((cfg.batch, cfg.seq_len), jnp.float32)
    pixels = (
        jnp.asarray(
            r.normal(size=(cfg.batch, cfg.vision_grid**2, cfg.vision_patch)).astype(np.float32)
        )
        if cfg.vision
        else None
    )
    return tokens, mask, pixels


# -------------------------------------------------------------------- layout


class TestParamLayout:
    @pytest.mark.parametrize("name", list(configs.ZOO))
    def test_layout_contiguous(self, name):
        cfg = configs.ZOO[name]
        layout = model.param_layout(cfg)
        off = 0
        for n, shape, o, size in layout:
            assert o == off
            assert size == int(np.prod(shape))
            off += size
        assert off == model.param_count(cfg)

    def test_unflatten_round_trip(self):
        cfg = configs.ACE_SIM
        vec = model.init_params(cfg, 3)
        p = model.unflatten(cfg, vec)
        rebuilt = jnp.concatenate([p[n].reshape(-1) for n, _ in model.param_defs(cfg)])
        np.testing.assert_array_equal(np.asarray(vec), np.asarray(rebuilt))

    def test_init_deterministic(self):
        cfg = configs.ACE_SIM
        a = model.init_params(cfg, 11)
        b = model.init_params(cfg, 11)
        c = model.init_params(cfg, 12)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert float(jnp.max(jnp.abs(a - c))) > 0

    def test_norm_scales_init_to_one(self):
        cfg = configs.NANO_SIM
        p = model.unflatten(cfg, model.init_params(cfg))
        assert jnp.all(p["ln_f"] == 1.0)
        assert jnp.all(p["b0.ln"] == 1.0)


# -------------------------------------------------------------------- forward


class TestForward:
    @pytest.mark.parametrize("name", ["ace-sim", "nano-sim", "nano3-sim", "super-sim"])
    def test_logit_shape(self, name):
        cfg = configs.ZOO[name]
        vec = model.init_params(cfg)
        tokens, _, _ = make_batch(cfg)
        logits = model.forward(cfg, vec, tokens)
        assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_vlm_needs_pixels(self):
        cfg = configs.VL_SIM
        vec = model.init_params(cfg)
        tokens, _, pixels = make_batch(cfg)
        logits = model.forward(cfg, vec, tokens, pixels)
        assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
        with pytest.raises(AssertionError):
            model.forward(cfg, vec, tokens, None)

    def test_vlm_pixels_matter(self):
        cfg = configs.VL_SIM
        vec = model.init_params(cfg)
        tokens, _, pixels = make_batch(cfg)
        a = model.forward(cfg, vec, tokens, pixels)
        b = model.forward(cfg, vec, tokens, pixels + 1.0)
        assert float(jnp.max(jnp.abs(a - b))) > 1e-4

    def test_causality(self):
        """Changing a future token must not affect past logits."""
        cfg = configs.ACE_SIM
        vec = model.init_params(cfg)
        tokens, _, _ = make_batch(cfg)
        t2 = tokens.at[:, -1].set((tokens[:, -1] + 1) % cfg.vocab)
        a = model.forward(cfg, vec, tokens)
        b = model.forward(cfg, vec, t2)
        np.testing.assert_allclose(
            np.asarray(a[:, :-1]), np.asarray(b[:, :-1]), rtol=1e-5, atol=1e-5
        )

    def test_ssm_causality(self):
        cfg = configs.NANO_SIM
        vec = model.init_params(cfg)
        tokens, _, _ = make_batch(cfg)
        t2 = tokens.at[:, 40:].set(5)
        a = model.forward(cfg, vec, tokens)
        b = model.forward(cfg, vec, t2)
        np.testing.assert_allclose(
            np.asarray(a[:, :39]), np.asarray(b[:, :39]), rtol=1e-4, atol=1e-4
        )

    def test_quantized_forward_differs_but_close(self):
        cfg = configs.ACE_SIM
        qcfg = cfg.with_quant(quant_cfg_for("ace-sim"))
        vec = model.init_params(cfg)
        tokens, _, _ = make_batch(cfg)
        a = model.forward(cfg, vec, tokens)
        q = model.forward(qcfg, vec, tokens)
        diff = float(jnp.max(jnp.abs(a - q)))
        assert diff > 1e-4  # quantization must actually change the output
        # ... but the distributions stay in the same regime.
        kl = jnp.mean(
            jnp.sum(
                jax.nn.softmax(a) * (jax.nn.log_softmax(a) - jax.nn.log_softmax(q)), axis=-1
            )
        )
        assert float(kl) < 1.0

    def test_selective_quant_skip_all_equals_bf16(self):
        """skip_first covering every block (+attn skip) must reproduce BF16
        exactly except the head... so also skip_last covers the head."""
        cfg = configs.ACE_SIM
        n = len(cfg.blocks)
        qc = QuantCfg(skip_attention=True, skip_first=n, skip_last=n)
        qcfg = cfg.with_quant(qc)
        vec = model.init_params(cfg)
        tokens, _, _ = make_batch(cfg)
        a = model.forward(cfg, vec, tokens)
        b = model.forward(qcfg, vec, tokens)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_nano_selective_quant_closer_than_full(self):
        """nano's skip config (attention + first/last at BF16) must have
        smaller logit error than fully-quantized."""
        cfg = configs.NANO_SIM
        vec = model.init_params(cfg)
        tokens, _, _ = make_batch(cfg)
        bf = model.forward(cfg, vec, tokens)
        sel = model.forward(cfg.with_quant(quant_cfg_for("nano-sim")), vec, tokens)
        full = model.forward(cfg.with_quant(QuantCfg()), vec, tokens)
        err_sel = float(jnp.linalg.norm(sel - bf))
        err_full = float(jnp.linalg.norm(full - bf))
        assert err_sel < err_full


# ---------------------------------------------------------------- train steps


class TestSteps:
    def test_state_layout(self):
        cfg = configs.ACE_SIM
        vec = model.init_params(cfg)
        st = steps.init_state(cfg, vec)
        assert st.shape == (steps.state_len(cfg),)
        p, m, v, sc = steps.split_state(cfg, st)
        np.testing.assert_array_equal(np.asarray(p), np.asarray(vec))
        assert jnp.all(m == 0) and jnp.all(v == 0) and jnp.all(sc == 0)

    def test_sft_decreases_loss(self):
        cfg = configs.ZOO["size-xs"]
        vec = model.init_params(cfg)
        st = steps.init_state(cfg, vec)
        tokens, mask, _ = make_batch(cfg)
        step = jax.jit(steps.make_sft_step(cfg))
        lr = jnp.float32(3e-3)
        first = None
        for i in range(30):
            st = step(st, tokens, mask, lr)
            if first is None:
                first = float(st[-steps.N_SCALARS + steps.S_LOSS])
        last = float(st[-steps.N_SCALARS + steps.S_LOSS])
        assert last < first * 0.7, (first, last)
        assert float(st[-steps.N_SCALARS + steps.S_STEP]) == 30.0

    def test_qad_reduces_kl(self):
        cfg = configs.ZOO["size-xs"]
        qcfg = cfg.with_quant(QuantCfg())
        teacher = model.init_params(cfg, 5)
        st = steps.init_state(cfg, teacher)  # student init = PTQ weights
        tokens, mask, _ = make_batch(cfg)
        step = jax.jit(steps.make_qad_step(qcfg, cfg, "jnp"))
        lr = jnp.float32(1e-3)
        kls = []
        for _ in range(25):
            st = step(st, teacher, tokens, mask, lr)
            kls.append(float(st[-steps.N_SCALARS + steps.S_KL]))
        assert kls[-1] < kls[0], kls
        assert kls[-1] >= 0

    def test_qad_keeps_teacher_fixed(self):
        cfg = configs.ZOO["size-xs"]
        qcfg = cfg.with_quant(QuantCfg())
        teacher = model.init_params(cfg, 5)
        st = steps.init_state(cfg, teacher)
        tokens, mask, _ = make_batch(cfg)
        step = jax.jit(steps.make_qad_step(qcfg, cfg, "jnp"))
        st = step(st, teacher, tokens, mask, jnp.float32(1e-3))
        # teacher vector is an input, never mutated — trivially true, but the
        # student params must have moved.
        p = steps.split_state(cfg, st)[0]
        assert float(jnp.max(jnp.abs(p - teacher))) > 0

    def test_rl_step_moves_toward_advantaged_sequences(self):
        cfg = configs.ZOO["size-xs"]
        vec = model.init_params(cfg)
        st = steps.init_state(cfg, vec)
        tokens, mask, _ = make_batch(cfg)
        adv = jnp.asarray(np.resize([1.0, -1.0], cfg.batch), jnp.float32)
        step = jax.jit(steps.make_rl_step(cfg))
        lr = jnp.float32(1e-3)

        def seq_ll(params):
            logits = model.forward(cfg, params, tokens[:, :-1])
            logp = jax.nn.log_softmax(logits)
            ll = jnp.take_along_axis(logp, tokens[:, 1:][..., None], axis=-1)[..., 0]
            return jnp.sum(ll * mask[:, 1:], axis=-1)

        before = seq_ll(vec)
        for _ in range(10):
            st = step(st, tokens, mask, adv, lr)
        after = seq_ll(steps.split_state(cfg, st)[0])
        gain = np.asarray(after - before)
        # Positive-advantage sequences gain log-likelihood relative to
        # negative-advantage ones.
        assert gain[adv > 0].mean() > gain[adv < 0].mean()

    def test_mse_step_runs(self):
        cfg = configs.ZOO["size-xs"]
        qcfg = cfg.with_quant(QuantCfg())
        teacher = model.init_params(cfg, 5)
        st = steps.init_state(cfg, teacher)
        tokens, mask, _ = make_batch(cfg)
        step = jax.jit(steps.make_mse_step(qcfg, cfg))
        st = step(st, teacher, tokens, mask, jnp.float32(1e-3))
        assert np.isfinite(float(st[-steps.N_SCALARS + steps.S_LOSS]))

    def test_nqt_grad_quantization_changes_update(self):
        cfg = configs.ZOO["size-xs"]
        qcfg = cfg.with_quant(QuantCfg())
        vec = model.init_params(cfg)
        tokens, mask, _ = make_batch(cfg)
        lr = jnp.float32(1e-3)
        a = steps.make_sft_step(qcfg)(steps.init_state(cfg, vec), tokens, mask, lr)
        b = steps.make_sft_step(qcfg, quantize_grads=True)(
            steps.init_state(cfg, vec), tokens, mask, lr
        )
        pa = steps.split_state(cfg, a)[0]
        pb = steps.split_state(cfg, b)[0]
        assert float(jnp.max(jnp.abs(pa - pb))) > 0

    def test_eval_metrics_zero_kl_for_identical(self):
        cfg = configs.ZOO["size-xs"]
        vec = model.init_params(cfg)
        tokens, mask, _ = make_batch(cfg)
        ev = jax.jit(steps.make_eval_metrics(cfg, cfg, "jnp"))
        out = ev(vec, vec, tokens, mask)
        assert out.shape == (8,)
        assert abs(float(out[0])) < 1e-5  # KL(teacher||teacher) == 0
        assert float(out[1]) > 0  # CE vs random labels is positive
        assert float(out[2]) == float(jnp.sum(mask[:, 1:]))

    def test_eval_metrics_quantized_kl_positive(self):
        cfg = configs.ZOO["size-xs"]
        qcfg = cfg.with_quant(QuantCfg())
        vec = model.init_params(cfg)
        tokens, mask, _ = make_batch(cfg)
        ev = jax.jit(steps.make_eval_metrics(qcfg, cfg, "jnp"))
        out = ev(vec, vec, tokens, mask)
        assert float(out[0]) > 1e-5  # PTQ shifts the distribution

    def test_mask_respected(self):
        """Loss must ignore masked-out positions."""
        cfg = configs.ZOO["size-xs"]
        vec = model.init_params(cfg)
        r = np.random.default_rng(0)
        tokens = jnp.asarray(r.integers(4, cfg.vocab, size=(cfg.batch, cfg.seq_len)), jnp.int32)
        half = jnp.concatenate(
            [jnp.zeros((cfg.batch, cfg.seq_len // 2)), jnp.ones((cfg.batch, cfg.seq_len // 2))],
            axis=1,
        ).astype(jnp.float32)
        # Perturb tokens only in the masked-out (prompt) label region but not
        # the inputs that generate masked-in labels: loss over masked region
        # uses labels at positions where half==1 only.
        l1 = steps.ce_loss(cfg, vec, tokens, half)
        t2 = tokens.at[:, 1 : cfg.seq_len // 2 - 1].set(7)
        # Changing masked-out *labels* changes inputs too (same ids feed the
        # model), so instead verify: full-mask loss != half-mask loss.
        l_full = steps.ce_loss(cfg, vec, tokens, jnp.ones_like(half))
        assert abs(float(l1) - float(l_full)) > 1e-7


# ------------------------------------------------------------------- lowering


class TestLowering:
    def test_hlo_text_round_trips(self, tmp_path):
        from compile import aot

        cfg = configs.ZOO["size-xs"]
        fwd = steps.make_fwd(cfg)
        p = jax.ShapeDtypeStruct((model.param_count(cfg),), jnp.float32)
        t = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
        lowered = jax.jit(fwd).lower(p, t)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text and "f32" in text
        # Single-array output: the root instruction is not a tuple.
        root_lines = [l for l in text.splitlines() if "ROOT" in l]
        assert root_lines and all("tuple(" not in l for l in root_lines), root_lines[:2]
