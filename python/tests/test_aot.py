"""Compile-path tests: HLO lowering, manifest integrity, golden vectors."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, configs, model, steps


class TestHloText:
    def test_single_output_no_tuple_root(self):
        cfg = configs.ZOO["size-xs"]
        fwd = steps.make_fwd(cfg)
        p = jax.ShapeDtypeStruct((model.param_count(cfg),), jnp.float32)
        t = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
        text = aot.to_hlo_text(jax.jit(fwd).lower(p, t))
        assert "ENTRY" in text
        roots = [l for l in text.splitlines() if "ROOT" in l]
        assert roots and all("tuple(" not in l for l in roots)

    def test_no_unparseable_ops(self):
        """Ops that postdate XLA 0.5.1's HLO text parser must not appear
        (regression: lax.top_k emitted `topk ... largest=true`)."""
        cfg = configs.ZOO["nano3-sim"]  # exercises MoE routing
        sft = steps.make_sft_step(cfg)
        n = steps.state_len(cfg)
        s = jax.ShapeDtypeStruct((n,), jnp.float32)
        t = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
        m = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.float32)
        lr = jax.ShapeDtypeStruct((), jnp.float32)
        text = aot.to_hlo_text(jax.jit(sft).lower(s, t, m, lr))
        for bad in (" topk(", "ragged", "composite-call"):
            assert bad not in text, f"{bad!r} not parseable by xla_extension 0.5.1"

    def test_state_vector_shape_contract(self):
        for name in ("ace-sim", "nano-sim", "vl-sim"):
            cfg = configs.ZOO[name]
            assert steps.state_len(cfg) == 3 * model.param_count(cfg) + steps.N_SCALARS

    def test_fwd_last_gathers_frontier_rows(self):
        """The frontier-gather graph must equal the full forward sliced at
        each row's own index — the contract `Sampler::generate` relies on
        when it downloads B·V floats instead of B·S·V."""
        cfg = configs.ZOO["size-xs"]
        rng = np.random.default_rng(0)
        params = model.init_params(cfg, 0)
        tokens = jnp.asarray(
            rng.integers(4, cfg.vocab, size=(cfg.batch, cfg.seq_len)), jnp.int32
        )
        idx = jnp.asarray(rng.integers(0, cfg.seq_len, size=(cfg.batch,)), jnp.int32)
        full = steps.make_fwd(cfg)(params, tokens)
        last = steps.make_fwd_last(cfg)(params, tokens, idx)
        assert last.shape == (cfg.batch, cfg.vocab)
        for b in range(cfg.batch):
            np.testing.assert_array_equal(
                np.asarray(last[b]), np.asarray(full[b, int(idx[b])])
            )

    def test_fwd_last_lowers_to_parseable_hlo(self):
        cfg = configs.ZOO["size-xs"]
        fwd_last = steps.make_fwd_last(cfg)
        p = jax.ShapeDtypeStruct((model.param_count(cfg),), jnp.float32)
        t = jax.ShapeDtypeStruct((cfg.batch, cfg.seq_len), jnp.int32)
        i = jax.ShapeDtypeStruct((cfg.batch,), jnp.int32)
        text = aot.to_hlo_text(jax.jit(fwd_last).lower(p, t, i))
        assert "ENTRY" in text
        roots = [l for l in text.splitlines() if "ROOT" in l]
        assert roots and all("tuple(" not in l for l in roots)


class TestManifest:
    @pytest.fixture(scope="class")
    def manifest(self):
        path = os.path.join(os.path.dirname(__file__), "../../artifacts/manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts not built")
        with open(path) as f:
            return json.load(f)

    def test_version_and_models(self, manifest):
        assert manifest["version"] == aot.MANIFEST_VERSION
        for name in configs.ZOO:
            assert name in manifest["models"], name

    def test_param_layout_matches_code(self, manifest):
        for name, cfg in configs.ZOO.items():
            entry = manifest["models"][name]
            assert entry["param_count"] == model.param_count(cfg), name
            layout = model.param_layout(cfg)
            assert len(entry["params"]) == len(layout)
            for p_json, (n, shape, off, size) in zip(entry["params"], layout):
                assert p_json["name"] == n
                assert tuple(p_json["shape"]) == tuple(shape)
                assert p_json["offset"] == off and p_json["size"] == size

    def test_artifact_files_exist(self, manifest):
        root = os.path.join(os.path.dirname(__file__), "../../artifacts")
        count = 0
        for name, entry in manifest["models"].items():
            for key, art in entry["artifacts"].items():
                path = os.path.join(root, art["file"])
                assert os.path.exists(path), f"{name}/{key}"
                count += 1
        assert count >= 40  # the zoo ships a substantial artifact set

    def test_core_artifacts_present(self, manifest):
        need = {"fwd_bf16", "fwd_nvfp4", "sft_bf16", "qat_nvfp4", "qad_nvfp4", "scalars",
                "fwd_bf16_state", "eval_nvfp4", "eval_bf16"}
        for name, entry in manifest["models"].items():
            missing = need - set(entry["artifacts"])
            assert not missing, f"{name} missing {missing}"

    def test_rl_models_have_rl_step(self, manifest):
        for name in ("ace-sim", "nano3-sim"):
            assert "rl_bf16" in manifest["models"][name]["artifacts"]

    def test_vocab_matches_tokenizer_contract(self, manifest):
        assert manifest["vocab"] == configs.VOCAB == 64
        sp = manifest["special"]
        assert (sp["pad"], sp["bos"], sp["eos"], sp["sep"]) == (0, 1, 2, 3)


class TestGolden:
    def test_golden_written_and_consistent(self, tmp_path):
        aot.write_golden(str(tmp_path))
        with open(tmp_path / "golden.json") as f:
            g = json.load(f)
        assert len(g["e4m3_in"]) == len(g["e4m3_out"])
        n = g["nvfp4_rows"] * g["nvfp4_cols"]
        assert len(g["nvfp4_deq"]) == n
        # dequantized values must be codes * scales exactly
        codes = np.asarray(g["nvfp4_codes"]).reshape(g["nvfp4_rows"], -1)
        scales = np.asarray(g["nvfp4_scales"]).reshape(g["nvfp4_rows"], -1)
        ts = g["nvfp4_tensor_scale"]
        deq = np.asarray(g["nvfp4_deq"]).reshape(codes.shape)
        rebuilt = codes * np.repeat(scales, 16, axis=1) * ts
        np.testing.assert_allclose(deq, rebuilt.astype(np.float32), rtol=1e-6)
