//! Serving-throughput harness: batched sampling over the quantized fwd
//! artifact — reports tokens/s and per-request latency percentiles for the
//! BF16 vs NVFP4 forward paths (the inference-efficiency side of the
//! paper's motivation: NVFP4 halves memory and raises throughput).
//!
//! Run: `cargo run --release --example serve_eval -- [--requests 64]`

use std::path::PathBuf;
use std::time::Instant;

use qadx::coordinator::init_params;
use qadx::data::{tasks, Suite};
use qadx::eval::{SampleCfg, Sampler};
use qadx::runtime::{Engine, ModelRuntime};
use qadx::util::args::Args;
use qadx::util::{mean, percentile, rng::Rng};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let engine = Engine::new(&PathBuf::from(args.get_or("artifacts", "artifacts")))?;
    let model = args.get_or("model", "ace-sim");
    let rt = ModelRuntime::new(&engine, &model)?;
    let n_requests = args.usize_or("requests", 64);
    let params = init_params(&rt.model, 3);
    let weights = rt.upload_params(&params)?;

    let mut rng = Rng::new(42);
    let suites = [Suite::Math500, Suite::Aime, Suite::Lcb, Suite::Gpqa];
    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|_| {
            let s = tasks::generate(*rng.choice(&suites), &mut rng, 4, 16);
            tasks::prompt_tokens(&s, rt.model.seq_len)
        })
        .collect();

    for fwd_key in ["fwd_bf16", "fwd_nvfp4"] {
        let mut sampler = Sampler::new(&rt, fwd_key, SampleCfg::default())?;
        // warm-up compile
        let _ = sampler.generate(&engine, &weights, &prompts[..1], None)?;
        let b = rt.model.batch;
        let mut latencies = Vec::new();
        let mut tokens_out = 0usize;
        let t0 = Instant::now();
        for chunk in prompts.chunks(b) {
            let t1 = Instant::now();
            let rows = sampler.generate(&engine, &weights, chunk, None)?;
            latencies.push(t1.elapsed().as_secs_f64() * 1000.0);
            for (p, row) in chunk.iter().zip(&rows) {
                tokens_out += row.iter().skip(p.len()).filter(|&&t| t != 0).count();
            }
        }
        let total = t0.elapsed().as_secs_f64();
        println!(
            "{fwd_key:<10} {n_requests} reqs | {:.1} req/s | {:.0} gen-tok/s | batch-lat p50 {:.0}ms p95 {:.0}ms (mean {:.0}ms)",
            n_requests as f64 / total,
            tokens_out as f64 / total,
            percentile(&latencies, 50.0),
            percentile(&latencies, 95.0),
            mean(&latencies),
        );
    }
    Ok(())
}
