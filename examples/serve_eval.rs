//! Serving-throughput harness over the `qadx::api` coalescing server:
//! requests are submitted one at a time and the `ServeHandle` fills
//! device batches (partial batches flush on a deadline), reporting req/s,
//! gen-tok/s, latency percentiles, and batch fill ratio for the BF16 vs
//! NVFP4 forward paths (the inference-efficiency side of the paper's
//! motivation: NVFP4 halves memory and raises throughput).
//!
//! Equivalent CLI: `qadx serve-bench --requests 64`.
//!
//! Run: `cargo run --release --example serve_eval -- [--requests 64]`

use std::time::Instant;

use qadx::api::{ServeCfg, Session};
use qadx::data::{tasks, Suite};
use qadx::util::args::Args;
use qadx::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let session = Session::builder()
        .artifacts_dir(args.get_or("artifacts", "artifacts"))
        .runs_dir(args.get_or("runs", "runs"))
        .build()?;
    let ms = session.model(&args.get_or("model", "ace-sim"))?;
    let n_requests = args.usize_or("requests", 64);

    let mut rng = Rng::new(42);
    let suites = [Suite::Math500, Suite::Aime, Suite::Lcb, Suite::Gpqa];
    let prompts: Vec<Vec<i32>> = (0..n_requests)
        .map(|_| {
            let s = tasks::generate(
                *rng.choice(&suites),
                &mut rng,
                ms.rt.model.vision_grid,
                ms.rt.model.vision_patch,
            );
            tasks::prompt_tokens(&s, ms.rt.model.seq_len)
        })
        .collect();

    for fwd_key in ["fwd_bf16", "fwd_nvfp4"] {
        let mut cfg = ServeCfg::default();
        cfg.max_batch_delay_ms = args.f64_or("max-delay-ms", 25.0);
        let mut server = ms.server(fwd_key, &cfg)?;
        let t0 = Instant::now();
        for p in &prompts {
            server.submit(p.clone())?;
        }
        let responses = server.drain()?;
        let total = t0.elapsed().as_secs_f64();
        anyhow::ensure!(responses.len() == n_requests, "lost requests");
        println!("{} | wall {total:.2}s", server.stats().summary());
    }
    Ok(())
}
