//! End-to-end validation driver (DESIGN.md §6): the full paper pipeline on
//! a real (sim-scale) workload, proving all three layers compose.
//!
//!   1. Train the AceReason-sim teacher through its multi-stage pipeline
//!      (cold-start SFT on partially-correct data → RL with verifiable
//!      rewards), all through AOT step artifacts on the PJRT runtime.
//!   2. PTQ-quantize (Rust NVFP4 codec) and measure the accuracy drop.
//!   3. Run QAD for a few hundred steps, logging the loss/KL curve.
//!   4. Evaluate BF16 / PTQ / QAD / QAT with the paper's sampling protocol
//!      and print the recovery table.
//!
//! Results are recorded in EXPERIMENTS.md. Flags: --scale F --steps N
//! --n N --k K (see qadx CLI).
//!
//! Run: `cargo run --release --example qad_e2e -- [--scale 0.5]`

use std::path::PathBuf;

use qadx::coordinator::{
    self, pipeline, ptq_report, Method, PipelineScale, RecoveryCfg,
};
use qadx::data::Suite;
use qadx::eval::EvalCfg;
use qadx::exper::report::TableReport;
use qadx::runtime::{Engine, ModelRuntime};
use qadx::util::args::Args;
use qadx::util::{CsvWriter, Timer};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let total = Timer::start("qad_e2e");
    let engine = Engine::new(&PathBuf::from(args.get_or("artifacts", "artifacts")))?;
    let runs = PathBuf::from(args.get_or("runs", "runs"));
    let scale = PipelineScale(args.f64_or("scale", 1.0));
    let model = "ace-sim";

    // --- 1. teacher pipeline (SFT -> RL) ----------------------------------
    println!("== stage 1: teacher post-training pipeline ({model}, scale {}) ==", scale.0);
    let teacher = coordinator::get_or_train_teacher(&engine, model, &runs, scale)?;
    let rt = ModelRuntime::new(&engine, model)?;

    // --- 2. PTQ -------------------------------------------------------------
    println!("\n== stage 2: NVFP4 PTQ export ==");
    let report = ptq_report(&rt, &teacher);
    for (name, err, _) in report.layers.iter().filter(|(_, e, _)| *e > 0.0) {
        println!("  {name:<12} rel_err {err:.4}");
    }
    println!(
        "  weights: {} -> {} bytes ({:.2}x compression)",
        report.total_bytes_f32,
        report.total_bytes_nvfp4,
        report.compression_ratio()
    );

    // --- 3. QAD with loss-curve logging -------------------------------------
    println!("\n== stage 3: QAD recovery ==");
    let steps = args.usize_or("steps", (300.0 * scale.0).max(60.0) as usize);
    let mut cfg = RecoveryCfg::new(
        vec![qadx::data::SourceSpec::sft_quality(
            pipeline::train_suites(model),
            0.7,
        )],
        args.f64_or("lr", 3e-4),
        steps,
    );
    cfg.train.log_every = (steps / 20).max(5);
    let qad = coordinator::run_method(&engine, &rt, Method::Qad, &teacher, &cfg)?;
    let mut csv = CsvWriter::create(&runs.join("e2e_loss_curve.csv"), &["step", "kl_loss"])?;
    for (s, l) in &qad.curve {
        println!("  step {s:>5}  KL loss {l:.5}");
        csv.row_f64("qad", &[*s as f64, *l])?;
    }
    let qat = coordinator::run_method(&engine, &rt, Method::Qat, &teacher, &cfg)?;

    // --- 4. evaluation -------------------------------------------------------
    println!("\n== stage 4: sampling-based evaluation ==");
    let mut ecfg = EvalCfg::default();
    ecfg.n_problems = args.usize_or("n", 32);
    ecfg.k_runs = args.usize_or("k", 3);
    let suites = [Suite::Math500, Suite::Aime, Suite::Lcb, Suite::SciCode];
    let mut table = TableReport::new(
        "qad_e2e",
        "end-to-end recovery (ace-sim)",
        &["Method", "math500", "aime", "livecodebench", "scicode"],
    );
    for (m, params) in [
        (Method::Bf16, &teacher),
        (Method::Ptq, &teacher),
        (Method::Qad, &qad.params),
        (Method::Qat, &qat.params),
    ] {
        let accs = coordinator::eval_method(&engine, &rt, m, params, &suites, &ecfg)?;
        let mut row = vec![m.name().to_string()];
        for s in &suites {
            row.push(format!("{:.1}", accs[s.name()]));
        }
        table.row(row);
    }
    table.print();
    table.save(&runs.join("report"))?;
    println!("{}", total.report());
    Ok(())
}
