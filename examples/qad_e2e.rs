//! End-to-end validation driver (DESIGN.md §6): the full paper pipeline on
//! a real (sim-scale) workload through the `qadx::api` façade.
//!
//!   1. Train the AceReason-sim teacher through its multi-stage pipeline
//!      (cold-start SFT on partially-correct data → RL with verifiable
//!      rewards) — `ModelSession::teacher()` caches it under runs/teachers.
//!   2. PTQ-quantize (Rust NVFP4 codec) and measure the accuracy drop.
//!   3. Run QAD for a few hundred steps, logging the loss/KL curve.
//!   4. Evaluate BF16 / PTQ / QAD / QAT with the paper's sampling protocol
//!      and print the recovery table.
//!
//! Results are recorded in EXPERIMENTS.md. Flags: --scale F --steps N
//! --n N --k K (see qadx CLI).
//!
//! Run: `cargo run --release --example qad_e2e -- [--scale 0.5]`

use qadx::api::Session;
use qadx::data::{SourceSpec, Suite};
use qadx::eval::EvalCfg;
use qadx::exper::report::TableReport;
use qadx::util::args::Args;
use qadx::util::{CsvWriter, Timer};

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let total = Timer::start("qad_e2e");
    let session = Session::builder()
        .artifacts_dir(args.get_or("artifacts", "artifacts"))
        .runs_dir(args.get_or("runs", "runs"))
        .scale(args.f64_or("scale", 1.0))
        .build()?;
    let ms = session.model("ace-sim")?;

    // --- 1. teacher pipeline (SFT -> RL) ----------------------------------
    println!(
        "== stage 1: teacher post-training pipeline ({}, scale {}) ==",
        ms.name(),
        session.scale().0
    );
    let teacher = ms.teacher()?;

    // --- 2. PTQ -------------------------------------------------------------
    println!("\n== stage 2: NVFP4 PTQ export ==");
    let report = ms.ptq_report()?;
    for (name, err, _) in report.layers.iter().filter(|(_, e, _)| *e > 0.0) {
        println!("  {name:<12} rel_err {err:.4}");
    }
    println!(
        "  weights: {} -> {} bytes ({:.2}x compression)",
        report.total_bytes_f32,
        report.total_bytes_nvfp4,
        report.compression_ratio()
    );

    // --- 3. QAD with loss-curve logging -------------------------------------
    println!("\n== stage 3: QAD recovery ==");
    let scale = session.scale().0;
    let steps = args.usize_or("steps", (300.0 * scale).max(60.0) as usize);
    let mut cfg = qadx::coordinator::RecoveryCfg::new(
        vec![SourceSpec::sft_quality(ms.train_suites(), 0.7)],
        args.f64_or("lr", 3e-4),
        steps,
    );
    cfg.train.log_every = (steps / 20).max(5);
    let qad = session.method("qad")?;
    let qat = session.method("qat")?;
    let qad_out = ms.recover(&*qad, &cfg)?;
    let mut csv = CsvWriter::create(
        &session.runs_dir().join("e2e_loss_curve.csv"),
        &["step", "kl_loss"],
    )?;
    for (s, l) in &qad_out.curve {
        println!("  step {s:>5}  KL loss {l:.5}");
        csv.row_f64("qad", &[*s as f64, *l])?;
    }
    let qat_out = ms.recover(&*qat, &cfg)?;

    // --- 4. evaluation -------------------------------------------------------
    println!("\n== stage 4: sampling-based evaluation ==");
    let mut ecfg = EvalCfg::default();
    ecfg.n_problems = args.usize_or("n", 32);
    ecfg.k_runs = args.usize_or("k", 3);
    let suites = [Suite::Math500, Suite::Aime, Suite::Lcb, Suite::SciCode];
    let mut table = TableReport::new(
        "qad_e2e",
        "end-to-end recovery (ace-sim)",
        &["Method", "math500", "aime", "livecodebench", "scicode"],
    );
    for (key, params) in [
        ("bf16", teacher.as_slice()),
        ("ptq", teacher.as_slice()),
        ("qad", qad_out.params.as_slice()),
        ("qat", qat_out.params.as_slice()),
    ] {
        let method = session.method(key)?;
        let accs = ms.evaluate(&*method, params, &suites, &ecfg)?;
        let mut row = vec![method.display_name().to_string()];
        for s in &suites {
            row.push(format!("{:.1}", accs[s.name()]));
        }
        table.row(row);
    }
    table.print();
    table.save(&session.report_dir())?;
    println!("{}", total.report());
    Ok(())
}
