//! Data-source ablation through the public API (a Table-5-style mini
//! sweep): recover an NVFP4 student with QAD using different training data
//! sources — including teacher-generated and random tokens — and compare.
//!
//! Run: `cargo run --release --example data_ablation -- [--steps 120] [--scale 0.5]`

use qadx::api::Session;
use qadx::data::{SourceKind, SourceSpec, Suite};
use qadx::eval::EvalCfg;
use qadx::exper::report::TableReport;
use qadx::util::args::Args;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let session = Session::builder()
        .artifacts_dir(args.get_or("artifacts", "artifacts"))
        .runs_dir(args.get_or("runs", "runs"))
        .scale(args.f64_or("scale", 1.0))
        .build()?;
    let ms = session.model("ace-sim")?;
    let qad = session.method("qad")?;

    let suites = ms.train_suites();
    let steps = args.usize_or("steps", 150);
    let mut ecfg = EvalCfg::default();
    ecfg.n_problems = args.usize_or("n", 24);
    ecfg.k_runs = args.usize_or("k", 2);
    let eval_suites = [Suite::Math500, Suite::Aime, Suite::Lcb];

    let mut table = TableReport::new(
        "data_ablation",
        "QAD data-source ablation (public-API example)",
        &["source", "math500", "aime", "livecodebench"],
    );

    let sources: Vec<(&str, SourceSpec)> = vec![
        ("sft", SourceSpec::sft_quality(suites, 0.7)),
        (
            "rl-generated",
            SourceSpec { kind: SourceKind::RlGenerated, suites: suites.to_vec(), weight: 1.0 },
        ),
        (
            "bos-generated",
            SourceSpec { kind: SourceKind::BosGenerated, suites: vec![], weight: 1.0 },
        ),
        (
            "random-tokens",
            SourceSpec { kind: SourceKind::RandomTokens, suites: vec![], weight: 1.0 },
        ),
    ];
    for (name, spec) in sources {
        let mut cfg =
            qadx::coordinator::RecoveryCfg::new(vec![spec], args.f64_or("lr", 3e-4), steps);
        cfg.eval = ecfg;
        let out = ms.recover(&*qad, &cfg)?;
        let accs = ms.evaluate(&*qad, &out.params, &eval_suites, &ecfg)?;
        let mut row = vec![name.to_string()];
        for s in &eval_suites {
            row.push(format!("{:.1}", accs[s.name()]));
        }
        println!("{name}: {accs:?}");
        table.row(row);
    }
    table.print();
    Ok(())
}
