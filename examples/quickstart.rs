//! Quickstart: the three layers in one page.
//!
//! 1. Quantize a tensor with the Rust NVFP4 codec and inspect the error.
//! 2. Load an AOT artifact (built by `make artifacts`) into the PJRT
//!    runtime and run the quantized forward pass.
//! 3. Run one QAD training step against a BF16 teacher and watch the KL
//!    metric come back from the device.
//!
//! Run: `cargo run --release --example quickstart`

use qadx::coordinator::init_params;
use qadx::data::{shape_for, BatchFactory, SourceSpec, TEXT_SUITES};
use qadx::quant::{self, Nvfp4Tensor};
use qadx::runtime::{scalar, DeviceState, Engine, ModelRuntime};
use qadx::util::rng::Rng;
use std::path::Path;

fn main() -> anyhow::Result<()> {
    // --- 1. The NVFP4 codec (no runtime needed) ---------------------------
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..64 * 64).map(|_| rng.normal() as f32).collect();
    let q = Nvfp4Tensor::quantize(&x, 64, 64, None);
    let deq = q.dequantize();
    println!(
        "NVFP4: {} f32 -> {} bytes ({:.2} bits/elem), rel err {:.3}",
        x.len(),
        q.storage_bytes(),
        q.bits_per_element(),
        quant::rel_error(&x, &deq),
    );

    // --- 2. The PJRT runtime ----------------------------------------------
    let engine = Engine::new(Path::new("artifacts"))?;
    let rt = ModelRuntime::new(&engine, "ace-sim")?;
    println!(
        "loaded {} ({} params, {} artifacts)",
        rt.model.name,
        rt.model.param_count,
        rt.model.artifacts.len()
    );
    let params = init_params(&rt.model, 0);
    let p_buf = rt.upload_params(&params)?;

    let mut factory = BatchFactory::new(
        shape_for(&rt.model),
        vec![SourceSpec::sft(TEXT_SUITES)],
        1,
    );
    let batch = factory.next_batch(None)?;
    let tokens = rt.upload_tokens(&batch)?;
    let fwd = rt.exe("fwd_nvfp4")?;
    let logits = engine.run_b(&fwd, &[&p_buf, &tokens])?;
    let host = engine.download_f32(&logits, rt.model.batch * rt.model.seq_len * rt.model.vocab)?;
    println!("quantized fwd: {} logits, first = {:.4}", host.len(), host[0]);

    // --- 3. One QAD step ----------------------------------------------------
    let mut state = DeviceState::from_params(&rt, &params)?;
    let qad = rt.exe("qad_nvfp4")?;
    let mask = rt.upload_mask(&batch)?;
    let lr = engine.upload_scalar(1e-4)?;
    for i in 0..5 {
        let out = engine.run_b(&qad, &[&state.buf, &p_buf, &tokens, &mask, &lr])?;
        state.advance(out);
        let sc = state.scalars()?;
        println!(
            "qad step {}: KL(teacher||student) = {:.5}",
            i + 1,
            sc[scalar::KL]
        );
    }
    println!("quickstart OK");
    Ok(())
}
