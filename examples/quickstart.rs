//! Quickstart: the three layers in one page, through the `qadx::api`
//! façade.
//!
//! 1. Quantize a tensor with the Rust NVFP4 codec and inspect the error.
//! 2. Open a `Session` (owns the PJRT engine + AOT artifacts, built by
//!    `make artifacts`), bind a model, and run the quantized forward pass.
//! 3. Run one QAD training step against a BF16 teacher and watch the KL
//!    metric come back from the device.
//!
//! Run: `cargo run --release --example quickstart`

use qadx::api::Session;
use qadx::coordinator::init_params;
use qadx::data::{shape_for, BatchFactory, SourceSpec, TEXT_SUITES};
use qadx::quant::{self, Nvfp4Tensor};
use qadx::runtime::{scalar, DeviceState};
use qadx::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // --- 1. The NVFP4 codec (no runtime needed) ---------------------------
    let mut rng = Rng::new(7);
    let x: Vec<f32> = (0..64 * 64).map(|_| rng.normal() as f32).collect();
    let q = Nvfp4Tensor::quantize(&x, 64, 64, None);
    let deq = q.dequantize();
    println!(
        "NVFP4: {} f32 -> {} bytes ({:.2} bits/elem), rel err {:.3}",
        x.len(),
        q.storage_bytes(),
        q.bits_per_element(),
        quant::rel_error(&x, &deq),
    );

    // --- 2. A session over the PJRT runtime -------------------------------
    let session = Session::builder().artifacts_dir("artifacts").build()?;
    let ms = session.model("ace-sim")?;
    let engine = session.engine();
    println!(
        "loaded {} ({} params, {} artifacts)",
        ms.name(),
        ms.rt.model.param_count,
        ms.rt.model.artifacts.len()
    );
    let params = init_params(&ms.rt.model, 0);
    let p_buf = ms.rt.upload_params(&params)?;

    let mut factory = BatchFactory::new(
        shape_for(&ms.rt.model),
        vec![SourceSpec::sft(TEXT_SUITES)],
        1,
    );
    let batch = factory.next_batch(None)?;
    let tokens = ms.rt.upload_tokens(&batch)?;
    let fwd = ms.rt.exe("fwd_nvfp4")?;
    let logits = engine.run_b(&fwd, &[&p_buf, &tokens])?;
    let host =
        engine.download_f32(&logits, ms.rt.model.batch * ms.rt.model.seq_len * ms.rt.model.vocab)?;
    println!("quantized fwd: {} logits, first = {:.4}", host.len(), host[0]);

    // --- 3. One QAD step ----------------------------------------------------
    let mut state = DeviceState::from_params(&ms.rt, &params)?;
    let qad = ms.rt.exe("qad_nvfp4")?;
    let mask = ms.rt.upload_mask(&batch)?;
    let lr = engine.upload_scalar(1e-4)?;
    for i in 0..5 {
        let out = engine.run_b(&qad, &[&state.buf, &p_buf, &tokens, &mask, &lr])?;
        state.advance(out);
        let sc = state.scalars()?;
        println!(
            "qad step {}: KL(teacher||student) = {:.5}",
            i + 1,
            sc[scalar::KL]
        );
    }
    // The full recovery loop is one call away:
    //   let out = ms.recover(&*session.method("qad")?, &ms.default_recovery_cfg(300))?;
    println!("quickstart OK");
    Ok(())
}
