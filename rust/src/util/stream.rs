//! Bounded producer/consumer channel with an explicit slow-consumer
//! policy — the backpressure primitive behind token streaming in
//! `api::serve` / `api::fleet`.
//!
//! Unlike `std::sync::mpsc::sync_channel`, overflow behavior is a
//! caller-chosen [`SlowConsumer`] policy: block with a hard deadline
//! (lossless, bounded producer stall), drop the oldest buffered item
//! (lossy, keeps the freshest tail), or disconnect the stream entirely
//! (fail-fast degrade — the producer keeps working, the stream stops).
//! Every policy decision is counted in [`ChanStats`], so servers surface
//! tokens-dropped / consumer-stall gauges instead of silently losing
//! data. The channel itself never panics and never blocks past the
//! configured deadline — one stalled consumer cannot wedge a producer.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::Duration;

/// What to do when a bounded stream buffer is full (the consumer is not
/// keeping up with the producer).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum SlowConsumer {
    /// Lossless with a hard bound: the producer waits for buffer space up
    /// to `deadline_ms`; if the consumer still has not drained anything,
    /// the stream degrades to disconnected (the request keeps generating,
    /// the stream stops). Each wait counts as a consumer stall.
    Block { deadline_ms: f64 },
    /// Lossy: discard the oldest buffered item to make room — the
    /// consumer sees the freshest tail and the producer never waits.
    DropOldest,
    /// Fail-fast: sever the stream on first overflow. Already-buffered
    /// items stay readable; everything after is discarded.
    Disconnect,
}

impl Default for SlowConsumer {
    fn default() -> SlowConsumer {
        SlowConsumer::Block { deadline_ms: 250.0 }
    }
}

/// Counters accumulated by one channel over its lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ChanStats {
    /// Items discarded: `DropOldest` victims plus anything pushed after
    /// the stream disconnected.
    pub dropped: u64,
    /// Producer stalls: blocking waits entered under `Block`, plus
    /// non-blocking pushes refused back to the caller (`try_push`).
    pub stalls: u64,
    /// The stream was severed by policy (`Disconnect` overflow, a `Block`
    /// deadline timeout, or the receiver going away).
    pub disconnected: bool,
}

/// What one push did after the policy was applied.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PushOutcome {
    Stored,
    /// Stored after a blocking wait (`Block`; counted as one stall).
    StoredAfterWait,
    /// Stored by discarding the oldest buffered item (`DropOldest`).
    DroppedOldest,
    /// The stream is disconnected; the item was discarded.
    Disconnected,
}

struct Inner<T> {
    cap: usize,
    policy: SlowConsumer,
    buf: VecDeque<T>,
    stats: ChanStats,
    /// Producer is done; the consumer may still drain the buffer.
    closed: bool,
}

struct Shared<T> {
    inner: Mutex<Inner<T>>,
    /// Signalled by the consumer whenever space frees up (and on
    /// receiver drop, so a blocked producer always wakes).
    space: Condvar,
}

fn lock<T>(shared: &Shared<T>) -> MutexGuard<'_, Inner<T>> {
    match shared.inner.lock() {
        Ok(g) => g,
        // A poisoned lock means a panic elsewhere; the queue state itself
        // is still coherent (every mutation is a single push/pop).
        Err(p) => p.into_inner(),
    }
}

/// Producer half. Clonable so a retried request can stream into the same
/// channel from a new worker; `Send` so it crosses into worker threads.
pub struct BoundedTx<T> {
    shared: Arc<Shared<T>>,
}

impl<T> Clone for BoundedTx<T> {
    fn clone(&self) -> BoundedTx<T> {
        BoundedTx { shared: self.shared.clone() }
    }
}

/// Consumer half (single consumer; polling interface).
pub struct BoundedRx<T> {
    shared: Arc<Shared<T>>,
}

/// Create a bounded channel of `capacity` items governed by `policy`.
/// Capacity is clamped to at least 1.
pub fn bounded<T>(capacity: usize, policy: SlowConsumer) -> (BoundedTx<T>, BoundedRx<T>) {
    let shared = Arc::new(Shared {
        inner: Mutex::new(Inner {
            cap: capacity.max(1),
            policy,
            buf: VecDeque::new(),
            stats: ChanStats::default(),
            closed: false,
        }),
        space: Condvar::new(),
    });
    (BoundedTx { shared: shared.clone() }, BoundedRx { shared })
}

impl<T> BoundedTx<T> {
    /// Deliver `v`, applying the slow-consumer policy on overflow. Only
    /// the `Block` policy can wait, and never past its deadline; a timed
    /// out wait severs the stream so later pushes return immediately.
    pub fn push(&self, v: T) -> PushOutcome {
        let mut inner = lock(&self.shared);
        if inner.stats.disconnected {
            inner.stats.dropped += 1;
            return PushOutcome::Disconnected;
        }
        if inner.buf.len() < inner.cap {
            inner.buf.push_back(v);
            return PushOutcome::Stored;
        }
        match inner.policy {
            SlowConsumer::DropOldest => {
                inner.buf.pop_front();
                inner.stats.dropped += 1;
                inner.buf.push_back(v);
                PushOutcome::DroppedOldest
            }
            SlowConsumer::Disconnect => {
                inner.stats.disconnected = true;
                inner.stats.dropped += 1;
                PushOutcome::Disconnected
            }
            SlowConsumer::Block { deadline_ms } => {
                inner.stats.stalls += 1;
                let deadline = Duration::from_secs_f64(deadline_ms.max(0.0) / 1000.0);
                let waited = self.shared.space.wait_timeout_while(inner, deadline, |i| {
                    i.buf.len() >= i.cap && !i.stats.disconnected
                });
                let mut inner = match waited {
                    Ok((g, _)) => g,
                    Err(p) => p.into_inner().0,
                };
                if inner.stats.disconnected {
                    inner.stats.dropped += 1;
                    PushOutcome::Disconnected
                } else if inner.buf.len() < inner.cap {
                    inner.buf.push_back(v);
                    PushOutcome::StoredAfterWait
                } else {
                    // deadline elapsed with no space: the consumer is
                    // gone for practical purposes — degrade the stream
                    inner.stats.disconnected = true;
                    inner.stats.dropped += 1;
                    PushOutcome::Disconnected
                }
            }
        }
    }

    /// Non-blocking variant: a full `Block`-policy buffer is returned to
    /// the caller (counted as a stall) instead of waiting. A single-
    /// threaded scheduler that is also the consumer's driver uses this to
    /// relay inline rather than deadlock against itself. The lossy
    /// policies behave exactly as in [`push`](Self::push).
    pub fn try_push(&self, v: T) -> Result<PushOutcome, T> {
        let mut inner = lock(&self.shared);
        if inner.stats.disconnected {
            inner.stats.dropped += 1;
            return Ok(PushOutcome::Disconnected);
        }
        if inner.buf.len() < inner.cap {
            inner.buf.push_back(v);
            return Ok(PushOutcome::Stored);
        }
        match inner.policy {
            SlowConsumer::DropOldest => {
                inner.buf.pop_front();
                inner.stats.dropped += 1;
                inner.buf.push_back(v);
                Ok(PushOutcome::DroppedOldest)
            }
            SlowConsumer::Disconnect => {
                inner.stats.disconnected = true;
                inner.stats.dropped += 1;
                Ok(PushOutcome::Disconnected)
            }
            SlowConsumer::Block { .. } => {
                inner.stats.stalls += 1;
                Err(v)
            }
        }
    }

    /// Producer is done; the consumer can still drain what is buffered.
    pub fn close(&self) {
        lock(&self.shared).closed = true;
    }

    pub fn is_disconnected(&self) -> bool {
        lock(&self.shared).stats.disconnected
    }

    pub fn stats(&self) -> ChanStats {
        lock(&self.shared).stats
    }
}

impl<T> BoundedRx<T> {
    /// Take the oldest buffered item, freeing space for the producer.
    pub fn try_recv(&self) -> Option<T> {
        let mut inner = lock(&self.shared);
        let v = inner.buf.pop_front();
        if v.is_some() {
            self.shared.space.notify_all();
        }
        v
    }

    /// Items currently buffered.
    pub fn len(&self) -> usize {
        lock(&self.shared).buf.len()
    }

    pub fn is_empty(&self) -> bool {
        lock(&self.shared).buf.is_empty()
    }

    /// Producer closed and the buffer is fully drained.
    pub fn finished(&self) -> bool {
        let inner = lock(&self.shared);
        inner.closed && inner.buf.is_empty()
    }

    pub fn stats(&self) -> ChanStats {
        lock(&self.shared).stats
    }
}

impl<T> Drop for BoundedRx<T> {
    fn drop(&mut self) {
        // the consumer is gone: sever the stream and wake any producer
        // blocked on space so it degrades instead of sleeping out its
        // deadline for nothing
        lock(&self.shared).stats.disconnected = true;
        self.shared.space.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stores_until_capacity_then_applies_drop_oldest() {
        let (tx, rx) = bounded::<u32>(2, SlowConsumer::DropOldest);
        assert_eq!(tx.push(1), PushOutcome::Stored);
        assert_eq!(tx.push(2), PushOutcome::Stored);
        assert_eq!(tx.push(3), PushOutcome::DroppedOldest);
        assert_eq!(tx.push(4), PushOutcome::DroppedOldest);
        // the freshest tail survives, oldest items were discarded
        assert_eq!(rx.try_recv(), Some(3));
        assert_eq!(rx.try_recv(), Some(4));
        assert_eq!(rx.try_recv(), None);
        let st = rx.stats();
        assert_eq!(st.dropped, 2);
        assert!(!st.disconnected);
    }

    #[test]
    fn disconnect_policy_severs_on_first_overflow() {
        let (tx, rx) = bounded::<u32>(1, SlowConsumer::Disconnect);
        assert_eq!(tx.push(1), PushOutcome::Stored);
        assert_eq!(tx.push(2), PushOutcome::Disconnected);
        assert!(tx.is_disconnected());
        // buffered items stay readable; post-disconnect pushes are counted
        assert_eq!(tx.push(3), PushOutcome::Disconnected);
        assert_eq!(rx.try_recv(), Some(1));
        assert_eq!(rx.try_recv(), None);
        assert_eq!(rx.stats().dropped, 2);
        assert!(rx.stats().disconnected);
    }

    #[test]
    fn try_push_refuses_block_overflow_without_waiting() {
        let (tx, rx) = bounded::<u32>(1, SlowConsumer::Block { deadline_ms: 10_000.0 });
        assert_eq!(tx.try_push(7), Ok(PushOutcome::Stored));
        // full + Block: returned to the caller immediately, stall counted
        assert_eq!(tx.try_push(8), Err(8));
        assert_eq!(tx.stats().stalls, 1);
        assert_eq!(rx.try_recv(), Some(7));
        assert_eq!(tx.try_push(8), Ok(PushOutcome::Stored));
    }

    #[test]
    fn block_policy_waits_for_a_live_consumer() {
        let (tx, rx) = bounded::<u32>(1, SlowConsumer::Block { deadline_ms: 5_000.0 });
        assert_eq!(tx.push(1), PushOutcome::Stored);
        let consumer = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(20));
            rx.try_recv()
        });
        // blocks until the consumer frees space, well inside the deadline
        assert_eq!(tx.push(2), PushOutcome::StoredAfterWait);
        assert_eq!(consumer.join().ok().flatten(), Some(1));
        let st = tx.stats();
        assert_eq!(st.stalls, 1);
        assert!(!st.disconnected);
    }

    #[test]
    fn block_deadline_timeout_degrades_to_disconnect() {
        let (tx, _rx) = bounded::<u32>(1, SlowConsumer::Block { deadline_ms: 5.0 });
        assert_eq!(tx.push(1), PushOutcome::Stored);
        // nobody drains: the wait times out and the stream severs instead
        // of blocking the producer forever
        assert_eq!(tx.push(2), PushOutcome::Disconnected);
        assert!(tx.is_disconnected());
        assert_eq!(tx.push(3), PushOutcome::Disconnected);
        let st = tx.stats();
        assert_eq!(st.stalls, 1);
        assert_eq!(st.dropped, 2);
    }

    #[test]
    fn dropping_the_receiver_disconnects_the_producer() {
        let (tx, rx) = bounded::<u32>(1, SlowConsumer::Block { deadline_ms: 60_000.0 });
        drop(rx);
        // no consumer: the push must return immediately, not wait 60s
        assert_eq!(tx.push(1), PushOutcome::Disconnected);
        assert!(tx.is_disconnected());
    }

    #[test]
    fn close_marks_finished_once_drained() {
        let (tx, rx) = bounded::<u32>(4, SlowConsumer::default());
        tx.push(1);
        tx.close();
        assert!(!rx.finished(), "buffered item still pending");
        assert_eq!(rx.try_recv(), Some(1));
        assert!(rx.finished());
    }
}
