//! The reference backend's GEMM family: cache-blocked, unrolled, and
//! row-tile parallel over [`pool`](super::pool) — while staying
//! *bit-identical* to the seed's naive loops.
//!
//! The invariant that makes that possible: for every output element, the
//! sequence of f32 operations (one rounded multiply + one rounded add per
//! contraction index, accumulated in ascending contraction order from a
//! 0.0 start) is exactly the seed kernel's sequence. Blocking only
//! reorders *which element* is updated next, never the op sequence within
//! an element; parallelism only partitions whole output rows, whose
//! chains are self-contained. Rust f32 arithmetic is strict IEEE (no FMA
//! contraction, no reassociation), so equal op sequences give equal bits
//! on every platform and at every thread count. The seed kernels are kept
//! under `reference` (cfg(test)) and the property tests at the bottom
//! assert bitwise equality across rectangular, ragged, and randomized
//! shapes.
//!
//! `matmul_nt` historically walked `i,p,j` with a scalar dot-product
//! accumulator — a strictly sequential FP reduction the compiler cannot
//! vectorize without changing results. It now packs Bᵀ once and runs the
//! same `i,k,j`-hoisted axpy traversal as `matmul`, which performs the
//! identical per-element op sequence (ascending contraction order) and
//! therefore identical bits, but vectorizes and blocks like the others.

use super::pool;

/// Contraction-panel length (rows of B kept hot across a row tile).
const KC: usize = 128;
/// Output-column panel length (f32s of each B row touched per pass).
const NC: usize = 256;

/// out[j] += av * b[j], unrolled by 8. Each element is one rounded
/// multiply + one rounded add — exactly the seed's scalar update.
#[inline]
fn axpy(o: &mut [f32], av: f32, b: &[f32]) {
    debug_assert_eq!(o.len(), b.len());
    let mut oc = o.chunks_exact_mut(8);
    let mut bc = b.chunks_exact(8);
    for (ov, bv) in (&mut oc).zip(&mut bc) {
        ov[0] += av * bv[0];
        ov[1] += av * bv[1];
        ov[2] += av * bv[2];
        ov[3] += av * bv[3];
        ov[4] += av * bv[4];
        ov[5] += av * bv[5];
        ov[6] += av * bv[6];
        ov[7] += av * bv[7];
    }
    for (ov, bv) in oc.into_remainder().iter_mut().zip(bc.remainder()) {
        *ov += av * *bv;
    }
}

/// Rows per parallel tile: enough tiles for load balance, capped so the
/// per-tile working set stays cache-sized. Purely a throughput knob —
/// results are tile-size-invariant.
fn row_tile(m: usize) -> usize {
    let target = pool::threads().saturating_mul(4).max(1);
    m.div_ceil(target).clamp(1, 64)
}

/// The blocked inner kernel for `rows` output rows starting at absolute
/// row `r0`: C[r0..r0+rows, :] += A[r0.., :k] · B, with B given in
/// (contraction, out-col) = (k, n) layout.
fn kernel_nn(a: &[f32], b: &[f32], out_tile: &mut [f32], r0: usize, k: usize, n: usize) {
    let rows = if n == 0 { 0 } else { out_tile.len() / n };
    for jj in (0..n).step_by(NC) {
        let jmax = (jj + NC).min(n);
        for kk in (0..k).step_by(KC) {
            let kmax = (kk + KC).min(k);
            for i in 0..rows {
                let arow = &a[(r0 + i) * k + kk..(r0 + i) * k + kmax];
                let orow = &mut out_tile[i * n + jj..i * n + jmax];
                for (dp, &av) in arow.iter().enumerate() {
                    let p = kk + dp;
                    axpy(orow, av, &b[p * n + jj..p * n + jmax]);
                }
            }
        }
    }
}

/// (m,k) @ (k,n) -> (m,n) into `out`, overwriting it.
pub fn matmul_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A is not {m}x{k}");
    assert_eq!(b.len(), k * n, "B is not {k}x{n}");
    assert_eq!(out.len(), m * n, "out is not {m}x{n}");
    if out.is_empty() {
        return;
    }
    let tile = row_tile(m);
    let work = m.saturating_mul(k).saturating_mul(n);
    pool::for_chunks(work, out, tile * n, |ci, out_tile| {
        out_tile.fill(0.0);
        kernel_nn(a, b, out_tile, ci * tile, k, n);
    });
}

/// (m,k) @ (k,n) -> (m,n), allocating.
pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * n];
    matmul_into(a, b, &mut out, m, k, n);
    out
}

/// aᵀ @ b for a (m,k), b (m,n) -> (k,n) into `out`, overwriting it.
/// Parallel over output (k) row tiles; each out[p][j] accumulates over
/// ascending i — the seed's chain (its i loop was outermost).
pub fn matmul_tn_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    assert_eq!(a.len(), m * k, "A is not {m}x{k}");
    assert_eq!(b.len(), m * n, "B is not {m}x{n}");
    assert_eq!(out.len(), k * n, "out is not {k}x{n}");
    if out.is_empty() {
        return;
    }
    let tile = row_tile(k);
    let work = m.saturating_mul(k).saturating_mul(n);
    pool::for_chunks(work, out, tile * n, |ci, out_tile| {
        out_tile.fill(0.0);
        let p0 = ci * tile;
        let rows = if n == 0 { 0 } else { out_tile.len() / n };
        for jj in (0..n).step_by(NC) {
            let jmax = (jj + NC).min(n);
            for ii in (0..m).step_by(KC) {
                let imax = (ii + KC).min(m);
                for p in 0..rows {
                    let orow = &mut out_tile[p * n + jj..p * n + jmax];
                    for i in ii..imax {
                        let av = a[i * k + p0 + p];
                        axpy(orow, av, &b[i * n + jj..i * n + jmax]);
                    }
                }
            }
        }
    });
}

/// aᵀ @ b for a (m,k), b (m,n) -> (k,n), allocating.
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    let mut out = vec![0f32; k * n];
    matmul_tn_into(a, b, &mut out, m, k, n);
    out
}

thread_local! {
    /// Reused Bᵀ pack buffer for `matmul_nt` (per thread: packing happens
    /// on the calling thread before workers fan out).
    static NT_PACK: std::cell::RefCell<Vec<f32>> = const { std::cell::RefCell::new(Vec::new()) };
}

/// a @ bᵀ for a (m,n), b (k,n) -> (m,k) into `out`, overwriting it.
///
/// Canonical traversal: pack Bᵀ (n,k), then the `matmul` kernel. For each
/// out[i][p] this performs the contraction in ascending j with a single
/// accumulator — the same rounded-op sequence as the historical scalar
/// dot product, so bits are unchanged while the inner loop vectorizes.
pub fn matmul_nt_into(a: &[f32], b: &[f32], out: &mut [f32], m: usize, n: usize, k: usize) {
    assert_eq!(a.len(), m * n, "A is not {m}x{n}");
    assert_eq!(b.len(), k * n, "B is not {k}x{n}");
    assert_eq!(out.len(), m * k, "out is not {m}x{k}");
    if out.is_empty() {
        return;
    }
    NT_PACK.with(|cell| {
        let mut pack = cell.borrow_mut();
        pack.clear();
        pack.resize(n * k, 0.0);
        // blocked transpose of b (k,n) -> bt (n,k)
        const TB: usize = 32;
        for r0 in (0..k).step_by(TB) {
            let rmax = (r0 + TB).min(k);
            for c0 in (0..n).step_by(TB) {
                let cmax = (c0 + TB).min(n);
                for r in r0..rmax {
                    for c in c0..cmax {
                        pack[c * k + r] = b[r * n + c];
                    }
                }
            }
        }
        let tile = row_tile(m);
        let work = m.saturating_mul(k).saturating_mul(n);
        let bt: &[f32] = &pack;
        pool::for_chunks(work, out, tile * k, |ci, out_tile| {
            out_tile.fill(0.0);
            kernel_nn(a, bt, out_tile, ci * tile, n, k);
        });
    });
}

/// a @ bᵀ for a (m,n), b (k,n) -> (m,k), allocating.
pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
    let mut out = vec![0f32; m * k];
    matmul_nt_into(a, b, &mut out, m, n, k);
    out
}

/// The seed's naive kernels, verbatim — the bit-for-bit oracles the
/// blocked/parallel family is property-tested against.
#[cfg(test)]
pub(crate) mod reference {
    /// (m,k) @ (k,n) -> (m,n), naive f32 with cache-friendly ikj order.
    pub fn matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                let brow = &b[p * n..(p + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    }

    /// aᵀ @ b for a (m,k), b (m,n) -> (k,n).
    pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut out = vec![0f32; k * n];
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for (p, &av) in arow.iter().enumerate() {
                let orow = &mut out[p * n..(p + 1) * n];
                for j in 0..n {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    }

    /// a @ bᵀ for a (m,n), b (k,n) -> (m,k) — the seed's i,p,j scalar-dot
    /// traversal (ascending-j chain, same as the packed kernel's).
    pub fn matmul_nt(a: &[f32], b: &[f32], m: usize, n: usize, k: usize) -> Vec<f32> {
        let mut out = vec![0f32; m * k];
        for i in 0..m {
            let arow = &a[i * n..(i + 1) * n];
            for p in 0..k {
                let brow = &b[p * n..(p + 1) * n];
                let mut s = 0f32;
                for j in 0..n {
                    s += arow[j] * brow[j];
                }
                out[i * k + p] = s;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::pool::with_threads;
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() as f32).collect()
    }

    fn assert_bits_eq(got: &[f32], want: &[f32], what: &str) {
        assert_eq!(got.len(), want.len(), "{what}: length");
        for (i, (g, w)) in got.iter().zip(want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "{what}: bit mismatch at {i}: {g} vs {w}");
        }
    }

    fn check_all(m: usize, k: usize, n: usize, seed: u64, threads: usize) {
        let a = randn(m * k, seed);
        let b = randn(k * n, seed ^ 0xb0b);
        let at = randn(m * k, seed ^ 0x7e); // (m,k) for tn
        let bt = randn(m * n, seed ^ 0x5a); // (m,n) for tn
        let an = randn(m * n, seed ^ 0x11); // (m,n) for nt
        let bn = randn(k * n, seed ^ 0x22); // (k,n) for nt
        with_threads(threads, || {
            assert_bits_eq(
                &matmul(&a, &b, m, k, n),
                &reference::matmul(&a, &b, m, k, n),
                &format!("matmul {m}x{k}x{n} t{threads}"),
            );
            assert_bits_eq(
                &matmul_tn(&at, &bt, m, k, n),
                &reference::matmul_tn(&at, &bt, m, k, n),
                &format!("matmul_tn {m}x{k}x{n} t{threads}"),
            );
            assert_bits_eq(
                &matmul_nt(&an, &bn, m, n, k),
                &reference::matmul_nt(&an, &bn, m, n, k),
                &format!("matmul_nt {m}x{n}x{k} t{threads}"),
            );
        });
    }

    #[test]
    fn blocked_matches_oracle_on_shape_cross_product() {
        // rectangular + ragged shapes: every (m,k,n) in the cross product,
        // at 1 thread and at 4 (4 forces the parallel partition whenever
        // the work threshold is met).
        let dims = [1usize, 2, 3, 16, 17, 64];
        for (si, &m) in dims.iter().enumerate() {
            for (sj, &k) in dims.iter().enumerate() {
                for (sk, &n) in dims.iter().enumerate() {
                    let seed = 1000 + (si * 36 + sj * 6 + sk) as u64;
                    check_all(m, k, n, seed, 1);
                    check_all(m, k, n, seed, 4);
                }
            }
        }
    }

    #[test]
    fn blocked_matches_oracle_randomized() {
        // 50 randomized shapes spanning the blocking boundaries (tiles,
        // KC/NC panels, unroll remainders), random thread counts.
        let mut r = Rng::new(0x6e44);
        for case in 0..50u64 {
            let m = 1 + r.below(97);
            let k = 1 + r.below(160);
            let n = 1 + r.below(300);
            let t = 1 + r.below(6);
            check_all(m, k, n, 0xA000 + case, t);
        }
    }

    #[test]
    fn panels_larger_than_blocking_constants_split_correctly() {
        // exceed KC and NC so multiple panels + ragged last panels run
        check_all(70, KC + 37, NC + 61, 0xBEEF, 3);
    }

    #[test]
    fn into_variants_overwrite_stale_contents() {
        let m = 9;
        let k = 33;
        let n = 21;
        let a = randn(m * k, 5);
        let b = randn(k * n, 6);
        let mut out = vec![f32::NAN; m * n];
        matmul_into(&a, &b, &mut out, m, k, n);
        assert_bits_eq(&out, &reference::matmul(&a, &b, m, k, n), "overwrite");
    }

    #[test]
    fn zero_sized_dims_are_fine() {
        assert!(matmul(&[], &[], 0, 0, 0).is_empty());
        assert_eq!(matmul(&[], &randn(6, 1), 0, 3, 2), Vec::<f32>::new());
        // k = 0: all-zero output of the right shape
        assert_eq!(matmul(&[], &[], 2, 0, 3), vec![0f32; 6]);
        assert_eq!(matmul_tn(&[], &[], 0, 2, 3), vec![0f32; 6]);
        assert_eq!(matmul_nt(&[], &[], 2, 0, 3), vec![0f32; 6]);
    }
}
