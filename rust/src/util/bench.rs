//! Minimal benchmarking harness (criterion is not in the offline crates
//! cache). Measures wall-clock over repeated runs, reports mean / p50 /
//! p95 / throughput, writes a CSV under runs/bench/, and emits a
//! machine-readable `BENCH_<tag>.json` at the repo root so the perf
//! trajectory is diffable across PRs (`scripts/bench_diff.py`).
//!
//! Env knobs:
//!   QADX_BENCH_SMOKE=1  — clamp every benchmark to 1 warmup / 1 iter and
//!                         skip the repo-root JSON rewrite (CI bit-rot
//!                         guard; numbers from a smoke run are noise).

use std::time::Instant;

use super::json::Json;
use super::{mean, percentile};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// Work units one benchmarked call performs (e.g. tokens decoded) —
    /// 1.0 unless set via `bench_units`; drives `units_per_sec`.
    pub units_per_iter: f64,
}

impl BenchResult {
    pub fn ns_per_op(&self) -> f64 {
        self.mean_ms * 1e6
    }

    /// Throughput in operations per second (1 op = one benchmarked call).
    pub fn ops_per_sec(&self) -> f64 {
        if self.mean_ms > 0.0 {
            1e3 / self.mean_ms
        } else {
            0.0
        }
    }

    /// Unit throughput (e.g. tokens/sec for decode benchmarks).
    pub fn units_per_sec(&self) -> f64 {
        self.ops_per_sec() * self.units_per_iter
    }

    pub fn print(&self) {
        println!(
            "{:<42} {:>5} iters  mean {:>9.3} ms  p50 {:>9.3} ms  p95 {:>9.3} ms",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p95_ms
        );
    }

    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.4},{:.4},{:.4}",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p95_ms
        )
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::Str(self.name.clone())),
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ms", Json::Num(self.mean_ms)),
            ("p50_ms", Json::Num(self.p50_ms)),
            ("p95_ms", Json::Num(self.p95_ms)),
            ("ns_per_op", Json::Num(self.ns_per_op())),
            ("ops_per_sec", Json::Num(self.ops_per_sec())),
        ];
        if self.units_per_iter != 1.0 {
            pairs.push(("units_per_iter", Json::Num(self.units_per_iter)));
            pairs.push(("units_per_sec", Json::Num(self.units_per_sec())));
        }
        Json::obj(pairs)
    }
}

/// Smoke mode: 1 warmup / 1 iter per benchmark (CI bit-rot guard).
/// Enabled by QADX_BENCH_SMOKE set to anything but ""/"0"/"false".
pub fn smoke_mode() -> bool {
    super::env_flag("QADX_BENCH_SMOKE")
}

/// Time `f` for `iters` iterations after `warmup` warm-up runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, f: F) -> BenchResult {
    bench_units(name, warmup, iters, 1.0, f)
}

/// Like [`bench`] but records that each call performs `units_per_iter`
/// work units (e.g. tokens decoded), so the JSON carries a unit
/// throughput (`units_per_sec`) next to the per-call numbers.
pub fn bench_units<F: FnMut()>(
    name: &str,
    warmup: usize,
    iters: usize,
    units_per_iter: f64,
    mut f: F,
) -> BenchResult {
    let (warmup, iters) = if smoke_mode() {
        (warmup.min(1), 1)
    } else {
        (warmup, iters.max(1))
    };
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1000.0);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean(&samples),
        p50_ms: percentile(&samples, 50.0),
        p95_ms: percentile(&samples, 95.0),
        units_per_iter,
    };
    r.print();
    r
}

/// Walk up from the current directory to the repo root (marked by
/// ROADMAP.md); falls back to the current directory.
fn repo_root() -> std::path::PathBuf {
    let cwd = std::env::current_dir().unwrap_or_else(|_| ".".into());
    let mut dir = cwd.clone();
    for _ in 0..4 {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        match dir.parent() {
            Some(p) => dir = p.to_path_buf(),
            None => break,
        }
    }
    cwd
}

/// Collects results; writes the CSV and the repo-root JSON at the end.
pub struct BenchSuite {
    pub results: Vec<BenchResult>,
    tag: String,
    csv_path: std::path::PathBuf,
}

impl BenchSuite {
    pub fn new(tag: &str) -> BenchSuite {
        let dir = std::path::PathBuf::from("runs/bench");
        std::fs::create_dir_all(&dir).ok();
        BenchSuite {
            results: Vec::new(),
            tag: tag.to_string(),
            csv_path: dir.join(format!("{tag}.csv")),
        }
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, f: F) {
        self.results.push(bench(name, warmup, iters, f));
    }

    /// Run a benchmark whose call performs `units` work units (tokens,
    /// rows, ...) — lands `units_per_sec` in the JSON.
    pub fn run_units<F: FnMut()>(
        &mut self,
        name: &str,
        warmup: usize,
        iters: usize,
        units: f64,
        f: F,
    ) {
        self.results.push(bench_units(name, warmup, iters, units, f));
    }

    pub fn finish(&self) {
        let mut csv = String::from("name,iters,mean_ms,p50_ms,p95_ms\n");
        for r in &self.results {
            csv.push_str(&r.csv_row());
            csv.push('\n');
        }
        if let Err(e) = std::fs::write(&self.csv_path, csv) {
            eprintln!("bench csv write failed: {e}");
        } else {
            println!("wrote {}", self.csv_path.display());
        }
        if smoke_mode() {
            println!("smoke mode: skipping BENCH_{}.json rewrite", self.tag);
            return;
        }
        let json_path = repo_root().join(format!("BENCH_{}.json", self.tag));
        // Carry the committed "baseline" section (and its provenance
        // "note") forward across regenerations so before/after stays
        // diffable (scripts/bench_diff.py).
        let prev = std::fs::read_to_string(&json_path).ok().and_then(|t| Json::parse(&t).ok());
        let baseline = prev.as_ref().and_then(|j| j.get("baseline").cloned());
        let note = prev.as_ref().and_then(|j| j.get("note").cloned());
        let mut pairs = vec![
            ("schema", Json::Str("qadx-bench-v1".into())),
            ("tag", Json::Str(self.tag.clone())),
            (
                "results",
                Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
            ),
        ];
        if let Some(n) = note {
            pairs.push(("note", n));
        }
        if let Some(b) = baseline {
            pairs.push(("baseline", b));
        }
        let doc = Json::obj(pairs);
        if let Err(e) = std::fs::write(&json_path, doc.pretty()) {
            eprintln!("bench json write failed: {e}");
        } else {
            println!("wrote {}", json_path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(r.iters >= 1);
        assert!(r.mean_ms >= 0.0 && r.p95_ms >= r.p50_ms * 0.5);
    }

    #[test]
    fn result_json_has_throughput_fields() {
        let r = BenchResult {
            name: "x".into(),
            iters: 3,
            mean_ms: 2.0,
            p50_ms: 2.0,
            p95_ms: 2.5,
            units_per_iter: 1.0,
        };
        let j = r.to_json();
        assert_eq!(j.get("ns_per_op").and_then(|v| v.as_f64()), Some(2e6));
        assert_eq!(j.get("ops_per_sec").and_then(|v| v.as_f64()), Some(500.0));
        assert_eq!(j.get("name").and_then(|v| v.as_str()), Some("x"));
        assert!(j.get("units_per_sec").is_none(), "unit fields only when set");
    }

    #[test]
    fn unit_throughput_scales_ops_per_sec() {
        let r = BenchResult {
            name: "decode".into(),
            iters: 3,
            mean_ms: 10.0,
            p50_ms: 10.0,
            p95_ms: 11.0,
            units_per_iter: 48.0,
        };
        assert_eq!(r.ops_per_sec(), 100.0);
        assert_eq!(r.units_per_sec(), 4800.0);
        let j = r.to_json();
        assert_eq!(j.get("units_per_sec").and_then(|v| v.as_f64()), Some(4800.0));
    }
}
