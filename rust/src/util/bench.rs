//! Minimal benchmarking harness (criterion is not in the offline crates
//! cache). Measures wall-clock over repeated runs, reports mean / p50 /
//! p95 / throughput, and writes a CSV so `cargo bench` output is diffable
//! across the §Perf iterations in EXPERIMENTS.md.

use std::time::Instant;

use super::{mean, percentile};

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<42} {:>5} iters  mean {:>9.3} ms  p50 {:>9.3} ms  p95 {:>9.3} ms",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p95_ms
        );
    }

    pub fn csv_row(&self) -> String {
        format!(
            "{},{},{:.4},{:.4},{:.4}",
            self.name, self.iters, self.mean_ms, self.p50_ms, self.p95_ms
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` warm-up runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1000.0);
    }
    let r = BenchResult {
        name: name.to_string(),
        iters,
        mean_ms: mean(&samples),
        p50_ms: percentile(&samples, 50.0),
        p95_ms: percentile(&samples, 95.0),
    };
    r.print();
    r
}

/// Collects results and writes the CSV at the end.
pub struct BenchSuite {
    pub results: Vec<BenchResult>,
    csv_path: std::path::PathBuf,
}

impl BenchSuite {
    pub fn new(tag: &str) -> BenchSuite {
        let dir = std::path::PathBuf::from("runs/bench");
        std::fs::create_dir_all(&dir).ok();
        BenchSuite { results: Vec::new(), csv_path: dir.join(format!("{tag}.csv")) }
    }

    pub fn run<F: FnMut()>(&mut self, name: &str, warmup: usize, iters: usize, f: F) {
        self.results.push(bench(name, warmup, iters, f));
    }

    pub fn finish(&self) {
        let mut csv = String::from("name,iters,mean_ms,p50_ms,p95_ms\n");
        for r in &self.results {
            csv.push_str(&r.csv_row());
            csv.push('\n');
        }
        if let Err(e) = std::fs::write(&self.csv_path, csv) {
            eprintln!("bench csv write failed: {e}");
        } else {
            println!("wrote {}", self.csv_path.display());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let r = bench("noop-ish", 1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert_eq!(r.iters, 5);
        assert!(r.mean_ms >= 0.0 && r.p95_ms >= r.p50_ms * 0.5);
    }
}
