//! Budgeted retry with decorrelated-jitter backoff.
//!
//! The fleet router requeues work from dead or failing workers; a retry
//! policy bounds how many times one request may bounce and spaces the
//! attempts out. The jitter follows the "decorrelated jitter" rule
//! (`delay = min(cap, uniform(base, prev * 3))`), which spreads retry
//! storms without the synchronized waves plain exponential backoff
//! produces. The RNG is injected, never ambient: given the same seed the
//! whole delay schedule replays exactly, which is what lets chaos tests
//! assert on retry behavior byte-for-byte.
//!
//! Nothing here sleeps or reads a clock — the policy only *computes*
//! delays; the caller decides whether to wait them out (a live server)
//! or merely record them (the deterministic test harness).

use crate::util::rng::Rng;

/// Retry budget + backoff shape. All decisions are pure functions of the
/// policy, the attempt counter, and the injected RNG.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Floor of every backoff delay (and the first delay's scale).
    pub base_ms: f64,
    /// Ceiling on any single delay.
    pub cap_ms: f64,
    /// Maximum retry attempts per request (0 = never retry).
    pub max_attempts: u32,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { base_ms: 5.0, cap_ms: 500.0, max_attempts: 3 }
    }
}

/// Per-request retry progress: how many attempts have been spent and the
/// previous delay (the decorrelation state).
#[derive(Clone, Copy, Debug, Default)]
pub struct RetryState {
    pub attempts: u32,
    pub last_delay_ms: f64,
}

impl RetryPolicy {
    /// Charge one attempt: `Some(delay_ms)` to retry after that backoff,
    /// `None` when the budget is exhausted. Decorrelated jitter:
    /// `delay = min(cap, uniform(base, last * 3))`, seeded from `rng`.
    pub fn next_delay(&self, state: &mut RetryState, rng: &mut Rng) -> Option<f64> {
        if state.attempts >= self.max_attempts {
            return None;
        }
        state.attempts += 1;
        let base = self.base_ms.max(0.0);
        let prev = state.last_delay_ms.max(base);
        let hi = (prev * 3.0).max(base);
        let delay = (base + rng.f64() * (hi - base)).min(self.cap_ms.max(base));
        state.last_delay_ms = delay;
        Some(delay)
    }

    /// Attempts left for `state` under this policy.
    pub fn remaining(&self, state: &RetryState) -> u32 {
        self.max_attempts.saturating_sub(state.attempts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_replays_the_exact_delay_schedule() {
        let policy = RetryPolicy { base_ms: 2.0, cap_ms: 100.0, max_attempts: 8 };
        let run = |seed: u64| -> Vec<f64> {
            let mut rng = Rng::new(seed);
            let mut st = RetryState::default();
            let mut out = Vec::new();
            while let Some(d) = policy.next_delay(&mut st, &mut rng) {
                out.push(d);
            }
            out
        };
        let a = run(42);
        let b = run(42);
        assert_eq!(a.len(), 8);
        assert_eq!(a, b, "same seed must replay the same schedule");
        let c = run(43);
        assert_ne!(a, c, "different seed should jitter differently");
    }

    #[test]
    fn delays_respect_base_floor_and_cap_ceiling() {
        let policy = RetryPolicy { base_ms: 4.0, cap_ms: 20.0, max_attempts: 64 };
        let mut rng = Rng::new(7);
        let mut st = RetryState::default();
        while let Some(d) = policy.next_delay(&mut st, &mut rng) {
            assert!(d >= policy.base_ms - 1e-12, "delay {d} below base");
            assert!(d <= policy.cap_ms + 1e-12, "delay {d} above cap");
        }
        // with a tight cap the schedule saturates at the cap rather than
        // growing without bound
        assert!(st.last_delay_ms <= policy.cap_ms + 1e-12);
    }

    #[test]
    fn attempt_cap_is_exact_and_zero_means_never() {
        let policy = RetryPolicy { base_ms: 1.0, cap_ms: 10.0, max_attempts: 3 };
        let mut rng = Rng::new(1);
        let mut st = RetryState::default();
        assert_eq!(policy.remaining(&st), 3);
        for _ in 0..3 {
            assert!(policy.next_delay(&mut st, &mut rng).is_some());
        }
        assert_eq!(policy.remaining(&st), 0);
        assert!(policy.next_delay(&mut st, &mut rng).is_none(), "budget exhausted");
        assert!(policy.next_delay(&mut st, &mut rng).is_none(), "stays exhausted");

        let never = RetryPolicy { max_attempts: 0, ..policy };
        let mut st = RetryState::default();
        assert!(never.next_delay(&mut st, &mut rng).is_none());
        assert_eq!(st.attempts, 0, "a refused retry must not charge the budget");
    }

    #[test]
    fn delays_grow_from_base_not_from_zero() {
        // first delay is uniform(base, base*3) — never below base even
        // though last_delay starts at 0
        let policy = RetryPolicy { base_ms: 10.0, cap_ms: 1000.0, max_attempts: 1 };
        for seed in 0..32 {
            let mut rng = Rng::new(seed);
            let mut st = RetryState::default();
            let d = policy.next_delay(&mut st, &mut rng).unwrap();
            assert!((10.0..=30.0).contains(&d), "first delay {d} outside [base, 3*base]");
        }
    }
}
