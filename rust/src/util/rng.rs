//! Deterministic PRNG (SplitMix64 core + xoshiro256** stream) used across
//! data generation, sampling, and experiment seeding. The `rand` crate is
//! not in the offline crates cache, and determinism across runs matters for
//! the experiment harness anyway, so the generators live here.

/// xoshiro256** seeded via SplitMix64 — fast, high-quality, reproducible.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (for per-worker / per-experiment seeds).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(hi > lo);
        lo + self.below((hi - lo) as usize) as i64
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }

    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        assert!(total > 0.0, "all weights zero");
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn weighted_prefers_heavy() {
        let mut r = Rng::new(11);
        let mut counts = [0usize; 3];
        for _ in 0..3000 {
            counts[r.weighted(&[1.0, 0.0, 9.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > counts[0] * 5);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
