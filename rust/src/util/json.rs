//! Minimal JSON parser/serializer.
//!
//! This repo builds fully offline and `serde_json` is not in the baked
//! crates cache, so the manifest/config/report plumbing uses this small
//! self-contained implementation instead. It supports the full JSON value
//! model (objects, arrays, strings with escapes, numbers, bools, null) —
//! everything `python -m compile.aot` emits — plus pretty serialization for
//! the run reports.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // --- typed accessors (None on type mismatch) --------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Required-field helpers that produce readable errors.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn req_usize(&self, key: &str) -> anyhow::Result<usize> {
        self.req(key)?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("json key {key:?} is not a number"))
    }

    pub fn req_str(&self, key: &str) -> anyhow::Result<&str> {
        self.req(key)?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("json key {key:?} is not a string"))
    }

    pub fn req_bool(&self, key: &str) -> anyhow::Result<bool> {
        self.req(key)?
            .as_bool()
            .ok_or_else(|| anyhow::anyhow!("json key {key:?} is not a bool"))
    }

    pub fn req_arr(&self, key: &str) -> anyhow::Result<&[Json]> {
        self.req(key)?
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("json key {key:?} is not an array"))
    }

    // --- construction helpers ---------------------------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr_f64(v: &[f64]) -> Json {
        Json::Arr(v.iter().map(|x| Json::Num(*x)).collect())
    }

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    // JSON has no NaN/Infinity literal; `{n}` would emit
                    // one and corrupt the whole line for strict parsers
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // Surrogate pairs are not needed for our data;
                            // map unpaired surrogates to the replacement char.
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // UTF-8 passthrough: copy the full codepoint.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-12.5e2").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
    }

    #[test]
    fn round_trips(){
        let src = r#"{"m":{"x":[1,2.5,-3],"s":"hi\t","b":false}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        let v3 = Json::parse(&v.pretty()).unwrap();
        assert_eq!(v, v3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""café ☕""#).unwrap();
        assert_eq!(v.as_str(), Some("café ☕"));
    }

    #[test]
    fn non_finite_numbers_serialize_as_null() {
        // JSON has no NaN/Infinity literal — a bare `NaN` token would
        // make the line unparseable for every strict consumer
        assert_eq!(Json::Num(f64::NAN).to_string(), "null");
        assert_eq!(Json::Num(f64::INFINITY).to_string(), "null");
        assert_eq!(Json::Num(f64::NEG_INFINITY).to_string(), "null");
        let obj = Json::obj(vec![("p50", Json::Num(f64::NAN)), ("n", Json::Num(2.0))]);
        let reparsed = Json::parse(&obj.to_string()).expect("line must stay valid JSON");
        assert_eq!(reparsed.get("p50"), Some(&Json::Null));
        assert_eq!(reparsed.get("n"), Some(&Json::Num(2.0)));
    }
}
