//! Small shared utilities: offline JSON, deterministic RNG, stats, timing,
//! and CSV output for the experiment harness.

pub mod args;
pub mod bench;
pub mod json;
pub mod rng;

use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Mean of a slice (0.0 for empty — callers guard when it matters).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((v.len() - 1) as f64 * p / 100.0).round() as usize;
    v[idx]
}

/// Wall-clock timer with human-friendly reporting.
pub struct Timer {
    start: Instant,
    pub label: String,
}

impl Timer {
    pub fn start(label: &str) -> Self {
        Timer { start: Instant::now(), label: label.to_string() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn report(&self) -> String {
        format!("{}: {:.2}s", self.label, self.secs())
    }
}

/// Append-only CSV writer (creates parent dirs; writes header once).
pub struct CsvWriter {
    file: std::fs::File,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file })
    }

    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        writeln!(self.file, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn row_f64(&mut self, tag: &str, fields: &[f64]) -> anyhow::Result<()> {
        let mut v = vec![tag.to_string()];
        v.extend(fields.iter().map(|x| format!("{x}")));
        self.row(&v)
    }
}

/// Format a fixed-width table (used by the experiment report printer).
pub fn format_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        let padded: Vec<String> = cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:w$}", c, w = widths.get(i).copied().unwrap_or(4)))
            .collect();
        out.push_str("| ");
        out.push_str(&padded.join(" | "));
        out.push_str(" |\n");
    };
    line(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    out.push_str("|");
    for w in &widths {
        out.push_str(&format!("{:-<w$}--|", "", w = w));
    }
    out.push('\n');
    for row in rows {
        line(&mut out, row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(percentile(&[5.0, 1.0, 3.0], 50.0), 3.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn table_formatting_aligns() {
        let t = format_table(
            &["name", "x"],
            &[vec!["a".into(), "1.00".into()], vec!["longer".into(), "2".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert_eq!(lines[2].len(), lines[3].len());
    }
}
