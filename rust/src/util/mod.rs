//! Small shared utilities: offline JSON, deterministic RNG, stats, timing,
//! and CSV output for the experiment harness.

pub mod args;
pub mod bench;
pub mod gemm;
pub mod json;
pub mod pool;
pub mod retry;
pub mod rng;
pub mod stream;

use std::io::Write;
use std::path::Path;
use std::time::Instant;

/// Truthy env flag: set to anything except "" / "0" / "false".
pub fn env_flag(name: &str) -> bool {
    match std::env::var(name) {
        Ok(v) => !matches!(v.as_str(), "" | "0" | "false"),
        Err(_) => false,
    }
}

/// Mean of a slice (0.0 for empty — callers guard when it matters).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    // total order: a stray NaN sorts to the end instead of panicking the
    // comparator mid-sort
    v.sort_by(|a, b| a.total_cmp(b));
    let idx = ((v.len() - 1) as f64 * p / 100.0).round() as usize;
    v[idx]
}

/// Bounded sliding-window statistics: percentiles come from the last
/// `cap` samples, while exact lifetime totals (count / sum) live in
/// scalars — long-running servers record every request without growing
/// memory per request.
#[derive(Clone, Debug)]
pub struct StatsWindow {
    cap: usize,
    buf: std::collections::VecDeque<f64>,
    count: u64,
    sum: f64,
}

/// Default window: enough samples for stable p99 at negligible memory.
pub const STATS_WINDOW_DEFAULT: usize = 4096;

impl Default for StatsWindow {
    fn default() -> StatsWindow {
        StatsWindow::with_capacity(STATS_WINDOW_DEFAULT)
    }
}

impl StatsWindow {
    pub fn with_capacity(cap: usize) -> StatsWindow {
        assert!(cap >= 1, "window capacity must be >= 1");
        StatsWindow {
            cap,
            buf: std::collections::VecDeque::with_capacity(cap.min(1024)),
            count: 0,
            sum: 0.0,
        }
    }

    /// Record one sample. Non-finite values are dropped: one NaN would
    /// otherwise poison the lifetime sum/mean forever and leak a bare
    /// `NaN` token into every summary and telemetry line derived from it.
    pub fn push(&mut self, v: f64) {
        if !v.is_finite() {
            return;
        }
        if self.buf.len() == self.cap {
            self.buf.pop_front();
        }
        self.buf.push_back(v);
        self.count += 1;
        self.sum += v;
    }

    /// Samples currently retained (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Exact lifetime sample count (not windowed).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Exact lifetime sum (not windowed).
    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact lifetime mean (not windowed).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn last(&self) -> Option<f64> {
        self.buf.back().copied()
    }

    pub fn iter(&self) -> impl Iterator<Item = f64> + '_ {
        self.buf.iter().copied()
    }

    /// Percentile over the retained window.
    pub fn percentile(&self, p: f64) -> f64 {
        let v: Vec<f64> = self.buf.iter().copied().collect();
        percentile(&v, p)
    }
}

/// Wall-clock timer with human-friendly reporting.
pub struct Timer {
    start: Instant,
    pub label: String,
}

impl Timer {
    pub fn start(label: &str) -> Self {
        Timer { start: Instant::now(), label: label.to_string() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn report(&self) -> String {
        format!("{}: {:.2}s", self.label, self.secs())
    }
}

/// Append-only CSV writer (creates parent dirs; writes header once).
pub struct CsvWriter {
    file: std::fs::File,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> anyhow::Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file })
    }

    pub fn row(&mut self, fields: &[String]) -> anyhow::Result<()> {
        writeln!(self.file, "{}", fields.join(","))?;
        Ok(())
    }

    pub fn row_f64(&mut self, tag: &str, fields: &[f64]) -> anyhow::Result<()> {
        let mut v = vec![tag.to_string()];
        v.extend(fields.iter().map(|x| format!("{x}")));
        self.row(&v)
    }
}

/// Format a fixed-width table (used by the experiment report printer).
pub fn format_table(headers: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = headers.len();
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate().take(ncol) {
            widths[i] = widths[i].max(cell.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: &[String]| {
        let padded: Vec<String> = cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:w$}", c, w = widths.get(i).copied().unwrap_or(4)))
            .collect();
        out.push_str("| ");
        out.push_str(&padded.join(" | "));
        out.push_str(" |\n");
    };
    line(&mut out, &headers.iter().map(|s| s.to_string()).collect::<Vec<_>>());
    out.push_str("|");
    for w in &widths {
        out.push_str(&format!("{:-<w$}--|", "", w = w));
    }
    out.push('\n');
    for row in rows {
        line(&mut out, row);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basics() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((stddev(&[1.0, 2.0, 3.0]) - 1.0).abs() < 1e-12);
        assert_eq!(percentile(&[5.0, 1.0, 3.0], 50.0), 3.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn stats_window_bounds_memory_keeps_exact_totals() {
        let mut w = StatsWindow::with_capacity(16);
        for i in 0..10_000 {
            w.push(i as f64);
        }
        assert_eq!(w.len(), 16, "window must stay bounded");
        assert_eq!(w.count(), 10_000, "lifetime count is exact");
        assert_eq!(w.sum(), (0..10_000).sum::<u64>() as f64);
        assert!((w.mean() - 4999.5).abs() < 1e-9);
        assert_eq!(w.last(), Some(9999.0));
        // window holds the most recent samples, in order
        let kept: Vec<f64> = w.iter().collect();
        assert_eq!(kept, (9984..10_000).map(|i| i as f64).collect::<Vec<_>>());
        assert_eq!(w.percentile(100.0), 9999.0);
    }

    #[test]
    fn stats_window_empty_is_safe() {
        let w = StatsWindow::default();
        assert!(w.is_empty());
        assert_eq!(w.mean(), 0.0);
        assert_eq!(w.percentile(50.0), 0.0);
        assert_eq!(w.last(), None);
    }

    #[test]
    fn stats_window_drops_non_finite_samples() {
        let mut w = StatsWindow::with_capacity(8);
        w.push(1.0);
        w.push(f64::NAN);
        w.push(f64::INFINITY);
        w.push(f64::NEG_INFINITY);
        w.push(3.0);
        assert_eq!(w.len(), 2, "non-finite samples must not be retained");
        assert_eq!(w.count(), 2);
        assert_eq!(w.mean(), 2.0, "sum/mean stay finite");
        assert!(w.percentile(50.0).is_finite());
    }

    #[test]
    fn percentile_tolerates_nan_without_panicking() {
        // a NaN that reaches the sort must not panic the comparator and
        // must not be returned for mid percentiles (it sorts last)
        let xs = [2.0, f64::NAN, 1.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 2.0);
    }

    #[test]
    fn table_formatting_aligns() {
        let t = format_table(
            &["name", "x"],
            &[vec!["a".into(), "1.00".into()], vec!["longer".into(), "2".into()]],
        );
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4);
        assert!(lines[0].contains("name"));
        assert_eq!(lines[2].len(), lines[3].len());
    }
}
