//! Scoped worker pool for the reference-backend compute core.
//!
//! Parallelism here is *deterministic by construction*: each chunk is
//! processed by exactly one worker running the same per-chunk code the
//! serial path runs, and — the invariant kernels must uphold — no f32
//! accumulation chain ever crosses a chunk boundary, with each chain
//! executing the serial op sequence. Chunk *sizes* may legitimately vary
//! with the worker count (the GEMM row tiles do); what makes results
//! bit-identical at 1 and at N threads is chain containment, not fixed
//! boundaries. Corollary for kernel authors: an order-bearing reduction
//! that combines per-chunk partials is only deterministic if its chunk
//! size is independent of the thread count (see `max_abs`, whose max
//! combine is order-insensitive and therefore safe either way). This is
//! what lets `QADX_THREADS` be a pure throughput knob (asserted by
//! rust/tests/threading.rs over full train steps and decode).
//!
//! Threads are `std::thread::scope` spawns per parallel region (no new
//! dependencies, no unsafe, no 'static bounds on borrowed inputs). Spawn
//! cost is a few tens of microseconds, so regions below [`PAR_MIN_WORK`]
//! scalar ops run inline on the caller thread — the tiny shapes of the
//! hermetic test models never pay for threads they can't use.
//!
//! Thread-count resolution, strongest first:
//! 1. [`with_threads`] (thread-local, scoped — used by tests to compare
//!    1-thread vs N-thread runs without racing the parallel test harness)
//! 2. [`set_threads`] (process-global — `--threads` CLI flag /
//!    `Session::builder().threads(..)`)
//! 3. `QADX_THREADS` env var (read once per process)
//! 4. `std::thread::available_parallelism()`

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Minimum scalar-op estimate for a region to go parallel; smaller
/// regions run inline (spawn overhead would dominate).
pub const PAR_MIN_WORK: usize = 64 * 1024;

static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    static TLS_THREADS: Cell<usize> = const { Cell::new(0) };
}

/// `QADX_THREADS` (read once) or the machine's available parallelism.
fn default_threads() -> usize {
    static DEFAULT: OnceLock<usize> = OnceLock::new();
    *DEFAULT.get_or_init(|| {
        if let Ok(v) = std::env::var("QADX_THREADS") {
            match v.trim().parse::<usize>() {
                Ok(n) if n >= 1 => return n,
                _ => eprintln!(
                    "QADX_THREADS={v:?} is not a positive integer; using available parallelism"
                ),
            }
        }
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
    })
}

/// The worker count parallel regions entered from this thread will use.
pub fn threads() -> usize {
    let tls = TLS_THREADS.with(|t| t.get());
    if tls >= 1 {
        return tls;
    }
    let global = GLOBAL_THREADS.load(Ordering::Relaxed);
    if global >= 1 {
        return global;
    }
    default_threads()
}

/// Set the process-global worker count (CLI `--threads`,
/// `Session::builder().threads(..)`). `0` clears the override, falling
/// back to `QADX_THREADS` / available parallelism.
pub fn set_threads(n: usize) {
    GLOBAL_THREADS.store(n, Ordering::Relaxed);
}

/// Run `f` with the worker count pinned to `n` on this thread (scoped,
/// restores the previous value on exit — panic-safe). Worker counts are
/// resolved on the thread that *enters* a parallel region, so this pins
/// every region `f` runs, including on spawned workers' behalf.
pub fn with_threads<R>(n: usize, f: impl FnOnce() -> R) -> R {
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            TLS_THREADS.with(|t| t.set(self.0));
        }
    }
    let prev = TLS_THREADS.with(|t| t.replace(n));
    let _restore = Restore(prev);
    f()
}

/// Contiguous chunk-index ranges: `workers` near-equal spans of
/// `0..n_chunks` (earlier workers take the remainder).
fn plan(n_chunks: usize, workers: usize) -> impl Iterator<Item = (usize, usize)> {
    let base = n_chunks / workers;
    let rem = n_chunks % workers;
    let mut start = 0usize;
    (0..workers).map(move |w| {
        let len = base + usize::from(w < rem);
        let span = (start, start + len);
        start += len;
        span
    })
}

fn should_parallelize(work: usize, n_chunks: usize) -> usize {
    if work < PAR_MIN_WORK || n_chunks < 2 {
        return 1;
    }
    threads().min(n_chunks)
}

/// Apply `f(chunk_index, chunk)` to every `chunk`-sized piece of `data`
/// (last piece may be ragged), in parallel when `work` — a caller
/// estimate of total scalar ops for the whole region — justifies it.
///
/// For a given `(data.len(), chunk)` the serial path runs the identical
/// per-chunk calls, so results never depend on the worker count as long
/// as `f` keeps every accumulation chain inside its own chunk. Callers
/// whose `chunk` itself derives from `threads()` must not do
/// order-bearing cross-chunk reductions over the results.
pub fn for_chunks<T, F>(work: usize, data: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    assert!(chunk >= 1, "chunk size must be >= 1");
    let n_chunks = data.len().div_ceil(chunk);
    let workers = should_parallelize(work, n_chunks);
    if workers <= 1 {
        for (ci, c) in data.chunks_mut(chunk).enumerate() {
            f(ci, c);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut rest = data;
        for (w, (c0, c1)) in plan(n_chunks, workers).enumerate() {
            let elems = ((c1 - c0) * chunk).min(rest.len());
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(elems);
            rest = tail;
            let fr = &f;
            let run = move || {
                for (ci, c) in head.chunks_mut(chunk).enumerate() {
                    fr(c0 + ci, c);
                }
            };
            if w + 1 == workers {
                run(); // caller thread takes the last span
            } else {
                s.spawn(run);
            }
        }
    });
}

/// Two-output variant: chunk `i` pairs `a[i*ca..][..ca]` with
/// `b[i*cb..][..cb]` (both possibly ragged at the end). The chunk count
/// is driven by `a`; `b` must hold matching chunks.
pub fn for_chunks2<A, B, F>(work: usize, a: &mut [A], ca: usize, b: &mut [B], cb: usize, f: F)
where
    A: Send,
    B: Send,
    F: Fn(usize, &mut [A], &mut [B]) + Sync,
{
    assert!(ca >= 1 && cb >= 1, "chunk sizes must be >= 1");
    let n_chunks = a.len().div_ceil(ca);
    assert!(
        b.len().div_ceil(cb) == n_chunks,
        "paired slices disagree on chunk count: {} vs {}",
        n_chunks,
        b.len().div_ceil(cb)
    );
    let workers = should_parallelize(work, n_chunks);
    if workers <= 1 {
        for (ci, (pa, pb)) in a.chunks_mut(ca).zip(b.chunks_mut(cb)).enumerate() {
            f(ci, pa, pb);
        }
        return;
    }
    std::thread::scope(|s| {
        let mut rest_a = a;
        let mut rest_b = b;
        for (w, (c0, c1)) in plan(n_chunks, workers).enumerate() {
            let ea = ((c1 - c0) * ca).min(rest_a.len());
            let eb = ((c1 - c0) * cb).min(rest_b.len());
            let (ha, ta) = std::mem::take(&mut rest_a).split_at_mut(ea);
            let (hb, tb) = std::mem::take(&mut rest_b).split_at_mut(eb);
            rest_a = ta;
            rest_b = tb;
            let fr = &f;
            let run = move || {
                for (ci, (pa, pb)) in ha.chunks_mut(ca).zip(hb.chunks_mut(cb)).enumerate() {
                    fr(c0 + ci, pa, pb);
                }
            };
            if w + 1 == workers {
                run();
            } else {
                s.spawn(run);
            }
        }
    });
}

/// Max |x| over a slice, chunk-parallel. f32 max is insensitive to
/// combination order (and `f32::max` drops NaN operands the same way in
/// any order), so this is exact and thread-count-invariant.
pub fn max_abs(x: &[f32]) -> f32 {
    const CHUNK: usize = 16 * 1024;
    if x.len() <= CHUNK {
        return x.iter().fold(0f32, |m, v| m.max(v.abs()));
    }
    let mut partials = vec![0f32; x.len().div_ceil(CHUNK)];
    for_chunks(x.len(), &mut partials, 1, |ci, slot| {
        let blk = &x[ci * CHUNK..((ci + 1) * CHUNK).min(x.len())];
        slot[0] = blk.iter().fold(0f32, |m, v| m.max(v.abs()));
    });
    partials.iter().fold(0f32, |m, v| m.max(*v))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn thread_resolution_precedence() {
        assert!(threads() >= 1);
        with_threads(7, || {
            assert_eq!(threads(), 7);
            with_threads(2, || assert_eq!(threads(), 2));
            assert_eq!(threads(), 7);
        });
        assert!(threads() >= 1);
    }

    #[test]
    fn with_threads_restores_on_panic() {
        let before = TLS_THREADS.with(|t| t.get());
        let r = std::panic::catch_unwind(|| with_threads(5, || panic!("boom")));
        assert!(r.is_err());
        assert_eq!(TLS_THREADS.with(|t| t.get()), before);
    }

    #[test]
    fn plan_covers_all_chunks_contiguously() {
        for n in [0usize, 1, 2, 7, 16, 33] {
            for w in [1usize, 2, 3, 8] {
                let spans: Vec<_> = plan(n, w).collect();
                assert_eq!(spans.len(), w);
                let mut next = 0;
                for (a, b) in spans {
                    assert_eq!(a, next);
                    assert!(b >= a);
                    next = b;
                }
                assert_eq!(next, n);
            }
        }
    }

    fn fill_by_chunk(n: usize, chunk: usize, threads: usize) -> Vec<u64> {
        let mut out = vec![0u64; n];
        with_threads(threads, || {
            // force the parallel path regardless of size
            for_chunks(PAR_MIN_WORK, &mut out, chunk, |ci, c| {
                for (j, v) in c.iter_mut().enumerate() {
                    *v = ((ci as u64) << 32) | j as u64;
                }
            });
        });
        out
    }

    #[test]
    fn for_chunks_matches_serial_for_ragged_shapes() {
        for n in [1usize, 5, 64, 101, 1024] {
            for chunk in [1usize, 3, 16, 200] {
                let serial = fill_by_chunk(n, chunk, 1);
                for t in [2usize, 3, 8] {
                    assert_eq!(fill_by_chunk(n, chunk, t), serial, "n={n} chunk={chunk} t={t}");
                }
            }
        }
    }

    #[test]
    fn for_chunks2_pairs_chunks_correctly() {
        let rows = 37usize;
        let (da, db) = (8usize, 3usize);
        let run = |t: usize| {
            let mut a = vec![0u32; rows * da];
            let mut b = vec![0u32; rows * db];
            with_threads(t, || {
                for_chunks2(PAR_MIN_WORK, &mut a, da, &mut b, db, |ci, pa, pb| {
                    for v in pa.iter_mut() {
                        *v = ci as u32 + 1;
                    }
                    for v in pb.iter_mut() {
                        *v = (ci as u32 + 1) * 1000;
                    }
                });
            });
            (a, b)
        };
        let (a1, b1) = run(1);
        let (a4, b4) = run(4);
        assert_eq!(a1, a4);
        assert_eq!(b1, b4);
        assert_eq!(a1[0], 1);
        assert_eq!(a1[rows * da - 1], rows as u32);
        assert_eq!(b1[rows * db - 1], rows as u32 * 1000);
    }

    #[test]
    fn small_work_stays_inline() {
        // work below the threshold must not spawn: detectable because the
        // closure sees the caller's thread id for every chunk.
        let caller = std::thread::current().id();
        let mut data = vec![0u8; 64];
        with_threads(8, || {
            for_chunks(1, &mut data, 4, |_, _| {
                assert_eq!(std::thread::current().id(), caller);
            });
        });
    }

    #[test]
    fn max_abs_matches_serial_fold() {
        let mut r = Rng::new(9);
        let x: Vec<f32> = (0..100_000).map(|_| r.normal() as f32 * 3.0).collect();
        let want = x.iter().fold(0f32, |m, v| m.max(v.abs()));
        assert_eq!(max_abs(&x).to_bits(), want.to_bits());
        let with_nan = {
            let mut y = x.clone();
            y[5] = f32::NAN;
            y
        };
        let want = with_nan.iter().fold(0f32, |m, v| m.max(v.abs()));
        assert_eq!(max_abs(&with_nan).to_bits(), want.to_bits());
        assert_eq!(max_abs(&[]), 0.0);
    }
}
