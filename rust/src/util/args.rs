//! Tiny CLI argument parser (clap is not in the offline crates cache).
//! Supports `--flag`, `--key value`, `--key=value`, and positionals.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.insert(stripped.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn positionals_and_flags() {
        let a = parse("table 3 --lr 1e-4 --quick --out=x.csv");
        assert_eq!(a.positional, vec!["table", "3"]);
        assert_eq!(a.f64_or("lr", 0.0), 1e-4);
        assert!(a.bool("quick"));
        assert_eq!(a.get("out"), Some("x.csv"));
    }

    #[test]
    fn defaults() {
        let a = parse("x");
        assert_eq!(a.usize_or("steps", 7), 7);
        assert!(!a.bool("quick"));
        assert_eq!(a.get_or("model", "ace-sim"), "ace-sim");
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse("--a --b 3");
        assert!(a.bool("a"));
        assert_eq!(a.usize_or("b", 0), 3);
    }
}
