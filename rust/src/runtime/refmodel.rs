//! The pure-Rust reference interpreter behind the `reference` backend:
//! tiny-transformer forward (attn / ssm / moe blocks, optional vision
//! front-end, fake-quantized GEMMs through the `quant::` codecs), manual
//! reverse-mode gradients with the straight-through estimator, the Adam
//! state update, the four loss kinds (CE / KL / MSE / REINFORCE), eval
//! metrics, and the frontier gather.
//!
//! Semantics mirror python/compile/{model,steps}.py — every formula here
//! was validated against `jax.value_and_grad` of those graphs (forward
//! logits, per-loss gradients, multi-step Adam state chains, eval metrics
//! all agree to float32 noise across attn/ssm/moe/vision configs and
//! nvfp4/mxfp4/int4 formats). The in-crate guard is the finite-difference
//! gradient tests at the bottom of this file.
//!
//! Compute runs on the shared parallel core: GEMMs go through
//! [`util::gemm`](crate::util::gemm) (cache-blocked, row-tile parallel,
//! bit-identical to the seed's naive loops) and the remaining hot loops
//! (attention scores/AV, softmax rows, gelu, rmsnorm, the ssm scan over
//! batch lanes, Adam) partition over [`util::pool`](crate::util::pool)
//! chunks whose per-element f32 accumulation chains are exactly the
//! serial ones. Order-bearing reductions (grad-norm, dscale/dbias
//! columns, loss sums, the embedding scatter) deliberately stay serial —
//! or reduce over per-row values in row order — so every result is
//! invariant under `QADX_THREADS` (asserted by rust/tests/threading.rs).

use std::ops::Range;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::engine::scalar;
use super::manifest::{ModelEntry, ParamDef};
use super::paged::{DecodeOpts, PagePool, PagedKv, PagedStats};
use crate::quant::packed::{KernelTier, PackedFormat, PackedWeight};
use crate::quant::{baselines, nvfp4};
use crate::util::gemm::{matmul, matmul_into, matmul_nt, matmul_tn};
use crate::util::pool;

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
const RMS_EPS: f32 = 1e-6;
const SQRT_2_OVER_PI: f32 = 0.797_884_6;

/// Fake-quant format of one operand class.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    None,
    Nvfp4,
    Mxfp4,
    Int4,
}

impl Format {
    /// Parse a manifest quant format. "bf16" maps to `None`: in the sim,
    /// BF16 operands are unquantized (the BF16 config is weights/acts
    /// "none"; some synthetic manifests spell it "bf16").
    pub fn parse(s: &str) -> Result<Format> {
        match s {
            "none" | "bf16" => Ok(Format::None),
            "nvfp4" => Ok(Format::Nvfp4),
            "mxfp4" => Ok(Format::Mxfp4),
            "int4" => Ok(Format::Int4),
            other => bail!("unknown quant format {other:?}"),
        }
    }
}

/// One model bound to an effective quantization config — what a single
/// forward/step program of the reference backend runs against.
#[derive(Clone, Debug)]
pub struct RefCfg {
    pub model: ModelEntry,
    pub weights_fmt: Format,
    pub acts_fmt: Format,
    /// GEMM datapath for quantized inference (forward/decode only; train
    /// and eval programs always run the exact tier). `Exact` fake-quants
    /// weights to f32; `Packed` computes on the packed nibbles.
    pub kernel: KernelTier,
}

impl RefCfg {
    /// Unquantized (the BF16 teacher precision).
    pub fn bf16(model: &ModelEntry) -> RefCfg {
        RefCfg {
            model: model.clone(),
            weights_fmt: Format::None,
            acts_fmt: Format::None,
            kernel: KernelTier::Exact,
        }
    }

    /// The config an artifact-key format suffix selects: "bf16" is
    /// unquantized; "nvfp4" uses the manifest's recorded quant settings;
    /// "mxfp4"/"int4" replace both formats (mirrors configs.quant_cfg_for).
    pub fn for_key_format(model: &ModelEntry, fmt: &str) -> Result<RefCfg> {
        match fmt {
            "bf16" => Ok(RefCfg::bf16(model)),
            "nvfp4" => Ok(RefCfg {
                model: model.clone(),
                weights_fmt: Format::parse(&model.quant.weights)?,
                acts_fmt: Format::parse(&model.quant.acts)?,
                kernel: KernelTier::Exact,
            }),
            "mxfp4" | "int4" => Ok(RefCfg {
                model: model.clone(),
                weights_fmt: Format::parse(fmt)?,
                acts_fmt: Format::parse(fmt)?,
                kernel: KernelTier::Exact,
            }),
            other => bail!("unknown artifact format suffix {other:?}"),
        }
    }

    /// Whether this config actually computes on packed weights: the
    /// packed tier only applies when weights are quantized (acts-only
    /// quantization has no packed representation to bind).
    fn packed_weights(&self) -> bool {
        self.kernel == KernelTier::Packed && self.weights_fmt != Format::None
    }

    /// The packed-layout format for this config's quantized weights.
    fn packed_format(&self) -> Result<PackedFormat> {
        match self.weights_fmt {
            Format::Nvfp4 => Ok(PackedFormat::Nvfp4),
            Format::Mxfp4 => Ok(PackedFormat::Mxfp4),
            Format::Int4 => Ok(PackedFormat::Int4),
            Format::None => bail!("unquantized weights have no packed format"),
        }
    }

    fn quant_enabled(&self) -> bool {
        !(self.weights_fmt == Format::None && self.acts_fmt == Format::None)
    }

    /// Selective quantization (paper §3.4) — matches model._block_quantized.
    fn block_quantized(&self, i: usize, kind: &str) -> bool {
        if !self.quant_enabled() {
            return false;
        }
        let q = &self.model.quant;
        if kind == "attn" && q.skip_attention {
            return false;
        }
        if i < q.skip_first {
            return false;
        }
        if i >= self.model.blocks.len().saturating_sub(q.skip_last) {
            return false;
        }
        true
    }

    fn head_quantized(&self) -> bool {
        let n = self.model.blocks.len();
        if n == 0 {
            return false;
        }
        self.block_quantized(n - 1, "head")
    }

    fn pdef(&self, name: &str) -> Result<&ParamDef> {
        self.model
            .params
            .iter()
            .find(|p| p.name == name)
            .with_context(|| {
                format!("model {} has no parameter {name:?} in its layout", self.model.name)
            })
    }

    fn pslice<'a>(&self, params: &'a [f32], name: &str) -> Result<&'a [f32]> {
        let d = self.pdef(name)?;
        if d.offset + d.size > params.len() {
            bail!(
                "parameter {name:?} [{}..{}] out of range of params len {}",
                d.offset,
                d.offset + d.size,
                params.len()
            );
        }
        Ok(&params[d.offset..d.offset + d.size])
    }

    /// Experts per moe block: the manifest field, or (older manifests)
    /// derived from the first router parameter's shape.
    fn n_experts(&self) -> Result<usize> {
        if self.model.n_experts > 0 {
            return Ok(self.model.n_experts);
        }
        for p in &self.model.params {
            if p.name.ends_with(".router") && p.shape.len() == 2 {
                return Ok(p.shape[1]);
            }
        }
        bail!("model {} has moe blocks but no n_experts", self.model.name)
    }
}

// ------------------------------------------------------------ fake quant

/// Fake-quantize a row-major (rows, cols) activation along the last axis
/// into `out` (cleared and refilled — reuses its allocation).
fn quant_acts_into(
    x: &[f32],
    rows: usize,
    cols: usize,
    fmt: Format,
    out: &mut Vec<f32>,
) -> Result<()> {
    match fmt {
        Format::None => {
            out.clear();
            out.extend_from_slice(x);
        }
        Format::Nvfp4 => {
            if cols % nvfp4::BLOCK != 0 {
                bail!("nvfp4 needs cols % 16 == 0, got {cols}");
            }
            nvfp4::fake_quant_into(x, rows, cols, out);
        }
        Format::Mxfp4 => {
            if cols % baselines::MXFP4_BLOCK != 0 {
                bail!("mxfp4 needs cols % 32 == 0, got {cols}");
            }
            baselines::mxfp4_fake_quant_into(x, rows, cols, out);
        }
        Format::Int4 => baselines::int4_fake_quant_into(x, rows, cols, out),
    }
    Ok(())
}

thread_local! {
    /// Transpose scratch for weight fake-quant — the per-GEMM temporaries
    /// that used to be fresh allocations on every call.
    static WQ_SCRATCH: std::cell::RefCell<(Vec<f32>, Vec<f32>)> =
        const { std::cell::RefCell::new((Vec::new(), Vec::new())) };
}

/// Fake-quantize a (k, n) weight along its contraction axis K into `out`:
/// transpose, quantize rows of the (n, k) view, transpose back (model.py
/// qgemm). The transpose temporaries live in thread-local scratch.
fn quant_weight_into(
    w: &[f32],
    k: usize,
    n: usize,
    fmt: Format,
    out: &mut Vec<f32>,
) -> Result<()> {
    if fmt == Format::None {
        out.clear();
        out.extend_from_slice(w);
        return Ok(());
    }
    WQ_SCRATCH.with(|cell| {
        let (t, tq) = &mut *cell.borrow_mut();
        t.clear();
        t.resize(k * n, 0.0);
        for r in 0..k {
            for c in 0..n {
                t[c * k + r] = w[r * n + c];
            }
        }
        quant_acts_into(t, n, k, fmt, tq)?;
        out.clear();
        out.resize(k * n, 0.0);
        for r in 0..k {
            for c in 0..n {
                out[r * n + c] = tq[c * k + r];
            }
        }
        Ok(())
    })
}

// --------------------------------------------------------------- tensor ops
//
// GEMMs live in crate::util::gemm (blocked + row-tile parallel, bit-
// identical to the seed loops). The helpers below cover the elementwise
// combines: chunk-parallel, one f32 op chain per element.

/// Elementwise chunk size for the parallel helpers below.
const EW_CHUNK: usize = 8192;

/// dst[i] += src[i].
fn add_assign(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    pool::for_chunks(dst.len(), dst, EW_CHUNK, |ci, c| {
        let base = ci * EW_CHUNK;
        for (j, v) in c.iter_mut().enumerate() {
            *v += src[base + j];
        }
    });
}

/// dst[i] += a[i] + b[i] (the three-way grad combine, seed op order).
fn add_assign2(dst: &mut [f32], a: &[f32], b: &[f32]) {
    debug_assert_eq!(dst.len(), a.len());
    debug_assert_eq!(dst.len(), b.len());
    pool::for_chunks(dst.len(), dst, EW_CHUNK, |ci, c| {
        let base = ci * EW_CHUNK;
        for (j, v) in c.iter_mut().enumerate() {
            *v += a[base + j] + b[base + j];
        }
    });
}

/// One quantized GEMM with cached quantized operands; backward applies the
/// straight-through estimator (quantizers are identity for gradients).
struct Gemm {
    xq: Vec<f32>,
    wq: Vec<f32>,
    out: Vec<f32>,
    m: usize,
    k: usize,
    n: usize,
}

impl Gemm {
    fn forward(
        x: &[f32],
        w: &[f32],
        m: usize,
        k: usize,
        n: usize,
        quantized: bool,
        cfg: &RefCfg,
    ) -> Result<Gemm> {
        if x.len() != m * k || w.len() != k * n {
            bail!("gemm shape mismatch: x {} != {m}x{k} or w {} != {k}x{n}", x.len(), w.len());
        }
        let xq = if quantized {
            let mut v = Vec::with_capacity(m * k);
            quant_acts_into(x, m, k, cfg.acts_fmt, &mut v)?;
            v
        } else {
            x.to_vec()
        };
        if quantized && cfg.packed_weights() {
            // Quantized-domain tier: pack the weight (same quantization
            // grid as quant_weight_into, bit for bit) and run the LUT
            // micro-kernel instead of materializing f32 weights. Forward-
            // only: the packed tier never reaches training programs, so
            // `wq` stays empty and `backward` is out of contract here.
            let pw = PackedWeight::pack(w, k, n, cfg.packed_format()?)?;
            let mut out = vec![0f32; m * n];
            pw.gemm_into(&xq, m, &mut out)?;
            return Ok(Gemm { xq, wq: Vec::new(), out, m, k, n });
        }
        let wq = if quantized {
            let mut v = Vec::with_capacity(k * n);
            quant_weight_into(w, k, n, cfg.weights_fmt, &mut v)?;
            v
        } else {
            w.to_vec()
        };
        let out = matmul(&xq, &wq, m, k, n);
        Ok(Gemm { xq, wq, out, m, k, n })
    }

    /// dy (m,n) -> (dx (m,k), dw (k,n)).
    fn backward(&self, dy: &[f32]) -> (Vec<f32>, Vec<f32>) {
        let dx = matmul_nt(dy, &self.wq, self.m, self.n, self.k);
        let dw = matmul_tn(&self.xq, dy, self.m, self.k, self.n);
        (dx, dw)
    }
}

/// rmsnorm over rows of length d; returns (y, per-row r = rsqrt(ms+eps)).
/// Row-parallel: each row's chain is self-contained.
fn rmsnorm_fwd(x: &[f32], scale: &[f32], rows: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0f32; rows * d];
    let mut rs = vec![0f32; rows];
    pool::for_chunks2(rows * d * 3, &mut y, d, &mut rs, 1, |i, yr, rv| {
        let xr = &x[i * d..(i + 1) * d];
        let mut ms = 0f32;
        for &v in xr {
            ms += v * v;
        }
        let r = 1.0 / (ms / d as f32 + RMS_EPS).sqrt();
        rv[0] = r;
        for j in 0..d {
            yr[j] = xr[j] * r * scale[j];
        }
    });
    (y, rs)
}

/// Backward of rmsnorm; accumulates dscale, returns dx. dx is row-
/// parallel; the dscale columns are an order-bearing reduction over rows
/// and stay a serial second pass (same ascending-row chain as the seed).
fn rmsnorm_bwd(
    dy: &[f32],
    x: &[f32],
    rs: &[f32],
    scale: &[f32],
    rows: usize,
    d: usize,
    dscale: &mut [f32],
) -> Vec<f32> {
    let mut dx = vec![0f32; rows * d];
    pool::for_chunks(rows * d * 6, &mut dx, d, |i, dxr| {
        let r = rs[i];
        let xr = &x[i * d..(i + 1) * d];
        let dyr = &dy[i * d..(i + 1) * d];
        let mut s = 0f32;
        for j in 0..d {
            s += dyr[j] * scale[j] * xr[j];
        }
        let c = r * r * r / d as f32 * s;
        for j in 0..d {
            dxr[j] = r * scale[j] * dyr[j] - xr[j] * c;
        }
    });
    for i in 0..rows {
        let r = rs[i];
        let xr = &x[i * d..(i + 1) * d];
        let dyr = &dy[i * d..(i + 1) * d];
        for j in 0..d {
            dscale[j] += dyr[j] * xr[j] * r;
        }
    }
    dx
}

/// tanh-approximate gelu (jax.nn.gelu approximate=True); returns (y, tanh).
fn gelu_fwd(x: &[f32]) -> (Vec<f32>, Vec<f32>) {
    let mut y = vec![0f32; x.len()];
    let mut ts = vec![0f32; x.len()];
    pool::for_chunks2(x.len() * 8, &mut y, EW_CHUNK, &mut ts, EW_CHUNK, |ci, yc, tc| {
        let base = ci * EW_CHUNK;
        for j in 0..yc.len() {
            let v = x[base + j];
            let t = (SQRT_2_OVER_PI * (v + 0.044715 * v * v * v)).tanh();
            tc[j] = t;
            yc[j] = 0.5 * v * (1.0 + t);
        }
    });
    (y, ts)
}

fn gelu_bwd(dy: &[f32], x: &[f32], ts: &[f32]) -> Vec<f32> {
    let mut dx = vec![0f32; x.len()];
    pool::for_chunks(x.len() * 8, &mut dx, EW_CHUNK, |ci, c| {
        let base = ci * EW_CHUNK;
        for (j, o) in c.iter_mut().enumerate() {
            let v = x[base + j];
            let t = ts[base + j];
            let dinner = SQRT_2_OVER_PI * (1.0 + 3.0 * 0.044715 * v * v);
            let dt = (1.0 - t * t) * dinner;
            *o = dy[base + j] * (0.5 * (1.0 + t) + 0.5 * v * dt);
        }
    });
    dx
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Softmax over contiguous rows of length n (row-parallel).
fn softmax_rows(x: &[f32], rows: usize, n: usize) -> Vec<f32> {
    let mut p = vec![0f32; rows * n];
    pool::for_chunks(rows * n * 6, &mut p, n, |i, pr| {
        let xr = &x[i * n..(i + 1) * n];
        let m = xr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0f32;
        for j in 0..n {
            let e = (xr[j] - m).exp();
            pr[j] = e;
            z += e;
        }
        for v in pr.iter_mut() {
            *v /= z;
        }
    });
    p
}

fn log_softmax_rows(x: &[f32], rows: usize, n: usize) -> Vec<f32> {
    let mut lp = vec![0f32; rows * n];
    pool::for_chunks(rows * n * 6, &mut lp, n, |i, lpr| {
        let xr = &x[i * n..(i + 1) * n];
        let m = xr.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0f32;
        for &v in xr {
            z += (v - m).exp();
        }
        let lz = z.ln();
        for j in 0..n {
            lpr[j] = xr[j] - m - lz;
        }
    });
    lp
}

/// dsoftmax: p ⊙ (dy − Σ dy⊙p), rowwise (row-parallel).
fn softmax_bwd_rows(dy: &[f32], p: &[f32], rows: usize, n: usize) -> Vec<f32> {
    let mut dx = vec![0f32; rows * n];
    pool::for_chunks(rows * n * 4, &mut dx, n, |i, dxr| {
        let dyr = &dy[i * n..(i + 1) * n];
        let pr = &p[i * n..(i + 1) * n];
        let mut s = 0f32;
        for j in 0..n {
            s += dyr[j] * pr[j];
        }
        for j in 0..n {
            dxr[j] = pr[j] * (dyr[j] - s);
        }
    });
    dx
}

// ------------------------------------------------------------ forward pass

enum BlockCache {
    Attn {
        x: Vec<f32>,
        r1: Vec<f32>,
        gq: Gemm,
        gk: Gemm,
        gv: Gemm,
        pa: Vec<f32>, // (B, h, T, T)
        go: Gemm,
        x1: Vec<f32>,
        r2: Vec<f32>,
        g1: Gemm,
        gelu_t: Vec<f32>,
        g2: Gemm,
    },
    Ssm {
        x: Vec<f32>,
        r: Vec<f32>,
        gin: Gemm,
        a: Vec<f32>,   // (B, T, d) post-sigmoid decay
        h: Vec<f32>,   // (B, T, d) scan states
        gout: Gemm,
    },
    Moe {
        x: Vec<f32>,
        r: Vec<f32>,
        y2: Vec<f32>,    // (M, d) post-ln rows
        probs: Vec<f32>, // (M, E)
        kept: Vec<bool>, // (M, E)
        gate: Vec<f32>,  // (M, E) unnormalized kept probs
        z: Vec<f32>,     // (M,) kept mass
        gaten: Vec<f32>, // (M, E)
        experts: Vec<(Gemm, Vec<f32>, Gemm)>,
    },
}

/// A completed forward pass with the caches backward() needs.
pub struct ForwardPass {
    b: usize,
    s_in: usize,
    t: usize,
    n_img: usize,
    tokens: Vec<usize>, // clamped ids, (B * s_in)
    caches: Vec<BlockCache>,
    vis: Option<Gemm>,
    final_x: Vec<f32>,
    final_r: Vec<f32>,
    head: Gemm,
    /// (B, s_in, vocab) row-major.
    pub logits: Vec<f32>,
}

/// Run the forward pass over `tokens` (B, s_in), caching for backward.
pub fn forward(
    cfg: &RefCfg,
    params: &[f32],
    tokens: &[i32],
    b: usize,
    s_in: usize,
    pixels: Option<&[f32]>,
) -> Result<ForwardPass> {
    let m = &cfg.model;
    let d = m.d_model;
    let v = m.vocab;
    if params.len() != m.param_count {
        bail!("params len {} != param_count {}", params.len(), m.param_count);
    }
    if tokens.len() != b * s_in {
        bail!("tokens len {} != {b}x{s_in}", tokens.len());
    }
    if d == 0 || m.n_heads == 0 || d % m.n_heads != 0 {
        bail!("model {}: d_model {d} not divisible by n_heads {}", m.name, m.n_heads);
    }

    // Embedding lookup (ids clamped like an XLA gather).
    let embed = cfg.pslice(params, "embed")?;
    if embed.len() != v * d {
        bail!("embed param size {} != vocab*d {}", embed.len(), v * d);
    }
    let ids: Vec<usize> = tokens
        .iter()
        .map(|&t| (t.max(0) as usize).min(v.saturating_sub(1)))
        .collect();

    let n_img = if m.vision { m.vision_grid * m.vision_grid } else { 0 };
    let t_len = s_in + n_img;
    let mut x = vec![0f32; b * t_len * d];

    let mut vis_gemm = None;
    let mut vis_bias: &[f32] = &[];
    if m.vision {
        let px = pixels.context("VLM forward requires pixels")?;
        let patch = m.vision_patch;
        if px.len() != b * n_img * patch {
            bail!("pixels len {} != {b}x{n_img}x{patch}", px.len());
        }
        let vis_proj = cfg.pslice(params, "vis_proj")?;
        vis_bias = cfg.pslice(params, "vis_bias")?;
        let quant_vis = cfg.quant_enabled();
        vis_gemm = Some(Gemm::forward(px, vis_proj, b * n_img, patch, d, quant_vis, cfg)?);
    }
    let pos_emb = cfg.pslice(params, "pos_emb")?;
    if pos_emb.len() < t_len * d {
        bail!("pos_emb size {} < seq {t_len} x d {d}", pos_emb.len());
    }
    // One row-parallel pass builds x: image rows = vis_proj out + bias +
    // pos, text rows = embedding + pos (seed's add order per element).
    {
        let vis_ref = vis_gemm.as_ref();
        let ids = &ids;
        pool::for_chunks(b * t_len * d * 2, &mut x, d, |ci, dst| {
            let ti = ci % t_len;
            let bi = ci / t_len;
            let pe = &pos_emb[ti * d..(ti + 1) * d];
            if ti < n_img {
                let gm = vis_ref.expect("image rows imply a vision gemm");
                let src = &gm.out[(bi * n_img + ti) * d..(bi * n_img + ti + 1) * d];
                for j in 0..d {
                    dst[j] = src[j] + vis_bias[j] + pe[j];
                }
            } else {
                let id = ids[bi * s_in + (ti - n_img)];
                let src = &embed[id * d..(id + 1) * d];
                for j in 0..d {
                    dst[j] = src[j] + pe[j];
                }
            }
        });
    }

    let mut caches = Vec::with_capacity(m.blocks.len());
    let blocks = m.blocks.clone();
    for (i, kind) in blocks.iter().enumerate() {
        let quant = cfg.block_quantized(i, kind);
        let pre = format!("b{i}.");
        x = match kind.as_str() {
            "attn" => attn_fwd(cfg, params, &pre, x, b, t_len, quant, &mut caches)?,
            "ssm" => ssm_fwd(cfg, params, &pre, x, b, t_len, quant, &mut caches)?,
            "moe" => moe_fwd(cfg, params, &pre, x, b, t_len, quant, &mut caches)?,
            other => bail!("unknown block kind {other:?} in model {}", m.name),
        };
    }

    let ln_f = cfg.pslice(params, "ln_f")?;
    let (y, final_r) = rmsnorm_fwd(&x, ln_f, b * t_len, d);
    // Drop image positions before the head.
    let mut y_text = vec![0f32; b * s_in * d];
    for bi in 0..b {
        let src = &y[(bi * t_len + n_img) * d..(bi * t_len + t_len) * d];
        y_text[bi * s_in * d..(bi + 1) * s_in * d].copy_from_slice(src);
    }
    let head_w = cfg.pslice(params, "head")?;
    let head = Gemm::forward(&y_text, head_w, b * s_in, d, v, cfg.head_quantized(), cfg)?;
    let logits = head.out.clone();

    Ok(ForwardPass {
        b,
        s_in,
        t: t_len,
        n_img,
        tokens: ids,
        caches,
        vis: vis_gemm,
        final_x: x,
        final_r,
        head,
        logits,
    })
}

#[allow(clippy::too_many_arguments)]
fn attn_fwd(
    cfg: &RefCfg,
    params: &[f32],
    pre: &str,
    x: Vec<f32>,
    b: usize,
    t: usize,
    quant: bool,
    caches: &mut Vec<BlockCache>,
) -> Result<Vec<f32>> {
    let d = cfg.model.d_model;
    let h = cfg.model.n_heads;
    let hd = d / h;
    let ff = cfg.model.d_ff;
    let rows = b * t;
    let ln1 = cfg.pslice(params, &format!("{pre}ln1"))?;
    let (y, r1) = rmsnorm_fwd(&x, ln1, rows, d);
    let gq = Gemm::forward(&y, cfg.pslice(params, &format!("{pre}wq"))?, rows, d, d, quant, cfg)?;
    let gk = Gemm::forward(&y, cfg.pslice(params, &format!("{pre}wk"))?, rows, d, d, quant, cfg)?;
    let gv = Gemm::forward(&y, cfg.pslice(params, &format!("{pre}wv"))?, rows, d, d, quant, cfg)?;
    // att[b,head,i,j] = q·k / sqrt(hd), causal-masked, softmaxed over j.
    // Parallel over (b, head, i) score rows — each row self-contained.
    let inv_sqrt = 1.0 / (hd as f32).sqrt();
    let mut att = vec![0f32; b * h * t * t];
    pool::for_chunks(b * h * t * t * hd, &mut att, t, |ci, arow| {
        let i = ci % t;
        let head = (ci / t) % h;
        let bi = ci / (t * h);
        let q = &gq.out[(bi * t + i) * d + head * hd..(bi * t + i) * d + (head + 1) * hd];
        for (j, av) in arow.iter_mut().enumerate() {
            if j > i {
                *av = -1e30;
                continue;
            }
            let k = &gk.out[(bi * t + j) * d + head * hd..(bi * t + j) * d + (head + 1) * hd];
            let mut s = 0f32;
            for c in 0..hd {
                s += q[c] * k[c];
            }
            *av = s * inv_sqrt;
        }
    });
    let pa = softmax_rows(&att, b * h * t, t);
    // o[b,i,head,c] = Σ_j pa · v — parallel over (b, i) output rows; the
    // per-element chain (ascending j within one head) is the seed's.
    let mut o = vec![0f32; rows * d];
    pool::for_chunks(rows * d * t, &mut o, d, |ci, orow_all| {
        let i = ci % t;
        let bi = ci / t;
        for head in 0..h {
            let parow = &pa[((bi * h + head) * t + i) * t..((bi * h + head) * t + i + 1) * t];
            let orow = &mut orow_all[head * hd..(head + 1) * hd];
            for (j, &pj) in parow.iter().enumerate().take(i + 1) {
                let vv =
                    &gv.out[(bi * t + j) * d + head * hd..(bi * t + j) * d + (head + 1) * hd];
                for c in 0..hd {
                    orow[c] += pj * vv[c];
                }
            }
        }
    });
    let go = Gemm::forward(&o, cfg.pslice(params, &format!("{pre}wo"))?, rows, d, d, quant, cfg)?;
    let mut x1 = x.clone();
    add_assign(&mut x1, &go.out);
    let ln2 = cfg.pslice(params, &format!("{pre}ln2"))?;
    let (y2, r2) = rmsnorm_fwd(&x1, ln2, rows, d);
    let w1 = cfg.pslice(params, &format!("{pre}w1"))?;
    let g1 = Gemm::forward(&y2, w1, rows, d, ff, quant, cfg)?;
    let (hdn, gelu_t) = gelu_fwd(&g1.out);
    let w2 = cfg.pslice(params, &format!("{pre}w2"))?;
    let g2 = Gemm::forward(&hdn, w2, rows, ff, d, quant, cfg)?;
    let mut x2 = x1.clone();
    add_assign(&mut x2, &g2.out);
    caches.push(BlockCache::Attn {
        x,
        r1,
        gq,
        gk,
        gv,
        pa,
        go,
        x1,
        r2,
        g1,
        gelu_t,
        g2,
    });
    Ok(x2)
}

#[allow(clippy::too_many_arguments)]
fn ssm_fwd(
    cfg: &RefCfg,
    params: &[f32],
    pre: &str,
    x: Vec<f32>,
    b: usize,
    t: usize,
    quant: bool,
    caches: &mut Vec<BlockCache>,
) -> Result<Vec<f32>> {
    let d = cfg.model.d_model;
    let rows = b * t;
    let ln = cfg.pslice(params, &format!("{pre}ln"))?;
    let (y, r) = rmsnorm_fwd(&x, ln, rows, d);
    let gin =
        Gemm::forward(&y, cfg.pslice(params, &format!("{pre}win"))?, rows, d, 3 * d, quant, cfg)?;
    let a_bias = cfg.pslice(params, &format!("{pre}a_bias"))?;
    // z rows: [v | g | decay-logit] — decay gate is row-parallel.
    let mut a = vec![0f32; rows * d];
    pool::for_chunks(rows * d * 8, &mut a, d, |i, ar| {
        let z = &gin.out[i * 3 * d..(i + 1) * 3 * d];
        for j in 0..d {
            ar[j] = sigmoid(z[2 * d + j] + a_bias[j]);
        }
    });
    // scan: h_t = a_t ⊙ h_{t-1} + (1-a_t) ⊙ v_t — sequential in t,
    // independent (and parallel) across batch lanes.
    let mut hs = vec![0f32; rows * d];
    pool::for_chunks(rows * d * 4, &mut hs, t * d, |bi, hb| {
        for ti in 0..t {
            let i = bi * t + ti;
            let z = &gin.out[i * 3 * d..(i + 1) * 3 * d];
            for j in 0..d {
                let av = a[i * d + j];
                let bv = (1.0 - av) * z[j];
                let prev = if ti > 0 { hb[(ti - 1) * d + j] } else { 0.0 };
                hb[ti * d + j] = av * prev + bv;
            }
        }
    });
    // o = h ⊙ silu(g) — row-parallel.
    let mut o = vec![0f32; rows * d];
    pool::for_chunks(rows * d * 8, &mut o, d, |i, or| {
        let z = &gin.out[i * 3 * d..(i + 1) * 3 * d];
        for j in 0..d {
            let g = z[d + j];
            or[j] = hs[i * d + j] * g * sigmoid(g);
        }
    });
    let gout =
        Gemm::forward(&o, cfg.pslice(params, &format!("{pre}wout"))?, rows, d, d, quant, cfg)?;
    let mut x2 = x.clone();
    add_assign(&mut x2, &gout.out);
    caches.push(BlockCache::Ssm { x, r, gin, a, h: hs, gout });
    Ok(x2)
}

#[allow(clippy::too_many_arguments)]
fn moe_fwd(
    cfg: &RefCfg,
    params: &[f32],
    pre: &str,
    x: Vec<f32>,
    b: usize,
    t: usize,
    quant: bool,
    caches: &mut Vec<BlockCache>,
) -> Result<Vec<f32>> {
    let d = cfg.model.d_model;
    let ff = cfg.model.d_ff;
    let e = cfg.n_experts()?;
    if e < 2 {
        bail!("moe block needs n_experts >= 2, got {e}");
    }
    let rows = b * t;
    let ln = cfg.pslice(params, &format!("{pre}ln"))?;
    let (y2, r) = rmsnorm_fwd(&x, ln, rows, d);
    let router = cfg.pslice(params, &format!("{pre}router"))?;
    if router.len() != d * e {
        bail!("router size {} != d*E {}", router.len(), d * e);
    }
    // Router stays high-precision.
    let logits = matmul(&y2, router, rows, d, e);
    let probs = softmax_rows(&logits, rows, e);
    // Top-2 threshold: mask the first argmax occurrence, take the max of
    // the rest, keep everything >= that value (model.py's two-pass form).
    let mut kept = vec![false; rows * e];
    let mut gate = vec![0f32; rows * e];
    let mut z = vec![0f32; rows];
    let mut gaten = vec![0f32; rows * e];
    for i in 0..rows {
        let pr = &probs[i * e..(i + 1) * e];
        let mut m1 = 0usize;
        for j in 1..e {
            if pr[j] > pr[m1] {
                m1 = j;
            }
        }
        let mut thresh = f32::NEG_INFINITY;
        for (j, &p) in pr.iter().enumerate() {
            if j != m1 && p > thresh {
                thresh = p;
            }
        }
        let mut zi = 0f32;
        for j in 0..e {
            if pr[j] >= thresh {
                kept[i * e + j] = true;
                gate[i * e + j] = pr[j];
                zi += pr[j];
            }
        }
        z[i] = zi;
        for j in 0..e {
            gaten[i * e + j] = gate[i * e + j] / (zi + 1e-9);
        }
    }
    let w1 = cfg.pslice(params, &format!("{pre}w1"))?;
    let w2 = cfg.pslice(params, &format!("{pre}w2"))?;
    if w1.len() != e * d * ff || w2.len() != e * ff * d {
        bail!("moe expert weights have unexpected sizes");
    }
    let mut out = vec![0f32; rows * d];
    let mut experts = Vec::with_capacity(e);
    for ei in 0..e {
        let g1 = Gemm::forward(&y2, &w1[ei * d * ff..(ei + 1) * d * ff], rows, d, ff, quant, cfg)?;
        let (hdn, gelu_t) = gelu_fwd(&g1.out);
        let g2 =
            Gemm::forward(&hdn, &w2[ei * ff * d..(ei + 1) * ff * d], rows, ff, d, quant, cfg)?;
        // gated combine, row-parallel (expert order stays the serial one,
        // so each out element's accumulation chain is unchanged)
        pool::for_chunks(rows * d * 2, &mut out, d, |i, orow| {
            let gn = gaten[i * e + ei];
            let srow = &g2.out[i * d..(i + 1) * d];
            for j in 0..d {
                orow[j] += gn * srow[j];
            }
        });
        experts.push((g1, gelu_t, g2));
    }
    let mut x2 = x.clone();
    add_assign(&mut x2, &out);
    caches.push(BlockCache::Moe { x, r, y2, probs, kept, gate, z, gaten, experts });
    Ok(x2)
}

// ----------------------------------------------------------------- backward

/// Accumulating gradient vector with name-addressed slices.
struct Grads<'c> {
    cfg: &'c RefCfg,
    flat: Vec<f32>,
}

impl<'c> Grads<'c> {
    fn new(cfg: &'c RefCfg) -> Grads<'c> {
        Grads { cfg, flat: vec![0f32; cfg.model.param_count] }
    }

    fn add(&mut self, name: &str, g: &[f32]) -> Result<()> {
        let d = self.cfg.pdef(name)?;
        if d.size != g.len() {
            bail!("grad for {name:?} has len {} != param size {}", g.len(), d.size);
        }
        let dst = &mut self.flat[d.offset..d.offset + d.size];
        for (a, b) in dst.iter_mut().zip(g) {
            *a += *b;
        }
        Ok(())
    }
}

impl ForwardPass {
    /// Reverse-mode pass: dlogits (B, s_in, vocab) -> flat dparams.
    pub fn backward(&self, cfg: &RefCfg, params: &[f32], dlogits: &[f32]) -> Result<Vec<f32>> {
        let m = &cfg.model;
        let d = m.d_model;
        let (b, s_in, t, n_img) = (self.b, self.s_in, self.t, self.n_img);
        if dlogits.len() != b * s_in * m.vocab {
            bail!("dlogits len {} != {}x{}x{}", dlogits.len(), b, s_in, m.vocab);
        }
        let mut grads = Grads::new(cfg);

        let (dy_text, dhead) = self.head.backward(dlogits);
        grads.add("head", &dhead)?;
        // Re-insert image positions (zero grad there from the head).
        let mut dy = vec![0f32; b * t * d];
        for bi in 0..b {
            let dst = &mut dy[(bi * t + n_img) * d..(bi * t + t) * d];
            dst.copy_from_slice(&dy_text[bi * s_in * d..(bi + 1) * s_in * d]);
        }
        let ln_f = cfg.pslice(params, "ln_f")?;
        let mut dln_f = vec![0f32; d];
        let mut dx =
            rmsnorm_bwd(&dy, &self.final_x, &self.final_r, ln_f, b * t, d, &mut dln_f);
        grads.add("ln_f", &dln_f)?;

        for (i, cache) in self.caches.iter().enumerate().rev() {
            let pre = format!("b{i}.");
            dx = match cache {
                BlockCache::Attn { .. } => {
                    self.attn_bwd(cfg, params, &pre, cache, dx, &mut grads)?
                }
                BlockCache::Ssm { .. } => {
                    self.ssm_bwd(cfg, params, &pre, cache, dx, &mut grads)?
                }
                BlockCache::Moe { .. } => {
                    self.moe_bwd(cfg, params, &pre, cache, dx, &mut grads)?
                }
            };
        }

        // dx is the grad wrt (embeddings ++ image tokens) + pos_emb.
        // dpos rows are independent: gather over ascending bi per row
        // (the seed's bi-outer chain), parallel across ti.
        let pe_def = cfg.pdef("pos_emb")?;
        let mut dpos = vec![0f32; pe_def.size];
        pool::for_chunks(b * t * d, &mut dpos[..t * d], d, |ti, dst| {
            for bi in 0..b {
                let src = &dx[(bi * t + ti) * d..(bi * t + ti + 1) * d];
                for j in 0..d {
                    dst[j] += src[j];
                }
            }
        });
        grads.add("pos_emb", &dpos)?;
        if let Some(vg) = &self.vis {
            let mut dimg = vec![0f32; b * n_img * d];
            let mut dbias = vec![0f32; d];
            for bi in 0..b {
                for ii in 0..n_img {
                    let src = &dx[(bi * t + ii) * d..(bi * t + ii + 1) * d];
                    let dst = &mut dimg[(bi * n_img + ii) * d..(bi * n_img + ii + 1) * d];
                    dst.copy_from_slice(src);
                    for j in 0..d {
                        dbias[j] += src[j];
                    }
                }
            }
            grads.add("vis_bias", &dbias)?;
            let (_dpx, dvis) = vg.backward(&dimg);
            grads.add("vis_proj", &dvis)?;
        }
        let emb_def = cfg.pdef("embed")?;
        let mut demb = vec![0f32; emb_def.size];
        for bi in 0..b {
            for si in 0..s_in {
                let id = self.tokens[bi * s_in + si];
                let src = &dx[(bi * t + n_img + si) * d..(bi * t + n_img + si + 1) * d];
                let dst = &mut demb[id * d..(id + 1) * d];
                for j in 0..d {
                    dst[j] += src[j];
                }
            }
        }
        grads.add("embed", &demb)?;
        Ok(grads.flat)
    }

    fn attn_bwd(
        &self,
        cfg: &RefCfg,
        params: &[f32],
        pre: &str,
        cache: &BlockCache,
        dx2: Vec<f32>,
        grads: &mut Grads,
    ) -> Result<Vec<f32>> {
        let BlockCache::Attn { x, r1, gq, gk, gv, pa, go, x1, r2, g1, gelu_t, g2 } = cache
        else {
            bail!("cache kind mismatch (attn)");
        };
        let d = cfg.model.d_model;
        let h = cfg.model.n_heads;
        let hd = d / h;
        let (b, t) = (self.b, self.t);
        let rows = b * t;
        // MLP half
        let (dhdn, dw2) = g2.backward(&dx2);
        grads.add(&format!("{pre}w2"), &dw2)?;
        let dg1 = gelu_bwd(&dhdn, &g1.out, gelu_t);
        let (dy2, dw1) = g1.backward(&dg1);
        grads.add(&format!("{pre}w1"), &dw1)?;
        let ln2 = cfg.pslice(params, &format!("{pre}ln2"))?;
        let mut dln2 = vec![0f32; d];
        let mut dx1 = rmsnorm_bwd(&dy2, x1, r2, ln2, rows, d, &mut dln2);
        grads.add(&format!("{pre}ln2"), &dln2)?;
        add_assign(&mut dx1, &dx2); // residual
        // attention half
        let (do2, dwo) = go.backward(&dx1);
        grads.add(&format!("{pre}wo"), &dwo)?;
        // dpa: parallel over (b, head, i) rows (independent writes).
        let mut dpa = vec![0f32; b * h * t * t];
        pool::for_chunks(b * h * t * t * hd, &mut dpa, t, |ci, dparow| {
            let i = ci % t;
            let head = (ci / t) % h;
            let bi = ci / (t * h);
            let doff = (bi * t + i) * d + head * hd;
            let dor = &do2[doff..doff + hd];
            for (j, dpj) in dparow.iter_mut().enumerate().take(i + 1) {
                let vv =
                    &gv.out[(bi * t + j) * d + head * hd..(bi * t + j) * d + (head + 1) * hd];
                let mut s = 0f32;
                for c in 0..hd {
                    s += dor[c] * vv[c];
                }
                *dpj = s;
            }
        });
        // dv: the seed scattered over j from an i-outer loop; gathered
        // form sums i = j..t ascending per row — the identical chain —
        // and is parallel over (b, j) rows.
        let mut dv = vec![0f32; rows * d];
        pool::for_chunks(b * t * t * d, &mut dv, d, |ci, dvrow| {
            let j = ci % t;
            let bi = ci / t;
            for head in 0..h {
                let dvr = &mut dvrow[head * hd..(head + 1) * hd];
                for i in j..t {
                    let pj = pa[((bi * h + head) * t + i) * t + j];
                    let dor = &do2
                        [(bi * t + i) * d + head * hd..(bi * t + i) * d + (head + 1) * hd];
                    for c in 0..hd {
                        dvr[c] += pj * dor[c];
                    }
                }
            }
        });
        let mut datt = softmax_bwd_rows(&dpa, pa, b * h * t, t);
        let inv_sqrt = 1.0 / (hd as f32).sqrt();
        pool::for_chunks(datt.len(), &mut datt, EW_CHUNK, |_, c| {
            for v in c.iter_mut() {
                *v *= inv_sqrt;
            }
        });
        // dq: parallel over (b, i) rows (ascending-j chain as the seed).
        let mut dq = vec![0f32; rows * d];
        pool::for_chunks(b * t * t * d, &mut dq, d, |ci, dqrow| {
            let i = ci % t;
            let bi = ci / t;
            for head in 0..h {
                let darow =
                    &datt[((bi * h + head) * t + i) * t..((bi * h + head) * t + i + 1) * t];
                let dqr = &mut dqrow[head * hd..(head + 1) * hd];
                for (j, &da) in darow.iter().enumerate().take(i + 1) {
                    if da == 0.0 {
                        continue;
                    }
                    let krow = &gk.out
                        [(bi * t + j) * d + head * hd..(bi * t + j) * d + (head + 1) * hd];
                    for c in 0..hd {
                        dqr[c] += da * krow[c];
                    }
                }
            }
        });
        // dk: gathered form of the seed's scatter — ascending i per row.
        let mut dk = vec![0f32; rows * d];
        pool::for_chunks(b * t * t * d, &mut dk, d, |ci, dkrow| {
            let j = ci % t;
            let bi = ci / t;
            for head in 0..h {
                let dkr = &mut dkrow[head * hd..(head + 1) * hd];
                for i in j..t {
                    let da = datt[((bi * h + head) * t + i) * t + j];
                    if da == 0.0 {
                        continue;
                    }
                    let qrow = &gq.out
                        [(bi * t + i) * d + head * hd..(bi * t + i) * d + (head + 1) * hd];
                    for c in 0..hd {
                        dkr[c] += da * qrow[c];
                    }
                }
            }
        });
        let (dyq, dwq) = gq.backward(&dq);
        let (dyk, dwk) = gk.backward(&dk);
        let (dyv, dwv) = gv.backward(&dv);
        grads.add(&format!("{pre}wq"), &dwq)?;
        grads.add(&format!("{pre}wk"), &dwk)?;
        grads.add(&format!("{pre}wv"), &dwv)?;
        let mut dy = dyq;
        add_assign2(&mut dy, &dyk, &dyv);
        let ln1 = cfg.pslice(params, &format!("{pre}ln1"))?;
        let mut dln1 = vec![0f32; d];
        let mut dxa = rmsnorm_bwd(&dy, x, r1, ln1, rows, d, &mut dln1);
        grads.add(&format!("{pre}ln1"), &dln1)?;
        add_assign(&mut dxa, &dx1);
        Ok(dxa)
    }

    fn ssm_bwd(
        &self,
        cfg: &RefCfg,
        params: &[f32],
        pre: &str,
        cache: &BlockCache,
        dx2: Vec<f32>,
        grads: &mut Grads,
    ) -> Result<Vec<f32>> {
        let BlockCache::Ssm { x, r, gin, a, h, gout } = cache else {
            bail!("cache kind mismatch (ssm)");
        };
        let d = cfg.model.d_model;
        let (b, t) = (self.b, self.t);
        let rows = b * t;
        let (do2, dwout) = gout.backward(&dx2);
        grads.add(&format!("{pre}wout"), &dwout)?;
        // o = h ⊙ silu(g): dh, dg — row-parallel.
        let mut dh = vec![0f32; rows * d];
        let mut dz = vec![0f32; rows * 3 * d]; // [dv | dg | dal]
        pool::for_chunks2(rows * d * 10, &mut dh, d, &mut dz, 3 * d, |i, dhr, dzr| {
            let z = &gin.out[i * 3 * d..(i + 1) * 3 * d];
            for j in 0..d {
                let g = z[d + j];
                let sg = sigmoid(g);
                let sil = g * sg;
                dhr[j] = do2[i * d + j] * sil;
                dzr[d + j] = do2[i * d + j] * h[i * d + j] * (sg * (1.0 + g * (1.0 - sg)));
            }
        });
        // scan backward: g_t = dh_t + a_{t+1} ⊙ g_{t+1};
        // da_t = g_t ⊙ (h_{t-1} − v_t); dv_t = g_t ⊙ (1 − a_t).
        // Sequential in t, parallel across batch lanes.
        pool::for_chunks(rows * d * 8, &mut dz, t * 3 * d, |bi, dzb| {
            let mut gacc = vec![0f32; d];
            for ti in (0..t).rev() {
                let i = bi * t + ti;
                let z = &gin.out[i * 3 * d..(i + 1) * 3 * d];
                for j in 0..d {
                    let gt = dh[i * d + j] + gacc[j];
                    let hprev = if ti > 0 { h[(i - 1) * d + j] } else { 0.0 };
                    let av = a[i * d + j];
                    let da = gt * (hprev - z[j]);
                    dzb[ti * 3 * d + 2 * d + j] = da * av * (1.0 - av); // through sigmoid
                    dzb[ti * 3 * d + j] = gt * (1.0 - av);
                    gacc[j] = gt * av;
                }
            }
        });
        let mut dbias = vec![0f32; d];
        for i in 0..rows {
            for j in 0..d {
                dbias[j] += dz[i * 3 * d + 2 * d + j];
            }
        }
        grads.add(&format!("{pre}a_bias"), &dbias)?;
        let (dy, dwin) = gin.backward(&dz);
        grads.add(&format!("{pre}win"), &dwin)?;
        let ln = cfg.pslice(params, &format!("{pre}ln"))?;
        let mut dln = vec![0f32; d];
        let mut dxa = rmsnorm_bwd(&dy, x, r, ln, rows, d, &mut dln);
        grads.add(&format!("{pre}ln"), &dln)?;
        add_assign(&mut dxa, &dx2);
        Ok(dxa)
    }

    fn moe_bwd(
        &self,
        cfg: &RefCfg,
        params: &[f32],
        pre: &str,
        cache: &BlockCache,
        dx2: Vec<f32>,
        grads: &mut Grads,
    ) -> Result<Vec<f32>> {
        let BlockCache::Moe { x, r, y2, probs, kept, gate, z, gaten, experts } = cache else {
            bail!("cache kind mismatch (moe)");
        };
        let d = cfg.model.d_model;
        let ff = cfg.model.d_ff;
        let e = experts.len();
        let (b, t) = (self.b, self.t);
        let rows = b * t;
        let mut dy2 = vec![0f32; rows * d];
        let mut dgaten = vec![0f32; rows * e];
        let mut dw1 = vec![0f32; e * d * ff];
        let mut dw2 = vec![0f32; e * ff * d];
        let mut scol = vec![0f32; rows];
        for (ei, (g1, gelu_t, g2)) in experts.iter().enumerate() {
            let mut doe = vec![0f32; rows * d];
            // row-parallel: doe rows + the per-row gate sensitivities
            // (scol is scattered into dgaten's strided column serially)
            pool::for_chunks2(rows * d * 3, &mut doe, d, &mut scol, 1, |i, der, sv| {
                let dout = &dx2[i * d..(i + 1) * d];
                let oe = &g2.out[i * d..(i + 1) * d];
                let gn = gaten[i * e + ei];
                let mut s = 0f32;
                for j in 0..d {
                    s += dout[j] * oe[j];
                    der[j] = dout[j] * gn;
                }
                sv[0] = s;
            });
            for i in 0..rows {
                dgaten[i * e + ei] = scol[i];
            }
            let (dhdn, dw2e) = g2.backward(&doe);
            dw2[ei * ff * d..(ei + 1) * ff * d].copy_from_slice(&dw2e);
            let dg1 = gelu_bwd(&dhdn, &g1.out, gelu_t);
            let (dye, dw1e) = g1.backward(&dg1);
            dw1[ei * d * ff..(ei + 1) * d * ff].copy_from_slice(&dw1e);
            add_assign(&mut dy2, &dye);
        }
        grads.add(&format!("{pre}w1"), &dw1)?;
        grads.add(&format!("{pre}w2"), &dw2)?;
        // gating backward: gaten = gate / (Z + 1e-9), gate = kept ? probs : 0
        let mut dprobs = vec![0f32; rows * e];
        for i in 0..rows {
            let zp = z[i] + 1e-9;
            let mut s = 0f32;
            for j in 0..e {
                s += dgaten[i * e + j] * gate[i * e + j];
            }
            for j in 0..e {
                if kept[i * e + j] {
                    dprobs[i * e + j] = dgaten[i * e + j] / zp - s / (zp * zp);
                }
            }
        }
        let dlogits = softmax_bwd_rows(&dprobs, probs, rows, e);
        let router = cfg.pslice(params, &format!("{pre}router"))?;
        let drouter = matmul_tn(y2, &dlogits, rows, d, e);
        grads.add(&format!("{pre}router"), &drouter)?;
        let dy_router = matmul_nt(&dlogits, router, rows, e, d);
        add_assign(&mut dy2, &dy_router);
        let ln = cfg.pslice(params, &format!("{pre}ln"))?;
        let mut dln = vec![0f32; d];
        let mut dxa = rmsnorm_bwd(&dy2, x, r, ln, rows, d, &mut dln);
        grads.add(&format!("{pre}ln"), &dln)?;
        add_assign(&mut dxa, &dx2);
        Ok(dxa)
    }
}

// ------------------------------------------------------------------- losses

pub enum LossKind {
    Ce,
    Kl,
    Mse,
    Reinforce,
}

/// Next-token shift: (inputs, labels, label-mask) over S-1 positions.
fn shift(tokens: &[i32], mask: &[f32], b: usize, s: usize) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    let sm = s - 1;
    let mut inp = vec![0i32; b * sm];
    let mut lab = vec![0i32; b * sm];
    let mut m = vec![0f32; b * sm];
    for bi in 0..b {
        for si in 0..sm {
            inp[bi * sm + si] = tokens[bi * s + si];
            lab[bi * sm + si] = tokens[bi * s + si + 1];
            m[bi * sm + si] = mask[bi * s + si + 1];
        }
    }
    (inp, lab, m)
}

fn clamp_ids(lab: &[i32], v: usize) -> Vec<usize> {
    lab.iter().map(|&t| (t.max(0) as usize).min(v.saturating_sub(1))).collect()
}

/// CE vs labels: (loss, dlogits). Gradient rows are parallel; the loss
/// reduces over per-row terms in ascending row order (the seed's chain).
fn ce_loss(logits: &[f32], lab: &[i32], m: &[f32], rows: usize, v: usize) -> (f32, Vec<f32>) {
    let lp = log_softmax_rows(logits, rows, v);
    let ids = clamp_ids(lab, v);
    let denom: f32 = m.iter().sum::<f32>() + 1e-6;
    let mut dl = vec![0f32; rows * v];
    let mut lrow = vec![0f32; rows];
    {
        let ids = &ids;
        pool::for_chunks2(rows * v * 3, &mut dl, v, &mut lrow, 1, |i, dr, lv| {
            lv[0] = lp[i * v + ids[i]] * m[i];
            let c = m[i] / denom;
            let lpr = &lp[i * v..(i + 1) * v];
            for j in 0..v {
                dr[j] = lpr[j].exp() * c;
            }
            dr[ids[i]] -= c;
        });
    }
    let mut loss = 0f32;
    for &lv in &lrow {
        loss -= lv;
    }
    (loss / denom, dl)
}

/// KL(teacher ‖ student): (loss, d/d s_logits).
fn kl_loss(
    s_logits: &[f32],
    t_logits: &[f32],
    m: &[f32],
    rows: usize,
    v: usize,
) -> (f32, Vec<f32>) {
    let ls = log_softmax_rows(s_logits, rows, v);
    let lt = log_softmax_rows(t_logits, rows, v);
    let denom: f32 = m.iter().sum::<f32>() + 1e-6;
    let mut dl = vec![0f32; rows * v];
    let mut lrow = vec![0f32; rows];
    pool::for_chunks2(rows * v * 6, &mut dl, v, &mut lrow, 1, |i, dr, lv| {
        let lsr = &ls[i * v..(i + 1) * v];
        let ltr = &lt[i * v..(i + 1) * v];
        let mut kl = 0f32;
        let c = m[i] / denom;
        for j in 0..v {
            let pt = ltr[j].exp();
            kl += pt * (ltr[j] - lsr[j]);
            dr[j] = (lsr[j].exp() - pt) * c;
        }
        lv[0] = kl * m[i];
    });
    let mut loss = 0f32;
    for &lv in &lrow {
        loss += lv;
    }
    (loss / denom, dl)
}

/// MSE over logits: (loss, d/d s_logits).
fn mse_loss(
    s_logits: &[f32],
    t_logits: &[f32],
    m: &[f32],
    rows: usize,
    v: usize,
) -> (f32, Vec<f32>) {
    let denom: f32 = m.iter().sum::<f32>() + 1e-6;
    let mut dl = vec![0f32; rows * v];
    let mut lrow = vec![0f32; rows];
    pool::for_chunks2(rows * v * 4, &mut dl, v, &mut lrow, 1, |i, dr, lv| {
        let mut se = 0f32;
        let c = m[i] / denom * 2.0 / v as f32;
        for j in 0..v {
            let diff = s_logits[i * v + j] - t_logits[i * v + j];
            se += diff * diff;
            dr[j] = diff * c;
        }
        lv[0] = se / v as f32 * m[i];
    });
    let mut loss = 0f32;
    for &lv in &lrow {
        loss += lv;
    }
    (loss / denom, dl)
}

/// REINFORCE: −mean_b(adv · seq_ll); (loss, dlogits). rows = b * sm.
fn reinforce_loss(
    logits: &[f32],
    lab: &[i32],
    m: &[f32],
    adv: &[f32],
    b: usize,
    sm: usize,
    v: usize,
) -> (f32, Vec<f32>) {
    let rows = b * sm;
    let lp = log_softmax_rows(logits, rows, v);
    let ids = clamp_ids(lab, v);
    let mut loss = 0f32;
    let mut dl = vec![0f32; rows * v];
    for bi in 0..b {
        let mut msum = 0f32;
        for si in 0..sm {
            msum += m[bi * sm + si];
        }
        let msum = msum + 1e-6;
        let mut seq_ll = 0f32;
        for si in 0..sm {
            let i = bi * sm + si;
            seq_ll += lp[i * v + ids[i]] * m[i];
        }
        seq_ll /= msum;
        loss -= adv[bi] * seq_ll / b as f32;
        let coef_b = -adv[bi] / b as f32 / msum;
        for si in 0..sm {
            let i = bi * sm + si;
            let c = coef_b * m[i];
            if c == 0.0 {
                continue;
            }
            let dr = &mut dl[i * v..(i + 1) * v];
            let lpr = &lp[i * v..(i + 1) * v];
            for j in 0..v {
                dr[j] = -c * lpr[j].exp();
            }
            dr[ids[i]] += c;
        }
    }
    (loss, dl)
}

// ----------------------------------------------------------------- stepping

/// Figure-2 "native quantized training" proxy: NVFP4 fake-quant of the flat
/// gradient vector (pad to a 16 multiple, quantize, unpad).
fn quantize_grads_nvfp4(g: &mut Vec<f32>) {
    let n = g.len();
    let padn = (16 - n % 16) % 16;
    let mut padded = std::mem::take(g);
    padded.resize(n + padn, 0.0);
    nvfp4::fake_quant_into(&padded, 1, n + padn, g);
    g.truncate(n);
}

/// One Adam step on the packed state vector (steps.adam_update).
fn adam_update(
    pcount: usize,
    state: &[f32],
    grads: &[f32],
    lr: f32,
    extra: &[(usize, f32)],
    n_scalars: usize,
) -> Result<Vec<f32>> {
    if state.len() != 3 * pcount + n_scalars {
        bail!("state len {} != 3*{pcount}+{n_scalars}", state.len());
    }
    if grads.len() != pcount {
        bail!("grads len {} != param_count {pcount}", grads.len());
    }
    let mut out = vec![0f32; state.len()];
    let sc_in = &state[3 * pcount..];
    let step = sc_in[scalar::STEP] + 1.0;
    let bc1 = 1.0 - ADAM_B1.powf(step);
    let bc2 = 1.0 - ADAM_B2.powf(step);
    // The grad-norm is an order-bearing reduction: keep the seed's single
    // ascending chain (serial — one cheap pass next to the update math).
    let mut gnorm_sq = 0f32;
    for &g in grads {
        gnorm_sq += g * g;
    }
    // The update itself is pure elementwise work: one chunk-parallel pass
    // for the (m, v) moments, then one for the parameters (identical op
    // sequences to the seed's fused loop, so bits are unchanged).
    let (pout, rest) = out.split_at_mut(pcount);
    let (mout, rest) = rest.split_at_mut(pcount);
    let (vout, sc) = rest.split_at_mut(pcount);
    pool::for_chunks2(pcount * 4, mout, EW_CHUNK, vout, EW_CHUNK, |ci, mc, vc| {
        let base = ci * EW_CHUNK;
        for j in 0..mc.len() {
            let g = grads[base + j];
            mc[j] = ADAM_B1 * state[pcount + base + j] + (1.0 - ADAM_B1) * g;
            vc[j] = ADAM_B2 * state[2 * pcount + base + j] + (1.0 - ADAM_B2) * g * g;
        }
    });
    {
        let mro: &[f32] = mout;
        let vro: &[f32] = vout;
        pool::for_chunks(pcount * 6, pout, EW_CHUNK, |ci, pc| {
            let base = ci * EW_CHUNK;
            for (j, p) in pc.iter_mut().enumerate() {
                let mhat = mro[base + j] / bc1;
                let vhat = vro[base + j] / bc2;
                *p = state[base + j] - lr * mhat / (vhat.sqrt() + ADAM_EPS);
            }
        });
    }
    sc.copy_from_slice(sc_in);
    sc[scalar::STEP] = step;
    sc[scalar::GRAD_NORM] = gnorm_sq.sqrt();
    sc[scalar::LR] = lr;
    for &(slot, val) in extra {
        if slot >= n_scalars {
            bail!("scalar slot {slot} out of range {n_scalars}");
        }
        sc[slot] = val;
    }
    Ok(out)
}

/// One training step: state -> state' (steps.make_*_step semantics).
#[allow(clippy::too_many_arguments)]
pub fn train_step(
    cfg: &RefCfg,
    teacher: Option<(&RefCfg, &[f32])>,
    loss_kind: &LossKind,
    quantize_grads: bool,
    state: &[f32],
    tokens: &[i32],
    mask: &[f32],
    b: usize,
    s: usize,
    lr: f32,
    adv: Option<&[f32]>,
    pixels: Option<&[f32]>,
    n_scalars: usize,
) -> Result<Vec<f32>> {
    let m = &cfg.model;
    let pcount = m.param_count;
    if s < 2 {
        bail!("seq_len {s} too short for next-token training");
    }
    if tokens.len() != b * s || mask.len() != b * s {
        bail!("batch shape mismatch: tokens {} mask {} vs {b}x{s}", tokens.len(), mask.len());
    }
    if state.len() != 3 * pcount + n_scalars {
        bail!("state len {} != 3*{pcount}+{n_scalars}", state.len());
    }
    let params = &state[..pcount];
    let (inp, lab, msk) = shift(tokens, mask, b, s);
    let sm = s - 1;
    let rows = b * sm;
    let v = m.vocab;

    let fwd = forward(cfg, params, &inp, b, sm, pixels)?;
    let (_loss, dlogits, extra): (f32, Vec<f32>, Vec<(usize, f32)>) = match loss_kind {
        LossKind::Ce => {
            let (l, dl) = ce_loss(&fwd.logits, &lab, &msk, rows, v);
            (l, dl, vec![(scalar::LOSS, l), (scalar::CE, l)])
        }
        LossKind::Kl => {
            let (tcfg, tparams) = teacher.context("KL distillation step needs teacher params")?;
            let tfwd = forward(tcfg, tparams, &inp, b, sm, pixels)?;
            if tfwd.logits.len() != fwd.logits.len() {
                bail!("teacher/student logits shapes differ");
            }
            let (l, dl) = kl_loss(&fwd.logits, &tfwd.logits, &msk, rows, v);
            (l, dl, vec![(scalar::LOSS, l), (scalar::KL, l)])
        }
        LossKind::Mse => {
            let (tcfg, tparams) = teacher.context("MSE distillation step needs teacher params")?;
            let tfwd = forward(tcfg, tparams, &inp, b, sm, pixels)?;
            if tfwd.logits.len() != fwd.logits.len() {
                bail!("teacher/student logits shapes differ");
            }
            let (l, dl) = mse_loss(&fwd.logits, &tfwd.logits, &msk, rows, v);
            (l, dl, vec![(scalar::LOSS, l)])
        }
        LossKind::Reinforce => {
            let adv = adv.context("REINFORCE step needs advantages")?;
            if adv.len() != b {
                bail!("advantage len {} != batch {b}", adv.len());
            }
            let (l, dl) = reinforce_loss(&fwd.logits, &lab, &msk, adv, b, sm, v);
            (l, dl, vec![(scalar::LOSS, l)])
        }
    };
    let mut grads = fwd.backward(cfg, params, &dlogits)?;
    if quantize_grads {
        quantize_grads_nvfp4(&mut grads);
    }
    adam_update(pcount, state, &grads, lr, &extra, n_scalars)
}

/// Eval metrics (steps.make_eval_metrics):
/// [kl_mean, ce_mean, n, kl_sum, ce_sum, 0, 0, 0].
#[allow(clippy::too_many_arguments)]
pub fn eval_metrics(
    student: &RefCfg,
    s_params: &[f32],
    teacher: &RefCfg,
    t_params: &[f32],
    tokens: &[i32],
    mask: &[f32],
    b: usize,
    s: usize,
    pixels: Option<&[f32]>,
    n_scalars: usize,
) -> Result<Vec<f32>> {
    if s < 2 {
        bail!("seq_len {s} too short for eval");
    }
    let (inp, lab, msk) = shift(tokens, mask, b, s);
    let sm = s - 1;
    let v = student.model.vocab;
    let rows = b * sm;
    let s_logits = forward(student, s_params, &inp, b, sm, pixels)?.logits;
    let t_logits = forward(teacher, t_params, &inp, b, sm, pixels)?.logits;
    if t_logits.len() != s_logits.len() {
        bail!("teacher/student logits shapes differ");
    }
    let ls = log_softmax_rows(&s_logits, rows, v);
    let lt = log_softmax_rows(&t_logits, rows, v);
    let ids = clamp_ids(&lab, v);
    // Per-row KL/CE terms in parallel; the running sums then reduce over
    // rows in ascending order — the seed's exact chains.
    let mut klrow = vec![0f32; rows];
    let mut cerow = vec![0f32; rows];
    {
        let ids = &ids;
        pool::for_chunks2(rows * v * 4, &mut klrow, 1, &mut cerow, 1, |i, kv, cv| {
            let mut kl = 0f32;
            for j in 0..v {
                let pt = lt[i * v + j].exp();
                kl += pt * (lt[i * v + j] - ls[i * v + j]);
            }
            kv[0] = kl * msk[i];
            cv[0] = ls[i * v + ids[i]] * msk[i];
        });
    }
    let mut n = 0f32;
    let mut kl_sum = 0f32;
    let mut ce_sum = 0f32;
    for i in 0..rows {
        n += msk[i];
        kl_sum += klrow[i];
        ce_sum -= cerow[i];
    }
    if n_scalars < 5 {
        bail!("eval metrics need n_scalars >= 5, manifest says {n_scalars}");
    }
    let denom = n + 1e-6;
    let mut out = vec![0f32; n_scalars];
    out[0] = kl_sum / denom;
    out[1] = ce_sum / denom;
    out[2] = n;
    out[3] = kl_sum;
    out[4] = ce_sum;
    Ok(out)
}

/// Plain forward logits (B, S, V).
pub fn fwd_logits(
    cfg: &RefCfg,
    params: &[f32],
    tokens: &[i32],
    b: usize,
    s: usize,
    pixels: Option<&[f32]>,
) -> Result<Vec<f32>> {
    Ok(forward(cfg, params, tokens, b, s, pixels)?.logits)
}

/// Fused forward + per-row frontier gather: (B, V) logits rows at `idx`.
pub fn fwd_last(
    cfg: &RefCfg,
    params: &[f32],
    tokens: &[i32],
    idx: &[i32],
    b: usize,
    s: usize,
    pixels: Option<&[f32]>,
) -> Result<Vec<f32>> {
    if idx.len() != b {
        bail!("frontier idx len {} != batch {b}", idx.len());
    }
    let logits = fwd_logits(cfg, params, tokens, b, s, pixels)?;
    let v = cfg.model.vocab;
    let mut out = vec![0f32; b * v];
    // batch-row parallel frontier gather
    pool::for_chunks(b * v, &mut out, v, |bi, orow| {
        // clamp like an XLA dynamic-slice gather
        let p = (idx[bi].max(0) as usize).min(s - 1);
        orow.copy_from_slice(&logits[(bi * s + p) * v..(bi * s + p + 1) * v]);
    });
    Ok(out)
}

// ------------------------------------------------------ incremental decode
//
// The stateful prefill/step path behind the reference backend's
// `DecodeSession` capability: one prefill builds the per-layer decode
// state (attention K/V rows, the SSM scan carry) by harvesting a normal
// `forward` pass over the prompt; each step then runs every layer at a
// single position against that state — O(frontier) per token instead of a
// full (B, S) forward.
//
// Bit-identity contract: every f32 op chain below is the corresponding
// per-row chain of `forward` (same expressions, same ascending
// contraction/position orders), and masked-out attention columns in the
// full pass contribute exactly 0.0 to its softmax sums, so step logits
// are bit-identical to the full forward's frontier rows (asserted by the
// tests at the bottom of this file and rust/tests/decode_equivalence.rs).
// Rows never interact, so a scheduler can admit a new row mid-generation
// without disturbing in-flight ones.

/// One cached K or V position sequence: the dense `seq_len`-capacity
/// buffer (PR 5 layout) or fixed-size pages from the context's shared
/// [`PagePool`]. Both expose identical `d`-float position rows, so every
/// downstream f32 chain is layout-independent (bit-identical logits).
enum KvSeq {
    Dense(Vec<f32>),
    Paged(PagedKv),
}

/// Append one `d`-float position row at the sequence frontier.
fn kv_push(seq: &mut KvSeq, pool: &mut PagePool, rowd: &[f32]) -> Result<()> {
    match seq {
        KvSeq::Dense(buf) => {
            buf.extend_from_slice(rowd);
            Ok(())
        }
        KvSeq::Paged(p) => p.push(pool, rowd),
    }
}

/// The `d` floats of position `j` — exactly the slice the dense layout
/// holds at `j * d`, whichever layout backs the sequence.
fn kv_row<'a>(seq: &'a KvSeq, pool: &'a PagePool, j: usize, d: usize) -> &'a [f32] {
    match seq {
        KvSeq::Dense(buf) => &buf[j * d..(j + 1) * d],
        KvSeq::Paged(p) => p.row(pool, j),
    }
}

/// Reset a sequence to empty, returning any pages to the pool.
fn kv_clear(seq: &mut KvSeq, pool: &mut PagePool) {
    match seq {
        KvSeq::Dense(buf) => buf.clear(),
        KvSeq::Paged(p) => p.clear(pool),
    }
}

/// Replace a sequence's contents with `src` (`len * d` floats, position
/// rows in ascending order) — the prefill harvest.
fn kv_fill(seq: &mut KvSeq, pool: &mut PagePool, src: &[f32], d: usize) -> Result<()> {
    match seq {
        KvSeq::Dense(buf) => {
            buf.clear();
            buf.extend_from_slice(src);
            Ok(())
        }
        KvSeq::Paged(p) => {
            p.clear(pool);
            for chunk in src.chunks_exact(d) {
                p.push(pool, chunk)?;
            }
            Ok(())
        }
    }
}

/// Per-layer decode state of one row.
enum RowBlockState {
    /// Cached post-GEMM K/V rows, `t * d` valid floats each.
    Attn { k: KvSeq, v: KvSeq },
    /// The scan carry h_{t-1}, `d` floats.
    Ssm { h: Vec<f32> },
    /// MoE blocks are position-local: nothing to carry.
    Moe,
}

/// One row's incremental decode state (see [`DecodeCtx`]).
pub struct DecodeRow {
    blocks: Vec<RowBlockState>,
    t: usize,
}

impl DecodeRow {
    /// Positions consumed so far.
    pub fn len(&self) -> usize {
        self.t
    }

    pub fn is_empty(&self) -> bool {
        self.t == 0
    }
}

/// Reusable per-step scratch (no allocation on the step hot path).
#[derive(Default)]
struct StepScratch {
    x: Vec<f32>,
    x1: Vec<f32>,
    y: Vec<f32>,
    xq: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    att: Vec<f32>,
    o: Vec<f32>,
    z3: Vec<f32>,
    h1: Vec<f32>,
    h1g: Vec<f32>,
    tmp: Vec<f32>,
    probs: Vec<f32>,
    gate: Vec<f32>,
    gaten: Vec<f32>,
    moe_out: Vec<f32>,
}

/// One pre-resolved GEMM weight on the step path: a fake-quantized copy
/// for quantized blocks (exactly what `Gemm::forward` recomputes on
/// every call), the packed quantized-domain tensor on the packed kernel
/// tier, or the raw parameter range.
enum StepWeight {
    Quantized(Vec<f32>),
    Packed(PackedWeight),
    Raw(Range<usize>),
}

impl StepWeight {
    fn slice<'a>(&'a self, params: &'a [f32]) -> &'a [f32] {
        match self {
            StepWeight::Quantized(v) => v,
            // Packed weights never hand out f32 rows; every step call
            // site dispatches through `step_gemm_w`, which routes this
            // variant to the LUT kernel. The empty slice trips the
            // `step_gemm` length check loudly if a call site forgets.
            StepWeight::Packed(_) => &[],
            StepWeight::Raw(r) => &params[r.clone()],
        }
    }

    /// Bytes of weight storage the step path reads through this binding.
    /// Raw ranges alias the params vector and count 0 extra.
    fn bytes(&self) -> usize {
        match self {
            StepWeight::Quantized(v) => v.len() * 4,
            StepWeight::Packed(pw) => pw.storage_bytes(),
            StepWeight::Raw(_) => 0,
        }
    }
}

/// Per-block weights resolved once at bind time, so the step hot path
/// does no name formatting, no map lookups, no layout searches.
enum BlockWeights {
    Attn {
        ln1: Range<usize>,
        wq: StepWeight,
        wk: StepWeight,
        wv: StepWeight,
        wo: StepWeight,
        ln2: Range<usize>,
        w1: StepWeight,
        w2: StepWeight,
    },
    Ssm {
        ln: Range<usize>,
        win: StepWeight,
        a_bias: Range<usize>,
        wout: StepWeight,
    },
    Moe {
        ln: Range<usize>,
        router: Range<usize>,
        /// (w1, w2) per expert, ascending expert order.
        experts: Vec<(StepWeight, StepWeight)>,
    },
}

/// One cached block state snapshotted at a prompt boundary: attention
/// K/V as refcounted page forks, the SSM carry by value.
enum CachedBlock {
    Attn { k: PagedKv, v: PagedKv },
    Ssm { h: Vec<f32> },
    Moe,
}

/// One prefix-cache entry: the full per-layer decode state after
/// prefilling `tokens`, plus the logits row that prefill produced (so an
/// exact hit answers without touching the model at all).
struct PrefixEntry {
    tokens: Vec<i32>,
    blocks: Vec<CachedBlock>,
    logits: Vec<f32>,
    /// Logical LRU clock (no wall time — eviction stays deterministic).
    tick: u64,
}

/// Shared-prompt-prefix cache over paged decode state. Lookup scans for
/// the longest entry whose tokens are an elementwise prefix of the
/// prompt; a hit donates its pages by refcount (copy-on-write protects
/// the entry when the borrowing row diverges). Eviction is
/// least-recently-used on a logical tick, oldest entry first.
struct PrefixCache {
    cap: usize,
    entries: Vec<PrefixEntry>,
    tick: u64,
    hits: u64,
    misses: u64,
}

impl PrefixCache {
    fn new(cap: usize) -> PrefixCache {
        PrefixCache { cap: cap.max(1), entries: Vec::new(), tick: 0, hits: 0, misses: 0 }
    }

    /// Longest cached prefix of `prompt`: `(entry index, matched len)`.
    /// Counts a hit/miss and touches the winner's LRU tick.
    fn lookup(&mut self, prompt: &[i32]) -> Option<(usize, usize)> {
        let mut best: Option<(usize, usize)> = None;
        for (i, e) in self.entries.iter().enumerate() {
            let n = e.tokens.len();
            if n > prompt.len() || !prompt.starts_with(&e.tokens) {
                continue;
            }
            let better = match best {
                Some((_, bl)) => n > bl,
                None => true,
            };
            if better {
                best = Some((i, n));
            }
        }
        match best {
            Some((i, n)) => {
                self.tick += 1;
                if let Some(e) = self.entries.get_mut(i) {
                    e.tick = self.tick;
                }
                self.hits += 1;
                Some((i, n))
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    /// Copy entry `idx`'s state into `row` (pages by refcounted fork, the
    /// SSM carry by value) and its stored logits into `logits`.
    fn fork_into(
        &self,
        idx: usize,
        pool: &mut PagePool,
        row: &mut DecodeRow,
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let Some(e) = self.entries.get(idx) else {
            bail!("prefix entry {idx} out of range ({} entries)", self.entries.len());
        };
        if e.blocks.len() != row.blocks.len() {
            bail!("prefix entry block count {} != row {}", e.blocks.len(), row.blocks.len());
        }
        for (bs, cb) in row.blocks.iter_mut().zip(&e.blocks) {
            match (bs, cb) {
                (RowBlockState::Attn { k, v }, CachedBlock::Attn { k: ck, v: cv }) => {
                    *k = KvSeq::Paged(ck.fork(pool, ck.len()));
                    *v = KvSeq::Paged(cv.fork(pool, cv.len()));
                }
                (RowBlockState::Ssm { h }, CachedBlock::Ssm { h: ch }) => {
                    h.copy_from_slice(ch);
                }
                (RowBlockState::Moe, CachedBlock::Moe) => {}
                _ => bail!("prefix entry block kinds diverged from the row"),
            }
        }
        row.t = e.tokens.len();
        logits.clear();
        logits.extend_from_slice(&e.logits);
        Ok(())
    }

    /// Snapshot `row` (which must hold exactly the state after prefilling
    /// `tokens`) as a new entry, then trim to capacity. A duplicate-token
    /// entry is touched instead of re-inserted.
    fn insert(&mut self, pool: &mut PagePool, row: &DecodeRow, tokens: &[i32], logits: &[f32]) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.entries.iter_mut().find(|e| e.tokens == tokens) {
            e.tick = tick;
            return;
        }
        let mut blocks = Vec::with_capacity(row.blocks.len());
        for bs in &row.blocks {
            let cb = match bs {
                RowBlockState::Attn { k: KvSeq::Paged(pk), v: KvSeq::Paged(pv) } => {
                    CachedBlock::Attn {
                        k: pk.fork(pool, pk.len()),
                        v: pv.fork(pool, pv.len()),
                    }
                }
                // dense rows cannot donate pages; skip caching entirely
                RowBlockState::Attn { .. } => return,
                RowBlockState::Ssm { h } => CachedBlock::Ssm { h: h.clone() },
                RowBlockState::Moe => CachedBlock::Moe,
            };
            blocks.push(cb);
        }
        self.entries.push(PrefixEntry {
            tokens: tokens.to_vec(),
            blocks,
            logits: logits.to_vec(),
            tick,
        });
        while self.entries.len() > self.cap {
            if !self.evict_lru(pool) {
                break;
            }
        }
    }

    /// Evict the least-recently-used entry, releasing its page
    /// references. Returns false when the cache is already empty.
    fn evict_lru(&mut self, pool: &mut PagePool) -> bool {
        let mut victim: Option<(usize, u64)> = None;
        for (i, e) in self.entries.iter().enumerate() {
            let older = match victim {
                Some((_, vt)) => e.tick < vt,
                None => true,
            };
            if older {
                victim = Some((i, e.tick));
            }
        }
        let Some((i, _)) = victim else { return false };
        let mut e = self.entries.remove(i);
        for cb in e.blocks.iter_mut() {
            if let CachedBlock::Attn { k, v } = cb {
                k.clear(pool);
                v.clear(pool);
            }
        }
        true
    }

    /// Drop every entry (drain/shutdown): all page references released.
    fn clear(&mut self, pool: &mut PagePool) {
        while self.evict_lru(pool) {}
    }
}

/// Weights bound for incremental decode: the raw parameter snapshot plus
/// per-block pre-resolved weight slices, with every quantized-GEMM
/// weight resolved once up front — fake-quantized f32 copies on the
/// exact tier, packed nibble tensors on the packed tier (the full
/// forward re-quantizes weights on every call; a per-token
/// re-quantization would dwarf the O(frontier) step itself). Immutable
/// after binding, so sessions share one binding via `Rc` instead of
/// re-quantizing per `generate` call.
pub struct BoundWeights {
    params: Vec<f32>,
    embed: Range<usize>,
    pos_emb: Range<usize>,
    ln_f: Range<usize>,
    head: StepWeight,
    /// (block quantized?, resolved weights), one per model block.
    blocks: Vec<(bool, BlockWeights)>,
    /// Attention blocks in `blocks` (page-headroom accounting).
    attn_blocks: usize,
    /// Kernel tier the weights were resolved for.
    kernel: KernelTier,
    /// Bytes of bound weight storage the step path reads per token.
    weight_bytes: usize,
}

impl BoundWeights {
    /// Bytes of bound weight storage the step path reads per token
    /// (f32 copies on the exact tier, packed nibbles + scales on the
    /// packed tier; raw ranges alias `params` and count 0).
    pub fn weight_bytes(&self) -> usize {
        self.weight_bytes
    }

    /// Resolve every decode weight of `cfg.model` inside `params`:
    /// rejects vision models (the stateless path handles pixels) and
    /// pre-quantizes every GEMM weight of the quantized blocks along its
    /// contraction axis — identical to what `Gemm::forward` computes per
    /// call on the exact tier, the packed quantized-domain layout on the
    /// packed tier.
    pub fn bind(cfg: &RefCfg, params: Vec<f32>) -> Result<BoundWeights> {
        let m = &cfg.model;
        if m.vision {
            bail!("incremental decode does not cover vision models");
        }
        if params.len() != m.param_count {
            bail!("params len {} != param_count {}", params.len(), m.param_count);
        }
        if m.d_model == 0 || m.n_heads == 0 || m.d_model % m.n_heads != 0 {
            bail!("model {}: d_model {} not divisible by n_heads {}", m.name, m.d_model, m.n_heads);
        }
        let d = m.d_model;
        let ff = m.d_ff;
        let fmt = cfg.weights_fmt;
        let packed_fmt = if cfg.packed_weights() { Some(cfg.packed_format()?) } else { None };
        // Resolve a parameter's range in the flat vector (bounds-checked
        // once here; the step path then indexes directly).
        let prange = |name: &str| -> Result<Range<usize>> {
            let def = cfg.pdef(name)?;
            if def.offset + def.size > params.len() {
                bail!(
                    "parameter {name:?} [{}..{}] out of range of params len {}",
                    def.offset,
                    def.offset + def.size,
                    params.len()
                );
            }
            Ok(def.offset..def.offset + def.size)
        };
        // Resolve one GEMM weight range: a packed quantized-domain tensor
        // on the packed tier, a pre-fake-quantized f32 copy on the exact
        // tier, the raw range for unquantized blocks.
        let resolve = |r: Range<usize>, k: usize, n: usize, quant: bool| -> Result<StepWeight> {
            if !quant {
                return Ok(StepWeight::Raw(r));
            }
            if let Some(pf) = packed_fmt {
                return Ok(StepWeight::Packed(PackedWeight::pack(&params[r], k, n, pf)?));
            }
            let mut out = Vec::with_capacity(k * n);
            quant_weight_into(&params[r], k, n, fmt, &mut out)?;
            Ok(StepWeight::Quantized(out))
        };
        let wres = |name: &str, k: usize, n: usize, quant: bool| -> Result<StepWeight> {
            let r = prange(name)?;
            if r.end - r.start != k * n {
                bail!("weight {name:?} has {} floats, expected {k}x{n}", r.end - r.start);
            }
            resolve(r, k, n, quant)
        };
        let mut blocks = Vec::with_capacity(m.blocks.len());
        for (i, kind) in m.blocks.iter().enumerate() {
            let quant = cfg.block_quantized(i, kind);
            let pre = format!("b{i}.");
            let bw = match kind.as_str() {
                "attn" => BlockWeights::Attn {
                    ln1: prange(&format!("{pre}ln1"))?,
                    wq: wres(&format!("{pre}wq"), d, d, quant)?,
                    wk: wres(&format!("{pre}wk"), d, d, quant)?,
                    wv: wres(&format!("{pre}wv"), d, d, quant)?,
                    wo: wres(&format!("{pre}wo"), d, d, quant)?,
                    ln2: prange(&format!("{pre}ln2"))?,
                    w1: wres(&format!("{pre}w1"), d, ff, quant)?,
                    w2: wres(&format!("{pre}w2"), ff, d, quant)?,
                },
                "ssm" => BlockWeights::Ssm {
                    ln: prange(&format!("{pre}ln"))?,
                    win: wres(&format!("{pre}win"), d, 3 * d, quant)?,
                    a_bias: prange(&format!("{pre}a_bias"))?,
                    wout: wres(&format!("{pre}wout"), d, d, quant)?,
                },
                "moe" => {
                    let e = cfg.n_experts()?;
                    if e < 2 {
                        bail!("moe block needs n_experts >= 2, got {e}");
                    }
                    let router = prange(&format!("{pre}router"))?;
                    if router.end - router.start != d * e {
                        bail!("router size {} != d*E {}", router.end - router.start, d * e);
                    }
                    let w1 = prange(&format!("{pre}w1"))?;
                    let w2 = prange(&format!("{pre}w2"))?;
                    if w1.end - w1.start != e * d * ff || w2.end - w2.start != e * ff * d {
                        bail!("moe expert weights have unexpected sizes");
                    }
                    let mut experts = Vec::with_capacity(e);
                    for ei in 0..e {
                        let r1 = w1.start + ei * d * ff..w1.start + (ei + 1) * d * ff;
                        let r2 = w2.start + ei * ff * d..w2.start + (ei + 1) * ff * d;
                        experts.push((resolve(r1, d, ff, quant)?, resolve(r2, ff, d, quant)?));
                    }
                    BlockWeights::Moe { ln: prange(&format!("{pre}ln"))?, router, experts }
                }
                other => bail!("unknown block kind {other:?} in model {}", m.name),
            };
            blocks.push((quant, bw));
        }
        let embed = prange("embed")?;
        if embed.end - embed.start != m.vocab * d {
            bail!("embed param size {} != vocab*d {}", embed.end - embed.start, m.vocab * d);
        }
        let pos_emb = prange("pos_emb")?;
        let ln_f = prange("ln_f")?;
        let head = wres("head", d, m.vocab, cfg.head_quantized())?;
        let attn_blocks =
            blocks.iter().filter(|(_, bw)| matches!(bw, BlockWeights::Attn { .. })).count();
        let mut weight_bytes = head.bytes();
        for (_, bw) in &blocks {
            weight_bytes += match bw {
                BlockWeights::Attn { wq, wk, wv, wo, w1, w2, .. } => {
                    wq.bytes() + wk.bytes() + wv.bytes() + wo.bytes() + w1.bytes() + w2.bytes()
                }
                BlockWeights::Ssm { win, wout, .. } => win.bytes() + wout.bytes(),
                BlockWeights::Moe { experts, .. } => {
                    experts.iter().map(|(a, b)| a.bytes() + b.bytes()).sum()
                }
            };
        }
        Ok(BoundWeights {
            params,
            embed,
            pos_emb,
            ln_f,
            head,
            blocks,
            attn_blocks,
            kernel: cfg.kernel,
            weight_bytes,
        })
    }
}

/// One incremental-decode session binding: shared bound weights plus the
/// mutable per-session state (step scratch, page slab, prefix cache).
pub struct DecodeCtx {
    cfg: RefCfg,
    bound: Rc<BoundWeights>,
    scratch: StepScratch,
    opts: DecodeOpts,
    /// Shared page slab for paged rows + cached prefixes (idle in dense
    /// mode).
    page_pool: PagePool,
    prefix: Option<PrefixCache>,
}

impl DecodeCtx {
    /// Bind `params` for decode under `cfg` with the default dense state
    /// layout (see [`DecodeCtx::with_opts`]).
    pub fn new(cfg: RefCfg, params: Vec<f32>) -> Result<DecodeCtx> {
        DecodeCtx::with_opts(cfg, params, DecodeOpts::default())
    }

    /// Bind `params` for decode under `cfg` ([`BoundWeights::bind`]).
    /// `opts` selects dense rows (`page_size == 0`) or paged state with
    /// an optional prefix cache and page budget.
    pub fn with_opts(cfg: RefCfg, params: Vec<f32>, opts: DecodeOpts) -> Result<DecodeCtx> {
        let bound = Rc::new(BoundWeights::bind(&cfg, params)?);
        DecodeCtx::with_bound(cfg, bound, opts)
    }

    /// Open a decode session over pre-bound (possibly shared) weights —
    /// the expensive quantize/pack work happens once in
    /// [`BoundWeights::bind`]; sessions over the same snapshot reuse it.
    /// `bound` must come from an equivalent `cfg` (same formats and
    /// kernel tier; the tier is re-checked because it selects the
    /// prefill path).
    pub fn with_bound(cfg: RefCfg, bound: Rc<BoundWeights>, opts: DecodeOpts) -> Result<DecodeCtx> {
        let m = &cfg.model;
        if opts.page_size == 0 && (opts.prefix_cache > 0 || opts.max_pages > 0) {
            bail!(
                "prefix_cache ({}) and max_pages ({}) require paged decode state (page_size > 0)",
                opts.prefix_cache,
                opts.max_pages
            );
        }
        if bound.kernel != cfg.kernel {
            bail!("bound weights are {} tier, session wants {}", bound.kernel, cfg.kernel);
        }
        if bound.params.len() != m.param_count {
            bail!("bound params len {} != param_count {}", bound.params.len(), m.param_count);
        }
        let page_pool = PagePool::new(opts.page_size.max(1), m.d_model, opts.max_pages);
        let prefix =
            if opts.prefix_cache > 0 { Some(PrefixCache::new(opts.prefix_cache)) } else { None };
        Ok(DecodeCtx { cfg, bound, scratch: StepScratch::default(), opts, page_pool, prefix })
    }

    pub fn model(&self) -> &ModelEntry {
        &self.cfg.model
    }

    /// A fresh (empty) row for this model's block stack. Dense rows
    /// reserve `seq_len × d` per K/V sequence up front; paged rows own
    /// nothing until tokens arrive (memory follows live tokens).
    pub fn new_row(&self) -> DecodeRow {
        let m = &self.cfg.model;
        let d = m.d_model;
        let cap = m.seq_len * d;
        let paged = self.opts.page_size > 0;
        let kv = |paged: bool| {
            if paged {
                KvSeq::Paged(PagedKv::default())
            } else {
                KvSeq::Dense(Vec::with_capacity(cap))
            }
        };
        let blocks = self
            .bound
            .blocks
            .iter()
            .map(|(_, bw)| match bw {
                BlockWeights::Attn { .. } => RowBlockState::Attn { k: kv(paged), v: kv(paged) },
                BlockWeights::Ssm { .. } => RowBlockState::Ssm { h: vec![0f32; d] },
                BlockWeights::Moe { .. } => RowBlockState::Moe,
            })
            .collect();
        DecodeRow { blocks, t: 0 }
    }

    /// Return `row`'s pages to the pool and reset it to empty (dense rows
    /// truncate in place; the SSM carry is re-zeroed either way).
    pub fn release_row(&mut self, row: &mut DecodeRow) {
        for bs in row.blocks.iter_mut() {
            match bs {
                RowBlockState::Attn { k, v } => {
                    kv_clear(k, &mut self.page_pool);
                    kv_clear(v, &mut self.page_pool);
                }
                RowBlockState::Ssm { h } => {
                    for x in h.iter_mut() {
                        *x = 0.0;
                    }
                }
                RowBlockState::Moe => {}
            }
        }
        row.t = 0;
    }

    /// Allocator/prefix-cache gauges (`None` in dense mode).
    pub fn paged_stats(&self) -> Option<PagedStats> {
        if self.opts.page_size == 0 {
            return None;
        }
        let mut st = PagedStats {
            page_size: self.opts.page_size,
            live_pages: self.page_pool.live_pages(),
            free_pages: self.page_pool.free_pages(),
            cow_copies: self.page_pool.cow_copies(),
            decode_weight_bytes: self.bound.weight_bytes,
            ..PagedStats::default()
        };
        if let Some(pc) = self.prefix.as_ref() {
            st.prefix_entries = pc.entries.len();
            st.prefix_hits = pc.hits;
            st.prefix_misses = pc.misses;
        }
        Some(st)
    }

    /// Bytes of bound weight storage the step path reads per token
    /// (valid in dense and paged mode alike).
    pub fn decode_weight_bytes(&self) -> usize {
        self.bound.weight_bytes
    }

    /// Make at least `need` pages allocatable, evicting LRU prefix
    /// entries when the budget is tight. Errors cleanly (one request
    /// degrades; the session stays usable) only when even an empty cache
    /// cannot satisfy the request.
    fn ensure_pages(&mut self, need: usize) -> Result<()> {
        let DecodeCtx { page_pool, prefix, .. } = self;
        loop {
            if page_pool.available() >= need {
                return Ok(());
            }
            let evicted = match prefix.as_mut() {
                Some(pc) => pc.evict_lru(page_pool),
                None => false,
            };
            if !evicted {
                bail!(
                    "decode page budget exhausted (need {need} pages, {} available of max {})",
                    page_pool.available(),
                    page_pool.max_pages()
                );
            }
        }
    }

    /// Reset `row` to `prompt` and write the logits row predicting the
    /// next token. Cold path: one normal `forward` over the prompt,
    /// harvesting its caches into the row state (K/V rows come straight
    /// from the forward's per-position GEMM outputs; the scan carry is
    /// the last scan state), so prefill logits are the full forward's by
    /// construction. With a prefix cache, a prompt sharing a cached
    /// prefix instead forks the prefilled pages (refcounted,
    /// copy-on-write on divergence) and replays only the suffix through
    /// the step path — bit-identical to cold by the step==full contract;
    /// an exact hit returns the stored logits without touching the model.
    pub fn prefill(
        &mut self,
        row: &mut DecodeRow,
        prompt: &[i32],
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let m = &self.cfg.model;
        let (d, v, s) = (m.d_model, m.vocab, m.seq_len);
        if prompt.is_empty() || prompt.len() > s {
            bail!("prefill needs 1..={s} prompt tokens, got {}", prompt.len());
        }
        if row.blocks.len() != self.bound.blocks.len() {
            bail!(
                "decode row block count {} != model {}",
                row.blocks.len(),
                self.bound.blocks.len()
            );
        }
        let l = prompt.len();
        self.release_row(row);
        if self.opts.page_size > 0 {
            // Worst case: K and V per attention block need ceil(l/psz)
            // fresh pages each, plus one COW apiece after a partial hit.
            let per_seq = l.div_ceil(self.opts.page_size) + 1;
            self.ensure_pages(2 * self.bound.attn_blocks * per_seq)?;
        }
        let hit = match self.prefix.as_mut() {
            Some(pc) => pc.lookup(prompt),
            None => None,
        };
        if let Some((idx, plen)) = hit {
            {
                let DecodeCtx { page_pool, prefix, .. } = &mut *self;
                let Some(pc) = prefix.as_ref() else {
                    bail!("prefix cache disappeared mid-prefill");
                };
                pc.fork_into(idx, page_pool, row, logits)?;
            }
            if plen < l {
                // Partial hit: replay the unmatched suffix one position
                // at a time. The final replayed step writes exactly the
                // cold-prefill logits (step == full forward).
                for &tk in &prompt[plen..] {
                    self.step_unchecked(row, tk, logits)?;
                }
                self.prefix_insert(row, prompt, logits);
            }
            return Ok(());
        }
        if self.cfg.packed_weights() {
            // Packed tier: cold prefill replays the prompt through the
            // step path, so the only GEMM kernel a packed session ever
            // runs is the quantized-domain one — the stateless forward
            // below would re-materialize fake-quantized f32 weights per
            // call, exactly the traffic this tier removes. Prefill ==
            // stepping then holds by construction.
            for &tk in prompt {
                self.step_unchecked(row, tk, logits)?;
            }
            self.prefix_insert(row, prompt, logits);
            return Ok(());
        }
        let fwd = forward(&self.cfg, &self.bound.params, prompt, 1, l, None)?;
        if row.blocks.len() != fwd.caches.len() {
            bail!("decode row block count {} != model {}", row.blocks.len(), fwd.caches.len());
        }
        let pool = &mut self.page_pool;
        for (bs, cache) in row.blocks.iter_mut().zip(&fwd.caches) {
            match (bs, cache) {
                (RowBlockState::Attn { k, v }, BlockCache::Attn { gk, gv, .. }) => {
                    kv_fill(k, pool, &gk.out, d)?;
                    kv_fill(v, pool, &gv.out, d)?;
                }
                (RowBlockState::Ssm { h }, BlockCache::Ssm { h: hs, .. }) => {
                    h.copy_from_slice(&hs[(l - 1) * d..l * d]);
                }
                (RowBlockState::Moe, BlockCache::Moe { .. }) => {}
                _ => bail!("decode row block kinds diverged from the model"),
            }
        }
        row.t = l;
        logits.clear();
        logits.extend_from_slice(&fwd.logits[(l - 1) * v..l * v]);
        self.prefix_insert(row, prompt, logits);
        Ok(())
    }

    /// Cache `row`'s post-prefill state for `prompt` (no-op without a
    /// prefix cache). Forking only retains pages, so this never
    /// allocates and cannot fail.
    fn prefix_insert(&mut self, row: &DecodeRow, prompt: &[i32], logits: &[f32]) {
        let DecodeCtx { page_pool, prefix, .. } = self;
        if let Some(pc) = prefix.as_mut() {
            pc.insert(page_pool, row, prompt, logits);
        }
    }

    /// Append `token` at the row frontier and write the next logits row.
    pub fn step(&mut self, row: &mut DecodeRow, token: i32, logits: &mut Vec<f32>) -> Result<()> {
        if self.opts.page_size > 0 {
            // One alloc (fresh page or COW) max per K/V push.
            self.ensure_pages(2 * self.bound.attn_blocks)?;
        }
        self.step_unchecked(row, token, logits)
    }

    /// [`DecodeCtx::step`] without the page-headroom check (replay loops
    /// reserve their pages once up front).
    fn step_unchecked(
        &mut self,
        row: &mut DecodeRow,
        token: i32,
        logits: &mut Vec<f32>,
    ) -> Result<()> {
        let DecodeCtx { cfg, bound, scratch, page_pool, .. } = self;
        let bw = bound.as_ref();
        step_position(
            cfg,
            &bw.params,
            bw.embed.clone(),
            bw.pos_emb.clone(),
            bw.ln_f.clone(),
            &bw.head,
            &bw.blocks,
            scratch,
            page_pool,
            row,
            token,
            logits,
        )
    }
}

/// One single-row GEMM on the step path: fake-quantize the activation row
/// when the block is quantized, multiply against the (pre-quantized)
/// weight via the shared blocked kernel — per-element chains are
/// `matmul`'s (ascending contraction order), so bits match the full pass.
fn step_gemm(
    x: &[f32],
    w: &[f32],
    k: usize,
    n: usize,
    quant: bool,
    acts_fmt: Format,
    xq: &mut Vec<f32>,
    out: &mut Vec<f32>,
) -> Result<()> {
    if w.len() != k * n {
        bail!("step gemm weight len {} != {k}x{n}", w.len());
    }
    let xrow: &[f32] = if quant {
        quant_acts_into(x, 1, k, acts_fmt, xq)?;
        xq
    } else {
        x
    };
    out.clear();
    out.resize(n, 0.0);
    matmul_into(xrow, w, out, 1, k, n);
    Ok(())
}

/// [`step_gemm`] dispatched over the bound weight representation: packed
/// weights run the quantized-domain LUT kernel straight off the nibble
/// planes (no f32 weight row is ever materialized); the other variants
/// take the f32 slice path above.
#[allow(clippy::too_many_arguments)]
fn step_gemm_w(
    x: &[f32],
    w: &StepWeight,
    params: &[f32],
    k: usize,
    n: usize,
    quant: bool,
    acts_fmt: Format,
    xq: &mut Vec<f32>,
    out: &mut Vec<f32>,
) -> Result<()> {
    let StepWeight::Packed(pw) = w else {
        return step_gemm(x, w.slice(params), k, n, quant, acts_fmt, xq, out);
    };
    let xrow: &[f32] = if quant {
        quant_acts_into(x, 1, k, acts_fmt, xq)?;
        xq
    } else {
        x
    };
    out.clear();
    out.resize(n, 0.0);
    pw.matvec_into(xrow, out)
}

/// rmsnorm of one row (the `rmsnorm_fwd` per-row chain).
fn step_rmsnorm(x: &[f32], scale: &[f32], out: &mut Vec<f32>) {
    let d = x.len();
    out.clear();
    out.resize(d, 0.0);
    let mut ms = 0f32;
    for &v in x {
        ms += v * v;
    }
    let r = 1.0 / (ms / d as f32 + RMS_EPS).sqrt();
    for j in 0..d {
        out[j] = x[j] * r * scale[j];
    }
}

/// tanh-approximate gelu of one row (the `gelu_fwd` per-element chain).
fn step_gelu(x: &[f32], out: &mut Vec<f32>) {
    out.clear();
    out.resize(x.len(), 0.0);
    for (j, &v) in x.iter().enumerate() {
        let t = (SQRT_2_OVER_PI * (v + 0.044715 * v * v * v)).tanh();
        out[j] = 0.5 * v * (1.0 + t);
    }
}

#[allow(clippy::too_many_arguments)]
fn step_position(
    cfg: &RefCfg,
    params: &[f32],
    embed: Range<usize>,
    pos_emb: Range<usize>,
    ln_f: Range<usize>,
    head: &StepWeight,
    blocks: &[(bool, BlockWeights)],
    sc: &mut StepScratch,
    page_pool: &mut PagePool,
    row: &mut DecodeRow,
    token: i32,
    logits: &mut Vec<f32>,
) -> Result<()> {
    let m = &cfg.model;
    let (d, v, s) = (m.d_model, m.vocab, m.seq_len);
    let t = row.t;
    if t >= s {
        bail!("decode row is full ({t} of {s} positions)");
    }
    let h = m.n_heads;
    let hd = d / h;
    let ff = m.d_ff;
    let acts = cfg.acts_fmt;

    // Embedding + positional row (ids clamped like an XLA gather).
    let embed = &params[embed];
    let pos_emb = &params[pos_emb];
    if pos_emb.len() < (t + 1) * d {
        bail!("pos_emb size {} < position {t} x d {d}", pos_emb.len());
    }
    let id = (token.max(0) as usize).min(v.saturating_sub(1));
    sc.x.clear();
    sc.x.resize(d, 0.0);
    let src = &embed[id * d..(id + 1) * d];
    let pe = &pos_emb[t * d..(t + 1) * d];
    for j in 0..d {
        sc.x[j] = src[j] + pe[j];
    }

    for (i, ((quant, bw), state)) in blocks.iter().zip(row.blocks.iter_mut()).enumerate() {
        let quant = *quant;
        match (bw, state) {
            (
                BlockWeights::Attn { ln1, wq, wk, wv, wo, ln2, w1, w2 },
                RowBlockState::Attn { k: kc, v: vc },
            ) => {
                step_rmsnorm(&sc.x, &params[ln1.clone()], &mut sc.y);
                step_gemm_w(&sc.y, wq, params, d, d, quant, acts, &mut sc.xq, &mut sc.q)?;
                step_gemm_w(&sc.y, wk, params, d, d, quant, acts, &mut sc.xq, &mut sc.k)?;
                step_gemm_w(&sc.y, wv, params, d, d, quant, acts, &mut sc.xq, &mut sc.v)?;
                kv_push(kc, page_pool, &sc.k)?;
                kv_push(vc, page_pool, &sc.v)?;
                // Scores over the cached prefix + softmax + AV, one head
                // at a time — each chain is the full pass's row chain
                // (ascending j; masked columns there are exact 0.0).
                // `kv_row` hands back the same d-float position slice in
                // either layout, so paging cannot perturb a single bit.
                let inv_sqrt = 1.0 / (hd as f32).sqrt();
                sc.o.clear();
                sc.o.resize(d, 0.0);
                sc.att.resize(t + 1, 0.0);
                for head in 0..h {
                    let qh = &sc.q[head * hd..(head + 1) * hd];
                    for j in 0..=t {
                        let kh = &kv_row(kc, page_pool, j, d)[head * hd..(head + 1) * hd];
                        let mut sdot = 0f32;
                        for c in 0..hd {
                            sdot += qh[c] * kh[c];
                        }
                        sc.att[j] = sdot * inv_sqrt;
                    }
                    let att = &mut sc.att[..=t];
                    let mx = att.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                    let mut z = 0f32;
                    for a in att.iter_mut() {
                        let e = (*a - mx).exp();
                        *a = e;
                        z += e;
                    }
                    for a in att.iter_mut() {
                        *a /= z;
                    }
                    let orow = &mut sc.o[head * hd..(head + 1) * hd];
                    for j in 0..=t {
                        let pj = sc.att[j];
                        let vv = &kv_row(vc, page_pool, j, d)[head * hd..(head + 1) * hd];
                        for c in 0..hd {
                            orow[c] += pj * vv[c];
                        }
                    }
                }
                step_gemm_w(&sc.o, wo, params, d, d, quant, acts, &mut sc.xq, &mut sc.tmp)?;
                sc.x1.clear();
                sc.x1.resize(d, 0.0);
                for j in 0..d {
                    sc.x1[j] = sc.x[j] + sc.tmp[j];
                }
                step_rmsnorm(&sc.x1, &params[ln2.clone()], &mut sc.y);
                step_gemm_w(&sc.y, w1, params, d, ff, quant, acts, &mut sc.xq, &mut sc.h1)?;
                step_gelu(&sc.h1, &mut sc.h1g);
                step_gemm_w(&sc.h1g, w2, params, ff, d, quant, acts, &mut sc.xq, &mut sc.tmp)?;
                for j in 0..d {
                    sc.x[j] = sc.x1[j] + sc.tmp[j];
                }
            }
            (BlockWeights::Ssm { ln, win, a_bias, wout }, RowBlockState::Ssm { h: hstate }) => {
                step_rmsnorm(&sc.x, &params[ln.clone()], &mut sc.y);
                step_gemm_w(&sc.y, win, params, d, 3 * d, quant, acts, &mut sc.xq, &mut sc.z3)?;
                let a_bias = &params[a_bias.clone()];
                // h_t = a ⊙ h_{t-1} + (1-a) ⊙ v (the scan's exact chain;
                // the carry starts at 0.0 like the full pass's ti == 0).
                for j in 0..d {
                    let av = sigmoid(sc.z3[2 * d + j] + a_bias[j]);
                    let bv = (1.0 - av) * sc.z3[j];
                    hstate[j] = av * hstate[j] + bv;
                }
                sc.o.clear();
                sc.o.resize(d, 0.0);
                for j in 0..d {
                    let g = sc.z3[d + j];
                    sc.o[j] = hstate[j] * g * sigmoid(g);
                }
                step_gemm_w(&sc.o, wout, params, d, d, quant, acts, &mut sc.xq, &mut sc.tmp)?;
                for j in 0..d {
                    sc.x[j] += sc.tmp[j];
                }
            }
            (BlockWeights::Moe { ln, router, experts }, RowBlockState::Moe) => {
                let e = experts.len();
                step_rmsnorm(&sc.x, &params[ln.clone()], &mut sc.y);
                // Router stays high-precision (matmul's ascending-k chain).
                let router = &params[router.clone()];
                sc.tmp.clear();
                sc.tmp.resize(e, 0.0);
                matmul_into(&sc.y, router, &mut sc.tmp, 1, d, e);
                // softmax (the `softmax_rows` row chain)
                sc.probs.clear();
                sc.probs.resize(e, 0.0);
                let mx = sc.tmp.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
                let mut z = 0f32;
                for j in 0..e {
                    let ev = (sc.tmp[j] - mx).exp();
                    sc.probs[j] = ev;
                    z += ev;
                }
                for p in sc.probs.iter_mut() {
                    *p /= z;
                }
                // Top-2 threshold gating (model.py's two-pass form).
                let mut m1 = 0usize;
                for j in 1..e {
                    if sc.probs[j] > sc.probs[m1] {
                        m1 = j;
                    }
                }
                let mut thresh = f32::NEG_INFINITY;
                for (j, &p) in sc.probs.iter().enumerate() {
                    if j != m1 && p > thresh {
                        thresh = p;
                    }
                }
                sc.gate.clear();
                sc.gate.resize(e, 0.0);
                let mut zi = 0f32;
                for j in 0..e {
                    if sc.probs[j] >= thresh {
                        sc.gate[j] = sc.probs[j];
                        zi += sc.probs[j];
                    }
                }
                sc.gaten.clear();
                sc.gaten.resize(e, 0.0);
                for j in 0..e {
                    sc.gaten[j] = sc.gate[j] / (zi + 1e-9);
                }
                sc.moe_out.clear();
                sc.moe_out.resize(d, 0.0);
                for (ei, (w1, w2)) in experts.iter().enumerate() {
                    step_gemm_w(&sc.y, w1, params, d, ff, quant, acts, &mut sc.xq, &mut sc.h1)?;
                    step_gelu(&sc.h1, &mut sc.h1g);
                    step_gemm_w(&sc.h1g, w2, params, ff, d, quant, acts, &mut sc.xq, &mut sc.tmp)?;
                    let gn = sc.gaten[ei];
                    for j in 0..d {
                        sc.moe_out[j] += gn * sc.tmp[j];
                    }
                }
                for j in 0..d {
                    sc.x[j] += sc.moe_out[j];
                }
            }
            _ => bail!("decode row block kind mismatch at b{i}"),
        }
    }

    step_rmsnorm(&sc.x, &params[ln_f], &mut sc.y);
    step_gemm_w(&sc.y, head, params, d, v, cfg.head_quantized(), acts, &mut sc.xq, logits)?;
    row.t = t + 1;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::SynthSpec;
    use crate::util::rng::Rng;

    fn synth_cfg_wa(blocks: &[&str], weights: &str, acts: &str, vision: bool) -> RefCfg {
        let spec = SynthSpec {
            // All contraction dims (d, ff, patch) are multiples of 16 so
            // the nvfp4 weight/act codecs apply on every GEMM.
            name: "ref-test".into(),
            d_model: 16,
            n_heads: 2,
            d_ff: 16,
            blocks: blocks.iter().map(|s| s.to_string()).collect(),
            vocab: 16,
            seq_len: 6,
            batch: 2,
            n_experts: 3,
            vision,
            vision_grid: 2,
            vision_patch: 16,
            weights: weights.into(),
            acts: acts.into(),
            skip_attention: false,
            skip_first: 0,
            skip_last: 0,
            artifact_keys: vec![],
            n_scalars: 8,
        };
        let entry = spec.entry();
        if weights == "none" && acts == "none" {
            RefCfg::bf16(&entry)
        } else {
            RefCfg::for_key_format(&entry, "nvfp4").unwrap()
        }
    }

    fn synth_cfg(blocks: &[&str], quant: &str, vision: bool) -> RefCfg {
        synth_cfg_wa(blocks, quant, quant, vision)
    }

    fn rand_params(cfg: &RefCfg, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        let mut p = vec![0f32; cfg.model.param_count];
        for d in &cfg.model.params {
            let leaf = d.name.rsplit('.').next().unwrap_or("");
            let slice = &mut p[d.offset..d.offset + d.size];
            if leaf.starts_with("ln") {
                slice.fill(1.0);
            } else if leaf == "a_bias" || leaf == "vis_bias" {
                slice.fill(0.0);
            } else {
                let fan_in = if d.shape.len() >= 2 {
                    d.shape[d.shape.len() - 2]
                } else {
                    d.shape[d.shape.len() - 1]
                };
                let std = 1.0 / (fan_in as f32).sqrt();
                for v in slice.iter_mut() {
                    *v = r.normal() as f32 * std;
                }
            }
        }
        p
    }

    fn rand_batch(cfg: &RefCfg, seed: u64) -> (Vec<i32>, Vec<f32>, Option<Vec<f32>>) {
        let m = &cfg.model;
        let mut r = Rng::new(seed);
        let tokens: Vec<i32> =
            (0..m.batch * m.seq_len).map(|_| r.range(1, m.vocab as i64) as i32).collect();
        let mut mask = vec![1f32; m.batch * m.seq_len];
        for b in 0..m.batch {
            for s in 0..m.seq_len / 3 {
                mask[b * m.seq_len + s] = 0.0;
            }
        }
        let pixels = if m.vision {
            let n = m.batch * m.vision_grid * m.vision_grid * m.vision_patch;
            Some((0..n).map(|_| r.normal() as f32).collect())
        } else {
            None
        };
        (tokens, mask, pixels)
    }

    /// Scalar loss for finite differencing (CE over the shifted batch).
    fn ce_scalar(
        cfg: &RefCfg,
        params: &[f32],
        tokens: &[i32],
        mask: &[f32],
        pixels: Option<&[f32]>,
    ) -> f32 {
        let m = &cfg.model;
        let (inp, lab, msk) = shift(tokens, mask, m.batch, m.seq_len);
        let sm = m.seq_len - 1;
        let fwd = forward(cfg, params, &inp, m.batch, sm, pixels).unwrap();
        ce_loss(&fwd.logits, &lab, &msk, m.batch * sm, m.vocab).0
    }

    /// Analytic gradients must match central finite differences. This is
    /// the in-crate transliteration guard for the full backward pass
    /// (attn/ssm/moe, rmsnorm, gelu, scan, gating, embed scatter).
    /// `probe` filters which parameter tensors get finite-differenced —
    /// probes must stay on continuously-differentiable paths.
    fn check_grads(cfg: &RefCfg, seed: u64, tol: f32, probe: fn(&str) -> bool) {
        let m = cfg.model.clone();
        let params = rand_params(cfg, seed);
        let (tokens, mask, pixels) = rand_batch(cfg, seed ^ 0x9e37);
        let px = pixels.as_deref();

        let (inp, lab, msk) = shift(&tokens, &mask, m.batch, m.seq_len);
        let sm = m.seq_len - 1;
        let fwd = forward(cfg, &params, &inp, m.batch, sm, px).unwrap();
        let (_, dlogits) = ce_loss(&fwd.logits, &lab, &msk, m.batch * sm, m.vocab);
        let grads = fwd.backward(cfg, &params, &dlogits).unwrap();

        // Probe a spread of parameter indices across the selected tensors.
        let mut r = Rng::new(seed ^ 0xfd);
        let mut checked = 0;
        for def in &m.params {
            if !probe(&def.name) {
                continue;
            }
            for _ in 0..3 {
                let idx = def.offset + r.below(def.size);
                let eps = 3e-3f32;
                let mut pp = params.clone();
                pp[idx] += eps;
                let lp = ce_scalar(cfg, &pp, &tokens, &mask, px);
                pp[idx] = params[idx] - eps;
                let lm = ce_scalar(cfg, &pp, &tokens, &mask, px);
                let fd = (lp - lm) / (2.0 * eps);
                let an = grads[idx];
                let err = (fd - an).abs();
                // f32 losses give ~1e-4 absolute FD noise at this eps; only
                // enforce relative agreement where the slope is meaningful.
                let scale = fd.abs().max(an.abs());
                if scale > 5e-3 {
                    assert!(
                        err <= tol * scale + 2e-3,
                        "{} idx {idx}: fd {fd} vs analytic {an}",
                        def.name,
                    );
                    checked += 1;
                }
            }
        }
        assert!(checked >= 8, "too few meaningful FD probes ({checked})");
    }

    fn probe_all(_name: &str) -> bool {
        true
    }

    /// Params whose loss dependence stays continuous when *weights* are
    /// fake-quantized (acts unquantized): everything that is not a GEMM
    /// weight. For these the STE gradient is the exact gradient.
    fn probe_non_gemm(name: &str) -> bool {
        let leaf = name.rsplit('.').next().unwrap_or(name);
        matches!(leaf, "embed" | "pos_emb" | "vis_bias" | "a_bias" | "router")
            || leaf.starts_with("ln")
    }

    #[test]
    fn grads_match_finite_differences_attn() {
        let cfg = synth_cfg(&["attn", "attn"], "none", false);
        check_grads(&cfg, 11, 0.08, probe_all);
    }

    #[test]
    fn grads_match_finite_differences_hybrid() {
        let cfg = synth_cfg(&["ssm", "moe", "attn"], "none", false);
        check_grads(&cfg, 13, 0.08, probe_all);
    }

    #[test]
    fn grads_match_finite_differences_vision() {
        let cfg = synth_cfg(&["attn"], "none", true);
        check_grads(&cfg, 17, 0.08, probe_all);
    }

    #[test]
    fn grads_match_finite_differences_weight_quantized() {
        // Weights on the NVFP4 grid, activations left continuous: the
        // quantized weights are (locally constant) grid values, so the loss
        // is differentiable in every non-weight parameter and the STE
        // gradient for those parameters is exact. (FD through a quantizer
        // itself is meaningless — fake-quant is piecewise constant.)
        let cfg = synth_cfg_wa(&["attn", "ssm"], "nvfp4", "none", false);
        assert_eq!(cfg.weights_fmt, Format::Nvfp4);
        assert_eq!(cfg.acts_fmt, Format::None);
        check_grads(&cfg, 19, 0.08, probe_non_gemm);
    }

    #[test]
    fn sft_steps_decrease_ce_loss() {
        let cfg = synth_cfg(&["attn", "attn"], "none", false);
        let m = cfg.model.clone();
        let params = rand_params(&cfg, 3);
        let (tokens, mask, _) = rand_batch(&cfg, 5);
        let mut state = vec![0f32; 3 * m.param_count + 8];
        state[..m.param_count].copy_from_slice(&params);
        let mut losses = Vec::new();
        for _ in 0..12 {
            state = train_step(
                &cfg,
                None,
                &LossKind::Ce,
                false,
                &state,
                &tokens,
                &mask,
                m.batch,
                m.seq_len,
                5e-2,
                None,
                None,
                8,
            )
            .unwrap();
            losses.push(state[3 * m.param_count + scalar::LOSS]);
        }
        assert_eq!(state[3 * m.param_count + scalar::STEP], 12.0);
        assert!(
            losses[11] < losses[0],
            "loss did not fall: {losses:?}"
        );
    }

    #[test]
    fn qad_step_reports_nonnegative_kl_and_zero_for_identical() {
        let cfg = synth_cfg(&["attn"], "none", false);
        let m = cfg.model.clone();
        let params = rand_params(&cfg, 7);
        let (tokens, mask, _) = rand_batch(&cfg, 9);
        let mut state = vec![0f32; 3 * m.param_count + 8];
        state[..m.param_count].copy_from_slice(&params);
        // teacher == student at the same precision -> KL exactly ~0
        let out = train_step(
            &cfg,
            Some((&cfg, &params)),
            &LossKind::Kl,
            false,
            &state,
            &tokens,
            &mask,
            m.batch,
            m.seq_len,
            1e-3,
            None,
            None,
            8,
        )
        .unwrap();
        let kl = out[3 * m.param_count + scalar::KL];
        assert!(kl.abs() < 1e-5, "identical teacher/student KL {kl}");
    }

    #[test]
    fn eval_metrics_zero_kl_for_identical_params() {
        let cfg = synth_cfg(&["attn"], "none", false);
        let m = cfg.model.clone();
        let params = rand_params(&cfg, 21);
        let (tokens, mask, _) = rand_batch(&cfg, 23);
        let ev = eval_metrics(
            &cfg, &params, &cfg, &params, &tokens, &mask, m.batch, m.seq_len, None, 8,
        )
        .unwrap();
        assert!(ev[0].abs() < 1e-5, "KL {ev:?}");
        assert!(ev[1] > 0.0, "CE {ev:?}");
        assert!(ev[2] > 0.0);
    }

    #[test]
    fn quantized_eval_has_positive_kl() {
        let bf16 = synth_cfg(&["attn", "attn"], "none", false);
        let q = synth_cfg(&["attn", "attn"], "nvfp4", false);
        let m = bf16.model.clone();
        let params = rand_params(&bf16, 31);
        let (tokens, mask, _) = rand_batch(&bf16, 33);
        let ev = eval_metrics(
            &q, &params, &bf16, &params, &tokens, &mask, m.batch, m.seq_len, None, 8,
        )
        .unwrap();
        assert!(ev[0] > 1e-7, "quantized KL should be > 0: {ev:?}");
    }

    #[test]
    fn fwd_last_matches_full_logits_rows() {
        let cfg = synth_cfg(&["attn", "ssm"], "nvfp4", false);
        let m = cfg.model.clone();
        let params = rand_params(&cfg, 41);
        let (tokens, _, _) = rand_batch(&cfg, 43);
        let full = fwd_logits(&cfg, &params, &tokens, m.batch, m.seq_len, None).unwrap();
        let idx: Vec<i32> = (0..m.batch).map(|b| (b % m.seq_len) as i32).collect();
        let last = fwd_last(&cfg, &params, &tokens, &idx, m.batch, m.seq_len, None).unwrap();
        for b in 0..m.batch {
            let p = idx[b] as usize;
            let want = &full[(b * m.seq_len + p) * m.vocab..(b * m.seq_len + p + 1) * m.vocab];
            let got = &last[b * m.vocab..(b + 1) * m.vocab];
            assert_eq!(want, got, "row {b}");
        }
    }

    #[test]
    fn nqt_grad_quantization_changes_update() {
        let cfg = synth_cfg(&["attn"], "nvfp4", false);
        let m = cfg.model.clone();
        let params = rand_params(&cfg, 51);
        let (tokens, mask, _) = rand_batch(&cfg, 53);
        let mut state = vec![0f32; 3 * m.param_count + 8];
        state[..m.param_count].copy_from_slice(&params);
        let a = train_step(
            &cfg, None, &LossKind::Ce, false, &state, &tokens, &mask, m.batch, m.seq_len,
            1e-2, None, None, 8,
        )
        .unwrap();
        let b = train_step(
            &cfg, None, &LossKind::Ce, true, &state, &tokens, &mask, m.batch, m.seq_len,
            1e-2, None, None, 8,
        )
        .unwrap();
        assert!(a[..m.param_count].iter().zip(&b[..m.param_count]).any(|(x, y)| x != y));
        // both still carry sane scalars
        assert_eq!(a[3 * m.param_count + scalar::STEP], 1.0);
        assert_eq!(b[3 * m.param_count + scalar::STEP], 1.0);
    }

    #[test]
    fn reinforce_step_moves_in_advantage_direction() {
        let cfg = synth_cfg(&["attn"], "none", false);
        let m = cfg.model.clone();
        let params = rand_params(&cfg, 61);
        let (tokens, mask, _) = rand_batch(&cfg, 63);
        let mut state = vec![0f32; 3 * m.param_count + 8];
        state[..m.param_count].copy_from_slice(&params);
        let adv = vec![1.0f32, -1.0];
        let out = train_step(
            &cfg,
            None,
            &LossKind::Reinforce,
            false,
            &state,
            &tokens,
            &mask,
            m.batch,
            m.seq_len,
            1e-2,
            Some(&adv),
            None,
            8,
        )
        .unwrap();
        assert!(out[3 * m.param_count + scalar::GRAD_NORM] > 0.0);
    }

    #[test]
    fn scan_backward_matches_fd_directly() {
        // Dedicated probe on the ssm block (the trickiest backward).
        let cfg = synth_cfg(&["ssm"], "none", false);
        check_grads(&cfg, 71, 0.08, probe_all);
    }

    /// Full train step at a fixed thread count (helper for the
    /// invariance tests below).
    fn step_at_threads(threads: usize, blocks: &[&str], loss: LossKind) -> Vec<f32> {
        crate::util::pool::with_threads(threads, || {
            let cfg = synth_cfg(blocks, "nvfp4", false);
            let m = cfg.model.clone();
            let params = rand_params(&cfg, 81);
            let (tokens, mask, _) = rand_batch(&cfg, 83);
            let mut state = vec![0f32; 3 * m.param_count + 8];
            state[..m.param_count].copy_from_slice(&params);
            let teacher_cfg = RefCfg::bf16(&m);
            for _ in 0..2 {
                let teacher = match loss {
                    LossKind::Kl => Some((&teacher_cfg, &params[..])),
                    _ => None,
                };
                state = train_step(
                    &cfg, teacher, &loss, false, &state, &tokens, &mask, m.batch, m.seq_len,
                    1e-2, None, None, 8,
                )
                .unwrap();
            }
            state
        })
    }

    #[test]
    fn train_step_state_is_thread_count_invariant() {
        // The packed state (params + Adam moments + scalars) must be
        // bit-identical at 1 and 4 threads — the determinism contract of
        // the parallel compute core.
        let a = step_at_threads(1, &["attn", "ssm", "moe"], LossKind::Ce);
        let b = step_at_threads(4, &["attn", "ssm", "moe"], LossKind::Ce);
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "state[{i}]: {x} vs {y}");
        }
        let a = step_at_threads(1, &["attn"], LossKind::Kl);
        let b = step_at_threads(3, &["attn"], LossKind::Kl);
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "kl state[{i}]: {x} vs {y}");
        }
    }

    /// Replay one token row through prefill+step and assert every step's
    /// logits are bit-identical to the full forward's row at the same
    /// position — the decode-cache contract, per block stack and format.
    fn assert_stepped_matches_full(blocks: &[&str], quant: &str, prefix: usize, seed: u64) {
        let cfg = synth_cfg(blocks, quant, false);
        let m = cfg.model.clone();
        let (s, v) = (m.seq_len, m.vocab);
        let params = rand_params(&cfg, seed);
        let (tokens, _, _) = rand_batch(&cfg, seed ^ 0x77);
        let row_tokens = &tokens[..s]; // first batch row
        let full = fwd_logits(&cfg, &params, row_tokens, 1, s, None).unwrap();

        let mut ctx = DecodeCtx::new(cfg.clone(), params.clone()).unwrap();
        let mut row = ctx.new_row();
        let mut logits = Vec::new();
        let prefix = prefix.clamp(1, s - 1);
        ctx.prefill(&mut row, &row_tokens[..prefix], &mut logits).unwrap();
        assert_eq!(row.len(), prefix);
        let check = |logits: &[f32], pos: usize| {
            let want = &full[pos * v..(pos + 1) * v];
            for (j, (a, b)) in logits.iter().zip(want).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "blocks {blocks:?} quant {quant} pos {pos} logit {j}: {a} vs {b}"
                );
            }
        };
        check(&logits, prefix - 1);
        for pos in prefix..s {
            ctx.step(&mut row, row_tokens[pos], &mut logits).unwrap();
            assert_eq!(row.len(), pos + 1);
            check(&logits, pos);
        }
        // the row is now full: one more step must error, not wrap
        assert!(ctx.step(&mut row, 1, &mut logits).is_err());
    }

    #[test]
    fn stepped_decode_bit_identical_attn() {
        assert_stepped_matches_full(&["attn", "attn"], "none", 2, 101);
        assert_stepped_matches_full(&["attn", "attn"], "nvfp4", 3, 103);
    }

    #[test]
    fn stepped_decode_bit_identical_ssm() {
        assert_stepped_matches_full(&["ssm", "ssm"], "none", 1, 105);
        assert_stepped_matches_full(&["ssm"], "nvfp4", 2, 107);
    }

    #[test]
    fn stepped_decode_bit_identical_hybrid() {
        assert_stepped_matches_full(&["attn", "ssm", "moe"], "none", 2, 109);
        assert_stepped_matches_full(&["ssm", "moe", "attn"], "nvfp4", 4, 111);
    }

    #[test]
    fn stepped_decode_single_token_prefill() {
        // prefill of exactly one token, stepping the whole rest of the row
        assert_stepped_matches_full(&["attn", "ssm"], "nvfp4", 1, 113);
    }

    #[test]
    fn stepped_decode_is_thread_count_invariant() {
        let run = |threads: usize| {
            crate::util::pool::with_threads(threads, || {
                let cfg = synth_cfg(&["attn", "ssm", "moe"], "nvfp4", false);
                let m = cfg.model.clone();
                let params = rand_params(&cfg, 115);
                let (tokens, _, _) = rand_batch(&cfg, 117);
                let mut ctx = DecodeCtx::new(cfg, params).unwrap();
                let mut row = ctx.new_row();
                let mut logits = Vec::new();
                let mut all = Vec::new();
                ctx.prefill(&mut row, &tokens[..2], &mut logits).unwrap();
                all.extend_from_slice(&logits);
                for pos in 2..m.seq_len {
                    ctx.step(&mut row, tokens[pos], &mut logits).unwrap();
                    all.extend_from_slice(&logits);
                }
                all
            })
        };
        let one = run(1);
        let four = run(4);
        for (i, (a, b)) in one.iter().zip(&four).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "stepped logits[{i}]");
        }
    }

    #[test]
    fn decode_rows_are_independent() {
        // Interleaving a second row's prefill/steps must not perturb the
        // first row's logits — the invariant continuous batching needs.
        let cfg = synth_cfg(&["attn", "ssm"], "nvfp4", false);
        let m = cfg.model.clone();
        let params = rand_params(&cfg, 121);
        let (tokens, _, _) = rand_batch(&cfg, 123);
        let (a_toks, b_toks) = (&tokens[..m.seq_len], &tokens[m.seq_len..2 * m.seq_len]);

        let mut solo_ctx = DecodeCtx::new(cfg.clone(), params.clone()).unwrap();
        let mut solo = solo_ctx.new_row();
        let mut solo_logits = Vec::new();
        solo_ctx.prefill(&mut solo, &a_toks[..3], &mut solo_logits).unwrap();
        let mut solo_all = solo_logits.clone();
        for pos in 3..m.seq_len {
            solo_ctx.step(&mut solo, a_toks[pos], &mut solo_logits).unwrap();
            solo_all.extend_from_slice(&solo_logits);
        }

        let mut ctx = DecodeCtx::new(cfg, params).unwrap();
        let (mut ra, mut rb) = (ctx.new_row(), ctx.new_row());
        let mut logits = Vec::new();
        ctx.prefill(&mut ra, &a_toks[..3], &mut logits).unwrap();
        let mut inter_all = logits.clone();
        for pos in 3..m.seq_len {
            // admit/step the other row between every step of row a
            if pos == 4 {
                ctx.prefill(&mut rb, &b_toks[..2], &mut logits).unwrap();
            } else if !rb.is_empty() && rb.len() < m.seq_len {
                ctx.step(&mut rb, b_toks[rb.len()], &mut logits).unwrap();
            }
            ctx.step(&mut ra, a_toks[pos], &mut logits).unwrap();
            inter_all.extend_from_slice(&logits);
        }
        for (i, (x, y)) in solo_all.iter().zip(&inter_all).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "interleaved logits[{i}]");
        }
    }

    #[test]
    fn decode_ctx_rejects_bad_shapes() {
        let cfg = synth_cfg(&["attn"], "none", false);
        assert!(DecodeCtx::new(cfg.clone(), vec![0.0; 3]).is_err());
        let params = rand_params(&cfg, 131);
        let mut ctx = DecodeCtx::new(cfg, params).unwrap();
        let mut row = ctx.new_row();
        let mut logits = Vec::new();
        assert!(ctx.prefill(&mut row, &[], &mut logits).is_err());
        let too_long = vec![1i32; ctx.model().seq_len + 1];
        assert!(ctx.prefill(&mut row, &too_long, &mut logits).is_err());
    }

    fn argmax(l: &[f32]) -> usize {
        let mut best = 0;
        for j in 1..l.len() {
            if l[j] > l[best] {
                best = j;
            }
        }
        best
    }

    /// Drive exact- and packed-tier sessions over the same snapshot in
    /// lockstep on the exact tier's greedy tokens: the packed argmax must
    /// equal the exact argmax at every position, and every packed logit
    /// must sit inside the accuracy budget. Bitwise equality is out of
    /// contract — the packed kernel hoists each block scale out of the
    /// element products, so its rounding chain differs from the exact
    /// tier's materialized-f32 dot in the last bits (~1e-6 absolute on
    /// these models, three orders under the budget). The one-token
    /// prefill keeps the comparison clean: a longer prefill would route
    /// the exact tier through the stateless forward, whose joint
    /// (multi-row) nvfp4 activation scale differs from the step path's
    /// per-row scale — a baseline property unrelated to the kernel tier.
    fn assert_packed_tracks_exact(blocks: &[&str], quant: &str, seed: u64) {
        use crate::quant::packed::within_budget;
        let cfg = synth_cfg(blocks, quant, false);
        let m = cfg.model.clone();
        let mut pcfg = cfg.clone();
        pcfg.kernel = KernelTier::Packed;
        let params = rand_params(&cfg, seed);
        let (tokens, _, _) = rand_batch(&cfg, seed ^ 0x77);
        let mut exact = DecodeCtx::new(cfg, params.clone()).unwrap();
        let mut packed = DecodeCtx::new(pcfg, params).unwrap();
        assert!(
            packed.decode_weight_bytes() * 4 < exact.decode_weight_bytes(),
            "packed tier binds {} weight bytes, exact {} — expected > 4x shrink",
            packed.decode_weight_bytes(),
            exact.decode_weight_bytes()
        );
        let (mut erow, mut prow) = (exact.new_row(), packed.new_row());
        let (mut el, mut pl) = (Vec::new(), Vec::new());
        let mut tok = tokens[0];
        exact.prefill(&mut erow, &[tok], &mut el).unwrap();
        packed.prefill(&mut prow, &[tok], &mut pl).unwrap();
        for pos in 1..m.seq_len {
            let ea = argmax(&el);
            assert_eq!(argmax(&pl), ea, "blocks {blocks:?} {quant} greedy diverged at {pos}");
            for (j, (p, e)) in pl.iter().zip(&el).enumerate() {
                assert!(
                    within_budget(*p, *e),
                    "blocks {blocks:?} {quant} pos {pos} logit {j}: packed {p} vs exact {e}"
                );
            }
            tok = ea as i32;
            exact.step(&mut erow, tok, &mut el).unwrap();
            packed.step(&mut prow, tok, &mut pl).unwrap();
        }
        assert_eq!(argmax(&pl), argmax(&el), "blocks {blocks:?} {quant} final greedy diverged");
    }

    #[test]
    fn packed_decode_tracks_exact_nvfp4() {
        assert_packed_tracks_exact(&["attn", "attn"], "nvfp4", 201);
        assert_packed_tracks_exact(&["ssm", "moe", "attn"], "nvfp4", 203);
    }

    #[test]
    fn packed_decode_tracks_exact_int4() {
        assert_packed_tracks_exact(&["attn", "ssm", "moe"], "int4", 205);
    }

    #[test]
    fn packed_decode_is_thread_count_invariant() {
        let run = |threads: usize| {
            crate::util::pool::with_threads(threads, || {
                let mut cfg = synth_cfg(&["attn", "ssm", "moe"], "nvfp4", false);
                cfg.kernel = KernelTier::Packed;
                let m = cfg.model.clone();
                let params = rand_params(&cfg, 217);
                let (tokens, _, _) = rand_batch(&cfg, 219);
                let mut ctx = DecodeCtx::new(cfg, params).unwrap();
                let mut row = ctx.new_row();
                let mut logits = Vec::new();
                let mut all = Vec::new();
                ctx.prefill(&mut row, &tokens[..2], &mut logits).unwrap();
                all.extend_from_slice(&logits);
                for pos in 2..m.seq_len {
                    ctx.step(&mut row, tokens[pos], &mut logits).unwrap();
                    all.extend_from_slice(&logits);
                }
                all
            })
        };
        let one = run(1);
        let four = run(4);
        for (i, (a, b)) in one.iter().zip(&four).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "packed stepped logits[{i}]");
        }
    }

    #[test]
    fn packed_prefill_replay_serves_paged_state_and_prefix_cache() {
        let mut cfg = synth_cfg(&["attn", "ssm"], "nvfp4", false);
        cfg.kernel = KernelTier::Packed;
        let params = rand_params(&cfg, 213);
        let (tokens, _, _) = rand_batch(&cfg, 215);
        let opts = DecodeOpts { page_size: 2, prefix_cache: 2, max_pages: 0, kernel: None };
        let mut ctx = DecodeCtx::with_opts(cfg, params, opts).unwrap();
        let mut row = ctx.new_row();
        let (mut cold, mut warm) = (Vec::new(), Vec::new());
        ctx.prefill(&mut row, &tokens[..3], &mut cold).unwrap();
        let st = ctx.paged_stats().unwrap();
        assert_eq!(st.prefix_misses, 1);
        assert!(st.decode_weight_bytes > 0);
        assert_eq!(st.decode_weight_bytes, ctx.decode_weight_bytes());
        ctx.prefill(&mut row, &tokens[..3], &mut warm).unwrap();
        assert_eq!(ctx.paged_stats().unwrap().prefix_hits, 1);
        for (i, (a, b)) in cold.iter().zip(&warm).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "packed prefix-hit logits[{i}]");
        }
    }

    #[test]
    fn with_bound_rejects_kernel_tier_mismatch() {
        let cfg = synth_cfg(&["attn"], "nvfp4", false);
        let params = rand_params(&cfg, 207);
        let bound = Rc::new(BoundWeights::bind(&cfg, params).unwrap());
        let mut pcfg = cfg.clone();
        pcfg.kernel = KernelTier::Packed;
        assert!(DecodeCtx::with_bound(pcfg, bound.clone(), DecodeOpts::default()).is_err());
        assert!(DecodeCtx::with_bound(cfg, bound, DecodeOpts::default()).is_ok());
    }

    #[test]
    fn shared_bound_weights_reproduce_fresh_binding_bitwise() {
        let cfg = synth_cfg(&["attn", "ssm"], "nvfp4", false);
        let m = cfg.model.clone();
        let params = rand_params(&cfg, 209);
        let (tokens, _, _) = rand_batch(&cfg, 211);
        let drive = |ctx: &mut DecodeCtx| {
            let mut row = ctx.new_row();
            let mut logits = Vec::new();
            let mut all = Vec::new();
            ctx.prefill(&mut row, &tokens[..2], &mut logits).unwrap();
            all.extend_from_slice(&logits);
            for pos in 2..m.seq_len {
                ctx.step(&mut row, tokens[pos], &mut logits).unwrap();
                all.extend_from_slice(&logits);
            }
            all
        };
        let mut fresh = DecodeCtx::new(cfg.clone(), params.clone()).unwrap();
        let bound = Rc::new(BoundWeights::bind(&cfg, params).unwrap());
        let mut a =
            DecodeCtx::with_bound(cfg.clone(), bound.clone(), DecodeOpts::default()).unwrap();
        let mut b = DecodeCtx::with_bound(cfg, bound, DecodeOpts::default()).unwrap();
        let want = drive(&mut fresh);
        for got in [drive(&mut a), drive(&mut b)] {
            assert_eq!(want.len(), got.len());
            for (i, (x, y)) in want.iter().zip(&got).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "shared-bound logits[{i}]");
            }
        }
    }

    #[test]
    fn forward_logits_are_thread_count_invariant() {
        let cfg = synth_cfg(&["ssm", "moe", "attn"], "nvfp4", false);
        let m = cfg.model.clone();
        let params = rand_params(&cfg, 91);
        let (tokens, _, _) = rand_batch(&cfg, 93);
        let one = crate::util::pool::with_threads(1, || {
            fwd_logits(&cfg, &params, &tokens, m.batch, m.seq_len, None).unwrap()
        });
        let four = crate::util::pool::with_threads(4, || {
            fwd_logits(&cfg, &params, &tokens, m.batch, m.seq_len, None).unwrap()
        });
        for (i, (x, y)) in one.iter().zip(&four).enumerate() {
            assert_eq!(x.to_bits(), y.to_bits(), "logits[{i}]");
        }
    }
}
