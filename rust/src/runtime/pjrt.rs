//! PJRT execution backend: the original AOT path — load HLO-text
//! artifacts, compile once per file through the PJRT CPU client, execute
//! device-resident. This is the only module in the crate that names an
//! `xla::` type; everything above it speaks the [`ExecBackend`] handles.

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::backend::{Buffer, Dtype, ExecBackend, Executable};
use super::manifest::{Manifest, ModelEntry};

pub struct PjrtBackend {
    client: PjRtClient,
}

impl PjrtBackend {
    /// Create a CPU PJRT client.
    pub fn new() -> Result<PjrtBackend> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend { client })
    }

    fn pjrt_buffer<'a>(&self, buf: &'a Buffer) -> Result<&'a PjRtBuffer> {
        buf.payload::<PjRtBuffer>()
            .context("buffer was not created by the pjrt backend")
    }
}

impl ExecBackend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn compile(&self, _manifest: &Manifest, model: &ModelEntry, key: &str) -> Result<Executable> {
        let art = model.artifact(key)?;
        let path_str = art
            .file
            .to_str()
            .with_context(|| format!("non-utf8 path {:?}", art.file))?;
        let proto = HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {:?}", art.file))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {:?}", art.file))?;
        Ok(Executable::new(key, Box::new(exe)))
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        // NOTE on scalars (dims == []): deliberately NOT
        // `buffer_from_host_literal` — that call maps to
        // `BufferFromHostLiteral`, which copies *asynchronously* on a PJRT
        // worker thread; a temporary `Literal` would be freed mid-copy
        // (observed SIGSEGV in `ShapeUtil::ByteSizeOf`).
        // `buffer_from_host_buffer` uses `kImmutableOnlyDuringCall`
        // semantics (synchronous copy).
        let buf = self.client.buffer_from_host_buffer(data, dims, None)?;
        Ok(Buffer::new(Some(dims.to_vec()), Dtype::F32, Box::new(buf)))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        let buf = self.client.buffer_from_host_buffer(data, dims, None)?;
        Ok(Buffer::new(Some(dims.to_vec()), Dtype::I32, Box::new(buf)))
    }

    fn execute(&self, exe: &Executable, args: &[&Buffer]) -> Result<Buffer> {
        let pexe = exe
            .payload::<PjRtLoadedExecutable>()
            .with_context(|| format!("executable {:?} was not compiled by pjrt", exe.key()))?;
        let mut pargs: Vec<&PjRtBuffer> = Vec::with_capacity(args.len());
        for a in args {
            pargs.push(self.pjrt_buffer(a)?);
        }
        let mut out = pexe.execute_b(&pargs)?;
        let replica = out.pop().context("no execution output")?;
        let buf = replica.into_iter().next().context("empty replica output")?;
        // The xla crate does not expose the output shape; downloads verify
        // the element count against the literal instead.
        Ok(Buffer::new(None, Dtype::F32, Box::new(buf)))
    }

    fn download_f32(&self, buf: &Buffer, expect_len: usize, out: &mut Vec<f32>) -> Result<()> {
        // Goes through `to_literal_sync` — the TFRT CPU plugin does not
        // implement `CopyRawToHost`, so partial/offset reads are
        // impossible; small reads use dedicated slicing artifacts instead
        // (see `DeviceState::scalars`).
        let pbuf = self.pjrt_buffer(buf)?;
        let lit = pbuf.to_literal_sync()?;
        let v: Vec<f32> = lit.to_vec()?;
        if v.len() != expect_len {
            bail!("downloaded {} elements, expected {expect_len}", v.len());
        }
        *out = v;
        Ok(())
    }
}
