//! The pure-Rust reference execution backend.
//!
//! "Compiling" an artifact key here means parsing the key's semantics
//! (fwd / fwd_last / scalars / train-step / eval, with a precision-format
//! suffix) against the manifest model entry; executing interprets those
//! semantics directly via [`refmodel`](super::refmodel) — no artifact
//! files, no XLA runtime. This is what makes the decode, serve, and
//! distill integration suites hermetic, and it doubles as a standing
//! cross-check oracle for the PJRT backend (see
//! rust/tests/backend_cross_validation.rs).
//!
//! Execution is multi-threaded through the shared compute core
//! (`util::{pool,gemm}`): forwards, train steps, eval metrics, and the
//! batch-row frontier gather all partition over worker threads sized by
//! `QADX_THREADS` / `--threads` / `Session::builder().threads(..)`,
//! while staying bit-identical at every thread count (the determinism
//! contract asserted by rust/tests/threading.rs).

use std::cell::RefCell;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::quant::packed::KernelTier;

use super::backend::{Buffer, DecodeSession, Dtype, ExecBackend, Executable};
use super::manifest::{ArgDef, Manifest, ModelEntry};
use super::paged::{DecodeOpts, PagedStats};
use super::refmodel::{self, BoundWeights, DecodeCtx, DecodeRow, LossKind, RefCfg};

/// Host-side tensor payload of a reference-backend buffer.
pub(crate) enum HostData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

enum ProgKind {
    /// state -> trailing scalar block.
    Scalars,
    Fwd {
        cfg: RefCfg,
        last: bool,
        from_state: bool,
    },
    Step {
        cfg: RefCfg,
        loss: LossKind,
        teacher: Option<RefCfg>,
        quantize_grads: bool,
    },
    Eval {
        student: RefCfg,
        teacher: RefCfg,
    },
}

struct RefProgram {
    n_scalars: usize,
    args: Vec<ArgDef>,
    kind: ProgKind,
}

/// Most-recently-used entries a backend keeps in its bound-weight cache.
/// Serving alternates between at most a handful of (model, format, tier)
/// bindings; four covers an A/B pair on two tiers without unbounded growth.
const BOUND_CACHE_CAP: usize = 4;

/// Identity of one decode weight binding. Two `open_decode` calls reuse a
/// binding only when the model, precision format, kernel tier, and the
/// exact parameter bits all match — the fingerprint is FNV-1a over the f32
/// bit patterns, so a single changed weight forces a rebind.
#[derive(Clone, PartialEq, Eq)]
struct BoundKey {
    model: String,
    fmt: String,
    tier: KernelTier,
    len: usize,
    fingerprint: u64,
}

fn fnv1a_f32(data: &[f32]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for v in data {
        for b in v.to_bits().to_le_bytes() {
            h ^= u64::from(b);
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

#[derive(Default)]
pub struct ReferenceBackend {
    /// MRU cache of decode weight bindings, shared across `open_decode`
    /// calls on this backend instance. Binding quantizes (exact tier) or
    /// packs (packed tier) every GEMM weight; before this cache each
    /// `generate` call on a serve scheduler re-did that work per request.
    bound: RefCell<Vec<(BoundKey, Rc<BoundWeights>)>>,
}

impl ReferenceBackend {
    pub fn new() -> ReferenceBackend {
        ReferenceBackend::default()
    }

    /// Fetch-or-bind the weights for `key`, refreshing its MRU position.
    fn cached_bound(
        &self,
        key: BoundKey,
        cfg: &RefCfg,
        params: &[f32],
    ) -> Result<Rc<BoundWeights>> {
        let mut cache = self.bound.borrow_mut();
        if let Some(pos) = cache.iter().position(|(k, _)| *k == key) {
            let hit = cache.remove(pos);
            let bw = Rc::clone(&hit.1);
            cache.push(hit);
            return Ok(bw);
        }
        let bw = Rc::new(BoundWeights::bind(cfg, params.to_vec())?);
        if cache.len() >= BOUND_CACHE_CAP {
            cache.remove(0);
        }
        cache.push((key, Rc::clone(&bw)));
        Ok(bw)
    }

    #[cfg(test)]
    fn bound_cache_len(&self) -> usize {
        self.bound.borrow().len()
    }
}

fn parse_key(manifest: &Manifest, model: &ModelEntry, key: &str) -> Result<ProgKind> {
    if key == "scalars" {
        return Ok(ProgKind::Scalars);
    }
    if let Some(rest) = key.strip_prefix("fwd_") {
        let (rest, last) = match rest.strip_prefix("last_") {
            Some(r) => (r, true),
            None => (rest, false),
        };
        let (fmt, from_state) = match rest.strip_suffix("_state") {
            Some(f) => (f, true),
            None => (rest, false),
        };
        let mut cfg = RefCfg::for_key_format(model, fmt)?;
        // Stateless forwards honor the session/env kernel tier too: a
        // packed session's cold prefill and its stateless cross-checks
        // must agree on which GEMM kernel produced the logits.
        cfg.kernel = KernelTier::resolve(None)?;
        return Ok(ProgKind::Fwd { cfg, last, from_state });
    }
    let (stem, fmt) = key
        .split_once('_')
        .with_context(|| format!("unrecognized artifact key {key:?}"))?;
    match stem {
        "sft" | "qat" | "nqt" => Ok(ProgKind::Step {
            cfg: RefCfg::for_key_format(model, fmt)?,
            loss: LossKind::Ce,
            teacher: None,
            quantize_grads: stem == "nqt",
        }),
        "rl" => Ok(ProgKind::Step {
            cfg: RefCfg::for_key_format(model, fmt)?,
            loss: LossKind::Reinforce,
            teacher: None,
            quantize_grads: false,
        }),
        "qad" | "mse" => {
            // "qad_nvfp4" distills from this model's BF16 teacher;
            // "qad_nvfp4_xsuper" from the super-sim teacher (Table 9).
            let (fmt, teacher) = match fmt.strip_suffix("_xsuper") {
                Some(f) => {
                    let t = manifest
                        .model("super-sim")
                        .context("cross-size step needs a super-sim manifest entry")?;
                    (f, RefCfg::bf16(t))
                }
                None => (fmt, RefCfg::bf16(model)),
            };
            Ok(ProgKind::Step {
                cfg: RefCfg::for_key_format(model, fmt)?,
                loss: if stem == "qad" { LossKind::Kl } else { LossKind::Mse },
                teacher: Some(teacher),
                quantize_grads: false,
            })
        }
        "eval" => Ok(ProgKind::Eval {
            student: RefCfg::for_key_format(model, fmt)?,
            teacher: RefCfg::bf16(model),
        }),
        other => bail!("reference backend does not know artifact stem {other:?} (key {key:?})"),
    }
}

fn f32_data<'a>(buf: &'a Buffer, what: &str) -> Result<&'a [f32]> {
    match buf.payload::<HostData>() {
        Some(HostData::F32(v)) => Ok(v),
        Some(HostData::I32(_)) => bail!("{what}: expected f32 buffer, got i32"),
        None => bail!("{what}: buffer was not created by the reference backend"),
    }
}

fn i32_data<'a>(buf: &'a Buffer, what: &str) -> Result<&'a [i32]> {
    match buf.payload::<HostData>() {
        Some(HostData::I32(v)) => Ok(v),
        Some(HostData::F32(_)) => bail!("{what}: expected i32 buffer, got f32"),
        None => bail!("{what}: buffer was not created by the reference backend"),
    }
}

/// Positional args resolved to named slots, validated against the
/// manifest's declared shapes/dtypes.
struct ArgMap<'a> {
    named: Vec<(&'a str, &'a Buffer)>,
}

impl<'a> ArgMap<'a> {
    fn bind(defs: &'a [ArgDef], args: &[&'a Buffer], key: &str) -> Result<ArgMap<'a>> {
        if defs.len() != args.len() {
            bail!("artifact {key:?} takes {} args, got {}", defs.len(), args.len());
        }
        let mut named = Vec::with_capacity(defs.len());
        for (d, &b) in defs.iter().zip(args) {
            let want: usize = d.shape.iter().product();
            let got = match b.payload::<HostData>() {
                Some(HostData::F32(v)) => v.len(),
                Some(HostData::I32(v)) => v.len(),
                None => bail!(
                    "artifact {key:?} arg {:?}: buffer was not created by the reference backend",
                    d.name
                ),
            };
            if got != want {
                bail!(
                    "artifact {key:?} arg {:?}: buffer has {got} elements, \
                     manifest declares {:?} ({want})",
                    d.name,
                    d.shape
                );
            }
            named.push((d.name.as_str(), b));
        }
        Ok(ArgMap { named })
    }

    fn get(&self, name: &str) -> Result<&'a Buffer> {
        self.named
            .iter()
            .find(|(n, _)| *n == name)
            .map(|(_, b)| *b)
            .with_context(|| format!("artifact is missing arg {name:?}"))
    }

    fn maybe(&self, name: &str) -> Option<&'a Buffer> {
        self.named.iter().find(|(n, _)| *n == name).map(|(_, b)| *b)
    }

    fn f32(&self, name: &str) -> Result<&'a [f32]> {
        f32_data(self.get(name)?, name)
    }

    fn i32(&self, name: &str) -> Result<&'a [i32]> {
        i32_data(self.get(name)?, name)
    }

    fn maybe_f32(&self, name: &str) -> Result<Option<&'a [f32]>> {
        match self.maybe(name) {
            Some(b) => Ok(Some(f32_data(b, name)?)),
            None => Ok(None),
        }
    }
}

fn out_f32(data: Vec<f32>, dims: Vec<usize>) -> Buffer {
    Buffer::new(Some(dims), Dtype::F32, Box::new(HostData::F32(data)))
}

/// The reference backend's stateful-decode session: a [`DecodeCtx`]
/// (weight snapshot + pre-quantized GEMM weights + step scratch) plus one
/// [`DecodeRow`] of per-layer state per slot. Step logits are
/// bit-identical to the stateless full forward's frontier rows (the
/// refmodel decode contract), and rows never interact — a freed slot can
/// be refilled mid-generation.
struct RefDecodeSession {
    ctx: DecodeCtx,
    rows: Vec<DecodeRow>,
}

impl DecodeSession for RefDecodeSession {
    fn rows(&self) -> usize {
        self.rows.len()
    }

    fn capacity(&self) -> usize {
        self.ctx.model().seq_len
    }

    fn len(&self, row: usize) -> usize {
        self.rows.get(row).map(|r| r.len()).unwrap_or(0)
    }

    fn prefill(&mut self, row: usize, prompt: &[i32], logits: &mut Vec<f32>) -> Result<()> {
        let n = self.rows.len();
        let r = self
            .rows
            .get_mut(row)
            .with_context(|| format!("decode row {row} out of range ({n} slots)"))?;
        self.ctx.prefill(r, prompt, logits)
    }

    fn step(&mut self, row: usize, token: i32, logits: &mut Vec<f32>) -> Result<()> {
        let n = self.rows.len();
        let r = self
            .rows
            .get_mut(row)
            .with_context(|| format!("decode row {row} out of range ({n} slots)"))?;
        self.ctx.step(r, token, logits)
    }

    fn close(&mut self, row: usize) -> Result<()> {
        let n = self.rows.len();
        let r = self
            .rows
            .get_mut(row)
            .with_context(|| format!("decode row {row} out of range ({n} slots)"))?;
        self.ctx.release_row(r);
        Ok(())
    }

    fn paged_stats(&self) -> Option<PagedStats> {
        self.ctx.paged_stats()
    }

    fn decode_weight_bytes(&self) -> usize {
        self.ctx.decode_weight_bytes()
    }
}

impl ExecBackend for ReferenceBackend {
    fn name(&self) -> &'static str {
        "reference"
    }

    fn compile(&self, manifest: &Manifest, model: &ModelEntry, key: &str) -> Result<Executable> {
        let art = model.artifact(key)?;
        let kind = parse_key(manifest, model, key)
            .with_context(|| format!("reference backend compiling {key:?} for {}", model.name))?;
        let prog = RefProgram { n_scalars: manifest.n_scalars, args: art.args.clone(), kind };
        Ok(Executable::new(key, Box::new(prog)))
    }

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        let want: usize = dims.iter().product();
        if data.len() != want {
            bail!("upload_f32: {} elements for dims {dims:?}", data.len());
        }
        Ok(Buffer::new(Some(dims.to_vec()), Dtype::F32, Box::new(HostData::F32(data.to_vec()))))
    }

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        let want: usize = dims.iter().product();
        if data.len() != want {
            bail!("upload_i32: {} elements for dims {dims:?}", data.len());
        }
        Ok(Buffer::new(Some(dims.to_vec()), Dtype::I32, Box::new(HostData::I32(data.to_vec()))))
    }

    fn execute(&self, exe: &Executable, args: &[&Buffer]) -> Result<Buffer> {
        let prog = exe
            .payload::<RefProgram>()
            .with_context(|| format!("executable {:?} was not compiled by reference", exe.key()))?;
        let am = ArgMap::bind(&prog.args, args, exe.key())?;
        match &prog.kind {
            ProgKind::Scalars => {
                let state = am.f32("state")?;
                if state.len() < prog.n_scalars {
                    bail!("state shorter than scalar block");
                }
                let sc = state[state.len() - prog.n_scalars..].to_vec();
                Ok(out_f32(sc, vec![prog.n_scalars]))
            }
            ProgKind::Fwd { cfg, last, from_state } => {
                let m = &cfg.model;
                let tokens = am.i32("tokens")?;
                let tok_def = am.get("tokens")?;
                let dims = tok_def.dims().context("tokens buffer has no dims")?;
                if dims.len() != 2 {
                    bail!("tokens must be rank 2, got {dims:?}");
                }
                let (b, s) = (dims[0], dims[1]);
                let params_full = if *from_state { am.f32("state")? } else { am.f32("params")? };
                if params_full.len() < m.param_count {
                    bail!(
                        "weights buffer has {} floats < param_count {}",
                        params_full.len(),
                        m.param_count
                    );
                }
                let params = &params_full[..m.param_count];
                let pixels = am.maybe_f32("pixels")?;
                if *last {
                    let idx = am.i32("frontier_idx")?;
                    let out = refmodel::fwd_last(cfg, params, tokens, idx, b, s, pixels)?;
                    Ok(out_f32(out, vec![b, m.vocab]))
                } else {
                    let out = refmodel::fwd_logits(cfg, params, tokens, b, s, pixels)?;
                    Ok(out_f32(out, vec![b, s, m.vocab]))
                }
            }
            ProgKind::Step { cfg, loss, teacher, quantize_grads } => {
                let state = am.f32("state")?;
                let tokens = am.i32("tokens")?;
                let mask = am.f32("mask")?;
                let dims = am.get("tokens")?.dims().context("tokens buffer has no dims")?;
                if dims.len() != 2 {
                    bail!("tokens must be rank 2, got {dims:?}");
                }
                let (b, s) = (dims[0], dims[1]);
                let lr_buf = am.f32("lr")?;
                let lr = *lr_buf.first().context("lr buffer is empty")?;
                let adv = am.maybe_f32("advantage")?;
                let pixels = am.maybe_f32("pixels")?;
                let teacher_pair = match teacher {
                    Some(tcfg) => {
                        let tp = am.f32("teacher_params")?;
                        if tp.len() != tcfg.model.param_count {
                            bail!(
                                "teacher params len {} != teacher param_count {}",
                                tp.len(),
                                tcfg.model.param_count
                            );
                        }
                        Some((tcfg, tp))
                    }
                    None => None,
                };
                let out = refmodel::train_step(
                    cfg,
                    teacher_pair,
                    loss,
                    *quantize_grads,
                    state,
                    tokens,
                    mask,
                    b,
                    s,
                    lr,
                    adv,
                    pixels,
                    prog.n_scalars,
                )?;
                let n = out.len();
                Ok(out_f32(out, vec![n]))
            }
            ProgKind::Eval { student, teacher } => {
                let params = am.f32("params")?;
                let t_params = am.f32("teacher_params")?;
                let tokens = am.i32("tokens")?;
                let mask = am.f32("mask")?;
                let dims = am.get("tokens")?.dims().context("tokens buffer has no dims")?;
                let (b, s) = (dims[0], dims[1]);
                let pixels = am.maybe_f32("pixels")?;
                let out = refmodel::eval_metrics(
                    student,
                    params,
                    teacher,
                    t_params,
                    tokens,
                    mask,
                    b,
                    s,
                    pixels,
                    prog.n_scalars,
                )?;
                let n = out.len();
                Ok(out_f32(out, vec![n]))
            }
        }
    }

    fn download_f32(&self, buf: &Buffer, expect_len: usize, out: &mut Vec<f32>) -> Result<()> {
        let v = f32_data(buf, "download")?;
        if v.len() != expect_len {
            bail!("downloaded {} elements, expected {expect_len}", v.len());
        }
        out.clear();
        out.extend_from_slice(v);
        Ok(())
    }

    fn open_decode(
        &self,
        _manifest: &Manifest,
        model: &ModelEntry,
        fwd_key: &str,
        weights: &Buffer,
        rows: usize,
        opts: &DecodeOpts,
    ) -> Result<Option<Box<dyn DecodeSession>>> {
        let Some(rest) = fwd_key.strip_prefix("fwd_") else {
            bail!("stateful decode needs a plain fwd_* artifact key, got {fwd_key:?}");
        };
        // The frontier-gather twin is itself a stateless artifact; vision
        // models decode through the stateless path (pixels plumbing).
        if rest.starts_with("last_") || model.vision {
            return Ok(None);
        }
        // Mirror the stateless path's contract: decoding an undeclared
        // artifact is an error there, so it is here too.
        model.artifact(fwd_key)?;
        let (fmt, from_state) = match rest.strip_suffix("_state") {
            Some(f) => (f, true),
            None => (rest, false),
        };
        let mut cfg = RefCfg::for_key_format(model, fmt)?;
        cfg.kernel = KernelTier::resolve(opts.kernel)?;
        let data = f32_data(weights, "decode weights")?;
        if from_state {
            if data.len() < model.param_count {
                bail!(
                    "state buffer has {} floats < param_count {}",
                    data.len(),
                    model.param_count
                );
            }
        } else if data.len() != model.param_count {
            bail!("params len {} != param_count {}", data.len(), model.param_count);
        }
        let params = &data[..model.param_count];
        let key = BoundKey {
            model: model.name.clone(),
            fmt: fmt.to_string(),
            tier: cfg.kernel,
            len: params.len(),
            fingerprint: fnv1a_f32(params),
        };
        let bound = self.cached_bound(key, &cfg, params)?;
        let ctx = DecodeCtx::with_bound(cfg, bound, *opts)?;
        let rows = (0..rows.max(1)).map(|_| ctx.new_row()).collect();
        Ok(Some(Box::new(RefDecodeSession { ctx, rows })))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{synthetic_manifest_json, SynthSpec};

    /// One unique dir per (test, process): the tests in this module run
    /// concurrently on harness threads, so the fixture must never share a
    /// path across tests.
    fn synth_manifest(tag: &str) -> Manifest {
        let dir = std::env::temp_dir()
            .join(format!("qadx_refbackend_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let spec = SynthSpec::small("ref-b");
        std::fs::write(dir.join("manifest.json"), synthetic_manifest_json(&[spec])).unwrap();
        let m = Manifest::load(&dir).unwrap();
        std::fs::remove_dir_all(&dir).ok();
        m
    }

    #[test]
    fn compiles_every_declared_key() {
        let manifest = synth_manifest("compiles_every");
        let model = manifest.model("ref-b").unwrap().clone();
        let be = ReferenceBackend::new();
        for key in model.artifacts.keys() {
            be.compile(&manifest, &model, key)
                .unwrap_or_else(|e| panic!("key {key}: {e:#}"));
        }
    }

    #[test]
    fn unknown_key_is_a_clear_error() {
        let manifest = synth_manifest("unknown_key");
        let mut model = manifest.model("ref-b").unwrap().clone();
        // declare a bogus artifact so the key lookup passes
        let art = model.artifacts["fwd_bf16"].clone();
        model.artifacts.insert("frobnicate_bf16".into(), art);
        let be = ReferenceBackend::new();
        let err = be.compile(&manifest, &model, "frobnicate_bf16").unwrap_err();
        assert!(format!("{err:#}").contains("frobnicate"), "{err:#}");
    }

    #[test]
    fn scalars_program_slices_tail() {
        let manifest = synth_manifest("scalars_program");
        let model = manifest.model("ref-b").unwrap().clone();
        let be = ReferenceBackend::new();
        let exe = be.compile(&manifest, &model, "scalars").unwrap();
        let mut state = vec![0f32; model.state_len];
        for (i, v) in state.iter_mut().enumerate() {
            *v = i as f32;
        }
        let sbuf = be.upload_f32(&state, &[model.state_len]).unwrap();
        let out = be.execute(&exe, &[&sbuf]).unwrap();
        let mut got = Vec::new();
        be.download_f32(&out, 8, &mut got).unwrap();
        let want: Vec<f32> = (model.state_len - 8..model.state_len).map(|i| i as f32).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn download_len_mismatch_is_an_error_not_a_truncation() {
        let be = ReferenceBackend::new();
        let buf = be.upload_f32(&[1.0, 2.0, 3.0, 4.0], &[2, 2]).unwrap();
        let mut out = Vec::new();
        let err = be.download_f32(&buf, 5, &mut out).unwrap_err();
        assert!(format!("{err}").contains("expected 5"), "{err}");
        assert!(out.is_empty());
        be.download_f32(&buf, 4, &mut out).unwrap();
        assert_eq!(out, vec![1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn upload_rejects_shape_mismatch() {
        let be = ReferenceBackend::new();
        assert!(be.upload_f32(&[1.0; 3], &[2, 2]).is_err());
        assert!(be.upload_i32(&[1; 4], &[2, 2]).is_ok());
    }

    #[test]
    fn decode_capability_probe_rules() {
        let manifest = synth_manifest("decode_probe");
        let model = manifest.model("ref-b").unwrap().clone();
        let be = ReferenceBackend::new();
        let params = vec![0.01f32; model.param_count];
        let w = be.upload_f32(&params, &[model.param_count]).unwrap();
        // plain fwd keys open a session
        let dflt = DecodeOpts::default();
        let s = be.open_decode(&manifest, &model, "fwd_bf16", &w, 3, &dflt).unwrap().unwrap();
        assert_eq!(s.rows(), 3);
        assert_eq!(s.capacity(), model.seq_len);
        assert_eq!(s.len(0), 0);
        // the frontier twin is stateless -> capability absent, not an error
        let last = be.open_decode(&manifest, &model, "fwd_last_bf16", &w, 1, &dflt).unwrap();
        assert!(last.is_none());
        // non-fwd keys and undeclared artifacts are errors
        assert!(be.open_decode(&manifest, &model, "sft_bf16", &w, 1, &dflt).is_err());
        assert!(be.open_decode(&manifest, &model, "fwd_int4", &w, 1, &dflt).is_err());
        // wrong weights length is an error
        let short = be.upload_f32(&[0.0; 4], &[4]).unwrap();
        assert!(be.open_decode(&manifest, &model, "fwd_bf16", &short, 1, &dflt).is_err());
    }

    #[test]
    fn decode_from_state_key_slices_params() {
        // fwd_bf16_state binds the packed train state; its decode must
        // match fwd_bf16 bound to the bare params slice, bit for bit.
        let manifest = synth_manifest("decode_state");
        let model = manifest.model("ref-b").unwrap().clone();
        let be = ReferenceBackend::new();
        let mut state = vec![0f32; model.state_len];
        for (i, v) in state.iter_mut().enumerate() {
            *v = ((i * 37 % 101) as f32 - 50.0) * 1e-2;
        }
        let params = state[..model.param_count].to_vec();
        let sbuf = be.upload_f32(&state, &[model.state_len]).unwrap();
        let pbuf = be.upload_f32(&params, &[model.param_count]).unwrap();
        let dflt = DecodeOpts::default();
        let mut a =
            be.open_decode(&manifest, &model, "fwd_bf16_state", &sbuf, 1, &dflt).unwrap().unwrap();
        let mut b =
            be.open_decode(&manifest, &model, "fwd_bf16", &pbuf, 1, &dflt).unwrap().unwrap();
        let (mut la, mut lb) = (Vec::new(), Vec::new());
        a.prefill(0, &[1, 5, 9], &mut la).unwrap();
        b.prefill(0, &[1, 5, 9], &mut lb).unwrap();
        assert_eq!(la.len(), model.vocab);
        for (x, y) in la.iter().zip(&lb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        a.step(0, 7, &mut la).unwrap();
        b.step(0, 7, &mut lb).unwrap();
        for (x, y) in la.iter().zip(&lb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(a.len(0), 4);
        // out-of-range rows error cleanly
        assert!(a.prefill(5, &[1], &mut la).is_err());
    }

    #[test]
    fn open_decode_reuses_bound_weights_across_calls() {
        let manifest = synth_manifest("bound_cache");
        let model = manifest.model("ref-b").unwrap().clone();
        let be = ReferenceBackend::new();
        let mut params = vec![0f32; model.param_count];
        for (i, v) in params.iter_mut().enumerate() {
            *v = ((i * 29 % 97) as f32 - 48.0) * 1e-2;
        }
        let w = be.upload_f32(&params, &[model.param_count]).unwrap();
        let dflt = DecodeOpts::default();
        let mut a = be.open_decode(&manifest, &model, "fwd_bf16", &w, 1, &dflt).unwrap().unwrap();
        assert_eq!(be.bound_cache_len(), 1);
        let mut b = be.open_decode(&manifest, &model, "fwd_bf16", &w, 1, &dflt).unwrap().unwrap();
        assert_eq!(be.bound_cache_len(), 1, "identical weights must reuse the cached binding");
        // the shared binding serves both sessions bit-identically
        let (mut la, mut lb) = (Vec::new(), Vec::new());
        a.prefill(0, &[1, 4, 2], &mut la).unwrap();
        b.prefill(0, &[1, 4, 2], &mut lb).unwrap();
        for (x, y) in la.iter().zip(&lb) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // one changed weight forces a fresh binding (fingerprint mismatch)
        params[3] += 1e-3;
        let w2 = be.upload_f32(&params, &[model.param_count]).unwrap();
        be.open_decode(&manifest, &model, "fwd_bf16", &w2, 1, &dflt).unwrap().unwrap();
        assert_eq!(be.bound_cache_len(), 2);
    }

    #[test]
    fn bound_cache_evicts_beyond_capacity() {
        let manifest = synth_manifest("bound_evict");
        let model = manifest.model("ref-b").unwrap().clone();
        let be = ReferenceBackend::new();
        let dflt = DecodeOpts::default();
        let mut params = vec![0f32; model.param_count];
        for fill in 0..BOUND_CACHE_CAP + 1 {
            for v in params.iter_mut() {
                *v = (fill as f32 + 1.0) * 1e-2;
            }
            let w = be.upload_f32(&params, &[model.param_count]).unwrap();
            be.open_decode(&manifest, &model, "fwd_bf16", &w, 1, &dflt).unwrap().unwrap();
        }
        assert_eq!(be.bound_cache_len(), BOUND_CACHE_CAP);
    }

    #[test]
    fn wrong_arg_count_and_dtype_are_rejected() {
        let manifest = synth_manifest("wrong_arg");
        let model = manifest.model("ref-b").unwrap().clone();
        let be = ReferenceBackend::new();
        let exe = be.compile(&manifest, &model, "scalars").unwrap();
        let b1 = be.upload_f32(&[0.0; 4], &[4]).unwrap();
        // wrong arity
        assert!(be.execute(&exe, &[&b1, &b1]).is_err());
        // wrong element count vs the declared state shape
        assert!(be.execute(&exe, &[&b1]).is_err());
    }
}
