//! Runtime layer: PJRT client wrapper + artifact manifest.
//!
//! `Engine` owns the PJRT CPU client and an executable cache;
//! `ModelRuntime` binds one manifest model entry to its artifacts;
//! `DeviceState` keeps the packed training state device-resident across
//! steps (see python/compile/steps.py for the state layout).

pub mod engine;
pub mod manifest;

pub use engine::{scalar, Batch, DeviceState, Engine, ModelRuntime};
pub use manifest::{frontier_key, ArtifactDef, Manifest, ModelEntry, ParamDef};
