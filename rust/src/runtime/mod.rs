//! Runtime layer: pluggable execution backends + artifact manifest.
//!
//! [`ExecBackend`] abstracts compile/upload/execute/download behind opaque
//! [`Buffer`]/[`Executable`] handles. Two implementations ship:
//! * `pjrt` (feature-gated, default) — the PJRT CPU client running AOT
//!   HLO-text artifacts;
//! * `reference` — a pure-Rust interpreter of the artifact semantics
//!   (forward, frontier gather, train steps, eval metrics) driven entirely
//!   by manifest metadata, selectable via `QADX_BACKEND=reference`, which
//!   makes the whole stack hermetically testable and cross-checks the
//!   PJRT path when real artifacts exist.
//!
//! `Engine` owns a backend + the manifest + an executable cache;
//! `ModelRuntime` binds one manifest model entry to its artifacts;
//! `DeviceState` keeps the packed training state device-resident across
//! steps (see python/compile/steps.py for the state layout).

pub mod backend;
pub mod engine;
pub mod manifest;
pub mod paged;
#[cfg(feature = "pjrt")]
pub mod pjrt;
pub mod reference;
pub mod refmodel;

pub use backend::{
    make_backend, BackendKind, Buffer, DecodeSession, Dtype, ExecBackend, Executable,
};
pub use paged::{DecodeOpts, PagedStats};
pub use engine::{scalar, Batch, DeviceState, Engine, ModelRuntime};
pub use manifest::{
    frontier_key, synthetic_manifest_json, ArtifactDef, Manifest, ModelEntry, ParamDef, SynthSpec,
};
pub use reference::ReferenceBackend;
