//! The execution-backend abstraction: compile / upload / execute / download
//! behind opaque buffer handles.
//!
//! `Engine`, `ModelRuntime`, `DeviceState`, the sampler, the serve façade,
//! and the coordinator all speak [`Buffer`] / [`Executable`] — never a
//! concrete backend type — so the same decode, serve, and distill code runs
//! on the PJRT CPU client (AOT HLO artifacts) or on the pure-Rust
//! [`reference`](super::reference) interpreter, and future backends (GPU,
//! sharded, remote) slot in behind the same trait.
//!
//! Backend selection: [`BackendKind`] — explicit via
//! `Session::builder().backend(..)` / `Engine::with_backend`, or the
//! `QADX_BACKEND` environment variable (`pjrt` | `reference`), defaulting
//! to PJRT when the crate is built with the `pjrt` feature (the default).

use std::any::Any;
use std::rc::Rc;

use anyhow::{bail, Result};

use super::manifest::{Manifest, ModelEntry};
use super::paged::{DecodeOpts, PagedStats};

/// Element type of a device buffer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

/// An opaque device buffer handle. The payload is backend-private; callers
/// only see the logical shape (when the backend tracks one) and the dtype.
pub struct Buffer {
    dims: Option<Vec<usize>>,
    dtype: Dtype,
    inner: Box<dyn Any>,
}

impl Buffer {
    /// Wrap a backend-private payload. `dims: None` means the backend does
    /// not know the logical shape (e.g. PJRT execution outputs); length
    /// checks then happen at download time only.
    pub fn new(dims: Option<Vec<usize>>, dtype: Dtype, inner: Box<dyn Any>) -> Buffer {
        Buffer { dims, dtype, inner }
    }

    pub fn dims(&self) -> Option<&[usize]> {
        self.dims.as_deref()
    }

    pub fn dtype(&self) -> Dtype {
        self.dtype
    }

    /// Total element count, when the logical shape is known.
    pub fn element_count(&self) -> Option<usize> {
        self.dims.as_ref().map(|d| d.iter().product())
    }

    /// Downcast the backend-private payload (backend implementations only).
    pub fn payload<T: 'static>(&self) -> Option<&T> {
        self.inner.downcast_ref::<T>()
    }
}

impl std::fmt::Debug for Buffer {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Buffer({:?}, dims {:?})", self.dtype, self.dims)
    }
}

/// An opaque compiled program handle (one manifest artifact on one backend).
pub struct Executable {
    key: String,
    inner: Box<dyn Any>,
}

impl Executable {
    pub fn new(key: impl Into<String>, inner: Box<dyn Any>) -> Executable {
        Executable { key: key.into(), inner }
    }

    /// The manifest artifact key this executable was compiled from.
    pub fn key(&self) -> &str {
        &self.key
    }

    /// Downcast the backend-private payload (backend implementations only).
    pub fn payload<T: 'static>(&self) -> Option<&T> {
        self.inner.downcast_ref::<T>()
    }
}

impl std::fmt::Debug for Executable {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Executable({:?})", self.key)
    }
}

/// One open stateful-decode binding: a fixed weight snapshot plus opaque
/// per-layer state (attention K/V rows, SSM scan carries) for a set of
/// independent row slots. `prefill` consumes a prompt once; every
/// `step` then costs O(frontier) instead of a full (B, S) forward.
///
/// Rows are fully independent — one row's prompt or tokens never affect
/// another row's logits — which is what lets a continuous-batching
/// scheduler refill a freed slot mid-generation. Backends must keep step
/// outputs bit-identical to the corresponding row of the stateless full
/// forward (the contract rust/tests/decode_equivalence.rs asserts).
pub trait DecodeSession {
    /// Concurrent row slots this session tracks.
    fn rows(&self) -> usize;

    /// Max sequence positions one row can hold (the model's seq_len).
    fn capacity(&self) -> usize;

    /// Positions currently cached for `row`.
    fn len(&self, row: usize) -> usize;

    /// Reset `row`, consume `prompt` (1..=capacity tokens), and write the
    /// vocab-sized logits row predicting the next token into `logits`.
    fn prefill(&mut self, row: usize, prompt: &[i32], logits: &mut Vec<f32>) -> Result<()>;

    /// Append `token` at `row`'s frontier and write the logits row
    /// predicting the following position. Errors once the row is full.
    fn step(&mut self, row: usize, token: i32, logits: &mut Vec<f32>) -> Result<()>;

    /// Release `row`'s decode state and reset it to empty. Paged sessions
    /// return its pages to the free list so the next admit can reuse them
    /// immediately; dense sessions just truncate. Default: no-op (a
    /// backend whose `prefill` fully resets a row needs nothing more).
    fn close(&mut self, row: usize) -> Result<()> {
        let _ = row;
        Ok(())
    }

    /// Allocator/prefix-cache gauges when this session stores state in
    /// pages (`DecodeOpts::page_size > 0`); `None` for dense sessions.
    fn paged_stats(&self) -> Option<PagedStats> {
        None
    }

    /// Bytes of weight storage this session's decode path reads per token
    /// (f32 copies on the exact tier, packed nibbles + scales on the
    /// packed tier). `0` when the backend doesn't bind weights per
    /// session.
    fn decode_weight_bytes(&self) -> usize {
        0
    }
}

/// One execution backend: compiles manifest artifacts and moves tensors.
///
/// All handles are opaque; passing a handle created by a different backend
/// is detected and reported as an error (never UB, never a silent
/// misread).
pub trait ExecBackend {
    /// Short name for logs/errors ("pjrt", "reference", ...).
    fn name(&self) -> &'static str;

    /// Compile (or construct) the executable for artifact `key` of `model`.
    /// `manifest` is available for cross-model artifacts (e.g. the
    /// cross-size distillation step references a second model entry).
    fn compile(&self, manifest: &Manifest, model: &ModelEntry, key: &str) -> Result<Executable>;

    fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer>;

    fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer>;

    /// Execute with device-resident args; returns the single output buffer.
    fn execute(&self, exe: &Executable, args: &[&Buffer]) -> Result<Buffer>;

    /// Download an f32 buffer into `out`, verifying the element count.
    /// Backends must error (not truncate, not pad) when the buffer holds a
    /// different number of elements than `expect_len`.
    fn download_f32(&self, buf: &Buffer, expect_len: usize, out: &mut Vec<f32>) -> Result<()>;

    /// Probe/open the optional stateful-decode capability for one plain
    /// `fwd_*` artifact, binding `weights` (params vector, or the packed
    /// train state for `fwd_*_state` keys) and `rows` independent slots.
    /// `opts` selects the state layout (dense vs paged, prefix cache,
    /// page budget); `DecodeOpts::default()` is the dense PR 5 layout.
    ///
    /// `Ok(None)` means the capability is absent (this default): callers
    /// fall back to the stateless frontier/full-logits decode path. A
    /// malformed request (non-fwd key, missing artifact, bad weights
    /// length, inconsistent opts) is an error, not `None`.
    fn open_decode(
        &self,
        manifest: &Manifest,
        model: &ModelEntry,
        fwd_key: &str,
        weights: &Buffer,
        rows: usize,
        opts: &DecodeOpts,
    ) -> Result<Option<Box<dyn DecodeSession>>> {
        let _ = (manifest, model, fwd_key, weights, rows, opts);
        Ok(None)
    }
}

/// Which execution backend an engine runs on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// The PJRT CPU client executing AOT HLO-text artifacts (requires the
    /// `pjrt` cargo feature and compiled artifacts on disk).
    Pjrt,
    /// The pure-Rust reference interpreter: executes artifact semantics
    /// directly from manifest metadata — no XLA, no artifact files.
    Reference,
}

impl BackendKind {
    /// Parse a backend name (`QADX_BACKEND`, `--backend`).
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s.trim().to_ascii_lowercase().as_str() {
            "pjrt" | "xla" => Ok(BackendKind::Pjrt),
            "reference" | "ref" => Ok(BackendKind::Reference),
            other => bail!("unknown backend {other:?} (known: pjrt, reference)"),
        }
    }

    /// The `QADX_BACKEND` override, if set (empty counts as unset).
    pub fn from_env() -> Result<Option<BackendKind>> {
        match std::env::var("QADX_BACKEND") {
            Ok(v) if !v.trim().is_empty() => Ok(Some(BackendKind::parse(&v)?)),
            _ => Ok(None),
        }
    }

    /// The build's default backend: PJRT when compiled in, else reference.
    pub fn default_kind() -> BackendKind {
        #[cfg(feature = "pjrt")]
        {
            BackendKind::Pjrt
        }
        #[cfg(not(feature = "pjrt"))]
        {
            BackendKind::Reference
        }
    }

    /// Resolve the effective kind: explicit choice, else `QADX_BACKEND`,
    /// else the build default.
    pub fn resolve(explicit: Option<BackendKind>) -> Result<BackendKind> {
        if let Some(k) = explicit {
            return Ok(k);
        }
        Ok(BackendKind::from_env()?.unwrap_or_else(BackendKind::default_kind))
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BackendKind::Pjrt => write!(f, "pjrt"),
            BackendKind::Reference => write!(f, "reference"),
        }
    }
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<BackendKind> {
        BackendKind::parse(s)
    }
}

/// Construct a backend of the given kind.
pub fn make_backend(kind: BackendKind) -> Result<Rc<dyn ExecBackend>> {
    match kind {
        BackendKind::Pjrt => {
            #[cfg(feature = "pjrt")]
            {
                Ok(Rc::new(super::pjrt::PjrtBackend::new()?))
            }
            #[cfg(not(feature = "pjrt"))]
            {
                bail!(
                    "backend 'pjrt' requested but this build has no `pjrt` feature; \
                     rebuild with --features pjrt or use QADX_BACKEND=reference"
                )
            }
        }
        BackendKind::Reference => Ok(Rc::new(super::reference::ReferenceBackend::new())),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses_aliases() {
        assert_eq!(BackendKind::parse("pjrt").unwrap(), BackendKind::Pjrt);
        assert_eq!(BackendKind::parse("REF").unwrap(), BackendKind::Reference);
        assert_eq!(BackendKind::parse(" reference ").unwrap(), BackendKind::Reference);
        assert!(BackendKind::parse("gpu").is_err());
    }

    #[test]
    fn backend_kind_round_trips_display() {
        for k in [BackendKind::Pjrt, BackendKind::Reference] {
            assert_eq!(BackendKind::parse(&k.to_string()).unwrap(), k);
        }
    }

    #[test]
    fn buffer_reports_shape_and_dtype() {
        let b = Buffer::new(Some(vec![2, 3]), Dtype::F32, Box::new(vec![0f32; 6]));
        assert_eq!(b.element_count(), Some(6));
        assert_eq!(b.dims(), Some(&[2usize, 3][..]));
        assert_eq!(b.dtype(), Dtype::F32);
        assert!(b.payload::<Vec<f32>>().is_some());
        assert!(b.payload::<Vec<i32>>().is_none());
        let unknown = Buffer::new(None, Dtype::F32, Box::new(()));
        assert_eq!(unknown.element_count(), None);
    }
}
