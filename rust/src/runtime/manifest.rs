//! Artifact manifest: the contract between the Python compile path
//! (python/compile/aot.py) and the Rust coordinator. Parsed from
//! artifacts/manifest.json.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

pub const SUPPORTED_VERSION: usize = 4;

#[derive(Clone, Debug)]
pub struct ArgDef {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

#[derive(Clone, Debug)]
pub struct ArtifactDef {
    pub file: PathBuf,
    pub args: Vec<ArgDef>,
}

#[derive(Clone, Debug)]
pub struct ParamDef {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Clone, Debug)]
pub struct QuantSettings {
    pub weights: String,
    pub acts: String,
    pub impl_: String,
    pub skip_attention: bool,
    pub skip_first: usize,
    pub skip_last: usize,
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub blocks: Vec<String>,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub vision: bool,
    pub vision_grid: usize,
    pub vision_patch: usize,
    pub param_count: usize,
    pub state_len: usize,
    pub quant: QuantSettings,
    pub params: Vec<ParamDef>,
    pub artifacts: BTreeMap<String, ArtifactDef>,
}

/// Manifest key of the frontier-gather twin of a forward artifact
/// ("fwd_bf16" → "fwd_last_bf16", "fwd_bf16_state" → "fwd_last_bf16_state");
/// None when `fwd_key` is not a fwd key or is already a frontier key.
pub fn frontier_key(fwd_key: &str) -> Option<String> {
    let rest = fwd_key.strip_prefix("fwd_")?;
    if rest.starts_with("last_") {
        return None;
    }
    Some(format!("fwd_last_{rest}"))
}

impl ModelEntry {
    /// Offset of the scalar metrics block inside the state vector.
    pub fn scalars_offset(&self) -> usize {
        3 * self.param_count
    }

    pub fn artifact(&self, key: &str) -> Result<&ArtifactDef> {
        self.artifacts
            .get(key)
            .with_context(|| format!("model {} has no artifact {key:?}", self.name))
    }

    pub fn has_artifact(&self, key: &str) -> bool {
        self.artifacts.contains_key(key)
    }

    /// The frontier-gather twin of `fwd_key`, when the manifest carries one.
    /// Older artifact builds simply lack the key, in which case callers fall
    /// back to the full-logits download path.
    pub fn frontier_artifact(&self, fwd_key: &str) -> Option<&ArtifactDef> {
        frontier_key(fwd_key).and_then(|k| self.artifacts.get(&k))
    }

    /// Selective-quantization predicate matching model.py `_block_quantized`
    /// — used by the Rust PTQ exporter to keep the same layers at BF16.
    pub fn param_skipped_by_selective_quant(&self, param_name: &str) -> bool {
        if param_name == "embed" || param_name == "pos_emb" {
            return true; // lookup tables, not GEMMs
        }
        let n_blocks = self.blocks.len();
        if param_name == "head" || param_name == "ln_f" {
            // head follows the last block's quantization decision
            return self.quant.skip_last > 0;
        }
        if let Some(rest) = param_name.strip_prefix('b') {
            if let Some((idx_s, _leaf)) = rest.split_once('.') {
                if let Ok(i) = idx_s.parse::<usize>() {
                    let kind = self.blocks.get(i).map(|s| s.as_str()).unwrap_or("attn");
                    if kind == "attn" && self.quant.skip_attention {
                        return true;
                    }
                    if i < self.quant.skip_first || i >= n_blocks - self.quant.skip_last {
                        return true;
                    }
                    return false;
                }
            }
        }
        // vision front-end & norms handled by the 1-D rule in quant::ptq
        false
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub vocab: usize,
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    pub sep: i32,
    pub n_scalars: usize,
    pub scalar_names: Vec<String>,
    pub models: BTreeMap<String, ModelEntry>,
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    Ok(j.as_arr()
        .context("shape not an array")?
        .iter()
        .map(|v| v.as_usize().unwrap_or(0))
        .collect())
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let version = j.req_usize("version")?;
        if version != SUPPORTED_VERSION {
            bail!("manifest version {version} != supported {SUPPORTED_VERSION}; rebuild artifacts");
        }
        let special = j.req("special")?;
        let mut models = BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj().context("models not an object")? {
            let quant_j = m.req("quant")?;
            let quant = QuantSettings {
                weights: quant_j.req_str("weights")?.to_string(),
                acts: quant_j.req_str("acts")?.to_string(),
                impl_: quant_j.req_str("impl")?.to_string(),
                skip_attention: quant_j.req_bool("skip_attention")?,
                skip_first: quant_j.req_usize("skip_first")?,
                skip_last: quant_j.req_usize("skip_last")?,
            };
            let params = m
                .req_arr("params")?
                .iter()
                .map(|p| -> Result<ParamDef> {
                    Ok(ParamDef {
                        name: p.req_str("name")?.to_string(),
                        shape: parse_shape(p.req("shape")?)?,
                        offset: p.req_usize("offset")?,
                        size: p.req_usize("size")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let mut artifacts = BTreeMap::new();
            for (key, a) in m.req("artifacts")?.as_obj().context("artifacts not an object")? {
                let args = a
                    .req_arr("args")?
                    .iter()
                    .map(|arg| -> Result<ArgDef> {
                        Ok(ArgDef {
                            name: arg.req_str("name")?.to_string(),
                            shape: parse_shape(arg.req("shape")?)?,
                            dtype: arg.req_str("dtype")?.to_string(),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                artifacts.insert(
                    key.clone(),
                    ArtifactDef { file: artifacts_dir.join(a.req_str("file")?), args },
                );
            }
            let entry = ModelEntry {
                name: name.clone(),
                d_model: m.req_usize("d_model")?,
                n_heads: m.req_usize("n_heads")?,
                d_ff: m.req_usize("d_ff")?,
                blocks: m
                    .req_arr("blocks")?
                    .iter()
                    .map(|b| b.as_str().unwrap_or("attn").to_string())
                    .collect(),
                vocab: m.req_usize("vocab")?,
                seq_len: m.req_usize("seq_len")?,
                batch: m.req_usize("batch")?,
                vision: m.req_bool("vision")?,
                vision_grid: m.req_usize("vision_grid")?,
                vision_patch: m.req_usize("vision_patch")?,
                param_count: m.req_usize("param_count")?,
                state_len: m.req_usize("state_len")?,
                quant,
                params,
                artifacts,
            };
            // Internal consistency.
            let laid: usize = entry.params.iter().map(|p| p.size).sum();
            if laid != entry.param_count {
                bail!("model {name}: param layout sums to {laid} != param_count {}", entry.param_count);
            }
            if entry.state_len != 3 * entry.param_count + j.req_usize("n_scalars")? {
                bail!("model {name}: state_len inconsistent");
            }
            models.insert(name.clone(), entry);
        }
        Ok(Manifest {
            root: artifacts_dir.to_path_buf(),
            vocab: j.req_usize("vocab")?,
            pad: special.req_usize("pad")? as i32,
            bos: special.req_usize("bos")? as i32,
            eos: special.req_usize("eos")? as i32,
            sep: special.req_usize("sep")? as i32,
            n_scalars: j.req_usize("n_scalars")?,
            scalar_names: j
                .req_arr("scalar_names")?
                .iter()
                .map(|s| s.as_str().unwrap_or("").to_string())
                .collect(),
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .with_context(|| format!("manifest has no model {name:?}"))
    }
}
