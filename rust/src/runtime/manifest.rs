//! Artifact manifest: the contract between the Python compile path
//! (python/compile/aot.py) and the Rust coordinator. Parsed from
//! artifacts/manifest.json.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

pub const SUPPORTED_VERSION: usize = 4;

#[derive(Clone, Debug)]
pub struct ArgDef {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String, // "f32" | "i32"
}

#[derive(Clone, Debug)]
pub struct ArtifactDef {
    pub file: PathBuf,
    pub args: Vec<ArgDef>,
}

#[derive(Clone, Debug)]
pub struct ParamDef {
    pub name: String,
    pub shape: Vec<usize>,
    pub offset: usize,
    pub size: usize,
}

#[derive(Clone, Debug)]
pub struct QuantSettings {
    pub weights: String,
    pub acts: String,
    pub impl_: String,
    pub skip_attention: bool,
    pub skip_first: usize,
    pub skip_last: usize,
}

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub blocks: Vec<String>,
    /// Experts per "moe" block (0 for models without moe blocks and for
    /// pre-field manifests; the reference backend then derives it from the
    /// router parameter shape).
    pub n_experts: usize,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub vision: bool,
    pub vision_grid: usize,
    pub vision_patch: usize,
    pub param_count: usize,
    pub state_len: usize,
    pub quant: QuantSettings,
    pub params: Vec<ParamDef>,
    pub artifacts: BTreeMap<String, ArtifactDef>,
}

/// Manifest key of the frontier-gather twin of a forward artifact
/// ("fwd_bf16" → "fwd_last_bf16", "fwd_bf16_state" → "fwd_last_bf16_state");
/// None when `fwd_key` is not a fwd key or is already a frontier key.
pub fn frontier_key(fwd_key: &str) -> Option<String> {
    let rest = fwd_key.strip_prefix("fwd_")?;
    if rest.starts_with("last_") {
        return None;
    }
    Some(format!("fwd_last_{rest}"))
}

impl ModelEntry {
    /// Offset of the scalar metrics block inside the state vector.
    pub fn scalars_offset(&self) -> usize {
        3 * self.param_count
    }

    pub fn artifact(&self, key: &str) -> Result<&ArtifactDef> {
        self.artifacts
            .get(key)
            .with_context(|| format!("model {} has no artifact {key:?}", self.name))
    }

    pub fn has_artifact(&self, key: &str) -> bool {
        self.artifacts.contains_key(key)
    }

    /// Selective-quantization predicate matching model.py `_block_quantized`
    /// — used by the Rust PTQ exporter to keep the same layers at BF16.
    pub fn param_skipped_by_selective_quant(&self, param_name: &str) -> bool {
        if param_name == "embed" || param_name == "pos_emb" {
            return true; // lookup tables, not GEMMs
        }
        let n_blocks = self.blocks.len();
        if param_name == "head" || param_name == "ln_f" {
            // head follows the last block's quantization decision
            return self.quant.skip_last > 0;
        }
        if let Some(rest) = param_name.strip_prefix('b') {
            if let Some((idx_s, _leaf)) = rest.split_once('.') {
                if let Ok(i) = idx_s.parse::<usize>() {
                    let kind = self.blocks.get(i).map(|s| s.as_str()).unwrap_or("attn");
                    if kind == "attn" && self.quant.skip_attention {
                        return true;
                    }
                    if i < self.quant.skip_first || i >= n_blocks - self.quant.skip_last {
                        return true;
                    }
                    return false;
                }
            }
        }
        // vision front-end & norms handled by the 1-D rule in quant::ptq
        false
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub vocab: usize,
    pub pad: i32,
    pub bos: i32,
    pub eos: i32,
    pub sep: i32,
    pub n_scalars: usize,
    pub scalar_names: Vec<String>,
    pub models: BTreeMap<String, ModelEntry>,
}

fn parse_shape(j: &Json) -> Result<Vec<usize>> {
    Ok(j.as_arr()
        .context("shape not an array")?
        .iter()
        .map(|v| v.as_usize().unwrap_or(0))
        .collect())
}

impl Manifest {
    pub fn load(artifacts_dir: &Path) -> Result<Manifest> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
        let j = Json::parse(&text).context("parsing manifest.json")?;
        let version = j.req_usize("version")?;
        if version != SUPPORTED_VERSION {
            bail!("manifest version {version} != supported {SUPPORTED_VERSION}; rebuild artifacts");
        }
        let special = j.req("special")?;
        let mut models = BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj().context("models not an object")? {
            let quant_j = m.req("quant")?;
            let quant = QuantSettings {
                weights: quant_j.req_str("weights")?.to_string(),
                acts: quant_j.req_str("acts")?.to_string(),
                impl_: quant_j.req_str("impl")?.to_string(),
                skip_attention: quant_j.req_bool("skip_attention")?,
                skip_first: quant_j.req_usize("skip_first")?,
                skip_last: quant_j.req_usize("skip_last")?,
            };
            let params = m
                .req_arr("params")?
                .iter()
                .map(|p| -> Result<ParamDef> {
                    Ok(ParamDef {
                        name: p.req_str("name")?.to_string(),
                        shape: parse_shape(p.req("shape")?)?,
                        offset: p.req_usize("offset")?,
                        size: p.req_usize("size")?,
                    })
                })
                .collect::<Result<Vec<_>>>()?;
            let mut artifacts = BTreeMap::new();
            for (key, a) in m.req("artifacts")?.as_obj().context("artifacts not an object")? {
                let args = a
                    .req_arr("args")?
                    .iter()
                    .map(|arg| -> Result<ArgDef> {
                        Ok(ArgDef {
                            name: arg.req_str("name")?.to_string(),
                            shape: parse_shape(arg.req("shape")?)?,
                            dtype: arg.req_str("dtype")?.to_string(),
                        })
                    })
                    .collect::<Result<Vec<_>>>()?;
                artifacts.insert(
                    key.clone(),
                    ArtifactDef { file: artifacts_dir.join(a.req_str("file")?), args },
                );
            }
            let entry = ModelEntry {
                name: name.clone(),
                d_model: m.req_usize("d_model")?,
                n_heads: m.req_usize("n_heads")?,
                d_ff: m.req_usize("d_ff")?,
                blocks: m
                    .req_arr("blocks")?
                    .iter()
                    .map(|b| b.as_str().unwrap_or("attn").to_string())
                    .collect(),
                n_experts: m.req_usize("n_experts").unwrap_or(0),
                vocab: m.req_usize("vocab")?,
                seq_len: m.req_usize("seq_len")?,
                batch: m.req_usize("batch")?,
                vision: m.req_bool("vision")?,
                vision_grid: m.req_usize("vision_grid")?,
                vision_patch: m.req_usize("vision_patch")?,
                param_count: m.req_usize("param_count")?,
                state_len: m.req_usize("state_len")?,
                quant,
                params,
                artifacts,
            };
            // Internal consistency.
            let laid: usize = entry.params.iter().map(|p| p.size).sum();
            if laid != entry.param_count {
                bail!("model {name}: param layout sums to {laid} != param_count {}", entry.param_count);
            }
            if entry.state_len != 3 * entry.param_count + j.req_usize("n_scalars")? {
                bail!("model {name}: state_len inconsistent");
            }
            models.insert(name.clone(), entry);
        }
        Ok(Manifest {
            root: artifacts_dir.to_path_buf(),
            vocab: j.req_usize("vocab")?,
            pad: special.req_usize("pad")? as i32,
            bos: special.req_usize("bos")? as i32,
            eos: special.req_usize("eos")? as i32,
            sep: special.req_usize("sep")? as i32,
            n_scalars: j.req_usize("n_scalars")?,
            scalar_names: j
                .req_arr("scalar_names")?
                .iter()
                .map(|s| s.as_str().unwrap_or("").to_string())
                .collect(),
            models,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .with_context(|| format!("manifest has no model {name:?}"))
    }
}

/// Spec for a synthetic manifest model — the knobs behind hermetic tests:
/// model size, block kinds, quantization format, and which artifact keys
/// exist. `entry()` produces a `ModelEntry` with the exact parameter
/// layout of python/compile/model.py `param_layout` and per-key artifact
/// argument lists matching aot.py, so the reference backend can execute
/// it without any files on disk; `manifest_json` serializes a full
/// manifest for tests that go through `Manifest::load`.
#[derive(Clone, Debug)]
pub struct SynthSpec {
    pub name: String,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub blocks: Vec<String>,
    pub vocab: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub n_experts: usize,
    pub vision: bool,
    pub vision_grid: usize,
    pub vision_patch: usize,
    pub weights: String,
    pub acts: String,
    pub skip_attention: bool,
    pub skip_first: usize,
    pub skip_last: usize,
    /// Artifact keys to declare ("fwd_bf16", "sft_bf16", "scalars", ...).
    pub artifact_keys: Vec<String>,
    pub n_scalars: usize,
}

impl SynthSpec {
    /// A small all-attention text model with the standard artifact set —
    /// the base most hermetic tests start from.
    pub fn small(name: &str) -> SynthSpec {
        SynthSpec {
            name: name.to_string(),
            d_model: 16,
            n_heads: 2,
            d_ff: 32,
            blocks: vec!["attn".into(), "attn".into()],
            vocab: 64,
            seq_len: 32,
            batch: 4,
            n_experts: 0,
            vision: false,
            vision_grid: 0,
            vision_patch: 0,
            weights: "nvfp4".into(),
            acts: "nvfp4".into(),
            skip_attention: false,
            skip_first: 0,
            skip_last: 0,
            artifact_keys: vec![
                "fwd_bf16".into(),
                "fwd_last_bf16".into(),
                "fwd_nvfp4".into(),
                "fwd_last_nvfp4".into(),
                "fwd_bf16_state".into(),
                "fwd_last_bf16_state".into(),
                "scalars".into(),
                "sft_bf16".into(),
                "qat_nvfp4".into(),
                "qad_nvfp4".into(),
                "mse_nvfp4".into(),
                "nqt_nvfp4".into(),
                "rl_bf16".into(),
                "eval_bf16".into(),
                "eval_nvfp4".into(),
            ],
            n_scalars: 8,
        }
    }

    /// Parameter layout matching model.py `param_defs` exactly.
    pub fn param_layout(&self) -> Vec<ParamDef> {
        let d = self.d_model;
        let ff = self.d_ff;
        let v = self.vocab;
        let n_img = if self.vision { self.vision_grid * self.vision_grid } else { 0 };
        let total_seq = self.seq_len + n_img;
        let mut defs: Vec<(String, Vec<usize>)> = vec![
            ("embed".into(), vec![v, d]),
            ("pos_emb".into(), vec![total_seq, d]),
        ];
        if self.vision {
            defs.push(("vis_proj".into(), vec![self.vision_patch, d]));
            defs.push(("vis_bias".into(), vec![d]));
        }
        for (i, kind) in self.blocks.iter().enumerate() {
            let p = format!("b{i}.");
            match kind.as_str() {
                "attn" => {
                    defs.push((format!("{p}ln1"), vec![d]));
                    defs.push((format!("{p}wq"), vec![d, d]));
                    defs.push((format!("{p}wk"), vec![d, d]));
                    defs.push((format!("{p}wv"), vec![d, d]));
                    defs.push((format!("{p}wo"), vec![d, d]));
                    defs.push((format!("{p}ln2"), vec![d]));
                    defs.push((format!("{p}w1"), vec![d, ff]));
                    defs.push((format!("{p}w2"), vec![ff, d]));
                }
                "ssm" => {
                    defs.push((format!("{p}ln"), vec![d]));
                    defs.push((format!("{p}win"), vec![d, 3 * d]));
                    defs.push((format!("{p}a_bias"), vec![d]));
                    defs.push((format!("{p}wout"), vec![d, d]));
                }
                "moe" => {
                    defs.push((format!("{p}ln"), vec![d]));
                    defs.push((format!("{p}router"), vec![d, self.n_experts]));
                    defs.push((format!("{p}w1"), vec![self.n_experts, d, ff]));
                    defs.push((format!("{p}w2"), vec![self.n_experts, ff, d]));
                }
                other => panic!("unknown block kind {other:?}"),
            }
        }
        defs.push(("ln_f".into(), vec![d]));
        defs.push(("head".into(), vec![d, v]));
        let mut out = Vec::with_capacity(defs.len());
        let mut off = 0usize;
        for (name, shape) in defs {
            let size: usize = shape.iter().product();
            out.push(ParamDef { name, shape, offset: off, size });
            off += size;
        }
        out
    }

    /// Argument list for one artifact key (aot.py arg order + names).
    fn artifact_args(&self, key: &str, param_count: usize, state_len: usize) -> Vec<ArgDef> {
        let arg = |name: &str, shape: Vec<usize>, dtype: &str| ArgDef {
            name: name.to_string(),
            shape,
            dtype: dtype.to_string(),
        };
        let (b, s) = (self.batch, self.seq_len);
        let state = arg("state", vec![state_len], "f32");
        let params = arg("params", vec![param_count], "f32");
        let teacher = arg("teacher_params", vec![param_count], "f32");
        let tokens = arg("tokens", vec![b, s], "i32");
        let mask = arg("mask", vec![b, s], "f32");
        let lr = arg("lr", vec![], "f32");
        let adv = arg("advantage", vec![b], "f32");
        let idx = arg("frontier_idx", vec![b], "i32");
        let pix = arg(
            "pixels",
            vec![b, self.vision_grid * self.vision_grid, self.vision_patch],
            "f32",
        );
        // Cross-size (`*_xsuper`) steps take the *teacher* model's param
        // shape (aot.py uses sup_params.shape); a SynthSpec cannot know
        // another spec's param count, so declaring such a key here would
        // silently produce an unexecutable arg list — fail loudly instead.
        assert!(
            !key.ends_with("_xsuper"),
            "SynthSpec cannot declare cross-size artifact {key:?}; build its arg list by hand"
        );
        let mut args: Vec<ArgDef> = if key == "scalars" {
            return vec![state];
        } else if key.starts_with("fwd_") {
            let from_state = key.ends_with("_state");
            let last = key.starts_with("fwd_last_");
            let mut v = vec![if from_state { state } else { params }, tokens];
            if last {
                v.push(idx);
            }
            v
        } else if key.starts_with("qad_") || key.starts_with("mse_") {
            vec![state, teacher, tokens, mask, lr]
        } else if key.starts_with("rl_") {
            vec![state, tokens, mask, adv, lr]
        } else if key.starts_with("eval_") {
            vec![params, teacher, tokens, mask]
        } else {
            // sft / qat / nqt and any other CE-style step
            vec![state, tokens, mask, lr]
        };
        if self.vision {
            args.push(pix);
        }
        args
    }

    /// Build the `ModelEntry` (no files involved; artifact paths are
    /// placeholders the reference backend never opens).
    pub fn entry(&self) -> ModelEntry {
        let params = self.param_layout();
        let param_count: usize = params.iter().map(|p| p.size).sum();
        let state_len = 3 * param_count + self.n_scalars;
        let mut artifacts = BTreeMap::new();
        for key in &self.artifact_keys {
            artifacts.insert(
                key.clone(),
                ArtifactDef {
                    file: PathBuf::from(format!("{}/{key}.hlo.txt", self.name)),
                    args: self.artifact_args(key, param_count, state_len),
                },
            );
        }
        ModelEntry {
            name: self.name.clone(),
            d_model: self.d_model,
            n_heads: self.n_heads,
            d_ff: self.d_ff,
            blocks: self.blocks.clone(),
            n_experts: self.n_experts,
            vocab: self.vocab,
            seq_len: self.seq_len,
            batch: self.batch,
            vision: self.vision,
            vision_grid: self.vision_grid,
            vision_patch: self.vision_patch,
            param_count,
            state_len,
            quant: QuantSettings {
                weights: self.weights.clone(),
                acts: self.acts.clone(),
                impl_: "ref".into(),
                skip_attention: self.skip_attention,
                skip_first: self.skip_first,
                skip_last: self.skip_last,
            },
            params,
            artifacts,
        }
    }
}

/// Serialize synthetic specs as a full manifest.json body (version 4) —
/// what hermetic integration tests write to a temp artifacts dir so the
/// whole `Manifest::load` → `Engine` path is exercised.
pub fn synthetic_manifest_json(specs: &[SynthSpec]) -> String {
    let n_scalars = specs.first().map(|s| s.n_scalars).unwrap_or(8);
    let vocab = specs.first().map(|s| s.vocab).unwrap_or(64);
    // The manifest header carries one global vocab / scalar-block size;
    // heterogeneous specs would silently disagree with their own entries.
    for s in specs {
        assert_eq!(s.vocab, vocab, "all SynthSpecs in one manifest share a vocab");
        assert_eq!(s.n_scalars, n_scalars, "all SynthSpecs share n_scalars");
    }
    let mut models = Vec::new();
    for spec in specs {
        let entry = spec.entry();
        let params = Json::Arr(
            entry
                .params
                .iter()
                .map(|p| {
                    Json::obj(vec![
                        ("name", Json::Str(p.name.clone())),
                        (
                            "shape",
                            Json::Arr(p.shape.iter().map(|&v| Json::Num(v as f64)).collect()),
                        ),
                        ("offset", Json::Num(p.offset as f64)),
                        ("size", Json::Num(p.size as f64)),
                    ])
                })
                .collect(),
        );
        let artifacts = Json::Obj(
            entry
                .artifacts
                .iter()
                .map(|(key, a)| {
                    let args = Json::Arr(
                        a.args
                            .iter()
                            .map(|arg| {
                                Json::obj(vec![
                                    ("name", Json::Str(arg.name.clone())),
                                    (
                                        "shape",
                                        Json::Arr(
                                            arg.shape
                                                .iter()
                                                .map(|&v| Json::Num(v as f64))
                                                .collect(),
                                        ),
                                    ),
                                    ("dtype", Json::Str(arg.dtype.clone())),
                                ])
                            })
                            .collect(),
                    );
                    (
                        key.clone(),
                        Json::obj(vec![
                            ("file", Json::Str(format!("{}/{key}.hlo.txt", spec.name))),
                            ("args", args),
                        ]),
                    )
                })
                .collect(),
        );
        models.push((
            spec.name.clone(),
            Json::obj(vec![
                ("d_model", Json::Num(entry.d_model as f64)),
                ("n_heads", Json::Num(entry.n_heads as f64)),
                ("d_ff", Json::Num(entry.d_ff as f64)),
                (
                    "blocks",
                    Json::Arr(entry.blocks.iter().map(|b| Json::Str(b.clone())).collect()),
                ),
                ("n_experts", Json::Num(entry.n_experts as f64)),
                ("vocab", Json::Num(entry.vocab as f64)),
                ("seq_len", Json::Num(entry.seq_len as f64)),
                ("batch", Json::Num(entry.batch as f64)),
                ("vision", Json::Bool(entry.vision)),
                ("vision_grid", Json::Num(entry.vision_grid as f64)),
                ("vision_patch", Json::Num(entry.vision_patch as f64)),
                ("param_count", Json::Num(entry.param_count as f64)),
                ("state_len", Json::Num(entry.state_len as f64)),
                (
                    "quant",
                    Json::obj(vec![
                        ("weights", Json::Str(entry.quant.weights.clone())),
                        ("acts", Json::Str(entry.quant.acts.clone())),
                        ("impl", Json::Str(entry.quant.impl_.clone())),
                        ("skip_attention", Json::Bool(entry.quant.skip_attention)),
                        ("skip_first", Json::Num(entry.quant.skip_first as f64)),
                        ("skip_last", Json::Num(entry.quant.skip_last as f64)),
                    ]),
                ),
                ("params", params),
                ("artifacts", artifacts),
            ]),
        ));
    }
    Json::obj(vec![
        ("version", Json::Num(SUPPORTED_VERSION as f64)),
        ("vocab", Json::Num(vocab as f64)),
        (
            "special",
            Json::obj(vec![
                ("pad", Json::Num(0.0)),
                ("bos", Json::Num(1.0)),
                ("eos", Json::Num(2.0)),
                ("sep", Json::Num(3.0)),
            ]),
        ),
        ("n_scalars", Json::Num(n_scalars as f64)),
        (
            "scalar_names",
            Json::Arr(
                ["step", "loss", "kl", "ce", "grad_norm", "lr", "aux0", "aux1"]
                    .iter()
                    .map(|s| Json::Str(s.to_string()))
                    .collect(),
            ),
        ),
        ("models", Json::Obj(models)),
    ])
    .pretty()
}

#[cfg(test)]
mod synth_tests {
    use super::*;

    #[test]
    fn synth_entry_layout_is_consistent() {
        let spec = SynthSpec::small("t");
        let e = spec.entry();
        let laid: usize = e.params.iter().map(|p| p.size).sum();
        assert_eq!(laid, e.param_count);
        assert_eq!(e.state_len, 3 * e.param_count + 8);
        // layout is contiguous
        let mut off = 0;
        for p in &e.params {
            assert_eq!(p.offset, off, "{}", p.name);
            off += p.size;
        }
        assert!(e.artifacts.contains_key("fwd_bf16"));
        assert_eq!(e.artifacts["sft_bf16"].args.len(), 4);
        assert_eq!(e.artifacts["qad_nvfp4"].args.len(), 5);
        assert_eq!(e.artifacts["rl_bf16"].args[3].name, "advantage");
        assert_eq!(e.artifacts["fwd_last_bf16"].args[2].name, "frontier_idx");
    }

    #[test]
    fn synth_manifest_round_trips_through_load() {
        let dir = std::env::temp_dir().join("qadx_synth_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut spec = SynthSpec::small("round");
        spec.blocks = vec!["attn".into(), "ssm".into(), "moe".into()];
        spec.n_experts = 3;
        let text = synthetic_manifest_json(&[spec.clone()]);
        std::fs::write(dir.join("manifest.json"), text).unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.model("round").unwrap();
        let want = spec.entry();
        assert_eq!(e.param_count, want.param_count);
        assert_eq!(e.state_len, want.state_len);
        assert_eq!(e.n_experts, 3);
        assert_eq!(e.blocks, want.blocks);
        assert_eq!(e.params.len(), want.params.len());
        for (a, b) in e.params.iter().zip(&want.params) {
            assert_eq!((a.name.as_str(), &a.shape, a.offset, a.size),
                       (b.name.as_str(), &b.shape, b.offset, b.size));
        }
        assert_eq!(e.artifacts.len(), want.artifacts.len());
        std::fs::remove_dir_all(&dir).ok();
    }
}
