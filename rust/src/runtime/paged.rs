//! Paged decode-state storage: a refcounted free-list page allocator plus
//! copy-on-write position sequences.
//!
//! Dense decode rows (PR 5) reserve `seq_len × d_model` floats per K/V
//! sequence up front, so slot count is bounded by worst-case memory and
//! two requests sharing a prompt prefix each hold a private copy of it.
//! This module stores a sequence as a list of fixed-size pages
//! ([`PagedKv`]) drawn from a shared pool ([`PagePool`]):
//!
//! * memory is bounded by **live tokens** — `ceil(t / page_size)` pages
//!   per sequence — not `max_slots × seq_len`;
//! * pages are refcounted, so a prefix cache can hand the same prefilled
//!   pages to many rows; a row appending into a shared page first copies
//!   the valid prefix into a fresh page (copy-on-write), leaving the
//!   donor untouched;
//! * freed pages return to a LIFO free list and are reused without
//!   reallocating, so steady-state serving does not grow the pool.
//!
//! Bit-exactness: paging only changes *where* a position's `d` floats
//! live, never their values or the order downstream loops reduce them in.
//! [`PagedKv::row`] returns exactly the `d`-float slice the dense layout
//! holds for that position, so attention chains stay bit-identical to the
//! dense path (pinned by rust/tests/decode_equivalence.rs).

use anyhow::{bail, Result};

use crate::quant::packed::KernelTier;

/// Knobs for opening a stateful decode session (see
/// [`crate::runtime::backend::ExecBackend::open_decode`]). The default is
/// the PR 5 behavior: dense rows, no prefix cache, unbounded state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct DecodeOpts {
    /// Positions per K/V page. `0` keeps the dense per-slot layout
    /// (one `seq_len × d` buffer per sequence).
    pub page_size: usize,
    /// Prefix-cache capacity in entries (`0` = off). Requires a paged
    /// layout (`page_size > 0`): cached prefixes donate pages by
    /// refcount, which dense rows cannot share.
    pub prefix_cache: usize,
    /// Page budget across all rows plus cached prefixes (`0` =
    /// unbounded). When tight, LRU prefix entries are evicted before a
    /// prefill/step fails cleanly.
    pub max_pages: usize,
    /// GEMM kernel tier for this session; `None` follows the process
    /// default (`--kernel` / `QADX_KERNEL`, else the exact f32 tier).
    pub kernel: Option<KernelTier>,
}

impl Default for DecodeOpts {
    fn default() -> DecodeOpts {
        DecodeOpts { page_size: 0, prefix_cache: 0, max_pages: 0, kernel: None }
    }
}

/// Allocator gauges reported by a paged decode session
/// (`DecodeSession::paged_stats`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PagedStats {
    pub page_size: usize,
    /// Pages currently referenced by at least one row or cached prefix.
    pub live_pages: usize,
    /// Pages sitting on the free list, ready for reuse.
    pub free_pages: usize,
    pub prefix_entries: usize,
    pub prefix_hits: u64,
    pub prefix_misses: u64,
    /// Copy-on-write page copies (divergence after a shared prefix).
    pub cow_copies: u64,
    /// Bytes of bound decode weights (f32 copies on the exact tier,
    /// packed nibbles + scales on the packed tier).
    pub decode_weight_bytes: usize,
}

/// A slab of fixed-size pages with per-page refcounts and a LIFO free
/// list. Page ids are dense indices into the slab; the slab only grows
/// (up to `max_pages`), freed pages are recycled in LIFO order so reuse
/// is deterministic.
pub struct PagePool {
    /// Positions per page.
    page_size: usize,
    /// Floats per position (`d_model` for K/V rows).
    width: usize,
    /// Slab growth bound in pages; `0` = unbounded.
    max_pages: usize,
    data: Vec<f32>,
    refs: Vec<u32>,
    free: Vec<u32>,
    live: usize,
    cow_copies: u64,
}

impl PagePool {
    pub fn new(page_size: usize, width: usize, max_pages: usize) -> PagePool {
        PagePool {
            page_size: page_size.max(1),
            width: width.max(1),
            max_pages,
            data: Vec::new(),
            refs: Vec::new(),
            free: Vec::new(),
            live: 0,
            cow_copies: 0,
        }
    }

    pub fn page_size(&self) -> usize {
        self.page_size
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn max_pages(&self) -> usize {
        self.max_pages
    }

    fn floats_per_page(&self) -> usize {
        self.page_size * self.width
    }

    /// Pages that can still be handed out without violating `max_pages`:
    /// the free list plus remaining slab headroom (`usize::MAX` when
    /// unbounded).
    pub fn available(&self) -> usize {
        if self.max_pages == 0 {
            usize::MAX
        } else {
            self.free.len() + self.max_pages.saturating_sub(self.refs.len())
        }
    }

    pub fn live_pages(&self) -> usize {
        self.live
    }

    pub fn free_pages(&self) -> usize {
        self.free.len()
    }

    pub fn cow_copies(&self) -> u64 {
        self.cow_copies
    }

    pub fn ref_count(&self, id: u32) -> u32 {
        self.refs.get(id as usize).copied().unwrap_or(0)
    }

    /// Hand out a page with refcount 1: most recently freed page first,
    /// else grow the slab (stale floats in a recycled page are never read
    /// — sequences only read positions they wrote).
    pub fn alloc(&mut self) -> Result<u32> {
        if let Some(id) = self.free.pop() {
            if let Some(r) = self.refs.get_mut(id as usize) {
                *r = 1;
            }
            self.live += 1;
            return Ok(id);
        }
        if self.max_pages > 0 && self.refs.len() >= self.max_pages {
            bail!(
                "page budget exhausted ({} pages of {} positions, max_pages {})",
                self.refs.len(),
                self.page_size,
                self.max_pages
            );
        }
        let id = self.refs.len() as u32;
        self.refs.push(1);
        let fp = self.floats_per_page();
        self.data.resize(self.data.len() + fp, 0.0);
        self.live += 1;
        Ok(id)
    }

    /// Add one reference to a live page (prefix-cache sharing).
    pub fn retain(&mut self, id: u32) {
        if let Some(r) = self.refs.get_mut(id as usize) {
            if *r > 0 {
                *r += 1;
            }
        }
    }

    /// Drop one reference; the page joins the free list when the count
    /// hits zero. Releasing an already-free page is a no-op.
    pub fn release(&mut self, id: u32) {
        let Some(r) = self.refs.get_mut(id as usize) else { return };
        if *r == 0 {
            return;
        }
        *r -= 1;
        if *r == 0 {
            self.free.push(id);
            self.live -= 1;
        }
    }

    pub fn page(&self, id: u32) -> &[f32] {
        let fp = self.floats_per_page();
        let start = id as usize * fp;
        &self.data[start..start + fp]
    }

    pub fn page_mut(&mut self, id: u32) -> &mut [f32] {
        let fp = self.floats_per_page();
        let start = id as usize * fp;
        &mut self.data[start..start + fp]
    }

    /// Copy the first `floats` of `src` into `dst` (the COW body).
    fn copy_prefix(&mut self, src: u32, dst: u32, floats: usize) {
        let fp = self.floats_per_page();
        let s = src as usize * fp;
        let d = dst as usize * fp;
        self.data.copy_within(s..s + floats, d);
    }
}

/// One position sequence stored as pool pages: `len` valid positions of
/// `width` floats each, `page_size` positions per page. No `Clone` —
/// sharing pages must go through [`PagedKv::fork`] so refcounts stay
/// honest.
#[derive(Debug, Default)]
pub struct PagedKv {
    pages: Vec<u32>,
    len: usize,
}

impl PagedKv {
    /// Valid positions.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn page_count(&self) -> usize {
        self.pages.len()
    }

    /// Append one `width`-float position row. At most one page allocation
    /// per call: a fresh page at a page boundary, or a copy-on-write
    /// replacement when the tail page is shared with a cached prefix (or
    /// a sibling fork) — the donor's floats are never touched.
    pub fn push(&mut self, pool: &mut PagePool, row: &[f32]) -> Result<()> {
        let (psz, w) = (pool.page_size(), pool.width());
        if row.len() != w {
            bail!("paged push of {} floats into width-{w} pool", row.len());
        }
        let within = self.len % psz;
        if within == 0 {
            let id = pool.alloc()?;
            self.pages.push(id);
        } else if let Some(&last) = self.pages.last() {
            if pool.ref_count(last) > 1 {
                let fresh = pool.alloc()?;
                pool.copy_prefix(last, fresh, within * w);
                pool.release(last);
                pool.cow_copies += 1;
                if let Some(slot) = self.pages.last_mut() {
                    *slot = fresh;
                }
            }
        }
        let Some(&page) = self.pages.last() else {
            bail!("paged sequence lost its tail page");
        };
        let off = within * w;
        pool.page_mut(page)[off..off + w].copy_from_slice(row);
        self.len += 1;
        Ok(())
    }

    /// The `width` floats of position `j` — the same slice a dense
    /// `Vec<f32>` layout holds at `j * width`.
    pub fn row<'p>(&self, pool: &'p PagePool, j: usize) -> &'p [f32] {
        let (psz, w) = (pool.page_size(), pool.width());
        debug_assert!(j < self.len, "position {j} past len {}", self.len);
        let page = self.pages[j / psz];
        let off = (j % psz) * w;
        &pool.page(page)[off..off + w]
    }

    /// Share the first `upto` positions: the covering pages gain a
    /// reference each and the fork starts at `len == upto`. Appends into
    /// a partially-covered tail page copy-on-write instead of clobbering
    /// the donor.
    pub fn fork(&self, pool: &mut PagePool, upto: usize) -> PagedKv {
        let psz = pool.page_size();
        let upto = upto.min(self.len);
        let n_pages = upto.div_ceil(psz);
        let mut pages = Vec::with_capacity(n_pages);
        for &id in self.pages.iter().take(n_pages) {
            pool.retain(id);
            pages.push(id);
        }
        PagedKv { pages, len: upto }
    }

    /// Drop every page reference and reset to empty.
    pub fn clear(&mut self, pool: &mut PagePool) {
        for &id in &self.pages {
            pool.release(id);
        }
        self.pages.clear();
        self.len = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rowv(w: usize, v: f32) -> Vec<f32> {
        vec![v; w]
    }

    #[test]
    fn alloc_release_recycles_lifo() {
        let mut p = PagePool::new(4, 2, 0);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_eq!((a, b), (0, 1));
        assert_eq!(p.live_pages(), 2);
        p.release(a);
        p.release(b);
        assert_eq!(p.live_pages(), 0);
        assert_eq!(p.free_pages(), 2);
        // LIFO: most recently freed first, slab does not grow
        assert_eq!(p.alloc().unwrap(), b);
        assert_eq!(p.alloc().unwrap(), a);
        assert_eq!(p.free_pages(), 0);
    }

    #[test]
    fn release_is_refcounted_and_idempotent_at_zero() {
        let mut p = PagePool::new(2, 1, 0);
        let a = p.alloc().unwrap();
        p.retain(a);
        assert_eq!(p.ref_count(a), 2);
        p.release(a);
        assert_eq!(p.live_pages(), 1);
        p.release(a);
        assert_eq!(p.live_pages(), 0);
        p.release(a); // double-release must not underflow or re-free
        assert_eq!(p.free_pages(), 1);
        assert_eq!(p.ref_count(a), 0);
    }

    #[test]
    fn budget_exhaustion_errors_cleanly() {
        let mut p = PagePool::new(2, 1, 2);
        assert_eq!(p.available(), 2);
        let a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        assert_eq!(p.available(), 0);
        let err = p.alloc().unwrap_err().to_string();
        assert!(err.contains("page budget exhausted"), "{err}");
        p.release(a);
        assert_eq!(p.available(), 1);
        assert!(p.alloc().is_ok());
    }

    #[test]
    fn push_and_row_roundtrip_across_page_boundaries() {
        for psz in [1usize, 3, 4, 16] {
            let mut p = PagePool::new(psz, 3, 0);
            let mut s = PagedKv::default();
            for i in 0..10 {
                s.push(&mut p, &rowv(3, i as f32)).unwrap();
            }
            assert_eq!(s.len(), 10);
            assert_eq!(s.page_count(), 10usize.div_ceil(psz));
            for i in 0..10 {
                assert_eq!(s.row(&p, i), &rowv(3, i as f32)[..], "psz {psz} pos {i}");
            }
            s.clear(&mut p);
            assert_eq!(p.live_pages(), 0);
        }
    }

    #[test]
    fn fork_shares_pages_then_cow_on_divergence() {
        let mut p = PagePool::new(4, 2, 0);
        let mut donor = PagedKv::default();
        for i in 0..6 {
            donor.push(&mut p, &rowv(2, i as f32)).unwrap();
        }
        // 6 positions over 4-position pages = 2 pages, tail half-full
        assert_eq!(p.live_pages(), 2);
        let mut fork = donor.fork(&mut p, 6);
        assert_eq!(p.live_pages(), 2); // shared, no copy yet
        assert_eq!(fork.len(), 6);
        // divergence: fork appends -> COW copies the shared tail page
        fork.push(&mut p, &rowv(2, 100.0)).unwrap();
        assert_eq!(p.cow_copies(), 1);
        assert_eq!(p.live_pages(), 3);
        assert_eq!(fork.row(&p, 6), &rowv(2, 100.0)[..]);
        // donor is untouched, including the position the fork diverged at
        assert_eq!(donor.len(), 6);
        for i in 0..6 {
            assert_eq!(donor.row(&p, i), &rowv(2, i as f32)[..]);
            assert_eq!(fork.row(&p, i), &rowv(2, i as f32)[..]);
        }
        fork.clear(&mut p);
        donor.clear(&mut p);
        assert_eq!(p.live_pages(), 0);
    }

    #[test]
    fn fork_at_page_boundary_needs_no_cow() {
        let mut p = PagePool::new(4, 1, 0);
        let mut donor = PagedKv::default();
        for i in 0..4 {
            donor.push(&mut p, &[i as f32]).unwrap();
        }
        let mut fork = donor.fork(&mut p, 4);
        fork.push(&mut p, &[9.0]).unwrap(); // fresh page, donor's is full
        assert_eq!(p.cow_copies(), 0);
        assert_eq!(p.live_pages(), 2);
        fork.clear(&mut p);
        // donor's page survives its own reference
        assert_eq!(p.live_pages(), 1);
        donor.clear(&mut p);
        assert_eq!(p.live_pages(), 0);
    }

    #[test]
    fn two_forks_diverge_independently() {
        let mut p = PagePool::new(4, 1, 0);
        let mut donor = PagedKv::default();
        for i in 0..2 {
            donor.push(&mut p, &[i as f32]).unwrap();
        }
        let mut fa = donor.fork(&mut p, 2);
        let mut fb = donor.fork(&mut p, 2);
        fa.push(&mut p, &[10.0]).unwrap();
        fb.push(&mut p, &[20.0]).unwrap();
        assert_eq!(p.cow_copies(), 2);
        assert_eq!(fa.row(&p, 2), &[10.0][..]);
        assert_eq!(fb.row(&p, 2), &[20.0][..]);
        assert_eq!(donor.len(), 2);
        for s in [&mut fa, &mut fb, &mut donor] {
            s.clear(&mut p);
        }
        assert_eq!(p.live_pages(), 0);
        assert_eq!(p.free_pages(), 3);
    }

    #[test]
    fn decode_opts_default_is_dense() {
        let o = DecodeOpts::default();
        assert_eq!(o, DecodeOpts { page_size: 0, prefix_cache: 0, max_pages: 0, kernel: None });
    }
}
