//! PJRT runtime: load AOT HLO-text artifacts, compile once, execute from
//! the Rust hot path. Python never runs here.
//!
//! Training state stays **device-resident**: every train-step artifact maps
//! `state -> state'` as a single flat f32 array, so the output buffer of
//! step t feeds `execute_b` of step t+1 without touching the host. Only the
//! 8-float scalar metrics block is copied back per step
//! (`copy_raw_to_host_sync` with an offset).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};
use xla::{HloModuleProto, PjRtBuffer, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use super::manifest::{ArtifactDef, Manifest, ModelEntry};

pub struct Engine {
    pub client: PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<PathBuf, Rc<PjRtLoadedExecutable>>>,
}

impl Engine {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn load(&self, art: &ArtifactDef) -> Result<Rc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&art.file) {
            return Ok(exe.clone());
        }
        let path_str = art
            .file
            .to_str()
            .with_context(|| format!("non-utf8 path {:?}", art.file))?;
        let proto = HloModuleProto::from_text_file(path_str)
            .with_context(|| format!("parsing HLO text {:?}", art.file))?;
        let comp = XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {:?}", art.file))?,
        );
        self.cache.borrow_mut().insert(art.file.clone(), exe.clone());
        Ok(exe)
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(data, dims, None)?)
    }

    /// Upload a rank-0 f32 scalar.
    ///
    /// Deliberately NOT `buffer_from_host_literal`: that call maps to
    /// `BufferFromHostLiteral`, which copies *asynchronously* on a PJRT
    /// worker thread — a temporary `Literal` would be freed mid-copy
    /// (observed SIGSEGV in `ShapeUtil::ByteSizeOf`). `buffer_from_host_buffer`
    /// uses `kImmutableOnlyDuringCall` semantics (synchronous copy).
    pub fn upload_scalar(&self, v: f32) -> Result<PjRtBuffer> {
        Ok(self.client.buffer_from_host_buffer(&[v], &[], None)?)
    }

    /// Execute with device-resident args; returns the first (only) output.
    pub fn run_b(&self, exe: &PjRtLoadedExecutable, args: &[&PjRtBuffer]) -> Result<PjRtBuffer> {
        let mut out = exe.execute_b(args)?;
        let replica = out.pop().context("no execution output")?;
        replica.into_iter().next().context("empty replica output")
    }

    /// Download a full f32 buffer to the host.
    ///
    /// Goes through `to_literal_sync` — the TFRT CPU plugin does not
    /// implement `CopyRawToHost`, so partial/offset reads are impossible;
    /// small reads use dedicated slicing artifacts instead (see
    /// `DeviceState::scalars`).
    pub fn download_f32(&self, buf: &PjRtBuffer, len: usize) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.download_f32_into(buf, len, &mut out)?;
        Ok(out)
    }

    /// Download an f32 buffer into a caller-held vector (decode hot loop).
    ///
    /// The literal path always materializes a fresh Vec, so this moves the
    /// download into `out` and frees the previous backing store — callers
    /// hold one live logits buffer per step instead of two, and the
    /// hot-loop call sites stay shaped for true reuse if the xla crate
    /// grows a copy-into API.
    pub fn download_f32_into(
        &self,
        buf: &PjRtBuffer,
        len: usize,
        out: &mut Vec<f32>,
    ) -> Result<()> {
        let lit = buf.to_literal_sync()?;
        let v: Vec<f32> = lit.to_vec()?;
        if v.len() != len {
            bail!("downloaded {} elements, expected {}", v.len(), len);
        }
        *out = v;
        Ok(())
    }
}

/// A host-side batch matching the artifact input layout.
#[derive(Clone, Debug, Default)]
pub struct Batch {
    pub tokens: Vec<i32>,       // (B, S) row-major
    pub mask: Vec<f32>,         // (B, S)
    pub pixels: Option<Vec<f32>>, // (B, G*G, patch) for VLM models
    pub advantage: Option<Vec<f32>>, // (B,) for RL steps
}

/// Per-model executable registry + shape checking.
pub struct ModelRuntime<'e> {
    pub engine: &'e Engine,
    pub model: ModelEntry,
}

impl<'e> ModelRuntime<'e> {
    pub fn new(engine: &'e Engine, model_name: &str) -> Result<ModelRuntime<'e>> {
        let model = engine.manifest.model(model_name)?.clone();
        Ok(ModelRuntime { engine, model })
    }

    pub fn exe(&self, key: &str) -> Result<Rc<PjRtLoadedExecutable>> {
        self.engine.load(self.model.artifact(key)?)
    }

    /// Upload the pieces of a batch as device buffers in manifest arg order
    /// (tokens, mask[, advantage][, pixels] — the caller interleaves state /
    /// params / lr as required by the specific artifact).
    pub fn upload_tokens(&self, batch: &Batch) -> Result<PjRtBuffer> {
        let (b, s) = (self.model.batch, self.model.seq_len);
        if batch.tokens.len() != b * s {
            bail!("tokens len {} != {}x{}", batch.tokens.len(), b, s);
        }
        self.engine.upload_i32(&batch.tokens, &[b, s])
    }

    pub fn upload_mask(&self, batch: &Batch) -> Result<PjRtBuffer> {
        let (b, s) = (self.model.batch, self.model.seq_len);
        self.engine.upload_f32(&batch.mask, &[b, s])
    }

    pub fn upload_pixels(&self, batch: &Batch) -> Result<Option<PjRtBuffer>> {
        if !self.model.vision {
            return Ok(None);
        }
        let px = batch
            .pixels
            .as_ref()
            .context("VLM model requires pixels in the batch")?;
        let dims = [
            self.model.batch,
            self.model.vision_grid * self.model.vision_grid,
            self.model.vision_patch,
        ];
        Ok(Some(self.engine.upload_f32(px, &dims)?))
    }

    pub fn upload_advantage(&self, batch: &Batch) -> Result<PjRtBuffer> {
        let adv = batch.advantage.as_ref().context("RL step requires advantages")?;
        self.engine.upload_f32(adv, &[self.model.batch])
    }

    /// Upload a parameter vector (teacher weights, PTQ weights, ...).
    pub fn upload_params(&self, params: &[f32]) -> Result<PjRtBuffer> {
        if params.len() != self.model.param_count {
            bail!(
                "params len {} != param_count {}",
                params.len(),
                self.model.param_count
            );
        }
        self.engine.upload_f32(params, &[self.model.param_count])
    }
}

/// Device-resident training state (the single flat vector).
pub struct DeviceState {
    pub buf: PjRtBuffer,
    pub state_len: usize,
    pub scalars_off: usize,
    pub n_scalars: usize,
    pub param_count: usize,
    /// The `scalars` slicing artifact (state -> f32[8]); compiled once.
    scalars_exe: Rc<PjRtLoadedExecutable>,
}

impl DeviceState {
    /// Build a fresh state (params + zeroed Adam moments + zeroed scalars)
    /// and upload it.
    pub fn from_params(rt: &ModelRuntime, params: &[f32]) -> Result<DeviceState> {
        let m = &rt.model;
        if params.len() != m.param_count {
            bail!("params len {} != {}", params.len(), m.param_count);
        }
        let mut state = vec![0f32; m.state_len];
        state[..m.param_count].copy_from_slice(params);
        Self::from_state_vec(rt, &state)
    }

    /// Upload a full pre-built state vector (checkpoint resume).
    pub fn from_state_vec(rt: &ModelRuntime, state: &[f32]) -> Result<DeviceState> {
        let m = &rt.model;
        if state.len() != m.state_len {
            bail!("state len {} != {}", state.len(), m.state_len);
        }
        let buf = rt.engine.upload_f32(state, &[m.state_len])?;
        let scalars_exe = rt.engine.load(m.artifact("scalars")?)?;
        Ok(DeviceState {
            buf,
            state_len: m.state_len,
            scalars_off: m.scalars_offset(),
            n_scalars: rt.engine.manifest.n_scalars,
            param_count: m.param_count,
            scalars_exe,
        })
    }

    /// Advance: replace the device buffer with the step output.
    pub fn advance(&mut self, new_buf: PjRtBuffer) {
        self.buf = new_buf;
    }

    /// A sibling state viewing another buffer of the same layout (used for
    /// scratch validation states that are dropped after reading metrics).
    pub fn like(&self, buf: PjRtBuffer) -> DeviceState {
        DeviceState {
            buf,
            state_len: self.state_len,
            scalars_off: self.scalars_off,
            n_scalars: self.n_scalars,
            param_count: self.param_count,
            scalars_exe: self.scalars_exe.clone(),
        }
    }

    /// Read the 8-float metrics block via the device-side `scalars`
    /// slicing artifact (cheap; never copies params to the host).
    pub fn scalars(&self) -> Result<Vec<f32>> {
        let mut out = self.scalars_exe.execute_b(&[&self.buf])?;
        let replica = out.pop().context("no scalars output")?;
        let buf = replica.into_iter().next().context("empty scalars output")?;
        let v: Vec<f32> = buf.to_literal_sync()?.to_vec()?;
        if v.len() != self.n_scalars {
            bail!("scalars artifact returned {} values", v.len());
        }
        Ok(v)
    }

    /// Download just the parameter slice (full state copy, then truncate —
    /// the CPU plugin has no partial reads; called only at checkpoints).
    pub fn params(&self) -> Result<Vec<f32>> {
        let mut full = self.full()?;
        full.truncate(self.param_count);
        Ok(full)
    }

    /// Download the full state (checkpointing).
    pub fn full(&self) -> Result<Vec<f32>> {
        let v: Vec<f32> = self.buf.to_literal_sync()?.to_vec()?;
        if v.len() != self.state_len {
            bail!("state download returned {} values", v.len());
        }
        Ok(v)
    }
}

/// Well-known scalar slots (matches python/compile/steps.py).
pub mod scalar {
    pub const STEP: usize = 0;
    pub const LOSS: usize = 1;
    pub const KL: usize = 2;
    pub const CE: usize = 3;
    pub const GRAD_NORM: usize = 4;
    pub const LR: usize = 5;
}
