//! Backend-agnostic runtime core: `Engine` owns an [`ExecBackend`] plus the
//! artifact manifest and an executable cache; `ModelRuntime` binds one
//! manifest model; `DeviceState` keeps the packed training state
//! device-resident across steps.
//!
//! Training state stays **device-resident**: every train-step artifact maps
//! `state -> state'` as a single flat f32 array, so the output buffer of
//! step t feeds the next execute without touching the host. Only the
//! 8-float scalar metrics block is copied back per step (via the `scalars`
//! slicing artifact).
//!
//! No concrete backend type appears here or anywhere above this layer —
//! the PJRT client lives behind `runtime::pjrt`, the pure-Rust interpreter
//! behind `runtime::reference`, both selectable per engine (see
//! [`BackendKind`]).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use super::backend::{make_backend, BackendKind, Buffer, DecodeSession, ExecBackend, Executable};
use super::manifest::{Manifest, ModelEntry};
use super::paged::DecodeOpts;

pub struct Engine {
    backend: Rc<dyn ExecBackend>,
    kind: BackendKind,
    pub manifest: Manifest,
    // qadx-lint: allow(nondet-iteration) -- exe cache is get/insert only; it never iterates into output
    cache: RefCell<HashMap<(String, String), Rc<Executable>>>,
}

impl Engine {
    /// Load the artifact manifest and construct the default backend
    /// (`QADX_BACKEND` env override, else PJRT when compiled in).
    pub fn new(artifacts_dir: &Path) -> Result<Engine> {
        Engine::with_backend(artifacts_dir, BackendKind::resolve(None)?)
    }

    /// Load the manifest on an explicitly chosen backend.
    pub fn with_backend(artifacts_dir: &Path, kind: BackendKind) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let backend = make_backend(kind)?;
        // qadx-lint: allow(nondet-iteration) -- exe cache is get/insert only; it never iterates into output
        Ok(Engine { backend, kind, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Which backend this engine executes on.
    pub fn backend_kind(&self) -> BackendKind {
        self.kind
    }

    pub(crate) fn backend(&self) -> Rc<dyn ExecBackend> {
        self.backend.clone()
    }

    /// Compile (or fetch from cache) the executable for `key` of `model`.
    pub fn load(&self, model: &ModelEntry, key: &str) -> Result<Rc<Executable>> {
        let cache_key = (model.name.clone(), key.to_string());
        if let Some(exe) = self.cache.borrow().get(&cache_key) {
            return Ok(exe.clone());
        }
        let exe = Rc::new(self.backend.compile(&self.manifest, model, key)?);
        self.cache.borrow_mut().insert(cache_key, exe.clone());
        Ok(exe)
    }

    pub fn upload_f32(&self, data: &[f32], dims: &[usize]) -> Result<Buffer> {
        self.backend.upload_f32(data, dims)
    }

    pub fn upload_i32(&self, data: &[i32], dims: &[usize]) -> Result<Buffer> {
        self.backend.upload_i32(data, dims)
    }

    /// Upload a rank-0 f32 scalar.
    pub fn upload_scalar(&self, v: f32) -> Result<Buffer> {
        self.backend.upload_f32(&[v], &[])
    }

    /// Execute with device-resident args; returns the single output.
    pub fn run_b(&self, exe: &Executable, args: &[&Buffer]) -> Result<Buffer> {
        self.backend.execute(exe, args)
    }

    /// Download a full f32 buffer to the host.
    pub fn download_f32(&self, buf: &Buffer, len: usize) -> Result<Vec<f32>> {
        let mut out = Vec::new();
        self.download_f32_into(buf, len, &mut out)?;
        Ok(out)
    }

    /// Download an f32 buffer into a caller-held vector (decode hot loop).
    ///
    /// Hardened on element count: when the buffer knows its logical shape,
    /// a `len` mismatch errors *before* touching the backend, and every
    /// backend re-verifies the actual element count after the transfer —
    /// a wrong caller-supplied length can never silently truncate or pad.
    pub fn download_f32_into(&self, buf: &Buffer, len: usize, out: &mut Vec<f32>) -> Result<()> {
        if let Some(n) = buf.element_count() {
            if n != len {
                bail!("download of {len} elements requested from a buffer holding {n}");
            }
        }
        self.backend.download_f32(buf, len, out)
    }

    /// Probe/open the backend's stateful-decode capability for one plain
    /// `fwd_*` artifact (see [`DecodeSession`]) with the default dense
    /// state layout. `Ok(None)` means the backend only supports stateless
    /// decode — callers fall back to the frontier/full-logits path.
    pub fn open_decode(
        &self,
        model: &ModelEntry,
        fwd_key: &str,
        weights: &Buffer,
        rows: usize,
    ) -> Result<Option<Box<dyn DecodeSession>>> {
        self.open_decode_opts(model, fwd_key, weights, rows, &DecodeOpts::default())
    }

    /// [`Engine::open_decode`] with an explicit state layout: paged K/V
    /// pages, a shared-prefix cache, and/or a page budget (see
    /// [`DecodeOpts`]).
    pub fn open_decode_opts(
        &self,
        model: &ModelEntry,
        fwd_key: &str,
        weights: &Buffer,
        rows: usize,
        opts: &DecodeOpts,
    ) -> Result<Option<Box<dyn DecodeSession>>> {
        self.backend.open_decode(&self.manifest, model, fwd_key, weights, rows, opts)
    }
}

/// A host-side batch matching the artifact input layout.
#[derive(Clone, Debug, Default)]
pub struct Batch {
    pub tokens: Vec<i32>,       // (B, S) row-major
    pub mask: Vec<f32>,         // (B, S)
    pub pixels: Option<Vec<f32>>, // (B, G*G, patch) for VLM models
    pub advantage: Option<Vec<f32>>, // (B,) for RL steps
}

/// Per-model executable registry + shape checking.
pub struct ModelRuntime<'e> {
    pub engine: &'e Engine,
    pub model: ModelEntry,
}

impl<'e> ModelRuntime<'e> {
    pub fn new(engine: &'e Engine, model_name: &str) -> Result<ModelRuntime<'e>> {
        let model = engine.manifest.model(model_name)?.clone();
        Ok(ModelRuntime { engine, model })
    }

    pub fn exe(&self, key: &str) -> Result<Rc<Executable>> {
        self.engine.load(&self.model, key)
    }

    /// Upload the pieces of a batch as device buffers in manifest arg order
    /// (tokens, mask[, advantage][, pixels] — the caller interleaves state /
    /// params / lr as required by the specific artifact).
    pub fn upload_tokens(&self, batch: &Batch) -> Result<Buffer> {
        let (b, s) = (self.model.batch, self.model.seq_len);
        if batch.tokens.len() != b * s {
            bail!("tokens len {} != {}x{}", batch.tokens.len(), b, s);
        }
        self.engine.upload_i32(&batch.tokens, &[b, s])
    }

    pub fn upload_mask(&self, batch: &Batch) -> Result<Buffer> {
        let (b, s) = (self.model.batch, self.model.seq_len);
        self.engine.upload_f32(&batch.mask, &[b, s])
    }

    pub fn upload_pixels(&self, batch: &Batch) -> Result<Option<Buffer>> {
        if !self.model.vision {
            return Ok(None);
        }
        let px = batch
            .pixels
            .as_ref()
            .context("VLM model requires pixels in the batch")?;
        let dims = [
            self.model.batch,
            self.model.vision_grid * self.model.vision_grid,
            self.model.vision_patch,
        ];
        Ok(Some(self.engine.upload_f32(px, &dims)?))
    }

    pub fn upload_advantage(&self, batch: &Batch) -> Result<Buffer> {
        let adv = batch.advantage.as_ref().context("RL step requires advantages")?;
        self.engine.upload_f32(adv, &[self.model.batch])
    }

    /// Upload a parameter vector (teacher weights, PTQ weights, ...).
    pub fn upload_params(&self, params: &[f32]) -> Result<Buffer> {
        if params.len() != self.model.param_count {
            bail!(
                "params len {} != param_count {}",
                params.len(),
                self.model.param_count
            );
        }
        self.engine.upload_f32(params, &[self.model.param_count])
    }
}

/// Device-resident training state (the single flat vector).
pub struct DeviceState {
    pub buf: Buffer,
    pub state_len: usize,
    pub scalars_off: usize,
    pub n_scalars: usize,
    pub param_count: usize,
    backend: Rc<dyn ExecBackend>,
    /// The `scalars` slicing artifact (state -> f32[8]); compiled once.
    scalars_exe: Rc<Executable>,
}

impl DeviceState {
    /// Build a fresh state (params + zeroed Adam moments + zeroed scalars)
    /// and upload it.
    pub fn from_params(rt: &ModelRuntime, params: &[f32]) -> Result<DeviceState> {
        let m = &rt.model;
        if params.len() != m.param_count {
            bail!("params len {} != {}", params.len(), m.param_count);
        }
        let mut state = vec![0f32; m.state_len];
        state[..m.param_count].copy_from_slice(params);
        Self::from_state_vec(rt, &state)
    }

    /// Upload a full pre-built state vector (checkpoint resume).
    pub fn from_state_vec(rt: &ModelRuntime, state: &[f32]) -> Result<DeviceState> {
        let m = &rt.model;
        if state.len() != m.state_len {
            bail!("state len {} != {}", state.len(), m.state_len);
        }
        let buf = rt.engine.upload_f32(state, &[m.state_len])?;
        let scalars_exe = rt.engine.load(m, "scalars")?;
        Ok(DeviceState {
            buf,
            state_len: m.state_len,
            scalars_off: m.scalars_offset(),
            n_scalars: rt.engine.manifest.n_scalars,
            param_count: m.param_count,
            backend: rt.engine.backend(),
            scalars_exe,
        })
    }

    /// Advance: replace the device buffer with the step output.
    pub fn advance(&mut self, new_buf: Buffer) {
        self.buf = new_buf;
    }

    /// A sibling state viewing another buffer of the same layout (used for
    /// scratch validation states that are dropped after reading metrics).
    pub fn like(&self, buf: Buffer) -> DeviceState {
        DeviceState {
            buf,
            state_len: self.state_len,
            scalars_off: self.scalars_off,
            n_scalars: self.n_scalars,
            param_count: self.param_count,
            backend: self.backend.clone(),
            scalars_exe: self.scalars_exe.clone(),
        }
    }

    /// Read the 8-float metrics block via the device-side `scalars`
    /// slicing artifact (cheap; never copies params to the host).
    pub fn scalars(&self) -> Result<Vec<f32>> {
        let out = self.backend.execute(&self.scalars_exe, &[&self.buf])?;
        let mut v = Vec::new();
        self.backend.download_f32(&out, self.n_scalars, &mut v)?;
        Ok(v)
    }

    /// Download just the parameter slice (full state copy, then truncate —
    /// the CPU plugin has no partial reads; called only at checkpoints).
    pub fn params(&self) -> Result<Vec<f32>> {
        let mut full = self.full()?;
        full.truncate(self.param_count);
        Ok(full)
    }

    /// Download the full state (checkpointing).
    pub fn full(&self) -> Result<Vec<f32>> {
        let mut v = Vec::new();
        self.backend.download_f32(&self.buf, self.state_len, &mut v)?;
        Ok(v)
    }
}

/// Well-known scalar slots (matches python/compile/steps.py).
pub mod scalar {
    pub const STEP: usize = 0;
    pub const LOSS: usize = 1;
    pub const KL: usize = 2;
    pub const CE: usize = 3;
    pub const GRAD_NORM: usize = 4;
    pub const LR: usize = 5;
}
