//! Benchmark harness: the paper's evaluation protocol (§3.4) over the sim
//! suites — k sampling runs per problem, exact-match (or instruction
//! compliance) scoring, averaged accuracy.

use std::collections::BTreeMap;

use anyhow::Result;

use super::sampler::{SampleCfg, Sampler};
use crate::data::tasks::{self, Suite};
use crate::runtime::{Buffer, Engine, ModelRuntime};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SuiteResult {
    pub suite: Suite,
    pub accuracy: f64,
    pub n_problems: usize,
    pub k_runs: usize,
}

#[derive(Clone, Copy, Debug)]
pub struct EvalCfg {
    pub n_problems: usize,
    pub k_runs: usize,
    pub sample: SampleCfg,
    /// Seed for the *problem set* (fixed across methods for comparability).
    pub problem_seed: u64,
}

impl Default for EvalCfg {
    fn default() -> Self {
        EvalCfg { n_problems: 32, k_runs: 3, sample: SampleCfg::default(), problem_seed: 20_250_101 }
    }
}

/// Evaluate one suite with `weights` through the given fwd artifact.
pub fn run_suite(
    engine: &Engine,
    rt: &ModelRuntime,
    fwd_key: &str,
    weights: &Buffer,
    suite: Suite,
    cfg: &EvalCfg,
) -> Result<SuiteResult> {
    let mut sampler = Sampler::new(rt, fwd_key, cfg.sample)?;
    let m = &rt.model;
    // Fixed problem set per (suite, seed): every method sees the same exams.
    let mut prng = Rng::new(cfg.problem_seed ^ (suite.name().len() as u64) << 17 ^ hash_name(suite.name()));
    let problems: Vec<tasks::Sample> = (0..cfg.n_problems)
        .map(|_| tasks::generate(suite, &mut prng, m.vision_grid, m.vision_patch))
        .collect();

    let mut total = 0.0;
    let mut count = 0usize;
    let b = m.batch;
    let px_len = m.vision_grid * m.vision_grid * m.vision_patch;
    for k in 0..cfg.k_runs {
        sampler.reseed(cfg.sample.seed ^ (k as u64 * 0x9e37) ^ hash_name(suite.name()));
        for chunk in problems.chunks(b) {
            let prompts: Vec<Vec<i32>> = chunk
                .iter()
                .map(|s| tasks::prompt_tokens(s, m.seq_len))
                .collect();
            let pixels: Option<Vec<f32>> = if m.vision {
                let mut px = Vec::with_capacity(b * px_len);
                for s in chunk {
                    px.extend(s.pixels.as_deref().unwrap_or(&vec![0.0; px_len]));
                }
                // pad to full batch
                px.resize(b * px_len, 0.0);
                Some(px)
            } else {
                None
            };
            let rows = sampler.generate(engine, weights, &prompts, pixels.as_deref())?;
            for ((sample, prompt), row) in chunk.iter().zip(&prompts).zip(rows) {
                let generated = crate::data::sources::decode_response(&row, prompt);
                total += sample.suite.score(&sample.answer, &generated);
                count += 1;
            }
        }
    }
    Ok(SuiteResult {
        suite,
        accuracy: 100.0 * total / count.max(1) as f64,
        n_problems: cfg.n_problems,
        k_runs: cfg.k_runs,
    })
}

/// Evaluate several suites; returns suite-name -> accuracy.
pub fn run_suites(
    engine: &Engine,
    rt: &ModelRuntime,
    fwd_key: &str,
    weights: &[f32],
    suites: &[Suite],
    cfg: &EvalCfg,
) -> Result<BTreeMap<String, f64>> {
    let wbuf = engine.upload_f32(weights, &[weights.len()])?;
    let mut out = BTreeMap::new();
    for &suite in suites {
        let r = run_suite(engine, rt, fwd_key, &wbuf, suite, cfg)?;
        out.insert(suite.name().to_string(), r.accuracy);
    }
    Ok(out)
}

fn hash_name(s: &str) -> u64 {
    s.bytes().fold(0xcbf29ce484222325u64, |h, b| (h ^ b as u64).wrapping_mul(0x100000001b3))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eval_cfg_defaults_match_protocol() {
        let c = EvalCfg::default();
        assert_eq!(c.sample.temperature, 0.6);
        assert_eq!(c.sample.top_p, 0.95);
        assert!(c.k_runs >= 1);
    }

    #[test]
    fn hash_name_distinct() {
        assert_ne!(hash_name("math500"), hash_name("aime"));
    }
}
