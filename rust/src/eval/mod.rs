//! Evaluation layer: temperature/top-p sampling, benchmark suites with the
//! paper's k-runs protocol, and distribution metrics (KL / CE).

pub mod metrics;
pub mod sampler;
pub mod suite;

pub use metrics::{eval_distribution, DistMetrics};
pub use sampler::{
    sample_token, sample_token_with, DecodeMode, SampleCfg, SampleScratch, Sampler,
    TeacherGenerator,
};
pub use suite::{run_suite, run_suites, EvalCfg, SuiteResult};
