//! Temperature / top-p sampling over a `fwd` artifact — the generation
//! engine behind RL rollouts, teacher-generated training data, and the
//! sampling-based benchmark evaluation (paper §3.4: T=0.6 top-p=0.95 for
//! the LLMs, T=1.0 top-p=1.0 for Nemotron-3-Nano).
//!
//! The fwd artifacts have a fixed (B, S) input; generation is incremental:
//! one forward pass per emitted position over the whole batch, sampling
//! each row's next token from the logits at its own frontier. Rows finish
//! independently at EOS.

use std::rc::Rc;

use anyhow::{bail, Result};
use xla::{PjRtBuffer, PjRtLoadedExecutable};

use crate::data::sources::ResponseGenerator;
use crate::data::tokenizer as tok;
use crate::runtime::{Engine, ModelEntry, ModelRuntime};
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug)]
pub struct SampleCfg {
    pub temperature: f32,
    pub top_p: f32,
    pub max_new: usize,
    pub seed: u64,
}

impl Default for SampleCfg {
    fn default() -> Self {
        // Paper default for the LLM evals.
        SampleCfg { temperature: 0.6, top_p: 0.95, max_new: 12, seed: 0 }
    }
}

impl SampleCfg {
    pub fn nano3() -> Self {
        SampleCfg { temperature: 1.0, top_p: 1.0, max_new: 12, seed: 0 }
    }

    pub fn greedy() -> Self {
        SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 12, seed: 0 }
    }
}

/// Sampler bound to one fwd artifact of one model. The weights buffer
/// (params vector or full train state, depending on the artifact) is passed
/// per call so the RL loop can sample from the live device state.
pub struct Sampler {
    pub model: ModelEntry,
    exe: Rc<PjRtLoadedExecutable>,
    pub cfg: SampleCfg,
    rng: Rng,
}

impl Sampler {
    /// `fwd_key`: "fwd_bf16" | "fwd_nvfp4" | "fwd_bf16_state" | ...
    pub fn new(rt: &ModelRuntime, fwd_key: &str, cfg: SampleCfg) -> Result<Sampler> {
        Ok(Sampler {
            model: rt.model.clone(),
            exe: rt.exe(fwd_key)?,
            cfg,
            rng: Rng::new(cfg.seed ^ 0x5a5a_1234),
        })
    }

    pub fn reseed(&mut self, seed: u64) {
        self.rng = Rng::new(seed ^ 0x5a5a_1234);
    }

    /// Generate completions for up to `batch` prompts (shorter slices are
    /// padded with dummy rows). Returns full rows (prompt + completion),
    /// PAD-tailed, one per input prompt.
    pub fn generate(
        &mut self,
        engine: &Engine,
        weights: &PjRtBuffer,
        prompts: &[Vec<i32>],
        pixels: Option<&[f32]>,
    ) -> Result<Vec<Vec<i32>>> {
        let (b, s, v) = (self.model.batch, self.model.seq_len, self.model.vocab);
        if prompts.is_empty() || prompts.len() > b {
            bail!("need 1..={b} prompts, got {}", prompts.len());
        }
        let mut tokens = vec![tok::PAD; b * s];
        let mut frontier = vec![0usize; b]; // next position to fill per row
        for (i, p) in prompts.iter().enumerate() {
            let n = p.len().min(s - 1);
            tokens[i * s..i * s + n].copy_from_slice(&p[..n]);
            frontier[i] = n;
        }
        // Dummy rows for the padded tail of the batch.
        for f in frontier.iter_mut().skip(prompts.len()) {
            *f = s; // already "done"
        }
        let mut done = vec![false; b];
        for (i, d) in done.iter_mut().enumerate() {
            *d = frontier[i] >= s;
        }

        let px_buf = match (self.model.vision, pixels) {
            (true, Some(px)) => Some(engine.upload_f32(
                px,
                &[b, self.model.vision_grid * self.model.vision_grid, self.model.vision_patch],
            )?),
            (true, None) => bail!("VLM sampler requires pixels"),
            _ => None,
        };

        for _ in 0..self.cfg.max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let tok_buf = engine.upload_i32(&tokens, &[b, s])?;
            let mut args: Vec<&PjRtBuffer> = vec![weights, &tok_buf];
            if let Some(px) = px_buf.as_ref() {
                args.push(px);
            }
            let out = engine.run_b(&self.exe, &args)?;
            let logits = engine.download_f32(&out, b * s * v)?;
            for i in 0..prompts.len() {
                if done[i] {
                    continue;
                }
                let pos = frontier[i];
                // logits at position pos-1 predict the token at pos
                let row = &logits[(i * s + pos - 1) * v..(i * s + pos) * v];
                let next = self.sample_from(row);
                tokens[i * s + pos] = next;
                frontier[i] += 1;
                if next == tok::EOS || frontier[i] >= s {
                    done[i] = true;
                }
            }
        }
        Ok((0..prompts.len())
            .map(|i| tokens[i * s..(i + 1) * s].to_vec())
            .collect())
    }

    /// Sample one token id from a logits row under temperature/top-p.
    fn sample_from(&mut self, logits: &[f32]) -> i32 {
        sample_token(&self.cfg, &mut self.rng, logits)
    }
}

/// The sampling math itself (free function — unit-tested without PJRT).
pub fn sample_token(cfg: &SampleCfg, rng: &mut Rng, logits: &[f32]) -> i32 {
    if cfg.temperature <= 0.0 {
        // greedy
        let mut best = 0usize;
        for (i, &l) in logits.iter().enumerate() {
            if l > logits[best] {
                best = i;
            }
        }
        return best as i32;
    }
    let inv_t = 1.0 / cfg.temperature;
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut probs: Vec<(usize, f64)> = logits
        .iter()
        .enumerate()
        .map(|(i, &l)| (i, (((l - mx) * inv_t) as f64).exp()))
        .collect();
    let z: f64 = probs.iter().map(|(_, p)| p).sum();
    for p in probs.iter_mut() {
        p.1 /= z;
    }
    // top-p nucleus
    if cfg.top_p < 1.0 {
        probs.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        let mut cum = 0.0;
        let mut cut = probs.len();
        for (idx, (_, p)) in probs.iter().enumerate() {
            cum += p;
            if cum >= cfg.top_p as f64 {
                cut = idx + 1;
                break;
            }
        }
        probs.truncate(cut);
    }
    let weights: Vec<f64> = probs.iter().map(|(_, p)| *p).collect();
    let pick = rng.weighted(&weights);
    probs[pick].0 as i32
}

/// Adapter: a Sampler + fixed weights buffer acts as the teacher-side
/// `ResponseGenerator` for the generation-backed data sources (Table 5).
pub struct TeacherGenerator<'a> {
    pub engine: &'a Engine,
    pub sampler: Sampler,
    pub weights: PjRtBuffer,
}

impl<'a> TeacherGenerator<'a> {
    pub fn new(
        engine: &'a Engine,
        rt: &ModelRuntime,
        fwd_key: &str,
        weights: &[f32],
        cfg: SampleCfg,
    ) -> Result<TeacherGenerator<'a>> {
        let sampler = Sampler::new(rt, fwd_key, cfg)?;
        let weights = engine.upload_f32(weights, &[weights.len()])?;
        Ok(TeacherGenerator { engine, sampler, weights })
    }
}

impl ResponseGenerator for TeacherGenerator<'_> {
    fn complete(
        &mut self,
        prompts: &[Vec<i32>],
        pixels: Option<&[f32]>,
        seq_len: usize,
    ) -> Result<Vec<(Vec<i32>, Vec<f32>)>> {
        let b = self.model_batch();
        let mut out = Vec::with_capacity(prompts.len());
        for chunk in prompts.chunks(b) {
            let rows = self
                .sampler
                .generate(self.engine, &self.weights, chunk, pixels)?;
            for (p, row) in chunk.iter().zip(rows) {
                let mut mask = vec![0f32; seq_len];
                for (j, m) in mask.iter_mut().enumerate().take(seq_len).skip(p.len()) {
                    // response region: everything generated up to and incl. EOS
                    if row[j] != tok::PAD {
                        *m = 1.0;
                    }
                }
                out.push((row, mask));
            }
        }
        Ok(out)
    }
}

impl TeacherGenerator<'_> {
    fn model_batch(&self) -> usize {
        self.sampler.model.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(cfg: &SampleCfg, seed: u64, logits: &[f32]) -> i32 {
        let mut rng = Rng::new(seed);
        sample_token(cfg, &mut rng, logits)
    }

    #[test]
    fn greedy_picks_argmax() {
        assert_eq!(sample(&SampleCfg::greedy(), 0, &[0.0, 5.0, 1.0]), 1);
        assert_eq!(sample(&SampleCfg::greedy(), 1, &[2.0, -5.0, 1.0]), 0);
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let cfg = SampleCfg { temperature: 1.0, top_p: 1.0, max_new: 4, seed: 3 };
        let mut rng = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            seen.insert(sample_token(&cfg, &mut rng, &[1.0, 1.0, 1.0, -100.0]));
        }
        assert!(seen.contains(&0) && seen.contains(&1) && seen.contains(&2));
        assert!(!seen.contains(&3)); // effectively zero probability
    }

    #[test]
    fn top_p_cuts_tail() {
        let cfg = SampleCfg { temperature: 1.0, top_p: 0.5, max_new: 4, seed: 9 };
        let mut rng = Rng::new(9);
        // One dominant token (p ~ 0.87) — nucleus at 0.5 keeps only it.
        for _ in 0..100 {
            assert_eq!(sample_token(&cfg, &mut rng, &[3.0, 0.0, 0.0, 0.0]), 0);
        }
    }

    #[test]
    fn low_temperature_sharpens() {
        let hot = SampleCfg { temperature: 2.0, top_p: 1.0, max_new: 4, seed: 5 };
        let cold = SampleCfg { temperature: 0.1, top_p: 1.0, max_new: 4, seed: 5 };
        let logits = [1.0f32, 0.0, 0.0, 0.0];
        let count = |cfg: &SampleCfg| {
            let mut rng = Rng::new(11);
            (0..500).filter(|_| sample_token(cfg, &mut rng, &logits) == 0).count()
        };
        assert!(count(&cold) > count(&hot));
    }
}
