//! Temperature / top-p sampling over a `fwd` artifact — the generation
//! engine behind RL rollouts, teacher-generated training data, and the
//! sampling-based benchmark evaluation (paper §3.4: T=0.6 top-p=0.95 for
//! the LLMs, T=1.0 top-p=1.0 for Nemotron-3-Nano).
//!
//! The fwd artifacts have a fixed (B, S) input; generation is incremental:
//! one forward pass per emitted position over the whole batch, sampling
//! each row's next token from the logits at its own frontier. Rows finish
//! independently at EOS.
//!
//! Decode hot path, in order of preference:
//!
//! 1. **Stateful prefill+step** ([`DecodeMode::Auto`], when the backend
//!    advertises the [`DecodeSession`] capability): the prompt is consumed
//!    once, per-layer state (attention K/V rows, SSM scan carries) is
//!    cached, and every emitted token costs O(frontier) work plus a `V`
//!    float transfer — no full (B, S) re-forward at all. Step logits are
//!    bit-identical to the stateless path's frontier rows, and rows are
//!    sampled in the same order with the same rng stream, so both paths
//!    emit identical tokens (rust/tests/decode_equivalence.rs).
//! 2. **Frontier gather**: when the manifest carries a frontier-gather
//!    twin of the fwd artifact (`fwd_last_*`: fused forward + per-row
//!    dynamic slice of the logits at a frontier-index input), each step
//!    downloads `B·V` floats instead of `B·S·V`.
//! 3. **Full logits**: the plain fwd artifact with a `B·S·V` download —
//!    always available (PJRT artifact builds without the twin, or
//!    `QADX_FORCE_FULL_LOGITS=1` as an operational escape hatch).
//!
//! `QADX_DECODE=auto|step|full` (or [`Sampler::set_decode_mode`]) pins the
//! choice between 1 and 2/3; `step` errors when the backend lacks the
//! capability instead of silently degrading. Host-side scratch (token
//! upload buffer, logits vector, frontier indices, sampling candidates) is
//! reused across steps and calls.

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::data::sources::ResponseGenerator;
use crate::data::tokenizer as tok;
use crate::runtime::{
    frontier_key, Buffer, DecodeSession, Engine, Executable, ModelEntry, ModelRuntime,
};
use crate::util::rng::Rng;

/// How `Sampler::generate` (and the serving scheduler) executes decoding.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum DecodeMode {
    /// Stateful prefill+step when the backend supports it, else the
    /// stateless frontier/full path. The default.
    #[default]
    Auto,
    /// Require stateful prefill+step; error when the backend lacks it.
    Step,
    /// Force the stateless path (frontier gather still applies unless
    /// `force_full_logits` is set).
    Full,
}

impl DecodeMode {
    pub fn parse(s: &str) -> Result<DecodeMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" => Ok(DecodeMode::Auto),
            "step" => Ok(DecodeMode::Step),
            "full" => Ok(DecodeMode::Full),
            other => bail!("unknown decode mode {other:?} (known: auto, step, full)"),
        }
    }

    /// The `QADX_DECODE` override, if set (empty counts as unset).
    pub fn from_env() -> Result<Option<DecodeMode>> {
        match std::env::var("QADX_DECODE") {
            Ok(v) if !v.trim().is_empty() => Ok(Some(DecodeMode::parse(&v)?)),
            _ => Ok(None),
        }
    }
}

impl std::fmt::Display for DecodeMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DecodeMode::Auto => write!(f, "auto"),
            DecodeMode::Step => write!(f, "step"),
            DecodeMode::Full => write!(f, "full"),
        }
    }
}

impl std::str::FromStr for DecodeMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<DecodeMode> {
        DecodeMode::parse(s)
    }
}

#[derive(Clone, Copy, Debug)]
pub struct SampleCfg {
    pub temperature: f32,
    pub top_p: f32,
    pub max_new: usize,
    pub seed: u64,
}

impl Default for SampleCfg {
    fn default() -> Self {
        // Paper default for the LLM evals.
        SampleCfg { temperature: 0.6, top_p: 0.95, max_new: 12, seed: 0 }
    }
}

impl SampleCfg {
    pub fn nano3() -> Self {
        SampleCfg { temperature: 1.0, top_p: 1.0, max_new: 12, seed: 0 }
    }

    pub fn greedy() -> Self {
        SampleCfg { temperature: 0.0, top_p: 1.0, max_new: 12, seed: 0 }
    }
}

/// Sampler bound to one fwd artifact of one model. The weights buffer
/// (params vector or full train state, depending on the artifact) is passed
/// per call so the RL loop can sample from the live device state.
pub struct Sampler {
    pub model: ModelEntry,
    fwd_key: String,
    exe: Rc<Executable>,
    /// Frontier-gather twin (`fwd_last_*`); None when the manifest lacks it.
    exe_last: Option<Rc<Executable>>,
    pub cfg: SampleCfg,
    rng: Rng,
    // per-step scratch, reused across steps and generate() calls
    scratch: SampleScratch,
    logits_host: Vec<f32>,
    idx_host: Vec<i32>,
    force_full: bool,
    decode_mode: DecodeMode,
    on_token: Option<TokenObserver>,
}

/// Per-token observer for the stateful decode loop: `(row, index, token)`
/// with `index` counting generated tokens per row from 0. Lets callers
/// stream tokens as they are sampled instead of waiting for full rows.
pub type TokenObserver = Box<dyn FnMut(usize, usize, i32)>;

/// The frontier-artifact load failure is a degraded-path notice, not a
/// per-call event: samplers are constructed inside generate-heavy loops
/// (RL rollouts, eval suites), and repeating the same warning every
/// construction drowns real output. Reported once per process.
static FRONTIER_LOAD_NOTICE: std::sync::Once = std::sync::Once::new();

impl Sampler {
    /// `fwd_key`: "fwd_bf16" | "fwd_nvfp4" | "fwd_bf16_state" | ...
    pub fn new(rt: &ModelRuntime, fwd_key: &str, cfg: SampleCfg) -> Result<Sampler> {
        let exe = rt.exe(fwd_key)?;
        // QADX_FORCE_FULL_LOGITS=1: operational escape hatch — skip the
        // frontier-gather path entirely without rebuilding artifacts.
        let force_full_env = crate::util::env_flag("QADX_FORCE_FULL_LOGITS");
        let fkey = frontier_key(fwd_key).filter(|k| rt.model.has_artifact(k));
        let exe_last = match fkey {
            Some(_) if force_full_env => None,
            Some(key) => match rt.exe(&key) {
                Ok(e) => Some(e),
                Err(err) => {
                    FRONTIER_LOAD_NOTICE.call_once(|| {
                        eprintln!(
                            "notice: frontier artifact for {fwd_key:?} failed to load \
                             ({err:#}); falling back to full-logits decode \
                             (reported once per process)"
                        );
                    });
                    None
                }
            },
            None => None,
        };
        Ok(Sampler {
            model: rt.model.clone(),
            fwd_key: fwd_key.to_string(),
            exe,
            exe_last,
            cfg,
            rng: Rng::new(cfg.seed ^ 0x5a5a_1234),
            scratch: SampleScratch::default(),
            logits_host: Vec::new(),
            idx_host: Vec::new(),
            force_full: false,
            decode_mode: DecodeMode::from_env()?.unwrap_or(DecodeMode::Auto),
            on_token: None,
        })
    }

    /// Install (or clear) the per-token streaming observer. Only the
    /// stateful prefill+step path emits tokens incrementally; the
    /// stateless fallback still answers at completion.
    pub fn set_token_observer(&mut self, obs: Option<TokenObserver>) {
        self.on_token = obs;
    }

    pub fn reseed(&mut self, seed: u64) {
        self.rng = Rng::new(seed ^ 0x5a5a_1234);
    }

    /// Force the full `B·S·V` logits download even when a frontier-gather
    /// artifact is available (A/B benches, equivalence tests). Only
    /// meaningful on the stateless path ([`DecodeMode::Full`]).
    pub fn force_full_logits(&mut self, force: bool) {
        self.force_full = force;
    }

    /// Whether generation currently uses the frontier-gather decode path
    /// (`B·V` host transfer per emitted token instead of `B·S·V`).
    pub fn uses_frontier(&self) -> bool {
        !self.force_full && self.exe_last.is_some()
    }

    /// Pin how decoding executes (default [`DecodeMode::Auto`], or the
    /// `QADX_DECODE` env override captured at construction).
    pub fn set_decode_mode(&mut self, mode: DecodeMode) {
        self.decode_mode = mode;
    }

    pub fn decode_mode(&self) -> DecodeMode {
        self.decode_mode
    }

    /// The fwd artifact key this sampler decodes through.
    pub fn fwd_key(&self) -> &str {
        &self.fwd_key
    }

    /// Generate completions for up to `batch` prompts (shorter slices are
    /// padded with dummy rows). Returns full rows (prompt + completion),
    /// PAD-tailed, one per input prompt.
    pub fn generate(
        &mut self,
        engine: &Engine,
        weights: &Buffer,
        prompts: &[Vec<i32>],
        pixels: Option<&[f32]>,
    ) -> Result<Vec<Vec<i32>>> {
        let (b, s, v) = (self.model.batch, self.model.seq_len, self.model.vocab);
        if prompts.is_empty() || prompts.len() > b {
            bail!("need 1..={b} prompts, got {}", prompts.len());
        }
        // Stateful prefill+step path: per-layer state cached across steps,
        // so each emitted token costs O(frontier) instead of a full (B, S)
        // forward. Vision models stay on the stateless path (pixels).
        if self.decode_mode != DecodeMode::Full && !self.model.vision {
            match engine.open_decode(&self.model, &self.fwd_key, weights, prompts.len())? {
                Some(session) => return self.generate_stepped(session, prompts),
                None if self.decode_mode == DecodeMode::Step => bail!(
                    "decode mode 'step' requested but backend {} has no stateful decode \
                     for {:?}",
                    engine.backend_kind(),
                    self.fwd_key
                ),
                None => {}
            }
        }
        let mut tokens = vec![tok::PAD; b * s];
        let mut frontier = vec![0usize; b]; // next position to fill per row
        for (i, p) in prompts.iter().enumerate() {
            if p.is_empty() {
                bail!("empty prompt at row {i}");
            }
            let n = p.len().min(s - 1);
            tokens[i * s..i * s + n].copy_from_slice(&p[..n]);
            frontier[i] = n;
        }
        // Dummy rows for the padded tail of the batch.
        for f in frontier.iter_mut().skip(prompts.len()) {
            *f = s; // already "done"
        }
        let mut done = vec![false; b];
        for (i, d) in done.iter_mut().enumerate() {
            *d = frontier[i] >= s;
        }

        let px_buf = match (self.model.vision, pixels) {
            (true, Some(px)) => Some(engine.upload_f32(
                px,
                &[b, self.model.vision_grid * self.model.vision_grid, self.model.vision_patch],
            )?),
            (true, None) => bail!("VLM sampler requires pixels"),
            _ => None,
        };

        let exe_last = if self.force_full { None } else { self.exe_last.clone() };
        let exe = self.exe.clone();
        for _ in 0..self.cfg.max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            let tok_buf = engine.upload_i32(&tokens, &[b, s])?;
            let frontier_step = if let Some(exe_last) = exe_last.as_ref() {
                // logits at position frontier-1 predict the token at
                // frontier; done/dummy rows pass a valid index but are
                // never sampled.
                self.idx_host.clear();
                self.idx_host
                    .extend(frontier.iter().map(|&f| f.saturating_sub(1).min(s - 1) as i32));
                let idx_buf = engine.upload_i32(&self.idx_host, &[b])?;
                let mut args: Vec<&Buffer> = vec![weights, &tok_buf, &idx_buf];
                if let Some(px) = px_buf.as_ref() {
                    args.push(px);
                }
                let out = engine.run_b(exe_last, &args)?;
                engine.download_f32_into(&out, b * v, &mut self.logits_host)?;
                true
            } else {
                let mut args: Vec<&Buffer> = vec![weights, &tok_buf];
                if let Some(px) = px_buf.as_ref() {
                    args.push(px);
                }
                let out = engine.run_b(&exe, &args)?;
                engine.download_f32_into(&out, b * s * v, &mut self.logits_host)?;
                false
            };
            for i in 0..prompts.len() {
                if done[i] {
                    continue;
                }
                let pos = frontier[i];
                // logits at position pos-1 predict the token at pos
                let row = if frontier_step {
                    &self.logits_host[i * v..(i + 1) * v]
                } else {
                    &self.logits_host[(i * s + pos - 1) * v..(i * s + pos) * v]
                };
                let next = sample_token_with(&self.cfg, &mut self.rng, row, &mut self.scratch);
                tokens[i * s + pos] = next;
                frontier[i] += 1;
                if next == tok::EOS || frontier[i] >= s {
                    done[i] = true;
                }
            }
        }
        Ok((0..prompts.len())
            .map(|i| tokens[i * s..(i + 1) * s].to_vec())
            .collect())
    }

    /// The stateful decode loop: round 0 prefills each row at its prompt
    /// frontier, later rounds step one token per live row. Rows are
    /// visited in ascending order every round and consume exactly one rng
    /// draw each — the stateless path's sampling stream — and step logits
    /// are bit-identical to its frontier rows, so both paths emit
    /// identical tokens.
    fn generate_stepped(
        &mut self,
        mut session: Box<dyn DecodeSession>,
        prompts: &[Vec<i32>],
    ) -> Result<Vec<Vec<i32>>> {
        let (s, v) = (self.model.seq_len, self.model.vocab);
        let n = prompts.len();
        let mut tokens = vec![tok::PAD; n * s];
        let mut frontier = vec![0usize; n];
        let mut done = vec![false; n];
        for (i, p) in prompts.iter().enumerate() {
            if p.is_empty() {
                bail!("empty prompt at row {i}");
            }
            let np = p.len().min(s - 1);
            tokens[i * s..i * s + np].copy_from_slice(&p[..np]);
            frontier[i] = np;
        }
        for round in 0..self.cfg.max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            for i in 0..n {
                if done[i] {
                    continue;
                }
                let pos = frontier[i];
                if round == 0 {
                    session.prefill(i, &tokens[i * s..i * s + pos], &mut self.logits_host)?;
                } else {
                    // the token sampled last round sits at pos - 1
                    session.step(i, tokens[i * s + pos - 1], &mut self.logits_host)?;
                }
                if self.logits_host.len() != v {
                    bail!(
                        "stateful decode returned {} logits, expected vocab {v}",
                        self.logits_host.len()
                    );
                }
                let next = sample_token_with(
                    &self.cfg,
                    &mut self.rng,
                    &self.logits_host,
                    &mut self.scratch,
                );
                tokens[i * s + pos] = next;
                frontier[i] += 1;
                if let Some(obs) = self.on_token.as_mut() {
                    obs(i, round, next);
                }
                if next == tok::EOS || frontier[i] >= s {
                    done[i] = true;
                }
            }
        }
        Ok((0..n).map(|i| tokens[i * s..(i + 1) * s].to_vec()).collect())
    }
}

/// Reusable candidate storage for `sample_token_with` — keeps the top-p
/// hot path allocation-free across calls.
#[derive(Clone, Debug, Default)]
pub struct SampleScratch {
    /// (unnormalized probability, token id); doubles as the selection heap.
    probs: Vec<(f64, u32)>,
}

/// The sampling math itself (free function — unit-tested without PJRT).
/// Allocates scratch per call; the hot path uses `sample_token_with`.
pub fn sample_token(cfg: &SampleCfg, rng: &mut Rng, logits: &[f32]) -> i32 {
    sample_token_with(cfg, rng, logits, &mut SampleScratch::default())
}

/// Sample one token id from a logits row under temperature/top-p.
///
/// Allocation-free given reused scratch: greedy touches no memory, the
/// top-p path heap-selects candidates in descending probability and stops
/// as soon as the kept mass reaches `top_p` — no full-vocab sort. Exactly
/// one uniform draw is consumed per non-greedy call (same stream shape as
/// the seed implementation).
pub fn sample_token_with(
    cfg: &SampleCfg,
    rng: &mut Rng,
    logits: &[f32],
    scratch: &mut SampleScratch,
) -> i32 {
    if cfg.temperature <= 0.0 {
        return argmax(logits);
    }
    let inv_t = 1.0 / cfg.temperature;
    let mx = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let probs = &mut scratch.probs;
    probs.clear();
    let mut z = 0.0f64;
    for (i, &l) in logits.iter().enumerate() {
        let p = (((l - mx) * inv_t) as f64).exp();
        z += p;
        probs.push((p, i as u32));
    }
    if z.is_nan() || z <= 0.0 {
        // degenerate row (empty or all -inf): fall back to argmax
        return argmax(logits);
    }
    if cfg.top_p >= 1.0 {
        // no nucleus cut: one cumulative walk over the unnormalized mass
        let mut x = rng.f64() * z;
        for &(p, i) in probs.iter() {
            x -= p;
            if x <= 0.0 {
                return i as i32;
            }
        }
        return probs.last().map(|&(_, i)| i as i32).unwrap_or(0);
    }
    // Partial selection: heapify, then pop the most probable candidates
    // until their cumulative mass reaches top_p·z. Popped entries collect
    // at the tail in ascending-position = descending-probability order.
    let n = probs.len();
    for i in (0..n / 2).rev() {
        sift_down(probs, i, n);
    }
    let target = cfg.top_p as f64 * z;
    let mut cum = 0.0f64;
    let mut k = 0usize;
    while k < n {
        probs.swap(0, n - 1 - k);
        k += 1;
        sift_down(probs, 0, n - k);
        cum += probs[n - k].0;
        if cum >= target {
            break;
        }
    }
    let mut x = rng.f64() * cum;
    for &(p, i) in probs[n - k..].iter().rev() {
        x -= p;
        if x <= 0.0 {
            return i as i32;
        }
    }
    // numerical residue: lowest-probability kept candidate (matches the
    // seed's "last weight wins" fallback)
    probs[n - k].1 as i32
}

fn argmax(logits: &[f32]) -> i32 {
    let mut best = 0usize;
    for (i, &l) in logits.iter().enumerate() {
        if l > logits[best] {
            best = i;
        }
    }
    best as i32
}

/// Restore the max-heap property (by probability) for `heap[..len]` from
/// root `i` downward.
fn sift_down(heap: &mut [(f64, u32)], mut i: usize, len: usize) {
    loop {
        let l = 2 * i + 1;
        if l >= len {
            return;
        }
        let mut m = l;
        let r = l + 1;
        if r < len && heap[r].0 > heap[l].0 {
            m = r;
        }
        if heap[m].0 > heap[i].0 {
            heap.swap(i, m);
            i = m;
        } else {
            return;
        }
    }
}

/// Adapter: a Sampler + fixed weights buffer acts as the teacher-side
/// `ResponseGenerator` for the generation-backed data sources (Table 5).
pub struct TeacherGenerator<'a> {
    pub engine: &'a Engine,
    pub sampler: Sampler,
    pub weights: Buffer,
}

impl<'a> TeacherGenerator<'a> {
    pub fn new(
        engine: &'a Engine,
        rt: &ModelRuntime,
        fwd_key: &str,
        weights: &[f32],
        cfg: SampleCfg,
    ) -> Result<TeacherGenerator<'a>> {
        let sampler = Sampler::new(rt, fwd_key, cfg)?;
        let weights = engine.upload_f32(weights, &[weights.len()])?;
        Ok(TeacherGenerator { engine, sampler, weights })
    }
}

impl ResponseGenerator for TeacherGenerator<'_> {
    fn complete(
        &mut self,
        prompts: &[Vec<i32>],
        pixels: Option<&[f32]>,
        seq_len: usize,
    ) -> Result<Vec<(Vec<i32>, Vec<f32>)>> {
        let b = self.model_batch();
        let mut out = Vec::with_capacity(prompts.len());
        for chunk in prompts.chunks(b) {
            let rows = self
                .sampler
                .generate(self.engine, &self.weights, chunk, pixels)?;
            for (p, row) in chunk.iter().zip(rows) {
                let mut mask = vec![0f32; seq_len];
                for (j, m) in mask.iter_mut().enumerate().take(seq_len).skip(p.len()) {
                    // response region: everything generated up to and incl. EOS
                    if row[j] != tok::PAD {
                        *m = 1.0;
                    }
                }
                out.push((row, mask));
            }
        }
        Ok(out)
    }
}

impl TeacherGenerator<'_> {
    fn model_batch(&self) -> usize {
        self.sampler.model.batch
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(cfg: &SampleCfg, seed: u64, logits: &[f32]) -> i32 {
        let mut rng = Rng::new(seed);
        sample_token(cfg, &mut rng, logits)
    }

    #[test]
    fn decode_mode_parses_and_round_trips() {
        assert_eq!(DecodeMode::parse("auto").unwrap(), DecodeMode::Auto);
        assert_eq!(DecodeMode::parse(" STEP ").unwrap(), DecodeMode::Step);
        assert_eq!(DecodeMode::parse("full").unwrap(), DecodeMode::Full);
        assert!(DecodeMode::parse("fast").is_err());
        for m in [DecodeMode::Auto, DecodeMode::Step, DecodeMode::Full] {
            assert_eq!(DecodeMode::parse(&m.to_string()).unwrap(), m);
        }
        assert_eq!(DecodeMode::default(), DecodeMode::Auto);
    }

    #[test]
    fn greedy_picks_argmax() {
        assert_eq!(sample(&SampleCfg::greedy(), 0, &[0.0, 5.0, 1.0]), 1);
        assert_eq!(sample(&SampleCfg::greedy(), 1, &[2.0, -5.0, 1.0]), 0);
    }

    #[test]
    fn temperature_sampling_covers_support() {
        let cfg = SampleCfg { temperature: 1.0, top_p: 1.0, max_new: 4, seed: 3 };
        let mut rng = Rng::new(3);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..300 {
            seen.insert(sample_token(&cfg, &mut rng, &[1.0, 1.0, 1.0, -100.0]));
        }
        assert!(seen.contains(&0) && seen.contains(&1) && seen.contains(&2));
        assert!(!seen.contains(&3)); // effectively zero probability
    }

    #[test]
    fn top_p_cuts_tail() {
        let cfg = SampleCfg { temperature: 1.0, top_p: 0.5, max_new: 4, seed: 9 };
        let mut rng = Rng::new(9);
        // One dominant token (p ~ 0.87) — nucleus at 0.5 keeps only it.
        for _ in 0..100 {
            assert_eq!(sample_token(&cfg, &mut rng, &[3.0, 0.0, 0.0, 0.0]), 0);
        }
    }

    #[test]
    fn low_temperature_sharpens() {
        let hot = SampleCfg { temperature: 2.0, top_p: 1.0, max_new: 4, seed: 5 };
        let cold = SampleCfg { temperature: 0.1, top_p: 1.0, max_new: 4, seed: 5 };
        let logits = [1.0f32, 0.0, 0.0, 0.0];
        let count = |cfg: &SampleCfg| {
            let mut rng = Rng::new(11);
            (0..500).filter(|_| sample_token(cfg, &mut rng, &logits) == 0).count()
        };
        assert!(count(&cold) > count(&hot));
    }

    #[test]
    fn scratch_reuse_is_equivalent() {
        // one shared scratch across calls == fresh scratch per call
        let cfg = SampleCfg { temperature: 0.8, top_p: 0.9, max_new: 4, seed: 21 };
        let logits: Vec<Vec<f32>> = (0..50)
            .map(|k| (0..32).map(|i| ((i * 7 + k * 13) % 19) as f32 * 0.3 - 2.0).collect())
            .collect();
        let mut r1 = Rng::new(77);
        let mut r2 = Rng::new(77);
        let mut scratch = SampleScratch::default();
        for row in &logits {
            let a = sample_token_with(&cfg, &mut r1, row, &mut scratch);
            let b = sample_token(&cfg, &mut r2, row);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn top_p_partial_selection_matches_distribution_of_full_sort() {
        // nucleus membership check: with p=0.7 over a known distribution,
        // tokens outside the nucleus must never be sampled
        let cfg = SampleCfg { temperature: 1.0, top_p: 0.7, max_new: 4, seed: 1 };
        // probs ~ [0.64, 0.23, 0.09, 0.03]: nucleus at 0.7 = {0, 1}
        let logits = [3.0f32, 2.0, 1.0, 0.0];
        let mut rng = Rng::new(13);
        let mut counts = [0usize; 4];
        for _ in 0..2000 {
            counts[sample_token(&cfg, &mut rng, &logits) as usize] += 1;
        }
        assert_eq!(counts[2] + counts[3], 0, "{counts:?}");
        assert!(counts[0] > counts[1], "{counts:?}");
    }

    #[test]
    fn degenerate_logits_fall_back_to_argmax() {
        let cfg = SampleCfg { temperature: 1.0, top_p: 0.9, max_new: 4, seed: 2 };
        let mut rng = Rng::new(2);
        let logits = [f32::NEG_INFINITY, f32::NEG_INFINITY, f32::NEG_INFINITY];
        // all-(-inf) row: no mass anywhere; must not panic
        let t = sample_token(&cfg, &mut rng, &logits);
        assert!((0..3).contains(&t));
    }
}
