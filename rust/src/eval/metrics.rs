//! Distribution-level evaluation: mean KL(teacher‖student) and CE vs labels
//! over held-out batches, via the `eval_*` artifacts — Table 1's two
//! columns.

use anyhow::Result;

use crate::data::{BatchFactory, SourceSpec};
use crate::runtime::{Buffer, Engine, ModelRuntime};

#[derive(Clone, Copy, Debug, Default)]
pub struct DistMetrics {
    pub kl: f64,
    pub ce: f64,
    pub tokens: f64,
}

/// Run the eval artifact over `n_batches` from a held-out source and
/// aggregate exactly (token-weighted sums).
pub fn eval_distribution(
    engine: &Engine,
    rt: &ModelRuntime,
    eval_key: &str,
    student: &[f32],
    teacher: &[f32],
    factory: &mut BatchFactory,
    spec: &SourceSpec,
    n_batches: usize,
) -> Result<DistMetrics> {
    let exe = rt.exe(eval_key)?;
    let s_buf = rt.upload_params(student)?;
    let t_buf = rt.upload_params(teacher)?;
    let mut kl_sum = 0f64;
    let mut ce_sum = 0f64;
    let mut n_tok = 0f64;
    for _ in 0..n_batches {
        let batch = factory.batch_from_spec(spec, None)?;
        let tokens = rt.upload_tokens(&batch)?;
        let mask = rt.upload_mask(&batch)?;
        let px = rt.upload_pixels(&batch)?;
        let mut args: Vec<&Buffer> = vec![&s_buf, &t_buf, &tokens, &mask];
        if let Some(p) = px.as_ref() {
            args.push(p);
        }
        let out = engine.run_b(&exe, &args)?;
        let m = engine.download_f32(&out, engine.manifest.n_scalars)?;
        // [kl_mean, ce_mean, n, kl_sum, ce_sum, ...]
        kl_sum += m[3] as f64;
        ce_sum += m[4] as f64;
        n_tok += m[2] as f64;
    }
    Ok(DistMetrics { kl: kl_sum / n_tok.max(1.0), ce: ce_sum / n_tok.max(1.0), tokens: n_tok })
}
