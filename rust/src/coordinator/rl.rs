//! RL post-training stage: group-relative REINFORCE (GRPO-style) with
//! verifiable rewards — the "RL-heavy" half of the teacher pipelines
//! (AceReason / Nemotron-3-Nano sims).
//!
//! Each iteration samples `batch/group_size` prompts, rolls out
//! `group_size` completions per prompt **from the live device state**
//! (the `fwd_bf16_state` artifact reads params straight out of the
//! training state — no host round-trip), scores them with the task
//! checker, centres rewards within each group, and applies one
//! REINFORCE step.

use anyhow::{Context, Result};

use crate::data::tasks::{self, Suite};
use crate::data::tokenizer as tok;
use crate::eval::{SampleCfg, Sampler};
use crate::runtime::{scalar, Batch, DeviceState, Engine, ModelRuntime};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct RlCfg {
    pub iterations: usize,
    pub group_size: usize,
    pub lr: f64,
    pub sample: SampleCfg,
    pub seed: u64,
    pub log_every: usize,
}

impl Default for RlCfg {
    fn default() -> Self {
        RlCfg {
            iterations: 150,
            group_size: 4,
            lr: 1e-4,
            sample: SampleCfg { temperature: 1.0, top_p: 1.0, max_new: 8, seed: 7 },
            seed: 7,
            log_every: 25,
        }
    }
}

#[derive(Debug, Default)]
pub struct RlLog {
    /// (iteration, mean reward, loss)
    pub curve: Vec<(usize, f64, f64)>,
    pub final_reward: f64,
}

pub fn rl_stage(
    engine: &Engine,
    rt: &ModelRuntime,
    state: &mut DeviceState,
    suites: &[Suite],
    cfg: &RlCfg,
) -> Result<RlLog> {
    let m = &rt.model;
    let b = m.batch;
    anyhow::ensure!(b % cfg.group_size == 0, "batch {b} % group {} != 0", cfg.group_size);
    let n_prompts = b / cfg.group_size;
    let mut sampler = Sampler::new(rt, "fwd_bf16_state", cfg.sample)?;
    let step_exe = rt.exe("rl_bf16")?;
    let mut rng = Rng::new(cfg.seed ^ r_l_seed());
    let mut log = RlLog::default();

    for it in 0..cfg.iterations {
        sampler.reseed(cfg.seed ^ (it as u64).wrapping_mul(0x9e3779b9));
        // --- rollout phase ------------------------------------------------
        let mut samples = Vec::with_capacity(n_prompts);
        let mut prompts = Vec::with_capacity(b);
        for _ in 0..n_prompts {
            let s = tasks::generate(*rng.choice(suites), &mut rng, m.vision_grid, m.vision_patch);
            let p = tasks::prompt_tokens(&s, m.seq_len);
            for _ in 0..cfg.group_size {
                prompts.push(p.clone());
            }
            samples.push(s);
        }
        let rows = sampler.generate(engine, &state.buf, &prompts, None)?;

        // --- reward + group-centred advantage -------------------------------
        let mut rewards = vec![0f64; b];
        for (i, row) in rows.iter().enumerate() {
            let sample = &samples[i / cfg.group_size];
            let generated = crate::data::sources::decode_response(row, &prompts[i]);
            let exact = sample.suite.score(&sample.answer, &generated);
            // Shaped reward: dense format credit keeps the group-relative
            // baseline informative even when exact-match is sparse early on
            // (length match + right char classes).
            let g = generated.trim();
            let fmt = !g.is_empty()
                && g.len() == sample.answer.trim().len()
                && g.chars().zip(sample.answer.trim().chars()).all(|(a, b)| {
                    a.is_ascii_digit() == b.is_ascii_digit()
                });
            rewards[i] = exact + if fmt { 0.25 } else { 0.0 };
        }
        let mut adv = vec![0f32; b];
        for g in 0..n_prompts {
            let grp = &rewards[g * cfg.group_size..(g + 1) * cfg.group_size];
            let mean: f64 = grp.iter().sum::<f64>() / cfg.group_size as f64;
            for j in 0..cfg.group_size {
                adv[g * cfg.group_size + j] = (grp[j] - mean) as f32;
            }
        }

        // --- policy update ---------------------------------------------------
        let mut tokens = Vec::with_capacity(b * m.seq_len);
        let mut mask = Vec::with_capacity(b * m.seq_len);
        for (i, row) in rows.iter().enumerate() {
            let plen = prompts[i].len();
            tokens.extend(row);
            for (j, &t) in row.iter().enumerate() {
                mask.push(if j >= plen && t != tok::PAD { 1.0 } else { 0.0 });
            }
        }
        let batch = Batch { tokens, mask, pixels: None, advantage: Some(adv) };
        let tok_buf = rt.upload_tokens(&batch)?;
        let mask_buf = rt.upload_mask(&batch)?;
        let adv_buf = rt.upload_advantage(&batch)?;
        let lr_buf = engine.upload_scalar(cfg.lr as f32)?;
        let out = engine.run_b(
            &step_exe,
            &[&state.buf, &tok_buf, &mask_buf, &adv_buf, &lr_buf],
        )?;
        state.advance(out);

        let mean_r: f64 = rewards.iter().sum::<f64>() / b as f64;
        log.final_reward = mean_r;
        if cfg.log_every > 0 && (it + 1) % cfg.log_every == 0 {
            let sc = state.scalars().context("rl scalars")?;
            log.curve.push((it + 1, mean_r, sc[scalar::LOSS] as f64));
        }
    }
    Ok(log)
}

fn r_l_seed() -> u64 {
    0x524c_u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RlCfg::default();
        assert_eq!(16 % c.group_size, 0);
        assert!(c.sample.temperature > 0.0); // exploration required
    }
}
