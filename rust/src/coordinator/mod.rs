//! L3 coordinator: the paper's pipeline — teacher post-training (SFT, RL,
//! merging), PTQ, and the QAD/QAT/MSE/NQT recovery methods with the §3.4
//! checkpoint-selection protocol.

pub mod checkpoint;
pub mod distill;
pub mod init;
pub mod merge;
pub mod pipeline;
pub mod rl;
pub mod trainer;

pub use checkpoint::Checkpoint;
pub use distill::{
    eval_method, ptq_report, run_method, run_recovery, Method, RecoveryCfg, RecoveryOutcome,
};
pub use init::init_params;
pub use pipeline::{get_or_train_teacher, train_teacher, PipelineScale, TeacherReport};
pub use rl::{rl_stage, RlCfg};
pub use trainer::{LrSchedule, StepRecord, TrainCfg, Trainer, TrainLog};
