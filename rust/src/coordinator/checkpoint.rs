//! Checkpoint store: flat f32 parameter vectors in a small binary format
//! ("QCKP"), with JSON sidecar metadata. Used for the teacher cache
//! (runs/teachers/) and the top-k-by-val-loss selection protocol (§3.4).

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

const MAGIC: &[u8; 4] = b"QCKP";

#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    pub step: usize,
    pub val_loss: f64,
    pub params: Vec<f32>,
}

/// Write a parameter vector (+ metadata) to `<path>` / `<path>.json`.
pub fn save(path: &Path, params: &[f32], meta: &Json) -> Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::fs::File::create(path)?;
    f.write_all(MAGIC)?;
    f.write_all(&(params.len() as u64).to_le_bytes())?;
    // bulk little-endian write
    let bytes: Vec<u8> = params.iter().flat_map(|v| v.to_le_bytes()).collect();
    f.write_all(&bytes)?;
    std::fs::write(path.with_extension("json"), meta.pretty())?;
    Ok(())
}

/// Load a parameter vector; verifies magic and length.
pub fn load(path: &Path) -> Result<Vec<f32>> {
    let mut f = std::fs::File::open(path)
        .with_context(|| format!("opening checkpoint {path:?}"))?;
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{path:?} is not a QCKP checkpoint");
    }
    let mut len_bytes = [0u8; 8];
    f.read_exact(&mut len_bytes)?;
    let len = u64::from_le_bytes(len_bytes) as usize;
    let mut bytes = vec![0u8; len * 4];
    f.read_exact(&mut bytes)?;
    let mut extra = Vec::new();
    f.read_to_end(&mut extra)?;
    if !extra.is_empty() {
        bail!("{path:?}: trailing bytes");
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

pub fn load_meta(path: &Path) -> Result<Json> {
    let text = std::fs::read_to_string(path.with_extension("json"))?;
    Ok(Json::parse(&text)?)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip() {
        let dir = std::env::temp_dir().join("qadx_ckpt_test");
        let path = dir.join("a/b/test.qckp");
        let params: Vec<f32> = (0..1000).map(|i| i as f32 * 0.5 - 3.0).collect();
        let meta = Json::obj(vec![("model", Json::Str("x".into())), ("steps", Json::Num(5.0))]);
        save(&path, &params, &meta).unwrap();
        assert_eq!(load(&path).unwrap(), params);
        let m = load_meta(&path).unwrap();
        assert_eq!(m.req_usize("steps").unwrap(), 5);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let dir = std::env::temp_dir().join("qadx_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.qckp");
        std::fs::write(&path, b"NOPE aaaaaaaaaaaaaaaa").unwrap();
        assert!(load(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
