//! The paper's contribution as an API: PTQ, QAD, QAT, and the ablation
//! variants (MSE distill, native-quantized-training proxy), with the §3.4
//! evaluation protocol (top-k checkpoints by validation loss, pick the
//! best on benchmarks).

use anyhow::Result;
use std::collections::BTreeMap;

use super::pipeline;
use super::trainer::{TrainCfg, Trainer};
use crate::data::tasks::Suite;
use crate::data::{shape_for, BatchFactory, SourceSpec};
use crate::eval::{run_suites, EvalCfg, SampleCfg, TeacherGenerator};
use crate::quant;
use crate::runtime::{DeviceState, Engine, ModelRuntime};

/// The paper's six recovery methods (the rows of Tables 2/3/10).
///
/// This enum is a convenience handle over the open `api::RecoveryMethod`
/// trait: each variant is registered as a built-in in
/// `api::MethodRegistry::builtin()`, and new methods are added by
/// implementing the trait — not by growing this enum. The experiment
/// harness (`exper/`) keeps using the enum for its fixed paper tables.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Bf16,
    Ptq,
    Qat,
    Qad,
    Mse,
    Nqt,
}

impl Method {
    /// All built-in methods, in paper-table row order.
    pub const ALL: [Method; 6] =
        [Method::Bf16, Method::Ptq, Method::Qat, Method::Qad, Method::Mse, Method::Nqt];

    /// Short registry key (CLI `--method` value, checkpoint file suffix).
    pub fn key(&self) -> &'static str {
        match self {
            Method::Bf16 => "bf16",
            Method::Ptq => "ptq",
            Method::Qat => "qat",
            Method::Qad => "qad",
            Method::Mse => "mse",
            Method::Nqt => "nqt",
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Bf16 => "BF16",
            Method::Ptq => "NVFP4 PTQ",
            Method::Qat => "NVFP4 QAT",
            Method::Qad => "NVFP4 QAD",
            Method::Mse => "NVFP4 MSE-distill",
            Method::Nqt => "NVFP4 native-QT",
        }
    }

    pub fn step_key(&self) -> Option<&'static str> {
        match self {
            Method::Bf16 | Method::Ptq => None,
            Method::Qat => Some("qat_nvfp4"),
            Method::Qad => Some("qad_nvfp4"),
            Method::Mse => Some("mse_nvfp4"),
            Method::Nqt => Some("nqt_nvfp4"),
        }
    }

    /// Which fwd artifact evaluates this method's weights.
    pub fn fwd_key(&self) -> &'static str {
        match self {
            Method::Bf16 => "fwd_bf16",
            _ => "fwd_nvfp4",
        }
    }
}

#[derive(Clone, Debug)]
pub struct RecoveryCfg {
    pub train: TrainCfg,
    pub data: Vec<SourceSpec>,
    /// Evaluate the top-k checkpoints on these suites and keep the best
    /// average (paper §3.4). Empty -> just use the final checkpoint.
    pub select_suites: Vec<Suite>,
    pub eval: EvalCfg,
    /// Teacher-side sampling for generation-backed data sources.
    pub teacher_sample: SampleCfg,
}

impl RecoveryCfg {
    pub fn new(data: Vec<SourceSpec>, lr: f64, steps: usize) -> RecoveryCfg {
        RecoveryCfg {
            train: TrainCfg {
                steps,
                lr,
                val_every: (steps / 6).max(25),
                keep_top_k: 5,
                log_every: (steps / 10).max(10),
                ..TrainCfg::default()
            },
            data,
            select_suites: vec![],
            eval: EvalCfg::default(),
            teacher_sample: SampleCfg { temperature: 1.0, top_p: 1.0, max_new: 12, seed: 33 },
        }
    }

    pub fn selecting_on(mut self, suites: &[Suite]) -> Self {
        self.select_suites = suites.to_vec();
        self
    }
}

/// The student weights a method produces (plus its training curve).
pub struct RecoveryOutcome {
    /// Registry key of the method that produced these weights ("qad", ...).
    pub method: String,
    pub params: Vec<f32>,
    pub curve: Vec<(usize, f64)>,
    pub val_curve: Vec<(usize, f64)>,
}

/// Produce student weights for the built-in `method` (enum convenience
/// wrapper over [`run_recovery`]).
///
/// * BF16  — the teacher itself (evaluated unquantized)
/// * PTQ   — teacher weights (evaluated through the fake-quant fwd; the
///           Rust codec also packs them for the memory accounting)
/// * QAT/QAD/MSE/NQT — fine-tuned from the teacher init with the matching
///           step artifact
pub fn run_method(
    engine: &Engine,
    rt: &ModelRuntime,
    method: Method,
    teacher: &[f32],
    cfg: &RecoveryCfg,
) -> Result<RecoveryOutcome> {
    run_recovery(engine, rt, method.key(), method.step_key(), method.fwd_key(), teacher, cfg)
}

/// The method-agnostic recovery loop: train `step_key` from the teacher
/// init (or return the teacher unchanged when `step_key` is None), then
/// apply the §3.4 top-k checkpoint-selection protocol through `fwd_key`.
///
/// This is the engine behind every `api::RecoveryMethod` implementation;
/// the method only decides which artifacts drive it.
pub fn run_recovery(
    engine: &Engine,
    rt: &ModelRuntime,
    method_key: &str,
    step_key: Option<&str>,
    fwd_key: &str,
    teacher: &[f32],
    cfg: &RecoveryCfg,
) -> Result<RecoveryOutcome> {
    let mut outcome = RecoveryOutcome {
        method: method_key.to_string(),
        params: teacher.to_vec(),
        curve: vec![],
        val_curve: vec![],
    };
    let Some(step_key) = step_key else {
        return Ok(outcome); // BF16 / PTQ need no training
    };

    let shape = shape_for(&rt.model);
    let mut factory = BatchFactory::new(shape, cfg.data.clone(), cfg.train.seed ^ 0xda7a);
    // Validation: clean SFT batches over the same suites.
    let val_suites: Vec<Suite> = cfg
        .data
        .iter()
        .flat_map(|s| s.suites.clone())
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect::<Vec<_>>();
    let val_suites = if val_suites.is_empty() {
        pipeline::train_suites(&rt.model.name).to_vec()
    } else {
        val_suites
    };
    let mut val_factory = BatchFactory::new(shape, vec![SourceSpec::sft(&val_suites)], 0x7a11);
    let val_spec = SourceSpec::sft(&val_suites);
    let trainer =
        Trainer::new(engine, rt).with_validation(&mut val_factory, &val_spec, 4)?;

    let needs_gen = cfg.data.iter().any(|s| s.kind.needs_generator());
    let mut generator = if needs_gen {
        Some(TeacherGenerator::new(engine, rt, "fwd_bf16", teacher, cfg.teacher_sample)?)
    } else {
        None
    };

    let teacher_buf = rt.upload_params(teacher)?;
    let mut state = DeviceState::from_params(rt, teacher)?;
    let log = trainer.train(
        step_key,
        &mut state,
        &mut factory,
        Some(&teacher_buf),
        generator
            .as_mut()
            .map(|g| g as &mut dyn crate::data::sources::ResponseGenerator),
        &cfg.train,
    )?;

    outcome.curve = log.records.iter().map(|r| (r.step, r.loss)).collect();
    outcome.val_curve = log.val_losses.clone();

    // §3.4 protocol: evaluate top-k checkpoints, keep the best average.
    let top = log.top_checkpoints();
    if top.is_empty() {
        outcome.params = state.params()?;
        return Ok(outcome);
    }
    if cfg.select_suites.is_empty() || top.len() == 1 {
        outcome.params = top[0].params.clone();
        return Ok(outcome);
    }
    let mut best: Option<(f64, Vec<f32>)> = None;
    for ck in top.iter().take(3) {
        let accs = run_suites(
            engine,
            rt,
            fwd_key,
            &ck.params,
            &cfg.select_suites,
            &cfg.eval,
        )?;
        let avg: f64 = accs.values().sum::<f64>() / accs.len().max(1) as f64;
        if best.as_ref().map(|(b, _)| avg > *b).unwrap_or(true) {
            best = Some((avg, ck.params.clone()));
        }
    }
    outcome.params = best.unwrap().1;
    Ok(outcome)
}

/// Evaluate a method's weights on the given suites.
pub fn eval_method(
    engine: &Engine,
    rt: &ModelRuntime,
    method: Method,
    params: &[f32],
    suites: &[Suite],
    cfg: &EvalCfg,
) -> Result<BTreeMap<String, f64>> {
    run_suites(engine, rt, method.fwd_key(), params, suites, cfg)
}

/// PTQ export report: pack the teacher's quantizable weights with the Rust
/// NVFP4 codec (bit-exact with the fwd_nvfp4 graph's weight handling) and
/// report compression + per-layer error.
pub fn ptq_report(rt: &ModelRuntime, teacher: &[f32]) -> quant::PtqReport {
    let mut params = teacher.to_vec();
    let layout: Vec<(String, Vec<usize>, usize, usize)> = rt
        .model
        .params
        .iter()
        .map(|p| (p.name.clone(), p.shape.clone(), p.offset, p.size))
        .collect();
    let model = rt.model.clone();
    quant::ptq_quantize_params(&mut params, &layout, &|name| {
        model.param_skipped_by_selective_quant(name)
    })
}
