//! Model merging substrate — the paper's multi-stage pipelines include
//! weight-space merging between post-training stages (Bercovich et al.,
//! 2025). Linear interpolation and uniform souping over flat parameter
//! vectors.

use anyhow::{bail, Result};

/// `(1-alpha)·a + alpha·b`, elementwise.
pub fn lerp(a: &[f32], b: &[f32], alpha: f32) -> Result<Vec<f32>> {
    if a.len() != b.len() {
        bail!("merge length mismatch: {} vs {}", a.len(), b.len());
    }
    Ok(a.iter()
        .zip(b)
        .map(|(x, y)| (1.0 - alpha) * x + alpha * y)
        .collect())
}

/// Uniform average of N parameter vectors ("model soup").
pub fn soup(models: &[&[f32]]) -> Result<Vec<f32>> {
    if models.is_empty() {
        bail!("empty soup");
    }
    let n = models[0].len();
    if models.iter().any(|m| m.len() != n) {
        bail!("soup length mismatch");
    }
    let scale = 1.0 / models.len() as f32;
    let mut out = vec![0f32; n];
    for m in models {
        for (o, v) in out.iter_mut().zip(*m) {
            *o += v * scale;
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lerp_endpoints_and_middle() {
        let a = vec![0.0f32, 2.0];
        let b = vec![4.0f32, -2.0];
        assert_eq!(lerp(&a, &b, 0.0).unwrap(), a);
        assert_eq!(lerp(&a, &b, 1.0).unwrap(), b);
        assert_eq!(lerp(&a, &b, 0.5).unwrap(), vec![2.0, 0.0]);
    }

    #[test]
    fn soup_is_mean() {
        let a = vec![1.0f32, 1.0];
        let b = vec![3.0f32, 5.0];
        let c = vec![2.0f32, 0.0];
        assert_eq!(soup(&[&a, &b, &c]).unwrap(), vec![2.0, 2.0]);
    }

    #[test]
    fn mismatch_rejected() {
        assert!(lerp(&[1.0], &[1.0, 2.0], 0.5).is_err());
        assert!(soup(&[]).is_err());
    }
}
