//! Parameter initialization on the Rust side (used when the coordinator
//! trains teachers from scratch — the whole post-training pipeline runs
//! in-repo, there are no external checkpoints).
//!
//! Follows the same scheme as python/compile/model.py `init_params`:
//! norm scales start at 1, bias-like vectors at 0, matrices at
//! N(0, 1/fan_in). Exact bit-equality with the Python init is not required
//! (training starts from scratch either way); the *layout* is the manifest
//! contract and is asserted here.

use crate::runtime::ModelEntry;
use crate::util::rng::Rng;

pub fn init_params(model: &ModelEntry, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x51ab_c0de);
    let mut out = vec![0f32; model.param_count];
    for p in &model.params {
        let leaf = p.name.rsplit('.').next().unwrap_or(&p.name);
        let slice = &mut out[p.offset..p.offset + p.size];
        if leaf.starts_with("ln") {
            slice.fill(1.0);
        } else if leaf == "a_bias" || leaf == "vis_bias" {
            slice.fill(0.0);
        } else {
            let fan_in = if p.shape.len() >= 2 {
                p.shape[p.shape.len() - 2]
            } else {
                p.shape[p.shape.len() - 1]
            }
            .max(1);
            let std = 1.0 / (fan_in as f64).sqrt();
            for v in slice.iter_mut() {
                *v = (rng.normal() * std) as f32;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{ModelEntry, ParamDef, QuantSettings};
    use std::collections::BTreeMap;

    fn toy_model() -> ModelEntry {
        ModelEntry {
            name: "toy".into(),
            d_model: 8,
            n_heads: 2,
            d_ff: 16,
            blocks: vec!["attn".into()],
            n_experts: 0,
            vocab: 16,
            seq_len: 8,
            batch: 2,
            vision: false,
            vision_grid: 0,
            vision_patch: 0,
            param_count: 8 + 64,
            state_len: 3 * 72 + 8,
            quant: QuantSettings {
                weights: "nvfp4".into(),
                acts: "nvfp4".into(),
                impl_: "jnp".into(),
                skip_attention: false,
                skip_first: 0,
                skip_last: 0,
            },
            params: vec![
                ParamDef { name: "b0.ln1".into(), shape: vec![8], offset: 0, size: 8 },
                ParamDef { name: "b0.wq".into(), shape: vec![8, 8], offset: 8, size: 64 },
            ],
            artifacts: BTreeMap::new(),
        }
    }

    #[test]
    fn norms_one_weights_random() {
        let m = toy_model();
        let p = init_params(&m, 0);
        assert!(p[..8].iter().all(|&v| v == 1.0));
        let w = &p[8..];
        assert!(w.iter().any(|&v| v != 0.0));
        let mean: f32 = w.iter().sum::<f32>() / w.len() as f32;
        assert!(mean.abs() < 0.2);
    }

    #[test]
    fn deterministic_per_seed() {
        let m = toy_model();
        assert_eq!(init_params(&m, 7), init_params(&m, 7));
        assert_ne!(init_params(&m, 7), init_params(&m, 8));
    }
}
