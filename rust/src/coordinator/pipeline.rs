//! Teacher construction: the multi-stage post-training pipelines the paper
//! distills *from*. Each sim model gets the pipeline of its real
//! counterpart (DESIGN.md §2):
//!
//!   super-sim  (Llama Nemotron Super V1): SFT branch A + SFT branch B →
//!              weight merge → SFT polish   ("SFT + model merging")
//!   ace-sim    (AceReason): cold-start SFT (partially-correct data) → RL
//!   nano-sim   (Nemotron Nano 9B V2): multi-stage SFT (broad mixture)
//!   nano3-sim  (Nemotron 3 Nano MoE): cold-start SFT → RL
//!   vl-sim     (Nemotron Nano VL): single-stage SFT on the vision suites
//!   size-*     : short clean SFT (Table 12 size-law sweep)
//!
//! Finished teachers are cached in runs/teachers/<model>.qckp; every
//! experiment reuses the same teacher.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use super::checkpoint;
use super::init::init_params;
use super::merge;
use super::rl::{rl_stage, RlCfg};
use super::trainer::{LrSchedule, TrainCfg, Trainer};
use crate::data::tasks::Suite;
use crate::data::{shape_for, BatchFactory, SourceSpec, TEXT_SUITES, VISION_SUITES};
use crate::eval::SampleCfg;
use crate::runtime::{DeviceState, Engine, ModelRuntime};
use crate::util::json::Json;
use crate::util::Timer;

/// Step-count scale knob: 1.0 = full sim pipeline; CI smoke uses ~0.05.
#[derive(Clone, Copy, Debug)]
pub struct PipelineScale(pub f64);

impl PipelineScale {
    pub fn steps(&self, base: usize) -> usize {
        ((base as f64 * self.0).round() as usize).max(8)
    }
}

impl Default for PipelineScale {
    fn default() -> Self {
        PipelineScale(1.0)
    }
}

pub const MATH_SUITES: &[Suite] = &[Suite::Math500, Suite::Aime];
pub const CODE_SUITES: &[Suite] = &[Suite::Lcb, Suite::SciCode];

/// Training suites per model (what the real model's post-training covered).
pub fn train_suites(model: &str) -> &'static [Suite] {
    match model {
        "ace-sim" => &[Suite::Math500, Suite::Aime, Suite::Lcb, Suite::SciCode],
        "vl-sim" => VISION_SUITES,
        _ => TEXT_SUITES,
    }
}

/// The RL prompt distribution for the RL-heavy models.
pub fn rl_suites(model: &str) -> &'static [Suite] {
    match model {
        "ace-sim" => &[Suite::Math500, Suite::Aime, Suite::Lcb, Suite::SciCode],
        "nano3-sim" => &[Suite::Math500, Suite::Aime, Suite::Lcb, Suite::Gpqa, Suite::AaLcr],
        _ => &[],
    }
}

/// Whether a model's pipeline ends with an RL stage (Table 3 models).
pub fn is_rl_heavy(model: &str) -> bool {
    matches!(model, "ace-sim" | "nano3-sim")
}

pub struct TeacherReport {
    pub params: Vec<f32>,
    pub stages: Vec<String>,
    pub rl_reward_before: f64,
    pub rl_reward_after: f64,
}

/// Load a cached teacher checkpoint if it exists and matches the expected
/// parameter count. A stale (wrong-size) or unreadable cache returns None
/// so the caller retrains instead of serving bad weights.
pub fn load_cached_teacher(path: &Path, expect: usize) -> Option<Vec<f32>> {
    if !path.exists() {
        return None;
    }
    match checkpoint::load(path) {
        Ok(params) if params.len() == expect => Some(params),
        Ok(params) => {
            eprintln!(
                "teacher cache {path:?} has stale size ({} != {expect}); retraining",
                params.len()
            );
            None
        }
        Err(e) => {
            eprintln!("teacher cache {path:?} unreadable ({e:#}); retraining");
            None
        }
    }
}

/// Load the cached teacher or run the full pipeline.
pub fn get_or_train_teacher(
    engine: &Engine,
    model: &str,
    runs_dir: &Path,
    scale: PipelineScale,
) -> Result<Vec<f32>> {
    let path = teacher_path(runs_dir, model);
    let expect = engine.manifest.model(model)?.param_count;
    if let Some(params) = load_cached_teacher(&path, expect) {
        return Ok(params);
    }
    let report = train_teacher(engine, model, scale)?;
    let meta = Json::obj(vec![
        ("model", Json::Str(model.into())),
        ("stages", Json::Arr(report.stages.iter().map(|s| Json::Str(s.clone())).collect())),
        // qadx-lint: allow(artifact-keys) -- checkpoint JSON metadata field, not an artifact key
        ("rl_reward_before", Json::Num(report.rl_reward_before)),
        // qadx-lint: allow(artifact-keys) -- checkpoint JSON metadata field, not an artifact key
        ("rl_reward_after", Json::Num(report.rl_reward_after)),
        ("scale", Json::Num(scale.0)),
    ]);
    checkpoint::save(&path, &report.params, &meta)?;
    Ok(report.params)
}

pub fn teacher_path(runs_dir: &Path, model: &str) -> PathBuf {
    runs_dir.join("teachers").join(format!("{model}.qckp"))
}

/// Run the model's full post-training pipeline from random init.
pub fn train_teacher(engine: &Engine, model: &str, scale: PipelineScale) -> Result<TeacherReport> {
    let timer = Timer::start(&format!("teacher[{model}]"));
    let rt = ModelRuntime::new(engine, model)?;
    let shape = shape_for(&rt.model);
    let mut stages = Vec::new();
    let suites = train_suites(model);
    let params = init_params(&rt.model, 42);
    let mut state = DeviceState::from_params(&rt, &params)?;

    let sft_cfg = |steps: usize, lr: f64, seed: u64| TrainCfg {
        steps,
        lr,
        schedule: LrSchedule::CosineWarmup { warmup: steps / 10, floor: 0.1 },
        log_every: 0,
        val_every: 0,
        keep_top_k: 0,
        seed,
    };

    let mut report = TeacherReport {
        params: Vec::new(),
        stages: Vec::new(),
        rl_reward_before: 0.0,
        rl_reward_after: 0.0,
    };

    match model {
        "super-sim" => {
            // SFT branch A → (from A) SFT branch B on a different slice →
            // merge → short polish: exercises the merging substrate.
            let half_a = &suites[..suites.len() / 2 + 1];
            let half_b = &suites[suites.len() / 2..];
            let trainer = Trainer::new(engine, &rt);
            let mut fa = BatchFactory::new(shape, vec![SourceSpec::sft(suites)], 1);
            trainer.train("sft_bf16", &mut state, &mut fa, None, None, &sft_cfg(scale.steps(3000), 2e-3, 1))?;
            stages.push("sft-base".into());
            let base = state.params()?;
            // branch A
            let mut fa2 = BatchFactory::new(shape, vec![SourceSpec::sft(half_a)], 2);
            let mut sa = DeviceState::from_params(&rt, &base)?;
            trainer.train("sft_bf16", &mut sa, &mut fa2, None, None, &sft_cfg(scale.steps(500), 1e-3, 2))?;
            // branch B
            let mut fb = BatchFactory::new(shape, vec![SourceSpec::sft(half_b)], 3);
            let mut sb = DeviceState::from_params(&rt, &base)?;
            trainer.train("sft_bf16", &mut sb, &mut fb, None, None, &sft_cfg(scale.steps(500), 1e-3, 3))?;
            let merged = merge::lerp(&sa.params()?, &sb.params()?, 0.5)?;
            stages.push("sft-branches+merge".into());
            // polish
            state = DeviceState::from_params(&rt, &merged)?;
            let mut fp = BatchFactory::new(shape, vec![SourceSpec::sft(suites)], 4);
            trainer.train("sft_bf16", &mut state, &mut fp, None, None, &sft_cfg(scale.steps(600), 5e-4, 4))?;
            stages.push("sft-polish".into());
        }
        "ace-sim" | "nano3-sim" => {
            // Cold-start SFT on partially-correct data, then RL.
            let trainer = Trainer::new(engine, &rt);
            let cold = SourceSpec::sft_quality(suites, 0.7);
            let mut f = BatchFactory::new(shape, vec![cold], 1);
            trainer.train("sft_bf16", &mut state, &mut f, None, None, &sft_cfg(scale.steps(3500), 2e-3, 1))?;
            stages.push("cold-start-sft(p_correct=0.7)".into());
            let rl_cfg = RlCfg {
                iterations: scale.steps(200),
                group_size: 4,
                lr: 1e-4,
                sample: SampleCfg { temperature: 1.0, top_p: 1.0, max_new: 8, seed: 11 },
                seed: 11,
                log_every: 20,
            };
            let rl_log = rl_stage(engine, &rt, &mut state, rl_suites(model), &rl_cfg)?;
            report.rl_reward_before = rl_log.curve.first().map(|c| c.1).unwrap_or(0.0);
            report.rl_reward_after = rl_log.final_reward;
            stages.push(format!(
                "rl(reward {:.2} -> {:.2})",
                report.rl_reward_before, report.rl_reward_after
            ));
        }
        "nano-sim" | "vl-sim" => {
            // Multi-stage SFT: broad mixture then a focused second stage.
            let trainer = Trainer::new(engine, &rt);
            let mut f = BatchFactory::new(shape, vec![SourceSpec::sft(suites)], 1);
            trainer.train("sft_bf16", &mut state, &mut f, None, None, &sft_cfg(scale.steps(3500), 2e-3, 1))?;
            stages.push("sft-stage1".into());
            let mut f2 = BatchFactory::new(shape, vec![SourceSpec::sft(suites)], 2);
            trainer.train("sft_bf16", &mut state, &mut f2, None, None, &sft_cfg(scale.steps(800), 5e-4, 2))?;
            stages.push("sft-stage2".into());
        }
        m if m.starts_with("size-") => {
            let trainer = Trainer::new(engine, &rt);
            let sw: &[Suite] = &[Suite::Math500, Suite::Lcb, Suite::Gpqa];
            let mut f = BatchFactory::new(shape, vec![SourceSpec::sft(sw)], 1);
            trainer.train("sft_bf16", &mut state, &mut f, None, None, &sft_cfg(scale.steps(2500), 2e-3, 1))?;
            stages.push("sft".into());
        }
        other => bail!("no pipeline defined for model {other:?}"),
    }

    report.params = state.params()?;
    report.stages = stages;
    eprintln!("{} ({} stages)", timer.report(), report.stages.len());
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::Json;

    #[test]
    fn cached_teacher_rejects_stale_size() {
        let dir = std::env::temp_dir().join("qadx_teacher_cache_test");
        let path = teacher_path(&dir, "m");
        let params: Vec<f32> = (0..16).map(|i| i as f32).collect();
        checkpoint::save(&path, &params, &Json::obj(vec![])).unwrap();
        assert_eq!(load_cached_teacher(&path, 16), Some(params));
        // wrong expected size -> treated as a miss, not served
        assert_eq!(load_cached_teacher(&path, 8), None);
        // missing file -> miss
        assert_eq!(load_cached_teacher(&teacher_path(&dir, "other"), 16), None);
        std::fs::remove_dir_all(&dir).ok();
    }
}
