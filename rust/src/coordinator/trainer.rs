//! The training loop: drives any step artifact (SFT / QAT / QAD / MSE /
//! NQT / RL) with a device-resident state vector, LR scheduling,
//! validation, and checkpoint capture.
//!
//! Arguments are assembled *from the manifest arg list* of the chosen
//! artifact (name-directed), so one loop serves every step variant.

use anyhow::{bail, Result};

use crate::data::sources::ResponseGenerator;
use crate::data::{BatchFactory, SourceSpec};
use crate::runtime::{scalar, Batch, Buffer, DeviceState, Engine, ModelRuntime};

use super::checkpoint::Checkpoint;

#[derive(Clone, Debug)]
pub enum LrSchedule {
    Const,
    /// Linear warmup over `warmup` steps then cosine decay to `floor`·lr.
    CosineWarmup { warmup: usize, floor: f64 },
}

#[derive(Clone, Debug)]
pub struct TrainCfg {
    pub steps: usize,
    pub lr: f64,
    pub schedule: LrSchedule,
    pub log_every: usize,
    /// Validate + (maybe) checkpoint every N steps; 0 disables.
    pub val_every: usize,
    /// Keep the top-K checkpoints by validation loss (paper §3.4 keeps 10).
    pub keep_top_k: usize,
    pub seed: u64,
}

impl Default for TrainCfg {
    fn default() -> Self {
        TrainCfg {
            steps: 500,
            lr: 1e-3,
            schedule: LrSchedule::Const,
            log_every: 50,
            val_every: 100,
            keep_top_k: 5,
            seed: 0,
        }
    }
}

impl TrainCfg {
    pub fn lr_at(&self, step: usize) -> f64 {
        match &self.schedule {
            LrSchedule::Const => self.lr,
            LrSchedule::CosineWarmup { warmup, floor } => {
                if step < *warmup {
                    self.lr * (step + 1) as f64 / *warmup as f64
                } else {
                    let t = (step - warmup) as f64 / (self.steps - warmup).max(1) as f64;
                    let cos = 0.5 * (1.0 + (std::f64::consts::PI * t.min(1.0)).cos());
                    self.lr * (floor + (1.0 - floor) * cos)
                }
            }
        }
    }
}

#[derive(Clone, Debug, Default)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f64,
    pub kl: f64,
    pub ce: f64,
    pub grad_norm: f64,
    pub lr: f64,
}

#[derive(Debug, Default)]
pub struct TrainLog {
    pub records: Vec<StepRecord>,
    pub val_losses: Vec<(usize, f64)>,
    pub checkpoints: Vec<Checkpoint>,
    pub final_loss: f64,
}

impl TrainLog {
    /// Checkpoints sorted best-val-loss first.
    pub fn top_checkpoints(&self) -> Vec<&Checkpoint> {
        let mut v: Vec<&Checkpoint> = self.checkpoints.iter().collect();
        v.sort_by(|a, b| a.val_loss.partial_cmp(&b.val_loss).unwrap());
        v
    }
}

pub struct Trainer<'e> {
    pub engine: &'e Engine,
    pub rt: &'e ModelRuntime<'e>,
    /// Validation batches (pre-generated, fixed).
    pub val_batches: Vec<Batch>,
}

impl<'e> Trainer<'e> {
    pub fn new(engine: &'e Engine, rt: &'e ModelRuntime<'e>) -> Trainer<'e> {
        Trainer { engine, rt, val_batches: Vec::new() }
    }

    /// Pre-generate fixed validation batches from a clean source.
    pub fn with_validation(
        mut self,
        factory: &mut BatchFactory,
        spec: &SourceSpec,
        n_batches: usize,
    ) -> Result<Self> {
        for _ in 0..n_batches {
            self.val_batches.push(factory.batch_from_spec(spec, None)?);
        }
        Ok(self)
    }

    /// Run `cfg.steps` of `step_key`, pulling batches from `factory`
    /// (using `gen` for generation-backed sources) and distilling from
    /// `teacher` when the artifact takes teacher params.
    pub fn train(
        &self,
        step_key: &str,
        state: &mut DeviceState,
        factory: &mut BatchFactory,
        teacher: Option<&Buffer>,
        mut gen: Option<&mut dyn ResponseGenerator>,
        cfg: &TrainCfg,
    ) -> Result<TrainLog> {
        let exe = self.rt.exe(step_key)?;
        let art = self.rt.model.artifact(step_key)?.clone();
        let mut log = TrainLog::default();

        for step in 0..cfg.steps {
            let batch = {
                let g = gen.as_mut().map(|g| &mut **g as &mut dyn ResponseGenerator);
                factory.next_batch(g)?
            };
            let lr = cfg.lr_at(step) as f32;
            let lr_buf = self.engine.upload_scalar(lr)?;
            let tokens = self.rt.upload_tokens(&batch)?;
            let mask = self.rt.upload_mask(&batch)?;
            let px = self.rt.upload_pixels(&batch)?;
            let adv = if art.args.iter().any(|a| a.name == "advantage") {
                Some(self.rt.upload_advantage(&batch)?)
            } else {
                None
            };

            let mut args: Vec<&Buffer> = Vec::with_capacity(art.args.len());
            for a in &art.args {
                args.push(match a.name.as_str() {
                    "state" => &state.buf,
                    "teacher_params" => teacher
                        .ok_or_else(|| anyhow::anyhow!("{step_key} needs teacher params"))?,
                    "tokens" => &tokens,
                    "mask" => &mask,
                    "lr" => &lr_buf,
                    "advantage" => adv.as_ref().unwrap(),
                    "pixels" => px
                        .as_ref()
                        .ok_or_else(|| anyhow::anyhow!("{step_key} needs pixels"))?,
                    other => bail!("unknown artifact arg {other:?}"),
                });
            }
            let out = self.engine.run_b(&exe, &args)?;
            state.advance(out);

            let want_log = cfg.log_every > 0 && (step + 1) % cfg.log_every == 0;
            let want_val = cfg.val_every > 0
                && ((step + 1) % cfg.val_every == 0 || step + 1 == cfg.steps);
            if want_log || want_val {
                let sc = state.scalars()?;
                log.records.push(StepRecord {
                    step: step + 1,
                    loss: sc[scalar::LOSS] as f64,
                    kl: sc[scalar::KL] as f64,
                    ce: sc[scalar::CE] as f64,
                    grad_norm: sc[scalar::GRAD_NORM] as f64,
                    lr: sc[scalar::LR] as f64,
                });
                log.final_loss = sc[scalar::LOSS] as f64;
            }
            if want_val && !self.val_batches.is_empty() {
                let vl = self.validate(step_key, state, teacher)?;
                log.val_losses.push((step + 1, vl));
                let ck = Checkpoint {
                    step: step + 1,
                    val_loss: vl,
                    params: state.params()?,
                };
                log.checkpoints.push(ck);
                // retain top-k (+ always the latest)
                if log.checkpoints.len() > cfg.keep_top_k {
                    let mut idx: Vec<usize> = (0..log.checkpoints.len()).collect();
                    idx.sort_by(|&a, &b| {
                        log.checkpoints[a]
                            .val_loss
                            .partial_cmp(&log.checkpoints[b].val_loss)
                            .unwrap()
                    });
                    idx.truncate(cfg.keep_top_k);
                    idx.sort();
                    let mut kept = Vec::with_capacity(idx.len());
                    for i in idx {
                        kept.push(log.checkpoints[i].clone());
                    }
                    log.checkpoints = kept;
                }
            }
        }
        Ok(log)
    }

    /// Validation loss: the *training* objective evaluated on the fixed
    /// validation batches without updating (uses a zero learning rate; the
    /// Adam moments in the scratch state are discarded).
    fn validate(
        &self,
        step_key: &str,
        state: &DeviceState,
        teacher: Option<&Buffer>,
    ) -> Result<f64> {
        let exe = self.rt.exe(step_key)?;
        let art = self.rt.model.artifact(step_key)?.clone();
        let zero_lr = self.engine.upload_scalar(0.0)?;
        let mut total = 0f64;
        for batch in &self.val_batches {
            let tokens = self.rt.upload_tokens(batch)?;
            let mask = self.rt.upload_mask(batch)?;
            let px = self.rt.upload_pixels(batch)?;
            let adv_host = Batch {
                advantage: Some(vec![0.0; self.rt.model.batch]),
                ..Default::default()
            };
            let adv = if art.args.iter().any(|a| a.name == "advantage") {
                Some(self.rt.upload_advantage(&adv_host)?)
            } else {
                None
            };
            let mut args: Vec<&Buffer> = Vec::with_capacity(art.args.len());
            for a in &art.args {
                args.push(match a.name.as_str() {
                    "state" => &state.buf,
                    "teacher_params" => {
                        teacher.ok_or_else(|| anyhow::anyhow!("needs teacher"))?
                    }
                    "tokens" => &tokens,
                    "mask" => &mask,
                    "lr" => &zero_lr,
                    "advantage" => adv.as_ref().unwrap(),
                    "pixels" => px.as_ref().ok_or_else(|| anyhow::anyhow!("needs pixels"))?,
                    other => bail!("unknown artifact arg {other:?}"),
                });
            }
            let out = self.engine.run_b(&exe, &args)?;
            // lr = 0 leaves params untouched (Adam moments shift, but the
            // scratch state is dropped right after reading the loss).
            let tmp = state.like(out);
            total += tmp.scalars()?[scalar::LOSS] as f64;
        }
        Ok(total / self.val_batches.len().max(1) as f64)
    }
}
