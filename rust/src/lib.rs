//! qadx — Quantization-Aware Distillation for NVFP4 inference accuracy
//! recovery: a three-layer Rust + JAX + Pallas reproduction.
//!
//! Layer map (see DESIGN.md):
//! * L1 (Pallas kernels) and L2 (JAX model/step graphs) live in
//!   `python/compile/` and are AOT-lowered to HLO text by `make artifacts`.
//! * L3 — this crate — owns everything at run time: the PJRT runtime
//!   (`runtime`), the bit-exact NVFP4 substrate (`quant`), synthetic task
//!   corpus + data sources (`data`), the post-training/distillation
//!   coordinator (`coordinator`), sampling-based evaluation (`eval`), and
//!   the paper-table experiment harness (`exper`).

pub mod api;
pub mod quant;
pub mod runtime;
pub mod util;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exper;
