//! qadx — leader entrypoint / CLI.
//!
//! Subcommands:
//!   info                         manifest + artifact summary
//!   teacher <model>              run the model's post-training pipeline
//!   ptq <model>                  PTQ export report (compression, per-layer err)
//!   recover <model> --method M   QAD/QAT/MSE/NQT accuracy recovery
//!   eval <model> --method M      benchmark a method's weights
//!   pilot                        scaled-down end-to-end sanity run
//!   table <N> | all-tables       regenerate paper tables (exper harness)
//!   figure <1|2>                 regenerate paper figures (CSV curves)
//!
//! Common flags: --artifacts DIR (default artifacts/), --runs DIR (default
//! runs/), --scale F (teacher pipeline step scale), --n / --k (eval size).

use std::path::PathBuf;
use std::process::ExitCode;

use qadx::coordinator::{self, Method, PipelineScale, RecoveryCfg};
use qadx::data::Suite;
use qadx::data::SourceSpec;
use qadx::eval::EvalCfg;
use qadx::exper;
use qadx::runtime::{Engine, ModelRuntime};
use qadx::util::args::Args;

fn main() -> ExitCode {
    let args = Args::from_env();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn engine(args: &Args) -> anyhow::Result<Engine> {
    let dir = PathBuf::from(args.get_or("artifacts", "artifacts"));
    Engine::new(&dir)
}

fn runs_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("runs", "runs"))
}

fn run(args: &Args) -> anyhow::Result<()> {
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "info" => info(args),
        "teacher" => teacher(args),
        "ptq" => ptq(args),
        "recover" => recover(args),
        "eval" => eval_cmd(args),
        "pilot" => pilot(args),
        "table" => exper::run_table_cmd(args),
        "all-tables" => exper::run_all_tables(args),
        "figure" => exper::run_figure_cmd(args),
        _ => {
            println!("{HELP}");
            Ok(())
        }
    }
}

const HELP: &str = "qadx — NVFP4 QAD reproduction
usage: qadx <info|teacher|ptq|recover|eval|pilot|table|all-tables|figure> [flags]
see rust/src/main.rs header for flags";

fn info(args: &Args) -> anyhow::Result<()> {
    let engine = engine(args)?;
    let m = &engine.manifest;
    println!("vocab={} scalars={:?}", m.vocab, m.scalar_names);
    for (name, e) in &m.models {
        println!(
            "{name}: d={} blocks={:?} params={} state={} quant={}/{} skip(attn={},first={},last={}) artifacts={}",
            e.d_model,
            e.blocks,
            e.param_count,
            e.state_len,
            e.quant.weights,
            e.quant.impl_,
            e.quant.skip_attention,
            e.quant.skip_first,
            e.quant.skip_last,
            e.artifacts.len()
        );
    }
    Ok(())
}

fn teacher(args: &Args) -> anyhow::Result<()> {
    let engine = engine(args)?;
    let model = args.positional.get(1).map(|s| s.as_str()).unwrap_or("ace-sim");
    let scale = PipelineScale(args.f64_or("scale", 1.0));
    let params = coordinator::get_or_train_teacher(&engine, model, &runs_dir(args), scale)?;
    println!("teacher[{model}]: {} params cached", params.len());
    Ok(())
}

fn ptq(args: &Args) -> anyhow::Result<()> {
    let engine = engine(args)?;
    let model = args.positional.get(1).map(|s| s.as_str()).unwrap_or("ace-sim");
    let scale = PipelineScale(args.f64_or("scale", 1.0));
    let teacher = coordinator::get_or_train_teacher(&engine, model, &runs_dir(args), scale)?;
    let rt = ModelRuntime::new(&engine, model)?;
    let report = coordinator::ptq_report(&rt, &teacher);
    println!("PTQ export for {model} (NVFP4, block 16, E4M3 scales):");
    for (name, err, bytes) in &report.layers {
        if *err > 0.0 {
            println!("  {name:<12} rel_err={err:.4} bytes={bytes}");
        }
    }
    println!(
        "total: {} B (f32 {} B) — compression {:.2}x",
        report.total_bytes_nvfp4,
        report.total_bytes_f32,
        report.compression_ratio()
    );
    Ok(())
}

fn parse_method(s: &str) -> anyhow::Result<Method> {
    Ok(match s {
        "bf16" => Method::Bf16,
        "ptq" => Method::Ptq,
        "qat" => Method::Qat,
        "qad" => Method::Qad,
        "mse" => Method::Mse,
        "nqt" => Method::Nqt,
        other => anyhow::bail!("unknown method {other:?}"),
    })
}

fn parse_suites(args: &Args, default: &[Suite]) -> Vec<Suite> {
    args.get("suites")
        .map(|s| s.split(',').filter_map(Suite::from_name).collect::<Vec<_>>())
        .filter(|v| !v.is_empty())
        .unwrap_or_else(|| default.to_vec())
}

fn recover(args: &Args) -> anyhow::Result<()> {
    let engine = engine(args)?;
    let model = args.positional.get(1).map(|s| s.as_str()).unwrap_or("ace-sim");
    let method = parse_method(&args.get_or("method", "qad"))?;
    let scale = PipelineScale(args.f64_or("scale", 1.0));
    let teacher = coordinator::get_or_train_teacher(&engine, model, &runs_dir(args), scale)?;
    let rt = ModelRuntime::new(&engine, model)?;
    let suites = parse_suites(args, coordinator::pipeline::train_suites(model));
    let cfg = RecoveryCfg::new(
        vec![SourceSpec::sft(&suites)],
        args.f64_or("lr", 1e-4),
        args.usize_or("steps", 300),
    );
    let out = coordinator::run_method(&engine, &rt, method, &teacher, &cfg)?;
    println!("{} trained; loss curve:", method.name());
    for (s, l) in &out.curve {
        println!("  step {s:>5}  loss {l:.5}");
    }
    let path = runs_dir(args)
        .join("recovered")
        .join(format!("{model}-{}.qckp", args.get_or("method", "qad")));
    coordinator::checkpoint::save(
        &path,
        &out.params,
        &qadx::util::json::Json::obj(vec![(
            "method",
            qadx::util::json::Json::Str(method.name().into()),
        )]),
    )?;
    println!("saved {path:?}");
    Ok(())
}

fn eval_cmd(args: &Args) -> anyhow::Result<()> {
    let engine = engine(args)?;
    let model = args.positional.get(1).map(|s| s.as_str()).unwrap_or("ace-sim");
    let method = parse_method(&args.get_or("method", "bf16"))?;
    let scale = PipelineScale(args.f64_or("scale", 1.0));
    let teacher = coordinator::get_or_train_teacher(&engine, model, &runs_dir(args), scale)?;
    let rt = ModelRuntime::new(&engine, model)?;
    let suites = parse_suites(args, coordinator::pipeline::train_suites(model));
    let mut ecfg = EvalCfg::default();
    ecfg.n_problems = args.usize_or("n", ecfg.n_problems);
    ecfg.k_runs = args.usize_or("k", ecfg.k_runs);
    let params = match method {
        Method::Bf16 | Method::Ptq => teacher,
        _ => {
            let p = runs_dir(args)
                .join("recovered")
                .join(format!("{model}-{}.qckp", args.get_or("method", "qad")));
            coordinator::checkpoint::load(&p)?
        }
    };
    let accs = coordinator::eval_method(&engine, &rt, method, &params, &suites, &ecfg)?;
    println!("{} on {model} (n={}, k={}):", method.name(), ecfg.n_problems, ecfg.k_runs);
    for (s, a) in accs {
        println!("  {s:<16} {a:6.1}");
    }
    Ok(())
}

/// Scaled-down end-to-end sanity run: teacher → PTQ gap → QAD/QAT recovery.
fn pilot(args: &Args) -> anyhow::Result<()> {
    let engine = engine(args)?;
    let model = args.get_or("model", "ace-sim");
    let scale = PipelineScale(args.f64_or("scale", 0.3));
    println!("== pilot on {model} (scale {}) ==", scale.0);
    let report = coordinator::train_teacher(&engine, &model, scale)?;
    println!("stages: {:?}", report.stages);
    let rt = ModelRuntime::new(&engine, &model)?;
    let suites = parse_suites(args, &[Suite::Math500, Suite::Aime, Suite::Lcb]);
    let mut ecfg = EvalCfg::default();
    ecfg.n_problems = args.usize_or("n", 24);
    ecfg.k_runs = args.usize_or("k", 2);

    let bf16 = coordinator::eval_method(&engine, &rt, Method::Bf16, &report.params, &suites, &ecfg)?;
    println!("BF16: {bf16:?}");
    let ptq = coordinator::eval_method(&engine, &rt, Method::Ptq, &report.params, &suites, &ecfg)?;
    println!("PTQ:  {ptq:?}");

    let cfg = RecoveryCfg::new(
        vec![SourceSpec::sft(&suites)],
        args.f64_or("lr", 1e-4),
        args.usize_or("steps", 200),
    );
    let qad = coordinator::run_method(&engine, &rt, Method::Qad, &report.params, &cfg)?;
    println!("QAD loss curve: {:?}", qad.curve);
    let qad_acc = coordinator::eval_method(&engine, &rt, Method::Qad, &qad.params, &suites, &ecfg)?;
    println!("QAD:  {qad_acc:?}");
    let qat = coordinator::run_method(&engine, &rt, Method::Qat, &report.params, &cfg)?;
    let qat_acc = coordinator::eval_method(&engine, &rt, Method::Qat, &qat.params, &suites, &ecfg)?;
    println!("QAT:  {qat_acc:?}");
    Ok(())
}
