//! qadx — leader entrypoint / CLI.
//!
//! Every subcommand is a thin typed wrapper over `qadx::api`: flags parse
//! into the same config structs library users build by hand
//! (`api::cli::*Args`), sessions come from `Session::builder()`, and all
//! teacher/checkpoint/method plumbing lives in the API layer. Run
//! `qadx help` (or `qadx help <command>`) for generated usage text.

use std::process::ExitCode;
use std::time::Instant;

use qadx::api::cli::{
    self, EvalArgs, PilotArgs, RecoverArgs, ServeBenchArgs, SessionArgs,
};
use qadx::api::{FleetCfg, RequestClass, Saturated, ServeCfg, TokenSink};
use qadx::coordinator::RecoveryCfg;
use qadx::data::{tasks, SourceSpec, Suite};
use qadx::eval::EvalCfg;
use qadx::exper;
use qadx::util::args::Args;
use qadx::util::rng::Rng;

fn main() -> ExitCode {
    let args = Args::from_env();
    match run(&args) {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run(args: &Args) -> anyhow::Result<()> {
    let name = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    let Some(cmd) = cli::find_command(name) else {
        println!("{}", cli::render_help());
        if name != "help" {
            anyhow::bail!("unknown command {name:?}");
        }
        return Ok(());
    };
    cli::check_flags(cmd, args)?;
    match cmd.name {
        "info" => info(args),
        "teacher" => teacher(args),
        "ptq" => ptq(args),
        "recover" => recover(args),
        "eval" => eval_cmd(args),
        "pilot" => pilot(args),
        "serve-bench" => serve_bench(args),
        "table" => exper::run_table_cmd(args),
        "all-tables" => exper::run_all_tables(args),
        "figure" => exper::run_figure_cmd(args),
        _ => {
            // `help [command]`
            match args.positional.get(1).and_then(|c| cli::find_command(c)) {
                Some(c) => println!("{}", cli::render_usage(c)),
                None => println!("{}", cli::render_help()),
            }
            Ok(())
        }
    }
}

fn positional_model(args: &Args) -> String {
    args.positional.get(1).cloned().unwrap_or_else(|| "ace-sim".into())
}

fn info(args: &Args) -> anyhow::Result<()> {
    let session = SessionArgs::parse(args)?.build()?;
    let m = session.manifest();
    println!("vocab={} scalars={:?}", m.vocab, m.scalar_names);
    for (name, e) in &m.models {
        println!(
            "{name}: d={} blocks={:?} params={} state={} quant={}/{} skip(attn={},first={},last={}) artifacts={}",
            e.d_model,
            e.blocks,
            e.param_count,
            e.state_len,
            e.quant.weights,
            e.quant.impl_,
            e.quant.skip_attention,
            e.quant.skip_first,
            e.quant.skip_last,
            e.artifacts.len()
        );
    }
    println!("methods: {}", session.methods().names().join(", "));
    Ok(())
}

fn teacher(args: &Args) -> anyhow::Result<()> {
    let session = SessionArgs::parse(args)?.build()?;
    let ms = session.model(&positional_model(args))?;
    let params = ms.teacher()?;
    println!("teacher[{}]: {} params cached", ms.name(), params.len());
    Ok(())
}

fn ptq(args: &Args) -> anyhow::Result<()> {
    let session = SessionArgs::parse(args)?.build()?;
    let ms = session.model(&positional_model(args))?;
    let report = ms.ptq_report()?;
    println!("PTQ export for {} (NVFP4, block 16, E4M3 scales):", ms.name());
    for (name, err, bytes) in &report.layers {
        if *err > 0.0 {
            println!("  {name:<12} rel_err={err:.4} bytes={bytes}");
        }
    }
    println!(
        "total: {} B (f32 {} B) — compression {:.2}x",
        report.total_bytes_nvfp4,
        report.total_bytes_f32,
        report.compression_ratio()
    );
    Ok(())
}

fn recover(args: &Args) -> anyhow::Result<()> {
    let r = RecoverArgs::parse(args)?;
    let session = r.session.build()?;
    let ms = session.model(&r.model)?;
    let suites = r.suites.clone().unwrap_or_else(|| ms.train_suites().to_vec());
    let mut cfg = RecoveryCfg::new(vec![SourceSpec::sft(&suites)], r.lr, r.steps);
    cfg.train.seed = session.seed();
    let out = ms.recover(&*r.method, &cfg)?;
    println!("{} trained; loss curve:", r.method.display_name());
    for (s, l) in &out.curve {
        println!("  step {s:>5}  loss {l:.5}");
    }
    let path = ms.save_recovered(&*r.method, &out)?;
    println!("saved {path:?}");
    Ok(())
}

fn eval_cmd(args: &Args) -> anyhow::Result<()> {
    let e = EvalArgs::parse(args)?;
    let session = e.session.build()?;
    let ms = session.model(&e.model)?;
    let suites = e.suites.clone().unwrap_or_else(|| ms.train_suites().to_vec());
    let mut ecfg = EvalCfg::default();
    ecfg.n_problems = e.n;
    ecfg.k_runs = e.k;
    ecfg.sample = ms.sample_cfg();
    // Weights follow the *parsed* method: teacher for training-free
    // methods, otherwise the checkpoint at the method-derived path.
    let params = ms.method_params(&*e.method)?;
    let accs = ms.evaluate(&*e.method, &params, &suites, &ecfg)?;
    println!(
        "{} on {} (n={}, k={}):",
        e.method.display_name(),
        ms.name(),
        ecfg.n_problems,
        ecfg.k_runs
    );
    for (s, a) in accs {
        println!("  {s:<16} {a:6.1}");
    }
    Ok(())
}

/// Scaled-down end-to-end sanity run: teacher → PTQ gap → QAD/QAT recovery.
fn pilot(args: &Args) -> anyhow::Result<()> {
    let p = PilotArgs::parse(args)?;
    let session = p.session.build()?;
    let ms = session.model(&p.model)?;
    println!("== pilot on {} (scale {}) ==", p.model, session.scale().0);
    let report = ms.train_teacher()?;
    println!("stages: {:?}", report.stages);
    let suites = p
        .suites
        .clone()
        .unwrap_or_else(|| vec![Suite::Math500, Suite::Aime, Suite::Lcb]);
    let mut ecfg = EvalCfg::default();
    ecfg.n_problems = p.n;
    ecfg.k_runs = p.k;

    let bf16 = session.method("bf16")?;
    let ptq = session.method("ptq")?;
    let qad = session.method("qad")?;
    let qat = session.method("qat")?;

    let bf16_acc = ms.evaluate(&*bf16, &report.params, &suites, &ecfg)?;
    println!("BF16: {bf16_acc:?}");
    let ptq_acc = ms.evaluate(&*ptq, &report.params, &suites, &ecfg)?;
    println!("PTQ:  {ptq_acc:?}");

    let mut cfg = RecoveryCfg::new(vec![SourceSpec::sft(&suites)], p.lr, p.steps);
    cfg.train.seed = session.seed();
    let qad_out = ms.recover_from(&*qad, &report.params, &cfg)?;
    println!("QAD loss curve: {:?}", qad_out.curve);
    let qad_acc = ms.evaluate(&*qad, &qad_out.params, &suites, &ecfg)?;
    println!("QAD:  {qad_acc:?}");
    let qat_out = ms.recover_from(&*qat, &report.params, &cfg)?;
    let qat_acc = ms.evaluate(&*qat, &qat_out.params, &suites, &ecfg)?;
    println!("QAT:  {qat_acc:?}");
    Ok(())
}

/// Serving throughput benchmark over both forward paths (continuous
/// batching when the backend supports stateful decode; `--decode full`
/// pins the legacy coalescing path for A/B comparison).
fn serve_bench(args: &Args) -> anyhow::Result<()> {
    let sb = ServeBenchArgs::parse(args)?;
    let session = sb.session.build()?;
    let ms = session.model(&sb.model)?;

    // Session seed varies the request mix (default 0 keeps the historic
    // serve_eval prompt stream).
    let mut rng = Rng::new(42 ^ session.seed());
    let suites = [Suite::Math500, Suite::Aime, Suite::Lcb, Suite::Gpqa];
    let prompts: Vec<Vec<i32>> = (0..sb.requests)
        .map(|_| {
            let s = tasks::generate(
                *rng.choice(&suites),
                &mut rng,
                ms.rt.model.vision_grid,
                ms.rt.model.vision_patch,
            );
            tasks::prompt_tokens(&s, ms.rt.model.seq_len)
        })
        .collect();

    // Per-request class assignment is seeded so the same seed + mix
    // always submits the identical interactive/batch sequence.
    let classes = class_mix_assignments(sb.requests, sb.class_mix, session.seed());

    if sb.fleet {
        return fleet_bench_loop(&sb, &ms, &prompts, &classes, session.seed());
    }

    for fwd_key in &sb.fwd_keys {
        let mut cfg = ServeCfg::default();
        cfg.max_batch_delay_ms = sb.max_delay_ms;
        cfg.sample.max_new = sb.max_new;
        cfg.decode = sb.decode;
        cfg.max_slots = sb.slots;
        cfg.telemetry = sb.telemetry.clone();
        cfg.page_size = sb.page_size;
        cfg.prefix_cache = sb.prefix_cache;
        cfg.slow_consumer = sb.slow_consumer;
        cfg.on_token = stall_sink(sb.consumer_delay_ms);
        let mut server = ms.server(fwd_key, &cfg)?;
        let t0 = Instant::now();
        for (p, class) in prompts.iter().zip(&classes) {
            server.submit_class(p.clone(), *class)?;
        }
        let responses = server.drain()?;
        let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
        anyhow::ensure!(
            responses.len() == sb.requests,
            "served {} of {} requests",
            responses.len(),
            sb.requests
        );
        println!("{} | wall {elapsed:.2}s", server.stats().summary());
    }
    Ok(())
}

/// Seeded interactive/batch assignment for `--class-mix`: the fraction is
/// a per-request coin, not a prefix split, so classes interleave the way
/// mixed traffic actually arrives.
fn class_mix_assignments(n: usize, frac_interactive: f64, seed: u64) -> Vec<RequestClass> {
    let mut rng = Rng::new(seed ^ 0xc1a5_5e50_a11e_5ed5);
    (0..n)
        .map(|_| {
            if rng.f64() < frac_interactive {
                RequestClass::Interactive
            } else {
                RequestClass::Batch
            }
        })
        .collect()
}

/// `--consumer-delay-ms`: a sink that sleeps per token, simulating a slow
/// stream consumer so the bounded-channel policy has something to absorb.
fn stall_sink(delay_ms: f64) -> Option<TokenSink> {
    if delay_ms <= 0.0 {
        return None;
    }
    let delay = std::time::Duration::from_secs_f64(delay_ms / 1000.0);
    Some(TokenSink::new(move |_ev| std::thread::sleep(delay)))
}

/// Fleet-mode serve-bench: a router over `--workers` worker engines.
/// With `--arrival-rate 0` every request is submitted up front (closed
/// loop); with a positive rate, arrivals follow a seeded exponential
/// inter-arrival process (open loop) so admission control actually sees
/// bursts. `Saturated` rejections are shed (counted in the stats), not
/// errors.
fn fleet_bench_loop(
    sb: &ServeBenchArgs,
    ms: &qadx::api::ModelSession,
    prompts: &[Vec<i32>],
    classes: &[RequestClass],
    seed: u64,
) -> anyhow::Result<()> {
    for fwd_key in &sb.fwd_keys {
        let mut cfg = FleetCfg::default();
        cfg.workers = sb.workers;
        cfg.sample.max_new = sb.max_new;
        cfg.max_slots = sb.slots;
        cfg.queue_cap = sb.queue_cap;
        cfg.deadline_ms = sb.deadline_ms;
        cfg.telemetry = sb.telemetry.clone();
        cfg.page_size = sb.page_size;
        cfg.prefix_cache = sb.prefix_cache;
        cfg.slow_consumer = sb.slow_consumer;
        cfg.on_token = stall_sink(sb.consumer_delay_ms);
        let mut fleet = ms.fleet(fwd_key, &cfg)?;
        let mut arrivals = Rng::new(seed ^ 0x0f1e_e7a9);
        let t0 = Instant::now();
        for (p, class) in prompts.iter().zip(classes) {
            if sb.arrival_rate > 0.0 {
                // Exponential inter-arrival: -ln(1-u)/lambda, in seconds.
                let u = arrivals.f64();
                let dt = -(1.0 - u).max(1e-12).ln() / sb.arrival_rate;
                std::thread::sleep(std::time::Duration::from_secs_f64(dt.min(1.0)));
                fleet.poll()?;
            }
            match fleet.submit_class(p.clone(), *class) {
                Ok(_) => {}
                Err(e) if e.downcast_ref::<Saturated>().is_some() => {}
                Err(e) => return Err(e),
            }
        }
        let responses = fleet.drain()?;
        let elapsed = t0.elapsed().as_secs_f64().max(1e-9);
        let stats = fleet.stats();
        anyhow::ensure!(
            responses.len() + stats.shed == sb.requests,
            "fleet resolved {} + shed {} of {} requests",
            responses.len(),
            stats.shed,
            sb.requests
        );
        println!("{} | wall {elapsed:.2}s", stats.summary());
        fleet.shutdown();
    }
    Ok(())
}
