//! Session façade: one `Session` owns the engine, runs directory,
//! pipeline scale, and method registry; a `ModelSession` binds one
//! manifest model and owns its teacher resolution (memory + disk cache)
//! and checkpoint paths. Every entry point — CLI, examples, benches, the
//! experiment harness — builds on this instead of hand-threading
//! `(Engine, ModelRuntime, teacher, runs_dir, Args)` tuples.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::coordinator::distill::RecoveryOutcome;
use crate::coordinator::{checkpoint, pipeline, PipelineScale, RecoveryCfg, TeacherReport};
use crate::data::tasks::Suite;
use crate::data::{SourceKind, SourceSpec};
use crate::eval::{run_suites, EvalCfg, SampleCfg};
use crate::quant::{KernelTier, PtqReport};
use crate::runtime::{
    BackendKind, Buffer, DecodeOpts, DecodeSession, Engine, Manifest, ModelRuntime,
};
use crate::util::json::Json;

use super::fleet::{FleetCfg, FleetHandle, FleetTarget};
use super::method::{MethodRef, MethodRegistry, RecoveryMethod};
use super::serve::{ServeCfg, ServeHandle, ServeWeights};

/// Where a model's recovered checkpoint lives — derived from the *parsed*
/// method (its registry name), never from a raw flag string.
pub fn recovered_path(runs_dir: &Path, model: &str, method_key: &str) -> PathBuf {
    runs_dir.join("recovered").join(format!("{model}-{method_key}.qckp"))
}

pub struct SessionBuilder {
    artifacts_dir: PathBuf,
    runs_dir: PathBuf,
    scale: PipelineScale,
    seed: u64,
    methods: MethodRegistry,
    backend: Option<BackendKind>,
    threads: Option<usize>,
    kernel: Option<KernelTier>,
}

impl SessionBuilder {
    pub fn new() -> SessionBuilder {
        SessionBuilder {
            artifacts_dir: PathBuf::from("artifacts"),
            runs_dir: PathBuf::from("runs"),
            scale: PipelineScale::default(),
            seed: 0,
            methods: MethodRegistry::builtin(),
            backend: None,
            threads: None,
            kernel: None,
        }
    }

    pub fn artifacts_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.artifacts_dir = dir.into();
        self
    }

    pub fn runs_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.runs_dir = dir.into();
        self
    }

    /// Teacher-pipeline step scale (1.0 = full sim pipeline).
    pub fn scale(mut self, scale: f64) -> Self {
        self.scale = PipelineScale(scale);
        self
    }

    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// Register an additional recovery method (see `api::RecoveryMethod`).
    pub fn register_method(mut self, method: Rc<dyn RecoveryMethod>) -> Self {
        self.methods.register(method);
        self
    }

    /// Choose the execution backend explicitly. Without this, the engine
    /// follows `QADX_BACKEND` and then the build default (PJRT when the
    /// `pjrt` feature is compiled in, reference otherwise).
    pub fn backend(mut self, kind: BackendKind) -> Self {
        self.backend = Some(kind);
        self
    }

    /// Worker threads for the reference backend's parallel compute core
    /// (`--threads` on the CLI). This sets the *process-global* worker
    /// count at `build()` (the compute core is a process-wide pool):
    /// the latest built session wins, and sessions built without
    /// `.threads(..)` keep whatever the knob was last set to (initially
    /// `QADX_THREADS`, then available parallelism). Results are
    /// identical at every thread count — purely a throughput knob; for a
    /// scoped override use `util::pool::with_threads`.
    pub fn threads(mut self, n: usize) -> Self {
        self.threads = Some(n);
        self
    }

    /// GEMM kernel tier for quantized formats on the reference backend
    /// (`--kernel` on the CLI): `Exact` recomputes fake-quantized f32
    /// weights (the bit-exact oracle), `Packed` computes directly on the
    /// packed 4-bit representation. Like `.threads(..)` this sets a
    /// *process-global* knob at `build()`; per-call overrides go through
    /// `DecodeOpts::kernel`. Packed logits stay within the published
    /// accuracy budget of exact and greedy decode picks the same tokens.
    pub fn kernel(mut self, tier: KernelTier) -> Self {
        self.kernel = Some(tier);
        self
    }

    pub fn build(self) -> Result<Session> {
        let kind = BackendKind::resolve(self.backend)?;
        let engine = Engine::with_backend(&self.artifacts_dir, kind)?;
        // Only touch the process-global knobs once construction can no
        // longer fail — a failed build must not change pool sizing or
        // kernel-tier selection.
        if let Some(n) = self.threads {
            crate::util::pool::set_threads(n);
        }
        if let Some(t) = self.kernel {
            crate::quant::packed::set_kernel(t);
        }
        Ok(Session {
            engine,
            runs_dir: self.runs_dir,
            scale: self.scale,
            seed: self.seed,
            methods: self.methods,
            teachers: RefCell::new(BTreeMap::new()),
        })
    }
}

impl Default for SessionBuilder {
    fn default() -> Self {
        SessionBuilder::new()
    }
}

/// Owns the PJRT engine, run artifacts, the recovery-method registry, and
/// an in-memory teacher cache shared by every `ModelSession`.
pub struct Session {
    engine: Engine,
    runs_dir: PathBuf,
    scale: PipelineScale,
    seed: u64,
    methods: MethodRegistry,
    /// BTreeMap keeps any future iteration over cached teachers in
    /// deterministic key order (today it is get/insert only).
    teachers: RefCell<BTreeMap<String, Rc<Vec<f32>>>>,
}

impl Session {
    pub fn builder() -> SessionBuilder {
        SessionBuilder::new()
    }

    pub fn engine(&self) -> &Engine {
        &self.engine
    }

    pub fn manifest(&self) -> &Manifest {
        &self.engine.manifest
    }

    pub fn runs_dir(&self) -> &Path {
        &self.runs_dir
    }

    pub fn report_dir(&self) -> PathBuf {
        self.runs_dir.join("report")
    }

    pub fn scale(&self) -> PipelineScale {
        self.scale
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    pub fn methods(&self) -> &MethodRegistry {
        &self.methods
    }

    /// Resolve a recovery method by registry name (built-ins plus any
    /// methods registered on the builder).
    pub fn method(&self, name: &str) -> Result<MethodRef> {
        self.methods.resolve(name)
    }

    /// Bind a manifest model.
    pub fn model(&self, name: &str) -> Result<ModelSession<'_>> {
        let rt = ModelRuntime::new(&self.engine, name)?;
        Ok(ModelSession { session: self, rt })
    }
}

/// One model bound to a session: runtime handles, teacher resolution,
/// recovery, evaluation, and serving.
pub struct ModelSession<'s> {
    session: &'s Session,
    pub rt: ModelRuntime<'s>,
}

impl<'s> ModelSession<'s> {
    pub fn session(&self) -> &'s Session {
        self.session
    }

    pub fn engine(&self) -> &'s Engine {
        &self.session.engine
    }

    pub fn name(&self) -> &str {
        &self.rt.model.name
    }

    /// The model's BF16 teacher: in-memory cache → disk cache
    /// (runs/teachers, rejecting stale sizes) → full post-training
    /// pipeline. Every caller in a session shares one copy.
    pub fn teacher(&self) -> Result<Rc<Vec<f32>>> {
        let name = self.rt.model.name.clone();
        if let Some(t) = self.session.teachers.borrow().get(&name) {
            return Ok(t.clone());
        }
        let params = pipeline::get_or_train_teacher(
            &self.session.engine,
            &name,
            &self.session.runs_dir,
            self.session.scale,
        )?;
        let rc = Rc::new(params);
        self.session.teachers.borrow_mut().insert(name, rc.clone());
        Ok(rc)
    }

    /// Run the model's full post-training pipeline from scratch and return
    /// the stage report (pilot / debugging). Updates the in-memory teacher
    /// cache but deliberately not the disk cache — scaled-down pilot
    /// teachers must not shadow full-scale ones.
    pub fn train_teacher(&self) -> Result<TeacherReport> {
        let report =
            pipeline::train_teacher(&self.session.engine, &self.rt.model.name, self.session.scale)?;
        self.session
            .teachers
            .borrow_mut()
            .insert(self.rt.model.name.clone(), Rc::new(report.params.clone()));
        Ok(report)
    }

    /// Where `method`'s recovered checkpoint for this model lives.
    pub fn checkpoint_path(&self, method: &dyn RecoveryMethod) -> PathBuf {
        recovered_path(&self.session.runs_dir, &self.rt.model.name, method.name())
    }

    /// Run a recovery method against the (cached) teacher.
    pub fn recover(
        &self,
        method: &dyn RecoveryMethod,
        cfg: &RecoveryCfg,
    ) -> Result<RecoveryOutcome> {
        let teacher = self.teacher()?;
        method.recover(self, &teacher, cfg)
    }

    /// Run a recovery method against explicit teacher weights (cross-model
    /// distillation, sweeps over intermediate teachers, ...).
    pub fn recover_from(
        &self,
        method: &dyn RecoveryMethod,
        teacher: &[f32],
        cfg: &RecoveryCfg,
    ) -> Result<RecoveryOutcome> {
        method.recover(self, teacher, cfg)
    }

    /// Persist a recovery outcome at the method-derived checkpoint path.
    pub fn save_recovered(
        &self,
        method: &dyn RecoveryMethod,
        outcome: &RecoveryOutcome,
    ) -> Result<PathBuf> {
        let path = self.checkpoint_path(method);
        checkpoint::save(
            &path,
            &outcome.params,
            &Json::obj(vec![
                ("model", Json::Str(self.rt.model.name.clone())),
                ("method", Json::Str(method.name().to_string())),
            ]),
        )?;
        Ok(path)
    }

    /// Load a method's recovered checkpoint.
    pub fn load_recovered(&self, method: &dyn RecoveryMethod) -> Result<Vec<f32>> {
        checkpoint::load(&self.checkpoint_path(method))
    }

    /// The weights to evaluate/serve for a method: training-free methods
    /// (BF16/PTQ) use the teacher; trained methods load their checkpoint.
    pub fn method_params(&self, method: &dyn RecoveryMethod) -> Result<Vec<f32>> {
        if method.step_key().is_none() {
            Ok(self.teacher()?.as_ref().clone())
        } else {
            self.load_recovered(method)
        }
    }

    /// Evaluate weights on benchmark suites through the method's fwd path.
    pub fn evaluate(
        &self,
        method: &dyn RecoveryMethod,
        params: &[f32],
        suites: &[Suite],
        cfg: &EvalCfg,
    ) -> Result<std::collections::BTreeMap<String, f64>> {
        run_suites(&self.session.engine, &self.rt, method.fwd_key(), params, suites, cfg)
    }

    /// PTQ export report for the (cached) teacher weights.
    pub fn ptq_report(&self) -> Result<PtqReport> {
        let teacher = self.teacher()?;
        Ok(crate::coordinator::ptq_report(&self.rt, &teacher))
    }

    /// Open the backend's stateful-decode capability for one fwd artifact
    /// of this model: prefill-once-then-step over cached per-layer state
    /// (`Ok(None)` when the backend only supports stateless decode). The
    /// sampler and the serving scheduler use this internally; it is
    /// exposed for callers building their own decode loops.
    pub fn decode_session(
        &self,
        fwd_key: &str,
        weights: &Buffer,
        rows: usize,
    ) -> Result<Option<Box<dyn DecodeSession>>> {
        self.session.engine.open_decode(&self.rt.model, fwd_key, weights, rows)
    }

    /// [`ModelSession::decode_session`] with an explicit state layout:
    /// paged K/V, shared-prefix cache, page budget (see [`DecodeOpts`]).
    pub fn decode_session_opts(
        &self,
        fwd_key: &str,
        weights: &Buffer,
        rows: usize,
        opts: &DecodeOpts,
    ) -> Result<Option<Box<dyn DecodeSession>>> {
        self.session.engine.open_decode_opts(&self.rt.model, fwd_key, weights, rows, opts)
    }

    /// Start a server over one fwd artifact — continuous batching when
    /// the backend supports stateful decode (see `ServeCfg::decode`),
    /// batch coalescing otherwise — resolving the weight source through
    /// this session (teacher cache, recovered checkpoints, random init).
    /// Overload behavior (priority lanes, per-class admission, bounded
    /// token streaming) is configured on [`ServeCfg`]: `starvation_bound`,
    /// `stream_buf`, `slow_consumer`.
    pub fn server(&self, fwd_key: &str, cfg: &ServeCfg) -> Result<ServeHandle<'s>> {
        let weights = match &cfg.weights {
            ServeWeights::Random { seed } => crate::coordinator::init_params(&self.rt.model, *seed),
            ServeWeights::Teacher => self.teacher()?.as_ref().clone(),
            ServeWeights::Method(name) => {
                let method = self.session.method(name)?;
                self.method_params(&*method)?
            }
            ServeWeights::Params(p) => p.clone(),
        };
        ServeHandle::new(&self.rt, fwd_key, &weights, cfg)
    }

    /// Start a fault-tolerant multi-worker fleet over one fwd artifact:
    /// N worker engines (one thread each, each running the continuous
    /// scheduler) behind a router with admission control and budgeted
    /// retry. Weights resolve through this session exactly like
    /// [`ModelSession::server`]; each worker rebuilds its own engine
    /// from the manifest root (engines cannot cross threads). Requires
    /// a stateful-decode backend. The router shares the serve layer's
    /// overload machinery: per-class lanes with a starvation bound,
    /// batch eviction under queue-cap pressure, and bounded per-request
    /// token channels (see [`FleetCfg`]).
    pub fn fleet(&self, fwd_key: &str, cfg: &FleetCfg) -> Result<FleetHandle> {
        if self.rt.model.vision {
            bail!("fleet serving supports text models (got VLM {:?})", self.rt.model.name);
        }
        let weights = match &cfg.weights {
            ServeWeights::Random { seed } => crate::coordinator::init_params(&self.rt.model, *seed),
            ServeWeights::Teacher => self.teacher()?.as_ref().clone(),
            ServeWeights::Method(name) => {
                let method = self.session.method(name)?;
                self.method_params(&*method)?
            }
            ServeWeights::Params(p) => p.clone(),
        };
        let engine = self.engine();
        let target = FleetTarget {
            artifacts_root: engine.manifest.root.clone(),
            backend: engine.backend_kind(),
            model: self.rt.model.name.clone(),
            seq_len: self.rt.model.seq_len,
            batch: self.rt.model.batch,
            fwd_key: fwd_key.to_string(),
        };
        FleetHandle::new(target, weights, cfg)
    }

    /// The suites the model's post-training covered (its natural
    /// training/eval distribution).
    pub fn train_suites(&self) -> &'static [Suite] {
        pipeline::train_suites(&self.rt.model.name)
    }

    /// Eval sampling config per model (paper §3.4: nano3 uses T=1/top-p 1).
    pub fn sample_cfg(&self) -> SampleCfg {
        default_sample_cfg(&self.rt.model.name)
    }

    /// The default recovery data mixture per model (paper §3.2).
    pub fn default_recovery_data(&self) -> Vec<SourceSpec> {
        default_recovery_data(&self.rt.model.name)
    }

    /// Default per-model recovery LR (paper §3.4 scaled to the sim).
    pub fn default_recovery_lr(&self) -> f64 {
        default_recovery_lr(&self.rt.model.name)
    }

    /// A ready-to-run recovery config with the per-model defaults; the
    /// session seed drives training-data order.
    pub fn default_recovery_cfg(&self, steps: usize) -> RecoveryCfg {
        let mut cfg = default_recovery_cfg(&self.rt.model.name, steps);
        cfg.train.seed = self.session.seed;
        cfg
    }
}

/// Eval sampling config per model (paper §3.4: nano3 uses T=1.0/top-p 1).
pub fn default_sample_cfg(model: &str) -> SampleCfg {
    if model == "nano3-sim" {
        SampleCfg::nano3()
    } else {
        SampleCfg::default()
    }
}

/// The default recovery data mixture per model — mirrors paper §3.2:
/// SFT-heavy models use their (clean) SFT mixture; ace uses only its
/// cold-start SFT data; nano3 uses cold-start SFT + RL generations.
pub fn default_recovery_data(model: &str) -> Vec<SourceSpec> {
    let suites = pipeline::train_suites(model);
    match model {
        "ace-sim" => vec![SourceSpec::sft_quality(suites, 0.7)],
        "nano3-sim" => vec![
            SourceSpec::sft_quality(suites, 0.7).with_weight(0.5),
            SourceSpec {
                kind: SourceKind::RlGenerated,
                suites: pipeline::rl_suites(model).to_vec(),
                weight: 0.5,
            },
        ],
        _ => vec![SourceSpec::sft(suites)],
    }
}

/// Default per-model recovery LR (paper §3.4 scaled to the sim:
/// RL-heavy models want larger QAD LRs).
pub fn default_recovery_lr(model: &str) -> f64 {
    if pipeline::is_rl_heavy(model) {
        3e-4
    } else {
        1e-4
    }
}

/// A ready-to-run recovery config with the per-model defaults.
pub fn default_recovery_cfg(model: &str, steps: usize) -> RecoveryCfg {
    let mut cfg = RecoveryCfg::new(default_recovery_data(model), default_recovery_lr(model), steps);
    cfg.teacher_sample = default_sample_cfg(model);
    cfg
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recovered_path_uses_method_key() {
        let p = recovered_path(Path::new("runs"), "ace-sim", "qad");
        assert_eq!(p, Path::new("runs").join("recovered").join("ace-sim-qad.qckp"));
    }
}
