//! `api::fleet` — fault-tolerant multi-worker serving.
//!
//! N worker engines (one OS thread each, own backend instance + own
//! continuous-batching slot scheduler over the stateful prefill/step
//! decode path) behind a front [`FleetHandle`] router:
//!
//! ```text
//!   submit ──> Router ──[admission: queue cap / deadline estimate]──┐
//!                │                                                  │
//!                │  bounded queue        Saturated{retry_after_ms} <┘
//!                ▼
//!        dispatch (least-loaded live worker)
//!        ┌──────────┬──────────┬──────────┐
//!        ▼          ▼          ▼          ▼
//!     worker 0   worker 1   ...       worker N-1     (thread each)
//!     [slots]    [slots]              [slots]
//!        └──────────┴──── events ─────┴───> Done / Failed / Died
//!                                             │
//!                      retry (budgeted, decorrelated jitter) / requeue
//! ```
//!
//! **Failure semantics.** A failed prefill/step (real or injected) fails
//! only that request's current attempt: the router requeues it under a
//! budgeted [`RetryPolicy`] and a healthy worker re-prefills it from
//! scratch. A dead worker ([`FaultPlan`] kill, or a closed channel) has
//! every request assigned to it requeued the same way. Because each
//! request samples from its **own** RNG stream — seeded from
//! `(sample.seed, request id)` only, never from slot index, worker
//! index, or attempt number — and decode rows are independent by the
//! decode-session contract, a retried response is **bit-identical** to
//! the same request in a no-fault run. That is the chaos-test oracle.
//!
//! **Determinism.** Every fault decision is a pure function of the plan
//! seed and stream-local counters (request id, attempt, step index,
//! worker round) — no wall clock, no ambient RNG — so a chaos run
//! replays exactly. Wall time is only *measured* (latency/TTFT stats)
//! and only consulted for deadline expiry, which is itself exercised
//! deterministically in tests via a zero deadline.
//!
//! **Overload behavior.** Requests carry a
//! [`RequestClass`](super::serve::RequestClass); the router keeps one
//! queue lane per class and dispatches interactive first, bounded by the
//! `starvation_bound` bypass (shared policy with `api::serve` —
//! [`take_batch_lane`](super::serve::take_batch_lane)). Admission is
//! per-class: queue-cap pressure lets an interactive arrival evict the
//! youngest queued batch request (degraded, not lost) before shedding,
//! and [`Saturated::retry_after_ms`] derives from the rejected class's
//! own service EWMA and backlog, so interactive and batch callers get
//! honest, distinct hints. With `stream_buf > 0` workers push tokens
//! into bounded per-request channels (`util::stream`) instead of
//! unbounded router events: a slow or stalled consumer costs drops /
//! stalls / a severed stream per the [`SlowConsumer`] policy, never a
//! stalled worker step round.

use std::collections::{BTreeMap, VecDeque};
use std::path::PathBuf;
use std::sync::mpsc::{channel, Receiver, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::data::tokenizer as tok;
use crate::eval::{sample_token_with, SampleCfg, SampleScratch};
use crate::runtime::{BackendKind, DecodeOpts, DecodeSession, Engine, ModelRuntime};
use crate::util::json::Json;
use crate::util::retry::{RetryPolicy, RetryState};
use crate::util::rng::Rng;
use crate::util::stream::{bounded, BoundedRx, BoundedTx, SlowConsumer};
use crate::util::StatsWindow;

use super::serve::{
    request_rng, take_batch_lane, ClassPair, RequestClass, Saturated, ServeWeights, TokenEvent,
    TokenSink, SEED_MIX,
};
use super::telemetry::JsonlAppender;

/// Domain tags for derived fault-decision RNG streams (the request
/// sampling stream itself lives in `serve::request_rng`).
const TAG_PREFILL: u64 = 0x9216_d5d9_8979_fb1b;
const TAG_STEP: u64 = 0xd131_0ba6_98df_b5ac;

/// Per-class [`Saturated::retry_after_ms`] hint: estimated wait for
/// `depth_ahead` queued requests at the class's own service EWMA
/// (falling back to the global estimate while the class is cold) over
/// `capacity` concurrent slots — floored at one service time and at
/// 1 ms so a rejected caller always backs off. Pure so both classes can
/// be unit-tested against the same queue state.
pub fn fleet_retry_hint(
    depth_ahead: usize,
    class_est_ms: f64,
    fallback_est_ms: f64,
    capacity: usize,
) -> f64 {
    let per_req = if class_est_ms > 0.0 { class_est_ms } else { fallback_est_ms };
    let wait = depth_ahead as f64 * per_req / capacity.max(1) as f64;
    wait.max(per_req).max(1.0)
}

/// Deterministic fault-injection plan. All decisions replay exactly:
/// seeded hashes of stream-local counters, never wall-clock or shared
/// RNG state (which would make them scheduling-order dependent).
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    /// Seed for every probabilistic decision below.
    pub seed: u64,
    /// `(worker, round)`: worker dies before executing its local decode
    /// round `round` (rounds count executed step-rounds, starting at 0).
    pub kills: Vec<(usize, usize)>,
    /// Probability an attempt's prefill fails (keyed on id + attempt, so
    /// a retry is a fresh draw, not a doomed replay).
    pub prefill_fail_p: f64,
    /// Probability any single decode step fails (keyed on id + attempt +
    /// step index).
    pub step_fail_p: f64,
    /// Injected latency per executed decode round, in ms. Pure timing —
    /// never consulted by any decision — so it perturbs interleavings
    /// without perturbing results.
    pub step_delay_ms: f64,
}

impl FaultPlan {
    /// Does `worker` die before executing its decode round `round`?
    pub fn kills_at(&self, worker: usize, round: usize) -> bool {
        self.kills.iter().any(|&(w, r)| w == worker && r == round)
    }

    /// Seeded coin for one (kind, id, attempt, step) event.
    fn coin(&self, kind: u64, id: u64, attempt: u32, step: u64, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        let mut rng = Rng::new(
            self.seed
                ^ kind
                ^ id.wrapping_mul(SEED_MIX)
                ^ (attempt as u64).wrapping_mul(0xff51_afd7_ed55_8ccd)
                ^ step.wrapping_mul(0xc4ce_b9fe_1a85_ec53),
        );
        rng.f64() < p
    }

    pub fn fail_prefill(&self, id: u64, attempt: u32) -> bool {
        self.coin(TAG_PREFILL, id, attempt, 0, self.prefill_fail_p)
    }

    pub fn fail_step(&self, id: u64, attempt: u32, step: usize) -> bool {
        self.coin(TAG_STEP, id, attempt, step as u64, self.step_fail_p)
    }

    /// Whether this plan injects anything at all.
    pub fn is_noop(&self) -> bool {
        self.kills.is_empty()
            && self.prefill_fail_p <= 0.0
            && self.step_fail_p <= 0.0
            && self.step_delay_ms <= 0.0
    }
}

/// Fleet configuration (see [`FleetHandle`]).
#[derive(Clone, Debug)]
pub struct FleetCfg {
    /// Worker engines (threads). Must be >= 1.
    pub workers: usize,
    pub sample: SampleCfg,
    pub weights: ServeWeights,
    /// Per-worker in-flight slot width (0 = the model's batch size).
    pub max_slots: usize,
    /// Router queue bound: `submit` past this many *router-queued*
    /// requests returns [`Saturated`]. 0 = unbounded.
    pub queue_cap: usize,
    /// Per-request deadline. Admission rejects a request whose estimated
    /// queue wait already blows this; a request still *router-queued*
    /// past it degrades (error set) instead of waiting forever. Requests
    /// already dispatched to a worker are never expired — their worker
    /// either finishes them or dies and they retry.
    pub deadline_ms: Option<f64>,
    /// Initial per-request service-time estimate feeding the admission
    /// estimator (EWMA-updated from observed completions).
    pub est_service_ms: f64,
    /// Retry budget + backoff shape for requeued work.
    pub retry: RetryPolicy,
    /// Seed for the backoff jitter stream.
    pub retry_seed: u64,
    /// Deterministic fault injection (chaos tests; `default()` = none).
    pub fault: FaultPlan,
    /// JSONL event log path; falls back to `QADX_TELEMETRY_JSONL`.
    pub telemetry: Option<PathBuf>,
    /// Per-worker decode-state page size in positions (0 = dense rows).
    /// See [`super::ServeCfg::page_size`]; paged is the default.
    pub page_size: usize,
    /// Per-worker shared-prefix cache capacity in entries (0 = off;
    /// requires `page_size > 0`). Each worker keeps its own cache.
    pub prefix_cache: usize,
    /// Per-worker page budget (0 = unbounded).
    pub max_pages: usize,
    /// Relay per-token `token` events into the router's telemetry JSONL.
    pub stream: bool,
    /// Router-side per-token callback (tokens relayed from workers; a
    /// retried attempt restarts its index at 0 with a higher `attempt`).
    pub on_token: Option<TokenSink>,
    /// Starvation bound for the batch lane: a queued batch request
    /// bypasses after this many consecutive interactive dispatches.
    /// 0 disables lanes entirely (strict submission order, no eviction).
    pub starvation_bound: usize,
    /// Per-request bounded token-channel capacity for streaming
    /// (`stream` / `on_token`). 0 falls back to the legacy unbounded
    /// worker-event relay.
    pub stream_buf: usize,
    /// What a worker does when a request's token channel is full.
    pub slow_consumer: SlowConsumer,
}

impl Default for FleetCfg {
    fn default() -> FleetCfg {
        FleetCfg {
            workers: 2,
            sample: SampleCfg::default(),
            weights: ServeWeights::Random { seed: 3 },
            max_slots: 0,
            queue_cap: 0,
            deadline_ms: None,
            est_service_ms: 0.0,
            retry: RetryPolicy::default(),
            retry_seed: 0x4f1e_7e7a,
            fault: FaultPlan::default(),
            telemetry: None,
            page_size: 32,
            prefix_cache: 0,
            max_pages: 0,
            stream: false,
            on_token: None,
            starvation_bound: 4,
            stream_buf: 64,
            slow_consumer: SlowConsumer::default(),
        }
    }
}

/// What the fleet serves — enough to rebuild an engine inside each
/// worker thread (engines hold `Rc` internals and cannot cross threads,
/// so workers construct their own from the artifacts root).
#[derive(Clone, Debug)]
pub struct FleetTarget {
    pub artifacts_root: PathBuf,
    pub backend: BackendKind,
    pub model: String,
    pub seq_len: usize,
    pub batch: usize,
    pub fwd_key: String,
}

/// One completed (or degraded) fleet request.
#[derive(Clone, Debug)]
pub struct FleetResponse {
    pub id: u64,
    /// Full token row (prompt + completion, PAD-tailed); prompt-only when
    /// the request degraded before generating.
    pub row: Vec<i32>,
    pub gen_tokens: usize,
    pub latency_ms: f64,
    pub ttft_ms: f64,
    /// Which worker completed it (None when it degraded in the router).
    pub worker: Option<usize>,
    /// Attempt that produced this response (0 = first try).
    pub attempt: u32,
    /// Set when the request degraded: retry budget exhausted, deadline
    /// expired while queued, or no live worker remained.
    pub error: Option<String>,
}

/// Per-worker slice of [`FleetStats`].
#[derive(Clone, Debug, Default)]
pub struct WorkerStats {
    pub requests: usize,
    pub gen_tokens: usize,
    /// Failed attempts reported by this worker (each either retried or
    /// degraded by the router).
    pub failures: usize,
    pub dead: bool,
    /// Decode rounds executed (reported at clean shutdown; 0 for a
    /// worker that died).
    pub rounds: usize,
    /// Mean per-round slot occupancy (reported at clean shutdown).
    pub occupancy: f64,
    /// Decode-state pages still live at clean shutdown (paged backends;
    /// nonzero after a full drain means a leak).
    pub live_pages: usize,
}

/// Aggregate fleet counters: global windows + per-worker slices.
#[derive(Clone, Debug, Default)]
pub struct FleetStats {
    pub fwd_key: String,
    pub workers: usize,
    pub submitted: usize,
    pub completed: usize,
    /// Requests that finished with `error` set.
    pub degraded: usize,
    /// Submissions rejected with [`Saturated`].
    pub shed: usize,
    /// Attempts requeued under the retry budget.
    pub retries: usize,
    pub worker_deaths: usize,
    /// Requests expired by the deadline while still router-queued.
    pub expired: usize,
    /// Queued batch requests evicted (degraded) to admit interactive
    /// traffic under queue-cap pressure.
    pub evicted: usize,
    /// Batch dispatches that used the starvation-bound bypass while
    /// interactive work was still queued.
    pub lane_bypasses: usize,
    /// Tokens dropped by `SlowConsumer::DropOldest` channels.
    pub tokens_dropped: u64,
    /// Worker pushes that found a request's token channel full.
    pub consumer_stalls: u64,
    /// Streams severed (`Disconnect` policy or a blocked push past its
    /// deadline).
    pub streams_disconnected: u64,
    pub latencies_ms: StatsWindow,
    pub ttft_ms: StatsWindow,
    /// Router-queue wait per request (submit -> dispatch).
    pub queue_wait_ms: StatsWindow,
    pub per_worker: Vec<WorkerStats>,
    /// Per-class SLO slices (see [`ClassStats`](super::serve::ClassStats)).
    pub per_class: ClassPair,
}

impl FleetStats {
    pub fn latency_p(&self, p: f64) -> f64 {
        self.latencies_ms.percentile(p)
    }

    /// Fraction of submissions shed by admission control.
    pub fn shed_rate(&self) -> f64 {
        let offered = self.submitted + self.shed;
        if offered == 0 {
            0.0
        } else {
            self.shed as f64 / offered as f64
        }
    }

    /// Rounds-weighted mean slot occupancy across workers that reported.
    pub fn occupancy(&self) -> f64 {
        let rounds: usize = self.per_worker.iter().map(|w| w.rounds).sum();
        if rounds == 0 {
            return 0.0;
        }
        self.per_worker.iter().map(|w| w.occupancy * w.rounds as f64).sum::<f64>()
            / rounds as f64
    }

    /// One-line report (CLI / bench output).
    pub fn summary(&self) -> String {
        let mut lanes = self.per_class.brief();
        if self.lane_bypasses > 0 {
            lanes.push_str(&format!(" | bypass {}", self.lane_bypasses));
        }
        let stream_clause = if self.tokens_dropped > 0
            || self.consumer_stalls > 0
            || self.streams_disconnected > 0
        {
            format!(
                " | stream drop {} stall {} disc {}",
                self.tokens_dropped, self.consumer_stalls, self.streams_disconnected
            )
        } else {
            String::new()
        };
        format!(
            "fleet {:<10} {}w | {}/{} ok ({} degraded, {} shed, {} expired) | \
             {} retries {} deaths | lat p50 {:.0}ms p95 {:.0}ms p99 {:.0}ms | \
             ttft p50 {:.0}ms | occ {:.2} | shed rate {:.2}{lanes}{stream_clause}",
            self.fwd_key,
            self.workers,
            self.completed - self.degraded,
            self.submitted,
            self.degraded,
            self.shed,
            self.expired,
            self.retries,
            self.worker_deaths,
            self.latency_p(50.0),
            self.latency_p(95.0),
            self.latency_p(99.0),
            self.ttft_ms.percentile(50.0),
            self.occupancy(),
            self.shed_rate(),
        )
    }
}

/// Router -> worker messages.
enum ToWorker {
    Job(Job),
    Stop,
}

struct Job {
    id: u64,
    prompt: Vec<i32>,
    attempt: u32,
    submitted: Instant,
    /// Bounded per-request token channel (producer half). `None` when the
    /// fleet is not streaming or runs the legacy event relay
    /// (`stream_buf == 0`). Cloned from the router's map on every
    /// attempt, so a retry streams into the same channel.
    stream: Option<BoundedTx<TokenEvent>>,
}

/// Worker -> router events.
enum WorkerEvent {
    Ready {
        worker: usize,
    },
    InitFailed {
        worker: usize,
        error: String,
    },
    Done {
        worker: usize,
        id: u64,
        attempt: u32,
        row: Vec<i32>,
        gen_tokens: usize,
        ttft_ms: f64,
        execute_ms: f64,
    },
    /// One generated token, streamed as it lands (legacy relay — only
    /// sent when streaming is on and `stream_buf == 0`; with bounded
    /// channels tokens bypass the event channel entirely).
    Token {
        worker: usize,
        id: u64,
        attempt: u32,
        token: i32,
        index: usize,
    },
    /// One attempt failed (real or injected prefill/step fault); the
    /// router decides whether to retry or degrade.
    Failed {
        worker: usize,
        id: u64,
        error: String,
    },
    /// The worker is gone (fault-plan kill). Everything assigned to it
    /// must be requeued by the router.
    Died {
        worker: usize,
    },
    /// Clean shutdown report (occupancy/rounds/live-pages for
    /// `FleetStats`).
    Stopped {
        worker: usize,
        rounds: usize,
        occupancy: f64,
        live_pages: usize,
    },
}

/// Router-side request record — the single source of truth for requeue
/// (workers never need to echo prompts back).
struct ReqState {
    prompt: Vec<i32>,
    class: RequestClass,
    submitted: Instant,
    attempt: u32,
    retry: RetryState,
    /// Which worker currently holds this request (None = router-queued).
    assigned: Option<usize>,
}

/// The fleet front end: admission control, dispatch, retry/requeue, and
/// aggregation. Single-threaded itself (like [`super::ServeHandle`], the
/// router advances when the caller calls `submit` / `poll` / `drain`);
/// the workers run free on their own threads.
pub struct FleetHandle {
    seq_len: usize,
    queue_cap: usize,
    deadline_ms: Option<f64>,
    est_service_ms: f64,
    slots_per_worker: usize,
    retry_policy: RetryPolicy,
    retry_rng: Rng,
    senders: Vec<Option<Sender<ToWorker>>>,
    events: Receiver<WorkerEvent>,
    joins: Vec<Option<JoinHandle<()>>>,
    outstanding: Vec<usize>,
    /// Ids waiting in the router for a worker slot, one lane per
    /// [`RequestClass`] (dispatch order within a lane; `take_batch_lane`
    /// arbitrates between them).
    lane_int: VecDeque<u64>,
    lane_bat: VecDeque<u64>,
    /// Interactive dispatches since the batch lane last got a turn.
    since_bypass: usize,
    /// Batch-lane starvation bound (0 = lanes off, strict id order).
    starvation_bound: usize,
    /// All unresolved requests (router-queued and worker-assigned).
    /// BTreeMap: requeue-on-death iterates it, and iteration order must
    /// be deterministic.
    requests: BTreeMap<u64, ReqState>,
    next_id: u64,
    completed: Vec<FleetResponse>,
    stats: FleetStats,
    telemetry: Option<JsonlAppender>,
    /// Append relayed `token` events to the telemetry JSONL.
    stream: bool,
    on_token: Option<TokenSink>,
    /// Bounded per-request token channels (both halves: the Tx is
    /// re-cloned into every attempt's Job, the Rx is relayed here).
    /// BTreeMap for deterministic relay order. Empty when not streaming
    /// or when `stream_buf == 0` (legacy event relay).
    streams: BTreeMap<u64, (BoundedTx<TokenEvent>, BoundedRx<TokenEvent>)>,
    /// Channel capacity; 0 disables the bounded-channel path.
    stream_buf: usize,
    slow_consumer: SlowConsumer,
}

impl FleetHandle {
    /// Spawn the worker fleet and wait for every worker to come up (or
    /// fail construction synchronously). Requires a stateful-decode
    /// backend: the fleet reuses the continuous-batching path per
    /// worker, and retry bit-identity is defined in terms of it.
    pub fn new(target: FleetTarget, weights: Vec<f32>, cfg: &FleetCfg) -> Result<FleetHandle> {
        if cfg.workers == 0 {
            bail!("fleet needs at least one worker");
        }
        if cfg.page_size == 0 && (cfg.prefix_cache > 0 || cfg.max_pages > 0) {
            bail!(
                "prefix_cache ({}) and max_pages ({}) require paged decode state (page_size > 0)",
                cfg.prefix_cache,
                cfg.max_pages
            );
        }
        let slots = (if cfg.max_slots == 0 { target.batch } else { cfg.max_slots }).max(1);
        let weights = Arc::new(weights);
        let decode_opts = DecodeOpts {
            page_size: cfg.page_size,
            prefix_cache: cfg.prefix_cache,
            max_pages: cfg.max_pages,
            kernel: None,
        };
        let stream_tokens = cfg.stream || cfg.on_token.is_some();
        // With bounded channels (stream_buf > 0) tokens travel through
        // per-request channels; the legacy unbounded Token event relay
        // stays only as the stream_buf == 0 fallback.
        let legacy_tokens = stream_tokens && cfg.stream_buf == 0;
        let (event_tx, event_rx) = channel::<WorkerEvent>();
        let mut senders = Vec::with_capacity(cfg.workers);
        let mut joins = Vec::with_capacity(cfg.workers);
        for worker in 0..cfg.workers {
            let (tx, rx) = channel::<ToWorker>();
            let wcfg = WorkerCfg {
                worker,
                target: target.clone(),
                weights: weights.clone(),
                sample: cfg.sample,
                slots,
                fault: cfg.fault.clone(),
                opts: decode_opts,
                stream: legacy_tokens,
            };
            let ev = event_tx.clone();
            let join = std::thread::Builder::new()
                .name(format!("qadx-fleet-{worker}"))
                .spawn(move || worker_main(wcfg, rx, ev))
                .context("spawning fleet worker thread")?;
            senders.push(Some(tx));
            joins.push(Some(join));
        }
        drop(event_tx);

        // Synchronous startup barrier: every worker reports Ready or
        // InitFailed before the constructor returns, so a missing
        // stateful-decode capability (e.g. PJRT) fails loudly here.
        let mut ready = 0usize;
        let mut init_err: Option<String> = None;
        while ready < cfg.workers && init_err.is_none() {
            match event_rx.recv_timeout(Duration::from_secs(60)) {
                Ok(WorkerEvent::Ready { .. }) => ready += 1,
                Ok(WorkerEvent::InitFailed { worker, error }) => {
                    init_err = Some(format!("fleet worker {worker} failed to start: {error}"));
                }
                Ok(_) => {}
                Err(_) => {
                    init_err = Some("fleet worker failed to start (timeout)".to_string());
                }
            }
        }
        if let Some(err) = init_err {
            for tx in senders.iter().flatten() {
                let _ = tx.send(ToWorker::Stop);
            }
            for join in joins.iter_mut().filter_map(|j| j.take()) {
                let _ = join.join();
            }
            bail!("{err}");
        }

        let mut telemetry = match cfg.telemetry.as_ref() {
            Some(p) => Some(JsonlAppender::open(p)?),
            None => JsonlAppender::from_env("QADX_TELEMETRY_JSONL"),
        };
        if let Some(tel) = telemetry.as_mut() {
            let _ = tel.append(&Json::obj(vec![
                ("event", Json::Str("fleet".into())),
                ("model", Json::Str(target.model.clone())),
                ("fwd", Json::Str(target.fwd_key.clone())),
                ("workers", Json::Num(cfg.workers as f64)),
                ("slots", Json::Num(slots as f64)),
                ("chaos", Json::Bool(!cfg.fault.is_noop())),
            ]));
        }

        Ok(FleetHandle {
            seq_len: target.seq_len,
            queue_cap: cfg.queue_cap,
            deadline_ms: cfg.deadline_ms,
            est_service_ms: cfg.est_service_ms.max(0.0),
            slots_per_worker: slots,
            retry_policy: cfg.retry,
            retry_rng: Rng::new(cfg.retry_seed),
            senders,
            events: event_rx,
            joins,
            outstanding: vec![0; cfg.workers],
            lane_int: VecDeque::new(),
            lane_bat: VecDeque::new(),
            since_bypass: 0,
            starvation_bound: cfg.starvation_bound,
            requests: BTreeMap::new(),
            next_id: 0,
            completed: Vec::new(),
            stats: FleetStats {
                fwd_key: target.fwd_key.clone(),
                workers: cfg.workers,
                per_worker: vec![WorkerStats::default(); cfg.workers],
                ..Default::default()
            },
            telemetry,
            stream: cfg.stream,
            on_token: cfg.on_token.clone(),
            streams: BTreeMap::new(),
            stream_buf: if stream_tokens { cfg.stream_buf } else { 0 },
            slow_consumer: cfg.slow_consumer,
        })
    }

    /// Workers still accepting work.
    pub fn live_workers(&self) -> usize {
        self.senders.iter().filter(|s| s.is_some()).count()
    }

    /// Requests waiting in the router (excludes worker-assigned ones).
    pub fn queued(&self) -> usize {
        self.lane_int.len() + self.lane_bat.len()
    }

    /// Router-queue depth per lane: `(interactive, batch)`.
    pub fn lane_depths(&self) -> (usize, usize) {
        (self.lane_int.len(), self.lane_bat.len())
    }

    /// Unresolved requests (router-queued + worker-assigned).
    pub fn pending(&self) -> usize {
        self.requests.len()
    }

    pub fn stats(&self) -> &FleetStats {
        &self.stats
    }

    /// Backlog ahead of a new request of `class`: interactive waits only
    /// on the interactive lane (batch yields, bypasses aside); batch
    /// waits on everything queued.
    fn class_depth(&self, class: RequestClass) -> usize {
        match class {
            RequestClass::Interactive => self.lane_int.len(),
            RequestClass::Batch => self.lane_int.len() + self.lane_bat.len(),
        }
    }

    /// Per-class service estimate: the class's own EWMA once it has
    /// observed completions, else the global estimate.
    fn class_est_ms(&self, class: RequestClass) -> f64 {
        let e = self.stats.per_class.get(class).exec_ewma_ms;
        if e > 0.0 {
            e
        } else {
            self.est_service_ms
        }
    }

    /// Estimated wait for a newly queued request of `class`: class
    /// backlog x per-class service estimate over live capacity.
    fn est_wait_ms(&self, class: RequestClass, depth: usize) -> f64 {
        let capacity = (self.live_workers() * self.slots_per_worker).max(1);
        depth as f64 * self.class_est_ms(class) / capacity as f64
    }

    /// Submit one [`RequestClass::Interactive`] request (see
    /// [`FleetHandle::submit_class`]).
    pub fn submit(&mut self, prompt: Vec<i32>) -> Result<u64> {
        self.submit_class(prompt, RequestClass::Interactive)
    }

    /// Submit one request under `class`. Admission control applies
    /// *before* enqueue: a full router queue, or an estimated wait that
    /// already blows the deadline, returns the typed [`Saturated`] error
    /// — except that an interactive arrival facing a full queue first
    /// evicts the youngest queued batch request (which degrades, not
    /// disappears). Returns the request id (matched by
    /// [`FleetResponse::id`]).
    pub fn submit_class(&mut self, prompt: Vec<i32>, class: RequestClass) -> Result<u64> {
        let seq_len = self.seq_len;
        if prompt.is_empty() {
            bail!("prompt is empty (need at least one token)");
        }
        if self.live_workers() == 0 {
            bail!("fleet has no live workers");
        }
        if prompt.len() >= seq_len {
            // a seq_len row cannot hold prompt + 1 generated token:
            // resolve as degraded (error set) instead of truncating the
            // prompt or bouncing the caller
            let id = self.next_id;
            self.next_id += 1;
            self.stats.submitted += 1;
            let plen = prompt.len();
            self.requests.insert(
                id,
                ReqState {
                    prompt,
                    class,
                    submitted: Instant::now(),
                    attempt: 0,
                    retry: RetryState::default(),
                    assigned: None,
                },
            );
            self.resolve_degraded(
                id,
                format!("prompt length {plen} leaves no room to generate (seq_len {seq_len})"),
            );
            return Ok(id);
        }
        let mut over_cap = self.queue_cap > 0 && self.queued() >= self.queue_cap;
        if over_cap
            && class == RequestClass::Interactive
            && self.starvation_bound > 0
            && self.evict_youngest_batch()
        {
            // the evict-batch rung of the degradation ladder freed a slot
            over_cap = self.queued() >= self.queue_cap;
        }
        let cdepth = self.class_depth(class);
        let class_est = self.class_est_ms(class);
        let est_wait = self.est_wait_ms(class, cdepth + 1);
        let over_deadline = match self.deadline_ms {
            // Unseeded estimator (no completion observed yet): est_wait is
            // 0 for ANY backlog, so a wait test would admit everything.
            // Until the EWMA seeds, bound admission by live slot capacity
            // — a request beyond what can run concurrently is shed.
            Some(_) if class_est <= 0.0 => {
                cdepth + 1 > (self.live_workers() * self.slots_per_worker).max(1)
            }
            Some(d) => est_wait > d,
            None => false,
        };
        if over_cap || over_deadline {
            self.stats.shed += 1;
            self.stats.per_class.get_mut(class).shed += 1;
            let capacity = (self.live_workers() * self.slots_per_worker).max(1);
            let hint = fleet_retry_hint(
                cdepth + 1,
                self.stats.per_class.get(class).exec_ewma_ms,
                self.est_service_ms,
                capacity,
            );
            let qdepth = self.queued();
            if let Some(tel) = self.telemetry.as_mut() {
                let _ = tel.append(&Json::obj(vec![
                    ("event", Json::Str("reject".into())),
                    ("class", Json::Str(class.label().into())),
                    ("queued", Json::Num(qdepth as f64)),
                    (
                        "reason",
                        Json::Str((if over_cap { "queue-cap" } else { "deadline" }).into()),
                    ),
                    ("retry_after_ms", Json::Num(hint)),
                ]));
            }
            return Err(Saturated { retry_after_ms: hint }.into());
        }
        let id = self.next_id;
        self.next_id += 1;
        self.stats.submitted += 1;
        self.requests.insert(
            id,
            ReqState {
                prompt,
                class,
                submitted: Instant::now(),
                attempt: 0,
                retry: RetryState::default(),
                assigned: None,
            },
        );
        match class {
            RequestClass::Interactive => self.lane_int.push_back(id),
            RequestClass::Batch => self.lane_bat.push_back(id),
        }
        self.dispatch();
        self.pump(false)?;
        self.relay_streams();
        Ok(id)
    }

    /// Pop the youngest queued batch request and resolve it degraded so
    /// an interactive arrival can take its queue slot. Returns whether a
    /// slot was freed (false when no batch request is queued).
    fn evict_youngest_batch(&mut self) -> bool {
        let Some(id) = self.lane_bat.pop_back() else { return false };
        self.stats.evicted += 1;
        self.stats.per_class.batch.evicted += 1;
        if let Some(tel) = self.telemetry.as_mut() {
            let _ = tel.append(&Json::obj(vec![
                ("event", Json::Str("evict".into())),
                ("id", Json::Num(id as f64)),
                ("class", Json::Str(RequestClass::Batch.label().into())),
            ]));
        }
        self.resolve_degraded(
            id,
            "evicted by interactive admission under saturation".to_string(),
        );
        true
    }

    /// Advance the router: absorb worker events, expire router-queued
    /// requests past their deadline, refill workers. Returns requests
    /// newly resolved by this call.
    pub fn poll(&mut self) -> Result<usize> {
        let before = self.completed.len();
        self.pump(false)?;
        self.relay_streams();
        self.expire();
        self.dispatch();
        Ok(self.completed.len() - before)
    }

    /// Run every submitted request to resolution and take the responses.
    /// Never hangs: if every worker dies, the remaining requests degrade
    /// with an error instead of waiting forever.
    pub fn drain(&mut self) -> Result<Vec<FleetResponse>> {
        while !self.requests.is_empty() {
            self.expire();
            self.dispatch();
            if self.requests.is_empty() {
                break;
            }
            if self.live_workers() == 0 {
                self.degrade_all("no live workers remain");
                break;
            }
            self.pump(true)?;
            self.relay_streams();
        }
        Ok(std::mem::take(&mut self.completed))
    }

    /// Stop every worker, join the threads, and absorb their shutdown
    /// reports into `stats`. Unresolved requests (drain not called, or
    /// not called to completion) degrade with an error.
    pub fn shutdown(&mut self) {
        for tx in self.senders.iter_mut() {
            if let Some(t) = tx.take() {
                let _ = t.send(ToWorker::Stop);
            }
        }
        for join in self.joins.iter_mut() {
            if let Some(j) = join.take() {
                let _ = j.join();
            }
        }
        // Workers are gone; everything left in the event channel is
        // final (Done/Stopped/Died stragglers).
        loop {
            match self.events.try_recv() {
                Ok(ev) => self.on_event(ev),
                Err(_) => break,
            }
        }
        self.degrade_all("fleet shut down");
    }

    /// Degrade every unresolved request with `reason` (no-live-worker /
    /// shutdown paths — never hang a caller).
    fn degrade_all(&mut self, reason: &str) {
        let ids: Vec<u64> = self.requests.keys().copied().collect();
        for id in ids {
            self.resolve_degraded(id, format!("request abandoned: {reason}"));
        }
        self.lane_int.clear();
        self.lane_bat.clear();
    }

    /// Dispatch router-queued requests to the least-loaded live worker
    /// (ties to the lowest index) while free slots exist. The lane
    /// arbiter ([`take_batch_lane`]) serves interactive first, bounded
    /// by `starvation_bound` batch bypasses.
    fn dispatch(&mut self) {
        loop {
            if self.lane_int.is_empty() && self.lane_bat.is_empty() {
                return;
            }
            let mut best: Option<(usize, usize)> = None;
            for (w, tx) in self.senders.iter().enumerate() {
                if tx.is_none() {
                    continue;
                }
                let load = self.outstanding.get(w).copied().unwrap_or(usize::MAX);
                if load >= self.slots_per_worker {
                    continue;
                }
                if best.map(|(_, b)| load < b).unwrap_or(true) {
                    best = Some((w, load));
                }
            }
            let Some((w, _)) = best else { return };
            let take_bat = take_batch_lane(
                self.lane_int.front().copied(),
                self.lane_bat.front().copied(),
                self.starvation_bound,
                self.since_bypass,
            );
            let popped = if take_bat {
                if self.starvation_bound > 0 && !self.lane_int.is_empty() {
                    self.stats.lane_bypasses += 1;
                }
                self.since_bypass = 0;
                self.lane_bat.pop_front()
            } else {
                if self.lane_bat.is_empty() {
                    self.since_bypass = 0;
                } else {
                    self.since_bypass += 1;
                }
                self.lane_int.pop_front()
            };
            let Some(id) = popped else { return };
            let stream = if self.stream_buf > 0 {
                let cap = self.stream_buf;
                let policy = self.slow_consumer;
                let chan = self.streams.entry(id).or_insert_with(|| bounded(cap, policy));
                Some(chan.0.clone())
            } else {
                None
            };
            let Some(req) = self.requests.get_mut(&id) else { continue };
            let class = req.class;
            let job = Job {
                id,
                prompt: req.prompt.clone(),
                attempt: req.attempt,
                submitted: req.submitted,
                stream,
            };
            let sent = match self.senders.get(w).and_then(|s| s.as_ref()) {
                Some(tx) => tx.send(ToWorker::Job(job)).is_ok(),
                None => false,
            };
            if sent {
                req.assigned = Some(w);
                if let Some(o) = self.outstanding.get_mut(w) {
                    *o += 1;
                }
            } else {
                // channel closed under us: the worker is dead even if its
                // Died event has not been absorbed yet
                match class {
                    RequestClass::Interactive => self.lane_int.push_front(id),
                    RequestClass::Batch => self.lane_bat.push_front(id),
                }
                if let Some(tx) = self.senders.get_mut(w) {
                    *tx = None;
                }
            }
        }
    }

    /// Drain every request's bounded token channel into the router-side
    /// sink / telemetry. BTreeMap order keeps the relay deterministic;
    /// within one request the channel is FIFO, so per-id token order is
    /// preserved exactly.
    fn relay_streams(&mut self) {
        for (_tx, rx) in self.streams.values() {
            while let Some(ev) = rx.try_recv() {
                if let Some(sink) = &self.on_token {
                    (sink.0)(&ev);
                }
                if self.stream {
                    if let Some(tel) = self.telemetry.as_mut() {
                        let _ = tel.append(&Json::obj(vec![
                            ("event", Json::Str("token".into())),
                            ("id", Json::Num(ev.id as f64)),
                            ("token", Json::Num(ev.token as f64)),
                            ("index", Json::Num(ev.index as f64)),
                            ("worker", Json::Num(ev.worker as f64)),
                            ("attempt", Json::Num(ev.attempt as f64)),
                        ]));
                    }
                }
            }
        }
    }

    /// Tear down `id`'s token channel at resolution: deliver whatever is
    /// still buffered, then fold the channel's drop/stall/disconnect
    /// counters into the fleet gauges.
    fn close_stream(&mut self, id: u64) {
        let Some((tx, rx)) = self.streams.remove(&id) else { return };
        tx.close();
        while let Some(ev) = rx.try_recv() {
            if let Some(sink) = &self.on_token {
                (sink.0)(&ev);
            }
            if self.stream {
                if let Some(tel) = self.telemetry.as_mut() {
                    let _ = tel.append(&Json::obj(vec![
                        ("event", Json::Str("token".into())),
                        ("id", Json::Num(ev.id as f64)),
                        ("token", Json::Num(ev.token as f64)),
                        ("index", Json::Num(ev.index as f64)),
                        ("worker", Json::Num(ev.worker as f64)),
                        ("attempt", Json::Num(ev.attempt as f64)),
                    ]));
                }
            }
        }
        let st = rx.stats();
        self.stats.tokens_dropped += st.dropped;
        self.stats.consumer_stalls += st.stalls;
        if st.disconnected {
            self.stats.streams_disconnected += 1;
        }
    }

    /// Absorb worker events. `block` waits (bounded) for at least one
    /// event when none is immediately available.
    fn pump(&mut self, block: bool) -> Result<()> {
        let mut got = false;
        loop {
            match self.events.try_recv() {
                Ok(ev) => {
                    got = true;
                    self.on_event(ev);
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    // every worker thread is gone (the event channel has
                    // no senders left) — even ones that never managed to
                    // report; drain() must degrade, not spin
                    for tx in self.senders.iter_mut() {
                        *tx = None;
                    }
                    return Ok(());
                }
            }
        }
        if block && !got && !self.requests.is_empty() {
            // Bounded wait: deadline expiry and dead-worker detection
            // must run even if no event ever arrives.
            match self.events.recv_timeout(Duration::from_millis(20)) {
                Ok(ev) => self.on_event(ev),
                Err(std::sync::mpsc::RecvTimeoutError::Timeout) => {}
                Err(std::sync::mpsc::RecvTimeoutError::Disconnected) => {
                    for tx in self.senders.iter_mut() {
                        *tx = None;
                    }
                }
            }
        }
        Ok(())
    }

    fn on_event(&mut self, ev: WorkerEvent) {
        match ev {
            WorkerEvent::Ready { .. } | WorkerEvent::InitFailed { .. } => {}
            WorkerEvent::Done { worker, id, attempt, row, gen_tokens, ttft_ms, execute_ms } => {
                if let Some(o) = self.outstanding.get_mut(worker) {
                    *o = o.saturating_sub(1);
                }
                // flush + retire the token channel before the terminal
                // event, so a consumer never sees tokens after "request"
                self.close_stream(id);
                let Some(req) = self.requests.remove(&id) else { return };
                let now = Instant::now();
                let latency_ms = now.duration_since(req.submitted).as_secs_f64() * 1000.0;
                let wait_ms = (latency_ms - execute_ms).max(0.0);
                self.stats.completed += 1;
                self.stats.latencies_ms.push(latency_ms);
                self.stats.ttft_ms.push(ttft_ms);
                self.stats.queue_wait_ms.push(wait_ms);
                // EWMA service estimates feed admission control (global
                // fallback + the rejected class's own hint)
                self.est_service_ms = if self.est_service_ms <= 0.0 {
                    execute_ms
                } else {
                    0.9 * self.est_service_ms + 0.1 * execute_ms
                };
                let deadline = self.deadline_ms;
                let cls = self.stats.per_class.get_mut(req.class);
                cls.requests += 1;
                cls.gen_tokens += gen_tokens;
                cls.ttft_ms.push(ttft_ms);
                cls.latencies_ms.push(latency_ms);
                cls.observe_exec(execute_ms);
                if let Some(d) = deadline {
                    if latency_ms <= d {
                        cls.deadline_hits += 1;
                    } else {
                        cls.deadline_misses += 1;
                    }
                }
                if let Some(ws) = self.stats.per_worker.get_mut(worker) {
                    ws.requests += 1;
                    ws.gen_tokens += gen_tokens;
                }
                if let Some(tel) = self.telemetry.as_mut() {
                    let _ = tel.append(&Json::obj(vec![
                        ("event", Json::Str("request".into())),
                        ("id", Json::Num(id as f64)),
                        ("class", Json::Str(req.class.label().into())),
                        ("worker", Json::Num(worker as f64)),
                        ("attempt", Json::Num(attempt as f64)),
                        ("ttft_ms", Json::Num(ttft_ms)),
                        ("latency_ms", Json::Num(latency_ms)),
                        ("gen_tokens", Json::Num(gen_tokens as f64)),
                    ]));
                }
                self.completed.push(FleetResponse {
                    id,
                    row,
                    gen_tokens,
                    latency_ms,
                    ttft_ms,
                    worker: Some(worker),
                    attempt,
                    error: None,
                });
            }
            WorkerEvent::Token { worker, id, attempt, token, index } => {
                if let Some(sink) = &self.on_token {
                    (sink.0)(&TokenEvent { id, token, index, worker, attempt });
                }
                if self.stream {
                    if let Some(tel) = self.telemetry.as_mut() {
                        let _ = tel.append(&Json::obj(vec![
                            ("event", Json::Str("token".into())),
                            ("id", Json::Num(id as f64)),
                            ("token", Json::Num(token as f64)),
                            ("index", Json::Num(index as f64)),
                            ("worker", Json::Num(worker as f64)),
                            ("attempt", Json::Num(attempt as f64)),
                        ]));
                    }
                }
            }
            WorkerEvent::Failed { worker, id, error } => {
                if let Some(o) = self.outstanding.get_mut(worker) {
                    *o = o.saturating_sub(1);
                }
                if let Some(ws) = self.stats.per_worker.get_mut(worker) {
                    ws.failures += 1;
                }
                self.requeue(id, Some(worker), &error);
            }
            WorkerEvent::Died { worker } => {
                let was_live = match self.senders.get_mut(worker) {
                    Some(tx) => tx.take().is_some(),
                    None => false,
                };
                if was_live || !self.stats.per_worker.get(worker).map(|w| w.dead).unwrap_or(true)
                {
                    self.stats.worker_deaths += 1;
                }
                if let Some(ws) = self.stats.per_worker.get_mut(worker) {
                    ws.dead = true;
                }
                if let Some(o) = self.outstanding.get_mut(worker) {
                    *o = 0;
                }
                // Requeue everything the dead worker held (in flight or
                // still in its channel) — ascending id order.
                let orphans: Vec<u64> = self
                    .requests
                    .iter()
                    .filter(|(_, r)| r.assigned == Some(worker))
                    .map(|(&id, _)| id)
                    .collect();
                if let Some(tel) = self.telemetry.as_mut() {
                    let _ = tel.append(&Json::obj(vec![
                        ("event", Json::Str("worker-death".into())),
                        ("worker", Json::Num(worker as f64)),
                        ("requeued", Json::Num(orphans.len() as f64)),
                    ]));
                }
                for id in orphans {
                    self.requeue(id, None, "worker died");
                }
            }
            WorkerEvent::Stopped { worker, rounds, occupancy, live_pages } => {
                if let Some(ws) = self.stats.per_worker.get_mut(worker) {
                    ws.rounds = rounds;
                    ws.occupancy = occupancy;
                    ws.live_pages = live_pages;
                }
            }
        }
    }

    /// One attempt failed: charge the retry budget and put the request
    /// back at the *front* of the router queue (it has already waited),
    /// or degrade it when the budget is spent.
    fn requeue(&mut self, id: u64, worker: Option<usize>, error: &str) {
        let Some(req) = self.requests.get_mut(&id) else { return };
        let delay =
            self.retry_policy.next_delay(&mut req.retry, &mut self.retry_rng);
        match delay {
            Some(backoff_ms) => {
                req.attempt += 1;
                req.assigned = None;
                let attempt = req.attempt;
                let class = req.class;
                self.stats.retries += 1;
                match class {
                    RequestClass::Interactive => self.lane_int.push_front(id),
                    RequestClass::Batch => self.lane_bat.push_front(id),
                }
                if let Some(tel) = self.telemetry.as_mut() {
                    let mut fields = vec![
                        ("event", Json::Str("retry".into())),
                        ("id", Json::Num(id as f64)),
                        ("attempt", Json::Num(attempt as f64)),
                        ("backoff_ms", Json::Num(backoff_ms)),
                        ("error", Json::Str(error.to_string())),
                    ];
                    if let Some(w) = worker {
                        fields.push(("worker", Json::Num(w as f64)));
                    }
                    let _ = tel.append(&Json::obj(fields));
                }
            }
            None => {
                let msg = format!(
                    "retry budget exhausted after {} attempts: {error}",
                    req.retry.attempts
                );
                self.resolve_degraded(id, msg);
            }
        }
    }

    /// Expire router-queued requests past the deadline (dispatched ones
    /// are the workers' to finish). Both lanes are scanned; each expiry
    /// leaves an "expired" event *and* a terminal "request" event (via
    /// [`FleetHandle::resolve_degraded`]) in the JSONL trail.
    fn expire(&mut self) {
        let Some(deadline) = self.deadline_ms else { return };
        let now = Instant::now();
        let expired: Vec<u64> = self
            .lane_int
            .iter()
            .chain(self.lane_bat.iter())
            .copied()
            .filter(|id| match self.requests.get(id) {
                Some(r) => {
                    r.assigned.is_none()
                        && now.duration_since(r.submitted).as_secs_f64() * 1000.0 >= deadline
                }
                None => false,
            })
            .collect();
        for id in expired {
            self.stats.expired += 1;
            let (waited, class) = match self.requests.get(&id) {
                Some(r) => (
                    now.duration_since(r.submitted).as_secs_f64() * 1000.0,
                    r.class,
                ),
                None => (0.0, RequestClass::Interactive),
            };
            self.stats.per_class.get_mut(class).expired += 1;
            if let Some(tel) = self.telemetry.as_mut() {
                let _ = tel.append(&Json::obj(vec![
                    ("event", Json::Str("expired".into())),
                    ("id", Json::Num(id as f64)),
                    ("class", Json::Str(class.label().into())),
                    ("waited_ms", Json::Num(waited)),
                ]));
            }
            self.resolve_degraded(id, format!("deadline exceeded ({deadline} ms) while queued"));
        }
    }

    /// Resolve `id` as degraded: prompt-only row, error set. Emits the
    /// request's terminal "request" JSONL event (class + reason), so
    /// every submission — completed, expired, evicted, or abandoned —
    /// leaves exactly one terminal record (stream/response parity).
    fn resolve_degraded(&mut self, id: u64, error: String) {
        self.close_stream(id);
        let Some(req) = self.requests.remove(&id) else { return };
        self.lane_int.retain(|&q| q != id);
        self.lane_bat.retain(|&q| q != id);
        let now = Instant::now();
        let latency_ms = now.duration_since(req.submitted).as_secs_f64() * 1000.0;
        let mut row = vec![tok::PAD; self.seq_len];
        for (dst, src) in row.iter_mut().zip(req.prompt.iter()) {
            *dst = *src;
        }
        self.stats.completed += 1;
        self.stats.degraded += 1;
        self.stats.latencies_ms.push(latency_ms);
        let deadline = self.deadline_ms;
        let cls = self.stats.per_class.get_mut(req.class);
        cls.requests += 1;
        cls.latencies_ms.push(latency_ms);
        if deadline.is_some() {
            cls.deadline_misses += 1;
        }
        if let Some(tel) = self.telemetry.as_mut() {
            let _ = tel.append(&Json::obj(vec![
                ("event", Json::Str("request".into())),
                ("id", Json::Num(id as f64)),
                ("class", Json::Str(req.class.label().into())),
                ("attempt", Json::Num(req.attempt as f64)),
                ("ttft_ms", Json::Num(latency_ms)),
                ("latency_ms", Json::Num(latency_ms)),
                ("gen_tokens", Json::Num(0.0)),
                ("error", Json::Str(error.clone())),
            ]));
        }
        self.completed.push(FleetResponse {
            id,
            row,
            gen_tokens: 0,
            latency_ms,
            ttft_ms: latency_ms,
            worker: None,
            attempt: req.attempt,
            error: Some(error),
        });
    }
}

impl Drop for FleetHandle {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Everything a worker thread needs to build its own engine (all Send).
struct WorkerCfg {
    worker: usize,
    target: FleetTarget,
    weights: Arc<Vec<f32>>,
    sample: SampleCfg,
    slots: usize,
    fault: FaultPlan,
    /// Decode-state layout (paged/prefix-cache) — per worker, so each
    /// worker keeps its own prefix cache over the prompts it served.
    opts: DecodeOpts,
    /// Send [`WorkerEvent::Token`] per generated token.
    stream: bool,
}

/// One in-flight row on a worker.
struct WSlot {
    id: u64,
    attempt: u32,
    row: Vec<i32>,
    frontier: usize,
    /// Per-request sampling stream (see [`request_rng`]) — carried in
    /// the slot so a generation's draws are a pure function of the
    /// request, not of its slot-mates.
    rng: Rng,
    gen: usize,
    admitted: Instant,
    ttft_ms: f64,
    /// Bounded token channel for this request (None = not streaming or
    /// legacy event relay). A full channel costs *this* request a drop /
    /// stall / severed stream per policy — never a blocked step round
    /// for its slot-mates.
    stream: Option<BoundedTx<TokenEvent>>,
}

/// Worker-local scheduler state (one per thread; never crosses threads).
struct WorkerInner {
    worker: usize,
    seq_len: usize,
    sample: SampleCfg,
    fault: FaultPlan,
    session: Box<dyn DecodeSession>,
    slots: Vec<Option<WSlot>>,
    scratch: SampleScratch,
    logits: Vec<f32>,
    /// Executed decode rounds (the fault plan's kill coordinate).
    rounds: usize,
    occ_sum: f64,
    /// Send [`WorkerEvent::Token`] per generated token.
    stream: bool,
}

impl WorkerInner {
    fn init(cfg: &WorkerCfg) -> Result<WorkerInner> {
        let engine = Engine::with_backend(&cfg.target.artifacts_root, cfg.target.backend)?;
        let rt = ModelRuntime::new(&engine, &cfg.target.model)?;
        let weights_buf = engine.upload_f32(&cfg.weights, &[cfg.weights.len()])?;
        let opened = engine.open_decode_opts(
            &rt.model,
            &cfg.target.fwd_key,
            &weights_buf,
            cfg.slots,
            &cfg.opts,
        )?;
        let Some(session) = opened else {
            bail!(
                "fleet serving requires a stateful-decode backend \
                 (backend {} has none for {:?})",
                engine.backend_kind(),
                cfg.target.fwd_key
            );
        };
        Ok(WorkerInner {
            worker: cfg.worker,
            seq_len: cfg.target.seq_len,
            sample: cfg.sample,
            fault: cfg.fault.clone(),
            session,
            slots: (0..cfg.slots).map(|_| None).collect(),
            scratch: SampleScratch::default(),
            logits: Vec::new(),
            rounds: 0,
            occ_sum: 0.0,
            stream: cfg.stream,
        })
    }

    fn active(&self) -> usize {
        self.slots.iter().filter(|s| s.is_some()).count()
    }

    fn free_slot(&self) -> Option<usize> {
        self.slots.iter().position(|s| s.is_none())
    }

    /// Prefill `job` into a free slot and sample its first token; short
    /// generations (EOS / length caps) finish on the spot. A failed or
    /// fault-injected prefill reports `Failed` — the router retries.
    fn admit_job(&mut self, job: Job, tx: &Sender<WorkerEvent>) {
        let Some(slot_idx) = self.free_slot() else { return };
        if self.fault.fail_prefill(job.id, job.attempt) {
            let _ = tx.send(WorkerEvent::Failed {
                worker: self.worker,
                id: job.id,
                error: "injected prefill fault".to_string(),
            });
            return;
        }
        let t0 = Instant::now();
        // the router's submit already rejects these lengths; a job that
        // still arrives out of range fails its attempt loudly instead of
        // silently truncating the prompt
        let np = job.prompt.len();
        if np == 0 || np >= self.seq_len {
            let _ = tx.send(WorkerEvent::Failed {
                worker: self.worker,
                id: job.id,
                error: format!(
                    "prompt length {np} out of range on worker (need 1..{})",
                    self.seq_len
                ),
            });
            return;
        }
        let prompt = job.prompt.as_slice();
        if let Err(e) = self.session.prefill(slot_idx, prompt, &mut self.logits) {
            let _ = self.session.close(slot_idx);
            let _ = tx.send(WorkerEvent::Failed {
                worker: self.worker,
                id: job.id,
                error: format!("prefill failed: {e:#}"),
            });
            return;
        }
        let mut rng = request_rng(self.sample.seed, job.id);
        let next = sample_token_with(&self.sample, &mut rng, &self.logits, &mut self.scratch);
        let now = Instant::now();
        let ttft_ms = now.duration_since(job.submitted).as_secs_f64() * 1000.0;
        let mut row = vec![tok::PAD; self.seq_len];
        for (dst, src) in row.iter_mut().zip(prompt.iter()) {
            *dst = *src;
        }
        if self.sample.max_new == 0 {
            let _ = self.session.close(slot_idx);
            let _ = tx.send(WorkerEvent::Done {
                worker: self.worker,
                id: job.id,
                attempt: job.attempt,
                row,
                gen_tokens: 0,
                ttft_ms,
                execute_ms: now.duration_since(t0).as_secs_f64() * 1000.0,
            });
            return;
        }
        if let Some(cell) = row.get_mut(np) {
            *cell = next;
        }
        if let Some(chan) = job.stream.as_ref() {
            let _ = chan.push(TokenEvent {
                id: job.id,
                token: next,
                index: 0,
                worker: self.worker,
                attempt: job.attempt,
            });
        } else if self.stream {
            let _ = tx.send(WorkerEvent::Token {
                worker: self.worker,
                id: job.id,
                attempt: job.attempt,
                token: next,
                index: 0,
            });
        }
        if next == tok::EOS || np + 1 >= self.seq_len || self.sample.max_new == 1 {
            let _ = self.session.close(slot_idx);
            let _ = tx.send(WorkerEvent::Done {
                worker: self.worker,
                id: job.id,
                attempt: job.attempt,
                row,
                gen_tokens: 1,
                ttft_ms,
                execute_ms: now.duration_since(t0).as_secs_f64() * 1000.0,
            });
        } else if let Some(slot) = self.slots.get_mut(slot_idx) {
            *slot = Some(WSlot {
                id: job.id,
                attempt: job.attempt,
                row,
                frontier: np + 1,
                rng,
                gen: 1,
                admitted: t0,
                ttft_ms,
                stream: job.stream,
            });
        }
    }

    /// One decode round over every live slot (ascending order). Injected
    /// and real step failures fail only that slot's attempt (`Failed`);
    /// the other slots keep generating.
    fn step_round(&mut self, tx: &Sender<WorkerEvent>) {
        let width = self.slots.len();
        let active = self.active();
        if active == 0 {
            return;
        }
        if self.fault.step_delay_ms > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(self.fault.step_delay_ms / 1000.0));
        }
        for idx in 0..width {
            let (id, attempt, last_tok, pos, gen) =
                match self.slots.get(idx).and_then(|s| s.as_ref()) {
                    Some(s) => {
                        let t = s
                            .frontier
                            .checked_sub(1)
                            .and_then(|i| s.row.get(i))
                            .copied()
                            .unwrap_or(tok::PAD);
                        (s.id, s.attempt, t, s.frontier, s.gen)
                    }
                    None => continue,
                };
            if self.fault.fail_step(id, attempt, gen) {
                if let Some(s) = self.slots.get_mut(idx) {
                    *s = None;
                }
                let _ = self.session.close(idx);
                let _ = tx.send(WorkerEvent::Failed {
                    worker: self.worker,
                    id,
                    error: "injected step fault".to_string(),
                });
                continue;
            }
            let stepped = self.session.step(idx, last_tok, &mut self.logits);
            if let Err(e) = stepped {
                if let Some(s) = self.slots.get_mut(idx) {
                    *s = None;
                }
                let _ = self.session.close(idx);
                let _ = tx.send(WorkerEvent::Failed {
                    worker: self.worker,
                    id,
                    error: format!("decode step failed: {e:#}"),
                });
                continue;
            }
            let Some(slot) = self.slots.get_mut(idx).and_then(|s| s.as_mut()) else { continue };
            let next =
                sample_token_with(&self.sample, &mut slot.rng, &self.logits, &mut self.scratch);
            if let Some(cell) = slot.row.get_mut(pos) {
                *cell = next;
            }
            slot.frontier += 1;
            slot.gen += 1;
            if let Some(chan) = slot.stream.as_ref() {
                let _ = chan.push(TokenEvent {
                    id,
                    token: next,
                    index: slot.gen - 1,
                    worker: self.worker,
                    attempt,
                });
            } else if self.stream {
                let _ = tx.send(WorkerEvent::Token {
                    worker: self.worker,
                    id,
                    attempt,
                    token: next,
                    index: slot.gen - 1,
                });
            }
            if next == tok::EOS || slot.frontier >= self.seq_len || slot.gen >= self.sample.max_new
            {
                if let Some(done) = self.slots.get_mut(idx).and_then(|s| s.take()) {
                    let now = Instant::now();
                    let _ = self.session.close(idx);
                    let _ = tx.send(WorkerEvent::Done {
                        worker: self.worker,
                        id: done.id,
                        attempt: done.attempt,
                        row: done.row,
                        gen_tokens: done.gen,
                        ttft_ms: done.ttft_ms,
                        execute_ms: now.duration_since(done.admitted).as_secs_f64() * 1000.0,
                    });
                }
            }
        }
        self.rounds += 1;
        self.occ_sum += active as f64 / width as f64;
    }

    fn occupancy(&self) -> f64 {
        if self.rounds == 0 {
            0.0
        } else {
            self.occ_sum / self.rounds as f64
        }
    }

    /// Decode-state pages currently live (0 for dense backends) — the
    /// shutdown leak report behind [`WorkerStats::live_pages`].
    fn live_pages(&self) -> usize {
        self.session.paged_stats().map(|p| p.live_pages).unwrap_or(0)
    }
}

/// Worker thread body: build the engine, then loop
/// `drain channel -> planned-kill check -> admit -> one decode round`.
/// Blocks on the channel only when fully idle.
fn worker_main(cfg: WorkerCfg, rx: Receiver<ToWorker>, tx: Sender<WorkerEvent>) {
    let worker = cfg.worker;
    let mut inner = match WorkerInner::init(&cfg) {
        Ok(i) => i,
        Err(e) => {
            let _ = tx.send(WorkerEvent::InitFailed { worker, error: format!("{e:#}") });
            return;
        }
    };
    let _ = tx.send(WorkerEvent::Ready { worker });
    let mut local: VecDeque<Job> = VecDeque::new();
    loop {
        if inner.active() == 0 && local.is_empty() {
            match rx.recv() {
                Ok(ToWorker::Job(j)) => local.push_back(j),
                Ok(ToWorker::Stop) | Err(_) => {
                    let _ = tx.send(WorkerEvent::Stopped {
                        worker,
                        rounds: inner.rounds,
                        occupancy: inner.occupancy(),
                        live_pages: inner.live_pages(),
                    });
                    return;
                }
            }
        }
        loop {
            match rx.try_recv() {
                Ok(ToWorker::Job(j)) => local.push_back(j),
                Ok(ToWorker::Stop) => {
                    let _ = tx.send(WorkerEvent::Stopped {
                        worker,
                        rounds: inner.rounds,
                        occupancy: inner.occupancy(),
                        live_pages: inner.live_pages(),
                    });
                    return;
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    if inner.active() == 0 && local.is_empty() {
                        return;
                    }
                    break;
                }
            }
        }
        // Planned kill: die before executing local round `r`. The router
        // requeues everything this worker held (in flight AND queued in
        // its channel) from its own request table.
        if cfg.fault.kills_at(worker, inner.rounds) {
            let _ = tx.send(WorkerEvent::Died { worker });
            return;
        }
        while inner.free_slot().is_some() {
            let Some(job) = local.pop_front() else { break };
            inner.admit_job(job, &tx);
        }
        inner.step_round(&tx);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fault_decisions_are_pure_functions_of_their_coordinates() {
        let plan = FaultPlan {
            seed: 9,
            kills: vec![(1, 4)],
            prefill_fail_p: 0.3,
            step_fail_p: 0.2,
            step_delay_ms: 0.0,
        };
        // replay-exact: the same coordinates always give the same answer
        for id in 0..64u64 {
            for attempt in 0..4u32 {
                assert_eq!(plan.fail_prefill(id, attempt), plan.fail_prefill(id, attempt));
                for step in 0..8usize {
                    assert_eq!(
                        plan.fail_step(id, attempt, step),
                        plan.fail_step(id, attempt, step)
                    );
                }
            }
        }
        assert!(plan.kills_at(1, 4));
        assert!(!plan.kills_at(1, 3));
        assert!(!plan.kills_at(0, 4));
        // attempts decorrelate: a doomed attempt does not doom its retry
        let doomed: Vec<u64> = (0..512).filter(|&id| plan.fail_prefill(id, 0)).collect();
        assert!(!doomed.is_empty(), "p=0.3 over 512 ids must hit some");
        let still_doomed =
            doomed.iter().filter(|&&id| plan.fail_prefill(id, 1)).count();
        assert!(
            still_doomed < doomed.len(),
            "retries must be fresh draws, not replays of the failed attempt"
        );
    }

    #[test]
    fn zero_probability_plan_is_noop() {
        let plan = FaultPlan::default();
        assert!(plan.is_noop());
        for id in 0..32u64 {
            assert!(!plan.fail_prefill(id, 0));
            assert!(!plan.fail_step(id, 0, 5));
        }
        assert!(!plan.kills_at(0, 0));
    }

    #[test]
    fn request_rng_depends_on_id_and_seed_only() {
        // identical streams for the same (seed, id) — the retry oracle
        let mut a = request_rng(7, 3);
        let mut b = request_rng(7, 3);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // different ids diverge
        let mut c = request_rng(7, 4);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| c.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fleet_stats_summary_and_rates() {
        let mut s = FleetStats {
            fwd_key: "fwd_nvfp4".into(),
            workers: 3,
            submitted: 90,
            completed: 90,
            degraded: 2,
            shed: 10,
            retries: 4,
            worker_deaths: 1,
            per_worker: vec![WorkerStats::default(); 3],
            ..Default::default()
        };
        for l in [10.0, 20.0, 30.0] {
            s.latencies_ms.push(l);
            s.ttft_ms.push(l / 2.0);
        }
        if let Some(w) = s.per_worker.get_mut(0) {
            w.rounds = 10;
            w.occupancy = 1.0;
        }
        if let Some(w) = s.per_worker.get_mut(1) {
            w.rounds = 30;
            w.occupancy = 0.5;
        }
        assert!((s.shed_rate() - 0.1).abs() < 1e-12);
        // rounds-weighted: (10*1.0 + 30*0.5) / 40
        assert!((s.occupancy() - 0.625).abs() < 1e-12);
        let line = s.summary();
        assert!(line.contains("3w"), "{line}");
        assert!(line.contains("88/90 ok"), "{line}");
        assert!(line.contains("1 deaths"), "{line}");
        assert!(line.contains("shed rate 0.10"), "{line}");
    }

    #[test]
    fn empty_fleet_stats_are_safe() {
        let s = FleetStats::default();
        assert_eq!(s.shed_rate(), 0.0);
        assert_eq!(s.occupancy(), 0.0);
        assert_eq!(s.latency_p(99.0), 0.0);
        assert!(s.summary().contains("0/0 ok"));
        // idle fleets report no lane or stream clause
        assert!(!s.summary().contains("bypass"), "{}", s.summary());
        assert!(!s.summary().contains("stream drop"), "{}", s.summary());
    }

    #[test]
    fn retry_hints_differ_per_class_under_the_same_queue_state() {
        // Same queue snapshot: 2 interactive + 6 batch queued, 4 slots.
        // Interactive waits only on its own lane at its own (fast) EWMA;
        // batch waits on everything at its own (slow) EWMA.
        let int = fleet_retry_hint(3, 20.0, 50.0, 4);
        let bat = fleet_retry_hint(9, 200.0, 50.0, 4);
        assert!((int - 20.0).abs() < 1e-12, "3*20/4 = 15, floored at one service time: {int}");
        assert!((bat - 450.0).abs() < 1e-12, "9*200/4: {bat}");
        assert!(bat > int, "batch callers must get the longer, honest hint");
        // cold class falls back to the global estimate
        let cold = fleet_retry_hint(3, 0.0, 50.0, 4);
        assert!((cold - 50.0).abs() < 1e-12, "3*50/4 = 37.5, floored at fallback: {cold}");
        // never below 1 ms, even with no estimate at all
        assert_eq!(fleet_retry_hint(0, 0.0, 0.0, 4), 1.0);
        // zero capacity never divides by zero
        assert!(fleet_retry_hint(5, 10.0, 0.0, 0).is_finite());
    }

    #[test]
    fn summary_reports_lane_and_stream_clauses() {
        let mut s = FleetStats {
            fwd_key: "fwd_nvfp4".into(),
            workers: 2,
            submitted: 12,
            completed: 12,
            lane_bypasses: 3,
            tokens_dropped: 7,
            consumer_stalls: 2,
            streams_disconnected: 1,
            per_worker: vec![WorkerStats::default(); 2],
            ..Default::default()
        };
        s.per_class.interactive.requests = 8;
        s.per_class.interactive.ttft_ms.push(4.0);
        s.per_class.batch.requests = 4;
        s.per_class.batch.shed = 2;
        let line = s.summary();
        assert!(line.contains("int 8"), "{line}");
        assert!(line.contains("bat 4"), "{line}");
        assert!(line.contains("shed 2"), "{line}");
        assert!(line.contains("bypass 3"), "{line}");
        assert!(line.contains("stream drop 7 stall 2 disc 1"), "{line}");
    }
}
