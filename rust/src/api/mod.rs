//! `qadx::api` — the typed session/method/serve façade every entry point
//! builds on (CLI, examples, benches, the experiment harness).
//!
//! * [`Session`] / [`SessionBuilder`] own the engine, runs directory,
//!   pipeline scale, seed, and the recovery-method registry.
//! * [`ModelSession`] binds one manifest model: teacher resolution with
//!   memory+disk caching, recovery, checkpoint paths, evaluation.
//! * [`RecoveryMethod`] + [`MethodRegistry`] make recovery methods an open
//!   set — the paper's six are built-ins; a seventh is one trait impl and
//!   one `register` call.
//! * [`ServeHandle`] is the serving façade: a continuous-batching slot
//!   scheduler over stateful prefill/step decode (with a run-to-completion
//!   batch-coalescing fallback for stateless backends) and optional JSONL
//!   telemetry.
//! * [`FleetHandle`] scales that to N worker engines behind a router with
//!   admission control ([`Saturated`] backpressure), budgeted
//!   retry/requeue of work from dead or failing workers, and a
//!   deterministic fault-injection layer ([`FaultPlan`]) for chaos tests.
//! * Overload robustness is shared between both: [`RequestClass`]
//!   priority lanes with a starvation bound and per-class SLO stats
//!   ([`ClassPair`]), plus bounded per-request token channels with a
//!   [`SlowConsumer`] policy so a stalled stream consumer never stalls a
//!   step round.
//! * [`cli`] holds the typed command definitions the `qadx` binary parses
//!   flags through, with usage text generated from the definitions.
//!
//! ```no_run
//! use qadx::api::{ServeCfg, Session};
//!
//! # fn main() -> anyhow::Result<()> {
//! let session = Session::builder().artifacts_dir("artifacts").build()?;
//! let ms = session.model("ace-sim")?;
//! let teacher = ms.teacher()?; // cached: disk (runs/teachers) + memory
//! let qad = session.method("qad")?;
//! let out = ms.recover(&*qad, &ms.default_recovery_cfg(300))?;
//! ms.save_recovered(&*qad, &out)?;
//! let mut server = ms.server("fwd_nvfp4", &ServeCfg::default())?;
//! # let _ = teacher;
//! # Ok(())
//! # }
//! ```

pub mod cli;
pub mod fleet;
pub mod method;
pub mod serve;
pub mod session;
pub mod telemetry;

pub use crate::eval::DecodeMode;
pub use crate::util::stream::{ChanStats, PushOutcome, SlowConsumer};
pub use fleet::{
    fleet_retry_hint, FaultPlan, FleetCfg, FleetHandle, FleetResponse, FleetStats, FleetTarget,
    WorkerStats,
};
pub use method::{MethodRef, MethodRegistry, RecoveryMethod};
pub use serve::{
    class_retry_hint, request_rng, take_batch_lane, ClassPair, ClassStats, Coalescer,
    RequestClass, Saturated, ServeCfg, ServeHandle, ServeResponse, ServeStats, ServeWeights,
    TokenEvent, TokenSink,
};
pub use session::{
    default_recovery_cfg, default_recovery_data, default_recovery_lr, default_sample_cfg,
    recovered_path, ModelSession, Session, SessionBuilder,
};
pub use telemetry::JsonlAppender;
