//! Typed CLI command definitions. Each subcommand declares its flags once
//! (`CommandDef`), parses them into the same config structs library users
//! build by hand, and gets its usage text generated from the declaration —
//! so `qadx help <cmd>` and unknown-flag errors always match what the
//! parser actually accepts.

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::data::tasks::Suite;
use crate::eval::{DecodeMode, EvalCfg};
use crate::quant::KernelTier;
use crate::util::args::Args;

use super::method::MethodRef;
use super::session::{Session, SessionBuilder};

pub struct FlagDef {
    pub name: &'static str,
    /// Value placeholder shown in usage ("" for boolean flags).
    pub value: &'static str,
    pub default: &'static str,
    pub help: &'static str,
}

pub struct CommandDef {
    pub name: &'static str,
    /// Positional-argument part of the usage line.
    pub args: &'static str,
    pub summary: &'static str,
    pub flags: &'static [FlagDef],
}

const fn flag(
    name: &'static str,
    value: &'static str,
    default: &'static str,
    help: &'static str,
) -> FlagDef {
    FlagDef { name, value, default, help }
}

/// Flags every subcommand accepts (session construction).
pub const SESSION_FLAGS: &[FlagDef] = &[
    flag("artifacts", "DIR", "artifacts", "AOT artifact directory (make artifacts)"),
    flag("runs", "DIR", "runs", "run outputs: teachers, checkpoints, reports"),
    flag("scale", "F", "1.0", "teacher pipeline step scale"),
    flag("seed", "N", "0", "session seed (data order, serve-bench mix)"),
    flag("backend", "B", "(QADX_BACKEND or pjrt)", "execution backend: pjrt|reference"),
    flag(
        "threads",
        "N",
        "(QADX_THREADS or all cores)",
        "reference-backend worker threads (results identical at any count)",
    ),
    flag(
        "kernel",
        "T",
        "(QADX_KERNEL or exact)",
        "quantized GEMM kernel tier: exact|packed (packed computes on 4-bit codes)",
    ),
];

pub const COMMANDS: &[CommandDef] = &[
    CommandDef { name: "info", args: "", summary: "manifest + artifact summary", flags: &[] },
    CommandDef {
        name: "teacher",
        args: "<model>",
        summary: "run (or load) the model's post-training pipeline",
        flags: &[],
    },
    CommandDef {
        name: "ptq",
        args: "<model>",
        summary: "PTQ export report (compression, per-layer err)",
        flags: &[],
    },
    CommandDef {
        name: "recover",
        args: "<model>",
        summary: "accuracy recovery (QAD/QAT/MSE/NQT) from the teacher",
        flags: &[
            flag("method", "M", "qad", "recovery method (bf16|ptq|qat|qad|mse|nqt)"),
            flag("lr", "F", "1e-4", "learning rate"),
            flag("steps", "N", "300", "training steps"),
            flag("suites", "A,B", "(per model)", "training suites (comma-separated)"),
        ],
    },
    CommandDef {
        name: "eval",
        args: "<model>",
        summary: "benchmark a method's weights (teacher or recovered ckpt)",
        flags: &[
            flag("method", "M", "bf16", "method whose weights to evaluate"),
            flag("n", "N", "32", "problems per suite"),
            flag("k", "K", "3", "sampling runs per problem"),
            flag("suites", "A,B", "(per model)", "eval suites (comma-separated)"),
        ],
    },
    CommandDef {
        name: "pilot",
        args: "",
        summary: "scaled-down end-to-end sanity run (teacher→PTQ→QAD/QAT)",
        flags: &[
            flag("model", "M", "ace-sim", "sim model"),
            flag("scale", "F", "0.3", "teacher pipeline step scale (pilot default)"),
            flag("n", "N", "24", "problems per suite"),
            flag("k", "K", "2", "sampling runs per problem"),
            flag("lr", "F", "1e-4", "recovery learning rate"),
            flag("steps", "N", "200", "recovery steps"),
            flag("suites", "A,B", "math500,aime,livecodebench", "eval suites"),
        ],
    },
    CommandDef {
        name: "serve-bench",
        args: "",
        summary: "serving throughput: req/s, tok/s, latency, TTFT, occupancy",
        flags: &[
            flag("model", "M", "ace-sim", "sim model"),
            flag("requests", "N", "64", "requests to submit"),
            flag("fwd", "K", "both", "forward path: both|bf16|nvfp4"),
            flag(
                "decode",
                "M",
                "auto",
                "scheduler: auto|step|full (step = continuous batching required)",
            ),
            flag("slots", "N", "0", "continuous in-flight slots (0 = model batch)"),
            flag("max-delay-ms", "F", "25", "coalescing partial-batch flush deadline"),
            flag("max-new", "N", "12", "tokens generated per request"),
            flag("telemetry", "FILE", "(off)", "JSONL event log (or QADX_TELEMETRY_JSONL)"),
            flag("fleet", "", "false", "multi-worker fleet mode (router + N worker engines)"),
            flag("workers", "N", "2", "fleet worker engines (threads)"),
            flag(
                "arrival-rate",
                "F",
                "0",
                "open-loop arrivals, req/s (0 = closed loop: submit all up front)",
            ),
            flag("queue-cap", "N", "0", "fleet router queue bound (0 = unbounded)"),
            flag("deadline-ms", "F", "(off)", "fleet per-request deadline (admission + expiry)"),
            flag("page-size", "N", "32", "decode-state page size in positions (0 = dense rows)"),
            flag("prefix-cache", "N", "0", "shared-prefix cache entries (0 = off; needs pages)"),
            flag("class-mix", "F", "1.0", "fraction of interactive requests (rest batch)"),
            flag(
                "consumer-delay-ms",
                "F",
                "0",
                "simulated per-token consumer stall (exercises slow-consumer policy)",
            ),
            flag(
                "slow-consumer",
                "P",
                "block",
                "stalled-stream policy: block|drop-oldest|disconnect",
            ),
        ],
    },
    CommandDef {
        name: "table",
        args: "<1..12>",
        summary: "regenerate one paper table (exper harness)",
        flags: &[
            flag("quick", "", "false", "reduced budgets (CI smoke)"),
            flag("n", "N", "40", "problems per suite"),
            flag("k", "K", "3", "sampling runs per problem"),
            flag("steps", "N", "400", "recovery steps"),
        ],
    },
    CommandDef {
        name: "all-tables",
        args: "",
        summary: "run the full evaluation section (tables 1-12 + figures)",
        flags: &[
            flag("quick", "", "false", "reduced budgets (CI smoke)"),
            flag("n", "N", "40", "problems per suite"),
            flag("k", "K", "3", "sampling runs per problem"),
            flag("steps", "N", "400", "recovery steps"),
            flag("only", "1,3", "(all)", "subset of tables (101,102 = figures)"),
        ],
    },
    CommandDef {
        name: "figure",
        args: "<1|2>",
        summary: "regenerate a paper figure (CSV curves)",
        flags: &[
            flag("quick", "", "false", "reduced budgets (CI smoke)"),
            flag("n", "N", "40", "problems per suite"),
            flag("k", "K", "3", "sampling runs per problem"),
            flag("steps", "N", "400", "recovery steps"),
        ],
    },
    CommandDef {
        name: "help",
        args: "[command]",
        summary: "this overview, or detailed usage for one command",
        flags: &[],
    },
];

pub fn find_command(name: &str) -> Option<&'static CommandDef> {
    COMMANDS.iter().find(|c| c.name == name)
}

fn flag_line(f: &FlagDef) -> String {
    let head = if f.value.is_empty() {
        format!("--{}", f.name)
    } else {
        format!("--{} {}", f.name, f.value)
    };
    format!("  {head:<22} {} [default: {}]\n", f.help, f.default)
}

/// Detailed usage for one command, generated from its definition.
pub fn render_usage(cmd: &CommandDef) -> String {
    let mut out = format!("usage: qadx {} {}\n  {}\n", cmd.name, cmd.args, cmd.summary);
    if !cmd.flags.is_empty() {
        out.push_str("flags:\n");
        for f in cmd.flags {
            out.push_str(&flag_line(f));
        }
    }
    out.push_str("session flags (all commands):\n");
    // A command-level flag overrides (shadows) the session flag of the
    // same name — e.g. pilot's scale default — so show only one of them.
    for f in SESSION_FLAGS {
        if !cmd.flags.iter().any(|c| c.name == f.name) {
            out.push_str(&flag_line(f));
        }
    }
    out
}

/// The top-level help: every command with its one-line summary.
pub fn render_help() -> String {
    let mut out = String::from(
        "qadx — NVFP4 quantization-aware distillation (paper reproduction)\n\
         usage: qadx <command> [flags]\n\ncommands:\n",
    );
    for c in COMMANDS {
        let head = format!("{} {}", c.name, c.args);
        out.push_str(&format!("  {:<24} {}\n", head.trim_end(), c.summary));
    }
    out.push_str("\nsession flags (all commands):\n");
    for f in SESSION_FLAGS {
        out.push_str(&flag_line(f));
    }
    out.push_str("\nrun `qadx help <command>` for per-command flags\n");
    out
}

/// Reject flags a command does not declare, pointing at its usage text.
pub fn check_flags(cmd: &CommandDef, args: &Args) -> Result<()> {
    for key in args.flags.keys() {
        let known = cmd.flags.iter().chain(SESSION_FLAGS).any(|f| f.name == key.as_str());
        if !known {
            bail!("unknown flag --{key} for `{}`\n\n{}", cmd.name, render_usage(cmd));
        }
    }
    Ok(())
}

/// A flag value that must parse if present — a typo'd `--steps 3O0` is an
/// error, not a silent fall-back to the default.
fn parse_flag<T: std::str::FromStr>(args: &Args, key: &str, default: T) -> Result<T> {
    match args.get(key) {
        None => Ok(default),
        Some(v) => v
            .parse()
            .map_err(|_| anyhow::anyhow!("invalid value {v:?} for --{key}")),
    }
}

/// Optional `--suites a,b,c` (None = the command's per-model default).
/// Unknown suite names are an error, consistent with unknown-flag handling.
pub fn parse_suites(args: &Args) -> Result<Option<Vec<Suite>>> {
    let Some(spec) = args.get("suites") else {
        return Ok(None);
    };
    let mut suites = Vec::new();
    for name in spec.split(',').filter(|n| !n.is_empty()) {
        match Suite::from_name(name) {
            Some(s) => suites.push(s),
            None => {
                let known: Vec<&str> = crate::data::TEXT_SUITES
                    .iter()
                    .chain(crate::data::VISION_SUITES)
                    .map(|s| s.name())
                    .collect();
                bail!("unknown suite {name:?} in --suites (known: {})", known.join(", "));
            }
        }
    }
    if suites.is_empty() {
        bail!("--suites given but empty");
    }
    Ok(Some(suites))
}

/// Session construction flags shared by every command.
#[derive(Clone, Debug)]
pub struct SessionArgs {
    pub artifacts: PathBuf,
    pub runs: PathBuf,
    pub scale: f64,
    pub seed: u64,
    /// Execution backend (`--backend pjrt|reference`); None defers to
    /// `QADX_BACKEND` / the build default.
    pub backend: Option<crate::runtime::BackendKind>,
    /// Worker threads for the parallel compute core (`--threads N`);
    /// None defers to `QADX_THREADS` / available parallelism.
    pub threads: Option<usize>,
    /// Quantized GEMM kernel tier (`--kernel exact|packed`); None defers
    /// to `QADX_KERNEL` / the exact default.
    pub kernel: Option<KernelTier>,
}

impl SessionArgs {
    pub fn parse(args: &Args) -> Result<SessionArgs> {
        let threads = match args.get("threads") {
            Some(v) => match v.parse::<usize>() {
                Ok(n) if n >= 1 => Some(n),
                _ => bail!("invalid value {v:?} for --threads (need a positive integer)"),
            },
            None => None,
        };
        let kernel = match args.get("kernel") {
            Some(v) => Some(KernelTier::parse(v)?),
            None => None,
        };
        Ok(SessionArgs {
            artifacts: PathBuf::from(args.get_or("artifacts", "artifacts")),
            runs: PathBuf::from(args.get_or("runs", "runs")),
            scale: parse_flag(args, "scale", 1.0)?,
            seed: parse_flag(args, "seed", 0)?,
            backend: match args.get("backend") {
                Some(v) => Some(crate::runtime::BackendKind::parse(v)?),
                None => None,
            },
            threads,
            kernel,
        })
    }

    pub fn builder(&self) -> SessionBuilder {
        let mut b = Session::builder()
            .artifacts_dir(&self.artifacts)
            .runs_dir(&self.runs)
            .scale(self.scale)
            .seed(self.seed);
        if let Some(kind) = self.backend {
            b = b.backend(kind);
        }
        if let Some(n) = self.threads {
            b = b.threads(n);
        }
        if let Some(t) = self.kernel {
            b = b.kernel(t);
        }
        b
    }

    pub fn build(&self) -> Result<Session> {
        self.builder().build()
    }
}

/// `qadx recover` flags as a typed config.
#[derive(Debug)]
pub struct RecoverArgs {
    pub session: SessionArgs,
    pub model: String,
    pub method: MethodRef,
    pub lr: f64,
    pub steps: usize,
    pub suites: Option<Vec<Suite>>,
}

impl RecoverArgs {
    pub fn parse(args: &Args) -> Result<RecoverArgs> {
        Ok(RecoverArgs {
            session: SessionArgs::parse(args)?,
            model: args.positional.get(1).cloned().unwrap_or_else(|| "ace-sim".into()),
            method: args.get_or("method", "qad").parse()?,
            lr: parse_flag(args, "lr", 1e-4)?,
            steps: parse_flag(args, "steps", 300)?,
            suites: parse_suites(args)?,
        })
    }
}

/// `qadx eval` flags as a typed config. The checkpoint path is derived
/// from `method` (the parsed method), fixing the old inconsistency where
/// the method defaulted to bf16 but the path to qad.
#[derive(Debug)]
pub struct EvalArgs {
    pub session: SessionArgs,
    pub model: String,
    pub method: MethodRef,
    pub n: usize,
    pub k: usize,
    pub suites: Option<Vec<Suite>>,
}

impl EvalArgs {
    pub fn parse(args: &Args) -> Result<EvalArgs> {
        let ecfg = EvalCfg::default();
        Ok(EvalArgs {
            session: SessionArgs::parse(args)?,
            model: args.positional.get(1).cloned().unwrap_or_else(|| "ace-sim".into()),
            method: args.get_or("method", "bf16").parse()?,
            n: parse_flag(args, "n", ecfg.n_problems)?,
            k: parse_flag(args, "k", ecfg.k_runs)?,
            suites: parse_suites(args)?,
        })
    }
}

/// `qadx pilot` flags as a typed config (default scale 0.3).
#[derive(Debug)]
pub struct PilotArgs {
    pub session: SessionArgs,
    pub model: String,
    pub n: usize,
    pub k: usize,
    pub lr: f64,
    pub steps: usize,
    pub suites: Option<Vec<Suite>>,
}

impl PilotArgs {
    pub fn parse(args: &Args) -> Result<PilotArgs> {
        let mut session = SessionArgs::parse(args)?;
        session.scale = parse_flag(args, "scale", 0.3)?;
        Ok(PilotArgs {
            session,
            model: args.get_or("model", "ace-sim"),
            n: parse_flag(args, "n", 24)?,
            k: parse_flag(args, "k", 2)?,
            lr: parse_flag(args, "lr", 1e-4)?,
            steps: parse_flag(args, "steps", 200)?,
            suites: parse_suites(args)?,
        })
    }
}

/// `qadx serve-bench` flags as a typed config.
#[derive(Clone, Debug)]
pub struct ServeBenchArgs {
    pub session: SessionArgs,
    pub model: String,
    pub requests: usize,
    pub fwd_keys: Vec<String>,
    /// Scheduler selection (`--decode auto|step|full`).
    pub decode: DecodeMode,
    /// Continuous in-flight slot width (`--slots`, 0 = model batch).
    pub slots: usize,
    pub max_delay_ms: f64,
    pub max_new: usize,
    pub telemetry: Option<PathBuf>,
    /// `--fleet`: route requests through a multi-worker fleet instead of
    /// one `ServeHandle`.
    pub fleet: bool,
    pub workers: usize,
    /// Open-loop arrival rate in req/s (0 = closed loop).
    pub arrival_rate: f64,
    pub queue_cap: usize,
    pub deadline_ms: Option<f64>,
    /// Decode-state page size in positions (`--page-size`, 0 = dense).
    pub page_size: usize,
    /// Shared-prefix cache entries (`--prefix-cache`, 0 = off).
    pub prefix_cache: usize,
    /// Fraction of requests submitted as interactive (`--class-mix`,
    /// 1.0 = all interactive, the legacy single-class behavior).
    pub class_mix: f64,
    /// Simulated per-token consumer stall in ms (`--consumer-delay-ms`,
    /// 0 = consume instantly). Exercises the slow-consumer policy.
    pub consumer_delay_ms: f64,
    /// Policy when a stream consumer falls behind
    /// (`--slow-consumer block|drop-oldest|disconnect`).
    pub slow_consumer: crate::util::stream::SlowConsumer,
}

impl ServeBenchArgs {
    pub fn parse(args: &Args) -> Result<ServeBenchArgs> {
        let fwd_keys = match args.get_or("fwd", "both").as_str() {
            "both" => vec!["fwd_bf16".to_string(), "fwd_nvfp4".to_string()],
            "bf16" => vec!["fwd_bf16".to_string()],
            "nvfp4" => vec!["fwd_nvfp4".to_string()],
            other => bail!("--fwd must be both|bf16|nvfp4, got {other:?}"),
        };
        let workers = parse_flag(args, "workers", 2usize)?;
        if workers == 0 {
            bail!("--workers must be >= 1");
        }
        let class_mix = parse_flag(args, "class-mix", 1.0f64)?;
        if !(0.0..=1.0).contains(&class_mix) {
            bail!("--class-mix must be in [0, 1], got {class_mix}");
        }
        let consumer_delay_ms = parse_flag(args, "consumer-delay-ms", 0.0f64)?;
        if !consumer_delay_ms.is_finite() || consumer_delay_ms < 0.0 {
            bail!("--consumer-delay-ms must be >= 0, got {consumer_delay_ms}");
        }
        let slow_consumer = match args.get_or("slow-consumer", "block").as_str() {
            "block" => crate::util::stream::SlowConsumer::default(),
            "drop-oldest" => crate::util::stream::SlowConsumer::DropOldest,
            "disconnect" => crate::util::stream::SlowConsumer::Disconnect,
            other => bail!("--slow-consumer must be block|drop-oldest|disconnect, got {other:?}"),
        };
        Ok(ServeBenchArgs {
            session: SessionArgs::parse(args)?,
            model: args.get_or("model", "ace-sim"),
            requests: parse_flag(args, "requests", 64)?,
            fwd_keys,
            decode: parse_flag(args, "decode", DecodeMode::Auto)?,
            slots: parse_flag(args, "slots", 0)?,
            max_delay_ms: parse_flag(args, "max-delay-ms", 25.0)?,
            max_new: parse_flag(args, "max-new", 12)?,
            telemetry: args.get("telemetry").map(PathBuf::from),
            fleet: args.bool("fleet"),
            workers,
            arrival_rate: parse_flag(args, "arrival-rate", 0.0)?,
            queue_cap: parse_flag(args, "queue-cap", 0usize)?,
            deadline_ms: match args.get("deadline-ms") {
                Some(v) => Some(
                    v.parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("invalid value {v:?} for --deadline-ms"))?,
                ),
                None => None,
            },
            page_size: parse_flag(args, "page-size", 32usize)?,
            prefix_cache: parse_flag(args, "prefix-cache", 0usize)?,
            class_mix,
            consumer_delay_ms,
            slow_consumer,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn every_command_renders_usage() {
        for cmd in COMMANDS {
            assert!(!cmd.summary.is_empty());
            let usage = render_usage(cmd);
            assert!(usage.contains(cmd.name), "{usage}");
            assert!(usage.contains("--artifacts"), "{usage}");
        }
        let help = render_help();
        for cmd in COMMANDS {
            assert!(help.contains(cmd.name));
        }
        assert!(!help.contains("see rust/src/main.rs"));
    }

    #[test]
    fn unknown_flags_are_rejected_with_usage() {
        let cmd = find_command("recover").unwrap();
        assert!(check_flags(cmd, &parse("recover ace-sim --method qad --scale 0.5")).is_ok());
        let err = check_flags(cmd, &parse("recover ace-sim --metod qad")).unwrap_err().to_string();
        assert!(err.contains("--metod") && err.contains("usage: qadx recover"), "{err}");
    }

    #[test]
    fn eval_checkpoint_follows_parsed_method() {
        // Old bug: `--method` defaulted to bf16 while the checkpoint path
        // was built from the raw flag string with a *qad* default.
        let e = EvalArgs::parse(&parse("eval ace-sim")).unwrap();
        assert_eq!(e.method.name(), "bf16");
        assert!(e.method.step_key().is_none()); // teacher weights, no ckpt
        let e = EvalArgs::parse(&parse("eval ace-sim --method qat")).unwrap();
        assert_eq!(e.method.name(), "qat");
        let p = super::super::session::recovered_path(&e.session.runs, &e.model, e.method.name());
        assert!(p.to_string_lossy().ends_with("ace-sim-qat.qckp"), "{p:?}");
    }

    #[test]
    fn recover_args_parse_method_and_suites() {
        let argv = parse("recover nano-sim --method mse --steps 50 --suites math500,aime");
        let r = RecoverArgs::parse(&argv).unwrap();
        assert_eq!(r.model, "nano-sim");
        assert_eq!(r.method.name(), "mse");
        assert_eq!(r.steps, 50);
        assert_eq!(r.suites.as_ref().map(|s| s.len()), Some(2));
        assert!(RecoverArgs::parse(&parse("recover x --method nope")).is_err());
    }

    #[test]
    fn threads_flag_parses_and_rejects_garbage() {
        let s = SessionArgs::parse(&parse("info")).unwrap();
        assert_eq!(s.threads, None);
        let s = SessionArgs::parse(&parse("info --threads 4")).unwrap();
        assert_eq!(s.threads, Some(4));
        assert!(SessionArgs::parse(&parse("info --threads 0")).is_err());
        assert!(SessionArgs::parse(&parse("info --threads many")).is_err());
    }

    #[test]
    fn kernel_flag_parses_tiers_and_rejects_garbage() {
        let s = SessionArgs::parse(&parse("info")).unwrap();
        assert_eq!(s.kernel, None);
        let s = SessionArgs::parse(&parse("info --kernel packed")).unwrap();
        assert_eq!(s.kernel, Some(KernelTier::Packed));
        let s = SessionArgs::parse(&parse("info --kernel exact")).unwrap();
        assert_eq!(s.kernel, Some(KernelTier::Exact));
        assert!(SessionArgs::parse(&parse("info --kernel turbo")).is_err());
    }

    #[test]
    fn flag_value_typos_are_errors_not_silent_defaults() {
        assert!(RecoverArgs::parse(&parse("recover x --steps 3O0")).is_err());
        assert!(EvalArgs::parse(&parse("eval x --n twelve")).is_err());
        assert!(SessionArgs::parse(&parse("info --seed abc")).is_err());
        // absent flags still take the documented defaults
        let r = RecoverArgs::parse(&parse("recover x")).unwrap();
        assert_eq!(r.steps, 300);
        assert_eq!(r.session.seed, 0);
    }

    #[test]
    fn suite_typos_are_errors_not_silent_fallbacks() {
        let err = parse_suites(&parse("eval x --suites mth500")).unwrap_err().to_string();
        assert!(err.contains("mth500") && err.contains("math500"), "{err}");
        assert!(parse_suites(&parse("eval x --suites ,")).is_err());
        assert_eq!(parse_suites(&parse("eval x")).unwrap(), None);
    }

    #[test]
    fn pilot_usage_shows_its_own_scale_default() {
        let usage = render_usage(find_command("pilot").unwrap());
        assert!(usage.contains("0.3"), "{usage}");
        // the shadowed session-level scale line (default 1.0) is hidden
        assert_eq!(usage.matches("--scale").count(), 1, "{usage}");
    }

    #[test]
    fn serve_bench_fwd_selection() {
        let s = ServeBenchArgs::parse(&parse("serve-bench --requests 10")).unwrap();
        assert_eq!(s.fwd_keys, vec!["fwd_bf16", "fwd_nvfp4"]);
        let s = ServeBenchArgs::parse(&parse("serve-bench --fwd nvfp4")).unwrap();
        assert_eq!(s.fwd_keys, vec!["fwd_nvfp4"]);
        assert!(ServeBenchArgs::parse(&parse("serve-bench --fwd tf32")).is_err());
    }

    #[test]
    fn serve_bench_decode_and_slots_flags() {
        let s = ServeBenchArgs::parse(&parse("serve-bench")).unwrap();
        assert_eq!(s.decode, DecodeMode::Auto);
        assert_eq!(s.slots, 0);
        let s = ServeBenchArgs::parse(&parse("serve-bench --decode step --slots 6")).unwrap();
        assert_eq!(s.decode, DecodeMode::Step);
        assert_eq!(s.slots, 6);
        let s = ServeBenchArgs::parse(&parse("serve-bench --decode full")).unwrap();
        assert_eq!(s.decode, DecodeMode::Full);
        // typo'd values are errors, not silent defaults
        assert!(ServeBenchArgs::parse(&parse("serve-bench --decode fast")).is_err());
        assert!(ServeBenchArgs::parse(&parse("serve-bench --slots many")).is_err());
        // the flags are declared, so the unknown-flag gate accepts them
        let cmd = find_command("serve-bench").unwrap();
        assert!(check_flags(cmd, &parse("serve-bench --decode step --slots 2")).is_ok());
        assert!(render_usage(cmd).contains("--decode"), "usage must list --decode");
    }

    #[test]
    fn serve_bench_fleet_flags() {
        let s = ServeBenchArgs::parse(&parse("serve-bench")).unwrap();
        assert!(!s.fleet);
        assert_eq!(s.workers, 2);
        assert_eq!(s.arrival_rate, 0.0);
        assert_eq!(s.queue_cap, 0);
        assert_eq!(s.deadline_ms, None);
        let s = ServeBenchArgs::parse(&parse(
            "serve-bench --fleet --workers 3 --arrival-rate 50 --queue-cap 8 --deadline-ms 250",
        ))
        .unwrap();
        assert!(s.fleet);
        assert_eq!(s.workers, 3);
        assert_eq!(s.arrival_rate, 50.0);
        assert_eq!(s.queue_cap, 8);
        assert_eq!(s.deadline_ms, Some(250.0));
        // zero workers and typo'd values are errors, not silent defaults
        assert!(ServeBenchArgs::parse(&parse("serve-bench --workers 0")).is_err());
        assert!(ServeBenchArgs::parse(&parse("serve-bench --arrival-rate fast")).is_err());
        assert!(ServeBenchArgs::parse(&parse("serve-bench --deadline-ms soon")).is_err());
        let cmd = find_command("serve-bench").unwrap();
        assert!(check_flags(cmd, &parse("serve-bench --fleet --workers 4")).is_ok());
        assert!(render_usage(cmd).contains("--fleet"), "usage must list --fleet");
    }

    #[test]
    fn serve_bench_overload_flags() {
        use crate::util::stream::SlowConsumer;
        let s = ServeBenchArgs::parse(&parse("serve-bench")).unwrap();
        assert_eq!(s.class_mix, 1.0, "all-interactive is the legacy default");
        assert_eq!(s.consumer_delay_ms, 0.0);
        assert!(matches!(s.slow_consumer, SlowConsumer::Block { .. }));
        let s = ServeBenchArgs::parse(&parse(
            "serve-bench --class-mix 0.25 --consumer-delay-ms 5 --slow-consumer drop-oldest",
        ))
        .unwrap();
        assert_eq!(s.class_mix, 0.25);
        assert_eq!(s.consumer_delay_ms, 5.0);
        assert!(matches!(s.slow_consumer, SlowConsumer::DropOldest));
        let s = ServeBenchArgs::parse(&parse("serve-bench --slow-consumer disconnect")).unwrap();
        assert!(matches!(s.slow_consumer, SlowConsumer::Disconnect));
        // out-of-range and typo'd values are errors, not silent defaults
        assert!(ServeBenchArgs::parse(&parse("serve-bench --class-mix 1.5")).is_err());
        assert!(ServeBenchArgs::parse(&parse("serve-bench --class-mix half")).is_err());
        assert!(ServeBenchArgs::parse(&parse("serve-bench --consumer-delay-ms -3")).is_err());
        assert!(ServeBenchArgs::parse(&parse("serve-bench --slow-consumer fastest")).is_err());
        let cmd = find_command("serve-bench").unwrap();
        assert!(check_flags(cmd, &parse("serve-bench --class-mix 0.5 --slow-consumer block"))
            .is_ok());
        assert!(render_usage(cmd).contains("--class-mix"), "usage must list --class-mix");
        assert!(render_usage(cmd).contains("--slow-consumer"), "usage must list --slow-consumer");
    }

    #[test]
    fn serve_bench_paged_decode_flags() {
        let s = ServeBenchArgs::parse(&parse("serve-bench")).unwrap();
        assert_eq!(s.page_size, 32, "paged decode state is the default");
        assert_eq!(s.prefix_cache, 0, "prefix cache is opt-in");
        let s = ServeBenchArgs::parse(&parse(
            "serve-bench --page-size 16 --prefix-cache 8",
        ))
        .unwrap();
        assert_eq!(s.page_size, 16);
        assert_eq!(s.prefix_cache, 8);
        let s = ServeBenchArgs::parse(&parse("serve-bench --page-size 0")).unwrap();
        assert_eq!(s.page_size, 0, "0 selects dense per-slot rows");
        // typo'd values are errors, not silent defaults
        assert!(ServeBenchArgs::parse(&parse("serve-bench --page-size big")).is_err());
        assert!(ServeBenchArgs::parse(&parse("serve-bench --prefix-cache lots")).is_err());
        let cmd = find_command("serve-bench").unwrap();
        assert!(check_flags(cmd, &parse("serve-bench --page-size 16 --prefix-cache 4")).is_ok());
        assert!(render_usage(cmd).contains("--page-size"), "usage must list --page-size");
        assert!(render_usage(cmd).contains("--prefix-cache"), "usage must list --prefix-cache");
    }
}
