//! Optional JSONL telemetry: an append-only event log for serving and
//! compile metrics. Opt-in via `ServeCfg::telemetry` or the
//! `QADX_TELEMETRY_JSONL` environment variable; when unset, nothing is
//! written and the hot path pays only an `Option` check.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::json::Json;

/// Append-only JSONL writer (one compact JSON object per line).
pub struct JsonlAppender {
    file: std::fs::File,
    pub path: PathBuf,
}

impl JsonlAppender {
    pub fn open(path: &Path) -> Result<JsonlAppender> {
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir)?;
            }
        }
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(JsonlAppender { file, path: path.to_path_buf() })
    }

    /// Open from an environment variable holding a path; None when the
    /// variable is unset or the file cannot be opened (telemetry must
    /// never take down the serving path).
    pub fn from_env(var: &str) -> Option<JsonlAppender> {
        std::env::var(var).ok().and_then(|p| JsonlAppender::open(Path::new(&p)).ok())
    }

    pub fn append(&mut self, record: &Json) -> Result<()> {
        writeln!(self.file, "{}", record.to_string())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn appends_one_object_per_line() {
        let dir = std::env::temp_dir().join("qadx_telemetry_test");
        let path = dir.join("events.jsonl");
        std::fs::remove_file(&path).ok();
        {
            let mut app = JsonlAppender::open(&path).unwrap();
            app.append(&Json::obj(vec![("event", Json::Str("a".into()))])).unwrap();
            app.append(&Json::obj(vec![("event", Json::Str("b".into()))])).unwrap();
        }
        // re-open appends rather than truncating
        let mut app = JsonlAppender::open(&path).unwrap();
        app.append(&Json::obj(vec![("event", Json::Str("c".into()))])).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        for line in lines {
            assert!(Json::parse(line).is_ok());
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
