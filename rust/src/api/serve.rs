//! Serving façade over one fwd artifact, in one of two scheduling modes:
//!
//! * **Continuous batching** (default when the backend advertises the
//!   stateful-decode capability): a fixed-width set of in-flight slots
//!   over one [`DecodeSession`]. A submitted request is prefilled into a
//!   free slot immediately (its first token — TTFT — is sampled right
//!   there); each decode round then steps every live slot by one token,
//!   and a slot freed by EOS/length is refilled from the queue *mid
//!   generation* — a request arriving one step after others start waits
//!   one round, not a whole generation. Rows are independent by the
//!   decode-session contract, so admissions never perturb in-flight rows.
//! * **Batch coalescing** (fallback, and `decode = full`): the legacy
//!   run-to-completion path — requests queue until `model.batch` rows
//!   coalesce (or the oldest waits past a deadline), then one
//!   `Sampler::generate` runs the whole batch.
//!
//! Per-request telemetry (TTFT, inter-token gaps, latency) and per-round
//! slot occupancy land in [`ServeStats`] and, optionally, a JSONL event
//! log. The runtime is single-threaded (device buffers are not Send), so
//! the queue is synchronous: `submit` admits/flushes inline, `poll` runs
//! one decode round (or applies the coalescing deadline), `drain` runs
//! everything out.
//!
//! **SLO classes + priority lanes.** Every request carries a
//! [`RequestClass`] (`submit` defaults to `Interactive`;
//! [`ServeHandle::submit_class`] is explicit). The continuous scheduler
//! keeps one queue lane per class: interactive work dispatches first,
//! bounded by a hard starvation bound — after `starvation_bound`
//! consecutive interactive admissions while batch work waits, the oldest
//! batch request bypasses. Under a saturated queue an interactive
//! submission evicts the youngest queued batch request (degraded, not
//! silently lost) instead of being shed alongside it. Per-class SLO
//! accounting (TTFT/latency windows, shed/evicted/expired counts, a
//! deadline-hit rate) lands in [`ClassStats`].
//!
//! **Backpressure-aware streaming.** With `stream_buf > 0` (the default)
//! generated tokens flow through a bounded per-request channel
//! (`util::stream`) and the sink/JSONL consumer is fed *outside* the
//! decode loop; the [`SlowConsumer`] policy decides what happens when a
//! consumer cannot keep up (block with deadline / drop oldest /
//! disconnect), so one stalled consumer can never stall a step round or
//! its slot-mates. Determinism is preserved throughout: each request
//! samples from its own RNG stream keyed on `(sample.seed, id)` only
//! ([`request_rng`]), so rows are bit-identical regardless of lane
//! order, eviction, requeue, or consumer speed.

use std::collections::{BTreeMap, VecDeque};
use std::rc::Rc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::data::tokenizer as tok;
use crate::eval::{sample_token_with, DecodeMode, SampleCfg, SampleScratch, Sampler};
use crate::quant::KernelTier;
use crate::runtime::{Buffer, DecodeOpts, DecodeSession, Engine, ModelRuntime};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stream::{bounded, BoundedRx, BoundedTx, SlowConsumer};
use crate::util::StatsWindow;

use super::telemetry::JsonlAppender;

/// SplitMix64 golden-ratio constant, used to decorrelate derived seeds.
pub(crate) const SEED_MIX: u64 = 0x9E37_79B9_7F4A_7C15;
/// Domain tag for the per-request sampling stream.
const TAG_REQUEST: u64 = 0x517c_c1b7_2722_0a95;

/// The per-request sampling stream: a function of the sample seed and
/// the request id **only**. Lane, slot index, worker index, eviction,
/// requeue, and retry attempt deliberately do not enter — this is the
/// determinism oracle that keeps a retried/reordered generation
/// bit-identical to the same request in an undisturbed run.
pub fn request_rng(sample_seed: u64, id: u64) -> Rng {
    Rng::new(sample_seed ^ id.wrapping_mul(SEED_MIX) ^ TAG_REQUEST)
}

/// SLO class carried on submit: which lane a request queues in and which
/// admission rules apply to it under pressure. The set is small by
/// design — policies key off the lane, so adding a class means adding a
/// lane, not rewriting the scheduler.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum RequestClass {
    /// Latency-sensitive traffic: dispatches ahead of `Batch` (bounded by
    /// the starvation bound) and may evict queued batch work instead of
    /// being shed when the queue saturates.
    #[default]
    Interactive,
    /// Throughput traffic: absorbs shed/eviction first under overload.
    Batch,
}

impl RequestClass {
    pub const ALL: [RequestClass; 2] = [RequestClass::Interactive, RequestClass::Batch];

    /// Telemetry/JSONL label.
    pub fn label(self) -> &'static str {
        match self {
            RequestClass::Interactive => "interactive",
            RequestClass::Batch => "batch",
        }
    }

    /// Compact label for summary lines.
    pub fn short(self) -> &'static str {
        match self {
            RequestClass::Interactive => "int",
            RequestClass::Batch => "bat",
        }
    }
}

/// Pure lane-selection policy shared by the serve scheduler and the
/// fleet router: should the next dispatch take from the **batch** lane?
///
/// * `bound == 0` disables the lanes: strict submission order (request
///   ids are monotonic, so the smaller front id is the older request).
/// * Otherwise interactive goes first, except that once
///   `since_bypass >= bound` consecutive interactive dispatches have run
///   while batch work waited, the oldest batch request bypasses — the
///   hard starvation bound.
pub fn take_batch_lane(
    int_front: Option<u64>,
    bat_front: Option<u64>,
    bound: usize,
    since_bypass: usize,
) -> bool {
    match (int_front, bat_front) {
        (_, None) => false,
        (None, Some(_)) => true,
        (Some(i), Some(b)) => {
            if bound == 0 {
                b < i
            } else {
                since_bypass >= bound
            }
        }
    }
}

/// Per-class retry-after estimate for a [`Saturated`] rejection (pure so
/// both serve and fleet unit-test it): the backlog a new request of this
/// class must wait out, times that class's per-request service estimate.
/// Interactive work waits only on the interactive lane (batch gets ahead
/// of it only via the bounded bypass); batch work waits on both lanes.
pub fn class_retry_hint(
    class: RequestClass,
    int_depth: usize,
    bat_depth: usize,
    in_flight: usize,
    class_est_ms: f64,
    fallback_est_ms: f64,
    floor_ms: f64,
) -> f64 {
    let ahead = match class {
        RequestClass::Interactive => int_depth + in_flight,
        RequestClass::Batch => int_depth + bat_depth + in_flight,
    };
    let per_req = if class_est_ms > 0.0 { class_est_ms } else { fallback_est_ms };
    (ahead as f64 * per_req).max(floor_ms).max(1.0)
}

/// Typed admission-control rejection: the submission queue is at capacity
/// (or the request's deadline cannot be met given the present backlog).
/// Carried through `anyhow::Error`; recover it with
/// `err.downcast_ref::<Saturated>()` and resubmit after the hint.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Saturated {
    /// Backpressure hint: estimated milliseconds until a slot frees up
    /// (queue depth x estimated per-request service time).
    pub retry_after_ms: f64,
}

impl std::fmt::Display for Saturated {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "saturated: retry after {:.1} ms", self.retry_after_ms)
    }
}

impl std::error::Error for Saturated {}

/// One generated token surfaced as it lands (continuous mode only — the
/// coalescing fallback has no per-token visibility).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TokenEvent {
    /// Request id (matches `ServeResponse::id` / `FleetResponse::id`).
    pub id: u64,
    pub token: i32,
    /// Generated-token index within the request, counting from 0 (the
    /// TTFT token).
    pub index: usize,
    /// Worker index the token was generated on (fleet; 0 for a single
    /// `ServeHandle`).
    pub worker: usize,
    /// Delivery attempt the token belongs to (fleet retries re-run a
    /// request from scratch; 0 for `ServeHandle`).
    pub attempt: u32,
}

/// Shared per-token callback. Wrapped in `Rc` so `ServeCfg`/`FleetCfg`
/// stay `Clone`; the sink runs inside the decode loop and must not call
/// back into the handle that invoked it.
#[derive(Clone)]
pub struct TokenSink(pub Rc<dyn Fn(&TokenEvent)>);

impl TokenSink {
    /// Wrap a plain closure.
    pub fn new(f: impl Fn(&TokenEvent) + 'static) -> TokenSink {
        TokenSink(Rc::new(f))
    }
}

impl std::fmt::Debug for TokenSink {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("TokenSink(..)")
    }
}

/// Where a server's weights come from (resolved by `ModelSession::server`).
#[derive(Clone, Debug)]
pub enum ServeWeights {
    /// Fresh random init (throughput benchmarking — accuracy irrelevant).
    Random { seed: u64 },
    /// The model's cached/trained BF16 teacher.
    Teacher,
    /// A recovered checkpoint by method name (e.g. "qad").
    Method(String),
    /// An explicit parameter vector.
    Params(Vec<f32>),
}

#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub sample: SampleCfg,
    pub weights: ServeWeights,
    /// Coalescing mode only: flush a partial batch once its oldest
    /// request has waited this long (continuous admission is immediate).
    pub max_batch_delay_ms: f64,
    /// Scheduling: `Auto` = continuous batching when the backend has
    /// stateful decode, else coalescing; `Step` = require continuous;
    /// `Full` = force the legacy coalescing path.
    pub decode: DecodeMode,
    /// Continuous mode: in-flight slot width (0 = `model.batch`). Unlike
    /// the coalescing path, the width is not bound by the artifact batch.
    pub max_slots: usize,
    /// Run one warm-up generation so compile/first-execute cost does not
    /// land on the first real request.
    pub warmup: bool,
    /// Admission control: `submit` past this many queued (not yet
    /// admitted/dispatched) requests returns the typed [`Saturated`]
    /// error instead of growing the queue without bound. 0 = unbounded
    /// (the pre-existing behavior).
    pub max_queue: usize,
    /// JSONL event log path; falls back to `QADX_TELEMETRY_JSONL`.
    pub telemetry: Option<std::path::PathBuf>,
    /// Continuous mode: decode-state page size in positions (0 = dense
    /// per-slot rows). Paged state bounds K/V memory by live tokens
    /// instead of `slots x seq_len` and is bit-identical to dense, so it
    /// is on by default.
    pub page_size: usize,
    /// Continuous mode: shared-prefix cache capacity in entries (0 = off;
    /// requires `page_size > 0`). Prompts sharing a cached prefix reuse
    /// its prefilled pages copy-on-write and skip the redundant prefill.
    pub prefix_cache: usize,
    /// Continuous mode: page budget across live slots + cached prefixes
    /// (0 = unbounded). Admission evicts cached prefixes before failing.
    pub max_pages: usize,
    /// Append per-token `token` events to the telemetry JSONL as tokens
    /// are generated (continuous mode).
    pub stream: bool,
    /// Per-token callback invoked as each token lands (the TTFT token is
    /// index 0).
    pub on_token: Option<TokenSink>,
    /// Priority lanes: hard starvation bound — after this many
    /// consecutive interactive admissions while batch work waits, the
    /// oldest batch request bypasses. 0 disables the lanes entirely
    /// (strict submission-order dispatch, no batch eviction).
    pub starvation_bound: usize,
    /// Streaming: bounded per-request token-channel capacity. 0 restores
    /// the legacy synchronous sink/JSONL call inside the decode loop.
    pub stream_buf: usize,
    /// Streaming: what happens when a consumer cannot keep up with the
    /// bounded channel (ignored when `stream_buf == 0`).
    pub slow_consumer: SlowConsumer,
    /// Quantized GEMM kernel tier for the decode session (None defers to
    /// the process-global `set_kernel` / `QADX_KERNEL` / exact chain).
    /// `Packed` computes decode GEMMs on the packed 4-bit codes instead
    /// of re-materialized fake-quantized f32 weights.
    pub kernel: Option<KernelTier>,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            sample: SampleCfg::default(),
            weights: ServeWeights::Random { seed: 3 },
            max_batch_delay_ms: 25.0,
            decode: DecodeMode::Auto,
            max_slots: 0,
            warmup: true,
            max_queue: 0,
            telemetry: None,
            page_size: 32,
            prefix_cache: 0,
            max_pages: 0,
            stream: false,
            on_token: None,
            starvation_bound: 4,
            stream_buf: 64,
            slow_consumer: SlowConsumer::default(),
            kernel: None,
        }
    }
}

/// Pure batching policy for the coalescing fallback: decides *when* a set
/// of queued request ids forms a batch (full, deadline-expired, or
/// forced). Kept free of any backend so the rules are unit-testable.
pub struct Coalescer {
    batch: usize,
    max_delay: Duration,
    queue: VecDeque<(u64, Instant)>,
}

impl Coalescer {
    pub fn new(batch: usize, max_delay: Duration) -> Coalescer {
        assert!(batch >= 1, "batch must be >= 1");
        Coalescer { batch, max_delay, queue: VecDeque::new() }
    }

    pub fn push(&mut self, id: u64, now: Instant) {
        // qadx-lint: allow(unbounded-growth) -- callers gate on ServeHandle::submit_class's max_queue admission check
        self.queue.push_back((id, now));
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Take the next batch if one is ready: a full batch always; a partial
    /// batch when forced or when the oldest entry has waited `max_delay`.
    pub fn take_ready(&mut self, now: Instant, force: bool) -> Option<Vec<u64>> {
        let oldest = self.queue.front()?.1;
        let full = self.queue.len() >= self.batch;
        let expired = now.duration_since(oldest) >= self.max_delay;
        if !(full || expired || force) {
            return None;
        }
        let n = self.queue.len().min(self.batch);
        Some(self.queue.drain(..n).map(|(id, _)| id).collect())
    }
}

/// One completed request.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub id: u64,
    /// Full token row (prompt + completion, PAD-tailed).
    pub row: Vec<i32>,
    pub gen_tokens: usize,
    /// Submit-to-complete latency (includes queueing delay).
    pub latency_ms: f64,
    /// Submit-to-first-generated-token. In the coalescing fallback tokens
    /// only surface when the whole batch completes, so there it equals
    /// `latency_ms`.
    pub ttft_ms: f64,
    /// Set when this request degraded instead of completing: a failed
    /// prefill/step ends the one request (row = prompt so far, no further
    /// tokens) without taking down the scheduler or its slot-mates.
    pub error: Option<String>,
}

/// Per-class SLO slice: whether a lane is meeting its objective
/// (TTFT/latency windows, deadline-hit rate) and what overload cost it
/// absorbed (shed / evicted / expired).
#[derive(Clone, Debug, Default)]
pub struct ClassStats {
    /// Requests resolved under this class (completed or degraded).
    pub requests: usize,
    pub gen_tokens: usize,
    /// Submissions rejected with [`Saturated`].
    pub shed: usize,
    /// Queued requests evicted (degraded) by higher-priority admission.
    pub evicted: usize,
    /// Requests that ran out their deadline while still queued (fleet).
    pub expired: usize,
    /// Resolutions inside / outside the configured deadline. Tracked only
    /// when a deadline exists; queue expiries count as misses.
    pub deadline_hits: usize,
    pub deadline_misses: usize,
    /// EWMA of observed per-request execute time — the per-class service
    /// estimate behind [`Saturated::retry_after_ms`].
    pub exec_ewma_ms: f64,
    pub ttft_ms: StatsWindow,
    pub latencies_ms: StatsWindow,
}

impl ClassStats {
    /// Fraction of deadline-tracked resolutions that met the deadline;
    /// 1.0 when nothing was tracked (no deadline configured).
    pub fn deadline_hit_rate(&self) -> f64 {
        let total = self.deadline_hits + self.deadline_misses;
        if total == 0 {
            1.0
        } else {
            self.deadline_hits as f64 / total as f64
        }
    }

    /// Fold one observed execute time into the per-class service EWMA.
    pub(crate) fn observe_exec(&mut self, ms: f64) {
        if !ms.is_finite() || ms < 0.0 {
            return;
        }
        self.exec_ewma_ms =
            if self.exec_ewma_ms <= 0.0 { ms } else { 0.9 * self.exec_ewma_ms + 0.1 * ms };
    }

    /// Compact summary clause; empty when the class saw no traffic.
    pub(crate) fn brief(&self, label: &str) -> String {
        if self.requests + self.shed + self.evicted + self.expired == 0 {
            return String::new();
        }
        format!(
            " | {label} {} ttft p99 {:.0}ms shed {} evict {} expire {} hit {:.2}",
            self.requests,
            self.ttft_ms.percentile(99.0),
            self.shed,
            self.evicted,
            self.expired,
            self.deadline_hit_rate()
        )
    }
}

/// The per-class stat slices, one per [`RequestClass`] lane. Named fields
/// instead of an array so hot paths never index.
#[derive(Clone, Debug, Default)]
pub struct ClassPair {
    pub interactive: ClassStats,
    pub batch: ClassStats,
}

impl ClassPair {
    pub fn get(&self, class: RequestClass) -> &ClassStats {
        match class {
            RequestClass::Interactive => &self.interactive,
            RequestClass::Batch => &self.batch,
        }
    }

    pub fn get_mut(&mut self, class: RequestClass) -> &mut ClassStats {
        match class {
            RequestClass::Interactive => &mut self.interactive,
            RequestClass::Batch => &mut self.batch,
        }
    }

    /// Summary clauses for both classes (empty for idle classes).
    pub(crate) fn brief(&self) -> String {
        let mut s = self.interactive.brief(RequestClass::Interactive.short());
        s.push_str(&self.batch.brief(RequestClass::Batch.short()));
        s
    }
}

/// Aggregate serving counters for one handle.
///
/// Per-sample series are bounded sliding windows (`StatsWindow`): exact
/// lifetime counts/means stay in scalars while percentiles come from the
/// most recent samples — a long-running server's stats stay O(window),
/// not O(requests).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub fwd_key: String,
    /// Artifact compile + warm-up time paid at construction.
    pub compile_ms: f64,
    pub requests: usize,
    pub batches: usize,
    pub gen_tokens: usize,
    pub latencies_ms: StatsWindow,
    /// Per-batch occupancy (submitted rows / model batch size) — the
    /// coalescing path's fill metric.
    pub fill_ratios: StatsWindow,
    /// Per-request time spent queued before its batch/slot launched — the
    /// scheduling cost. latency ≈ queue wait + execute.
    pub queue_wait_ms: StatsWindow,
    /// Per-request time from admission to completion (coalescing: the
    /// generation call that served it) — the compute cost.
    pub execute_ms: StatsWindow,
    /// Per-request submit → first generated token. Continuous mode
    /// measures the true first-token time (prefill + one sample); the
    /// coalescing fallback can only observe batch completion, so there it
    /// equals the request latency.
    pub ttft_ms: StatsWindow,
    /// Per-token gap between consecutive emitted tokens of one request
    /// (continuous mode only).
    pub inter_token_ms: StatsWindow,
    /// Per-decode-round in-flight slots / slot width (continuous mode).
    pub slot_occupancy: StatsWindow,
    /// Requests admitted into a slot freed while other rows were still
    /// mid-generation — the continuous scheduler doing its job.
    pub mid_gen_admissions: usize,
    /// Requests that ended with `ServeResponse::error` set (a failed
    /// prefill/step degraded the one request, not the scheduler).
    pub degraded: usize,
    /// Submissions rejected with [`Saturated`] by the queue bound —
    /// backpressure doing its job, not an error path.
    pub shed: usize,
    /// Decode rounds executed by the continuous scheduler.
    pub decode_rounds: usize,
    /// Time spent inside prefill/step/generation calls.
    pub busy_secs: f64,
    /// Paged decode state (continuous mode with `page_size > 0`): the
    /// session's page size in positions; 0 when rows are dense.
    pub page_size: usize,
    /// Pages currently referenced by live slots or cached prefixes.
    pub live_pages: usize,
    /// Prompts admitted via a shared-prefix cache hit (cumulative).
    pub prefix_hits: u64,
    /// Prompts prefilled cold with the prefix cache enabled (cumulative).
    pub prefix_misses: u64,
    /// Copy-on-write page copies taken when a forked sequence diverged
    /// inside a shared page (cumulative).
    pub cow_copies: u64,
    /// Per-class SLO accounting (lanes).
    pub per_class: ClassPair,
    /// Queued batch requests evicted (degraded) by interactive admissions
    /// under a saturated queue — the middle rung of the degradation
    /// ladder (shed → evict-batch → degrade).
    pub evicted: usize,
    /// Batch requests dispatched via the starvation-bound bypass while
    /// interactive work was waiting.
    pub lane_bypasses: usize,
    /// Streaming: tokens discarded by the `DropOldest` policy or a
    /// disconnected stream.
    pub tokens_dropped: u64,
    /// Streaming: producer-side stalls on a full bounded channel under
    /// the `Block` policy.
    pub consumer_stalls: u64,
    /// Streaming: channels severed by policy (`Disconnect` overflow or a
    /// `Block` deadline timeout).
    pub streams_disconnected: u64,
    /// Bytes of bound weight storage the decode session reads per token
    /// (continuous mode): f32 copies on the exact kernel tier, packed
    /// 4-bit codes + block scales on the packed tier — the gauge that
    /// shows the packed tier's ~8x decode weight-traffic cut.
    pub decode_weight_bytes: usize,
}

impl ServeStats {
    /// Exact lifetime mean occupancy (not windowed).
    pub fn mean_fill_ratio(&self) -> f64 {
        self.fill_ratios.mean()
    }

    /// Latency percentile over the retained window.
    pub fn latency_p(&self, p: f64) -> f64 {
        self.latencies_ms.percentile(p)
    }

    pub fn req_per_sec(&self) -> f64 {
        if self.busy_secs > 0.0 {
            self.requests as f64 / self.busy_secs
        } else {
            0.0
        }
    }

    pub fn gen_tok_per_sec(&self) -> f64 {
        if self.busy_secs > 0.0 {
            self.gen_tokens as f64 / self.busy_secs
        } else {
            0.0
        }
    }

    /// One-line report: req/s, gen-tok/s, latency percentiles (with the
    /// queue-wait / execute split), TTFT, and the schedule's utilization
    /// metric — per-round slot occupancy for the continuous scheduler,
    /// batch fill ratio for the coalescing path. The single source for
    /// CLI/example output. Throughput is over *busy* time (inside
    /// generation); callers that want end-to-end throughput divide by
    /// their own wall clock.
    pub fn summary(&self) -> String {
        let shape = if self.decode_rounds > 0 {
            format!(
                "{} reqs / {} rounds (+{} mid-gen)",
                self.requests, self.decode_rounds, self.mid_gen_admissions
            )
        } else {
            format!("{} reqs / {} batches", self.requests, self.batches)
        };
        let util = if self.decode_rounds > 0 {
            format!("occ {:.2}", self.slot_occupancy.mean())
        } else {
            format!("fill {:.2}", self.mean_fill_ratio())
        };
        let paged = if self.page_size > 0 {
            format!(
                " | pages {} live (x{} pos) prefix {}/{} cow {}",
                self.live_pages,
                self.page_size,
                self.prefix_hits,
                self.prefix_hits + self.prefix_misses,
                self.cow_copies
            )
        } else {
            String::new()
        };
        let mut lanes = self.per_class.brief();
        if self.lane_bypasses > 0 {
            lanes.push_str(&format!(" | bypass {}", self.lane_bypasses));
        }
        let wbytes = if self.decode_weight_bytes > 0 {
            format!(" | w-bytes {}", self.decode_weight_bytes)
        } else {
            String::new()
        };
        let streamc = if self.tokens_dropped > 0
            || self.consumer_stalls > 0
            || self.streams_disconnected > 0
        {
            format!(
                " | stream drop {} stall {} disc {}",
                self.tokens_dropped, self.consumer_stalls, self.streams_disconnected
            )
        } else {
            String::new()
        };
        format!(
            "{:<10} {} | busy {:.1} req/s {:.0} gen-tok/s | \
             lat p50 {:.0}ms p95 {:.0}ms p99 {:.0}ms (wait p50 {:.0}ms exec p50 {:.0}ms) | \
             ttft p50 {:.0}ms | {} | compile {:.0}ms{paged}{wbytes}{lanes}{streamc}",
            self.fwd_key,
            shape,
            self.req_per_sec(),
            self.gen_tok_per_sec(),
            self.latency_p(50.0),
            self.latency_p(95.0),
            self.latency_p(99.0),
            self.queue_wait_ms.percentile(50.0),
            self.execute_ms.percentile(50.0),
            self.ttft_ms.percentile(50.0),
            util,
            self.compile_ms,
        )
    }
}

struct Pending {
    prompt: Vec<i32>,
    class: RequestClass,
    submitted: Instant,
}

/// A request waiting for a continuous-scheduler slot.
struct Queued {
    id: u64,
    prompt: Vec<i32>,
    class: RequestClass,
    submitted: Instant,
}

/// One in-flight continuous-scheduler row.
struct Slot {
    id: u64,
    class: RequestClass,
    /// Full seq_len row (prompt + generated so far, PAD tail).
    row: Vec<i32>,
    frontier: usize,
    submitted: Instant,
    admitted: Instant,
    ttft_ms: f64,
    last_token: Instant,
    gen: usize,
    /// Per-request sampling stream ([`request_rng`]): owned by the slot
    /// so admission order cannot leak into another request's tokens.
    rng: Rng,
}

enum Sched {
    /// Slot-based continuous batching over a stateful decode session.
    Continuous {
        session: Box<dyn DecodeSession>,
        slots: Vec<Option<Slot>>,
        /// Priority lanes ([`take_batch_lane`] picks between them):
        /// interactive ahead of batch, bounded by the starvation bound.
        lane_int: VecDeque<Queued>,
        lane_bat: VecDeque<Queued>,
        /// Consecutive interactive admissions taken while batch work was
        /// waiting (resets on a batch dispatch or an empty batch lane).
        since_bypass: usize,
        scratch: SampleScratch,
        logits: Vec<f32>,
        /// Decode rounds since the scheduler was last fully idle — an
        /// admission while this is non-zero (and another row is live) is
        /// a mid-generation admission.
        rounds_in_flight: usize,
    },
    /// Legacy run-to-completion batches over `Sampler::generate`.
    /// (Boxed: the sampler embeds a full `ModelEntry`, which would
    /// otherwise dwarf the `Continuous` variant.)
    Coalescing {
        sampler: Box<Sampler>,
        coalescer: Coalescer,
        /// BTreeMap, not HashMap: `run_batch` never iterates it today
        /// (the coalescer queue fixes batch order), but a deterministic
        /// map keeps any future iteration byte-stable by construction.
        pending: BTreeMap<u64, Pending>,
    },
}

/// Bounded per-request token channels for the continuous scheduler. The
/// serving runtime is single-threaded, so the handle is both producer
/// (decode loop) and relay (drains channels to the sink/JSONL *between*
/// decode rounds). The bound + policy still matter: a stalled sink
/// consumes its delay in the relay, never inside a round, and under
/// `DropOldest`/`Disconnect` the backlog is clipped instead of growing.
/// The fleet reuses the same channels across the worker boundary, where
/// they decouple producer and consumer threads outright.
struct StreamSet {
    cap: usize,
    policy: SlowConsumer,
    /// Live channels by request id (created on first token, removed at
    /// finish) — bounded by the slot width, and a BTreeMap so the relay
    /// order is deterministic.
    chans: BTreeMap<u64, (BoundedTx<TokenEvent>, BoundedRx<TokenEvent>)>,
}

/// A live server over one (model, fwd artifact, weights) binding.
pub struct ServeHandle<'e> {
    engine: &'e Engine,
    seq_len: usize,
    batch: usize,
    sample: SampleCfg,
    weights: Buffer,
    sched: Sched,
    next_id: u64,
    max_queue: usize,
    /// Coalescing deadline, reused as the retry-after floor when the
    /// execute window is still empty.
    max_batch_delay_ms: f64,
    starvation_bound: usize,
    completed: Vec<ServeResponse>,
    stats: ServeStats,
    telemetry: Option<JsonlAppender>,
    /// Stream per-token `token` events into the telemetry JSONL.
    stream: bool,
    on_token: Option<TokenSink>,
    /// `Some` when buffered streaming is on (`stream_buf > 0` and there
    /// is a sink or JSONL stream to feed); `None` falls back to the
    /// legacy synchronous delivery inside the decode loop.
    streams: Option<StreamSet>,
}

/// Deliver one token event to the configured sink and (when streaming is
/// on) the telemetry JSONL — the consumer side of the bounded channels.
fn deliver_token(
    telemetry: &mut Option<JsonlAppender>,
    on_token: &Option<TokenSink>,
    stream: bool,
    ev: &TokenEvent,
) {
    if let Some(sink) = on_token {
        (sink.0)(ev);
    }
    if stream {
        if let Some(tel) = telemetry.as_mut() {
            let _ = tel.append(&Json::obj(vec![
                ("event", Json::Str("token".into())),
                ("id", Json::Num(ev.id as f64)),
                ("token", Json::Num(ev.token as f64)),
                ("index", Json::Num(ev.index as f64)),
            ]));
        }
    }
}

/// Surface one generated token: queue it on the request's bounded channel
/// (created on first use), or fall back to synchronous delivery when
/// buffered streaming is off. Under `Block` with a full buffer the
/// channel is relayed inline and the push retried — the blocking
/// semantics land on the producer, as configured, instead of deadlocking
/// a single-threaded scheduler against itself. Free function so
/// scheduler methods can call it while `sched` is borrowed.
fn emit_token(
    streams: &mut Option<StreamSet>,
    telemetry: &mut Option<JsonlAppender>,
    on_token: &Option<TokenSink>,
    stream: bool,
    id: u64,
    token: i32,
    index: usize,
) {
    if !stream && on_token.is_none() {
        return;
    }
    let ev = TokenEvent { id, token, index, worker: 0, attempt: 0 };
    let Some(set) = streams.as_mut() else {
        deliver_token(telemetry, on_token, stream, &ev);
        return;
    };
    let (tx, rx) = set.chans.entry(id).or_insert_with(|| bounded(set.cap, set.policy));
    match tx.try_push(ev) {
        Ok(_) => {}
        Err(ev) => {
            // full under Block: drain this channel to the sink to make
            // room, then store (never fails twice — the buffer has space)
            while let Some(queued) = rx.try_recv() {
                deliver_token(telemetry, on_token, stream, &queued);
            }
            let _ = tx.try_push(ev);
        }
    }
}

/// Drain one request's channel to the sink/JSONL, fold its slow-consumer
/// counters into `stats`, and drop it. Called when the request resolves,
/// before its terminal `request` event is appended.
fn close_stream(
    streams: &mut Option<StreamSet>,
    telemetry: &mut Option<JsonlAppender>,
    on_token: &Option<TokenSink>,
    stream: bool,
    stats: &mut ServeStats,
    id: u64,
) {
    let Some(set) = streams.as_mut() else { return };
    let Some((tx, rx)) = set.chans.remove(&id) else { return };
    tx.close();
    while let Some(ev) = rx.try_recv() {
        deliver_token(telemetry, on_token, stream, &ev);
    }
    let st = rx.stats();
    stats.tokens_dropped += st.dropped;
    stats.consumer_stalls += st.stalls;
    if st.disconnected {
        stats.streams_disconnected += 1;
    }
}

/// Record one completed request into stats/completed/telemetry (free
/// function so scheduler methods can call it while `sched` is borrowed).
#[allow(clippy::too_many_arguments)]
fn finish_request(
    stats: &mut ServeStats,
    completed: &mut Vec<ServeResponse>,
    telemetry: &mut Option<JsonlAppender>,
    id: u64,
    class: RequestClass,
    row: Vec<i32>,
    gen_tokens: usize,
    submitted: Instant,
    admitted: Instant,
    ttft_ms: f64,
    error: Option<String>,
    now: Instant,
) {
    let latency_ms = now.duration_since(submitted).as_secs_f64() * 1000.0;
    let execute_ms = now.duration_since(admitted).as_secs_f64() * 1000.0;
    stats.requests += 1;
    stats.gen_tokens += gen_tokens;
    stats.latencies_ms.push(latency_ms);
    stats.execute_ms.push(execute_ms);
    let cs = stats.per_class.get_mut(class);
    cs.requests += 1;
    cs.gen_tokens += gen_tokens;
    cs.ttft_ms.push(ttft_ms);
    cs.latencies_ms.push(latency_ms);
    cs.observe_exec(execute_ms);
    if error.is_some() {
        stats.degraded += 1;
    }
    if let Some(tel) = telemetry.as_mut() {
        let mut fields = vec![
            ("event", Json::Str("request".into())),
            ("id", Json::Num(id as f64)),
            ("class", Json::Str(class.label().into())),
            ("ttft_ms", Json::Num(ttft_ms)),
            ("latency_ms", Json::Num(latency_ms)),
            ("gen_tokens", Json::Num(gen_tokens as f64)),
        ];
        if let Some(e) = &error {
            fields.push(("error", Json::Str(e.clone())));
        }
        let _ = tel.append(&Json::obj(fields));
    }
    completed.push(ServeResponse { id, row, gen_tokens, latency_ms, ttft_ms, error });
}

impl<'e> ServeHandle<'e> {
    /// Build a server; uploads weights, then opens the stateful decode
    /// session (continuous batching) or compiles the fwd artifact for
    /// batch coalescing, per `cfg.decode` and the backend's capability.
    /// (Library users normally go through `ModelSession::server`, which
    /// resolves `ServeWeights` first.)
    pub fn new(
        rt: &ModelRuntime<'e>,
        fwd_key: &str,
        weights: &[f32],
        cfg: &ServeCfg,
    ) -> Result<ServeHandle<'e>> {
        if rt.model.vision {
            bail!("serving façade supports text models (got VLM {:?})", rt.model.name);
        }
        if cfg.page_size == 0 && (cfg.prefix_cache > 0 || cfg.max_pages > 0) {
            bail!(
                "prefix_cache ({}) and max_pages ({}) require paged decode state (page_size > 0)",
                cfg.prefix_cache,
                cfg.max_pages
            );
        }
        let engine = rt.engine;
        let t0 = Instant::now();
        let weights_buf = engine.upload_f32(weights, &[weights.len()])?;
        let width = (if cfg.max_slots == 0 { rt.model.batch } else { cfg.max_slots }).max(1);
        let decode_opts = DecodeOpts {
            page_size: cfg.page_size,
            prefix_cache: cfg.prefix_cache,
            max_pages: cfg.max_pages,
            kernel: cfg.kernel,
        };

        let mut sched = None;
        let mut decode_weight_bytes = 0usize;
        if cfg.decode != DecodeMode::Full {
            let opened =
                engine.open_decode_opts(&rt.model, fwd_key, &weights_buf, width, &decode_opts)?;
            if let Some(mut session) = opened {
                decode_weight_bytes = session.decode_weight_bytes();
                if cfg.warmup {
                    // exercise weight pre-quantization + one prefill/sample
                    // (the warm-up RNG is local — real requests each get
                    // their own request_rng stream)
                    let mut rng = Rng::new(cfg.sample.seed ^ 0x5a5a_1234);
                    let mut logits = Vec::new();
                    session.prefill(0, &[tok::BOS], &mut logits)?;
                    let mut scratch = SampleScratch::default();
                    let _ = sample_token_with(&cfg.sample, &mut rng, &logits, &mut scratch);
                    // return the warm-up row's pages to the free list so
                    // the first real admission starts from a clean pool
                    session.close(0)?;
                }
                sched = Some(Sched::Continuous {
                    session,
                    slots: (0..width).map(|_| None).collect(),
                    lane_int: VecDeque::new(),
                    lane_bat: VecDeque::new(),
                    since_bypass: 0,
                    scratch: SampleScratch::default(),
                    logits: Vec::new(),
                    rounds_in_flight: 0,
                });
            } else if cfg.decode == DecodeMode::Step {
                bail!(
                    "serve decode mode 'step' requires a stateful-decode backend \
                     (backend {} has none for {fwd_key:?})",
                    engine.backend_kind()
                );
            }
        }
        let sched = match sched {
            Some(s) => s,
            None => {
                let mut sampler = Box::new(Sampler::new(rt, fwd_key, cfg.sample)?);
                // the run-to-completion path is the stateless one by
                // definition — don't step inside coalesced batches
                sampler.set_decode_mode(DecodeMode::Full);
                if cfg.warmup {
                    sampler.generate(engine, &weights_buf, &[vec![tok::BOS]], None)?;
                    sampler.reseed(cfg.sample.seed);
                }
                Sched::Coalescing {
                    sampler,
                    coalescer: Coalescer::new(
                        rt.model.batch,
                        Duration::from_secs_f64(cfg.max_batch_delay_ms.max(0.0) / 1000.0),
                    ),
                    pending: BTreeMap::new(),
                }
            }
        };
        let continuous = matches!(sched, Sched::Continuous { .. });
        let compile_ms = t0.elapsed().as_secs_f64() * 1000.0;

        // An explicitly configured path must open (the caller asked for the
        // log); only the env-var fallback is best-effort.
        let mut telemetry = match cfg.telemetry.as_ref() {
            Some(p) => Some(JsonlAppender::open(p)?),
            None => JsonlAppender::from_env("QADX_TELEMETRY_JSONL"),
        };
        if let Some(tel) = telemetry.as_mut() {
            let _ = tel.append(&Json::obj(vec![
                ("event", Json::Str("compile".into())),
                ("model", Json::Str(rt.model.name.clone())),
                ("fwd", Json::Str(fwd_key.to_string())),
                (
                    "mode",
                    Json::Str((if continuous { "continuous" } else { "coalescing" }).into()),
                ),
                ("slots", Json::Num(width as f64)),
                ("compile_ms", Json::Num(compile_ms)),
                ("decode_weight_bytes", Json::Num(decode_weight_bytes as f64)),
            ]));
        }

        let wants_stream = cfg.stream || cfg.on_token.is_some();
        let streams = if continuous && cfg.stream_buf > 0 && wants_stream {
            Some(StreamSet {
                cap: cfg.stream_buf,
                policy: cfg.slow_consumer,
                chans: BTreeMap::new(),
            })
        } else {
            None
        };
        Ok(ServeHandle {
            engine,
            seq_len: rt.model.seq_len,
            batch: rt.model.batch,
            sample: cfg.sample,
            weights: weights_buf,
            sched,
            next_id: 0,
            max_queue: cfg.max_queue,
            max_batch_delay_ms: cfg.max_batch_delay_ms.max(0.0),
            starvation_bound: cfg.starvation_bound,
            completed: Vec::new(),
            stats: ServeStats {
                fwd_key: fwd_key.to_string(),
                compile_ms,
                decode_weight_bytes,
                ..Default::default()
            },
            telemetry,
            stream: cfg.stream,
            on_token: cfg.on_token.clone(),
            streams,
        })
    }

    /// Whether requests run under the continuous (prefill/step) scheduler.
    pub fn continuous(&self) -> bool {
        matches!(self.sched, Sched::Continuous { .. })
    }

    /// Rows currently being generated (continuous mode; 0 otherwise).
    pub fn in_flight(&self) -> usize {
        match &self.sched {
            Sched::Continuous { slots, .. } => slots.iter().filter(|s| s.is_some()).count(),
            Sched::Coalescing { .. } => 0,
        }
    }

    /// Queue depths per lane (coalescing mode has a single FIFO lane,
    /// reported as interactive).
    fn lane_depths(&self) -> (usize, usize) {
        match &self.sched {
            Sched::Continuous { lane_int, lane_bat, .. } => (lane_int.len(), lane_bat.len()),
            Sched::Coalescing { coalescer, .. } => (coalescer.len(), 0),
        }
    }

    /// Backpressure hint for a [`Saturated`] rejection: the backlog this
    /// class must wait out times its observed per-request service time —
    /// the per-class execute EWMA, falling back to the global execute
    /// mean while the class is cold, floored by the coalescing delay so
    /// an empty window still suggests a real wait.
    fn retry_after_hint(&self, class: RequestClass) -> f64 {
        let (int_depth, bat_depth) = self.lane_depths();
        class_retry_hint(
            class,
            int_depth,
            bat_depth,
            self.in_flight(),
            self.stats.per_class.get(class).exec_ewma_ms,
            self.stats.execute_ms.mean(),
            self.max_batch_delay_ms,
        )
    }

    /// Degradation-ladder step: resolve the youngest queued batch request
    /// as evicted (degraded, zero tokens) to make room for an interactive
    /// arrival under a saturated queue. Returns false when no batch work
    /// is queued (the coalescing fallback has no lanes to evict from).
    fn evict_youngest_batch(&mut self) -> bool {
        let q = match &mut self.sched {
            Sched::Continuous { lane_bat, .. } => lane_bat.pop_back(),
            Sched::Coalescing { .. } => None,
        };
        let Some(q) = q else { return false };
        let now = Instant::now();
        self.stats.evicted += 1;
        self.stats.per_class.batch.evicted += 1;
        if let Some(tel) = self.telemetry.as_mut() {
            let _ = tel.append(&Json::obj(vec![
                ("event", Json::Str("evict".into())),
                ("id", Json::Num(q.id as f64)),
                ("class", Json::Str(RequestClass::Batch.label().into())),
            ]));
        }
        let mut row = vec![tok::PAD; self.seq_len];
        for (dst, src) in row.iter_mut().zip(q.prompt.iter()) {
            *dst = *src;
        }
        let waited_ms = now.duration_since(q.submitted).as_secs_f64() * 1000.0;
        finish_request(
            &mut self.stats,
            &mut self.completed,
            &mut self.telemetry,
            q.id,
            RequestClass::Batch,
            row,
            0,
            q.submitted,
            now,
            waited_ms,
            Some("evicted by interactive admission under saturation".into()),
            now,
        );
        true
    }

    /// Enqueue one request as [`RequestClass::Interactive`] (see
    /// [`submit_class`](Self::submit_class)).
    pub fn submit(&mut self, prompt: Vec<i32>) -> Result<u64> {
        self.submit_class(prompt, RequestClass::Interactive)
    }

    /// Enqueue one request under an explicit SLO class. Continuous mode
    /// admits it into a free slot immediately (prefill + first token);
    /// the coalescing fallback flushes inline whenever a full batch
    /// forms. Returns the request id (matched by `ServeResponse::id`).
    /// When `cfg.max_queue` is set and that many requests are already
    /// queued, applies the degradation ladder: an interactive arrival
    /// first evicts the youngest queued batch request (when lanes are
    /// enabled); otherwise the submission is shed with the typed
    /// [`Saturated`] error carrying a per-class retry hint.
    pub fn submit_class(&mut self, prompt: Vec<i32>, class: RequestClass) -> Result<u64> {
        let seq_len = self.seq_len;
        if prompt.is_empty() {
            bail!("prompt is empty (need at least one token)");
        }
        if prompt.len() >= seq_len {
            // a row of seq_len positions cannot hold prompt + 1 generated
            // token: resolve immediately as a degraded response (error
            // set, no tokens) instead of truncating or bouncing the caller
            let id = self.next_id;
            self.next_id += 1;
            let now = Instant::now();
            let plen = prompt.len();
            let mut row = prompt;
            row.truncate(seq_len);
            finish_request(
                &mut self.stats,
                &mut self.completed,
                &mut self.telemetry,
                id,
                class,
                row,
                0,
                now,
                now,
                0.0,
                Some(format!(
                    "prompt length {plen} leaves no room to generate (seq_len {seq_len})"
                )),
                now,
            );
            return Ok(id);
        }
        if self.max_queue > 0 && self.queued() >= self.max_queue {
            let evicted = class == RequestClass::Interactive
                && self.starvation_bound > 0
                && self.evict_youngest_batch();
            if !evicted {
                self.stats.shed += 1;
                self.stats.per_class.get_mut(class).shed += 1;
                let hint = self.retry_after_hint(class);
                if let Some(tel) = self.telemetry.as_mut() {
                    let _ = tel.append(&Json::obj(vec![
                        ("event", Json::Str("reject".into())),
                        ("class", Json::Str(class.label().into())),
                        ("queued", Json::Num(self.max_queue as f64)),
                        ("retry_after_ms", Json::Num(hint)),
                    ]));
                }
                return Err(Saturated { retry_after_ms: hint }.into());
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let now = Instant::now();
        match &mut self.sched {
            Sched::Continuous { lane_int, lane_bat, .. } => {
                let q = Queued { id, prompt, class, submitted: now };
                match class {
                    RequestClass::Interactive => lane_int.push_back(q),
                    RequestClass::Batch => lane_bat.push_back(q),
                }
            }
            Sched::Coalescing { coalescer, pending, .. } => {
                pending.insert(id, Pending { prompt, class, submitted: now });
                coalescer.push(id, now);
            }
        }
        if self.continuous() {
            self.admit()?;
        } else {
            self.dispatch(false)?;
        }
        self.relay_streams();
        self.sync_paged();
        Ok(id)
    }

    /// Advance the scheduler: continuous mode admits what it can and runs
    /// one decode round; the coalescing fallback flushes any batch whose
    /// deadline has passed. Returns requests completed (continuous) /
    /// dispatched (coalescing) by this call.
    pub fn poll(&mut self) -> Result<usize> {
        let n = if self.continuous() {
            let before = self.completed.len();
            self.admit()?;
            self.step_round()?;
            self.relay_streams();
            self.admit()?;
            self.completed.len() - before
        } else {
            self.dispatch(false)?
        };
        self.relay_streams();
        self.sync_paged();
        Ok(n)
    }

    /// Run every queued and in-flight request to completion and take all
    /// accumulated responses.
    pub fn drain(&mut self) -> Result<Vec<ServeResponse>> {
        if self.continuous() {
            loop {
                self.admit()?;
                if self.in_flight() == 0 {
                    break;
                }
                self.step_round()?;
                self.relay_streams();
            }
        } else {
            self.dispatch(true)?;
        }
        self.relay_streams();
        self.sync_paged();
        Ok(std::mem::take(&mut self.completed))
    }

    /// Drain every live token channel to the sink/JSONL. Runs *between*
    /// decode rounds — a stalled sink spends its delay here, never inside
    /// a round where it would hold up slot-mates.
    fn relay_streams(&mut self) {
        let Some(set) = self.streams.as_mut() else { return };
        for (_tx, rx) in set.chans.values() {
            while let Some(ev) = rx.try_recv() {
                deliver_token(&mut self.telemetry, &self.on_token, self.stream, &ev);
            }
        }
    }

    /// Copy the decode session's paged-state counters into `stats`
    /// (no-op for dense sessions and the coalescing path).
    fn sync_paged(&mut self) {
        if let Sched::Continuous { session, .. } = &self.sched {
            self.stats.decode_weight_bytes = session.decode_weight_bytes();
            if let Some(ps) = session.paged_stats() {
                self.stats.page_size = ps.page_size;
                self.stats.live_pages = ps.live_pages;
                self.stats.prefix_hits = ps.prefix_hits;
                self.stats.prefix_misses = ps.prefix_misses;
                self.stats.cow_copies = ps.cow_copies;
            }
        }
    }

    pub fn queued(&self) -> usize {
        let (int_depth, bat_depth) = self.lane_depths();
        int_depth + bat_depth
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Admit queued requests into free slots: pick a lane (interactive
    /// first, bounded by the starvation bypass), prefill the prompt,
    /// sample the first token (TTFT) from the request's own RNG stream,
    /// and either park the row in the slot or — for EOS/length-1
    /// completions — finish it on the spot. A failed prefill finishes
    /// that one request with `error` set; the scheduler and every other
    /// slot keep running.
    fn admit(&mut self) -> Result<usize> {
        let mut admitted = 0usize;
        loop {
            let bound = self.starvation_bound;
            let Sched::Continuous {
                session,
                slots,
                lane_int,
                lane_bat,
                since_bypass,
                scratch,
                logits,
                rounds_in_flight,
            } = &mut self.sched
            else {
                return Ok(admitted);
            };
            let Some(slot_idx) = slots.iter().position(|s| s.is_none()) else {
                return Ok(admitted);
            };
            let any_active = slots.iter().any(|s| s.is_some());
            let take_bat = take_batch_lane(
                lane_int.front().map(|q| q.id),
                lane_bat.front().map(|q| q.id),
                bound,
                *since_bypass,
            );
            let q = if take_bat {
                if bound > 0 && !lane_int.is_empty() {
                    // a waiting interactive request was passed over: this
                    // is the starvation bound doing its job
                    self.stats.lane_bypasses += 1;
                }
                *since_bypass = 0;
                lane_bat.pop_front()
            } else {
                if lane_bat.is_empty() {
                    *since_bypass = 0;
                } else {
                    *since_bypass += 1;
                }
                lane_int.pop_front()
            };
            let Some(q) = q else {
                return Ok(admitted);
            };
            let t0 = Instant::now();
            let np = q.prompt.len().min(self.seq_len - 1);
            // np <= prompt.len() by construction, so get() always hits
            let prompt = q.prompt.get(..np).unwrap_or(&q.prompt);
            let mut rng = request_rng(self.sample.seed, q.id);
            let prefill = session.prefill(slot_idx, prompt, logits);
            let next = match &prefill {
                Ok(()) => sample_token_with(&self.sample, &mut rng, logits, scratch),
                Err(_) => tok::EOS,
            };
            let now = Instant::now();
            let wait_ms = t0.duration_since(q.submitted).as_secs_f64() * 1000.0;
            let ttft_ms = now.duration_since(q.submitted).as_secs_f64() * 1000.0;
            self.stats.queue_wait_ms.push(wait_ms);
            self.stats.ttft_ms.push(ttft_ms);
            self.stats.busy_secs += now.duration_since(t0).as_secs_f64();
            if any_active && *rounds_in_flight > 0 {
                self.stats.mid_gen_admissions += 1;
            }
            admitted += 1;
            let mut row = vec![tok::PAD; self.seq_len];
            for (dst, src) in row.iter_mut().zip(prompt.iter()) {
                *dst = *src;
            }
            if let Err(e) = prefill {
                // degrade the one request: prompt-only row, zero tokens
                // (close returns any partially-filled pages to the pool)
                let _ = session.close(slot_idx);
                finish_request(
                    &mut self.stats,
                    &mut self.completed,
                    &mut self.telemetry,
                    q.id,
                    q.class,
                    row,
                    0,
                    q.submitted,
                    t0,
                    ttft_ms,
                    Some(format!("prefill failed: {e:#}")),
                    now,
                );
                continue;
            }
            if self.sample.max_new == 0 {
                // degenerate cap: nothing may be emitted (matches the
                // stateless path, whose decode loop never runs)
                let _ = session.close(slot_idx);
                finish_request(
                    &mut self.stats,
                    &mut self.completed,
                    &mut self.telemetry,
                    q.id,
                    q.class,
                    row,
                    0,
                    q.submitted,
                    t0,
                    ttft_ms,
                    None,
                    now,
                );
                continue;
            }
            if let Some(cell) = row.get_mut(np) {
                *cell = next;
            }
            emit_token(
                &mut self.streams,
                &mut self.telemetry,
                &self.on_token,
                self.stream,
                q.id,
                next,
                0,
            );
            if next == tok::EOS || np + 1 >= self.seq_len || self.sample.max_new == 1 {
                let _ = session.close(slot_idx);
                close_stream(
                    &mut self.streams,
                    &mut self.telemetry,
                    &self.on_token,
                    self.stream,
                    &mut self.stats,
                    q.id,
                );
                finish_request(
                    &mut self.stats,
                    &mut self.completed,
                    &mut self.telemetry,
                    q.id,
                    q.class,
                    row,
                    1,
                    q.submitted,
                    t0,
                    ttft_ms,
                    None,
                    now,
                );
            } else if let Some(slot) = slots.get_mut(slot_idx) {
                *slot = Some(Slot {
                    id: q.id,
                    class: q.class,
                    row,
                    frontier: np + 1,
                    submitted: q.submitted,
                    admitted: t0,
                    ttft_ms,
                    last_token: now,
                    gen: 1,
                    rng,
                });
            }
        }
    }

    /// One decode round: step every live slot by one token (ascending
    /// slot order), finishing rows that hit EOS or the sequence end. A
    /// failed step finishes that one slot's request with `error` set and
    /// leaves every other slot running.
    fn step_round(&mut self) -> Result<usize> {
        let Sched::Continuous { session, slots, scratch, logits, rounds_in_flight, .. } =
            &mut self.sched
        else {
            return Ok(0);
        };
        let width = slots.len();
        let active = slots.iter().filter(|s| s.is_some()).count();
        if active == 0 {
            return Ok(0);
        }
        let t0 = Instant::now();
        let mut finished = 0usize;
        for idx in 0..width {
            let (last_tok, pos) = match slots.get(idx).and_then(|s| s.as_ref()) {
                Some(s) => match s.frontier.checked_sub(1).and_then(|i| s.row.get(i)) {
                    Some(&t) => (t, s.frontier),
                    None => (tok::PAD, s.frontier), // frontier always >= 1 once parked
                },
                None => continue,
            };
            let stepped = session.step(idx, last_tok, logits);
            let Some(slot) = slots.get_mut(idx).and_then(|s| s.as_mut()) else { continue };
            let mut error: Option<String> = None;
            // sample from the slot's own request_rng stream, so slot-mates
            // and scheduling order cannot perturb this request's tokens
            let next = match &stepped {
                Ok(()) => sample_token_with(&self.sample, &mut slot.rng, logits, scratch),
                Err(e) => {
                    error = Some(format!("decode step failed: {e:#}"));
                    tok::EOS
                }
            };
            let now = Instant::now();
            self.stats
                .inter_token_ms
                .push(now.duration_since(slot.last_token).as_secs_f64() * 1000.0);
            slot.last_token = now;
            if error.is_none() {
                if let Some(cell) = slot.row.get_mut(pos) {
                    *cell = next;
                }
                slot.frontier += 1;
                slot.gen += 1;
                let (id, idx0) = (slot.id, slot.gen - 1);
                emit_token(
                    &mut self.streams,
                    &mut self.telemetry,
                    &self.on_token,
                    self.stream,
                    id,
                    next,
                    idx0,
                );
            }
            // same per-request cap as the stateless path: at most max_new
            // generated tokens (EOS / sequence end finish earlier); an
            // errored slot finishes immediately with whatever it has
            if error.is_some()
                || next == tok::EOS
                || slot.frontier >= self.seq_len
                || slot.gen >= self.sample.max_new
            {
                if let Some(sl) = slots.get_mut(idx).and_then(|s| s.take()) {
                    let _ = session.close(idx);
                    close_stream(
                        &mut self.streams,
                        &mut self.telemetry,
                        &self.on_token,
                        self.stream,
                        &mut self.stats,
                        sl.id,
                    );
                    finish_request(
                        &mut self.stats,
                        &mut self.completed,
                        &mut self.telemetry,
                        sl.id,
                        sl.class,
                        sl.row,
                        sl.gen,
                        sl.submitted,
                        sl.admitted,
                        sl.ttft_ms,
                        error,
                        now,
                    );
                    finished += 1;
                }
            }
        }
        *rounds_in_flight += 1;
        self.stats.decode_rounds += 1;
        self.stats.slot_occupancy.push(active as f64 / width as f64);
        self.stats.busy_secs += Instant::now().duration_since(t0).as_secs_f64();
        if slots.iter().all(|s| s.is_none()) {
            *rounds_in_flight = 0;
        }
        Ok(finished)
    }

    /// Coalescing fallback: flush ready batches.
    fn dispatch(&mut self, force: bool) -> Result<usize> {
        let mut ran = 0;
        loop {
            let ids = {
                let Sched::Coalescing { coalescer, .. } = &mut self.sched else {
                    return Ok(ran);
                };
                match coalescer.take_ready(Instant::now(), force) {
                    Some(ids) => ids,
                    None => return Ok(ran),
                }
            };
            ran += ids.len();
            self.run_batch(&ids)?;
        }
    }

    fn run_batch(&mut self, ids: &[u64]) -> Result<()> {
        let t0 = Instant::now();
        let engine = self.engine;
        let Sched::Coalescing { sampler, pending, .. } = &mut self.sched else {
            bail!("run_batch called on the continuous scheduler");
        };
        // move prompts out of the pending map — no per-request cloning;
        // an id with no pending entry (can't happen via the public API)
        // is skipped rather than panicking the scheduler
        let mut kept = Vec::with_capacity(ids.len());
        let mut prompts = Vec::with_capacity(ids.len());
        let mut submitted = Vec::with_capacity(ids.len());
        let mut classes = Vec::with_capacity(ids.len());
        for id in ids {
            let Some(p) = pending.remove(id) else { continue };
            kept.push(*id);
            prompts.push(p.prompt);
            submitted.push(p.submitted);
            classes.push(p.class);
        }
        if kept.is_empty() {
            return Ok(());
        }
        let rows = sampler.generate(engine, &self.weights, &prompts, None)?;
        let done = Instant::now();
        let batch_ms = done.duration_since(t0).as_secs_f64() * 1000.0;
        let fill = kept.len() as f64 / self.batch as f64;

        let mut batch_tokens = 0usize;
        let mut max_wait_ms = 0f64;
        for ((((row, id), prompt), sub), class) in
            rows.into_iter().zip(&kept).zip(&prompts).zip(&submitted).zip(&classes)
        {
            let gen_tokens = row.iter().skip(prompt.len()).filter(|&&t| t != tok::PAD).count();
            batch_tokens += gen_tokens;
            let latency_ms = done.duration_since(*sub).as_secs_f64() * 1000.0;
            // split: time queued before the batch launched vs time inside
            // the generation call (shared by every request in the batch)
            let wait_ms = t0.duration_since(*sub).as_secs_f64() * 1000.0;
            max_wait_ms = max_wait_ms.max(wait_ms);
            self.stats.latencies_ms.push(latency_ms);
            self.stats.queue_wait_ms.push(wait_ms);
            self.stats.execute_ms.push(batch_ms);
            // first token surfaces only at batch completion here
            self.stats.ttft_ms.push(latency_ms);
            let cs = self.stats.per_class.get_mut(*class);
            cs.requests += 1;
            cs.gen_tokens += gen_tokens;
            cs.ttft_ms.push(latency_ms);
            cs.latencies_ms.push(latency_ms);
            cs.observe_exec(batch_ms);
            self.completed.push(ServeResponse {
                id: *id,
                row,
                gen_tokens,
                latency_ms,
                ttft_ms: latency_ms,
                error: None,
            });
        }
        self.stats.requests += kept.len();
        self.stats.batches += 1;
        self.stats.gen_tokens += batch_tokens;
        self.stats.fill_ratios.push(fill);
        self.stats.busy_secs += batch_ms / 1000.0;

        if let Some(tel) = self.telemetry.as_mut() {
            let _ = tel.append(&Json::obj(vec![
                ("event", Json::Str("batch".into())),
                ("fwd", Json::Str(self.stats.fwd_key.clone())),
                ("requests", Json::Num(kept.len() as f64)),
                ("fill_ratio", Json::Num(fill)),
                // batch_ms is the batch's execute time (kept under its
                // pre-existing name); max_queue_wait_ms is the slowest
                // request's coalescing wait before this batch launched
                ("batch_ms", Json::Num(batch_ms)),
                ("max_queue_wait_ms", Json::Num(max_wait_ms)),
                ("gen_tokens", Json::Num(batch_tokens as f64)),
            ]));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coalescer_flushes_full_batches_immediately() {
        let now = Instant::now();
        let mut c = Coalescer::new(4, Duration::from_secs(60));
        for id in 0..4 {
            c.push(id, now);
        }
        assert_eq!(c.take_ready(now, false), Some(vec![0, 1, 2, 3]));
        assert!(c.is_empty());
    }

    #[test]
    fn coalescer_holds_partial_until_deadline() {
        let now = Instant::now();
        let mut c = Coalescer::new(4, Duration::from_millis(10));
        c.push(0, now);
        c.push(1, now);
        assert_eq!(c.take_ready(now, false), None);
        // deadline reached -> partial batch goes out
        assert_eq!(c.take_ready(now + Duration::from_millis(10), false), Some(vec![0, 1]));
        assert!(c.is_empty());
    }

    #[test]
    fn coalescer_drains_ragged_tail_completely() {
        // N % batch != 0: every request must come out, in order, with the
        // expected per-batch sizes.
        let now = Instant::now();
        let mut c = Coalescer::new(4, Duration::from_secs(60));
        for id in 0..10 {
            c.push(id, now);
        }
        let mut sizes = Vec::new();
        let mut all = Vec::new();
        while let Some(ids) = c.take_ready(now, true) {
            sizes.push(ids.len());
            all.extend(ids);
        }
        assert_eq!(sizes, vec![4, 4, 2]);
        assert_eq!(all, (0..10).collect::<Vec<u64>>());
        assert!(c.is_empty());
    }

    #[test]
    fn coalescer_force_on_empty_queue_is_none() {
        let now = Instant::now();
        let mut c = Coalescer::new(4, Duration::from_millis(1));
        assert_eq!(c.take_ready(now, true), None);
        // still none after time passes with nothing queued
        assert_eq!(c.take_ready(now + Duration::from_secs(5), true), None);
    }

    #[test]
    fn coalescer_exact_deadline_boundary_flushes() {
        // duration_since(oldest) == max_delay must flush (>=, not >)
        let now = Instant::now();
        let delay = Duration::from_millis(25);
        let mut c = Coalescer::new(8, delay);
        c.push(0, now);
        assert_eq!(c.take_ready(now + delay - Duration::from_nanos(1), false), None);
        assert_eq!(c.take_ready(now + delay, false), Some(vec![0]));
    }

    #[test]
    fn coalescer_zero_delay_flushes_every_poll() {
        let now = Instant::now();
        let mut c = Coalescer::new(4, Duration::from_secs(0));
        c.push(7, now);
        assert_eq!(c.take_ready(now, false), Some(vec![7]));
        assert!(c.is_empty());
    }

    #[test]
    fn coalescer_overfull_queue_drains_batch_at_a_time() {
        // more than one full batch queued and expired: each take_ready
        // returns exactly one batch, oldest first
        let now = Instant::now();
        let mut c = Coalescer::new(3, Duration::from_secs(0));
        for id in 0..7 {
            c.push(id, now);
        }
        assert_eq!(c.take_ready(now, false), Some(vec![0, 1, 2]));
        assert_eq!(c.take_ready(now, false), Some(vec![3, 4, 5]));
        assert_eq!(c.take_ready(now, false), Some(vec![6]));
        assert_eq!(c.take_ready(now, false), None);
    }

    #[test]
    fn saturated_error_downcasts_through_anyhow() {
        let err: anyhow::Error = Saturated { retry_after_ms: 12.5 }.into();
        let sat = err.downcast_ref::<Saturated>().expect("typed saturation error");
        assert_eq!(sat.retry_after_ms, 12.5);
        assert!(err.to_string().contains("retry after"), "{err}");
        // a generic error must NOT downcast — callers can rely on the type
        let other = anyhow::anyhow!("boom");
        assert!(other.downcast_ref::<Saturated>().is_none());
    }

    #[test]
    fn fill_ratio_reports_partial_batches() {
        let mut stats = ServeStats::default();
        for f in [1.0, 1.0, 0.5] {
            stats.fill_ratios.push(f);
        }
        for l in [10.0, 20.0, 30.0] {
            stats.latencies_ms.push(l);
        }
        assert!((stats.mean_fill_ratio() - 2.5 / 3.0).abs() < 1e-12);
        assert_eq!(stats.latency_p(50.0), 20.0);
    }

    #[test]
    fn stats_stay_bounded_for_long_running_servers() {
        let mut stats = ServeStats::default();
        let n = 3 * crate::util::STATS_WINDOW_DEFAULT;
        for i in 0..n {
            stats.latencies_ms.push(i as f64);
            stats.fill_ratios.push(0.5);
        }
        assert_eq!(stats.latencies_ms.len(), crate::util::STATS_WINDOW_DEFAULT);
        assert_eq!(stats.latencies_ms.count(), n as u64);
        // exact lifetime mean survives the windowing
        assert!((stats.mean_fill_ratio() - 0.5).abs() < 1e-12);
        // percentiles reflect the recent window
        assert!(stats.latency_p(0.0) >= (n - crate::util::STATS_WINDOW_DEFAULT) as f64);
    }

    #[test]
    fn idle_stats_do_not_divide_by_zero() {
        let stats = ServeStats::default();
        assert_eq!(stats.req_per_sec(), 0.0);
        assert_eq!(stats.gen_tok_per_sec(), 0.0);
        assert_eq!(stats.mean_fill_ratio(), 0.0);
        assert!(stats.summary().contains("0 reqs"));
    }

    #[test]
    fn queue_wait_execute_split_lands_in_summary() {
        let mut stats = ServeStats::default();
        // three requests from one batch: same execute time, varying waits
        for w in [2.0, 5.0, 11.0] {
            stats.queue_wait_ms.push(w);
            stats.execute_ms.push(40.0);
            stats.latencies_ms.push(w + 40.0);
        }
        assert_eq!(stats.queue_wait_ms.percentile(50.0), 5.0);
        assert_eq!(stats.execute_ms.percentile(50.0), 40.0);
        let s = stats.summary();
        assert!(s.contains("wait p50 5ms"), "{s}");
        assert!(s.contains("exec p50 40ms"), "{s}");
    }

    #[test]
    fn summary_reports_ttft_and_mode_specific_utilization() {
        // coalescing shape: batches + fill
        let mut stats = ServeStats::default();
        stats.requests = 4;
        stats.batches = 1;
        stats.ttft_ms.push(12.0);
        stats.fill_ratios.push(1.0);
        let s = stats.summary();
        assert!(s.contains("ttft p50 12ms"), "{s}");
        assert!(s.contains("4 reqs / 1 batches"), "{s}");
        assert!(s.contains("fill 1.00"), "{s}");
        // continuous shape: rounds + occupancy + mid-gen admissions
        let mut stats = ServeStats::default();
        stats.requests = 3;
        stats.decode_rounds = 5;
        stats.mid_gen_admissions = 1;
        stats.ttft_ms.push(3.0);
        stats.slot_occupancy.push(0.5);
        stats.slot_occupancy.push(1.0);
        let s = stats.summary();
        assert!(s.contains("3 reqs / 5 rounds (+1 mid-gen)"), "{s}");
        assert!(s.contains("occ 0.75"), "{s}");
        assert!(s.contains("ttft p50 3ms"), "{s}");
    }

    #[test]
    fn request_class_defaults_to_interactive() {
        assert_eq!(RequestClass::default(), RequestClass::Interactive);
        assert_eq!(RequestClass::Interactive.label(), "interactive");
        assert_eq!(RequestClass::Batch.label(), "batch");
        assert_eq!(RequestClass::ALL.len(), 2);
    }

    #[test]
    fn request_rng_streams_are_keyed_on_seed_and_id_only() {
        // same (seed, id) -> identical stream; either input changing
        // decorrelates it
        let mut ra = request_rng(7, 3);
        let mut rb = request_rng(7, 3);
        let a: Vec<u64> = (0..4).map(|_| ra.next_u64()).collect();
        let b: Vec<u64> = (0..4).map(|_| rb.next_u64()).collect();
        assert_eq!(a, b);
        assert_ne!(request_rng(7, 3).next_u64(), request_rng(7, 4).next_u64());
        assert_ne!(request_rng(7, 3).next_u64(), request_rng(8, 3).next_u64());
    }

    #[test]
    fn lanes_disabled_is_strict_submission_order() {
        // bound 0: the older front id wins regardless of class
        assert!(!take_batch_lane(Some(3), Some(5), 0, 99));
        assert!(take_batch_lane(Some(6), Some(5), 0, 0));
        // single-lane cases are class-blind
        assert!(!take_batch_lane(Some(1), None, 0, 0));
        assert!(take_batch_lane(None, Some(1), 0, 0));
    }

    #[test]
    fn starvation_bound_bypasses_batch_every_k_interactive_dispatches() {
        // replicate the admit-loop counter discipline over synthetic
        // lanes: bound 2 -> two interactive dispatches, then one batch
        // bypass, repeating; the tail drains whichever lane remains
        let bound = 2;
        let mut lane_int: VecDeque<u64> = (0..6).collect();
        let mut lane_bat: VecDeque<u64> = (100..103).collect();
        let mut since = 0usize;
        let mut order = Vec::new();
        let mut bypasses = 0usize;
        while !(lane_int.is_empty() && lane_bat.is_empty()) {
            let take_bat = take_batch_lane(
                lane_int.front().copied(),
                lane_bat.front().copied(),
                bound,
                since,
            );
            if take_bat {
                if bound > 0 && !lane_int.is_empty() {
                    bypasses += 1;
                }
                since = 0;
                order.push(lane_bat.pop_front().unwrap());
            } else {
                if lane_bat.is_empty() {
                    since = 0;
                } else {
                    since += 1;
                }
                order.push(lane_int.pop_front().unwrap());
            }
        }
        assert_eq!(order, vec![0, 1, 100, 2, 3, 101, 4, 5, 102]);
        // only bypasses taken while interactive work waited are counted:
        // 102 drains from an empty interactive lane, so exactly two
        assert_eq!(bypasses, 2);
    }

    #[test]
    fn class_retry_hints_differ_under_the_same_queue_state() {
        // satellite: both classes, same queue (2 interactive + 3 batch
        // queued, 1 in flight), distinct per-class service estimates
        let int =
            class_retry_hint(RequestClass::Interactive, 2, 3, 1, 10.0, 40.0, 0.0);
        let bat = class_retry_hint(RequestClass::Batch, 2, 3, 1, 80.0, 40.0, 0.0);
        // interactive waits on its own lane + in-flight only: 3 * 10ms
        assert_eq!(int, 30.0);
        // batch waits on both lanes + in-flight at its own rate: 6 * 80ms
        assert_eq!(bat, 480.0);
        // a cold class EWMA falls back to the global estimate
        assert_eq!(
            class_retry_hint(RequestClass::Interactive, 2, 3, 1, 0.0, 40.0, 0.0),
            120.0
        );
        // floor applies when the queue is empty
        assert_eq!(class_retry_hint(RequestClass::Batch, 0, 0, 0, 10.0, 0.0, 25.0), 25.0);
        assert_eq!(class_retry_hint(RequestClass::Batch, 0, 0, 0, 0.0, 0.0, 0.0), 1.0);
    }

    #[test]
    fn class_stats_deadline_hit_rate_and_exec_ewma() {
        let mut cs = ClassStats::default();
        assert_eq!(cs.deadline_hit_rate(), 1.0, "no deadline tracked -> vacuous hit");
        cs.deadline_hits = 3;
        cs.deadline_misses = 1;
        assert!((cs.deadline_hit_rate() - 0.75).abs() < 1e-12);
        // first observation seeds the EWMA; later ones decay 0.9/0.1
        cs.observe_exec(100.0);
        assert_eq!(cs.exec_ewma_ms, 100.0);
        cs.observe_exec(200.0);
        assert!((cs.exec_ewma_ms - 110.0).abs() < 1e-9);
        // non-finite and negative samples are dropped
        cs.observe_exec(f64::NAN);
        cs.observe_exec(-5.0);
        assert!((cs.exec_ewma_ms - 110.0).abs() < 1e-9);
    }

    #[test]
    fn summary_reports_lane_and_stream_clauses() {
        let mut stats = ServeStats::default();
        stats.requests = 5;
        stats.decode_rounds = 9;
        stats.per_class.interactive.requests = 3;
        stats.per_class.interactive.ttft_ms.push(4.0);
        stats.per_class.batch.requests = 2;
        stats.per_class.batch.shed = 1;
        stats.per_class.batch.evicted = 2;
        stats.lane_bypasses = 3;
        stats.tokens_dropped = 7;
        stats.consumer_stalls = 1;
        let s = stats.summary();
        assert!(s.contains("int 3 ttft p99 4ms"), "{s}");
        assert!(s.contains("bat 2"), "{s}");
        assert!(s.contains("shed 1 evict 2"), "{s}");
        assert!(s.contains("bypass 3"), "{s}");
        assert!(s.contains("stream drop 7 stall 1 disc 0"), "{s}");
        // idle classes and a clean stream add no clauses
        let idle = ServeStats::default().summary();
        assert!(!idle.contains("int "), "{idle}");
        assert!(!idle.contains("stream drop"), "{idle}");
    }
}
