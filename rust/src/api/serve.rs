//! Serving façade: a request queue with batch coalescing over one fwd
//! artifact. Requests are submitted one at a time; the handle fills
//! device batches up to `model.batch`, flushing a partial batch once the
//! oldest request has waited past a deadline (or on `drain`). Per-batch
//! telemetry (compile ms, fill ratio, tokens) optionally lands in a JSONL
//! event log.
//!
//! The runtime is single-threaded (PJRT buffers are not Send), so the
//! queue is synchronous: `submit` flushes full batches inline, `poll`
//! applies the deadline, and `drain` forces everything out.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::data::tokenizer as tok;
use crate::eval::{SampleCfg, Sampler};
use crate::runtime::{Buffer, Engine, ModelRuntime};
use crate::util::json::Json;
use crate::util::StatsWindow;

use super::telemetry::JsonlAppender;

/// Where a server's weights come from (resolved by `ModelSession::server`).
#[derive(Clone, Debug)]
pub enum ServeWeights {
    /// Fresh random init (throughput benchmarking — accuracy irrelevant).
    Random { seed: u64 },
    /// The model's cached/trained BF16 teacher.
    Teacher,
    /// A recovered checkpoint by method name (e.g. "qad").
    Method(String),
    /// An explicit parameter vector.
    Params(Vec<f32>),
}

#[derive(Clone, Debug)]
pub struct ServeCfg {
    pub sample: SampleCfg,
    pub weights: ServeWeights,
    /// Flush a partial batch once its oldest request has waited this long.
    pub max_batch_delay_ms: f64,
    /// Run one warm-up generation so compile/first-execute cost does not
    /// land on the first real request.
    pub warmup: bool,
    /// JSONL event log path; falls back to `QADX_TELEMETRY_JSONL`.
    pub telemetry: Option<std::path::PathBuf>,
}

impl Default for ServeCfg {
    fn default() -> ServeCfg {
        ServeCfg {
            sample: SampleCfg::default(),
            weights: ServeWeights::Random { seed: 3 },
            max_batch_delay_ms: 25.0,
            warmup: true,
            telemetry: None,
        }
    }
}

/// Pure batching policy: decides *when* a set of queued request ids forms
/// a batch (full, deadline-expired, or forced). Kept free of PJRT so the
/// coalescing rules are unit-testable without artifacts.
pub struct Coalescer {
    batch: usize,
    max_delay: Duration,
    queue: VecDeque<(u64, Instant)>,
}

impl Coalescer {
    pub fn new(batch: usize, max_delay: Duration) -> Coalescer {
        assert!(batch >= 1, "batch must be >= 1");
        Coalescer { batch, max_delay, queue: VecDeque::new() }
    }

    pub fn push(&mut self, id: u64, now: Instant) {
        self.queue.push_back((id, now));
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Take the next batch if one is ready: a full batch always; a partial
    /// batch when forced or when the oldest entry has waited `max_delay`.
    pub fn take_ready(&mut self, now: Instant, force: bool) -> Option<Vec<u64>> {
        let oldest = self.queue.front()?.1;
        let full = self.queue.len() >= self.batch;
        let expired = now.duration_since(oldest) >= self.max_delay;
        if !(full || expired || force) {
            return None;
        }
        let n = self.queue.len().min(self.batch);
        Some(self.queue.drain(..n).map(|(id, _)| id).collect())
    }
}

/// One completed request.
#[derive(Clone, Debug)]
pub struct ServeResponse {
    pub id: u64,
    /// Full token row (prompt + completion, PAD-tailed).
    pub row: Vec<i32>,
    pub gen_tokens: usize,
    /// Submit-to-complete latency (includes queueing delay).
    pub latency_ms: f64,
}

/// Aggregate serving counters for one handle.
///
/// Per-sample series are bounded sliding windows (`StatsWindow`): exact
/// lifetime counts/means stay in scalars while percentiles come from the
/// most recent samples — a long-running server's stats stay O(window),
/// not O(requests).
#[derive(Clone, Debug, Default)]
pub struct ServeStats {
    pub fwd_key: String,
    /// Artifact compile + warm-up time paid at construction.
    pub compile_ms: f64,
    pub requests: usize,
    pub batches: usize,
    pub gen_tokens: usize,
    pub latencies_ms: StatsWindow,
    /// Per-batch occupancy (submitted rows / model batch size).
    pub fill_ratios: StatsWindow,
    /// Per-request time spent queued before its batch launched — the
    /// coalescing cost. latency ≈ queue wait + execute.
    pub queue_wait_ms: StatsWindow,
    /// Per-request time inside the generation call that served it — the
    /// compute cost (where `--threads` shows up).
    pub execute_ms: StatsWindow,
    /// Time spent inside generation calls.
    pub busy_secs: f64,
}

impl ServeStats {
    /// Exact lifetime mean occupancy (not windowed).
    pub fn mean_fill_ratio(&self) -> f64 {
        self.fill_ratios.mean()
    }

    /// Latency percentile over the retained window.
    pub fn latency_p(&self, p: f64) -> f64 {
        self.latencies_ms.percentile(p)
    }

    pub fn req_per_sec(&self) -> f64 {
        if self.busy_secs > 0.0 {
            self.requests as f64 / self.busy_secs
        } else {
            0.0
        }
    }

    pub fn gen_tok_per_sec(&self) -> f64 {
        if self.busy_secs > 0.0 {
            self.gen_tokens as f64 / self.busy_secs
        } else {
            0.0
        }
    }

    /// One-line report: req/s, gen-tok/s, latency percentiles (with the
    /// queue-wait / execute split), batch fill ratio, compile cost. The
    /// single source for CLI/example output. Throughput is over *busy*
    /// time (inside generation); callers that want end-to-end throughput
    /// divide by their own wall clock.
    pub fn summary(&self) -> String {
        format!(
            "{:<10} {} reqs / {} batches | busy {:.1} req/s {:.0} gen-tok/s | \
             lat p50 {:.0}ms p95 {:.0}ms p99 {:.0}ms (wait p50 {:.0}ms exec p50 {:.0}ms) | \
             fill {:.2} | compile {:.0}ms",
            self.fwd_key,
            self.requests,
            self.batches,
            self.req_per_sec(),
            self.gen_tok_per_sec(),
            self.latency_p(50.0),
            self.latency_p(95.0),
            self.latency_p(99.0),
            self.queue_wait_ms.percentile(50.0),
            self.execute_ms.percentile(50.0),
            self.mean_fill_ratio(),
            self.compile_ms,
        )
    }
}

struct Pending {
    prompt: Vec<i32>,
    submitted: Instant,
}

/// A live server over one (model, fwd artifact, weights) binding.
pub struct ServeHandle<'e> {
    engine: &'e Engine,
    sampler: Sampler,
    weights: Buffer,
    coalescer: Coalescer,
    pending: HashMap<u64, Pending>,
    next_id: u64,
    completed: Vec<ServeResponse>,
    stats: ServeStats,
    telemetry: Option<JsonlAppender>,
}

impl<'e> ServeHandle<'e> {
    /// Build a server; compiles the fwd artifact and uploads weights.
    /// (Library users normally go through `ModelSession::server`, which
    /// resolves `ServeWeights` first.)
    pub fn new(
        rt: &ModelRuntime<'e>,
        fwd_key: &str,
        weights: &[f32],
        cfg: &ServeCfg,
    ) -> Result<ServeHandle<'e>> {
        if rt.model.vision {
            bail!("serving façade supports text models (got VLM {:?})", rt.model.name);
        }
        let engine = rt.engine;
        let t0 = Instant::now();
        let mut sampler = Sampler::new(rt, fwd_key, cfg.sample)?;
        let weights_buf = engine.upload_f32(weights, &[weights.len()])?;
        if cfg.warmup {
            sampler.generate(engine, &weights_buf, &[vec![tok::BOS]], None)?;
            sampler.reseed(cfg.sample.seed);
        }
        let compile_ms = t0.elapsed().as_secs_f64() * 1000.0;

        // An explicitly configured path must open (the caller asked for the
        // log); only the env-var fallback is best-effort.
        let mut telemetry = match cfg.telemetry.as_ref() {
            Some(p) => Some(JsonlAppender::open(p)?),
            None => JsonlAppender::from_env("QADX_TELEMETRY_JSONL"),
        };
        if let Some(tel) = telemetry.as_mut() {
            let _ = tel.append(&Json::obj(vec![
                ("event", Json::Str("compile".into())),
                ("model", Json::Str(rt.model.name.clone())),
                ("fwd", Json::Str(fwd_key.to_string())),
                ("compile_ms", Json::Num(compile_ms)),
            ]));
        }

        let batch = rt.model.batch;
        Ok(ServeHandle {
            engine,
            sampler,
            weights: weights_buf,
            coalescer: Coalescer::new(
                batch,
                Duration::from_secs_f64(cfg.max_batch_delay_ms.max(0.0) / 1000.0),
            ),
            pending: HashMap::new(),
            next_id: 0,
            completed: Vec::new(),
            stats: ServeStats { fwd_key: fwd_key.to_string(), compile_ms, ..Default::default() },
            telemetry,
        })
    }

    /// Enqueue one request; flushes inline whenever a full batch forms.
    /// Returns the request id (matched by `ServeResponse::id`).
    pub fn submit(&mut self, prompt: Vec<i32>) -> Result<u64> {
        let seq_len = self.sampler.model.seq_len;
        if prompt.is_empty() || prompt.len() >= seq_len {
            bail!(
                "prompt length {} out of range (need 1..{seq_len} to leave room to generate)",
                prompt.len()
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        let now = Instant::now();
        self.pending.insert(id, Pending { prompt, submitted: now });
        self.coalescer.push(id, now);
        self.dispatch(false)?;
        Ok(id)
    }

    /// Flush any batch whose deadline has passed; returns requests run.
    pub fn poll(&mut self) -> Result<usize> {
        self.dispatch(false)
    }

    /// Force out all queued requests (partial final batch included) and
    /// take every completed response accumulated so far.
    pub fn drain(&mut self) -> Result<Vec<ServeResponse>> {
        self.dispatch(true)?;
        Ok(std::mem::take(&mut self.completed))
    }

    pub fn queued(&self) -> usize {
        self.coalescer.len()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    fn dispatch(&mut self, force: bool) -> Result<usize> {
        let mut ran = 0;
        while let Some(ids) = self.coalescer.take_ready(Instant::now(), force) {
            ran += ids.len();
            self.run_batch(&ids)?;
        }
        Ok(ran)
    }

    fn run_batch(&mut self, ids: &[u64]) -> Result<()> {
        let t0 = Instant::now();
        // move prompts out of the pending map — no per-request cloning
        let mut prompts = Vec::with_capacity(ids.len());
        let mut submitted = Vec::with_capacity(ids.len());
        for id in ids {
            let p = self.pending.remove(id).expect("queued id has a pending entry");
            prompts.push(p.prompt);
            submitted.push(p.submitted);
        }
        let rows = self.sampler.generate(self.engine, &self.weights, &prompts, None)?;
        let done = Instant::now();
        let batch_ms = done.duration_since(t0).as_secs_f64() * 1000.0;
        let fill = ids.len() as f64 / self.sampler.model.batch as f64;

        let mut batch_tokens = 0usize;
        let mut max_wait_ms = 0f64;
        for (k, row) in rows.into_iter().enumerate() {
            let gen_tokens =
                row.iter().skip(prompts[k].len()).filter(|&&t| t != tok::PAD).count();
            batch_tokens += gen_tokens;
            let latency_ms = done.duration_since(submitted[k]).as_secs_f64() * 1000.0;
            // split: time queued before the batch launched vs time inside
            // the generation call (shared by every request in the batch)
            let wait_ms = t0.duration_since(submitted[k]).as_secs_f64() * 1000.0;
            max_wait_ms = max_wait_ms.max(wait_ms);
            self.stats.latencies_ms.push(latency_ms);
            self.stats.queue_wait_ms.push(wait_ms);
            self.stats.execute_ms.push(batch_ms);
            self.completed.push(ServeResponse { id: ids[k], row, gen_tokens, latency_ms });
        }
        self.stats.requests += ids.len();
        self.stats.batches += 1;
        self.stats.gen_tokens += batch_tokens;
        self.stats.fill_ratios.push(fill);
        self.stats.busy_secs += batch_ms / 1000.0;

        if let Some(tel) = self.telemetry.as_mut() {
            let _ = tel.append(&Json::obj(vec![
                ("event", Json::Str("batch".into())),
                ("fwd", Json::Str(self.stats.fwd_key.clone())),
                ("requests", Json::Num(ids.len() as f64)),
                ("fill_ratio", Json::Num(fill)),
                // batch_ms is the batch's execute time (kept under its
                // pre-existing name); max_queue_wait_ms is the slowest
                // request's coalescing wait before this batch launched
                ("batch_ms", Json::Num(batch_ms)),
                ("max_queue_wait_ms", Json::Num(max_wait_ms)),
                ("gen_tokens", Json::Num(batch_tokens as f64)),
            ]));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coalescer_flushes_full_batches_immediately() {
        let now = Instant::now();
        let mut c = Coalescer::new(4, Duration::from_secs(60));
        for id in 0..4 {
            c.push(id, now);
        }
        assert_eq!(c.take_ready(now, false), Some(vec![0, 1, 2, 3]));
        assert!(c.is_empty());
    }

    #[test]
    fn coalescer_holds_partial_until_deadline() {
        let now = Instant::now();
        let mut c = Coalescer::new(4, Duration::from_millis(10));
        c.push(0, now);
        c.push(1, now);
        assert_eq!(c.take_ready(now, false), None);
        // deadline reached -> partial batch goes out
        assert_eq!(c.take_ready(now + Duration::from_millis(10), false), Some(vec![0, 1]));
        assert!(c.is_empty());
    }

    #[test]
    fn coalescer_drains_ragged_tail_completely() {
        // N % batch != 0: every request must come out, in order, with the
        // expected per-batch sizes.
        let now = Instant::now();
        let mut c = Coalescer::new(4, Duration::from_secs(60));
        for id in 0..10 {
            c.push(id, now);
        }
        let mut sizes = Vec::new();
        let mut all = Vec::new();
        while let Some(ids) = c.take_ready(now, true) {
            sizes.push(ids.len());
            all.extend(ids);
        }
        assert_eq!(sizes, vec![4, 4, 2]);
        assert_eq!(all, (0..10).collect::<Vec<u64>>());
        assert!(c.is_empty());
    }

    #[test]
    fn fill_ratio_reports_partial_batches() {
        let mut stats = ServeStats::default();
        for f in [1.0, 1.0, 0.5] {
            stats.fill_ratios.push(f);
        }
        for l in [10.0, 20.0, 30.0] {
            stats.latencies_ms.push(l);
        }
        assert!((stats.mean_fill_ratio() - 2.5 / 3.0).abs() < 1e-12);
        assert_eq!(stats.latency_p(50.0), 20.0);
    }

    #[test]
    fn stats_stay_bounded_for_long_running_servers() {
        let mut stats = ServeStats::default();
        let n = 3 * crate::util::STATS_WINDOW_DEFAULT;
        for i in 0..n {
            stats.latencies_ms.push(i as f64);
            stats.fill_ratios.push(0.5);
        }
        assert_eq!(stats.latencies_ms.len(), crate::util::STATS_WINDOW_DEFAULT);
        assert_eq!(stats.latencies_ms.count(), n as u64);
        // exact lifetime mean survives the windowing
        assert!((stats.mean_fill_ratio() - 0.5).abs() < 1e-12);
        // percentiles reflect the recent window
        assert!(stats.latency_p(0.0) >= (n - crate::util::STATS_WINDOW_DEFAULT) as f64);
    }

    #[test]
    fn idle_stats_do_not_divide_by_zero() {
        let stats = ServeStats::default();
        assert_eq!(stats.req_per_sec(), 0.0);
        assert_eq!(stats.gen_tok_per_sec(), 0.0);
        assert_eq!(stats.mean_fill_ratio(), 0.0);
        assert!(stats.summary().contains("0 reqs"));
    }

    #[test]
    fn queue_wait_execute_split_lands_in_summary() {
        let mut stats = ServeStats::default();
        // three requests from one batch: same execute time, varying waits
        for w in [2.0, 5.0, 11.0] {
            stats.queue_wait_ms.push(w);
            stats.execute_ms.push(40.0);
            stats.latencies_ms.push(w + 40.0);
        }
        assert_eq!(stats.queue_wait_ms.percentile(50.0), 5.0);
        assert_eq!(stats.execute_ms.percentile(50.0), 40.0);
        let s = stats.summary();
        assert!(s.contains("wait p50 5ms"), "{s}");
        assert!(s.contains("exec p50 40ms"), "{s}");
    }
}
