//! The open recovery-method interface. The paper's six methods
//! (BF16/PTQ/QAT/QAD/MSE/NQT) are built-in implementations; new methods
//! plug in by implementing [`RecoveryMethod`] and registering — no enum to
//! grow, no dispatch sites to edit (BitDistiller- or LLM-QAT-style
//! variants differ only in loss/data wiring, i.e. in which artifacts and
//! config a method binds).

use std::collections::BTreeMap;
use std::rc::Rc;
use std::str::FromStr;

use anyhow::Result;

use crate::coordinator::distill::{run_recovery, Method, RecoveryCfg, RecoveryOutcome};

use super::session::ModelSession;

/// One accuracy-recovery method: a named strategy that turns teacher
/// weights into student weights, plus the forward artifact its students
/// are evaluated through.
pub trait RecoveryMethod {
    /// Registry key — the CLI `--method` value and checkpoint-file suffix
    /// (e.g. "qad"). Must be unique within a registry.
    fn name(&self) -> &str;

    /// Human-readable label for tables/reports (e.g. "NVFP4 QAD").
    fn display_name(&self) -> &str {
        self.name()
    }

    /// Train-step artifact key, or None for training-free methods
    /// (BF16 baseline, PTQ) whose students are the teacher weights.
    fn step_key(&self) -> Option<&str>;

    /// Forward artifact that evaluates/serves this method's students.
    fn fwd_key(&self) -> &str;

    /// Produce student weights from `teacher`. The default drives the
    /// shared method-agnostic loop (train `step_key`, §3.4 top-k
    /// checkpoint selection through `fwd_key`); override for methods
    /// that need custom orchestration.
    fn recover(
        &self,
        model: &ModelSession,
        teacher: &[f32],
        cfg: &RecoveryCfg,
    ) -> Result<RecoveryOutcome> {
        run_recovery(
            model.engine(),
            &model.rt,
            self.name(),
            self.step_key(),
            self.fwd_key(),
            teacher,
            cfg,
        )
    }
}

impl RecoveryMethod for Method {
    fn name(&self) -> &str {
        self.key()
    }

    fn display_name(&self) -> &str {
        // Inherent `Method::name` is the paper-table label.
        Method::name(self)
    }

    fn step_key(&self) -> Option<&str> {
        Method::step_key(self)
    }

    fn fwd_key(&self) -> &str {
        Method::fwd_key(self)
    }
}

/// A shared handle to a registered method (what name lookup returns).
#[derive(Clone)]
pub struct MethodRef(pub Rc<dyn RecoveryMethod>);

impl std::ops::Deref for MethodRef {
    type Target = dyn RecoveryMethod;

    fn deref(&self) -> &Self::Target {
        self.0.as_ref()
    }
}

impl std::fmt::Debug for MethodRef {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "MethodRef({})", self.0.name())
    }
}

/// Parse a method name against the built-in registry. Session-registered
/// custom methods resolve through `Session::method` instead.
impl FromStr for MethodRef {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<MethodRef> {
        MethodRegistry::builtin().resolve(s)
    }
}

/// Name → method lookup. `builtin()` seeds the six paper methods;
/// `register` adds more (later registrations shadow earlier names).
pub struct MethodRegistry {
    methods: BTreeMap<String, Rc<dyn RecoveryMethod>>,
}

impl MethodRegistry {
    pub fn empty() -> MethodRegistry {
        MethodRegistry { methods: BTreeMap::new() }
    }

    pub fn builtin() -> MethodRegistry {
        let mut reg = MethodRegistry::empty();
        for m in Method::ALL {
            reg.register(Rc::new(m));
        }
        reg
    }

    pub fn register(&mut self, method: Rc<dyn RecoveryMethod>) -> &mut Self {
        self.methods.insert(method.name().to_string(), method);
        self
    }

    pub fn get(&self, name: &str) -> Option<MethodRef> {
        self.methods.get(name).map(|m| MethodRef(m.clone()))
    }

    pub fn resolve(&self, name: &str) -> Result<MethodRef> {
        self.get(name).ok_or_else(|| {
            anyhow::anyhow!("unknown method {name:?} (known: {})", self.names().join(", "))
        })
    }

    pub fn names(&self) -> Vec<String> {
        self.methods.keys().cloned().collect()
    }
}

impl Default for MethodRegistry {
    fn default() -> Self {
        MethodRegistry::builtin()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_names_round_trip_through_fromstr() {
        let reg = MethodRegistry::builtin();
        let names = reg.names();
        assert_eq!(names.len(), 6);
        for name in names {
            let m: MethodRef = name.parse().unwrap();
            assert_eq!(m.name(), name);
        }
    }

    #[test]
    fn unknown_name_lists_known_methods() {
        let err = "frobnicate".parse::<MethodRef>().unwrap_err().to_string();
        assert!(err.contains("frobnicate") && err.contains("qad"), "{err}");
    }

    #[test]
    fn enum_shim_matches_trait_view() {
        let qad = MethodRegistry::builtin().resolve("qad").unwrap();
        assert_eq!(qad.display_name(), "NVFP4 QAD");
        assert_eq!(qad.step_key(), Some("qad_nvfp4"));
        assert_eq!(qad.fwd_key(), "fwd_nvfp4");
        let bf16 = MethodRegistry::builtin().resolve("bf16").unwrap();
        assert_eq!(bf16.step_key(), None);
        assert_eq!(bf16.fwd_key(), "fwd_bf16");
    }

    #[test]
    fn custom_method_registers_and_shadows_nothing() {
        struct Dummy;
        impl RecoveryMethod for Dummy {
            fn name(&self) -> &str {
                "dummy"
            }
            fn step_key(&self) -> Option<&str> {
                None
            }
            fn fwd_key(&self) -> &str {
                "fwd_bf16"
            }
        }
        let mut reg = MethodRegistry::builtin();
        reg.register(Rc::new(Dummy));
        assert_eq!(reg.names().len(), 7);
        assert_eq!(reg.resolve("dummy").unwrap().name(), "dummy");
        assert_eq!(reg.resolve("qad").unwrap().name(), "qad");
    }
}
