//! Scalar mini-float codecs: FP8 E4M3 (fn variant) and FP4 E2M1.
//!
//! Encoding uses value tables + round-half-to-even-mantissa, which is
//! definitionally correct (both formats have few enough codes to
//! enumerate). These are cross-validated bit-exactly against the JAX
//! oracle through the golden vectors in `artifacts/golden.json`
//! (rust/tests/golden_cross_validation.rs).

/// Maximum finite magnitude of E4M3 (fn): 0b0_1111_110 = 1.75 * 2^8.
pub const E4M3_MAX: f32 = 448.0;
/// Maximum magnitude of E2M1: 1.5 * 2^2.
pub const E2M1_MAX: f32 = 6.0;

/// Positive magnitudes of the E2M1 grid, indexed by the 3-bit magnitude code.
pub const E2M1_GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Decode an E4M3 (fn) byte to f32. Code 0x7f/0xff (NaN in the fn format)
/// decodes to NaN.
pub fn e4m3_decode(code: u8) -> f32 {
    let sign = if code & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((code >> 3) & 0x0f) as i32;
    let man = (code & 0x07) as f32;
    if exp == 0x0f && man == 7.0 {
        return f32::NAN;
    }
    if exp == 0 {
        // subnormal: m/8 * 2^-6
        sign * (man / 8.0) * 2f32.powi(-6)
    } else {
        sign * (1.0 + man / 8.0) * 2f32.powi(exp - 7)
    }
}

fn e4m3_table() -> &'static [(f32, u8)] {
    use std::sync::OnceLock;
    static TABLE: OnceLock<Vec<(f32, u8)>> = OnceLock::new();
    TABLE.get_or_init(|| {
        // All non-negative finite codes, sorted by value.
        let mut v: Vec<(f32, u8)> = (0u8..0x7f).map(|c| (e4m3_decode(c), c)).collect();
        v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        v
    })
}

/// Encode f32 to the nearest E4M3 value (round-half-to-even mantissa),
/// saturating at ±448. Returns the code byte.
pub fn e4m3_encode(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7f;
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let a = x.abs().min(E4M3_MAX);
    let t = e4m3_table();
    // Binary search for the insertion point.
    let idx = t.partition_point(|(v, _)| *v < a);
    let code = if idx == 0 {
        t[0].1
    } else if idx == t.len() {
        t[t.len() - 1].1
    } else {
        let (lo_v, lo_c) = t[idx - 1];
        let (hi_v, hi_c) = t[idx];
        let mid = (lo_v + hi_v) * 0.5;
        if a < mid {
            lo_c
        } else if a > mid {
            hi_c
        } else {
            // tie: even mantissa LSB wins
            if lo_c & 1 == 0 {
                lo_c
            } else {
                hi_c
            }
        }
    };
    sign | code
}

/// Round-trip f32 through E4M3 (the "fake quant" scalar).
pub fn e4m3_round(x: f32) -> f32 {
    e4m3_decode(e4m3_encode(x))
}

/// Encode f32 to the nearest E2M1 magnitude code (0..7) + sign bit in bit 3.
/// Round-half-to-even grid index, saturate at ±6.
pub fn e2m1_encode(x: f32) -> u8 {
    let sign = if x.is_sign_negative() { 0x8u8 } else { 0 };
    let a = x.abs().min(E2M1_MAX);
    let mut best = 0usize;
    for i in 0..E2M1_GRID.len() {
        let lo = E2M1_GRID[best];
        let hi = E2M1_GRID[i];
        let d_lo = (a - lo).abs();
        let d_hi = (a - hi).abs();
        if d_hi < d_lo || (d_hi == d_lo && i % 2 == 0) {
            best = i;
        }
    }
    sign | best as u8
}

pub fn e2m1_decode(code: u8) -> f32 {
    let mag = E2M1_GRID[(code & 0x7) as usize];
    if code & 0x8 != 0 {
        -mag
    } else {
        mag
    }
}

pub fn e2m1_round(x: f32) -> f32 {
    e2m1_decode(e2m1_encode(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e4m3_exact_values() {
        for v in [0.0f32, 1.0, -1.0, 2.0, 0.5, 448.0, -448.0, 1.5, 0.0625] {
            assert_eq!(e4m3_round(v), v, "{v}");
        }
    }

    #[test]
    fn e4m3_saturates() {
        assert_eq!(e4m3_round(1e9), 448.0);
        assert_eq!(e4m3_round(-1e9), -448.0);
    }

    #[test]
    fn e4m3_round_trip_all_codes() {
        for c in 0u8..=0xff {
            let v = e4m3_decode(c);
            if v.is_nan() {
                continue;
            }
            let c2 = e4m3_encode(v);
            assert_eq!(e4m3_decode(c2), v, "code {c:#x} -> {v} -> {c2:#x}");
        }
    }

    #[test]
    fn e4m3_subnormals() {
        let min_sub = 2f32.powi(-9);
        assert_eq!(e4m3_round(min_sub), min_sub);
        assert_eq!(e4m3_round(min_sub * 0.4), 0.0);
        assert_eq!(e4m3_round(min_sub * 0.6), min_sub);
    }

    #[test]
    fn e4m3_monotone() {
        let mut prev = f32::NEG_INFINITY;
        for i in 0..10_000 {
            let x = -500.0 + i as f32 * 0.1;
            let y = e4m3_round(x);
            assert!(y >= prev, "{x} -> {y} < {prev}");
            prev = y;
        }
    }

    #[test]
    fn e4m3_relative_error_bound() {
        // normal range: 3 mantissa bits -> rel err <= 2^-4
        let mut x = 0.02f32;
        while x < 440.0 {
            let y = e4m3_round(x);
            assert!((y - x).abs() / x <= 2f32.powi(-4) + 1e-6, "{x} -> {y}");
            x *= 1.01;
        }
    }

    #[test]
    fn e2m1_grid_and_ties() {
        for (i, v) in E2M1_GRID.iter().enumerate() {
            assert_eq!(e2m1_encode(*v) as usize, i);
        }
        // ties to even grid index
        assert_eq!(e2m1_round(0.25), 0.0);
        assert_eq!(e2m1_round(0.75), 1.0);
        assert_eq!(e2m1_round(1.25), 1.0);
        assert_eq!(e2m1_round(1.75), 2.0);
        assert_eq!(e2m1_round(2.5), 2.0);
        assert_eq!(e2m1_round(3.5), 4.0);
        assert_eq!(e2m1_round(5.0), 4.0);
        assert_eq!(e2m1_round(-2.5), -2.0);
    }

    #[test]
    fn e2m1_saturates() {
        assert_eq!(e2m1_round(100.0), 6.0);
        assert_eq!(e2m1_round(-100.0), -6.0);
    }

    #[test]
    fn e2m1_sign_bit() {
        assert_eq!(e2m1_decode(e2m1_encode(-1.5)), -1.5);
        assert_eq!(e2m1_encode(-1.5) & 0x8, 0x8);
    }
}
