//! Scalar mini-float codecs: FP8 E4M3 (fn variant) and FP4 E2M1.
//!
//! Hot-path implementations are table- and bit-driven: a const 256-entry
//! E4M3 decode LUT, a mantissa-rounding bit trick for E4M3 encode, and a
//! branchless threshold cascade (in integer bit space) for E2M1 encode.
//! All of them are bit-identical to the seed's value-table +
//! round-half-to-even-mantissa reference, which is kept under
//! `reference` (cfg(test)) as the property-test oracle, and they are
//! cross-validated bit-exactly against the JAX oracle through the golden
//! vectors in `artifacts/golden.json` (rust/tests/golden_cross_validation.rs).

/// Maximum finite magnitude of E4M3 (fn): 0b0_1111_110 = 1.75 * 2^8.
pub const E4M3_MAX: f32 = 448.0;
/// Smallest normal E4M3 magnitude: 2^-6.
pub const E4M3_MIN_NORMAL: f32 = 0.015625;
/// Maximum magnitude of E2M1: 1.5 * 2^2.
pub const E2M1_MAX: f32 = 6.0;

/// Positive magnitudes of the E2M1 grid, indexed by the 3-bit magnitude code.
pub const E2M1_GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Exact power of two as f32 (const-evaluable; exponents stay in range).
const fn pow2f(e: i32) -> f32 {
    let mut v = 1.0f32;
    let mut i = 0;
    while i < e {
        v *= 2.0;
        i += 1;
    }
    while i > e {
        v *= 0.5;
        i -= 1;
    }
    v
}

const fn e4m3_decode_scalar(code: u8) -> f32 {
    let sign = if code & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let exp = ((code >> 3) & 0x0f) as i32;
    let man = (code & 0x07) as f32;
    if exp == 0x0f && (code & 0x07) == 7 {
        return f32::NAN;
    }
    if exp == 0 {
        // subnormal: m/8 * 2^-6
        sign * (man / 8.0) * pow2f(-6)
    } else {
        sign * (1.0 + man / 8.0) * pow2f(exp - 7)
    }
}

const fn build_e4m3_decode_lut() -> [f32; 256] {
    let mut t = [0f32; 256];
    let mut c = 0usize;
    while c < 256 {
        t[c] = e4m3_decode_scalar(c as u8);
        c += 1;
    }
    t
}

/// All 256 E4M3 codes decoded to f32 (0x7f/0xff hold NaN).
pub static E4M3_DECODE_LUT: [f32; 256] = build_e4m3_decode_lut();

/// Decode an E4M3 (fn) byte to f32 — one table load. Code 0x7f/0xff (NaN
/// in the fn format) decodes to NaN.
#[inline]
pub fn e4m3_decode(code: u8) -> f32 {
    E4M3_DECODE_LUT[code as usize]
}

/// Encode f32 to the nearest E4M3 value (round-half-to-even mantissa),
/// saturating at ±448. Returns the code byte.
///
/// Normal range rounds the f32 mantissa to 3 bits directly in bit space
/// (add `half-ulp - 1 + kept-lsb`, truncate); the carry into the exponent
/// field lands on the correct next binade automatically. Subnormals
/// (|x| < 2^-6) are a round-ties-even of x·2^9; the overflow value 8 *is*
/// code 8 (exp=1, man=0), so the cast stays uniform.
#[inline]
pub fn e4m3_encode(x: f32) -> u8 {
    if x.is_nan() {
        return 0x7f;
    }
    let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
    let a = x.abs().min(E4M3_MAX);
    if a < E4M3_MIN_NORMAL {
        return sign | (a * 512.0).round_ties_even() as u8;
    }
    let bits = a.to_bits();
    let lsb = (bits >> 20) & 1;
    let r = bits + 0x0007_ffff + lsb;
    let exp = (r >> 23) - 120; // f32 bias 127 -> e4m3 bias 7
    let man = (r >> 20) & 7;
    sign | ((exp << 3) | man) as u8
}

/// Round-trip f32 through E4M3 (the "fake quant" scalar).
#[inline]
pub fn e4m3_round(x: f32) -> f32 {
    e4m3_decode(e4m3_encode(x))
}

/// Encode f32 to the nearest E2M1 magnitude code (0..7) + sign bit in bit 3.
/// Round-half-to-even grid index, saturate at ±6.
///
/// Branchless: for non-negative floats IEEE ordering equals integer
/// ordering of the bit patterns, so the seven grid midpoints become
/// integer thresholds on `bits & 0x7fff_ffff`. The `>` / `>=` alternation
/// encodes the tie-to-even-grid-index rule exactly, and the magnitude
/// clamp to 6.0 maps NaN payloads to 6.0 — the same result the reference
/// gets from `abs().min(E2M1_MAX)` (f32::min returns the non-NaN operand).
#[inline]
pub fn e2m1_encode(x: f32) -> u8 {
    let bits = x.to_bits();
    let sign = ((bits >> 28) & 8) as u8;
    let mut ab = bits & 0x7fff_ffff;
    if ab > 0x40c0_0000 {
        ab = 0x40c0_0000; // clamp to |6.0|
    }
    let idx = (ab > 0x3e80_0000) as u8   // 0.25: tie -> idx 0 (even)
        + (ab >= 0x3f40_0000) as u8      // 0.75: tie -> idx 2 (even)
        + (ab > 0x3fa0_0000) as u8       // 1.25: tie -> idx 2 (even)
        + (ab >= 0x3fe0_0000) as u8      // 1.75: tie -> idx 4 (even)
        + (ab > 0x4020_0000) as u8       // 2.5:  tie -> idx 4 (even)
        + (ab >= 0x4060_0000) as u8      // 3.5:  tie -> idx 6 (even)
        + (ab > 0x40a0_0000) as u8; // 5.0:  tie -> idx 6 (even)
    sign | idx
}

#[inline]
pub fn e2m1_decode(code: u8) -> f32 {
    let mag = E2M1_GRID[(code & 0x7) as usize];
    if code & 0x8 != 0 {
        -mag
    } else {
        mag
    }
}

#[inline]
pub fn e2m1_round(x: f32) -> f32 {
    e2m1_decode(e2m1_encode(x))
}

/// The seed's scalar codecs (value-table binary search for E4M3,
/// nearest-grid loop for E2M1) — kept verbatim as the oracle the LUT
/// implementations are property-tested against, bit for bit.
#[cfg(test)]
pub(crate) mod reference {
    use super::{E2M1_GRID, E2M1_MAX, E4M3_MAX};

    pub fn e4m3_decode(code: u8) -> f32 {
        let sign = if code & 0x80 != 0 { -1.0f32 } else { 1.0 };
        let exp = ((code >> 3) & 0x0f) as i32;
        let man = (code & 0x07) as f32;
        if exp == 0x0f && man == 7.0 {
            return f32::NAN;
        }
        if exp == 0 {
            sign * (man / 8.0) * 2f32.powi(-6)
        } else {
            sign * (1.0 + man / 8.0) * 2f32.powi(exp - 7)
        }
    }

    fn e4m3_table() -> &'static [(f32, u8)] {
        use std::sync::OnceLock;
        static TABLE: OnceLock<Vec<(f32, u8)>> = OnceLock::new();
        TABLE.get_or_init(|| {
            let mut v: Vec<(f32, u8)> = (0u8..0x7f).map(|c| (e4m3_decode(c), c)).collect();
            v.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            v
        })
    }

    pub fn e4m3_encode(x: f32) -> u8 {
        if x.is_nan() {
            return 0x7f;
        }
        let sign = if x.is_sign_negative() { 0x80u8 } else { 0 };
        let a = x.abs().min(E4M3_MAX);
        let t = e4m3_table();
        let idx = t.partition_point(|(v, _)| *v < a);
        let code = if idx == 0 {
            t[0].1
        } else if idx == t.len() {
            t[t.len() - 1].1
        } else {
            let (lo_v, lo_c) = t[idx - 1];
            let (hi_v, hi_c) = t[idx];
            let mid = (lo_v + hi_v) * 0.5;
            if a < mid {
                lo_c
            } else if a > mid {
                hi_c
            } else if lo_c & 1 == 0 {
                lo_c
            } else {
                hi_c
            }
        };
        sign | code
    }

    pub fn e2m1_encode(x: f32) -> u8 {
        let sign = if x.is_sign_negative() { 0x8u8 } else { 0 };
        let a = x.abs().min(E2M1_MAX);
        let mut best = 0usize;
        for i in 0..E2M1_GRID.len() {
            let lo = E2M1_GRID[best];
            let hi = E2M1_GRID[i];
            let d_lo = (a - lo).abs();
            let d_hi = (a - hi).abs();
            if d_hi < d_lo || (d_hi == d_lo && i % 2 == 0) {
                best = i;
            }
        }
        sign | best as u8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn e4m3_exact_values() {
        for v in [0.0f32, 1.0, -1.0, 2.0, 0.5, 448.0, -448.0, 1.5, 0.0625] {
            assert_eq!(e4m3_round(v), v, "{v}");
        }
    }

    #[test]
    fn e4m3_saturates() {
        assert_eq!(e4m3_round(1e9), 448.0);
        assert_eq!(e4m3_round(-1e9), -448.0);
    }

    #[test]
    fn e4m3_round_trip_all_codes() {
        for c in 0u8..=0xff {
            let v = e4m3_decode(c);
            if v.is_nan() {
                continue;
            }
            let c2 = e4m3_encode(v);
            assert_eq!(e4m3_decode(c2), v, "code {c:#x} -> {v} -> {c2:#x}");
        }
    }

    #[test]
    fn e4m3_subnormals() {
        let min_sub = 2f32.powi(-9);
        assert_eq!(e4m3_round(min_sub), min_sub);
        assert_eq!(e4m3_round(min_sub * 0.4), 0.0);
        assert_eq!(e4m3_round(min_sub * 0.6), min_sub);
    }

    #[test]
    fn e4m3_monotone() {
        let mut prev = f32::NEG_INFINITY;
        for i in 0..10_000 {
            let x = -500.0 + i as f32 * 0.1;
            let y = e4m3_round(x);
            assert!(y >= prev, "{x} -> {y} < {prev}");
            prev = y;
        }
    }

    #[test]
    fn e4m3_relative_error_bound() {
        // normal range: 3 mantissa bits -> rel err <= 2^-4
        let mut x = 0.02f32;
        while x < 440.0 {
            let y = e4m3_round(x);
            assert!((y - x).abs() / x <= 2f32.powi(-4) + 1e-6, "{x} -> {y}");
            x *= 1.01;
        }
    }

    #[test]
    fn e2m1_grid_and_ties() {
        for (i, v) in E2M1_GRID.iter().enumerate() {
            assert_eq!(e2m1_encode(*v) as usize, i);
        }
        // ties to even grid index
        assert_eq!(e2m1_round(0.25), 0.0);
        assert_eq!(e2m1_round(0.75), 1.0);
        assert_eq!(e2m1_round(1.25), 1.0);
        assert_eq!(e2m1_round(1.75), 2.0);
        assert_eq!(e2m1_round(2.5), 2.0);
        assert_eq!(e2m1_round(3.5), 4.0);
        assert_eq!(e2m1_round(5.0), 4.0);
        assert_eq!(e2m1_round(-2.5), -2.0);
    }

    #[test]
    fn e2m1_saturates() {
        assert_eq!(e2m1_round(100.0), 6.0);
        assert_eq!(e2m1_round(-100.0), -6.0);
    }

    #[test]
    fn e2m1_sign_bit() {
        assert_eq!(e2m1_decode(e2m1_encode(-1.5)), -1.5);
        assert_eq!(e2m1_encode(-1.5) & 0x8, 0x8);
    }

    // ---- LUT-vs-reference property tests --------------------------------

    #[test]
    fn e4m3_lut_decode_matches_reference_all_256_codes() {
        for c in 0u8..=0xff {
            let lut = e4m3_decode(c);
            let oracle = reference::e4m3_decode(c);
            assert!(
                lut.to_bits() == oracle.to_bits()
                    || (lut.is_nan() && oracle.is_nan()),
                "code {c:#x}: lut {lut} vs reference {oracle}"
            );
        }
    }

    #[test]
    fn e4m3_encode_matches_reference_on_grid_and_midpoints() {
        // every code value, every value-space midpoint, and ±1-ulp
        // neighbours of the midpoints: the complete set of tie cases.
        let mut vals: Vec<f32> = (0u8..0x7f).map(reference::e4m3_decode).collect();
        vals.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut cases = vals.clone();
        for w in vals.windows(2) {
            let mid = (w[0] + w[1]) * 0.5;
            cases.push(mid);
            if mid > 0.0 {
                cases.push(f32::from_bits(mid.to_bits() - 1));
                cases.push(f32::from_bits(mid.to_bits() + 1));
            }
        }
        cases.extend([0.0, -0.0, f32::INFINITY, f32::NEG_INFINITY, 449.0, 1e30]);
        for &v in &cases {
            for x in [v, -v] {
                assert_eq!(
                    e4m3_encode(x),
                    reference::e4m3_encode(x),
                    "e4m3_encode({x}) diverges from the reference"
                );
            }
        }
    }

    #[test]
    fn encoders_match_reference_on_random_bit_patterns() {
        // raw u32 bit patterns cover every float class: normals across all
        // binades, subnormals, zeros, infinities, and NaN payloads.
        let mut r = Rng::new(0xB17F10A7);
        for _ in 0..200_000 {
            let x = f32::from_bits(r.next_u64() as u32);
            assert_eq!(
                e4m3_encode(x),
                reference::e4m3_encode(x),
                "e4m3_encode({x} = {:#010x})",
                x.to_bits()
            );
            assert_eq!(
                e2m1_encode(x),
                reference::e2m1_encode(x),
                "e2m1_encode({x} = {:#010x})",
                x.to_bits()
            );
        }
    }

    #[test]
    fn e2m1_matches_reference_at_thresholds() {
        for t in [0.25f32, 0.75, 1.25, 1.75, 2.5, 3.5, 5.0, 6.0] {
            for x in [
                t,
                -t,
                f32::from_bits(t.to_bits() - 1),
                f32::from_bits(t.to_bits() + 1),
            ] {
                assert_eq!(e2m1_encode(x), reference::e2m1_encode(x), "{x}");
            }
        }
    }
}
