//! Baseline 4-bit formats the paper compares NVFP4 against: MXFP4
//! (block-32, power-of-two E8M0 scales) and symmetric INT4 (per-channel
//! scale). Mirror the JAX references in python/compile/kernels/ref.py.
//! Both fake-quants run block-/row-parallel over `util::pool` chunks
//! (independent scale groups, so results are thread-count-invariant) and
//! have `*_into` variants that reuse the caller's output allocation.

use super::fp::e2m1_round;
use crate::util::pool;

pub const MXFP4_BLOCK: usize = 32;

/// Scale blocks per parallel chunk for mxfp4 (8 KiB of input).
const MX_BLOCKS_PER_CHUNK: usize = 64;

/// MXFP4 fake-quant of a row-major (rows, cols) tensor; cols % 32 == 0.
/// Shared scale per block is 2^(floor(log2(amax)) - 2) (E8M0 semantics).
pub fn mxfp4_fake_quant(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(x.len());
    mxfp4_fake_quant_into(x, rows, cols, &mut out);
    out
}

/// MXFP4 fake-quant into a caller-provided Vec (cleared and refilled).
pub fn mxfp4_fake_quant_into(x: &[f32], rows: usize, cols: usize, out: &mut Vec<f32>) {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(cols % MXFP4_BLOCK, 0);
    out.clear();
    out.resize(x.len(), 0.0);
    pool::for_chunks(x.len() * 6, out, MX_BLOCKS_PER_CHUNK * MXFP4_BLOCK, |ci, out_chunk| {
        let base = ci * MX_BLOCKS_PER_CHUNK * MXFP4_BLOCK;
        for (bb, o) in out_chunk.chunks_exact_mut(MXFP4_BLOCK).enumerate() {
            let blk = &x[base + bb * MXFP4_BLOCK..base + (bb + 1) * MXFP4_BLOCK];
            let amax = blk.iter().fold(0f32, |m, v| m.max(v.abs()));
            if amax == 0.0 {
                continue;
            }
            let e = amax.log2().floor() - 2.0;
            let scale = e.exp2();
            // hoisted reciprocal: exact for a power-of-two scale unless it
            // leaves the normal range (then divide, bit-identical either way)
            let inv = 1.0 / scale;
            if inv.is_normal() {
                for (ov, &v) in o.iter_mut().zip(blk) {
                    *ov = e2m1_round(v * inv) * scale;
                }
            } else {
                for (ov, &v) in o.iter_mut().zip(blk) {
                    *ov = e2m1_round(v / scale) * scale;
                }
            }
        }
    });
}

/// Symmetric INT4 per-channel (row) fake-quant, grid -7..7.
pub fn int4_fake_quant(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(x.len());
    int4_fake_quant_into(x, rows, cols, &mut out);
    out
}

/// INT4 fake-quant into a caller-provided Vec (cleared and refilled).
/// Row-parallel: each channel's scale group is independent.
pub fn int4_fake_quant_into(x: &[f32], rows: usize, cols: usize, out: &mut Vec<f32>) {
    assert_eq!(x.len(), rows * cols);
    out.clear();
    out.resize(x.len(), 0.0);
    if cols == 0 {
        return;
    }
    pool::for_chunks(x.len() * 5, out, cols, |i, o| {
        let row = &x[i * cols..(i + 1) * cols];
        let amax = row.iter().fold(0f32, |m, v| m.max(v.abs()));
        let s = if amax > 0.0 { amax / 7.0 } else { 1.0 };
        // s = amax/7 is not a power of two, so the division must stay
        // exact — a rounded reciprocal flips q at round-half midpoints
        for (ov, &v) in o.iter_mut().zip(row) {
            let q = (v / s).round().clamp(-7.0, 7.0);
            *ov = q * s;
        }
    });
}

/// BF16 rounding (truncate-with-RNE of the low 16 f32 bits) — used when
/// simulating the "BF16 baseline" storage.
pub fn bf16_round(x: f32) -> f32 {
    let bits = x.to_bits();
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7fff + lsb);
    f32::from_bits(rounded & 0xffff_0000)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::nvfp4;
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() as f32).collect()
    }

    #[test]
    fn mxfp4_error_band() {
        let x = randn(64 * 64, 1);
        let q = mxfp4_fake_quant(&x, 64, 64);
        let rel = nvfp4::rel_error(&x, &q);
        assert!(rel > 0.03 && rel < 0.30, "rel {rel}");
    }

    #[test]
    fn mxfp4_scale_is_power_of_two() {
        // All quantized values must be e2m1-grid values times 2^k.
        let x = randn(32, 2);
        let q = mxfp4_fake_quant(&x, 1, 32);
        for v in q {
            if v == 0.0 {
                continue;
            }
            let mut m = v.abs();
            while m > 6.0 {
                m /= 2.0;
            }
            while m < 3.0 {
                m *= 2.0;
            }
            // m in (3, 6]: grid values reachable by scaling are 3, 4, 6, 5?? —
            // e2m1 grid {0.5..6} * 2^k lands m in {3,4,6} ∪ {5? no} within (3,6]
            assert!(
                [3.0f32, 4.0, 6.0].iter().any(|g| (m - g).abs() < 1e-5),
                "value {v} not on a po2-scaled grid (m={m})"
            );
        }
    }

    #[test]
    fn nvfp4_beats_mxfp4_with_outliers() {
        let mut r = Rng::new(3);
        let mut x = randn(64 * 128, 4);
        for _ in 0..32 {
            let i = r.below(x.len());
            x[i] *= 50.0;
        }
        let err_nv = nvfp4::rel_error(&x, &nvfp4::fake_quant(&x, 64, 128));
        let err_mx = nvfp4::rel_error(&x, &mxfp4_fake_quant(&x, 64, 128));
        assert!(err_nv < err_mx, "nv {err_nv} mx {err_mx}");
    }

    #[test]
    fn int4_grid() {
        let x = vec![7.0, -7.0, 3.5, 0.0, 1.0, 2.0, -3.0, 5.0];
        let q = int4_fake_quant(&x, 1, 8);
        let s = 1.0f32; // amax 7 / 7
        for (a, b) in x.iter().zip(&q) {
            assert!((a / s).round().clamp(-7.0, 7.0) * s == *b);
        }
    }

    #[test]
    fn baseline_codecs_thread_invariant_and_into_variants_reuse() {
        // 128x128 = 16384 elements puts both codecs past PAR_MIN_WORK,
        // so the 4-thread run exercises the parallel partition.
        let (r, c) = (128usize, 128usize);
        let x = randn(r * c, 11);
        let run = |t: usize| {
            crate::util::pool::with_threads(t, || {
                (mxfp4_fake_quant(&x, r, c), int4_fake_quant(&x, r, c))
            })
        };
        let (m1, i1) = run(1);
        let (m4, i4) = run(4);
        for (a, b) in m1.iter().zip(&m4).chain(i1.iter().zip(&i4)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        let mut buf = vec![7f32; 3]; // stale contents + wrong size
        mxfp4_fake_quant_into(&x, r, c, &mut buf);
        assert_eq!(buf.len(), r * c);
        for (a, b) in buf.iter().zip(&m1) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        int4_fake_quant_into(&x, r, c, &mut buf);
        for (a, b) in buf.iter().zip(&i1) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bf16_round_exact_for_bf16_values() {
        for v in [1.0f32, -2.5, 0.15625, 448.0] {
            let r = bf16_round(v);
            assert_eq!(bf16_round(r), r);
        }
        // bf16 has 8 mantissa bits: rel err <= 2^-9
        let x = 1.2345678f32;
        assert!((bf16_round(x) - x).abs() / x < 2f32.powi(-8));
    }
}
