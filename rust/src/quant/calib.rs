//! PTQ calibration: choose the per-tensor FP32 scale from calibration data.
//!
//! The paper's PTQ baseline uses max calibration (§2.1); we also provide
//! percentile clipping and an MSE sweep (the "more sophisticated" methods
//! the paper surveys) for the calibration ablation bench.

use super::fp::{E2M1_MAX, E4M3_MAX};
use super::nvfp4::{rel_error, Nvfp4Tensor};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CalibMethod {
    /// amax / (6 * 448) — the paper's default.
    Max,
    /// Clip at the p-th percentile of |x| (p in tenths of a percent: 999 = 99.9%).
    Percentile(u32),
    /// Sweep clipping factors in [0.3, 1.0], keep the one minimizing
    /// reconstruction MSE on the calibration tensor.
    MseSweep,
}

/// Compute the per-tensor scale for NVFP4 from calibration samples.
/// `rows`/`cols` describe the layout used for the error sweep.
pub fn calibrate(x: &[f32], rows: usize, cols: usize, method: CalibMethod) -> f32 {
    let amax = x.iter().fold(0f32, |m, v| m.max(v.abs()));
    if amax == 0.0 {
        return 1.0;
    }
    match method {
        CalibMethod::Max => amax / (E2M1_MAX * E4M3_MAX),
        CalibMethod::Percentile(tenths) => {
            let mut mags: Vec<f32> = x.iter().map(|v| v.abs()).collect();
            mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let q = (tenths as f64 / 1000.0).clamp(0.0, 1.0);
            let idx = ((mags.len() - 1) as f64 * q).round() as usize;
            (mags[idx].max(f32::MIN_POSITIVE)) / (E2M1_MAX * E4M3_MAX)
        }
        CalibMethod::MseSweep => {
            let mut best = (f64::INFINITY, amax / (E2M1_MAX * E4M3_MAX));
            for i in 0..15 {
                let factor = 0.3 + 0.05 * i as f32;
                let ts = amax * factor / (E2M1_MAX * E4M3_MAX);
                let q = Nvfp4Tensor::quantize(x, rows, cols, Some(ts)).dequantize();
                let err = rel_error(x, &q);
                if err < best.0 {
                    best = (err, ts);
                }
            }
            best.1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() as f32).collect()
    }

    #[test]
    fn max_matches_formula() {
        let x = randn(256, 1);
        let amax = x.iter().fold(0f32, |m, v| m.max(v.abs()));
        assert_eq!(calibrate(&x, 16, 16, CalibMethod::Max), amax / (6.0 * 448.0));
    }

    #[test]
    fn percentile_clips_outliers() {
        let mut x = randn(4096, 2);
        x[0] = 1e6;
        let s_max = calibrate(&x, 256, 16, CalibMethod::Max);
        let s_p999 = calibrate(&x, 256, 16, CalibMethod::Percentile(999));
        assert!(s_p999 < s_max / 100.0, "{s_p999} vs {s_max}");
    }

    #[test]
    fn mse_sweep_never_worse_than_max_by_much() {
        let x = randn(64 * 16, 3);
        let s_mse = calibrate(&x, 64, 16, CalibMethod::MseSweep);
        let q_max = Nvfp4Tensor::quantize(&x, 64, 16, None).dequantize();
        let q_mse = Nvfp4Tensor::quantize(&x, 64, 16, Some(s_mse)).dequantize();
        let e_max = rel_error(&x, &q_max);
        let e_mse = rel_error(&x, &q_mse);
        assert!(e_mse <= e_max + 1e-9, "mse {e_mse} max {e_max}");
    }

    #[test]
    fn zero_input_safe() {
        let x = vec![0f32; 64];
        for m in [CalibMethod::Max, CalibMethod::Percentile(990), CalibMethod::MseSweep] {
            assert_eq!(calibrate(&x, 4, 16, m), 1.0);
        }
    }
}
