//! NVFP4 tensor codec: block-16 E2M1 values + per-block E4M3 scales +
//! per-tensor FP32 scale, with real 4-bit packing (two codes per byte).
//!
//! Matches the JAX reference (python/compile/kernels/ref.py) bit-exactly —
//! verified through golden vectors in rust/tests/. Used by the coordinator
//! for PTQ weight export, checkpoint size accounting (the paper's ~1.8×
//! memory-reduction claim vs FP8), and quantization-error analysis.
//!
//! Hot-path layout: the codec runs block-parallel over the independent
//! 16-element scale blocks (chunked through `util::pool`, deterministic
//! at every thread count), with the per-block denominator (E4M3 LUT
//! decode × tensor scale) hoisted out of the element loop, a branchless
//! E2M1 encode, and a 256-entry nibble-pair LUT on the dequantize side.
//! The scale division stays exact (a rounded reciprocal can flip codes at
//! grid midpoints). `fake_quant` fuses encode+decode per element — no
//! packed intermediates — and the `*_into` variants reuse caller buffers
//! so per-GEMM fake-quant in the reference model stops allocating. All of
//! it is bit-identical to the seed's scalar loop for *all* inputs, with
//! the seed kept under `reference` (cfg(test)) as the property-test
//! oracle.

use super::fp::{e2m1_encode, e2m1_round, e4m3_decode, e4m3_encode, E2M1_GRID, E2M1_MAX, E4M3_MAX};
use crate::util::pool;

pub const BLOCK: usize = 16;

/// Scale blocks per parallel chunk (16 KiB of input per chunk).
const BLOCKS_PER_CHUNK: usize = 256;

const fn e2m1_decode_const(code: u8) -> f32 {
    let mag = E2M1_GRID[(code & 0x7) as usize];
    if code & 0x8 != 0 {
        -mag
    } else {
        mag
    }
}

const fn build_nibble_pair_lut() -> [[f32; 2]; 256] {
    let mut t = [[0f32; 2]; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b] = [e2m1_decode_const((b & 0x0f) as u8), e2m1_decode_const((b >> 4) as u8)];
        b += 1;
    }
    t
}

/// Both nibbles of every packed code byte decoded at once:
/// `[low nibble (element 2i), high nibble (element 2i+1)]`. Shared with
/// the quantized-domain GEMM kernels (`quant::packed`).
pub(crate) static NIBBLE_PAIR_LUT: [[f32; 2]; 256] = build_nibble_pair_lut();

/// A quantized tensor: packed payload + two-level scales.
#[derive(Clone, Debug)]
pub struct Nvfp4Tensor {
    /// Row-major packed E2M1 codes; element 2i in low nibble, 2i+1 in high.
    pub codes: Vec<u8>,
    /// One E4M3 code per 16-element block, row-major.
    pub block_scales: Vec<u8>,
    /// Second-level FP32 scale.
    pub tensor_scale: f32,
    pub rows: usize,
    pub cols: usize,
}

/// Per-tensor FP32 scale: maps tensor amax onto E2M1_MAX * E4M3_MAX.
/// (The amax reduction is chunk-parallel; f32 max is order-insensitive.)
pub fn tensor_scale(x: &[f32]) -> f32 {
    let amax = pool::max_abs(x);
    if amax > 0.0 {
        amax / (E2M1_MAX * E4M3_MAX)
    } else {
        1.0
    }
}

/// One scale block: E4M3 scale code + the 8 packed payload bytes.
/// The op sequence per element is exactly the seed's (scale → exact
/// divide → branchless encode → nibble pack). Shared with the packed
/// weight layout (`quant::packed`) so both sides stay bit-identical.
#[inline]
pub(crate) fn quantize_block(blk: &[f32], ts: f32, bytes: &mut [u8]) -> u8 {
    let amax = blk.iter().fold(0f32, |m, v| m.max(v.abs()));
    let raw = (amax / E2M1_MAX / ts).clamp(-E4M3_MAX, E4M3_MAX);
    let sb = e4m3_encode(raw);
    // denom = sb*ts first — the exact multiplication order of the JAX
    // oracle (bit-exactness checked by the golden tests). The division
    // stays exact: a rounded reciprocal can flip codes at grid midpoints.
    let denom = e4m3_decode(sb) * ts;
    for (byte, pair) in bytes.iter_mut().zip(blk.chunks_exact(2)) {
        if denom > 0.0 {
            *byte = e2m1_encode(pair[0] / denom) | (e2m1_encode(pair[1] / denom) << 4);
        } else {
            // matches the reference's denom==0 branch (y stays 0.0)
            *byte = 0;
        }
    }
    sb
}

impl Nvfp4Tensor {
    /// Quantize a (rows, cols) row-major tensor; cols must be /16.
    /// `ts`: calibrated tensor scale, or None for dynamic (max) calibration.
    pub fn quantize(x: &[f32], rows: usize, cols: usize, ts: Option<f32>) -> Self {
        let mut t = Nvfp4Tensor {
            codes: Vec::new(),
            block_scales: Vec::new(),
            tensor_scale: 1.0,
            rows,
            cols,
        };
        Nvfp4Tensor::quantize_into(x, rows, cols, ts, &mut t);
        t
    }

    /// Quantize into an existing tensor, reusing its `codes` /
    /// `block_scales` allocations (the hot-path variant). Block-parallel
    /// over the independent 16-element scale blocks.
    pub fn quantize_into(
        x: &[f32],
        rows: usize,
        cols: usize,
        ts: Option<f32>,
        t: &mut Nvfp4Tensor,
    ) {
        assert_eq!(x.len(), rows * cols, "shape mismatch");
        assert_eq!(cols % BLOCK, 0, "cols {cols} not a multiple of {BLOCK}");
        let ts = ts.unwrap_or_else(|| tensor_scale(x));
        let n = rows * cols;
        let n_blocks = n / BLOCK;
        t.codes.clear();
        t.codes.resize(n / 2, 0);
        t.block_scales.clear();
        t.block_scales.resize(n_blocks, 0);
        t.tensor_scale = ts;
        t.rows = rows;
        t.cols = cols;
        pool::for_chunks2(
            n * 6,
            &mut t.codes,
            BLOCKS_PER_CHUNK * BLOCK / 2,
            &mut t.block_scales,
            BLOCKS_PER_CHUNK,
            |ci, code_chunk, scale_chunk| {
                let b0 = ci * BLOCKS_PER_CHUNK;
                for (bb, sb) in scale_chunk.iter_mut().enumerate() {
                    let blk = &x[(b0 + bb) * BLOCK..(b0 + bb + 1) * BLOCK];
                    let bytes = &mut code_chunk[bb * BLOCK / 2..(bb + 1) * BLOCK / 2];
                    *sb = quantize_block(blk, ts, bytes);
                }
            },
        );
    }

    pub fn code_at(&self, idx: usize) -> u8 {
        let byte = self.codes[idx / 2];
        if idx % 2 == 0 {
            byte & 0x0f
        } else {
            byte >> 4
        }
    }

    /// Dequantize back to f32 — exactly what the NVFP4 GEMM datapath sees.
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.rows * self.cols];
        self.dequantize_into(&mut out);
        out
    }

    /// Dequantize into a caller-provided slice (len must be rows*cols) —
    /// the allocation-free hot path: one nibble-pair LUT load + two
    /// multiplies per packed byte, block denominator hoisted,
    /// block-parallel over scale-block chunks.
    pub fn dequantize_into(&self, out: &mut [f32]) {
        let n = self.rows * self.cols;
        assert_eq!(out.len(), n, "output slice shape mismatch");
        pool::for_chunks(n * 3, out, BLOCKS_PER_CHUNK * BLOCK, |ci, out_chunk| {
            let b0 = ci * BLOCKS_PER_CHUNK;
            for (bb, o) in out_chunk.chunks_exact_mut(BLOCK).enumerate() {
                let sb = self.block_scales[b0 + bb];
                let bytes = &self.codes[(b0 + bb) * BLOCK / 2..(b0 + bb + 1) * BLOCK / 2];
                // denom = sb*ts first — the exact multiplication order of
                // the JAX oracle (bit-exactness checked by golden tests).
                let denom = e4m3_decode(sb) * self.tensor_scale;
                for (pair, &byte) in o.chunks_exact_mut(2).zip(bytes) {
                    let d = &NIBBLE_PAIR_LUT[byte as usize];
                    pair[0] = d[0] * denom;
                    pair[1] = d[1] * denom;
                }
            }
        });
    }

    /// Stored size in bytes: packed nibbles + E4M3 scales + f32 tensor scale.
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.block_scales.len() + 4
    }

    /// Effective bits per element.
    pub fn bits_per_element(&self) -> f64 {
        self.storage_bytes() as f64 * 8.0 / (self.rows * self.cols) as f64
    }
}

/// One-shot fake-quant (quantize + dequantize) of a row-major tensor.
pub fn fake_quant(x: &[f32], rows: usize, cols: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity(x.len());
    fake_quant_into(x, rows, cols, &mut out);
    out
}

/// Fake-quant into a caller-provided Vec (cleared and refilled — reuses
/// its allocation): the per-GEMM hot path of the reference model.
///
/// Fused per block: encode+decode per element with no packed
/// intermediates. The op sequence is exactly quantize→dequantize
/// (`e2m1_round(v / denom) * denom`, with the reference's denom==0
/// branch), so the result is bit-identical to the two-step codec —
/// asserted by the property tests. Block-parallel like the codec.
pub fn fake_quant_into(x: &[f32], rows: usize, cols: usize, out: &mut Vec<f32>) {
    assert_eq!(x.len(), rows * cols, "shape mismatch");
    assert_eq!(cols % BLOCK, 0, "cols {cols} not a multiple of {BLOCK}");
    let ts = tensor_scale(x);
    let n = rows * cols;
    out.clear();
    out.resize(n, 0.0);
    pool::for_chunks(n * 8, out, BLOCKS_PER_CHUNK * BLOCK, |ci, out_chunk| {
        let base = ci * BLOCKS_PER_CHUNK * BLOCK;
        for (bb, o) in out_chunk.chunks_exact_mut(BLOCK).enumerate() {
            let blk = &x[base + bb * BLOCK..base + (bb + 1) * BLOCK];
            let amax = blk.iter().fold(0f32, |m, v| m.max(v.abs()));
            let raw = (amax / E2M1_MAX / ts).clamp(-E4M3_MAX, E4M3_MAX);
            let denom = e4m3_decode(e4m3_encode(raw)) * ts;
            if denom > 0.0 {
                for (ov, &v) in o.iter_mut().zip(blk) {
                    *ov = e2m1_round(v / denom) * denom;
                }
            } else {
                // quantize leaves all codes 0; dequantize multiplies the
                // decoded 0.0 by denom — keep the same op for bit-parity
                for ov in o.iter_mut() {
                    *ov = 0.0 * denom;
                }
            }
        }
    });
}

/// Relative Frobenius quantization error ‖q−x‖/‖x‖.
pub fn rel_error(x: &[f32], q: &[f32]) -> f64 {
    let num: f64 = x.iter().zip(q).map(|(a, b)| ((a - b) as f64).powi(2)).sum();
    let den: f64 = x.iter().map(|a| (*a as f64).powi(2)).sum();
    if den == 0.0 {
        0.0
    } else {
        (num / den).sqrt()
    }
}

/// The seed's scalar codec loop (per-element division, per-element scale
/// decode), built on the `fp::reference` oracle — the bit-for-bit ground
/// truth for the LUT property tests.
#[cfg(test)]
pub(crate) mod reference {
    use super::super::fp::{e2m1_decode, reference as fpref};
    use super::{Nvfp4Tensor, BLOCK, E2M1_MAX, E4M3_MAX};

    pub fn quantize(x: &[f32], rows: usize, cols: usize, ts: Option<f32>) -> Nvfp4Tensor {
        assert_eq!(x.len(), rows * cols, "shape mismatch");
        assert_eq!(cols % BLOCK, 0);
        let ts = ts.unwrap_or_else(|| super::tensor_scale(x));
        let n_blocks = rows * cols / BLOCK;
        let mut codes = vec![0u8; (rows * cols + 1) / 2];
        let mut block_scales = vec![0u8; n_blocks];
        for b in 0..n_blocks {
            let start = b * BLOCK;
            let blk = &x[start..start + BLOCK];
            let amax = blk.iter().fold(0f32, |m, v| m.max(v.abs()));
            let raw = (amax / E2M1_MAX / ts).clamp(-E4M3_MAX, E4M3_MAX);
            let sb_code = fpref::e4m3_encode(raw);
            block_scales[b] = sb_code;
            let denom = fpref::e4m3_decode(sb_code) * ts;
            for (j, &v) in blk.iter().enumerate() {
                let y = if denom > 0.0 { v / denom } else { 0.0 };
                let c = fpref::e2m1_encode(y);
                let idx = start + j;
                if idx % 2 == 0 {
                    codes[idx / 2] |= c;
                } else {
                    codes[idx / 2] |= c << 4;
                }
            }
        }
        Nvfp4Tensor { codes, block_scales, tensor_scale: ts, rows, cols }
    }

    pub fn dequantize(t: &Nvfp4Tensor) -> Vec<f32> {
        let n = t.rows * t.cols;
        let mut out = vec![0f32; n];
        for (i, o) in out.iter_mut().enumerate() {
            let denom = fpref::e4m3_decode(t.block_scales[i / BLOCK]) * t.tensor_scale;
            *o = e2m1_decode(t.code_at(i)) * denom;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(n: usize, seed: u64, scale: f32) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| r.normal() as f32 * scale).collect()
    }

    #[test]
    fn round_trip_error_band() {
        let x = randn(64 * 64, 1, 1.0);
        let q = fake_quant(&x, 64, 64);
        let rel = rel_error(&x, &q);
        assert!(rel > 0.03 && rel < 0.20, "rel {rel}");
    }

    #[test]
    fn idempotent() {
        let x = randn(32 * 32, 2, 3.0);
        let q1 = fake_quant(&x, 32, 32);
        let q2 = fake_quant(&q1, 32, 32);
        for (a, b) in q1.iter().zip(&q2) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn zero_tensor() {
        let x = vec![0f32; 256];
        let t = Nvfp4Tensor::quantize(&x, 16, 16, None);
        assert_eq!(t.tensor_scale, 1.0);
        assert!(t.dequantize().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn packing_layout() {
        // Distinct values in one block land in the right nibbles.
        let mut x = vec![0f32; 16];
        x[0] = 6.0;
        x[1] = -6.0;
        let t = Nvfp4Tensor::quantize(&x, 1, 16, None);
        assert_eq!(t.code_at(0) & 0x7, 7); // |6| is grid idx 7
        assert_eq!(t.code_at(1), 0x8 | 7); // negative 6
        assert_eq!(t.code_at(2), 0);
    }

    #[test]
    fn storage_is_about_4_5_bits() {
        let x = randn(128 * 128, 3, 1.0);
        let t = Nvfp4Tensor::quantize(&x, 128, 128, None);
        let bpe = t.bits_per_element();
        // 4 bits payload + 8/16 bits scale = 4.5 plus epsilon
        assert!(bpe > 4.4 && bpe < 4.7, "bits/elem {bpe}");
    }

    #[test]
    fn memory_reduction_vs_fp8() {
        let x = randn(256 * 256, 4, 1.0);
        let t = Nvfp4Tensor::quantize(&x, 256, 256, None);
        let fp8_bytes = x.len(); // 1 byte/elem (ignoring fp8 scales)
        let ratio = fp8_bytes as f64 / t.storage_bytes() as f64;
        assert!(ratio > 1.7 && ratio < 1.9, "ratio {ratio}"); // paper: ~1.8x
    }

    #[test]
    fn scale_equivariance() {
        let x = randn(16 * 32, 5, 1.0);
        let q1 = fake_quant(&x, 16, 32);
        let xs: Vec<f32> = x.iter().map(|v| v * 1024.0).collect();
        let q2 = fake_quant(&xs, 16, 32);
        for (a, b) in q1.iter().zip(&q2) {
            assert!((a * 1024.0 - b).abs() <= (b.abs() * 1e-5).max(1e-6));
        }
    }

    #[test]
    fn calibrated_scale_respected() {
        let x = randn(16 * 16, 6, 1.0);
        let t = Nvfp4Tensor::quantize(&x, 16, 16, Some(0.01));
        assert_eq!(t.tensor_scale, 0.01);
    }

    #[test]
    fn outlier_containment() {
        let mut x = randn(64, 7, 1.0);
        x[0] = 1000.0;
        let q = fake_quant(&x, 1, 64);
        // blocks 1..3 (elements 16..64) must keep sane error
        let rel = rel_error(&x[16..], &q[16..]);
        assert!(rel < 0.25, "rel {rel}");
    }

    #[test]
    fn dequantize_into_matches_dequantize() {
        let x = randn(32 * 32, 8, 1.0);
        let t = Nvfp4Tensor::quantize(&x, 32, 32, None);
        let a = t.dequantize();
        let mut b = vec![0f32; 32 * 32];
        t.dequantize_into(&mut b);
        assert_eq!(a, b);
    }

    // ---- LUT-vs-reference property tests --------------------------------

    fn assert_codec_bit_identical(x: &[f32], rows: usize, cols: usize) {
        let fast = Nvfp4Tensor::quantize(x, rows, cols, None);
        let oracle = reference::quantize(x, rows, cols, None);
        assert_eq!(
            fast.tensor_scale.to_bits(),
            oracle.tensor_scale.to_bits(),
            "tensor scale diverged"
        );
        assert_eq!(fast.block_scales, oracle.block_scales, "block scales diverged");
        assert_eq!(fast.codes, oracle.codes, "packed codes diverged");
        let deq_fast = fast.dequantize();
        let deq_oracle = reference::dequantize(&oracle);
        for (i, (a, b)) in deq_fast.iter().zip(&deq_oracle).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "dequant bit mismatch at {i}");
        }
    }

    #[test]
    fn lut_codec_bit_identical_to_reference_randomized() {
        // Randomized tensors across magnitudes (incl. a near-subnormal
        // scale) — the full codec must agree with the seed's scalar
        // reference bit for bit, which holds for arbitrary inputs because
        // the element ops are exhaustively-equivalent encodes plus the
        // same exact division.
        for k in 0..8u64 {
            let x = randn(64 * 64, 0xC0DEC + k, 1.0);
            assert_codec_bit_identical(&x, 64, 64);
        }
        let x = randn(32 * 32, 0xC0DEC + 100, 3.0);
        assert_codec_bit_identical(&x, 32, 32);
        let x = randn(16 * 64, 0xC0DEC + 101, 0.01);
        assert_codec_bit_identical(&x, 16, 64);
        let x = randn(8 * 128, 0xC0DEC + 102, 50.0);
        assert_codec_bit_identical(&x, 8, 128);
        let x = randn(16 * 16, 0xC0DEC + 103, 1e-38);
        assert_codec_bit_identical(&x, 16, 16);
    }

    #[test]
    fn fake_quant_into_bit_identical_to_two_step_codec() {
        for (seed, scale) in [(1u64, 1.0f32), (2, 0.01), (3, 30.0), (4, 1e-30)] {
            let x = randn(64 * 64, 0xFA4E + seed, scale);
            let two_step = reference::dequantize(&reference::quantize(&x, 64, 64, None));
            let mut fused = Vec::new();
            fake_quant_into(&x, 64, 64, &mut fused);
            for (i, (a, b)) in fused.iter().zip(&two_step).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "scale {scale} elem {i}: {a} vs {b}");
            }
        }
        // denom==0 path: all-zero input
        let zeros = vec![0f32; 64];
        let mut out = vec![9f32; 1]; // stale contents must be discarded
        fake_quant_into(&zeros, 4, 16, &mut out);
        assert_eq!(out.len(), 64);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn quantize_into_reuses_buffers_and_matches_fresh() {
        let x1 = randn(32 * 32, 21, 1.0);
        let x2 = randn(16 * 16, 22, 4.0);
        let mut t = Nvfp4Tensor::quantize(&x1, 32, 32, None);
        Nvfp4Tensor::quantize_into(&x2, 16, 16, None, &mut t);
        let fresh = Nvfp4Tensor::quantize(&x2, 16, 16, None);
        assert_eq!(t.codes, fresh.codes);
        assert_eq!(t.block_scales, fresh.block_scales);
        assert_eq!(t.tensor_scale.to_bits(), fresh.tensor_scale.to_bits());
        assert_eq!((t.rows, t.cols), (16, 16));
    }

    #[test]
    fn codec_is_thread_count_invariant() {
        // 256x128 = 32768 elements: every leg (quantize work 6n,
        // dequantize 3n, fake-quant 8n) clears PAR_MIN_WORK, so the
        // 4-thread run really partitions (not serial-vs-serial).
        let x = randn(256 * 128, 0x7777, 2.0);
        let run = |threads: usize| {
            crate::util::pool::with_threads(threads, || {
                let t = Nvfp4Tensor::quantize(&x, 256, 128, None);
                (t.block_scales.clone(), t.codes.clone(), t.dequantize(), fake_quant(&x, 256, 128))
            })
        };
        let (s1, c1, d1, f1) = run(1);
        let (s4, c4, d4, f4) = run(4);
        assert_eq!(s1, s4);
        assert_eq!(c1, c4);
        for (a, b) in d1.iter().zip(&d4).chain(f1.iter().zip(&f4)) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn lut_codec_bit_identical_on_structured_tensors() {
        // outlier + all-zero block, mirroring the golden tensor's shape
        let mut x = randn(8 * 64, 0xC0DEC + 200, 2.0);
        x[3] = 77.0;
        for v in x[5 * 16..7 * 16].iter_mut() {
            *v = 0.0;
        }
        assert_codec_bit_identical(&x, 8, 64);
        // pure zeros
        let zeros = vec![0f32; 256];
        assert_codec_bit_identical(&zeros, 16, 16);
    }
}
