//! Bit-exact quantization substrate (Rust side).
//!
//! The JAX/Pallas kernels implement fake-quant inside the AOT'd compute
//! graphs; this module is the *coordinator's* view of the same formats:
//! real 4-bit packing for checkpoint export and memory accounting, PTQ
//! calibration, per-layer error analysis, and the format baselines
//! (MXFP4 / INT4) used by the comparison benches. Cross-validated against
//! the JAX oracle through golden vectors (rust/tests/).

pub mod baselines;
pub mod calib;
pub mod fp;
pub mod nvfp4;
pub mod packed;

pub use calib::CalibMethod;
pub use nvfp4::{fake_quant, rel_error, Nvfp4Tensor};
pub use packed::{KernelTier, PackedFormat, PackedWeight};

/// Quantize a whole model parameter vector layer-by-layer (PTQ weight
/// export): 2-D weight tensors go through the NVFP4 codec along their
/// contraction axis; 1-D tensors (norm scales, biases) stay in f32, as on
/// real deployments.
pub struct PtqReport {
    /// (param name, relative Frobenius error, storage bytes)
    pub layers: Vec<(String, f64, usize)>,
    pub total_bytes_nvfp4: usize,
    pub total_bytes_f32: usize,
}

impl PtqReport {
    pub fn compression_ratio(&self) -> f64 {
        self.total_bytes_f32 as f64 / self.total_bytes_nvfp4 as f64
    }
}

/// Fake-quantize the weight tensors of a flat parameter vector in place,
/// following the manifest layout. `skip` decides (by name) which tensors
/// stay high-precision — mirrors model.py's selective quantization.
/// Returns a per-layer error report.
pub fn ptq_quantize_params(
    params: &mut [f32],
    layout: &[(String, Vec<usize>, usize, usize)],
    skip: &dyn Fn(&str) -> bool,
) -> PtqReport {
    let mut layers = Vec::new();
    let mut total_q = 0usize;
    let mut total_f = 0usize;
    for (name, shape, offset, size) in layout {
        total_f += size * 4;
        let is_matrix = shape.len() >= 2;
        let cols = *shape.last().unwrap_or(&1);
        // Quantize along the contraction axis: model.py quantizes w.T along
        // K, i.e. blocks run down a column of w. Transpose here to match.
        if !is_matrix || skip(name) || cols == 0 || size % cols != 0 {
            total_q += size * 4;
            layers.push((name.clone(), 0.0, size * 4));
            continue;
        }
        let rows = size / cols;
        if rows % nvfp4::BLOCK != 0 {
            // Contraction dim not blockable — keep high precision (rare:
            // only tiny tensors like vis_proj with patch=16 pass anyway).
            total_q += size * 4;
            layers.push((name.clone(), 0.0, size * 4));
            continue;
        }
        let slice = &mut params[*offset..*offset + *size];
        // transpose (rows, cols) -> (cols, rows) so blocks lie along K=rows
        let mut t = vec![0f32; *size];
        for r in 0..rows {
            for c in 0..cols {
                t[c * rows + r] = slice[r * cols + c];
            }
        }
        let qt = Nvfp4Tensor::quantize(&t, cols, rows, None);
        let deq = qt.dequantize();
        let mut err_num = 0f64;
        let mut err_den = 0f64;
        for r in 0..rows {
            for c in 0..cols {
                let orig = slice[r * cols + c];
                let q = deq[c * rows + r];
                err_num += ((orig - q) as f64).powi(2);
                err_den += (orig as f64).powi(2);
                slice[r * cols + c] = q;
            }
        }
        let rel = if err_den > 0.0 { (err_num / err_den).sqrt() } else { 0.0 };
        total_q += qt.storage_bytes();
        layers.push((name.clone(), rel, qt.storage_bytes()));
    }
    PtqReport { layers, total_bytes_nvfp4: total_q, total_bytes_f32: total_f }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn layout_2d(name: &str, rows: usize, cols: usize, off: usize) -> (String, Vec<usize>, usize, usize) {
        (name.to_string(), vec![rows, cols], off, rows * cols)
    }

    #[test]
    fn ptq_quantizes_matrices_skips_vectors() {
        let mut r = Rng::new(1);
        let rows = 32;
        let cols = 48;
        let mut params: Vec<f32> = (0..rows * cols + 16).map(|_| r.normal() as f32).collect();
        let before = params.clone();
        let layout = vec![
            layout_2d("w", rows, cols, 0),
            ("ln".to_string(), vec![16], rows * cols, 16),
        ];
        let report = ptq_quantize_params(&mut params, &layout, &|_| false);
        // matrix changed
        assert!(params[..rows * cols].iter().zip(&before).any(|(a, b)| a != b));
        // vector untouched
        assert_eq!(&params[rows * cols..], &before[rows * cols..]);
        assert!(report.layers[0].1 > 0.0 && report.layers[0].1 < 0.2);
        assert_eq!(report.layers[1].1, 0.0);
    }

    #[test]
    fn skip_predicate_respected() {
        let mut r = Rng::new(2);
        let mut params: Vec<f32> = (0..32 * 32).map(|_| r.normal() as f32).collect();
        let before = params.clone();
        let layout = vec![layout_2d("b0.wq", 32, 32, 0)];
        ptq_quantize_params(&mut params, &layout, &|n| n.contains("wq"));
        assert_eq!(params, before);
    }

    #[test]
    fn compression_ratio_sane() {
        let mut r = Rng::new(3);
        let mut params: Vec<f32> = (0..128 * 128).map(|_| r.normal() as f32).collect();
        let layout = vec![layout_2d("w", 128, 128, 0)];
        let report = ptq_quantize_params(&mut params, &layout, &|_| false);
        let ratio = report.compression_ratio();
        assert!(ratio > 6.5 && ratio < 7.5, "f32->nvfp4 should be ~7.1x, got {ratio}");
    }
}
