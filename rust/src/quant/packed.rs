//! Quantized-domain GEMM kernel tier: compute directly on the packed
//! 4-bit representation instead of fake-quantizing weights back to f32.
//!
//! [`PackedWeight`] holds a (k, n) weight quantized along its contraction
//! axis exactly like `refmodel`'s `quant_weight_into` (transpose →
//! quantize rows of the (n, k) view), but keeps the *packed* form: nibble
//! codes (two elements per byte) plus per-block scales — E4M3 codes + one
//! f32 tensor scale for NVFP4, power-of-two f32 scales for MXFP4, one
//! per-row f32 scale for INT4 (sign-magnitude nibbles so `-0.0` survives
//! the round trip). `dequantize_into` reproduces the fake-quant f32
//! weights **bit for bit** — the packed layout is a lossless re-encoding
//! of the exact tier's quantized values, property-tested below.
//!
//! The dot-product micro-kernels ([`PackedWeight::matvec_into`] /
//! [`PackedWeight::gemm_into`]) walk the packed bytes through the shared
//! 256-entry nibble-pair LUT with the block-scale product hoisted out of
//! the element loop: `acc += scale_b * Σ (lut[byte]·x_pair)`. Weight
//! traffic drops ~8× vs the f32 copies the exact tier binds (u8 nibbles
//! vs f32), which is the bandwidth win the 4-bit formats exist for. The
//! per-output-element f32 chain is fixed — parallelism tiles the *output*
//! (`util::pool`), so results are bit-identical at every thread count.
//!
//! Accuracy budget: the packed kernels hoist block scales and accumulate
//! per block, so logits are *not* bit-identical to the exact tier's f32
//! GEMM — they agree within [`PACKED_LOGIT_ATOL`]/[`PACKED_LOGIT_RTOL`]
//! and must produce identical greedy tokens on the test models
//! (tests/packed_kernels.rs). The exact tier remains the bit-exact
//! oracle.
//!
//! Tier selection: [`KernelTier`] resolves explicit choice (per-session
//! `DecodeOpts::kernel` / `Session::builder().kernel(..)`) over the
//! process-global knob (`--kernel`) over the `QADX_KERNEL` env var,
//! defaulting to `Exact`.

use std::fmt;
use std::str::FromStr;
use std::sync::atomic::{AtomicU8, Ordering};

use anyhow::{bail, Context, Result};

use super::baselines::MXFP4_BLOCK;
use super::fp::{e2m1_encode, e4m3_decode};
use super::nvfp4::{self, NIBBLE_PAIR_LUT, BLOCK as NV_BLOCK};
use crate::util::pool;

// ------------------------------------------------------------- kernel tier

/// Which GEMM datapath quantized decode/forward uses.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelTier {
    /// Fake-quant weights back to f32 and run the blocked f32 GEMM — the
    /// bit-exact oracle path.
    #[default]
    Exact,
    /// Compute directly on packed nibbles via the LUT micro-kernels;
    /// logits within tolerance of `Exact`, identical greedy tokens.
    Packed,
}

impl KernelTier {
    pub fn parse(s: &str) -> Result<KernelTier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "exact" | "f32" => Ok(KernelTier::Exact),
            "packed" | "lut" => Ok(KernelTier::Packed),
            other => bail!("unknown kernel tier {other:?} (expected exact|packed)"),
        }
    }

    /// Resolve the effective tier: explicit choice > process-global knob
    /// (`set_kernel`, i.e. `--kernel` / `Session::builder().kernel(..)`) >
    /// `QADX_KERNEL` env var > `Exact`.
    pub fn resolve(explicit: Option<KernelTier>) -> Result<KernelTier> {
        let env = std::env::var("QADX_KERNEL").ok();
        resolve_from(explicit, GLOBAL_KERNEL.load(Ordering::Relaxed), env.as_deref())
    }
}

fn resolve_from(explicit: Option<KernelTier>, global: u8, env: Option<&str>) -> Result<KernelTier> {
    if let Some(t) = explicit {
        return Ok(t);
    }
    match global {
        1 => return Ok(KernelTier::Exact),
        2 => return Ok(KernelTier::Packed),
        _ => {}
    }
    match env {
        Some(s) if !s.trim().is_empty() => {
            KernelTier::parse(s).context("invalid QADX_KERNEL (expected exact|packed)")
        }
        _ => Ok(KernelTier::Exact),
    }
}

impl fmt::Display for KernelTier {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            KernelTier::Exact => write!(f, "exact"),
            KernelTier::Packed => write!(f, "packed"),
        }
    }
}

impl FromStr for KernelTier {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<KernelTier> {
        KernelTier::parse(s)
    }
}

/// Process-global tier knob: 0 = unset, 1 = exact, 2 = packed.
static GLOBAL_KERNEL: AtomicU8 = AtomicU8::new(0);

/// Set the process-global kernel tier (CLI `--kernel`,
/// `Session::builder().kernel(..)`). Per-session `DecodeOpts::kernel`
/// still wins where given.
pub fn set_kernel(t: KernelTier) {
    let v = match t {
        KernelTier::Exact => 1,
        KernelTier::Packed => 2,
    };
    GLOBAL_KERNEL.store(v, Ordering::Relaxed);
}

/// Clear the process-global tier knob back to "unset" (env/default rule).
pub fn clear_kernel() {
    GLOBAL_KERNEL.store(0, Ordering::Relaxed);
}

// --------------------------------------------------------- accuracy budget

/// Absolute logit tolerance of the packed tier vs the exact oracle.
pub const PACKED_LOGIT_ATOL: f32 = 5e-3;
/// Relative logit tolerance of the packed tier vs the exact oracle.
pub const PACKED_LOGIT_RTOL: f32 = 5e-3;

/// The accuracy-budget predicate: `|got - want| <= atol + rtol * |want|`.
pub fn within_budget(got: f32, want: f32) -> bool {
    (got - want).abs() <= PACKED_LOGIT_ATOL + PACKED_LOGIT_RTOL * want.abs()
}

// --------------------------------------------------------- packed weights

/// Quantization format of a [`PackedWeight`] (the quantizable subset of
/// `refmodel::Format`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PackedFormat {
    Nvfp4,
    Mxfp4,
    Int4,
}

impl fmt::Display for PackedFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PackedFormat::Nvfp4 => write!(f, "nvfp4"),
            PackedFormat::Mxfp4 => write!(f, "mxfp4"),
            PackedFormat::Int4 => write!(f, "int4"),
        }
    }
}

/// Output elements per parallel chunk of the packed GEMM kernels. Each
/// element is an independent k-length dot product, so any tile size is
/// bit-invariant; 64 keeps chunks ~micro-task sized.
const OUT_TILE: usize = 64;

/// A (k, n) weight quantized along K and kept in packed form: the decode
/// datapath reads u8 nibbles + per-block scales instead of a full f32
/// copy. Layout is the (n, k)-transposed view — one output row's K-dim
/// codes are contiguous, so the matvec kernel streams them linearly.
#[derive(Clone, Debug)]
pub struct PackedWeight {
    fmt: PackedFormat,
    k: usize,
    n: usize,
    /// Nibble codes, (n, k/2) bytes: element 2j of transposed row r in the
    /// low nibble of `codes[r*k/2 + j]`, element 2j+1 in the high nibble.
    codes: Vec<u8>,
    /// NVFP4: one E4M3 scale code per 16-element block, (n, k/16).
    sblock: Vec<u8>,
    /// MXFP4: one f32 scale per 32-element block, (n, k/32).
    /// INT4: one f32 scale per output row, (n).
    sfloat: Vec<f32>,
    /// NVFP4 second-level per-tensor scale.
    tensor_scale: f32,
}

impl PackedWeight {
    /// Pack a row-major (k, n) weight along its contraction axis, with
    /// the exact quantization `refmodel::quant_weight_into` applies:
    /// `dequantize_into` reproduces the fake-quant f32 weights bitwise.
    pub fn pack(w: &[f32], k: usize, n: usize, fmt: PackedFormat) -> Result<PackedWeight> {
        if w.len() != k * n {
            bail!("packed weight shape mismatch: len {} != {k}x{n}", w.len());
        }
        match fmt {
            PackedFormat::Nvfp4 if k % NV_BLOCK != 0 => {
                bail!("nvfp4 packed weights need k % {NV_BLOCK} == 0, got {k}")
            }
            PackedFormat::Mxfp4 if k % MXFP4_BLOCK != 0 => {
                bail!("mxfp4 packed weights need k % {MXFP4_BLOCK} == 0, got {k}")
            }
            PackedFormat::Int4 if k % 2 != 0 => {
                bail!("int4 packed weights need k % 2 == 0, got {k}")
            }
            _ => {}
        }
        // Transposed (n, k) staging view — the same intermediate the exact
        // tier quantizes, so block boundaries and fold orders line up.
        let mut t = vec![0f32; k * n];
        for r in 0..k {
            for c in 0..n {
                t[c * k + r] = w[r * n + c];
            }
        }
        let mut pw = PackedWeight {
            fmt,
            k,
            n,
            codes: vec![0u8; k * n / 2],
            sblock: Vec::new(),
            sfloat: Vec::new(),
            tensor_scale: 1.0,
        };
        match fmt {
            PackedFormat::Nvfp4 => {
                pw.tensor_scale = nvfp4::tensor_scale(&t);
                pw.sblock = vec![0u8; k * n / NV_BLOCK];
                for (b, sb) in pw.sblock.iter_mut().enumerate() {
                    let blk = &t[b * NV_BLOCK..(b + 1) * NV_BLOCK];
                    let bytes = &mut pw.codes[b * NV_BLOCK / 2..(b + 1) * NV_BLOCK / 2];
                    *sb = nvfp4::quantize_block(blk, pw.tensor_scale, bytes);
                }
            }
            PackedFormat::Mxfp4 => {
                pw.sfloat = vec![0f32; k * n / MXFP4_BLOCK];
                for (b, sf) in pw.sfloat.iter_mut().enumerate() {
                    let blk = &t[b * MXFP4_BLOCK..(b + 1) * MXFP4_BLOCK];
                    let bytes = &mut pw.codes[b * MXFP4_BLOCK / 2..(b + 1) * MXFP4_BLOCK / 2];
                    let amax = blk.iter().fold(0f32, |m, v| m.max(v.abs()));
                    if amax == 0.0 {
                        // scale 0 + zero codes decode to +0.0, matching the
                        // baseline's untouched-output branch
                        continue;
                    }
                    let e = amax.log2().floor() - 2.0;
                    let scale = e.exp2();
                    *sf = scale;
                    // identical reciprocal-vs-divide selection to the
                    // baseline codec so the codes (and -0.0 signs) match
                    let inv = 1.0 / scale;
                    if inv.is_normal() {
                        for (byte, pair) in bytes.iter_mut().zip(blk.chunks_exact(2)) {
                            *byte = e2m1_encode(pair[0] * inv) | (e2m1_encode(pair[1] * inv) << 4);
                        }
                    } else {
                        for (byte, pair) in bytes.iter_mut().zip(blk.chunks_exact(2)) {
                            *byte =
                                e2m1_encode(pair[0] / scale) | (e2m1_encode(pair[1] / scale) << 4);
                        }
                    }
                }
            }
            PackedFormat::Int4 => {
                pw.sfloat = vec![0f32; n];
                for (r, sf) in pw.sfloat.iter_mut().enumerate() {
                    let row = &t[r * k..(r + 1) * k];
                    let bytes = &mut pw.codes[r * k / 2..(r + 1) * k / 2];
                    let amax = row.iter().fold(0f32, |m, v| m.max(v.abs()));
                    let s = if amax > 0.0 { amax / 7.0 } else { 1.0 };
                    *sf = s;
                    for (byte, pair) in bytes.iter_mut().zip(row.chunks_exact(2)) {
                        *byte = int4_encode(pair[0] / s) | (int4_encode(pair[1] / s) << 4);
                    }
                }
            }
        }
        Ok(pw)
    }

    pub fn format(&self) -> PackedFormat {
        self.fmt
    }

    /// (k, n) logical dims of the packed weight.
    pub fn dims(&self) -> (usize, usize) {
        (self.k, self.n)
    }

    /// Bytes the packed representation actually holds (nibble planes +
    /// block scales + the tensor scale) — the decode weight footprint.
    pub fn storage_bytes(&self) -> usize {
        self.codes.len() + self.sblock.len() + self.sfloat.len() * 4 + 4
    }

    /// Dequantize back to the row-major (k, n) f32 weights — bit-identical
    /// to what the exact tier's `quant_weight_into` materializes. Oracle
    /// path for tests; the kernels below never call it.
    pub fn dequantize_into(&self, out: &mut Vec<f32>) {
        let (k, n) = (self.k, self.n);
        out.clear();
        out.resize(k * n, 0.0);
        for r in 0..n {
            for j in 0..k {
                out[j * n + r] = self.element(r, j);
            }
        }
    }

    /// One dequantized element of transposed row `r`, K-index `j`.
    fn element(&self, r: usize, j: usize) -> f32 {
        let byte = self.codes[(r * self.k + j) / 2];
        let nib = if j % 2 == 0 { byte & 0x0f } else { byte >> 4 };
        match self.fmt {
            PackedFormat::Nvfp4 => {
                let sb = self.sblock[(r * self.k + j) / NV_BLOCK];
                let denom = e4m3_decode(sb) * self.tensor_scale;
                nvfp4_nibble(nib) * denom
            }
            PackedFormat::Mxfp4 => {
                let scale = self.sfloat[(r * self.k + j) / MXFP4_BLOCK];
                nvfp4_nibble(nib) * scale
            }
            PackedFormat::Int4 => int4_decode(nib) * self.sfloat[r],
        }
    }

    /// y[r] = Σ_j w[j][r] · x[j] over the packed codes: nibble-pair LUT
    /// loads with the block-scale product hoisted per block. One fixed f32
    /// chain per output element — bit-identical at every thread count.
    pub fn matvec_into(&self, x: &[f32], out: &mut [f32]) -> Result<()> {
        self.gemm_into(x, 1, out)
    }

    /// Row-major (m, k) × packed (k, n) → (m, n). Small-M decode GEMM:
    /// parallel over output tiles, each element an independent dot.
    pub fn gemm_into(&self, x: &[f32], m: usize, out: &mut [f32]) -> Result<()> {
        let (k, n) = (self.k, self.n);
        if x.len() != m * k || out.len() != m * n {
            bail!(
                "packed gemm shape mismatch: x {} != {m}x{k} or out {} != {m}x{n}",
                x.len(),
                out.len()
            );
        }
        pool::for_chunks(m * n * k, out, OUT_TILE, |ci, oc| {
            let base = ci * OUT_TILE;
            for (j, o) in oc.iter_mut().enumerate() {
                let flat = base + j;
                let (i, r) = (flat / n, flat % n);
                *o = self.dot_row(r, &x[i * k..(i + 1) * k]);
            }
        });
        Ok(())
    }

    /// The packed dot micro-kernel: one transposed weight row against one
    /// activation row. `acc += scale_b * Σ_pairs (lut[byte]·x_pair)`.
    #[inline]
    fn dot_row(&self, r: usize, x: &[f32]) -> f32 {
        let k = self.k;
        let bytes = &self.codes[r * k / 2..(r + 1) * k / 2];
        match self.fmt {
            PackedFormat::Nvfp4 => {
                let scales = &self.sblock[r * k / NV_BLOCK..(r + 1) * k / NV_BLOCK];
                let mut acc = 0f32;
                for (bi, (&sb, bb)) in
                    scales.iter().zip(bytes.chunks_exact(NV_BLOCK / 2)).enumerate()
                {
                    let denom = e4m3_decode(sb) * self.tensor_scale;
                    let xb = &x[bi * NV_BLOCK..(bi + 1) * NV_BLOCK];
                    let mut ba = 0f32;
                    for (pair, &byte) in xb.chunks_exact(2).zip(bb) {
                        let d = &NIBBLE_PAIR_LUT[byte as usize];
                        ba += d[0] * pair[0];
                        ba += d[1] * pair[1];
                    }
                    acc += denom * ba;
                }
                acc
            }
            PackedFormat::Mxfp4 => {
                let scales = &self.sfloat[r * k / MXFP4_BLOCK..(r + 1) * k / MXFP4_BLOCK];
                let mut acc = 0f32;
                for (bi, (&scale, bb)) in
                    scales.iter().zip(bytes.chunks_exact(MXFP4_BLOCK / 2)).enumerate()
                {
                    let xb = &x[bi * MXFP4_BLOCK..(bi + 1) * MXFP4_BLOCK];
                    let mut ba = 0f32;
                    for (pair, &byte) in xb.chunks_exact(2).zip(bb) {
                        let d = &NIBBLE_PAIR_LUT[byte as usize];
                        ba += d[0] * pair[0];
                        ba += d[1] * pair[1];
                    }
                    acc += scale * ba;
                }
                acc
            }
            PackedFormat::Int4 => {
                let s = self.sfloat[r];
                let mut ba = 0f32;
                for (pair, &byte) in x.chunks_exact(2).zip(bytes) {
                    let d = &INT4_PAIR_LUT[byte as usize];
                    ba += d[0] * pair[0];
                    ba += d[1] * pair[1];
                }
                s * ba
            }
        }
    }

    /// Test-only raw constructor (exhaustive nibble/scale-class sweeps).
    #[cfg(test)]
    pub(crate) fn from_raw_nvfp4(
        codes: Vec<u8>,
        sblock: Vec<u8>,
        tensor_scale: f32,
        k: usize,
        n: usize,
    ) -> PackedWeight {
        PackedWeight {
            fmt: PackedFormat::Nvfp4,
            k,
            n,
            codes,
            sblock,
            sfloat: Vec::new(),
            tensor_scale,
        }
    }
}

/// Decode an E2M1 nibble (shared grid with the NVFP4/MXFP4 codecs).
#[inline]
fn nvfp4_nibble(nib: u8) -> f32 {
    NIBBLE_PAIR_LUT[nib as usize][0]
}

/// Encode an already-scaled INT4 value as a sign-magnitude nibble
/// (bit 3 = sign, bits 0..2 = |q|). Sign-magnitude rather than two's
/// complement so `-0.0` quantized values survive bitwise — the exact
/// tier's `q * s` keeps the sign of a negative-rounded zero.
#[inline]
fn int4_encode(v: f32) -> u8 {
    let q = v.round().clamp(-7.0, 7.0);
    let sign = if q.is_sign_negative() { 0x8u8 } else { 0 };
    sign | (q.abs() as u8)
}

/// Decode a sign-magnitude INT4 nibble to f32 (−0.0 for 0x8).
#[inline]
fn int4_decode(nib: u8) -> f32 {
    INT4_PAIR_LUT[nib as usize][0]
}

const fn int4_decode_const(nib: u8) -> f32 {
    let mag = (nib & 0x7) as f32;
    if nib & 0x8 != 0 {
        -mag
    } else {
        mag
    }
}

const fn build_int4_pair_lut() -> [[f32; 2]; 256] {
    let mut t = [[0f32; 2]; 256];
    let mut b = 0usize;
    while b < 256 {
        t[b] = [int4_decode_const((b & 0x0f) as u8), int4_decode_const((b >> 4) as u8)];
        b += 1;
    }
    t
}

/// Both sign-magnitude INT4 nibbles of a packed byte decoded at once.
static INT4_PAIR_LUT: [[f32; 2]; 256] = build_int4_pair_lut();

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::baselines;
    use crate::util::rng::Rng;

    fn randn(len: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..len).map(|_| r.normal() as f32).collect()
    }

    /// The exact tier's weight quantization (transpose → fake-quant rows
    /// of the (n, k) view → transpose back), via the public codecs.
    fn fake_quant_weight(w: &[f32], k: usize, n: usize, fmt: PackedFormat) -> Vec<f32> {
        let mut t = vec![0f32; k * n];
        for r in 0..k {
            for c in 0..n {
                t[c * k + r] = w[r * n + c];
            }
        }
        let tq = match fmt {
            PackedFormat::Nvfp4 => nvfp4::fake_quant(&t, n, k),
            PackedFormat::Mxfp4 => baselines::mxfp4_fake_quant(&t, n, k),
            PackedFormat::Int4 => baselines::int4_fake_quant(&t, n, k),
        };
        let mut out = vec![0f32; k * n];
        for r in 0..k {
            for c in 0..n {
                out[r * n + c] = tq[c * k + r];
            }
        }
        out
    }

    #[test]
    fn kernel_tier_parse_display_roundtrip_and_rejects_garbage() {
        for t in [KernelTier::Exact, KernelTier::Packed] {
            assert_eq!(KernelTier::parse(&t.to_string()).unwrap(), t);
        }
        assert_eq!(KernelTier::parse("f32").unwrap(), KernelTier::Exact);
        assert_eq!(KernelTier::parse("LUT").unwrap(), KernelTier::Packed);
        assert_eq!(" Packed ".parse::<KernelTier>().unwrap(), KernelTier::Packed);
        assert!(KernelTier::parse("fast").is_err());
        assert_eq!(KernelTier::default(), KernelTier::Exact);
    }

    #[test]
    fn resolve_prefers_explicit_then_global_then_env_then_exact() {
        // pure-precedence helper: no process globals touched, so this
        // can't race concurrently-running decode tests.
        let r = |e, g, v| resolve_from(e, g, v).unwrap();
        assert_eq!(r(Some(KernelTier::Packed), 1, Some("exact")), KernelTier::Packed);
        assert_eq!(r(None, 2, Some("exact")), KernelTier::Packed);
        assert_eq!(r(None, 1, Some("packed")), KernelTier::Exact);
        assert_eq!(r(None, 0, Some("packed")), KernelTier::Packed);
        assert_eq!(r(None, 0, Some("  ")), KernelTier::Exact);
        assert_eq!(r(None, 0, None), KernelTier::Exact);
        assert!(resolve_from(None, 0, Some("warp")).is_err());
    }

    #[test]
    fn packed_dequantize_matches_fake_quant_oracle_bitwise_all_formats() {
        let (k, n) = (64usize, 24usize);
        for (fmt, seed) in [
            (PackedFormat::Nvfp4, 11u64),
            (PackedFormat::Mxfp4, 12),
            (PackedFormat::Int4, 13),
        ] {
            let mut w = randn(k * n, seed);
            // edge content: an all-zero contraction block, an outlier, and
            // values that round to -0.0 in the int4 grid
            for r in 0..NV_BLOCK {
                w[r * n + 3] = 0.0;
            }
            w[5 * n + 7] = 57.0;
            w[6 * n + 7] = -1e-6;
            let pw = PackedWeight::pack(&w, k, n, fmt).unwrap();
            let oracle = fake_quant_weight(&w, k, n, fmt);
            let mut got = Vec::new();
            pw.dequantize_into(&mut got);
            assert_eq!(got.len(), oracle.len());
            for (i, (a, b)) in got.iter().zip(&oracle).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "{fmt} elem {i}: packed {a} vs fake-quant oracle {b}"
                );
            }
        }
    }

    #[test]
    fn packed_dot_all_256_nibble_pairs_across_scale_classes() {
        // One 16-element NVFP4 block, every code byte in slot 0, across
        // subnormal / normal / max-edge E4M3 block scales and three
        // tensor scales. The kernel must equal the hand-hoisted chain
        // bitwise and the dequantized-f32 dot within the accuracy budget.
        let x = randn(NV_BLOCK, 21);
        for sb in [0x00u8, 0x01, 0x07, 0x35, 0x7e] {
            for ts in [1.0f32, 0.0078125, 0.37] {
                for byte in 0u8..=255 {
                    let mut codes = vec![0u8; NV_BLOCK / 2];
                    codes[0] = byte;
                    let pw = PackedWeight::from_raw_nvfp4(codes, vec![sb], ts, NV_BLOCK, 1);
                    let mut out = [0f32; 1];
                    pw.matvec_into(&x, &mut out).unwrap();
                    // hand-hoisted expected chain (the kernel's op order):
                    // the zero code bytes still contribute their ±0.0
                    // products, exactly as the kernel accumulates them
                    let denom = e4m3_decode(sb) * ts;
                    let d = &NIBBLE_PAIR_LUT[byte as usize];
                    let z = &NIBBLE_PAIR_LUT[0];
                    let mut ba = 0f32;
                    ba += d[0] * x[0];
                    ba += d[1] * x[1];
                    for pair in x[2..].chunks_exact(2) {
                        ba += z[0] * pair[0];
                        ba += z[1] * pair[1];
                    }
                    let expect = denom * ba;
                    assert_eq!(
                        out[0].to_bits(),
                        expect.to_bits(),
                        "sb {sb:#x} ts {ts} byte {byte:#x}: kernel {} vs chain {expect}",
                        out[0]
                    );
                    // and the plain f32 dot over dequantized weights stays
                    // inside the accuracy budget
                    let mut wd = Vec::new();
                    pw.dequantize_into(&mut wd);
                    let plain: f32 = wd.iter().zip(&x).map(|(w, xv)| w * xv).sum();
                    assert!(
                        within_budget(out[0], plain),
                        "sb {sb:#x} ts {ts} byte {byte:#x}: kernel {} vs f32 dot {plain}",
                        out[0]
                    );
                }
            }
        }
    }

    #[test]
    fn packed_gemm_thread_invariant_and_matches_matvec_bitwise() {
        // 8x64x256 = 131k MACs: past PAR_MIN_WORK, so 4 threads really
        // partitions the output tiles.
        let (m, k, n) = (8usize, 64usize, 256usize);
        let w = randn(k * n, 31);
        let x = randn(m * k, 32);
        for fmt in [PackedFormat::Nvfp4, PackedFormat::Mxfp4, PackedFormat::Int4] {
            let pw = PackedWeight::pack(&w, k, n, fmt).unwrap();
            let run = |t: usize| {
                pool::with_threads(t, || {
                    let mut out = vec![0f32; m * n];
                    pw.gemm_into(&x, m, &mut out).unwrap();
                    out
                })
            };
            let o1 = run(1);
            let o4 = run(4);
            for (i, (a, b)) in o1.iter().zip(&o4).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "{fmt} out {i}: 1-thread {a} vs 4-thread {b}");
            }
            let mut row = vec![0f32; n];
            for i in 0..m {
                pw.matvec_into(&x[i * k..(i + 1) * k], &mut row).unwrap();
                for (a, b) in row.iter().zip(&o1[i * n..(i + 1) * n]) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{fmt} row {i}: matvec vs gemm");
                }
            }
        }
    }

    #[test]
    fn packed_storage_is_many_times_smaller_than_f32() {
        let (k, n) = (256usize, 64usize);
        let w = randn(k * n, 41);
        let f32_bytes = k * n * 4;
        for (fmt, floor) in [
            (PackedFormat::Nvfp4, 7usize),
            (PackedFormat::Mxfp4, 6),
            (PackedFormat::Int4, 7),
        ] {
            let pw = PackedWeight::pack(&w, k, n, fmt).unwrap();
            let bytes = pw.storage_bytes();
            assert!(
                bytes * floor < f32_bytes,
                "{fmt}: {bytes} packed bytes vs {f32_bytes} f32 (floor {floor}x)"
            );
            assert!(bytes > k * n / 2, "{fmt}: {bytes} suspiciously small");
        }
    }

    #[test]
    fn packed_shape_errors() {
        let w = vec![0f32; 8 * 4];
        assert!(PackedWeight::pack(&w, 8, 4, PackedFormat::Nvfp4).is_err());
        assert!(PackedWeight::pack(&w, 8, 4, PackedFormat::Mxfp4).is_err());
        assert!(PackedWeight::pack(&w[..9], 3, 3, PackedFormat::Int4).is_err());
        assert!(PackedWeight::pack(&w, 7, 4, PackedFormat::Int4).is_err());
        let pw = PackedWeight::pack(&[0.5f32; 16 * 2], 16, 2, PackedFormat::Nvfp4).unwrap();
        assert_eq!(pw.dims(), (16, 2));
        let mut out = vec![0f32; 2];
        assert!(pw.matvec_into(&[0.0; 8], &mut out).is_err());
        assert!(pw.gemm_into(&[0.0; 16], 1, &mut [0f32; 5]).is_err());
    }

    #[test]
    fn int4_negative_zero_survives_packing() {
        // a tiny negative value rounds to -0.0 in the int4 grid; the
        // exact tier's q*s keeps that sign, so the packed layout must too
        let (k, n) = (4usize, 1usize);
        let w = vec![1.0f32, -1e-8, 0.5, -0.25];
        let pw = PackedWeight::pack(&w, k, n, PackedFormat::Int4).unwrap();
        let oracle = fake_quant_weight(&w, k, n, PackedFormat::Int4);
        assert!(oracle[1].to_bits() == (-0.0f32).to_bits(), "fixture lost its -0.0");
        let mut got = Vec::new();
        pw.dequantize_into(&mut got);
        for (a, b) in got.iter().zip(&oracle) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn within_budget_combines_absolute_and_relative_terms() {
        assert!(within_budget(0.0, 0.004));
        assert!(!within_budget(0.0, 0.02));
        assert!(within_budget(100.0, 100.4));
        assert!(!within_budget(100.0, 101.0));
    }
}
