//! Synthetic task suite: the sim counterparts of the paper's evaluation
//! benchmarks (DESIGN.md §2). Every task has a *verifiable* exact answer,
//! which is what makes true SFT, REINFORCE-style RL, and sampling-based
//! evaluation possible in-repo.
//!
//! Mapping (paper benchmark → sim suite):
//!   MATH500            → Math500   2-digit modular addition
//!   AIME24 / AIME25    → Aime      mul-add chains mod 100 (harder)
//!   LiveCodeBench      → Lcb       sort / reverse digit strings
//!   SciCode            → SciCode   composed transforms (desc-sort, inc)
//!   GPQA-Diamond       → Gpqa      key-value recall with distractors
//!   IFEval-Instruction → Ifeval    bracket-format compliance
//!   AA-LCR             → AaLcr     long-context recall (context-filling KV)
//!   AI2D/ChartQA/DocVQA/InfoVQA/OCRBench/TextVQA → grid-image QA variants

use super::tokenizer as tok;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Domain {
    Math,
    Code,
    Knowledge,
    Instruction,
    Vision,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Suite {
    Math500,
    Aime,
    Lcb,
    SciCode,
    Gpqa,
    Ifeval,
    AaLcr,
    Ai2d,
    ChartQa,
    DocVqa,
    InfoVqa,
    OcrBench,
    TextVqa,
}

impl Suite {
    pub fn name(&self) -> &'static str {
        match self {
            Suite::Math500 => "math500",
            Suite::Aime => "aime",
            Suite::Lcb => "livecodebench",
            Suite::SciCode => "scicode",
            Suite::Gpqa => "gpqa-d",
            Suite::Ifeval => "ifeval",
            Suite::AaLcr => "aa-lcr",
            Suite::Ai2d => "ai2d",
            Suite::ChartQa => "chartqa",
            Suite::DocVqa => "docvqa",
            Suite::InfoVqa => "infovqa",
            Suite::OcrBench => "ocrbench",
            Suite::TextVqa => "textvqa",
        }
    }

    pub fn from_name(s: &str) -> Option<Suite> {
        use Suite::*;
        Some(match s {
            "math500" => Math500,
            "aime" | "aime24" | "aime25" => Aime,
            "livecodebench" | "lcb" => Lcb,
            "scicode" => SciCode,
            "gpqa-d" | "gpqa" => Gpqa,
            "ifeval" => Ifeval,
            "aa-lcr" | "aalcr" => AaLcr,
            "ai2d" => Ai2d,
            "chartqa" => ChartQa,
            "docvqa" => DocVqa,
            "infovqa" => InfoVqa,
            "ocrbench" => OcrBench,
            "textvqa" => TextVqa,
            _ => return None,
        })
    }

    pub fn domain(&self) -> Domain {
        match self {
            Suite::Math500 | Suite::Aime => Domain::Math,
            Suite::Lcb | Suite::SciCode => Domain::Code,
            Suite::Gpqa | Suite::AaLcr => Domain::Knowledge,
            Suite::Ifeval => Domain::Instruction,
            _ => Domain::Vision,
        }
    }

    pub fn is_vision(&self) -> bool {
        self.domain() == Domain::Vision
    }

    /// Scoring mode: IFEval scores instruction (format) compliance, all
    /// other suites score exact answer match.
    pub fn score(&self, expected: &str, generated: &str) -> f64 {
        match self {
            Suite::Ifeval => {
                let g = generated.trim();
                // instruction: answer wrapped in brackets, non-empty inside
                if g.starts_with('[') && g.ends_with(']') && g.len() > 2 {
                    1.0
                } else {
                    0.0
                }
            }
            _ => {
                if generated.trim() == expected.trim() {
                    1.0
                } else {
                    0.0
                }
            }
        }
    }
}

/// All text suites (the LLM benchmark set).
pub const TEXT_SUITES: &[Suite] = &[
    Suite::Math500,
    Suite::Aime,
    Suite::Lcb,
    Suite::SciCode,
    Suite::Gpqa,
    Suite::Ifeval,
    Suite::AaLcr,
];

/// All vision suites (the VLM benchmark set).
pub const VISION_SUITES: &[Suite] = &[
    Suite::Ai2d,
    Suite::ChartQa,
    Suite::DocVqa,
    Suite::InfoVqa,
    Suite::OcrBench,
    Suite::TextVqa,
];

#[derive(Clone, Debug)]
pub struct Sample {
    pub suite: Suite,
    pub prompt: String,
    pub answer: String,
    /// Flattened (grid*grid, patch) pixels for vision suites.
    pub pixels: Option<Vec<f32>>,
}

/// A 4×4 digit grid rendered into patch pixels: each patch is filled with
/// the (normalized) cell value — the linear vision front-end reads it back.
fn render_grid(cells: &[u8], grid: usize, patch: usize) -> Vec<f32> {
    let mut px = Vec::with_capacity(grid * grid * patch);
    for &v in cells {
        let base = (v as f32 / 9.0 - 0.5) * 2.0;
        for j in 0..patch {
            // small fixed positional ramp keeps patches non-constant
            px.push(base + 0.01 * j as f32);
        }
    }
    px
}

pub fn generate(suite: Suite, rng: &mut Rng, grid: usize, patch: usize) -> Sample {
    match suite {
        Suite::Math500 => {
            // single-digit modular addition: learnable by the sim models in
            // a few thousand steps on the 1-core testbed (DESIGN.md §5)
            let a = rng.below(10);
            let b = rng.below(10);
            Sample {
                suite,
                prompt: format!("{a}+{b}="),
                answer: format!("{}", (a + b) % 10),
                pixels: None,
            }
        }
        Suite::Aime => {
            // harder: exact 3-term sum — multi-digit answers compound
            // per-token errors, the "hard reasoning" analogue
            let a = rng.below(10);
            let b = rng.below(10);
            let c = rng.below(10);
            Sample {
                suite,
                prompt: format!("{a}+{b}+{c}="),
                answer: format!("{}", a + b + c),
                pixels: None,
            }
        }
        Suite::Lcb => {
            let n = 4 + rng.below(2);
            let digits: Vec<u8> = (0..n).map(|_| rng.below(10) as u8).collect();
            let s: String = digits.iter().map(|d| (b'0' + d) as char).collect();
            if rng.bool(0.5) {
                let mut v = digits.clone();
                v.sort();
                Sample {
                    suite,
                    prompt: format!("sort:{s}="),
                    answer: v.iter().map(|d| (b'0' + d) as char).collect(),
                    pixels: None,
                }
            } else {
                Sample {
                    suite,
                    prompt: format!("rev:{s}="),
                    answer: s.chars().rev().collect(),
                    pixels: None,
                }
            }
        }
        Suite::SciCode => {
            let n = 4 + rng.below(2);
            let digits: Vec<u8> = (0..n).map(|_| rng.below(10) as u8).collect();
            let s: String = digits.iter().map(|d| (b'0' + d) as char).collect();
            if rng.bool(0.5) {
                let mut v = digits.clone();
                v.sort();
                v.reverse();
                Sample {
                    suite,
                    prompt: format!("dsrt:{s}="),
                    answer: v.iter().map(|d| (b'0' + d) as char).collect(),
                    pixels: None,
                }
            } else {
                Sample {
                    suite,
                    prompt: format!("inc:{s}="),
                    answer: digits.iter().map(|d| (b'0' + (d + 1) % 10) as char).collect(),
                    pixels: None,
                }
            }
        }
        Suite::Gpqa => {
            let keys = pick_letters(rng, 3);
            let vals: Vec<usize> = (0..3).map(|_| rng.below(10)).collect();
            let q = rng.below(3);
            let ctx: Vec<String> =
                keys.iter().zip(&vals).map(|(k, v)| format!("{k}={v}")).collect();
            Sample {
                suite,
                prompt: format!("{};{}?", ctx.join(";"), keys[q]),
                answer: format!("{}", vals[q]),
                pixels: None,
            }
        }
        Suite::AaLcr => {
            // Fill most of the context window with KV pairs.
            let n = 7;
            let keys = pick_letters(rng, n);
            let vals: Vec<usize> = (0..n).map(|_| rng.below(10)).collect();
            let q = rng.below(n);
            let ctx: Vec<String> =
                keys.iter().zip(&vals).map(|(k, v)| format!("{k}={v}")).collect();
            Sample {
                suite,
                prompt: format!("{};{}?", ctx.join(";"), keys[q]),
                answer: format!("{}", vals[q]),
                pixels: None,
            }
        }
        Suite::Ifeval => {
            let a = rng.below(10);
            let b = rng.below(10);
            Sample {
                suite,
                prompt: format!("fmt:{a}+{b}="),
                answer: format!("[{}]", (a + b) % 10),
                pixels: None,
            }
        }
        // --- vision suites ------------------------------------------------
        Suite::DocVqa => {
            let cells = rand_cells(rng, grid);
            let r = rng.below(grid);
            let c = rng.below(grid);
            Sample {
                suite,
                prompt: format!("cell{r}{c}="),
                answer: format!("{}", cells[r * grid + c]),
                pixels: Some(render_grid(&cells, grid, patch)),
            }
        }
        Suite::InfoVqa => {
            let cells = rand_cells(rng, grid);
            let r = rng.below(grid);
            let sum: usize = (0..grid).map(|c| cells[r * grid + c] as usize).sum();
            Sample {
                suite,
                prompt: format!("rsum{r}="),
                answer: format!("{}", sum % 10),
                pixels: Some(render_grid(&cells, grid, patch)),
            }
        }
        Suite::ChartQa => {
            let cells = rand_cells(rng, grid);
            let c = rng.below(grid);
            let mx = (0..grid).map(|r| cells[r * grid + c]).max().unwrap();
            Sample {
                suite,
                prompt: format!("cmax{c}="),
                answer: format!("{mx}"),
                pixels: Some(render_grid(&cells, grid, patch)),
            }
        }
        Suite::Ai2d => {
            let cells = rand_cells(rng, grid);
            let r = rng.below(grid);
            let k = rng.below(8) as u8;
            let cnt = (0..grid).filter(|&c| cells[r * grid + c] > k).count();
            Sample {
                suite,
                prompt: format!("cnt{r}>{k}="),
                answer: format!("{cnt}"),
                pixels: Some(render_grid(&cells, grid, patch)),
            }
        }
        Suite::OcrBench => {
            let cells = rand_cells(rng, grid);
            let r = rng.below(grid);
            let row: String =
                (0..grid).map(|c| (b'0' + cells[r * grid + c]) as char).collect();
            Sample {
                suite,
                prompt: format!("read{r}="),
                answer: row,
                pixels: Some(render_grid(&cells, grid, patch)),
            }
        }
        Suite::TextVqa => {
            let cells = rand_cells(rng, grid);
            let (r1, c1) = (rng.below(grid), rng.below(grid));
            let (r2, c2) = (rng.below(grid), rng.below(grid));
            let a = cells[r1 * grid + c1];
            let b = cells[r2 * grid + c2];
            let ans = if a < b { "<" } else if a > b { ">" } else { "=" };
            Sample {
                suite,
                prompt: format!("cmp{r1}{c1},{r2}{c2}="),
                answer: ans.to_string(),
                pixels: Some(render_grid(&cells, grid, patch)),
            }
        }
    }
}

fn rand_cells(rng: &mut Rng, grid: usize) -> Vec<u8> {
    (0..grid * grid).map(|_| rng.below(10) as u8).collect()
}

fn pick_letters(rng: &mut Rng, n: usize) -> Vec<char> {
    let mut letters: Vec<char> = ('a'..='z').collect();
    rng.shuffle(&mut letters);
    letters.truncate(n);
    letters
}

/// Corrupt an answer (cold-start SFT data quality knob): flip one digit.
pub fn corrupt_answer(answer: &str, rng: &mut Rng) -> String {
    let chars: Vec<char> = answer.chars().collect();
    let digit_positions: Vec<usize> = chars
        .iter()
        .enumerate()
        .filter(|(_, c)| c.is_ascii_digit())
        .map(|(i, _)| i)
        .collect();
    if digit_positions.is_empty() {
        return answer.to_string();
    }
    let pos = *rng.choice(&digit_positions);
    let old = chars[pos] as u8 - b'0';
    let new = (old + 1 + rng.below(9) as u8) % 10;
    let mut out = chars;
    out[pos] = (b'0' + new) as char;
    out.into_iter().collect()
}

/// Tokenized training/eval row: BOS prompt SEP answer EOS PAD…, with the
/// loss mask covering the answer span + EOS (the *label* positions — see
/// python/compile/steps.py `_shift`).
pub fn build_row(sample: &Sample, answer: &str, seq_len: usize) -> (Vec<i32>, Vec<f32>) {
    let mut tokens = vec![tok::PAD; seq_len];
    let mut mask = vec![0f32; seq_len];
    let p = tok::encode(&sample.prompt);
    let a = tok::encode(answer);
    let mut i = 0;
    tokens[i] = tok::BOS;
    i += 1;
    for &t in &p {
        if i >= seq_len - 2 {
            break;
        }
        tokens[i] = t;
        i += 1;
    }
    tokens[i] = tok::SEP;
    i += 1;
    for &t in &a {
        if i >= seq_len - 1 {
            break;
        }
        tokens[i] = t;
        mask[i] = 1.0;
        i += 1;
    }
    tokens[i] = tok::EOS;
    mask[i] = 1.0;
    (tokens, mask)
}

/// Extract the prompt region (BOS..=SEP) of a row, for generation.
pub fn prompt_tokens(sample: &Sample, seq_len: usize) -> Vec<i32> {
    let p = tok::encode(&sample.prompt);
    let mut out = Vec::with_capacity(p.len() + 2);
    out.push(tok::BOS);
    out.extend(p.iter().take(seq_len - 3));
    out.push(tok::SEP);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(1234)
    }

    #[test]
    fn all_text_suites_generate_and_fit() {
        let mut r = rng();
        for &s in TEXT_SUITES {
            for _ in 0..50 {
                let smp = generate(s, &mut r, 4, 16);
                assert!(smp.pixels.is_none());
                let (tokens, mask) = build_row(&smp, &smp.answer, 64);
                assert_eq!(tokens.len(), 64);
                assert!(mask.iter().sum::<f32>() >= 1.0, "{s:?}");
                // round trip: decode must contain the answer
                let decoded = tok::decode(&tokens);
                assert!(decoded.contains(&smp.answer), "{s:?} {decoded} {}", smp.answer);
            }
        }
    }

    #[test]
    fn vision_suites_generate_pixels() {
        let mut r = rng();
        for &s in VISION_SUITES {
            let smp = generate(s, &mut r, 4, 16);
            let px = smp.pixels.as_ref().unwrap();
            assert_eq!(px.len(), 4 * 4 * 16);
            assert!(px.iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn answers_verifiable() {
        let mut r = rng();
        // math500 correctness
        let s = generate(Suite::Math500, &mut r, 4, 16);
        let parts: Vec<usize> = s
            .prompt
            .trim_end_matches('=')
            .split('+')
            .map(|x| x.parse().unwrap())
            .collect();
        assert_eq!(s.answer, format!("{}", (parts[0] + parts[1]) % 100));
    }

    #[test]
    fn scoring_exact_and_format() {
        assert_eq!(Suite::Math500.score("42", "42"), 1.0);
        assert_eq!(Suite::Math500.score("42", " 42 "), 1.0);
        assert_eq!(Suite::Math500.score("42", "41"), 0.0);
        assert_eq!(Suite::Ifeval.score("[9]", "[7]"), 1.0); // format-only
        assert_eq!(Suite::Ifeval.score("[9]", "9"), 0.0);
    }

    #[test]
    fn corrupt_changes_digits() {
        let mut r = rng();
        let mut changed = 0;
        for _ in 0..50 {
            let c = corrupt_answer("42", &mut r);
            assert_eq!(c.len(), 2);
            if c != "42" {
                changed += 1;
            }
        }
        assert_eq!(changed, 50); // digit flip always produces a different digit
    }

    #[test]
    fn mask_covers_answer_and_eos_only() {
        let s = Sample {
            suite: Suite::Math500,
            prompt: "1+2=".into(),
            answer: "3".into(),
            pixels: None,
        };
        let (tokens, mask) = build_row(&s, &s.answer, 16);
        // BOS 1 + 2 = SEP 3 EOS -> positions 0..7
        assert_eq!(tokens[0], tok::BOS);
        assert_eq!(tokens[5], tok::SEP);
        assert_eq!(mask.iter().sum::<f32>(), 2.0); // "3" and EOS
        assert_eq!(mask[6], 1.0);
        assert_eq!(mask[7], 1.0);
    }

    #[test]
    fn deterministic_generation() {
        let mut a = Rng::new(9);
        let mut b = Rng::new(9);
        for &s in TEXT_SUITES {
            let x = generate(s, &mut a, 4, 16);
            let y = generate(s, &mut b, 4, 16);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn suite_name_round_trip() {
        for &s in TEXT_SUITES.iter().chain(VISION_SUITES) {
            assert_eq!(Suite::from_name(s.name()), Some(s));
        }
    }
}
