//! Training data sources — the paper's Table 5 ablation axis:
//!
//!   1. SFT data (cold-start SFT corpus, with a data-quality knob)
//!   2. Generated from RL prompts (teacher samples responses)
//!   3. Generated from RL prompts, correct-only (reward-filtered)
//!   4. Generated from a BOS token (data-free distillation, Liu et al. '23)
//!   5. Random tokens
//!
//! Generation-backed sources pull completions from the full-precision
//! teacher through the `ResponseGenerator` trait (implemented by
//! eval::Sampler over the `fwd_bf16` artifact), so the whole data path
//! stays inside the Rust runtime.

use super::tasks::{self, Sample, Suite};
use super::tokenizer as tok;
use crate::runtime::Batch;
use crate::util::rng::Rng;

#[derive(Clone, Debug, PartialEq)]
pub enum SourceKind {
    /// Task corpus with ground-truth answers; `p_correct` < 1 simulates
    /// cold-start data quality (answers corrupted with prob 1-p).
    Sft { p_correct: f64 },
    /// Teacher-generated responses to task prompts (the RL prompt set).
    RlGenerated,
    /// Same, filtered to reward-positive completions.
    RlGeneratedCorrectOnly,
    /// Teacher free-running from BOS (data-free).
    BosGenerated,
    /// Uniform random token sequences.
    RandomTokens,
}

impl SourceKind {
    pub fn name(&self) -> &'static str {
        match self {
            SourceKind::Sft { .. } => "sft",
            SourceKind::RlGenerated => "rl-generated",
            SourceKind::RlGeneratedCorrectOnly => "rl-generated-correct",
            SourceKind::BosGenerated => "bos-generated",
            SourceKind::RandomTokens => "random-tokens",
        }
    }

    pub fn needs_generator(&self) -> bool {
        matches!(
            self,
            SourceKind::RlGenerated | SourceKind::RlGeneratedCorrectOnly | SourceKind::BosGenerated
        )
    }
}

/// Shape info the factory needs about the target model.
#[derive(Clone, Copy, Debug)]
pub struct BatchShape {
    pub batch: usize,
    pub seq_len: usize,
    pub vision: bool,
    pub grid: usize,
    pub patch: usize,
    pub vocab: usize,
}

/// Teacher-side completion source (wired to eval::Sampler by the
/// coordinator; kept as a trait so `data` does not depend on `eval`).
pub trait ResponseGenerator {
    /// Complete each prompt row; returns full token rows (prompt + response,
    /// PAD-tail) plus the response mask.
    fn complete(
        &mut self,
        prompts: &[Vec<i32>],
        pixels: Option<&[f32]>,
        seq_len: usize,
    ) -> anyhow::Result<Vec<(Vec<i32>, Vec<f32>)>>;
}

/// One weighted component of a data mixture.
#[derive(Clone, Debug)]
pub struct SourceSpec {
    pub kind: SourceKind,
    pub suites: Vec<Suite>,
    pub weight: f64,
}

impl SourceSpec {
    pub fn sft(suites: &[Suite]) -> SourceSpec {
        SourceSpec { kind: SourceKind::Sft { p_correct: 1.0 }, suites: suites.to_vec(), weight: 1.0 }
    }

    pub fn sft_quality(suites: &[Suite], p_correct: f64) -> SourceSpec {
        SourceSpec { kind: SourceKind::Sft { p_correct }, suites: suites.to_vec(), weight: 1.0 }
    }

    pub fn with_weight(mut self, w: f64) -> SourceSpec {
        self.weight = w;
        self
    }
}

/// Builds training batches from a weighted mixture of sources.
pub struct BatchFactory {
    pub shape: BatchShape,
    pub sources: Vec<SourceSpec>,
    rng: Rng,
}

impl BatchFactory {
    pub fn new(shape: BatchShape, sources: Vec<SourceSpec>, seed: u64) -> Self {
        assert!(!sources.is_empty());
        BatchFactory { shape, sources, rng: Rng::new(seed) }
    }

    /// Sample one task row (text or vision) from the given suites.
    fn sample_task(&mut self, suites: &[Suite]) -> Sample {
        let suite = *self.rng.choice(suites);
        tasks::generate(suite, &mut self.rng, self.shape.grid, self.shape.patch)
    }

    /// Produce the next batch; `gen` must be Some for generation-backed
    /// sources.
    pub fn next_batch(
        &mut self,
        gen: Option<&mut dyn ResponseGenerator>,
    ) -> anyhow::Result<Batch> {
        let weights: Vec<f64> = self.sources.iter().map(|s| s.weight).collect();
        let idx = self.rng.weighted(&weights);
        let spec = self.sources[idx].clone();
        self.batch_from_spec(&spec, gen)
    }

    pub fn batch_from_spec(
        &mut self,
        spec: &SourceSpec,
        mut gen: Option<&mut dyn ResponseGenerator>,
    ) -> anyhow::Result<Batch> {
        let sh = self.shape;
        let (b, s) = (sh.batch, sh.seq_len);
        let mut tokens = Vec::with_capacity(b * s);
        let mut mask = Vec::with_capacity(b * s);
        let mut pixels: Option<Vec<f32>> = if sh.vision { Some(Vec::new()) } else { None };

        match &spec.kind {
            SourceKind::Sft { p_correct } => {
                for _ in 0..b {
                    let smp = self.sample_task(&spec.suites);
                    let answer = if self.rng.bool(*p_correct) {
                        smp.answer.clone()
                    } else {
                        tasks::corrupt_answer(&smp.answer, &mut self.rng)
                    };
                    let (t, m) = tasks::build_row(&smp, &answer, s);
                    tokens.extend(t);
                    mask.extend(m);
                    if let Some(px) = pixels.as_mut() {
                        px.extend(smp.pixels.as_deref().unwrap_or(&vec![0.0; sh.grid * sh.grid * sh.patch]));
                    }
                }
            }
            SourceKind::RandomTokens => {
                for _ in 0..b {
                    tokens.push(tok::BOS);
                    mask.push(0.0);
                    for _ in 1..s {
                        tokens.push(self.rng.range(4, sh.vocab as i64) as i32);
                        mask.push(1.0);
                    }
                    if let Some(px) = pixels.as_mut() {
                        for _ in 0..sh.grid * sh.grid * sh.patch {
                            px.push(self.rng.normal() as f32);
                        }
                    }
                }
            }
            SourceKind::BosGenerated => {
                let g = gen.as_mut().ok_or_else(|| {
                    anyhow::anyhow!("source {:?} needs a teacher generator", spec.kind)
                })?;
                let prompts: Vec<Vec<i32>> = (0..b).map(|_| vec![tok::BOS]).collect();
                let rows = g.complete(&prompts, None, s)?;
                for (t, m) in rows {
                    tokens.extend(t);
                    mask.extend(m);
                }
            }
            SourceKind::RlGenerated | SourceKind::RlGeneratedCorrectOnly => {
                let correct_only = spec.kind == SourceKind::RlGeneratedCorrectOnly;
                let g = gen.as_mut().ok_or_else(|| {
                    anyhow::anyhow!("source {:?} needs a teacher generator", spec.kind)
                })?;
                let mut rows_done = 0usize;
                let mut attempts = 0usize;
                while rows_done < b {
                    attempts += 1;
                    if attempts > 8 {
                        // Teacher too weak to produce enough correct samples:
                        // fall back to unfiltered for the remainder.
                        anyhow::ensure!(!tokens.is_empty() || !correct_only || attempts <= 16,
                            "correct-only generation starved");
                    }
                    let mut samples = Vec::with_capacity(b);
                    let mut prompts = Vec::with_capacity(b);
                    let mut pxbuf: Vec<f32> = Vec::new();
                    for _ in 0..b {
                        let smp = self.sample_task(&spec.suites);
                        prompts.push(tasks::prompt_tokens(&smp, s));
                        if sh.vision {
                            pxbuf.extend(smp.pixels.as_deref().unwrap_or(&vec![0.0; sh.grid * sh.grid * sh.patch]));
                        }
                        samples.push(smp);
                    }
                    let px_opt = if sh.vision { Some(pxbuf.as_slice()) } else { None };
                    let rows = g.complete(&prompts, px_opt, s)?;
                    for (i, (t, m)) in rows.into_iter().enumerate() {
                        if rows_done >= b {
                            break;
                        }
                        if correct_only {
                            let generated = decode_response(&t, &prompts[i]);
                            if samples[i].suite.score(&samples[i].answer, &generated) < 1.0 {
                                continue;
                            }
                        }
                        tokens.extend(t);
                        mask.extend(m);
                        if let Some(px) = pixels.as_mut() {
                            let n = sh.grid * sh.grid * sh.patch;
                            px.extend(&pxbuf[i * n..(i + 1) * n]);
                        }
                        rows_done += 1;
                    }
                    if attempts > 32 {
                        anyhow::bail!("correct-only generation starved after 32 rounds");
                    }
                }
            }
        }
        anyhow::ensure!(tokens.len() == b * s, "batch underfull: {}", tokens.len());
        Ok(Batch { tokens, mask, pixels, advantage: None })
    }
}

/// Decode the response region (after SEP) of a generated row.
pub fn decode_response(row: &[i32], prompt: &[i32]) -> String {
    tok::decode(&row[prompt.len().min(row.len())..])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tasks::TEXT_SUITES;

    fn shape() -> BatchShape {
        BatchShape { batch: 4, seq_len: 64, vision: false, grid: 4, patch: 16, vocab: 64 }
    }

    struct EchoGen; // fake teacher: echoes the correct answer for testing
    impl ResponseGenerator for EchoGen {
        fn complete(
            &mut self,
            prompts: &[Vec<i32>],
            _pixels: Option<&[f32]>,
            seq_len: usize,
        ) -> anyhow::Result<Vec<(Vec<i32>, Vec<f32>)>> {
            Ok(prompts
                .iter()
                .map(|p| {
                    let mut t = vec![tok::PAD; seq_len];
                    let mut m = vec![0f32; seq_len];
                    t[..p.len()].copy_from_slice(p);
                    t[p.len()] = tok::DIGIT0 + 7; // always answer "7"
                    m[p.len()] = 1.0;
                    t[p.len() + 1] = tok::EOS;
                    m[p.len() + 1] = 1.0;
                    (t, m)
                })
                .collect())
        }
    }

    #[test]
    fn sft_batch_well_formed() {
        let mut f = BatchFactory::new(shape(), vec![SourceSpec::sft(TEXT_SUITES)], 1);
        let b = f.next_batch(None).unwrap();
        assert_eq!(b.tokens.len(), 4 * 64);
        assert_eq!(b.mask.len(), 4 * 64);
        assert!(b.pixels.is_none());
        // every row starts with BOS and has some mask
        for r in 0..4 {
            assert_eq!(b.tokens[r * 64], tok::BOS);
            assert!(b.mask[r * 64..(r + 1) * 64].iter().sum::<f32>() >= 1.0);
        }
    }

    #[test]
    fn quality_knob_corrupts() {
        // p_correct=0 must produce different label distribution than p=1
        let mk = |p| {
            let mut f = BatchFactory::new(
                shape(),
                vec![SourceSpec::sft_quality(&[Suite::Math500], p)],
                7,
            );
            f.next_batch(None).unwrap().tokens
        };
        assert_ne!(mk(0.0), mk(1.0));
    }

    #[test]
    fn random_tokens_masked_everywhere() {
        let mut f = BatchFactory::new(
            shape(),
            vec![SourceSpec { kind: SourceKind::RandomTokens, suites: vec![], weight: 1.0 }],
            3,
        );
        let b = f.next_batch(None).unwrap();
        assert_eq!(b.mask.iter().sum::<f32>(), 4.0 * 63.0);
        assert!(b.tokens.iter().skip(1).all(|&t| t >= 0 && t < 64));
    }

    #[test]
    fn generated_source_requires_generator() {
        let mut f = BatchFactory::new(
            shape(),
            vec![SourceSpec { kind: SourceKind::RlGenerated, suites: vec![Suite::Math500], weight: 1.0 }],
            3,
        );
        assert!(f.next_batch(None).is_err());
        let mut g = EchoGen;
        let b = f.next_batch(Some(&mut g)).unwrap();
        assert_eq!(b.tokens.len(), 4 * 64);
        assert!(b.mask.iter().sum::<f32>() >= 4.0);
    }

    #[test]
    fn mixture_draws_from_all() {
        let mut f = BatchFactory::new(
            shape(),
            vec![
                SourceSpec::sft(&[Suite::Math500]).with_weight(0.5),
                SourceSpec { kind: SourceKind::RandomTokens, suites: vec![], weight: 0.5 },
            ],
            11,
        );
        let mut saw_random = false;
        let mut saw_sft = false;
        for _ in 0..20 {
            let b = f.next_batch(None).unwrap();
            let msum = b.mask.iter().sum::<f32>();
            if msum == 4.0 * 63.0 {
                saw_random = true;
            } else {
                saw_sft = true;
            }
        }
        assert!(saw_random && saw_sft);
    }

    #[test]
    fn vision_batches_carry_pixels() {
        let sh = BatchShape { vision: true, ..shape() };
        let mut f = BatchFactory::new(sh, vec![SourceSpec::sft(&[Suite::DocVqa])], 5);
        let b = f.next_batch(None).unwrap();
        let px = b.pixels.unwrap();
        assert_eq!(px.len(), 4 * 16 * 16);
    }
}
