//! Symbolic tokenizer shared with the compile path.
//!
//! The vocabulary is fixed (64 ids, matching python/compile/configs.py —
//! the manifest records the size and the engine asserts it at load). Ids:
//!   0..3   specials: PAD BOS EOS SEP
//!   4..13  digits 0-9
//!   14..39 letters a-z
//!   40..   operators / punctuation (see `SYMBOLS`)

pub const PAD: i32 = 0;
pub const BOS: i32 = 1;
pub const EOS: i32 = 2;
pub const SEP: i32 = 3;

pub const DIGIT0: i32 = 4;
pub const LETTER_A: i32 = 14;
pub const SYMBOL0: i32 = 40;

/// Symbol characters mapped to ids 40.. in order.
pub const SYMBOLS: &[char] = &[
    '+', '-', '*', '=', '/', '(', ')', '[', ']', '{', '}', '<', '>', ',', '.', ':', ';', '?',
    '!', ' ', '|', '&', '^', '%',
];

pub const VOCAB: usize = 64;

/// Encode one char; None if unmappable.
pub fn encode_char(c: char) -> Option<i32> {
    match c {
        '0'..='9' => Some(DIGIT0 + (c as i32 - '0' as i32)),
        'a'..='z' => Some(LETTER_A + (c as i32 - 'a' as i32)),
        _ => SYMBOLS.iter().position(|&s| s == c).map(|i| SYMBOL0 + i as i32),
    }
}

/// Decode one id; '\u{fffd}' for specials/out-of-range.
pub fn decode_id(id: i32) -> char {
    match id {
        d if (DIGIT0..DIGIT0 + 10).contains(&d) => (b'0' + (d - DIGIT0) as u8) as char,
        l if (LETTER_A..LETTER_A + 26).contains(&l) => (b'a' + (l - LETTER_A) as u8) as char,
        s if (SYMBOL0..SYMBOL0 + SYMBOLS.len() as i32).contains(&s) => {
            SYMBOLS[(s - SYMBOL0) as usize]
        }
        _ => '\u{fffd}',
    }
}

/// Encode a string; panics on unmappable chars (task generators only emit
/// vocabulary chars — a panic here is a bug, not a data error).
pub fn encode(s: &str) -> Vec<i32> {
    s.chars()
        .map(|c| encode_char(c).unwrap_or_else(|| panic!("unencodable char {c:?} in {s:?}")))
        .collect()
}

/// Decode ids to a string, stopping at EOS and skipping PAD/BOS/SEP.
pub fn decode(ids: &[i32]) -> String {
    let mut out = String::new();
    for &id in ids {
        if id == EOS {
            break;
        }
        if id == PAD || id == BOS || id == SEP {
            continue;
        }
        out.push(decode_id(id));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_ascii() {
        let s = "12+34=abc sort:x,y";
        let ids = encode(s);
        assert_eq!(decode(&ids), s);
    }

    #[test]
    fn ids_in_vocab() {
        for c in "0123456789abcdefghijklmnopqrstuvwxyz".chars() {
            let id = encode_char(c).unwrap();
            assert!((4..VOCAB as i32).contains(&id), "{c} -> {id}");
        }
        for &c in SYMBOLS {
            let id = encode_char(c).unwrap();
            assert!((SYMBOL0..VOCAB as i32).contains(&id), "{c} -> {id}");
        }
    }

    #[test]
    fn ids_unique() {
        let mut seen = std::collections::HashSet::new();
        for c in "0123456789abcdefghijklmnopqrstuvwxyz".chars() {
            assert!(seen.insert(encode_char(c).unwrap()));
        }
        for &c in SYMBOLS {
            assert!(seen.insert(encode_char(c).unwrap()), "{c}");
        }
    }

    #[test]
    fn decode_stops_at_eos() {
        let ids = vec![BOS, DIGIT0 + 1, EOS, DIGIT0 + 2];
        assert_eq!(decode(&ids), "1");
    }

    #[test]
    fn unencodable_is_none() {
        assert_eq!(encode_char('@'), None);
        assert_eq!(encode_char('Z'), None);
    }
}
