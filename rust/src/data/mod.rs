//! Data substrate: tokenizer, synthetic task corpus, and the Table-5 data
//! sources (SFT / RL-generated / BOS-generated / random), assembled into
//! device-ready batches by `BatchFactory`.

pub mod sources;
pub mod tasks;
pub mod tokenizer;

pub use sources::{BatchFactory, BatchShape, ResponseGenerator, SourceKind, SourceSpec};
pub use tasks::{Domain, Sample, Suite, TEXT_SUITES, VISION_SUITES};

use crate::runtime::ModelEntry;

/// Batch shape for a manifest model.
pub fn shape_for(model: &ModelEntry) -> BatchShape {
    BatchShape {
        batch: model.batch,
        seq_len: model.seq_len,
        vision: model.vision,
        grid: model.vision_grid,
        patch: model.vision_patch,
        vocab: model.vocab,
    }
}
