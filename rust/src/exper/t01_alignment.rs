//! Table 1 — QAD aligns the quantized model with the BF16 baseline:
//! KL divergence vs teacher and CE vs labels for BF16 / QAT / QAD.
//! Paper model: Llama Nemotron Super V1 → sim: super-sim.

use anyhow::Result;

use super::common::Ctx;
use super::report::TableReport;
use crate::coordinator::{pipeline, Method};
use crate::data::{shape_for, BatchFactory, SourceSpec};
use crate::eval::eval_distribution;

pub fn run(ctx: &Ctx) -> Result<TableReport> {
    let model = "super-sim";
    let teacher = ctx.teacher(model)?;
    let rt = ctx.rt(model)?;
    let cfg = ctx.recovery_cfg(model);

    let qat = ctx.recover(&rt, Method::Qat, &teacher, &cfg)?;
    let qad = ctx.recover(&rt, Method::Qad, &teacher, &cfg)?;

    // Held-out evaluation set: fresh seed, clean SFT distribution (~the
    // paper's 5k held-out samples).
    let suites = pipeline::train_suites(model);
    let spec = SourceSpec::sft(suites);
    let n_batches = if ctx.eval.n_problems <= 12 { 4 } else { 16 };

    let mut report = TableReport::new(
        "table1",
        "QAD aligns the model with the BF16 baseline (KL vs CE)",
        &["Method", "KL Divergence (vs BF16)", "Cross Entropy (vs labels)"],
    );
    let paper = [
        ("BF16", 0.0, 0.408),
        ("QAT", 0.311, 0.408),
        ("QAD", 0.004, 0.416),
    ];
    for ((name, p_kl, p_ce), (params, key)) in paper.iter().zip([
        (&teacher, "eval_bf16"),
        (&qat, "eval_nvfp4"),
        (&qad, "eval_nvfp4"),
    ]) {
        let mut factory =
            BatchFactory::new(shape_for(&rt.model), vec![spec.clone()], 0xe7a1);
        let m = eval_distribution(
            ctx.engine(), &rt, key, params, &teacher, &mut factory, &spec, n_batches,
        )?;
        report.row(vec![
            name.to_string(),
            format!("{:.4} (paper {p_kl})", m.kl),
            format!("{:.3} (paper {p_ce})", m.ce),
        ]);
        eprintln!("  [table1] {name}: kl={:.4} ce={:.3} ({} tokens)", m.kl, m.ce, m.tokens);
    }
    report.note("sim: super-sim teacher; held-out clean SFT batches; paper used ~8M held-out tokens");
    report.note("expected shape: QAT CE ≈ BF16 CE but KL >> 0; QAD KL ≈ 0");
    Ok(report)
}
