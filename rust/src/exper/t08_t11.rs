//! Table 8  — KL-divergence vs MSE distillation loss (ace-sim + nano-sim)
//! Table 9  — original-size teacher vs larger teacher (nano-sim ← super-sim)
//! Table 10 — VLM: single-stage SFT model, QAT ≈ QAD (Appendix A)
//! Table 11 — Nemotron-3-Nano data-composition ablation (Appendix B)

use anyhow::Result;

use super::common::{col, col_seeded, run_standard_methods, Col, Ctx};
use super::report::TableReport;
use crate::coordinator::{run_method, Method};
use crate::data::{shape_for, BatchFactory, SourceKind, SourceSpec, Suite, VISION_SUITES};
use crate::runtime::DeviceState;

pub fn run_table8(ctx: &Ctx) -> Result<TableReport> {
    let mut report = TableReport::new(
        "table8",
        "KL divergence vs MSE distillation loss",
        &["Model", "Loss", "GPQA-D", "AIME24", "AIME25", "LCB"],
    );
    let cols = vec![
        col("GPQA-D", Suite::Gpqa),
        col_seeded("AIME24", Suite::Aime, 24),
        col_seeded("AIME25", Suite::Aime, 25),
        col("LCB", Suite::Lcb),
    ];
    let paper: [(&str, [[f64; 4]; 2]); 2] = [
        ("ace-sim", [[f64::NAN, 71.7, 62.0, 53.3], [f64::NAN, 71.7, 60.1, 52.4]]),
        ("nano-sim", [[62.7, 80.4, 71.5, 67.8], [60.3, 80.0, 71.5, 66.7]]),
    ];
    for (model, rows) in paper {
        let teacher = ctx.teacher(model)?;
        let rt = ctx.rt(model)?;
        let cfg = ctx.recovery_cfg(model);
        for (mi, method) in [Method::Qad, Method::Mse].into_iter().enumerate() {
            let params = ctx.recover(&rt, method, &teacher, &cfg)?;
            let accs = ctx.eval_cols(&rt, method, &params, &cols)?;
            eprintln!("  [table8] {model} {}: {accs:?}", method.name());
            let label = if method == Method::Qad { "KL-Div" } else { "MSE" };
            let mut row = vec![model.to_string(), label.to_string()];
            for (j, c) in cols.iter().enumerate() {
                let p = rows[mi][j];
                row.push(super::report::cell(
                    accs[c.label],
                    if p.is_nan() { None } else { Some(p) },
                ));
            }
            report.row(row);
        }
    }
    report.note("expected shape: KL ≥ MSE on most columns");
    Ok(report)
}

pub fn run_table9(ctx: &Ctx) -> Result<TableReport> {
    let model = "nano-sim";
    let teacher = ctx.teacher(model)?; // the model's own BF16 teacher ("9B")
    let big_teacher = ctx.teacher("super-sim")?; // larger-family teacher ("12B")
    let rt = ctx.rt(model)?;
    let cols = vec![
        col_seeded("AIME24", Suite::Aime, 24),
        col_seeded("AIME25", Suite::Aime, 25),
        col("LCB", Suite::Lcb),
    ];
    let mut report = TableReport::new(
        "table9",
        "Distilling from the original vs a larger teacher",
        &["Teacher", "AIME24", "AIME25", "LCB"],
    );

    // Own teacher: the standard QAD path.
    let cfg = ctx.recovery_cfg(model);
    let own = ctx.recover(&rt, Method::Qad, &teacher, &cfg)?;
    let own_accs = ctx.eval_cols(&rt, Method::Qad, &own, &cols)?;
    report.row(ctx.method_row("own BF16 (9B-sim)", &cols, &own_accs, &[80.4, 71.5, 67.8]));

    // Larger teacher: the qad_nvfp4_xsuper artifact takes super-sim params.
    // run_method drives the standard artifact, so drive this one manually.
    let shape = shape_for(&rt.model);
    let mut factory = BatchFactory::new(shape, cfg.data.clone(), 0x7e);
    let t_buf = ctx.engine().upload_f32(&big_teacher, &[big_teacher.len()])?;
    let mut state = DeviceState::from_params(&rt, &teacher)?;
    let trainer = crate::coordinator::Trainer::new(ctx.engine(), &rt);
    trainer.train("qad_nvfp4_xsuper", &mut state, &mut factory, Some(&t_buf), None, &cfg.train)?;
    let big = state.params()?;
    let big_accs = ctx.eval_cols(&rt, Method::Qad, &big, &cols)?;
    report.row(ctx.method_row("larger BF16 (12B-sim)", &cols, &big_accs, &[80.2, 69.8, 66.7]));

    report.note("expected shape: own-teacher ≥ larger-teacher (matching a different distribution needs more data)");
    Ok(report)
}

pub fn run_table10(ctx: &Ctx) -> Result<TableReport> {
    let cols: Vec<Col> = VISION_SUITES
        .iter()
        .map(|&s| col(Box::leak(s.name().to_string().into_boxed_str()), s))
        .collect();
    let mut report = TableReport::new(
        "table10",
        "VLM (single-stage SFT): QAT ≈ QAD (Appendix A)",
        &["Method", "ai2d", "chartqa", "docvqa", "infovqa", "ocrbench", "textvqa"],
    );
    let paper: [(&str, [f64; 6]); 4] = [
        ("Baseline", [87.3, 89.7, 94.3, 79.3, 85.5, 85.2]),
        ("PTQ", [86.8, 89.6, 93.8, 78.2, 85.0, 84.8]),
        ("QAT", [86.5, 89.8, 93.7, 78.3, 84.8, 84.8]),
        ("QAD", [86.7, 89.4, 93.9, 78.4, 85.8, 85.2]),
    ];
    let results = run_standard_methods(ctx, "vl-sim", &cols, None)?;
    for ((_, accs), (label, p)) in results.iter().zip(&paper) {
        report.row(ctx.method_row(label, &cols, accs, p));
    }
    report.note("paper OCRBench /1000 quoted as /10; expected shape: all four rows close (small PTQ gap)");
    Ok(report)
}

pub fn run_table11(ctx: &Ctx) -> Result<TableReport> {
    let model = "nano3-sim";
    let teacher = ctx.teacher(model)?;
    let rt = ctx.rt(model)?;
    let cols = vec![
        col("AA-LCR", Suite::AaLcr),
        col_seeded("AIME25", Suite::Aime, 25),
        col("GPQA-D", Suite::Gpqa),
        col("LCB-v5", Suite::Lcb),
        col("SciCode", Suite::SciCode),
    ];
    let mut report = TableReport::new(
        "table11",
        "Nemotron-3-Nano data-composition ablation (Appendix B)",
        &["Training data", "AA-LCR", "AIME25", "GPQA-D", "LCB-v5", "SciCode"],
    );
    let bf = ctx.eval_cols(&rt, Method::Bf16, &teacher, &cols)?;
    report.row(ctx.method_row("BF16 Baseline", &cols, &bf, &[35.9, 89.1, 73.0, 72.1, 33.0]));
    let ptq = ctx.eval_cols(&rt, Method::Ptq, &teacher, &cols)?;
    report.row(ctx.method_row("NVFP4 PTQ", &cols, &ptq, &[31.3, 85.0, 71.6, 68.9, 30.5]));

    let suites = crate::coordinator::pipeline::train_suites(model);
    let rl = crate::coordinator::pipeline::rl_suites(model);
    let variants: [(&str, Vec<SourceSpec>, [f64; 5]); 3] = [
        (
            "SFT data",
            vec![SourceSpec::sft_quality(suites, 0.7)],
            [32.6, 86.0, 72.7, 70.0, 31.7],
        ),
        (
            "Generated from RL prompts",
            vec![SourceSpec { kind: SourceKind::RlGenerated, suites: rl.to_vec(), weight: 1.0 }],
            [34.0, 82.7, 73.9, 70.4, 33.1],
        ),
        (
            "SFT+RL generations mixture",
            vec![
                SourceSpec::sft_quality(suites, 0.7).with_weight(0.5),
                SourceSpec { kind: SourceKind::RlGenerated, suites: rl.to_vec(), weight: 0.5 },
            ],
            [34.3, 87.9, 72.7, 68.9, 32.3],
        ),
    ];
    for (label, data, paper) in variants {
        let mut cfg = ctx.recovery_cfg(model);
        cfg.data = data;
        let outcome = run_method(ctx.engine(), &rt, Method::Qad, &teacher, &cfg)?;
        let accs = ctx.eval_cols(&rt, Method::Qad, &outcome.params, &cols)?;
        eprintln!("  [table11] {label}: {accs:?}");
        report.row(ctx.method_row(label, &cols, &accs, &paper));
    }
    report.note("expected shape: all three sources land near-BF16 — QAD robust to data composition");
    Ok(report)
}
