//! Table 12 (Appendix C) — PTQ robustness grows with model size.
//!
//! The paper shows 253B/671B models lose almost nothing under NVFP4 PTQ
//! while small models do. Sim: a width/depth sweep (size-xs..size-l), each
//! SFT-trained on the same corpus, PTQ'd, and evaluated; the BF16−PTQ gap
//! should shrink as parameters grow.

use anyhow::Result;

use super::common::{col, Ctx};
use super::report::TableReport;
use crate::coordinator::Method;
use crate::data::Suite;

pub fn run(ctx: &Ctx) -> Result<TableReport> {
    let cols = vec![
        col("MATH500", Suite::Math500),
        col("LCB", Suite::Lcb),
        col("GPQA-D", Suite::Gpqa),
    ];
    let mut report = TableReport::new(
        "table12",
        "PTQ robustness vs model size (size-law sweep)",
        &["Model", "Params", "Method", "MATH500", "LCB", "GPQA-D", "avg gap"],
    );
    for model in ["size-xs", "size-s", "size-m", "size-l"] {
        let teacher = ctx.teacher(model)?;
        let rt = ctx.rt(model)?;
        let bf = ctx.eval_cols(&rt, Method::Bf16, &teacher, &cols)?;
        let ptq = ctx.eval_cols(&rt, Method::Ptq, &teacher, &cols)?;
        let gap: f64 = cols
            .iter()
            .map(|c| bf[c.label] - ptq[c.label])
            .sum::<f64>()
            / cols.len() as f64;
        eprintln!("  [table12] {model}: bf={bf:?} ptq={ptq:?} gap={gap:.1}");
        let pc = rt.model.param_count;
        let mut row_bf = vec![model.to_string(), format!("{pc}"), "BF16".into()];
        let mut row_q = vec![model.to_string(), format!("{pc}"), "NVFP4 PTQ".into()];
        for c in &cols {
            row_bf.push(format!("{:.1}", bf[c.label]));
            row_q.push(format!("{:.1}", ptq[c.label]));
        }
        row_bf.push(String::new());
        row_q.push(format!("{gap:.1}"));
        report.row(row_bf);
        report.row(row_q);
    }
    report.note("paper: 253B/671B models lose ≤1pt under PTQ — here the gap should shrink monotonically with size");
    Ok(report)
}
