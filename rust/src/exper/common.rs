//! Shared experiment context: engine, teacher cache, recovery/eval helpers,
//! and the sim↔paper column mappings used by the table drivers.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::coordinator::{
    get_or_train_teacher, pipeline, run_method, Method, PipelineScale, RecoveryCfg,
};
use crate::data::{SourceKind, SourceSpec, Suite};
use crate::eval::{run_suite, EvalCfg, SampleCfg};
use crate::runtime::{Engine, ModelRuntime};
use crate::util::args::Args;

/// An evaluation column: paper benchmark label → sim suite + problem-seed
/// offset (AIME24 vs AIME25 are the same sim suite with different exams).
#[derive(Clone, Debug)]
pub struct Col {
    pub label: &'static str,
    pub suite: Suite,
    pub seed_offset: u64,
}

pub fn col(label: &'static str, suite: Suite) -> Col {
    Col { label, suite, seed_offset: 0 }
}

pub fn col_seeded(label: &'static str, suite: Suite, seed_offset: u64) -> Col {
    Col { label, suite, seed_offset }
}

pub struct Ctx {
    pub engine: Engine,
    pub runs: PathBuf,
    pub scale: PipelineScale,
    pub eval: EvalCfg,
    /// Default recovery step budget (tables override per experiment).
    pub recover_steps: usize,
}

impl Ctx {
    pub fn from_args(args: &Args) -> Result<Ctx> {
        let engine = Engine::new(&PathBuf::from(args.get_or("artifacts", "artifacts")))?;
        let quick = args.bool("quick");
        let mut eval = EvalCfg::default();
        eval.n_problems = args.usize_or("n", if quick { 12 } else { 40 });
        eval.k_runs = args.usize_or("k", if quick { 1 } else { 3 });
        Ok(Ctx {
            engine,
            runs: PathBuf::from(args.get_or("runs", "runs")),
            scale: PipelineScale(args.f64_or("scale", if quick { 0.08 } else { 1.0 })),
            eval,
            recover_steps: args.usize_or("steps", if quick { 60 } else { 400 }),
        })
    }

    pub fn report_dir(&self) -> PathBuf {
        self.runs.join("report")
    }

    pub fn teacher(&self, model: &str) -> Result<Vec<f32>> {
        get_or_train_teacher(&self.engine, model, &self.runs, self.scale)
    }

    pub fn rt(&self, model: &str) -> Result<ModelRuntime<'_>> {
        ModelRuntime::new(&self.engine, model)
    }

    /// Eval sampling config per model (paper §3.4: nano3 uses T=1.0/top-p 1).
    pub fn sample_cfg(&self, model: &str) -> SampleCfg {
        if model == "nano3-sim" {
            SampleCfg::nano3()
        } else {
            SampleCfg::default()
        }
    }

    /// The default recovery data per model — mirrors paper §3.2:
    /// SFT-heavy models use their (clean) SFT mixture; ace uses only its
    /// cold-start SFT data; nano3 uses cold-start SFT + RL generations.
    pub fn recovery_data(&self, model: &str) -> Vec<SourceSpec> {
        let suites = pipeline::train_suites(model);
        match model {
            "ace-sim" => vec![SourceSpec::sft_quality(suites, 0.7)],
            "nano3-sim" => vec![
                SourceSpec::sft_quality(suites, 0.7).with_weight(0.5),
                SourceSpec {
                    kind: SourceKind::RlGenerated,
                    suites: pipeline::rl_suites(model).to_vec(),
                    weight: 0.5,
                },
            ],
            _ => vec![SourceSpec::sft(suites)],
        }
    }

    /// Default per-model recovery LR (paper §3.4 scaled to the sim).
    pub fn recovery_lr(&self, model: &str) -> f64 {
        if pipeline::is_rl_heavy(model) {
            3e-4 // paper: RL-heavy models want larger QAD LRs
        } else {
            1e-4
        }
    }

    pub fn recovery_cfg(&self, model: &str) -> RecoveryCfg {
        let mut cfg = RecoveryCfg::new(
            self.recovery_data(model),
            self.recovery_lr(model),
            self.recover_steps,
        );
        cfg.eval = self.eval;
        cfg.teacher_sample = self.sample_cfg(model);
        cfg
    }

    /// Run a recovery method and return the student weights.
    pub fn recover(
        &self,
        rt: &ModelRuntime,
        method: Method,
        teacher: &[f32],
        cfg: &RecoveryCfg,
    ) -> Result<Vec<f32>> {
        Ok(run_method(&self.engine, rt, method, teacher, cfg)?.params)
    }

    /// Evaluate weights over labelled columns (per-column problem seeds).
    pub fn eval_cols(
        &self,
        rt: &ModelRuntime,
        method: Method,
        params: &[f32],
        cols: &[Col],
    ) -> Result<BTreeMap<&'static str, f64>> {
        let wbuf = self.engine.upload_f32(params, &[params.len()])?;
        let mut out = BTreeMap::new();
        for c in cols {
            let mut ecfg = self.eval;
            ecfg.sample = self.sample_cfg(&rt.model.name);
            ecfg.problem_seed = ecfg.problem_seed.wrapping_add(c.seed_offset);
            let r = run_suite(&self.engine, rt, method.fwd_key(), &wbuf, c.suite, &ecfg)?;
            out.insert(c.label, r.accuracy);
        }
        Ok(out)
    }

    /// Standard method row: name + accuracy cells in column order.
    pub fn method_row(
        &self,
        label: &str,
        cols: &[Col],
        accs: &BTreeMap<&'static str, f64>,
        paper: &[f64],
    ) -> Vec<String> {
        let mut row = vec![label.to_string()];
        for (i, c) in cols.iter().enumerate() {
            let m = accs.get(c.label).copied().unwrap_or(f64::NAN);
            let p = paper.get(i).copied();
            row.push(super::report::cell(m, p));
        }
        row
    }
}

/// Method lists used by several tables.
pub const STANDARD_METHODS: &[Method] = &[Method::Bf16, Method::Ptq, Method::Qat, Method::Qad];

/// Run PTQ/QAT/QAD/BF16 for one model over given columns; returns
/// method → (column → accuracy).
pub fn run_standard_methods(
    ctx: &Ctx,
    model: &str,
    cols: &[Col],
    cfg_override: Option<RecoveryCfg>,
) -> Result<Vec<(Method, BTreeMap<&'static str, f64>)>> {
    let teacher = ctx.teacher(model)?;
    let rt = ctx.rt(model)?;
    let cfg = cfg_override.unwrap_or_else(|| ctx.recovery_cfg(model));
    let mut out = Vec::new();
    for &m in STANDARD_METHODS {
        let params = match m {
            Method::Bf16 | Method::Ptq => teacher.clone(),
            _ => ctx.recover(&rt, m, &teacher, &cfg)?,
        };
        let accs = ctx.eval_cols(&rt, m, &params, cols)?;
        eprintln!("  [{model}] {}: {accs:?}", m.name());
        out.push((m, accs));
    }
    Ok(out)
}
