//! Shared experiment context: a `qadx::api::Session` plus eval/recovery
//! budgets, and the sim↔paper column mappings used by the table drivers.

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::Result;

use crate::api::{self, cli, Session};
use crate::coordinator::{run_method, Method, RecoveryCfg};
use crate::data::{SourceSpec, Suite};
use crate::eval::{run_suite, EvalCfg, SampleCfg};
use crate::runtime::{Engine, ModelRuntime};
use crate::util::args::Args;

/// An evaluation column: paper benchmark label → sim suite + problem-seed
/// offset (AIME24 vs AIME25 are the same sim suite with different exams).
#[derive(Clone, Debug)]
pub struct Col {
    pub label: &'static str,
    pub suite: Suite,
    pub seed_offset: u64,
}

pub fn col(label: &'static str, suite: Suite) -> Col {
    Col { label, suite, seed_offset: 0 }
}

pub fn col_seeded(label: &'static str, suite: Suite, seed_offset: u64) -> Col {
    Col { label, suite, seed_offset }
}

pub struct Ctx {
    pub session: Session,
    pub eval: EvalCfg,
    /// Default recovery step budget (tables override per experiment).
    pub recover_steps: usize,
}

impl Ctx {
    pub fn from_args(args: &Args) -> Result<Ctx> {
        let quick = args.bool("quick");
        let mut sargs = cli::SessionArgs::parse(args)?;
        if args.get("scale").is_none() {
            sargs.scale = if quick { 0.08 } else { 1.0 };
        }
        let session = sargs.build()?;
        let mut eval = EvalCfg::default();
        eval.n_problems = args.usize_or("n", if quick { 12 } else { 40 });
        eval.k_runs = args.usize_or("k", if quick { 1 } else { 3 });
        Ok(Ctx {
            session,
            eval,
            recover_steps: args.usize_or("steps", if quick { 60 } else { 400 }),
        })
    }

    pub fn engine(&self) -> &Engine {
        self.session.engine()
    }

    pub fn report_dir(&self) -> PathBuf {
        self.session.report_dir()
    }

    /// The model's teacher (session-cached in memory + on disk).
    pub fn teacher(&self, model: &str) -> Result<Vec<f32>> {
        Ok(self.session.model(model)?.teacher()?.as_ref().clone())
    }

    pub fn rt(&self, model: &str) -> Result<ModelRuntime<'_>> {
        ModelRuntime::new(self.session.engine(), model)
    }

    /// Eval sampling config per model (paper §3.4: nano3 uses T=1.0/top-p 1).
    pub fn sample_cfg(&self, model: &str) -> SampleCfg {
        api::default_sample_cfg(model)
    }

    /// The default recovery data per model (paper §3.2).
    pub fn recovery_data(&self, model: &str) -> Vec<SourceSpec> {
        api::default_recovery_data(model)
    }

    /// Default per-model recovery LR (paper §3.4 scaled to the sim).
    pub fn recovery_lr(&self, model: &str) -> f64 {
        api::default_recovery_lr(model)
    }

    pub fn recovery_cfg(&self, model: &str) -> RecoveryCfg {
        let mut cfg = api::default_recovery_cfg(model, self.recover_steps);
        cfg.train.seed = self.session.seed();
        cfg.eval = self.eval;
        cfg
    }

    /// Run a recovery method and return the student weights.
    pub fn recover(
        &self,
        rt: &ModelRuntime,
        method: Method,
        teacher: &[f32],
        cfg: &RecoveryCfg,
    ) -> Result<Vec<f32>> {
        Ok(run_method(self.engine(), rt, method, teacher, cfg)?.params)
    }

    /// Evaluate weights over labelled columns (per-column problem seeds).
    pub fn eval_cols(
        &self,
        rt: &ModelRuntime,
        method: Method,
        params: &[f32],
        cols: &[Col],
    ) -> Result<BTreeMap<&'static str, f64>> {
        let wbuf = self.engine().upload_f32(params, &[params.len()])?;
        let mut out = BTreeMap::new();
        for c in cols {
            let mut ecfg = self.eval;
            ecfg.sample = self.sample_cfg(&rt.model.name);
            ecfg.problem_seed = ecfg.problem_seed.wrapping_add(c.seed_offset);
            let r = run_suite(self.engine(), rt, method.fwd_key(), &wbuf, c.suite, &ecfg)?;
            out.insert(c.label, r.accuracy);
        }
        Ok(out)
    }

    /// Standard method row: name + accuracy cells in column order.
    pub fn method_row(
        &self,
        label: &str,
        cols: &[Col],
        accs: &BTreeMap<&'static str, f64>,
        paper: &[f64],
    ) -> Vec<String> {
        let mut row = vec![label.to_string()];
        for (i, c) in cols.iter().enumerate() {
            let m = accs.get(c.label).copied().unwrap_or(f64::NAN);
            let p = paper.get(i).copied();
            row.push(super::report::cell(m, p));
        }
        row
    }
}

/// Method lists used by several tables.
pub const STANDARD_METHODS: &[Method] = &[Method::Bf16, Method::Ptq, Method::Qat, Method::Qad];

/// Run PTQ/QAT/QAD/BF16 for one model over given columns; returns
/// method → (column → accuracy).
pub fn run_standard_methods(
    ctx: &Ctx,
    model: &str,
    cols: &[Col],
    cfg_override: Option<RecoveryCfg>,
) -> Result<Vec<(Method, BTreeMap<&'static str, f64>)>> {
    let teacher = ctx.teacher(model)?;
    let rt = ctx.rt(model)?;
    let cfg = cfg_override.unwrap_or_else(|| ctx.recovery_cfg(model));
    let mut out = Vec::new();
    for &m in STANDARD_METHODS {
        let params = match m {
            Method::Bf16 | Method::Ptq => teacher.clone(),
            _ => ctx.recover(&rt, m, &teacher, &cfg)?,
        };
        let accs = ctx.eval_cols(&rt, m, &params, cols)?;
        eprintln!("  [{model}] {}: {accs:?}", m.name());
        out.push((m, accs));
    }
    Ok(out)
}
