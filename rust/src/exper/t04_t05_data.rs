//! Table 4 — cross-domain transfer (math-only / code-only / math+code QAD)
//! Table 5 — training-data-quality ablation (5 sources).
//! Both on AceReason Nemotron 1.1 7B → ace-sim.

use anyhow::Result;

use super::common::{col_seeded, Col, Ctx};
use super::report::TableReport;
use crate::coordinator::pipeline::{CODE_SUITES, MATH_SUITES};
use crate::coordinator::Method;
use crate::data::{SourceKind, SourceSpec, Suite};

fn ace_cols() -> Vec<Col> {
    vec![
        col_seeded("AIME24", Suite::Aime, 24),
        col_seeded("AIME25", Suite::Aime, 25),
        col_seeded("LCB-v6", Suite::Lcb, 0),
    ]
}

fn baseline_rows(
    ctx: &Ctx,
    report: &mut TableReport,
    cols: &[Col],
    teacher: &[f32],
    rt: &crate::runtime::ModelRuntime,
) -> Result<()> {
    let bf = ctx.eval_cols(rt, Method::Bf16, teacher, cols)?;
    report.row(ctx.method_row("BF16 Baseline", cols, &bf, &[73.0, 63.5, 54.3]));
    let ptq = ctx.eval_cols(rt, Method::Ptq, teacher, cols)?;
    report.row(ctx.method_row("NVFP4 PTQ", cols, &ptq, &[69.4, 58.7, 52.0]));
    Ok(())
}

pub fn run_table4(ctx: &Ctx) -> Result<TableReport> {
    let model = "ace-sim";
    let teacher = ctx.teacher(model)?;
    let rt = ctx.rt(model)?;
    let cols = ace_cols();
    let mut report = TableReport::new(
        "table4",
        "QAD with partial domain coverage (cross-domain transfer)",
        &["Training data", "AIME24", "AIME25", "LCB-v6"],
    );
    baseline_rows(ctx, &mut report, &cols, &teacher, &rt)?;

    let variants: [(&str, &[Suite], [f64; 3]); 3] = [
        ("QAD (math only)", MATH_SUITES, [71.0, 61.7, 53.1]),
        ("QAD (code only)", CODE_SUITES, [71.0, 62.0, 53.3]),
        ("QAD (math+code)", &[Suite::Math500, Suite::Aime, Suite::Lcb, Suite::SciCode], [71.7, 62.0, 53.3]),
    ];
    for (label, suites, paper) in variants {
        let mut cfg = ctx.recovery_cfg(model);
        cfg.data = vec![SourceSpec::sft_quality(suites, 0.7)];
        let params = ctx.recover(&rt, Method::Qad, &teacher, &cfg)?;
        let accs = ctx.eval_cols(&rt, Method::Qad, &params, &cols)?;
        eprintln!("  [table4] {label}: {accs:?}");
        report.row(ctx.method_row(label, &cols, &accs, &paper));
    }
    report.note("expected shape: code-only QAD still recovers math accuracy (teacher soft labels transfer)");
    Ok(report)
}

pub fn run_table5(ctx: &Ctx) -> Result<TableReport> {
    let model = "ace-sim";
    let teacher = ctx.teacher(model)?;
    let rt = ctx.rt(model)?;
    let cols = ace_cols();
    let all: &[Suite] = &[Suite::Math500, Suite::Aime, Suite::Lcb, Suite::SciCode];
    let mut report = TableReport::new(
        "table5",
        "Impact of training data source on QAD",
        &["Training data", "AIME24", "AIME25", "LCB-v6"],
    );
    baseline_rows(ctx, &mut report, &cols, &teacher, &rt)?;

    let sources: [(&str, SourceSpec, [f64; 3]); 5] = [
        (
            "SFT data",
            SourceSpec::sft_quality(all, 0.7),
            [71.7, 62.0, 53.3],
        ),
        (
            "Generated from RL prompts",
            SourceSpec { kind: SourceKind::RlGenerated, suites: all.to_vec(), weight: 1.0 },
            [71.9, 61.3, 52.6],
        ),
        (
            "Generated (correct only)",
            SourceSpec {
                kind: SourceKind::RlGeneratedCorrectOnly,
                suites: all.to_vec(),
                weight: 1.0,
            },
            [70.5, 61.6, 52.3],
        ),
        (
            "Generated from BOS token",
            SourceSpec { kind: SourceKind::BosGenerated, suites: vec![], weight: 1.0 },
            [70.1, 60.9, 52.4],
        ),
        (
            "Random tokens",
            SourceSpec { kind: SourceKind::RandomTokens, suites: vec![], weight: 1.0 },
            [68.6, 60.0, 51.7],
        ),
    ];
    for (label, spec, paper) in sources {
        let mut cfg = ctx.recovery_cfg(model);
        cfg.data = vec![spec];
        let params = ctx.recover(&rt, Method::Qad, &teacher, &cfg)?;
        let accs = ctx.eval_cols(&rt, Method::Qad, &params, &cols)?;
        eprintln!("  [table5] {label}: {accs:?}");
        report.row(ctx.method_row(label, &cols, &accs, &paper));
    }
    report.note("expected shape: SFT ≈ RL-generated > BOS-generated > random ≥ PTQ; nothing breaks the model");
    Ok(report)
}
