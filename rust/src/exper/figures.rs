//! Figure 1 — the quantitative content of the paper's QAT-vs-QAD schematic:
//! measured training curves of KL-vs-teacher and CE-vs-labels for both
//! methods (CSV series under runs/report/figure1.csv).
//!
//! Figure 2 — QAT/QAD vs *native quantized training*: the nqt_nvfp4 step
//! also quantizes the gradient path (Wgrad/Dgrad proxy); compare recovery
//! quality and per-step cost.

use std::time::Instant;

use anyhow::Result;

use super::common::{col, col_seeded, Ctx};
use super::report::TableReport;
use crate::coordinator::{pipeline, Method, Trainer};
use crate::data::{shape_for, BatchFactory, SourceSpec, Suite};
use crate::eval::eval_distribution;
use crate::runtime::DeviceState;
use crate::util::CsvWriter;

pub fn run_figure1(ctx: &Ctx) -> Result<TableReport> {
    let model = "super-sim";
    let teacher = ctx.teacher(model)?;
    let rt = ctx.rt(model)?;
    let suites = pipeline::train_suites(model);
    let spec = SourceSpec::sft(suites);
    let shape = shape_for(&rt.model);
    let cfg = ctx.recovery_cfg(model);
    let segments = 8usize;
    let seg_steps = (cfg.train.steps / segments).max(5);

    let mut csv = CsvWriter::create(
        &ctx.report_dir().join("figure1.csv"),
        &["method", "step", "train_loss", "kl_vs_teacher", "ce_vs_labels"],
    )?;
    let mut report = TableReport::new(
        "figure1",
        "QAT vs QAD training dynamics (KL to teacher / CE to labels)",
        &["Method", "step", "KL vs teacher", "CE vs labels"],
    );

    for (method, step_key) in [(Method::Qat, "qat_nvfp4"), (Method::Qad, "qad_nvfp4")] {
        let mut factory = BatchFactory::new(shape, vec![spec.clone()], 0xf16);
        let teacher_buf = rt.upload_params(&teacher)?;
        let mut state = DeviceState::from_params(&rt, &teacher)?;
        let trainer = Trainer::new(ctx.engine(), &rt);
        let mut seg_cfg = cfg.train.clone();
        seg_cfg.steps = seg_steps;
        seg_cfg.val_every = 0;
        seg_cfg.log_every = seg_steps;
        for seg in 0..segments {
            let log = trainer.train(step_key, &mut state, &mut factory, Some(&teacher_buf), None, &seg_cfg)?;
            let params = state.params()?;
            let mut vf = BatchFactory::new(shape, vec![spec.clone()], 0xe7a1);
            let m = eval_distribution(
                ctx.engine(), &rt, "eval_nvfp4", &params, &teacher, &mut vf, &spec, 4,
            )?;
            let step = (seg + 1) * seg_steps;
            csv.row_f64(
                method.name(),
                &[step as f64, log.final_loss, m.kl, m.ce],
            )?;
            if seg == segments - 1 || seg == segments / 2 - 1 {
                report.row(vec![
                    method.name().into(),
                    format!("{step}"),
                    format!("{:.4}", m.kl),
                    format!("{:.3}", m.ce),
                ]);
            }
            eprintln!("  [figure1] {} step {step}: kl={:.4} ce={:.3}", method.name(), m.kl, m.ce);
        }
    }
    report.note("full series in runs/report/figure1.csv");
    report.note("expected shape: QAD drives KL→0; QAT lowers CE but leaves KL high (distribution drift)");
    Ok(report)
}

pub fn run_figure2(ctx: &Ctx) -> Result<TableReport> {
    let model = "ace-sim";
    let teacher = ctx.teacher(model)?;
    let rt = ctx.rt(model)?;
    let cols = vec![
        col_seeded("AIME24", Suite::Aime, 24),
        col_seeded("AIME25", Suite::Aime, 25),
        col("LCB", Suite::Lcb),
    ];
    let mut report = TableReport::new(
        "figure2",
        "Quantization placement: fwd-only (QAT/QAD) vs fwd+grad (native-QT proxy)",
        &["Variant", "AIME24", "AIME25", "LCB", "ms/step"],
    );
    let cfg = ctx.recovery_cfg(model);
    for method in [Method::Qad, Method::Qat, Method::Nqt] {
        let t0 = Instant::now();
        let params = ctx.recover(&rt, method, &teacher, &cfg)?;
        let ms = t0.elapsed().as_millis() as f64 / cfg.train.steps as f64;
        let accs = ctx.eval_cols(&rt, method, &params, &cols)?;
        eprintln!("  [figure2] {}: {accs:?} {ms:.0}ms/step", method.name());
        let mut row = vec![method.name().to_string()];
        for c in &cols {
            row.push(format!("{:.1}", accs[c.label]));
        }
        row.push(format!("{ms:.0}"));
        report.row(row);
    }
    report.note("native-QT proxy quantizes the gradient vector (Wgrad/Dgrad stand-in, DESIGN.md)");
    report.note("expected shape: fwd-only recovery ≥ fwd+grad; QAD best");
    Ok(report)
}
