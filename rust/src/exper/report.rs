//! Table/figure report type: paper rows next to measured rows, printed as a
//! fixed-width table and saved under runs/report/.

use std::path::Path;

use anyhow::Result;

use crate::util::format_table;

#[derive(Debug, Clone)]
pub struct TableReport {
    pub id: String,
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form notes (paper-vs-sim caveats, substitutions).
    pub notes: Vec<String>,
}

impl TableReport {
    pub fn new(id: &str, title: &str, headers: &[&str]) -> TableReport {
        TableReport {
            id: id.to_string(),
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
            notes: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        self.rows.push(cells);
    }

    pub fn note(&mut self, s: impl Into<String>) {
        self.notes.push(s.into());
    }

    pub fn render(&self) -> String {
        let mut out = format!("== {} — {} ==\n", self.id, self.title);
        let headers: Vec<&str> = self.headers.iter().map(|s| s.as_str()).collect();
        out.push_str(&format_table(&headers, &self.rows));
        for n in &self.notes {
            out.push_str(&format!("note: {n}\n"));
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Save `<dir>/<id>.txt` and `<dir>/<id>.csv`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        std::fs::create_dir_all(dir)?;
        std::fs::write(dir.join(format!("{}.txt", self.id)), self.render())?;
        let mut csv = self.headers.join(",") + "\n";
        for r in &self.rows {
            csv.push_str(&r.join(","));
            csv.push('\n');
        }
        std::fs::write(dir.join(format!("{}.csv", self.id)), csv)?;
        Ok(())
    }
}

/// Format an accuracy cell: "measured (paper P)".
pub fn cell(measured: f64, paper: Option<f64>) -> String {
    match paper {
        Some(p) => format!("{measured:.1} (paper {p})"),
        None => format!("{measured:.1}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_and_save() {
        let mut t = TableReport::new("t00", "demo", &["method", "acc"]);
        t.row(vec!["QAD".into(), cell(93.25, Some(94.6))]);
        t.note("sim-scale");
        let s = t.render();
        assert!(s.contains("t00") && s.contains("93.2 (paper 94.6)") && s.contains("note:"));
        let dir = std::env::temp_dir().join("qadx_report_test");
        t.save(&dir).unwrap();
        assert!(dir.join("t00.txt").exists());
        assert!(dir.join("t00.csv").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
