//! Table 2 (SFT-heavy models) and Table 3 (RL-heavy models):
//! BF16 / PTQ / QAT / QAD accuracy across benchmark columns.
//!
//! The paper's central claims:
//!   Table 2 — QAD ≥ QAT on SFT-heavy multi-stage models, near-BF16.
//!   Table 3 — QAT *breaks* RL-trained models (below PTQ); QAD recovers.

use anyhow::Result;

use super::common::{col, col_seeded, run_standard_methods, Col, Ctx};
use super::report::TableReport;
use crate::data::Suite;

fn model_section(
    ctx: &Ctx,
    report: &mut TableReport,
    model: &str,
    cols: &[Col],
    paper_rows: &[(&str, &[f64])],
) -> Result<()> {
    let results = run_standard_methods(ctx, model, cols, None)?;
    for ((method, accs), (label, paper)) in results.iter().zip(paper_rows) {
        debug_assert_eq!(method.name().contains("QAD"), label.contains("QAD"));
        let mut row = vec![model.to_string()];
        row.extend(ctx.method_row(label, cols, accs, paper).into_iter());
        report.row(row);
    }
    Ok(())
}

pub fn run_table2(ctx: &Ctx) -> Result<TableReport> {
    let cols = [
        col("MATH500", Suite::Math500),
        col_seeded("AIME25", Suite::Aime, 25),
        col("GPQA-D", Suite::Gpqa),
        col("IFEval-Instr", Suite::Ifeval),
    ];
    let mut report = TableReport::new(
        "table2",
        "SFT-heavy models: QAD near-BF16, beats QAT on reasoning",
        &["Model", "Method", "MATH500", "AIME25", "GPQA-D", "IFEval-Instr"],
    );
    // Llama Nemotron Super V1 → super-sim
    model_section(
        ctx,
        &mut report,
        "super-sim",
        &cols,
        &[
            ("BF16", &[95.8, 46.0, 66.5, 87.5]),
            ("NVFP4 PTQ", &[91.4, 32.3, 62.1, 86.9]),
            ("NVFP4 QAT", &[94.3, 41.5, 63.3, 87.2]),
            ("NVFP4 QAD", &[94.6, 45.6, 64.5, 87.8]),
        ],
    )?;
    // Nemotron Nano 9B V2 → nano-sim (selective quantization config)
    model_section(
        ctx,
        &mut report,
        "nano-sim",
        &cols,
        &[
            ("BF16", &[97.8, 71.1, 64.0, 90.3]),
            ("NVFP4 PTQ", &[97.2, 69.8, 59.0, 89.8]),
            ("NVFP4 QAT", &[97.2, 67.1, 56.9, 86.2]),
            ("NVFP4 QAD", &[97.2, 71.5, 62.7, 89.3]),
        ],
    )?;
    report.note("paper: Llama Nemotron Super V1 49B + Nemotron Nano 9B V2; sim: super-sim + nano-sim");
    report.note("expected shape: PTQ < QAT < QAD ≈ BF16, largest QAD-QAT gap on hard-reasoning columns");
    Ok(report)
}

pub fn run_table3(ctx: &Ctx) -> Result<TableReport> {
    // (a) Nemotron 3 Nano 30B-A3B → nano3-sim
    let cols_a = [
        col("AA-LCR", Suite::AaLcr),
        col_seeded("AIME25", Suite::Aime, 25),
        col("GPQA-D", Suite::Gpqa),
        col("LCB-v5", Suite::Lcb),
        col("SciCode", Suite::SciCode),
    ];
    let mut report = TableReport::new(
        "table3",
        "RL-heavy models: QAT breaks RL capabilities, QAD recovers",
        &["Model", "Method", "c1", "c2", "c3", "c4", "c5"],
    );
    report.note("(a) nano3-sim cols: AA-LCR AIME25 GPQA-D LiveCodeBench-v5 SciCode");
    report.note("(b) ace-sim cols: AIME24 AIME25 LiveCodeBench-v6 (c4,c5 = '-')");
    model_section(
        ctx,
        &mut report,
        "nano3-sim",
        &cols_a,
        &[
            ("BF16", &[35.9, 89.1, 73.0, 72.1, 33.0]),
            ("NVFP4 PTQ", &[31.3, 85.0, 71.6, 68.9, 30.5]),
            ("NVFP4 QAT", &[f64::NAN, f64::NAN, 66.0, f64::NAN, 25.8]),
            ("NVFP4 QAD", &[34.3, 87.9, 72.7, 68.9, 32.3]),
        ],
    )?;
    // (b) AceReason Nemotron 1.1 7B → ace-sim
    let cols_b = [
        col_seeded("AIME24", Suite::Aime, 24),
        col_seeded("AIME25", Suite::Aime, 25),
        col("LCB-v6", Suite::Lcb),
    ];
    let results = run_standard_methods(ctx, "ace-sim", &cols_b, None)?;
    let paper_b: [(&str, [f64; 3]); 4] = [
        ("BF16", [73.0, 63.5, 54.3]),
        ("NVFP4 PTQ", [69.4, 58.7, 52.0]),
        ("NVFP4 QAT", [62.1, 46.1, 45.9]),
        ("NVFP4 QAD", [71.7, 62.0, 53.3]),
    ];
    for ((_, accs), (label, paper)) in results.iter().zip(&paper_b) {
        let mut row = vec!["ace-sim".to_string()];
        row.extend(ctx.method_row(label, &cols_b, accs, paper));
        row.push("-".into());
        row.push("-".into());
        report.row(row);
    }
    report.note("expected shape: QAT < PTQ (capability breakage); QAD ≈ BF16");
    report.note("QAT/QAD train on cold-start SFT data (ace) / SFT+RL-gen mixture (nano3), as in §3.2");
    Ok(report)
}
