//! Table 6 — learning-rate sensitivity: RL-heavy (ace-sim) wants a larger
//! QAD LR than SFT-heavy (nano-sim).
//! Table 7 — LR sensitivity for the VLM (vl-sim): best well below the
//! original SFT LR; too-high LR collapses accuracy.
//!
//! Sim LR grids are the paper grids shifted by the sim/paper LR ratio
//! (sim post-training uses ~2e-3 vs the paper's ~2e-5; see DESIGN.md §5).

use anyhow::Result;

use super::common::{col, col_seeded, Col, Ctx};
use super::report::TableReport;
use crate::coordinator::Method;
use crate::data::{SourceSpec, Suite, VISION_SUITES};

pub fn run_table6(ctx: &Ctx) -> Result<TableReport> {
    let cols = vec![
        col_seeded("AIME24", Suite::Aime, 24),
        col_seeded("AIME25", Suite::Aime, 25),
        col("LCB", Suite::Lcb),
    ];
    let mut report = TableReport::new(
        "table6",
        "QAD learning-rate sensitivity (RL-heavy vs SFT-heavy)",
        &["Model", "LR (sim)", "AIME24", "AIME25", "LCB"],
    );
    // paper rows for reference ordering (smallest -> largest LR)
    let paper_ace: [[f64; 3]; 4] = [
        [70.8, 61.0, 52.6],
        [71.0, 60.9, 53.2],
        [71.7, 62.0, 53.3],
        [72.4, 61.8, 53.0],
    ];
    let paper_nano: [[f64; 3]; 4] = [
        [80.4, 71.5, 67.8],
        [80.0, 71.0, 66.8],
        [80.8, 69.4, 67.4],
        [78.8, 65.2, 64.0],
    ];
    let lrs = [1e-5, 1e-4, 3e-4, 1e-3];
    for (model, paper) in [("ace-sim", &paper_ace), ("nano-sim", &paper_nano)] {
        let teacher = ctx.teacher(model)?;
        let rt = ctx.rt(model)?;
        for (i, &lr) in lrs.iter().enumerate() {
            let mut cfg = ctx.recovery_cfg(model);
            cfg.train.lr = lr;
            let params = ctx.recover(&rt, Method::Qad, &teacher, &cfg)?;
            let accs = ctx.eval_cols(&rt, Method::Qad, &params, &cols)?;
            eprintln!("  [table6] {model} lr={lr:.0e}: {accs:?}");
            let mut row = vec![model.to_string(), format!("{lr:.0e}")];
            for (j, c) in cols.iter().enumerate() {
                row.push(super::report::cell(accs[c.label], Some(paper[i][j])));
            }
            report.row(row);
        }
    }
    report.note("paper LRs 1e-6..1e-4 map to sim LRs 1e-5..1e-3 (sim post-training LR is ~100x larger)");
    report.note("expected shape: ace-sim (RL-heavy) peaks at a larger LR than nano-sim (SFT-heavy)");
    Ok(report)
}

pub fn run_table7(ctx: &Ctx) -> Result<TableReport> {
    let model = "vl-sim";
    let cols: Vec<Col> = VISION_SUITES
        .iter()
        .map(|&s| col(Box::leak(s.name().to_string().into_boxed_str()), s))
        .collect();
    let mut report = TableReport::new(
        "table7",
        "LR sensitivity for the VLM (QAD)",
        &["LR (sim)", "ai2d", "chartqa", "docvqa", "infovqa", "ocrbench", "textvqa"],
    );
    let teacher = ctx.teacher(model)?;
    let rt = ctx.rt(model)?;
    // paper: 1e-4 (collapse) / 2e-5 (original SFT lr) / 2e-6 (best)
    let paper: [[f64; 6]; 3] = [
        [67.0, 76.0, 75.0, 47.6, 68.5, 70.6],
        [85.3, 87.6, 91.6, 72.2, 82.0, 82.8],
        [87.1, 89.7, 94.0, 78.9, 85.7, 84.7],
    ];
    for (i, lr) in [1e-2, 2e-3, 2e-4].into_iter().enumerate() {
        let mut cfg = ctx.recovery_cfg(model);
        cfg.train.lr = lr;
        cfg.data = vec![SourceSpec::sft(VISION_SUITES)];
        let params = ctx.recover(&rt, Method::Qad, &teacher, &cfg)?;
        let accs = ctx.eval_cols(&rt, Method::Qad, &params, &cols)?;
        eprintln!("  [table7] lr={lr:.0e}: {accs:?}");
        let mut row = vec![format!("{lr:.0e}")];
        for (j, c) in cols.iter().enumerate() {
            row.push(super::report::cell(accs[c.label], Some(paper[i][j])));
        }
        report.row(row);
    }
    report.note("paper OCRBench is /1000; quoted here /10 to compare with sim accuracy (%)");
    report.note("expected shape: accuracy degrades monotonically as LR rises above the sweet spot");
    Ok(report)
}
