//! Experiment harness: one driver per paper table/figure (DESIGN.md §4).
//!
//! `qadx table <n>` regenerates one table; `qadx all-tables` runs the full
//! evaluation section. Reports are printed and saved to runs/report/.

pub mod common;
pub mod figures;
pub mod report;
pub mod t01_alignment;
pub mod t02_t03_heavy;
pub mod t04_t05_data;
pub mod t06_t07_lr;
pub mod t08_t11;
pub mod t12_size;

use anyhow::{bail, Result};

use crate::util::args::Args;
use crate::util::Timer;
use common::Ctx;
use report::TableReport;

pub fn run_table(ctx: &Ctx, n: usize) -> Result<TableReport> {
    Ok(match n {
        1 => t01_alignment::run(ctx)?,
        2 => t02_t03_heavy::run_table2(ctx)?,
        3 => t02_t03_heavy::run_table3(ctx)?,
        4 => t04_t05_data::run_table4(ctx)?,
        5 => t04_t05_data::run_table5(ctx)?,
        6 => t06_t07_lr::run_table6(ctx)?,
        7 => t06_t07_lr::run_table7(ctx)?,
        8 => t08_t11::run_table8(ctx)?,
        9 => t08_t11::run_table9(ctx)?,
        10 => t08_t11::run_table10(ctx)?,
        11 => t08_t11::run_table11(ctx)?,
        12 => t12_size::run(ctx)?,
        other => bail!("no table {other} (1..=12)"),
    })
}

pub fn run_figure(ctx: &Ctx, n: usize) -> Result<TableReport> {
    Ok(match n {
        1 => figures::run_figure1(ctx)?,
        2 => figures::run_figure2(ctx)?,
        other => bail!("no figure {other} (1..=2)"),
    })
}

pub fn run_table_cmd(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .get(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("usage: qadx table <1..12>"))?;
    let ctx = Ctx::from_args(args)?;
    let timer = Timer::start(&format!("table{n}"));
    let rep = run_table(&ctx, n)?;
    rep.print();
    rep.save(&ctx.report_dir())?;
    eprintln!("{}", timer.report());
    Ok(())
}

pub fn run_figure_cmd(args: &Args) -> Result<()> {
    let n: usize = args
        .positional
        .get(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow::anyhow!("usage: qadx figure <1|2>"))?;
    let ctx = Ctx::from_args(args)?;
    let rep = run_figure(&ctx, n)?;
    rep.print();
    rep.save(&ctx.report_dir())?;
    Ok(())
}

pub fn run_all_tables(args: &Args) -> Result<()> {
    let ctx = Ctx::from_args(args)?;
    let total = Timer::start("all-tables");
    let only: Option<Vec<usize>> =
        args.get("only").map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect());
    let selected = |n: usize| only.as_ref().map(|f| f.contains(&n)).unwrap_or(true);
    for n in 1..=12 {
        if !selected(n) {
            continue;
        }
        let timer = Timer::start(&format!("table{n}"));
        match run_table(&ctx, n) {
            Ok(rep) => {
                rep.print();
                rep.save(&ctx.report_dir())?;
            }
            Err(e) => eprintln!("table{n} FAILED: {e:#}"),
        }
        eprintln!("{}", timer.report());
    }
    for n in 1..=2 {
        if !selected(100 + n) {
            continue;
        }
        match run_figure(&ctx, n) {
            Ok(rep) => {
                rep.print();
                rep.save(&ctx.report_dir())?;
            }
            Err(e) => eprintln!("figure{n} FAILED: {e:#}"),
        }
    }
    eprintln!("{}", total.report());
    Ok(())
}
