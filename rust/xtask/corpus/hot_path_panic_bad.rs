// corpus: hot-path-panic MUST fire — unwrap/expect/panic! and (with
// index_check) bare slice indexing inside a configured scheduler
// function can kill every in-flight request.
impl Handle {
    fn admit(&mut self) -> Result<usize> {
        let q = self.queue.pop_front().expect("checked non-empty");
        let first = q.prompt[0];
        let parsed = parse(first).unwrap();
        if parsed == 0 {
            panic!("zero token");
        }
        Ok(parsed)
    }

    fn cold_helper(&self) -> usize {
        // not in the hot-fn list: unwrap here is out of scope
        self.queue.front().unwrap().prompt.len()
    }
}
