// corpus: nondet-iteration must NOT fire — BTreeMap everywhere, and the
// only HashMap mentions are a `use` line (no-op by itself) plus test
// scaffolding, which the module rules exempt.
use std::collections::BTreeMap;
use std::collections::HashMap;

pub struct Report {
    pub per_layer: BTreeMap<String, f32>,
}

pub fn collect() -> BTreeMap<String, f32> {
    let mut m = BTreeMap::new();
    m.insert("a".to_string(), 1.0);
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scratch_maps_are_fine_in_tests() {
        let mut scratch: HashMap<u32, u32> = HashMap::new();
        scratch.insert(1, 2);
        assert_eq!(collect().len(), 1);
    }
}
