// corpus: a well-formed allow-annotation (rule + `--` + reason) covers
// the finding on its own line or the next code line; the finding is
// still reported, but as allowed, and the gate stays green.
use std::collections::HashMap;

pub struct Cache {
    // qadx-lint: allow(nondet-iteration) -- get/insert only, never iterated into output
    pub inner: HashMap<String, u32>,
}

pub fn build() -> HashMap<String, u32> { // qadx-lint: allow(nondet-iteration) -- mirrors Cache::inner
    HashMap::new() // qadx-lint: allow(nondet-iteration) -- mirrors Cache::inner
}
