// corpus: annotation meta-rule MUST fire — an allow-annotation that no
// finding needs is stale (the violation it excused was fixed or moved)
// and must be deleted, or it will excuse a future regression.
pub fn f() -> u32 {
    // qadx-lint: allow(nondet-iteration) -- this code no longer uses a map
    1
}
