// corpus: annotation meta-rule MUST fire — an allow without a
// `-- reason` is itself a finding, and it suppresses nothing, so the
// underlying nondet-iteration finding stays unallowed too.
use std::collections::HashMap;

pub struct Cache {
    // qadx-lint: allow(nondet-iteration)
    pub inner: HashMap<String, u32>,
}
