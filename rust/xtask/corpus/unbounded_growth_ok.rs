//! Clean twin: every grow call sits in an admission path, drains are free,
//! and the one deliberate helper carries a reasoned allow-annotation.

struct Router {
    lane_int: std::collections::VecDeque<u64>,
    lane_bat: std::collections::VecDeque<u64>,
}

impl Router {
    fn submit_class(&mut self, id: u64, cap: usize) {
        if self.lane_int.len() + self.lane_bat.len() >= cap {
            return;
        }
        self.lane_int.push_back(id);
    }

    fn requeue(&mut self, id: u64) {
        // put-back of already-admitted work is itself an admission path
        self.lane_bat.push_front(id);
    }

    fn next(&mut self) -> Option<u64> {
        self.lane_int.pop_front().or_else(|| self.lane_bat.pop_front())
    }

    fn sanctioned_helper(&mut self, id: u64) {
        // qadx-lint: allow(unbounded-growth) -- every caller sits behind submit_class's cap check
        self.lane_int.push_back(id);
    }
}
