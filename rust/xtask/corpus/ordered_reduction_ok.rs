// corpus: ordered-reduction must NOT fire — accumulation stays inside
// the closure (chunk-local partials), and the cross-chunk combine is a
// sequential pass outside the parallel region. This is the repo's
// sanctioned two-pass reduction shape.
fn dot(a: &[f32], b: &[f32], partials: &mut [f32]) -> f32 {
    crate::util::pool::for_chunks2(partials.len(), a, 1, b, 1, |_i, ca, cb| {
        let mut local = 0.0f32;
        for (x, y) in ca.iter().zip(cb) {
            local += x * y;
        }
        let s: f32 = ca.iter().sum();
        let _ = s;
    });
    let mut acc = 0.0f32;
    for p in partials.iter() {
        acc += p; // sequential combine outside for_chunks: deterministic
    }
    acc
}
