// corpus: annotation meta-rule MUST fire — allow() naming a rule the
// linter does not know is a typo that would otherwise rot silently.
pub fn f() -> u32 {
    // qadx-lint: allow(nondet-interation) -- typo'd rule name
    1
}
