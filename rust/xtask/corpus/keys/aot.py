# corpus: python lowering side for the artifact-keys cross-check.
# Lowers fwd_bf16 and the fwd_last_* family, plus one key the Rust side
# never references (mse_python_only -> MUST fire) and one deliberately
# one-sided key excused by the python-side allow-annotation.
KEYS = ["fwd_bf16", "scalars"]

def emit(fmt):
    write(f"fwd_last_{fmt}")

# qadx-lint: allow(artifact-keys) -- lowered for external tooling only
EXTRA = "nqt_external_probe"

ORPHAN = "mse_python_only"
