// corpus: Rust runtime side for the artifact-keys cross-check. Loads
// fwd_bf16 / scalars / the fwd_last_* family (all covered by aot.py)
// plus one key python never lowers (qad_rust_only -> MUST fire).
pub fn load_all(m: &Manifest) -> Result<()> {
    m.load("fwd_bf16")?;
    m.load("scalars")?;
    let k = format!("fwd_last_{}", fmt);
    m.load(&k)?;
    m.load("qad_rust_only")?;
    Ok(())
}
