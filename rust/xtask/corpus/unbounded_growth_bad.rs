//! unbounded-growth corpus: lane growth outside the admission-checked paths.

struct Router {
    lane_int: std::collections::VecDeque<u64>,
    lane_bat: std::collections::VecDeque<u64>,
}

impl Router {
    fn submit_class(&mut self, id: u64) {
        // admission-checked entry point: growth here is sanctioned
        self.lane_int.push_back(id);
    }

    fn sneak_in(&mut self, id: u64) {
        // grows a bounded lane with no admission check in sight
        self.lane_bat.push_back(id);
    }

    fn backfill(&mut self, id: u64) {
        self.lane_int.push_front(id);
    }
}
