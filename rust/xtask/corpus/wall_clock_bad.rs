// corpus: wall-clock MUST fire — reading the clock inside a numeric
// kernel ties its behavior to wall time; timing belongs to callers.
use std::time::Instant;

pub fn gemm_block(a: &[f32], b: &[f32], c: &mut [f32]) {
    let t0 = Instant::now();
    for (i, x) in a.iter().enumerate() {
        c[i] = x * b[i];
    }
    let _elapsed = t0.elapsed();
}
