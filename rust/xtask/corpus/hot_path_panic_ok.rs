// corpus: hot-path-panic must NOT fire — the same scheduler function
// written in the degrade-through-Result shape: let-else, get(),
// unwrap_or, and error values instead of panics.
impl Handle {
    fn admit(&mut self) -> Result<usize> {
        let Some(q) = self.queue.pop_front() else {
            return Ok(0);
        };
        let first = q.prompt.get(0).copied().unwrap_or_default();
        let parsed = parse(first)?;
        if parsed == 0 {
            return Err(anyhow!("zero token"));
        }
        Ok(parsed)
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn tests_may_unwrap_and_index() {
        let v = vec![1, 2, 3];
        assert_eq!(v[0], *v.first().unwrap());
    }
}
