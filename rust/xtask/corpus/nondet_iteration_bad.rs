// corpus: nondet-iteration MUST fire — a HashMap in a module whose
// output is serialized (telemetry / manifest / reports) makes emission
// order depend on the hasher seed.
use std::collections::HashMap;

pub struct Report {
    pub per_layer: HashMap<String, f32>,
}

pub fn collect() -> HashMap<String, f32> {
    let mut m = HashMap::new();
    m.insert("a".to_string(), 1.0);
    m
}
