// corpus: ordered-reduction MUST fire — the closure accumulates into
// state captured from the enclosing scope, so the reduction order (and
// therefore the float result) depends on chunk scheduling.
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    crate::util::pool::for_chunks2(a.len(), a, 1, b, 1, |_i, ca, cb| {
        for (x, y) in ca.iter().zip(cb) {
            acc += x * y;
        }
    });
    acc
}

fn norm(a: &[f32]) -> f32 {
    let mut total = 0.0f32;
    crate::util::pool::for_chunks(a.len(), a, 1, |_i, chunk| {
        total = chunk.iter().map(|x| x * x).sum();
    });
    total
}
