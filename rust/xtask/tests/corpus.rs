//! Corpus tests: every violating snippet under `corpus/` must fire its
//! rule, every clean twin must stay silent, and the real repo tree must
//! lint clean (zero unallowed findings) — the same invariant CI gates on.

use std::path::Path;

use xtask::keys;
use xtask::rules::{
    Config, Finding, RULE_ANNOTATION, RULE_ARTIFACT_KEYS, RULE_HOT_PATH_PANIC,
    RULE_NONDET_ITERATION, RULE_ORDERED_REDUCTION, RULE_UNBOUNDED_GROWTH, RULE_WALL_CLOCK,
};
use xtask::{lint_snippet, run_lint};

fn unallowed<'a>(fs: &'a [Finding]) -> Vec<&'a Finding> {
    fs.iter().filter(|f| !f.allowed).collect()
}

fn rules_of(fs: &[&Finding]) -> Vec<String> {
    fs.iter().map(|f| f.rule.clone()).collect()
}

#[test]
fn ordered_reduction_bad_fires() {
    let src = include_str!("../corpus/ordered_reduction_bad.rs");
    // ordered-reduction applies everywhere, module path irrelevant
    let fs = lint_snippet("rust/src/anywhere.rs", src, &Config::repo());
    let un = unallowed(&fs);
    assert_eq!(un.len(), 2, "{un:?}");
    assert!(un.iter().all(|f| f.rule == RULE_ORDERED_REDUCTION), "{un:?}");
    // one per accumulation site: `acc +=` and the assigned `.sum()`
    let lines: Vec<u32> = un.iter().map(|f| f.line).collect();
    assert!(lines[0] < lines[1], "sorted by line: {lines:?}");
}

#[test]
fn ordered_reduction_ok_is_clean() {
    let src = include_str!("../corpus/ordered_reduction_ok.rs");
    let fs = lint_snippet("rust/src/anywhere.rs", src, &Config::repo());
    assert!(unallowed(&fs).is_empty(), "{fs:?}");
}

#[test]
fn nondet_iteration_bad_fires_in_covered_module() {
    let src = include_str!("../corpus/nondet_iteration_bad.rs");
    let fs = lint_snippet("rust/src/quant/fake.rs", src, &Config::repo());
    let un = unallowed(&fs);
    assert_eq!(un.len(), 3, "struct field, fn signature, constructor: {un:?}");
    assert!(un.iter().all(|f| f.rule == RULE_NONDET_ITERATION), "{un:?}");
    // the `use` line alone is never a finding
    assert!(un.iter().all(|f| f.line > 4), "{un:?}");
}

#[test]
fn nondet_iteration_bad_is_out_of_scope_elsewhere() {
    let src = include_str!("../corpus/nondet_iteration_bad.rs");
    let fs = lint_snippet("rust/src/util/pool.rs", src, &Config::repo());
    assert!(unallowed(&fs).is_empty(), "pool.rs is not a nondet module: {fs:?}");
}

#[test]
fn nondet_iteration_ok_is_clean() {
    let src = include_str!("../corpus/nondet_iteration_ok.rs");
    let fs = lint_snippet("rust/src/quant/fake.rs", src, &Config::repo());
    assert!(unallowed(&fs).is_empty(), "BTreeMap + test-only HashMap: {fs:?}");
}

#[test]
fn hot_path_panic_bad_fires_per_function() {
    let src = include_str!("../corpus/hot_path_panic_bad.rs");
    // serve.rs config lists `admit` with index_check=true
    let fs = lint_snippet("rust/src/api/serve.rs", src, &Config::repo());
    let un = unallowed(&fs);
    assert_eq!(un.len(), 4, "expect, index, unwrap, panic!: {un:?}");
    assert!(un.iter().all(|f| f.rule == RULE_HOT_PATH_PANIC), "{un:?}");
    // cold_helper's unwrap (line 17) is outside the hot-fn list
    assert!(un.iter().all(|f| f.line < 15), "{un:?}");
}

#[test]
fn hot_path_panic_ok_is_clean() {
    let src = include_str!("../corpus/hot_path_panic_ok.rs");
    let fs = lint_snippet("rust/src/api/serve.rs", src, &Config::repo());
    assert!(unallowed(&fs).is_empty(), "Result shape + test scaffolding: {fs:?}");
}

#[test]
fn unbounded_growth_bad_fires_outside_admission_fns() {
    let src = include_str!("../corpus/unbounded_growth_bad.rs");
    // fleet.rs config lists lane_int/lane_bat with submit_class admission
    let fs = lint_snippet("rust/src/api/fleet.rs", src, &Config::repo());
    let un = unallowed(&fs);
    assert_eq!(un.len(), 2, "sneak_in + backfill: {un:?}");
    assert!(un.iter().all(|f| f.rule == RULE_UNBOUNDED_GROWTH), "{un:?}");
    let lines: Vec<u32> = un.iter().map(|f| f.line).collect();
    assert_eq!(lines, vec![16, 20], "submit_class's growth never surfaces: {un:?}");
    // the rule is per-file scoped: the same code elsewhere is out of scope
    let fs2 = lint_snippet("rust/src/api/session.rs", src, &Config::repo());
    assert!(unallowed(&fs2).is_empty(), "{fs2:?}");
}

#[test]
fn unbounded_growth_ok_is_clean_and_allow_reports() {
    let src = include_str!("../corpus/unbounded_growth_ok.rs");
    let fs = lint_snippet("rust/src/api/fleet.rs", src, &Config::repo());
    assert!(unallowed(&fs).is_empty(), "{fs:?}");
    let allowed: Vec<&Finding> = fs.iter().filter(|f| f.allowed).collect();
    assert_eq!(allowed.len(), 1, "only the annotated helper: {allowed:?}");
    assert_eq!(allowed[0].rule, RULE_UNBOUNDED_GROWTH);
}

#[test]
fn wall_clock_bad_fires_in_numeric_module() {
    let src = include_str!("../corpus/wall_clock_bad.rs");
    let fs = lint_snippet("rust/src/util/gemm.rs", src, &Config::repo());
    let un = unallowed(&fs);
    assert_eq!(un.len(), 1, "{un:?}");
    assert_eq!(un[0].rule, RULE_WALL_CLOCK);
    // the same file is clean where kernels are allowed to time themselves
    let fs2 = lint_snippet("rust/src/api/cli.rs", src, &Config::repo());
    assert!(unallowed(&fs2).is_empty(), "{fs2:?}");
}

#[test]
fn allow_annotation_keeps_gate_green_but_reports() {
    let src = include_str!("../corpus/allow_ok.rs");
    let fs = lint_snippet("rust/src/quant/fake.rs", src, &Config::repo());
    assert!(unallowed(&fs).is_empty(), "{fs:?}");
    let allowed: Vec<&Finding> = fs.iter().filter(|f| f.allowed).collect();
    assert_eq!(allowed.len(), 3, "standalone + two trailing annotations: {allowed:?}");
    assert!(allowed.iter().all(|f| f.rule == RULE_NONDET_ITERATION), "{allowed:?}");
}

#[test]
fn allow_without_reason_is_a_finding_and_suppresses_nothing() {
    let src = include_str!("../corpus/allow_missing_reason.rs");
    let fs = lint_snippet("rust/src/quant/fake.rs", src, &Config::repo());
    let un = unallowed(&fs);
    let rules = rules_of(&un);
    assert!(rules.contains(&RULE_ANNOTATION.to_string()), "{un:?}");
    assert!(rules.contains(&RULE_NONDET_ITERATION.to_string()), "{un:?}");
}

#[test]
fn allow_with_unknown_rule_is_a_finding() {
    let src = include_str!("../corpus/allow_unknown_rule.rs");
    let fs = lint_snippet("rust/src/quant/fake.rs", src, &Config::repo());
    let un = unallowed(&fs);
    assert!(!un.is_empty() && un.iter().any(|f| f.rule == RULE_ANNOTATION), "{un:?}");
}

#[test]
fn stale_allow_is_a_finding() {
    let src = include_str!("../corpus/stale_allow.rs");
    let fs = lint_snippet("rust/src/quant/fake.rs", src, &Config::repo());
    let un = unallowed(&fs);
    assert_eq!(un.len(), 1, "{un:?}");
    assert_eq!(un[0].rule, RULE_ANNOTATION);
    assert!(un[0].msg.contains("unused"), "{un:?}");
}

#[test]
fn keys_corpus_cross_check_fires_both_ways_and_honors_allow() {
    let rs_src = include_str!("../corpus/keys/runtime.rs");
    let py_src = include_str!("../corpus/keys/aot.py");
    let rust = keys::rust_keys("rust/src/runtime/fake.rs", &xtask::lexer::lex(rs_src));
    let python = keys::python_keys("python/compile/aot.py", py_src);
    let srcs = vec![("python/compile/aot.py".to_string(), py_src.to_string())];
    let (r, p) = keys::cross_check(&rust, &python, &srcs);
    // Rust-only key
    assert_eq!(r.len(), 1, "{r:?}");
    assert_eq!(r[0].rule, RULE_ARTIFACT_KEYS);
    assert!(r[0].msg.contains("qad_rust_only"), "{r:?}");
    // Python side: one excused by annotation, one genuinely one-sided
    assert_eq!(p.len(), 2, "{p:?}");
    let excused: Vec<_> = p.iter().filter(|f| f.allowed).collect();
    let live: Vec<_> = p.iter().filter(|f| !f.allowed).collect();
    assert_eq!(excused.len(), 1, "{p:?}");
    assert!(excused[0].msg.contains("nqt_external_probe"), "{p:?}");
    assert_eq!(live.len(), 1, "{p:?}");
    assert!(live[0].msg.contains("mse_python_only"), "{p:?}");
    // the shared keys (fwd_bf16, scalars, fwd_last_*) never surface
    assert!(!p.iter().chain(r.iter()).any(|f| f.msg.contains("fwd_")), "{p:?} {r:?}");
}

#[test]
fn real_tree_lints_clean() {
    // the invariant CI gates on: zero unallowed findings over the repo
    let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("..").join("..");
    let fs = run_lint(&root, &Config::repo()).expect("repo tree is readable");
    let un = unallowed(&fs);
    assert!(
        un.is_empty(),
        "unallowed findings in the tree:\n{}",
        un.iter()
            .map(|f| format!("  {}:{}: [{}] {}", f.file, f.line, f.rule, f.msg))
            .collect::<Vec<_>>()
            .join("\n")
    );
}
