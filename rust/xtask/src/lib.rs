//! qadx-lint library surface: the lexer, the rule passes, the
//! cross-language key check, and the repo-tree driver. The `xtask`
//! binary (`src/main.rs`) is a thin CLI over [`run_lint`]; the
//! integration tests run the same entry points against the corpus and
//! against the real tree.

pub mod keys;
pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

use rules::{analyze_source, finalize, Config, FileAnalysis, Finding};

/// Directories scanned for Rust sources, relative to the repo root.
pub const RUST_DIRS: &[&str] = &["rust/src", "rust/tests", "rust/benches", "examples"];
/// Python lowering sources for the artifact-key cross-check.
pub const PY_FILES: &[&str] = &["python/compile/aot.py", "python/compile/steps.py"];

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            collect_rs(&p, out);
        } else if p.extension().is_some_and(|e| e == "rs") {
            out.push(p);
        }
    }
}

fn rel_of(root: &Path, p: &Path) -> String {
    p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/")
}

/// Run the full analysis over a repo tree with the given enforcement map.
pub fn run_lint(root: &Path, cfg: &Config) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    for d in RUST_DIRS {
        collect_rs(&root.join(d), &mut files);
    }
    let mut analyses: Vec<FileAnalysis> = Vec::new();
    for p in &files {
        let src = std::fs::read_to_string(p)?;
        analyses.push(analyze_source(&rel_of(root, p), &src, cfg));
    }

    // cross-language artifact keys
    let mut rust_keys = Vec::new();
    for fa in &analyses {
        // benches/examples/tests invent throwaway tags; key ground truth
        // on the Rust side is the runtime + api + eval tree
        if fa.rel.starts_with("rust/src/") {
            rust_keys.extend(keys::rust_keys(&fa.rel, &fa.lexed));
        }
    }
    let mut py_srcs = Vec::new();
    let mut py_keys = Vec::new();
    for f in PY_FILES {
        let p = root.join(f);
        if let Ok(src) = std::fs::read_to_string(&p) {
            py_keys.extend(keys::python_keys(f, &src));
            py_srcs.push((f.to_string(), src));
        }
    }
    let (rust_side, py_side) = keys::cross_check(&rust_keys, &py_keys, &py_srcs);
    for f in rust_side {
        if let Some(fa) = analyses.iter_mut().find(|a| a.rel == f.file) {
            fa.findings.push(f);
        }
    }

    let mut findings = Vec::new();
    for fa in &mut analyses {
        finalize(fa);
        findings.append(&mut fa.findings);
    }
    findings.extend(py_side);
    Ok(findings)
}

/// Analyze one source string as if it lived at `rel` (corpus testing).
pub fn lint_snippet(rel: &str, src: &str, cfg: &Config) -> Vec<Finding> {
    let mut fa = analyze_source(rel, src, cfg);
    finalize(&mut fa);
    fa.findings
}
